#!/usr/bin/env bash
# Test/CI entrypoint: install declared deps (best effort — offline containers
# fall back to tests/_hypothesis_stub.py via tests/conftest.py), then run the
# tier-1 suite.
set -euo pipefail
cd "$(dirname "$0")/.."

if ! python -c "import hypothesis" >/dev/null 2>&1; then
    python -m pip install -q -r requirements.txt 2>/dev/null \
        || echo "pip install unavailable (offline?); using vendored hypothesis shim"
fi

PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" exec python -m pytest -x -q "$@"
