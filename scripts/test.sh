#!/usr/bin/env bash
# Test/CI entrypoint: install declared deps (best effort — offline containers
# fall back to tests/_hypothesis_stub.py via tests/conftest.py), then run the
# tier-1 suite + the experiment-API CLI smoke + the sweep-CLI smoke + the
# feddyn chaos smoke (SIGTERM a checkpointing FedDyn run, resume, assert
# the per-client correction state came back bitwise) + the sweep-resume
# chaos smoke (SIGTERM a --workers 2 sweep mid-matrix, then
# --resume it) + the fleet smoke (1000-client streamed cohort store vs the
# replicated oracle, bitwise), then the sharded smoke leg (round/block-engine
# + API + sweep/service/axes/fleet tests and the same CLI smokes on a forced
# 4-device host mesh, exercising the shard_map client axis on CPU).
#
# Tiering (pytest.ini): the default run selects tier-1 only (-m "not slow");
# pass --all as the FIRST argument to include slow-marked tests. Remaining
# arguments are forwarded to pytest.
set -euo pipefail
cd "$(dirname "$0")/.."

MARKER=(-m "not slow")
if [[ "${1:-}" == "--all" ]]; then
    MARKER=()
    shift
fi

if ! python -c "import hypothesis" >/dev/null 2>&1; then
    python -m pip install -q -r requirements.txt 2>/dev/null \
        || echo "pip install unavailable (offline?); using vendored hypothesis shim"
fi

# CLI smoke: run a 4-round synthetic spec through `python -m repro.api.cli
# run`, then `resume` from the mid-run checkpoint it wrote (round 2 is the
# latest checkpoint, so resume really executes round 3). Runs in BOTH legs
# — single-device and forced-4-device — so the spec -> build -> run ->
# checkpoint -> resume path is exercised on the sharded client axis too.
# NOTE: callers invoke this as `cli_smoke || status=$?`, which disables
# set -e INSIDE the function body — so every step's failure is recorded
# explicitly in `ok` (otherwise the trailing rm -rf's exit 0 would mask a
# broken CLI and the smoke legs could never fail CI).
cli_smoke() {
    local work ok=0
    work="$(mktemp -d)"
    cat > "$work/spec.json" <<'EOF'
{
  "data": {"dataset": "synthetic-mnist", "n_clients": 6, "sigma": 5.0,
           "n_train": 240, "n_test": 60, "seed": 0},
  "model": {"name": "mlp-edge"},
  "wireless": {"e0": 1000000.0, "t0": 1000000.0, "seed": 0},
  "scheme": {"name": "proposed", "rounds": 4, "eta": 0.1, "batch": 8,
             "ao": {"outer_iters": 1}},
  "run": {"seed": 0, "eval_every": 2, "checkpoint_every": 2,
          "rounds_per_dispatch": 2}
}
EOF
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m repro.api.cli run "$work/spec.json" \
        --checkpoint-dir "$work/ckpt" --out "$work/run.jsonl" || ok=1
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m repro.api.cli resume "$work/ckpt" \
        --out "$work/resumed.jsonl" || ok=1
    test -s "$work/run.jsonl" || ok=1
    test -s "$work/resumed.jsonl" || ok=1
    rm -rf "$work"
    return "$ok"
}

# Sweep-CLI smoke: 2 seeds x 2 schemes over one spec template, streamed as
# per-run JSONL into --out-dir (4 run files + the sweep.jsonl index), then
# the report's seed-aggregated mean±std section over the directory glob.
# Same error discipline as cli_smoke.
sweep_smoke() {
    local work ok=0 n
    work="$(mktemp -d)"
    cat > "$work/spec.json" <<'EOF'
{
  "data": {"dataset": "synthetic-mnist", "n_clients": 6, "sigma": 5.0,
           "n_train": 240, "n_test": 60, "seed": 0},
  "model": {"name": "mlp-edge"},
  "wireless": {"e0": 1000000.0, "t0": 1000000.0, "seed": 0},
  "scheme": {"name": "proposed", "rounds": 3, "eta": 0.1, "batch": 8,
             "ao": {"outer_iters": 1}},
  "run": {"seed": 0, "eval_every": 2}
}
EOF
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m repro.api.cli sweep "$work/spec.json" \
        --seeds 0,1 --schemes proposed,no_gen \
        --out-dir "$work/runs" || ok=1
    n="$(ls "$work"/runs/0*.jsonl 2>/dev/null | wc -l)"
    [[ "$n" -eq 4 ]] || { echo "sweep smoke: expected 4 run files, got $n"; ok=1; }
    test -s "$work/runs/sweep.jsonl" || ok=1
    # plain grep (not -q) drains the whole pipe, so the report never dies
    # on a broken pipe mid-print
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m benchmarks.report --runs "$work/runs/*.jsonl" \
        | grep "seed-aggregated" >/dev/null || ok=1
    rm -rf "$work"
    return "$ok"
}

# Chaos smoke: the cli_smoke spec under a byzantine upload attack
# (ScaledMalicious, exactly 2 of 6 attackers per round) defended by the
# trimmed-mean robust aggregator, run -> resume from the mid-run
# checkpoint -> assert both the fault counters and the aggregation
# counters surfaced in the exported JSONL. `fixed_selection` keeps every
# client in every round so the trim statistic is nonzero. Same error
# discipline as cli_smoke.
chaos_smoke() {
    local work ok=0
    work="$(mktemp -d)"
    cat > "$work/spec.json" <<'EOF'
{
  "data": {"dataset": "synthetic-mnist", "n_clients": 6, "sigma": 5.0,
           "n_train": 240, "n_test": 60, "seed": 0},
  "model": {"name": "mlp-edge"},
  "wireless": {"e0": 1000000.0, "t0": 1000000.0, "seed": 0,
               "fault_model": "scaled_malicious",
               "fault_kwargs": {"rate": 0.34, "scale": -10.0,
                                "exact": true, "seed": 7}},
  "scheme": {"name": "fixed_selection", "rounds": 4, "eta": 0.1, "batch": 8,
             "ao": {"outer_iters": 1},
             "aggregator": "trimmed_mean",
             "aggregator_kwargs": {"beta": 0.34}},
  "run": {"seed": 0, "eval_every": 2, "checkpoint_every": 2,
          "rounds_per_dispatch": 2}
}
EOF
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m repro.api.cli run "$work/spec.json" \
        --checkpoint-dir "$work/ckpt" --out "$work/run.jsonl" || ok=1
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m repro.api.cli resume "$work/ckpt" \
        --out "$work/resumed.jsonl" || ok=1
    grep '"n_corrupt_finite"' "$work/run.jsonl" >/dev/null \
        || { echo "chaos smoke: no fault counters in run.jsonl"; ok=1; }
    grep '"aggregation"' "$work/run.jsonl" >/dev/null \
        || { echo "chaos smoke: no aggregation block in run.jsonl"; ok=1; }
    grep '"n_trimmed"' "$work/resumed.jsonl" >/dev/null \
        || { echo "chaos smoke: no aggregation counters in resumed.jsonl"; ok=1; }
    rm -rf "$work"
    return "$ok"
}

# FedDyn chaos smoke: a checkpointing FedDyn run (stateful per-client
# correction buffer h rides every checkpoint) is SIGTERMed as soon as a
# checkpoint lands, then resumed. Asserts (a) the killed run's latest
# checkpoint npz really carries the h leaf, and (b) the resumed export's
# round records are BYTE IDENTICAL to an uninterrupted oracle's — the
# post-resume rounds replay through the restored h, so byte equality here
# IS the h-restored-bitwise assertion. Same error discipline as
# cli_smoke.
feddyn_chaos_smoke() {
    local work ok=0 pid i
    work="$(mktemp -d)"
    cat > "$work/spec.json" <<'EOF'
{
  "data": {"dataset": "synthetic-mnist", "n_clients": 6, "sigma": 5.0,
           "n_train": 240, "n_test": 60, "seed": 0},
  "model": {"name": "mlp-edge"},
  "wireless": {"e0": 1000000.0, "t0": 1000000.0, "seed": 0},
  "scheme": {"name": "proposed", "rounds": 6, "eta": 0.1, "batch": 8,
             "ao": {"outer_iters": 1},
             "local_scheme": "feddyn", "local_steps": 2,
             "local_kwargs": {"alpha": 0.1}},
  "run": {"seed": 0, "eval_every": 3, "checkpoint_every": 1}
}
EOF
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m repro.api.cli run "$work/spec.json" \
        --out "$work/oracle.jsonl" || ok=1
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m repro.api.cli run "$work/spec.json" \
        --checkpoint-dir "$work/ckpt" --out "$work/run.jsonl" \
        >/dev/null 2>&1 &
    pid=$!
    for i in $(seq 1 600); do
        ls "$work"/ckpt/*.npz >/dev/null 2>&1 && break
        kill -0 "$pid" 2>/dev/null || break
        sleep 0.1
    done
    kill -TERM "$pid" 2>/dev/null || true
    wait "$pid" 2>/dev/null || true
    python - "$work/ckpt" <<'EOF' \
        || { echo "feddyn chaos smoke: no per-client h leaf in checkpoint"; ok=1; }
import glob
import sys

import numpy as np

paths = sorted(glob.glob(sys.argv[1] + "/*.npz"))
if not paths:
    sys.exit(1)
with np.load(paths[-1]) as d:
    sys.exit(0 if "['h']" in d.files else 1)
EOF
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m repro.api.cli resume "$work/ckpt" \
        --out "$work/resumed.jsonl" || ok=1
    grep '"kind": "round"' "$work/oracle.jsonl" > "$work/o.rounds" || ok=1
    grep '"kind": "round"' "$work/resumed.jsonl" > "$work/r.rounds" || ok=1
    cmp -s "$work/o.rounds" "$work/r.rounds" \
        || { echo "feddyn chaos smoke: resumed trajectory diverged from the uninterrupted oracle (h not restored bitwise?)"; ok=1; }
    rm -rf "$work"
    return "$ok"
}

# Sweep-resume chaos smoke: a 2x2 matrix run with --workers 2 is
# SIGTERMed as soon as the service has durable state (a mid-cell
# checkpoint dir or a completed per-run file), then relaunched with
# --resume. The resume must report its skip/ran split, and the final
# sink directory must hold all 4 per-run files with every cell named in
# the sweep.jsonl index (as sweep_run or sweep_skip). Same error
# discipline as cli_smoke. checkpoint_every=1 makes mid-cell state
# appear within one round, so the kill lands mid-matrix rather than
# racing the whole sweep.
sweep_resume_smoke() {
    local work ok=0 pid i n f name
    work="$(mktemp -d)"
    cat > "$work/spec.json" <<'EOF'
{
  "data": {"dataset": "synthetic-mnist", "n_clients": 6, "sigma": 5.0,
           "n_train": 240, "n_test": 60, "seed": 0},
  "model": {"name": "mlp-edge"},
  "wireless": {"e0": 1000000.0, "t0": 1000000.0, "seed": 0},
  "scheme": {"name": "proposed", "rounds": 4, "eta": 0.1, "batch": 8,
             "ao": {"outer_iters": 1}},
  "run": {"seed": 0, "eval_every": 2, "checkpoint_every": 1}
}
EOF
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m repro.api.cli sweep "$work/spec.json" \
        --seeds 0,1 --schemes proposed,no_gen \
        --out-dir "$work/runs" --workers 2 >/dev/null 2>&1 &
    pid=$!
    for i in $(seq 1 600); do
        if [[ -d "$work/runs/ckpt" ]] \
            || ls "$work"/runs/0*.jsonl >/dev/null 2>&1; then
            break
        fi
        kill -0 "$pid" 2>/dev/null || break
        sleep 0.1
    done
    kill -TERM "$pid" 2>/dev/null || true
    wait "$pid" 2>/dev/null || true
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m repro.api.cli sweep "$work/spec.json" \
        --seeds 0,1 --schemes proposed,no_gen \
        --out-dir "$work/runs" --workers 2 --resume \
        > "$work/resume.out" || ok=1
    grep "resume: skipped" "$work/resume.out" >/dev/null \
        || { echo "sweep-resume smoke: no resume skip/ran summary"; ok=1; }
    n="$(ls "$work"/runs/0*.jsonl 2>/dev/null | wc -l)"
    [[ "$n" -eq 4 ]] \
        || { echo "sweep-resume smoke: expected 4 run files, got $n"; ok=1; }
    for f in "$work"/runs/0*.jsonl; do
        name="$(basename "$f" .jsonl)"
        grep -F "\"name\": \"$name\"" "$work/runs/sweep.jsonl" >/dev/null \
            || { echo "sweep-resume smoke: $name missing from index"; ok=1; }
    done
    rm -rf "$work"
    return "$ok"
}

# Fleet smoke: a 1000-client synthetic-fleet population through the
# streamed cohort store (`random_k` scheme — the paper solvers are O(N)
# per client and fleet-infeasible), run twice: streamed and with the
# replicated-store oracle. The per-round records of the two exports must
# be BYTE IDENTICAL (streaming moves data, never results), the streamed
# summary must carry the fleet counters, and a mid-sweep SIGTERM +
# --resume with streaming on must finish the matrix (the cohort schedule
# is selection-pure, so the resumed leg replays it bit-for-bit). Same
# error discipline as cli_smoke.
fleet_smoke() {
    local work ok=0 pid i n
    work="$(mktemp -d)"
    cat > "$work/streamed.json" <<'EOF'
{
  "data": {"dataset": "synthetic-fleet", "n_clients": 1000,
           "n_train": 8000, "n_test": 64, "seed": 5},
  "model": {"name": "mlp-edge", "kwargs": {"hidden": 16}},
  "wireless": {"e0": 1000000.0, "t0": 1000000.0, "seed": 0},
  "scheme": {"name": "random_k", "rounds": 6, "eta": 0.1, "batch": 8,
             "ao": {"k": 6, "seed": 1}},
  "run": {"seed": 2, "eval_every": 3, "stop_on_budget": false,
          "rounds_per_dispatch": 3, "client_store": "streamed",
          "checkpoint_every": 2}
}
EOF
    sed 's/"streamed"/"replicated"/' "$work/streamed.json" \
        > "$work/replicated.json"
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m repro.api.cli run "$work/streamed.json" \
        --out "$work/streamed.jsonl" || ok=1
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m repro.api.cli run "$work/replicated.json" \
        --out "$work/replicated.jsonl" || ok=1
    grep '"fleet"' "$work/streamed.jsonl" >/dev/null \
        || { echo "fleet smoke: no fleet counters in streamed export"; ok=1; }
    grep '"fleet"' "$work/replicated.jsonl" >/dev/null \
        && { echo "fleet smoke: fleet counters leaked into replicated export"; ok=1; }
    grep '"kind": "round"' "$work/streamed.jsonl" > "$work/s.rounds" || ok=1
    grep '"kind": "round"' "$work/replicated.jsonl" > "$work/r.rounds" || ok=1
    cmp -s "$work/s.rounds" "$work/r.rounds" \
        || { echo "fleet smoke: streamed round records diverged from the replicated oracle"; ok=1; }
    # mid-sweep SIGTERM + --resume with streaming on (2 seeds x 1 scheme)
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m repro.api.cli sweep "$work/streamed.json" \
        --seeds 0,1 --out-dir "$work/runs" >/dev/null 2>&1 &
    pid=$!
    for i in $(seq 1 600); do
        if [[ -d "$work/runs/ckpt" ]] \
            || ls "$work"/runs/0*.jsonl >/dev/null 2>&1; then
            break
        fi
        kill -0 "$pid" 2>/dev/null || break
        sleep 0.1
    done
    kill -TERM "$pid" 2>/dev/null || true
    wait "$pid" 2>/dev/null || true
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
        python -m repro.api.cli sweep "$work/streamed.json" \
        --seeds 0,1 --out-dir "$work/runs" --resume \
        > "$work/resume.out" || ok=1
    grep "resume: skipped" "$work/resume.out" >/dev/null \
        || { echo "fleet smoke: no resume skip/ran summary"; ok=1; }
    n="$(ls "$work"/runs/0*.jsonl 2>/dev/null | wc -l)"
    [[ "$n" -eq 2 ]] \
        || { echo "fleet smoke: expected 2 run files, got $n"; ok=1; }
    rm -rf "$work"
    return "$ok"
}

# run all legs even if an earlier one fails (the seed ships with
# known-failing arch/serving suites); exit non-zero if any leg failed
status=0
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q ${MARKER[@]+"${MARKER[@]}"} "$@" \
    || status=$?

echo "== CLI smoke leg: spec run + checkpoint resume (1 device) =="
cli_smoke || status=$?

echo "== sweep-CLI smoke leg: 2 seeds x 2 schemes, streamed JSONL (1 device) =="
sweep_smoke || status=$?

echo "== chaos smoke leg: byzantine attack + robust aggregator (1 device) =="
chaos_smoke || status=$?

echo "== feddyn chaos leg: SIGTERM mid-run + resume with per-client state (1 device) =="
feddyn_chaos_smoke || status=$?

echo "== sweep-resume chaos leg: SIGTERM mid-matrix + --resume (1 device) =="
sweep_resume_smoke || status=$?

echo "== fleet smoke leg: streamed cohorts vs replicated oracle (1 device) =="
fleet_smoke || status=$?

echo "== sharded smoke leg: round/block engines + API under 4 forced host devices =="
# forced flag goes LAST: XLA takes the final occurrence of a duplicated
# flag, so an inherited force-count must not override the leg's; an
# inherited shard-count override would likewise silently unshard the leg.
# The per-round, multi-round-block, experiment-API, sweep, and scenario-axes
# parity suites all run here (the 1-device leg above already ran them
# unsharded), so every engine path is exercised on the mesh.
XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=4" \
    REPRO_ROUND_SHARDS= \
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q ${MARKER[@]+"${MARKER[@]}"} \
        tests/test_round_engine.py tests/test_block_engine.py \
        tests/test_api.py tests/test_sweep.py tests/test_sweep_service.py \
        tests/test_scenario_axes.py \
        tests/test_faults.py tests/test_aggregators.py \
        tests/test_fleet.py tests/test_local_schemes.py \
    || status=$?

echo "== CLI smoke leg: spec run + checkpoint resume (4 forced devices) =="
(
    export XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=4"
    export REPRO_ROUND_SHARDS=
    cli_smoke
) || status=$?

echo "== sweep-CLI smoke leg: streamed sweep (4 forced devices) =="
(
    export XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=4"
    export REPRO_ROUND_SHARDS=
    sweep_smoke
) || status=$?

echo "== chaos smoke leg: byzantine attack + robust aggregator (4 forced devices) =="
(
    export XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=4"
    export REPRO_ROUND_SHARDS=
    chaos_smoke
) || status=$?

echo "== feddyn chaos leg: SIGTERM mid-run + resume with per-client state (4 forced devices) =="
(
    export XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=4"
    export REPRO_ROUND_SHARDS=
    feddyn_chaos_smoke
) || status=$?

echo "== sweep-resume chaos leg: SIGTERM mid-matrix + --resume (4 forced devices) =="
(
    export XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=4"
    export REPRO_ROUND_SHARDS=
    sweep_resume_smoke
) || status=$?

echo "== fleet smoke leg: streamed cohorts vs replicated oracle (4 forced devices) =="
(
    export XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=4"
    export REPRO_ROUND_SHARDS=
    fleet_smoke
) || status=$?

exit $status
