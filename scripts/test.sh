#!/usr/bin/env bash
# Test/CI entrypoint: install declared deps (best effort — offline containers
# fall back to tests/_hypothesis_stub.py via tests/conftest.py), then run the
# tier-1 suite, then the sharded smoke leg (round-engine tests on a forced
# 4-device host mesh, exercising the shard_map client axis on CPU).
set -euo pipefail
cd "$(dirname "$0")/.."

if ! python -c "import hypothesis" >/dev/null 2>&1; then
    python -m pip install -q -r requirements.txt 2>/dev/null \
        || echo "pip install unavailable (offline?); using vendored hypothesis shim"
fi

# run both legs even if the first fails (the seed ships with known-failing
# arch/serving suites); exit non-zero if either leg failed
status=0
PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" python -m pytest -x -q "$@" \
    || status=$?

echo "== sharded smoke leg: round/block engines under 4 forced host devices =="
# forced flag goes LAST: XLA takes the final occurrence of a duplicated
# flag, so an inherited force-count must not override the leg's; an
# inherited shard-count override would likewise silently unshard the leg.
# Both the per-round and the multi-round-block parity suites run here (the
# 1-device leg above already ran them unsharded), so every engine path is
# exercised on the mesh.
XLA_FLAGS="${XLA_FLAGS:+$XLA_FLAGS }--xla_force_host_platform_device_count=4" \
    REPRO_ROUND_SHARDS= \
    PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}" \
    python -m pytest -x -q tests/test_round_engine.py tests/test_block_engine.py \
    || status=$?

exit $status
