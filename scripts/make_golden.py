"""Regenerate the committed golden-trajectory fixture (tests/golden/).

    PYTHONPATH=src python scripts/make_golden.py

The fixture is a tiny fixed-seed RunResult JSONL whose spec is stored in
its own header record; tests/test_golden.py re-runs that spec and asserts
BITWISE-equal per-round history on fp32 — one test that guards the packed
/ block / sharded engines (and the whole spec -> schedule -> trainer
pipeline above them) against silent numeric drift. Only regenerate after
an INTENDED numerics change, and say so in the commit message: a diff in
this file is a change to the reproduction's trajectory contract.
"""
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.api import (  # noqa: E402
    DataSpec, Experiment, ExperimentSpec, ModelSpec, RunSpec, SchemeSpec,
    WirelessSpec,
)

GOLDEN_DIR = os.path.join(os.path.dirname(__file__), "..", "tests",
                          "golden")
OUT = os.path.join(GOLDEN_DIR, "run_mlp_edge.jsonl")
OUT_FEDPROX = os.path.join(GOLDEN_DIR, "run_mlp_edge_fedprox.jsonl")

# Small enough to run in seconds, rich enough to touch selection, pruning,
# aggregation, eval, and the budget ledger. shards=1 pins the single-device
# engine so the fixture holds on forced-multi-device CI hosts too;
# rounds_per_dispatch=2 exercises the block engine (bitwise == per-round).
GOLDEN_SPEC = ExperimentSpec(
    data=DataSpec(dataset="synthetic-mnist", n_clients=6, sigma=5.0,
                  n_train=240, n_test=60, seed=0),
    model=ModelSpec(name="mlp-edge"),
    wireless=WirelessSpec(e0=1e6, t0=1e6, seed=0),
    scheme=SchemeSpec(name="proposed", rounds=6, eta=0.1, batch=8,
                      ao={"outer_iters": 1}),
    run=RunSpec(seed=0, eval_every=3, shards=1, rounds_per_dispatch=2))

# The local-epoch fixture: FedProx with E=3 (pads to the pow2 step bucket
# of 4, so the padded-step no-op gating is inside the pinned trajectory)
# over the same tiny federation. tests/test_golden.py re-runs it through
# the packed rpd=2 block path AND the eager reference backend.
GOLDEN_FEDPROX_SPEC = ExperimentSpec(
    data=DataSpec(dataset="synthetic-mnist", n_clients=6, sigma=5.0,
                  n_train=240, n_test=60, seed=0),
    model=ModelSpec(name="mlp-edge"),
    wireless=WirelessSpec(e0=1e6, t0=1e6, seed=0),
    scheme=SchemeSpec(name="proposed", rounds=6, eta=0.1, batch=8,
                      ao={"outer_iters": 1}, local_scheme="fedprox",
                      local_steps=3, local_kwargs={"mu": 0.05}),
    run=RunSpec(seed=0, eval_every=3, shards=1, rounds_per_dispatch=2))


def main() -> None:
    os.makedirs(GOLDEN_DIR, exist_ok=True)
    for spec, out in ((GOLDEN_SPEC, OUT),
                      (GOLDEN_FEDPROX_SPEC, OUT_FEDPROX)):
        res = Experiment(spec).run()
        res.to_jsonl(out)
        print(f"wrote {os.path.normpath(out)} "
              f"({res.summary['rounds_run']} rounds, final acc "
              f"{res.summary['final_accuracy']:.3f})")


if __name__ == "__main__":
    main()
