"""Continuous-batching serving engine: exactness vs sequential generation,
slot reuse, ragged positions, SSM family support."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import Runtime, decode_step, init_cache, init_params, prefill
from repro.serving import ServingEngine

RT = Runtime(attn_impl="naive")


def _gen_ref(params, cfg, prompt, new=8, max_seq=256):
    p = len(prompt)
    cache = init_cache(cfg, 1, max_seq)
    _, cache = prefill(params, jnp.asarray(prompt[:-1])[None], cache, cfg,
                       RT, None)
    tok, pos, out = int(prompt[-1]), p - 1, []
    for _ in range(new):
        lg, cache = decode_step(params, jnp.asarray([[tok]], jnp.int32),
                                cache, pos, cfg, RT)
        tok = int(lg[0].argmax())
        out.append(tok)
        pos += 1
    return out


@pytest.mark.parametrize("arch", ["granite-3-2b", "mamba2-130m"])
def test_engine_matches_sequential(arch):
    cfg = get_config(arch).reduced()
    params = init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(0)
    prompts = [rng.integers(0, cfg.vocab_size, size=n).astype(np.int32)
               for n in (12, 20, 7, 30, 16)]
    refs = [_gen_ref(params, cfg, pr) for pr in prompts]

    eng = ServingEngine(params, cfg, max_batch=3, max_seq=256, rt=RT,
                        prompt_buckets=(32,))
    for pr in prompts:
        eng.submit(pr, max_new_tokens=8)
    done = eng.run_to_completion()
    assert len(done) == len(prompts)
    by_uid = {st.request.uid: st.generated for st in done}
    for i, ref in enumerate(refs):
        assert by_uid[i] == ref, f"request {i}: {by_uid[i]} != {ref}"


def test_slots_reused_and_ragged_positions():
    cfg = get_config("granite-3-2b").reduced()
    params = init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(1)
    eng = ServingEngine(params, cfg, max_batch=2, max_seq=128, rt=RT,
                        prompt_buckets=(16,))
    # 6 requests through 2 slots, different lengths => ragged positions
    for n in (5, 9, 13, 6, 11, 8):
        eng.submit(rng.integers(0, cfg.vocab_size, size=n).astype(np.int32),
                   max_new_tokens=4)
    done = eng.run_to_completion()
    assert len(done) == 6
    assert all(len(st.generated) == 4 for st in done)
    slots_used = {st.slot for st in done}
    assert slots_used == {0, 1}


def test_eos_stops_early():
    cfg = get_config("granite-3-2b").reduced()
    params = init_params(jax.random.key(0), cfg)
    rng = np.random.default_rng(2)
    prompt = rng.integers(0, cfg.vocab_size, size=10).astype(np.int32)
    ref = _gen_ref(params, cfg, prompt, new=1)
    eos = ref[0]  # first generated token == eos => stop after 1 token
    eng = ServingEngine(params, cfg, max_batch=1, max_seq=128, rt=RT,
                        prompt_buckets=(16,))
    eng.submit(prompt, max_new_tokens=16, eos_id=eos)
    done = eng.run_to_completion()
    assert len(done) == 1
    assert done[0].generated == [eos]
