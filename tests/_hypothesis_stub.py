"""Offline stand-in for the small `hypothesis` surface this suite uses.

The real `hypothesis` package is preferred (see requirements.txt and
scripts/test.sh); this shim exists so `python -m pytest` still collects and
runs in containers without network access. It implements exactly what the
tests import — `given`, `settings`, and `strategies.{integers, floats,
sampled_from, lists}` — with deterministic draws:

  * example 0 exercises every strategy's lower bound,
  * example 1 exercises every upper bound,
  * remaining examples are drawn from a per-test seeded RNG, so failures
    reproduce across runs.

No shrinking, health checks, or stateful testing.
"""
from __future__ import annotations

import functools
import sys
import types
import zlib

import numpy as np

__version__ = "0.0-stub"


class HealthCheck:
    """Placeholder attributes so `suppress_health_check=` doesn't explode."""

    too_slow = data_too_large = filter_too_much = function_scoped_fixture = None
    all = classmethod(lambda cls: [])


class _Strategy:
    def __init__(self, draw, edges=()):
        self._draw = draw
        self.edges = tuple(edges)

    def draw(self, rng):
        return self._draw(rng)


def _as_strategy_module():
    mod = types.ModuleType("hypothesis.strategies")

    def integers(min_value, max_value):
        return _Strategy(
            lambda rng: int(rng.integers(min_value, max_value + 1)),
            (int(min_value), int(max_value)))

    def floats(min_value=0.0, max_value=1.0, **_kw):
        lo, hi = float(min_value), float(max_value)
        return _Strategy(lambda rng: float(rng.uniform(lo, hi)), (lo, hi))

    def sampled_from(elements):
        elems = list(elements)
        return _Strategy(
            lambda rng: elems[int(rng.integers(len(elems)))],
            (elems[0], elems[-1]))

    def lists(elem, min_size=0, max_size=None):
        hi = max_size if max_size is not None else min_size + 8
        edges = ()
        if elem.edges:
            edges = ([elem.edges[0]] * max(min_size, 1),
                     [elem.edges[-1]] * hi)

        def draw(rng):
            size = int(rng.integers(min_size, hi + 1))
            return [elem.draw(rng) for _ in range(size)]

        return _Strategy(draw, edges)

    def booleans():
        return _Strategy(lambda rng: bool(rng.integers(2)), (False, True))

    def just(value):
        return _Strategy(lambda rng: value, (value, value))

    mod.integers = integers
    mod.floats = floats
    mod.sampled_from = sampled_from
    mod.lists = lists
    mod.booleans = booleans
    mod.just = just
    return mod


strategies = _as_strategy_module()

_DEFAULT_MAX_EXAMPLES = 20


def settings(max_examples=None, deadline=None, **_kw):
    def deco(fn):
        fn._stub_max_examples = max_examples or _DEFAULT_MAX_EXAMPLES
        return fn
    return deco


def given(*strats, **kw_strats):
    if kw_strats:
        raise NotImplementedError("stub `given` supports positional strategies")

    def deco(fn):
        # NOTE: no functools.wraps — it would expose `__wrapped__`, and pytest
        # would then introspect the original signature and try to inject the
        # strategy parameters as fixtures.
        def wrapper(*args, **kwargs):
            n = getattr(wrapper, "_stub_max_examples", _DEFAULT_MAX_EXAMPLES)
            rng = np.random.default_rng(
                zlib.crc32(fn.__qualname__.encode()) & 0xFFFFFFFF)
            for i in range(max(n, 1)):
                if i == 0 and all(s.edges for s in strats):
                    vals = [s.edges[0] for s in strats]
                elif i == 1 and all(len(s.edges) > 1 for s in strats):
                    vals = [s.edges[-1] for s in strats]
                else:
                    vals = [s.draw(rng) for s in strats]
                try:
                    fn(*args, *vals, **kwargs)
                except Exception as exc:
                    raise AssertionError(
                        f"falsifying example (stub hypothesis): "
                        f"{fn.__name__}({', '.join(map(repr, vals))})"
                    ) from exc
        wrapper.__name__ = fn.__name__
        wrapper.__qualname__ = fn.__qualname__
        wrapper.__doc__ = fn.__doc__
        wrapper.__module__ = fn.__module__
        wrapper.__dict__.update(getattr(fn, "__dict__", {}))  # pytest marks
        return wrapper
    return deco


def assume(condition):
    """Best effort: the stub cannot retry a draw, so a failed assumption
    simply skips the remaining assertions by raising nothing when true."""
    return bool(condition)


def install() -> None:
    """Register this module as `hypothesis` in sys.modules."""
    me = sys.modules[__name__]
    sys.modules.setdefault("hypothesis", me)
    sys.modules.setdefault("hypothesis.strategies", strategies)
