"""Byzantine-robust aggregation (core/aggregators.py, DESIGN.md §11).

Coverage mirrors tests/test_faults.py's shape: unit tests for the
registry / validation / attack-draw protocol, reducer-level weight-aware
correctness against float64 numpy oracles (padding + quarantined lanes
excluded bitwise), the bucket-capacity invariance that underwrites both
the reference backend's zero-padded stack and the sharded all-gather
path, kernel parity (Pallas interpret sort network vs the stable lax.sort
mirror), a breakdown-point property test per reducer, and the
differential contracts: every aggregator bitwise between backend="packed"
(shards=1) and backend="reference" with and without an active attack,
rpd=1 vs rpd=4 block dispatch, counter surfacing through RunResult, and
bit-for-bit checkpoint resume with the aggregation counters. The
slow-tier efficacy test runs benchmarks/robust_aggregation.py's grid at
quickstart scale and asserts the defense actually defends.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    DataSpec, Experiment, ExperimentSpec, ModelSpec, RunSpec, SchemeSpec,
    SweepSpec, WirelessSpec, override_field, run_sweep,
)
from repro.core import (
    AGGREGATORS, ClientData, FederatedTrainer, GaussianPoison,
    ScaledMalicious, SignFlip, aggregator_names, make_aggregator,
    register_aggregator,
)
from repro.core.aggregators import CoordMedian, MultiKrum, NormClip, TrimmedMean
from repro.kernels import ops
from repro.models import make_loss_fn
from repro.wireless import ChannelModel, SystemParams

from _trainer_pair import assert_trainers_bitwise, make_schedule

N, ROUNDS, BATCH = 4, 6, 4

AGG_CASES = [
    ("coord_median", {}),
    ("trimmed_mean", {"beta": 0.3}),
    ("norm_clip", {}),
    ("norm_clip", {"tau": 0.05}),
    ("multi_krum", {"f": 1}),
]
AGG_IDS = ["coord_median", "trimmed_mean", "norm_clip_adaptive",
           "norm_clip_fixed", "multi_krum"]


def tiny_trainer_inputs():
    rng = np.random.default_rng(0)
    clients = [ClientData(rng.normal(size=(12, 4, 4, 1)).astype(np.float32),
                          rng.integers(0, 3, size=12).astype(np.int32))
               for _ in range(N)]

    def apply_fn(p, x):
        return x.reshape(x.shape[0], -1) @ p["w"]

    params = {"w": jnp.asarray(rng.normal(size=(16, 3)).astype(np.float32))}
    return clients, params, make_loss_fn(apply_fn)


def run_backend_pair(aggregator=None, fault_model=None, rounds=ROUNDS):
    """Both backends, full participation every round, the SAME aggregator
    and fault model; packed pinned to one shard (bit-for-bit contract)."""
    clients, params, loss_fn = tiny_trainer_inputs()
    sched = make_schedule(np.ones((rounds, N)), 0.3)
    sp = SystemParams.table1(N)
    ch = ChannelModel(N)
    out = {}
    for backend in ("reference", "packed"):
        kw = {"shards": 1} if backend == "packed" else {}
        tr = FederatedTrainer(loss_fn, params, clients, eta=0.1,
                              batch_size=BATCH, seed=0, backend=backend,
                              fault_model=fault_model,
                              aggregator=aggregator, **kw)
        out[backend] = (tr, tr.run(sched, sp, ch.uplink, ch.downlink))
    return out


def agg_spec(*, aggregator="mean", aggregator_kwargs=None, backend="packed",
             shards=None, rpd=1, fault_model="none", fault_kwargs=None,
             **run_kw):
    return ExperimentSpec(
        data=DataSpec(dataset="synthetic-mnist", n_clients=N, sigma=5.0,
                      n_train=160, n_test=60, seed=0),
        model=ModelSpec(name="mlp-edge"),
        wireless=WirelessSpec(e0=1e6, t0=1e6, seed=0,
                              fault_model=fault_model,
                              fault_kwargs=fault_kwargs or {}),
        scheme=SchemeSpec(name="fixed_selection", rounds=ROUNDS, eta=0.1,
                          batch=BATCH, ao={"outer_iters": 1},
                          aggregator=aggregator,
                          aggregator_kwargs=aggregator_kwargs or {}),
        run=RunSpec(seed=0, eval_every=3, backend=backend, shards=shards,
                    rounds_per_dispatch=rpd, **run_kw))


# ---------------------------------------------------------------------------
# Registry + validation
# ---------------------------------------------------------------------------

def test_registry_and_validation():
    assert make_aggregator("mean") is None        # builtin mean path
    assert set(aggregator_names()) >= {
        "mean", "coord_median", "trimmed_mean", "norm_clip", "multi_krum"}
    with pytest.raises(TypeError, match="mean takes no kwargs"):
        make_aggregator("mean", beta=0.1)
    with pytest.raises(KeyError, match="registered"):
        make_aggregator("wat")
    with pytest.raises(ValueError, match="beta"):
        make_aggregator("trimmed_mean", beta=0.5)
    with pytest.raises(ValueError, match="f must be"):
        make_aggregator("multi_krum", f=-1)
    with pytest.raises(ValueError, match="m must be"):
        make_aggregator("multi_krum", m=0)
    with pytest.raises(KeyError, match="already registered"):
        register_aggregator("mean", lambda **kw: None)
    # override=True replaces; restore the original afterwards
    orig = AGGREGATORS["mean"]
    try:
        marker = object()
        register_aggregator("mean", lambda **kw: marker, override=True)
        assert make_aggregator("mean") is marker
    finally:
        AGGREGATORS["mean"] = orig
    # instances are frozen + hashable with a canonical identity key
    a, b = make_aggregator("trimmed_mean", beta=0.2), TrimmedMean(beta=0.2)
    assert a == b and a.spec_key == b.spec_key
    assert a.spec_key != TrimmedMean(beta=0.3).spec_key
    assert TrimmedMean().stat_field == "n_trimmed"
    assert NormClip().stat_field == "n_clipped"
    assert CoordMedian().stat_field == "n_excluded"
    assert MultiKrum().stat_field == "n_excluded"


def test_spec_roundtrip_and_sweepable():
    spec = agg_spec(aggregator="trimmed_mean",
                    aggregator_kwargs={"beta": 0.2})
    assert ExperimentSpec.from_json(spec.to_json()) == spec
    s2 = override_field(spec, "scheme.aggregator_kwargs.beta", 0.4)
    assert s2.scheme.aggregator_kwargs == {"beta": 0.4}
    assert spec.scheme.aggregator_kwargs == {"beta": 0.2}   # no aliasing
    s3 = override_field(spec, "scheme.aggregator", "coord_median")
    assert s3.scheme.aggregator == "coord_median"


# ---------------------------------------------------------------------------
# Attack draw protocol
# ---------------------------------------------------------------------------

def test_byzantine_draw_determinism_and_population_invariance():
    all_ids = np.arange(8)
    for cls, field in ((SignFlip, "corrupt"), (ScaledMalicious, "corrupt")):
        m = cls(rate=0.5, seed=3)
        a = m.draw(5, 8, all_ids)
        assert a.upload_ok.all()                  # uploads DO arrive
        np.testing.assert_array_equal(a.corrupt, m.draw(5, 8, all_ids).corrupt)
        sub = m.draw(5, 8, np.array([2, 6]))
        np.testing.assert_array_equal(sub.corrupt, a.corrupt[[2, 6]])
        assert not np.array_equal(a.corrupt, m.draw(6, 8, all_ids).corrupt)
        with pytest.raises(ValueError, match="rate"):
            cls(rate=1.5)
    # the two multiplicative attacks share the flag stream at one key:
    # same hit set, different payloads
    sf = SignFlip(rate=0.5, scale=2.0, seed=3).draw(5, 8, all_ids)
    sm = ScaledMalicious(rate=0.5, scale=7.0, seed=3).draw(5, 8, all_ids)
    np.testing.assert_array_equal(sf.corrupt == -2.0, sm.corrupt == 7.0)
    assert ((sf.corrupt == 1.0) | (sf.corrupt == -2.0)).all()


def test_byzantine_exact_mode_pins_attacker_count():
    """exact=True: round(rate * n) attackers EVERY round (the f-of-n
    threat model), membership rotating, selection-invariance intact."""
    m = ScaledMalicious(rate=0.3, scale=10.0, seed=0, exact=True)
    all_ids = np.arange(10)
    rosters = []
    for r in range(30):
        d = m.draw(r, 10, all_ids)
        hit = d.corrupt == 10.0
        assert int(hit.sum()) == 3, r
        rosters.append(tuple(np.flatnonzero(hit)))
        sub = m.draw(r, 10, np.array([0, 4, 9]))
        np.testing.assert_array_equal(sub.corrupt, d.corrupt[[0, 4, 9]])
    assert len(set(rosters)) > 1                  # membership rotates
    # degenerate fractions clamp cleanly
    z = ScaledMalicious(rate=0.01, exact=True).draw(0, 10, all_ids)
    assert (z.corrupt == 1.0).all()               # round(.1) = 0 attackers
    full = ScaledMalicious(rate=0.99, exact=True).draw(0, 10, all_ids)
    assert (full.corrupt != 1.0).all()            # round(9.9) = everyone
    # exact mode rides the poison model identically
    gp = GaussianPoison(rate=0.3, sigma=0.5, seed=0, exact=True)
    assert int(np.asarray(gp.draw(2, 10, all_ids).poison.flags).sum()) == 3


def test_gaussian_poison_draw_protocol():
    m = GaussianPoison(rate=0.6, sigma=0.5, seed=4)
    all_ids = np.arange(8)
    d = m.draw(2, 8, all_ids)
    assert d.upload_ok.all() and d.corrupt is None
    assert d.poison is not None
    flags = np.asarray(d.poison.flags, bool)
    assert flags.any() and not flags.all()        # the seed really poisons
    valid = np.zeros((3, 128), np.float32)
    valid[:, :100] = 1.0
    stack = d.poison((3, 128), valid)
    assert stack.shape == (8, 3, 128) and stack.dtype == np.float32
    # clean rows exactly zero; flagged rows nonzero only on valid lanes
    assert not stack[~flags].any()
    assert all(stack[i].any() for i in np.flatnonzero(flags))
    np.testing.assert_array_equal(stack * valid, stack)
    # per-client keying: a sub-selection reproduces the same rows
    sub = m.draw(2, 8, np.array([1, 5]))
    np.testing.assert_array_equal(sub.poison.flags, flags[[1, 5]])
    if flags[[1, 5]].any():
        np.testing.assert_array_equal(sub.poison((3, 128), valid),
                                      stack[[1, 5]])
    # rate=0 draws clean (no poison callable at all)
    assert GaussianPoison(rate=0.0).draw(2, 8, all_ids).poison is None
    with pytest.raises(ValueError, match="sigma"):
        GaussianPoison(sigma=-1.0)


# ---------------------------------------------------------------------------
# Reducer-level correctness: float64 numpy oracles + weight-aware ranks
# ---------------------------------------------------------------------------

def np_oracle(kind, g, cw, *, beta=0.1, f=1, m=None, tau=None):
    """Float64 oracle over the VALID rows only — the semantics the
    weight-aware packed reducers must match."""
    g = np.asarray(g, np.float64)
    gv = g[np.asarray(cw) > 0]
    n = gv.shape[0]
    if kind == "coord_median":
        return np.median(gv, axis=0)
    if kind == "trimmed_mean":
        t = int(np.floor(beta * n))
        sv = np.sort(gv, axis=0)
        return sv[t:n - t].mean(axis=0)
    if kind == "norm_clip":
        norms = np.linalg.norm(gv.reshape(n, -1), axis=1)
        tau_v = float(np.median(norms)) if tau is None else float(tau)
        fac = np.minimum(1.0, tau_v / norms)
        return (gv * fac[:, None, None]).mean(axis=0)
    if kind == "multi_krum":
        d2 = ((gv.reshape(n, 1, -1) - gv.reshape(1, n, -1)) ** 2).sum(-1)
        np.fill_diagonal(d2, np.inf)
        k = min(max(n - f - 2, 1), n - 1)
        scores = np.sort(d2, axis=1)[:, :k].sum(axis=1)
        msel = max(1, min(n - f if m is None else m, n))
        keep = np.argsort(scores, kind="stable")[:msel]
        return gv[keep].mean(axis=0)
    raise ValueError(kind)


def garbage_stack(c=8, r=4, n_valid=5, seed=0):
    """[c, r, 128] stack whose invalid rows hold adversarial garbage
    (NaN / inf / huge) that must not influence any output bit."""
    rng = np.random.default_rng(seed)
    g = rng.normal(size=(c, r, 128)).astype(np.float32)
    cw = np.zeros(c, np.float32)
    cw[:n_valid] = 1.0
    g[n_valid] = np.nan
    if n_valid + 1 < c:
        g[n_valid + 1] = np.inf
    if n_valid + 2 < c:
        g[n_valid + 2] = 1e30
    return jnp.asarray(g), jnp.asarray(cw)


@pytest.mark.parametrize("name,kwargs", AGG_CASES, ids=AGG_IDS)
def test_reducer_matches_numpy_oracle(name, kwargs):
    g, cw = garbage_stack()
    agg = make_aggregator(name, **kwargs)
    ghat, stat = agg.reduce(g, cw)
    oracle = np_oracle(name, g, cw, **kwargs)
    np.testing.assert_allclose(np.asarray(ghat, np.float64), oracle,
                               rtol=2e-5, atol=1e-7)
    assert int(stat) >= 0
    assert bool(jnp.isfinite(ghat).all())         # garbage never leaks


@pytest.mark.parametrize("name,kwargs", AGG_CASES, ids=AGG_IDS)
def test_reducer_bucket_capacity_invariance_bitwise(name, kwargs):
    """The designed contract: zero-weight lanes (garbage values included)
    change NO output bit, so a compact stack of the valid rows and any
    zero-padded / garbage-padded bucket agree exactly — what lets the
    reference backend zero-pad and the sharded path all-gather."""
    g, cw = garbage_stack()
    agg = make_aggregator(name, **kwargs)
    ghat_b, stat_b = agg.reduce(g, cw)
    nv = int(np.asarray(cw).sum())
    ghat_c, stat_c = agg.reduce(g[:nv], jnp.ones(nv, jnp.float32))
    np.testing.assert_array_equal(np.asarray(ghat_b), np.asarray(ghat_c))
    assert int(stat_b) == int(stat_c)
    # ... and permuting which lanes are invalid doesn't matter either
    perm = np.array([5, 0, 6, 1, 7, 2, 3, 4])
    ghat_p, stat_p = agg.reduce(jnp.asarray(np.asarray(g)[perm]),
                                jnp.asarray(np.asarray(cw)[perm]))
    np.testing.assert_array_equal(np.asarray(ghat_b), np.asarray(ghat_p))
    assert int(stat_b) == int(stat_p)


def test_reducer_stat_counts():
    g, cw = garbage_stack(n_valid=6)              # 6 valid lanes
    _, st = make_aggregator("trimmed_mean", beta=0.34).reduce(g, cw)
    assert int(st) == 4                           # floor(.34*6)=2 per tail
    _, st = make_aggregator("coord_median").reduce(g, cw)
    assert int(st) == 4                           # 6 valid, 2-lane window
    _, st = make_aggregator("multi_krum", f=2).reduce(g, cw)
    assert int(st) == 2                           # keep m = n - f = 4
    _, st = make_aggregator("norm_clip", tau=1e9).reduce(g, cw)
    assert int(st) == 0                           # nobody over a huge tau
    _, st = make_aggregator("norm_clip", tau=1e-9).reduce(g, cw)
    assert int(st) == 6                           # everyone over a tiny tau


def test_rank_sort_pallas_vs_xla_bitwise():
    g, cw = garbage_stack(c=8, r=4, n_valid=5)
    a = ops.packed_client_rank_sort(g, cw, impl="pallas")
    b = ops.packed_client_rank_sort(g, cw, impl="xla")
    nv = int(np.asarray(cw).sum())
    np.testing.assert_array_equal(np.asarray(a)[:nv], np.asarray(b)[:nv])
    # the valid prefix is genuinely sorted per coordinate
    av = np.asarray(a)[:nv]
    assert (np.diff(av, axis=0) >= 0).all()
    # sorted reducers agree bitwise across kernel impls too
    for name, kwargs in (("coord_median", {}), ("trimmed_mean",
                                                {"beta": 0.3})):
        gp, sp_ = make_aggregator(name, impl="pallas", **kwargs).reduce(g, cw)
        gx, sx = make_aggregator(name, impl="xla", **kwargs).reduce(g, cw)
        np.testing.assert_array_equal(np.asarray(gp), np.asarray(gx))
        assert int(sp_) == int(sx)


@pytest.mark.parametrize("name,kwargs", AGG_CASES, ids=AGG_IDS)
def test_reducer_degenerate_counts(name, kwargs):
    """n=1 and n=0 never divide by zero or read a sentinel lane."""
    g, cw = garbage_stack(n_valid=1)
    agg = make_aggregator(name, **kwargs)
    ghat, _ = agg.reduce(g, cw)
    if kwargs.get("tau") is not None:
        # a fixed tau legitimately clips even a lone gradient
        np.testing.assert_allclose(np.asarray(ghat),
                                   np_oracle(name, g, cw, **kwargs),
                                   rtol=2e-5, atol=1e-7)
    else:
        np.testing.assert_array_equal(np.asarray(ghat), np.asarray(g[0]))
    ghat0, _ = agg.reduce(g, jnp.zeros_like(cw))
    assert bool(jnp.isfinite(ghat0).all())        # caller gates on alive


# ---------------------------------------------------------------------------
# Breakdown point: f attackers below the tolerance cannot move the output
# outside the honest envelope, while the mean is dominated
# ---------------------------------------------------------------------------

def test_breakdown_point_property():
    rng = np.random.default_rng(7)
    honest = rng.normal(size=(7, 3, 128)).astype(np.float32)
    attack = np.full((3, 3, 128), 1e6, np.float32)  # f=3 colluders
    g = jnp.asarray(np.concatenate([honest, attack]))
    cw = jnp.ones(10, jnp.float32)
    hmax = np.abs(honest).max()
    for agg in (TrimmedMean(beta=0.35),           # floor(.35*10)=3 >= f
                CoordMedian(),                     # f=3 < n/2
                MultiKrum(f=3)):
        ghat, stat = agg.reduce(g, cw)
        assert float(jnp.abs(ghat).max()) <= hmax + 1e-6, type(agg).__name__
        assert int(stat) > 0
    # norm_clip bounds the damage to tau = median norm (it cannot remove
    # the attackers, only shrink them to honest magnitude)
    ghat, stat = NormClip().reduce(g, cw)
    med = float(np.median(np.linalg.norm(
        np.concatenate([honest, attack]).reshape(10, -1), axis=1)))
    assert float(jnp.linalg.norm(ghat.ravel())) <= med + 1e-3
    # adaptive tau = median norm clips the attackers AND any honest client
    # above the median, so the count is at least f
    assert int(stat) >= 3
    # the undefended mean IS dominated — the property is non-vacuous
    mean = np.concatenate([honest, attack]).mean(axis=0)
    assert np.abs(mean).max() > 1e4


# ---------------------------------------------------------------------------
# Differential: packed vs reference, block dispatch, sharding
# ---------------------------------------------------------------------------

ATTACKS = [None, ScaledMalicious(rate=0.4, scale=10.0, seed=5),
           SignFlip(rate=0.4, scale=2.0, seed=5),
           GaussianPoison(rate=0.4, sigma=0.5, seed=5)]
ATTACK_IDS = ["clean", "scaled_malicious", "sign_flip", "gaussian_poison"]


@pytest.mark.parametrize("fm", ATTACKS, ids=ATTACK_IDS)
@pytest.mark.parametrize("name,kwargs", AGG_CASES, ids=AGG_IDS)
def test_aggregator_packed_vs_reference_bitwise(name, kwargs, fm):
    agg = make_aggregator(name, **kwargs)
    out = run_backend_pair(aggregator=agg, fault_model=fm)
    (tr_ref, hist_ref), (tr_pk, hist_pk) = out["reference"], out["packed"]
    np.testing.assert_array_equal(
        np.asarray([m.train_loss for m in hist_ref]),
        np.asarray([m.train_loss for m in hist_pk]))
    assert [m.n_agg_adjusted for m in hist_ref] == \
        [m.n_agg_adjusted for m in hist_pk]
    assert tr_ref.agg_counters == tr_pk.agg_counters
    assert tr_ref.fault_counters == tr_pk.fault_counters
    assert_trainers_bitwise(tr_ref, tr_pk)
    assert all(bool(jnp.isfinite(p).all())
               for p in jax.tree_util.tree_leaves(tr_pk.params))


def test_mean_aggregator_is_bitwise_noop():
    """aggregator="mean" resolves to None and keeps the engine's default
    weighted-mean path — bitwise the pre-registry trajectory (the
    committed golden is the cross-session sensor; this is the in-session
    one)."""
    base = run_backend_pair(aggregator=None)
    mean = run_backend_pair(aggregator=make_aggregator("mean"))
    assert [m.train_loss for m in base["packed"][1]] == \
        [m.train_loss for m in mean["packed"][1]]
    assert_trainers_bitwise(base["packed"][0], mean["packed"][0])
    tr = mean["packed"][0]
    assert tr.aggregator is None and tr.agg_counters == {}
    # ... and a robust aggregator is genuinely a different trajectory
    med = run_backend_pair(aggregator=CoordMedian())
    assert [m.train_loss for m in base["packed"][1]] != \
        [m.train_loss for m in med["packed"][1]]


@pytest.mark.parametrize("name,kwargs",
                         [("trimmed_mean", {"beta": 0.3}),
                          ("multi_krum", {"f": 1})],
                         ids=["trimmed_mean", "multi_krum"])
def test_aggregator_block_dispatch_bitwise(name, kwargs):
    """rpd=1 vs rpd=4 under the DEFAULT shard count with an active attack:
    the robust reduction rides the [K,...] block-scan operands bitwise."""
    results = {}
    for rpd in (1, 4):
        spec = agg_spec(aggregator=name, aggregator_kwargs=kwargs, rpd=rpd,
                        fault_model="scaled_malicious",
                        fault_kwargs={"rate": 0.4, "scale": 10.0, "seed": 5})
        run = Experiment(spec).build()
        results[rpd] = (run, run.run())
    (run1, res1), (run4, res4) = results[1], results[4]
    assert run4.trainer.n_block_dispatches > 0
    np.testing.assert_array_equal(
        np.asarray([m.train_loss for m in res1.history]),
        np.asarray([m.train_loss for m in res4.history]))
    assert res1.summary["aggregation"] == res4.summary["aggregation"]
    assert [m.n_agg_adjusted for m in res1.history] == \
        [m.n_agg_adjusted for m in res4.history]
    for a, b in zip(jax.tree_util.tree_leaves(run1.trainer.params),
                    jax.tree_util.tree_leaves(run4.trainer.params)):
        assert bool(jnp.all(a == b))


@pytest.mark.skipif(len(jax.devices()) == 1,
                    reason="needs >1 device for a sharded client axis")
@pytest.mark.parametrize("name,kwargs",
                         [("coord_median", {}), ("multi_krum", {"f": 1})],
                         ids=["coord_median", "multi_krum"])
def test_aggregator_sharded_vs_single_shard_bitwise(name, kwargs):
    """The all-gather path: shards=n_dev must match shards=1 bitwise —
    the gathered stack reduces identically on every shard."""
    agg = make_aggregator(name, **kwargs)
    fm = ScaledMalicious(rate=0.4, scale=10.0, seed=5)
    clients, params, loss_fn = tiny_trainer_inputs()
    sched = make_schedule(np.ones((ROUNDS, N)), 0.3)
    sp = SystemParams.table1(N)
    ch = ChannelModel(N)
    out = {}
    for shards in (1, len(jax.devices())):
        tr = FederatedTrainer(loss_fn, params, clients, eta=0.1,
                              batch_size=BATCH, seed=0, backend="packed",
                              shards=shards, fault_model=fm, aggregator=agg)
        out[shards] = (tr, tr.run(sched, sp, ch.uplink, ch.downlink))
    (tr1, h1), (trn, hn) = out[1], out[len(jax.devices())]
    np.testing.assert_array_equal(
        np.asarray([m.train_loss for m in h1]),
        np.asarray([m.train_loss for m in hn]))
    assert tr1.agg_counters == trn.agg_counters
    assert_trainers_bitwise(tr1, trn)


# ---------------------------------------------------------------------------
# API path: summary counters, resume, sweep pooling, report column
# ---------------------------------------------------------------------------

def test_counters_surface_in_summary_and_report(tmp_path):
    res = Experiment(agg_spec(
        aggregator="trimmed_mean", aggregator_kwargs={"beta": 0.3},
        fault_model="scaled_malicious",
        fault_kwargs={"rate": 0.4, "scale": 10.0, "seed": 5})).run()
    a = res.summary["aggregation"]
    assert a["aggregator"] == "trimmed_mean"
    assert a["n_trimmed"] == sum(m.n_agg_adjusted for m in res.history) > 0
    f = res.summary["faults"]
    assert f["n_corrupt_finite"] > 0 and f["n_quarantined"] == 0
    # a mean run keeps the summary exactly as before this layer
    assert "aggregation" not in Experiment(agg_spec()).run().summary
    report = pytest.importorskip("benchmarks.report")
    p = res.to_jsonl(str(tmp_path / "run.jsonl"))
    table = report.runs_table([p])
    assert "aggregation" in table
    assert f"trimmed_mean n_trimmed={a['n_trimmed']}" in table


@pytest.mark.parametrize("rpd", [1, 4])
def test_aggregator_resume_bitwise_with_counters(tmp_path, rpd):
    """Checkpoint/resume mid-attack: trajectory AND aggregation counters
    match the uninterrupted run (attack draws are round-keyed; the
    checkpoint carries the counter totals)."""
    base = agg_spec(aggregator="trimmed_mean",
                    aggregator_kwargs={"beta": 0.3}, rpd=rpd,
                    fault_model="scaled_malicious",
                    fault_kwargs={"rate": 0.4, "scale": 10.0, "seed": 5})
    res_a = Experiment(base).run()
    assert res_a.summary["aggregation"]["n_trimmed"] > 0

    ckpt = str(tmp_path / f"ckpt_rpd{rpd}")
    spec = dataclasses.replace(
        base, run=dataclasses.replace(base.run, checkpoint_dir=ckpt,
                                      checkpoint_every=3))
    Experiment(spec).run()                        # writes checkpoints
    run_b = Experiment(spec).build()
    res_b = run_b.resume(ckpt, step=3)
    assert res_b.summary["resumed_from"] == 3
    np.testing.assert_array_equal(
        np.asarray([m.train_loss for m in res_a.history]),
        np.asarray([m.train_loss for m in res_b.history]))
    assert res_b.summary["aggregation"] == res_a.summary["aggregation"]
    assert res_b.summary["faults"] == res_a.summary["faults"]


def test_aggregator_pools_trainers_in_sweep():
    """The aggregator is engine-construction state: sweeping it must NOT
    reuse one trainer across different aggregators (the trainer key keeps
    them apart), while same-aggregator seeds still pool."""
    sw = SweepSpec(base=agg_spec(), seeds=[0, 1],
                   grid={"scheme.aggregator": ["mean", "coord_median"]})
    res = run_sweep(sw)
    assert not res.errors
    assert res.n_env_builds == 1                  # aggregator is trainer-level
    assert res.n_trainer_builds == 2              # one per aggregator
    a0, a1, b0, b1 = res.results
    assert [m.train_loss for m in a0.history] != \
        [m.train_loss for m in b0.history]
    # seed pooling within an aggregator stayed bit-for-bit: the pooled
    # seed-1 cell equals a cold standalone seed-1 run
    cold = Experiment(override_field(
        agg_spec(aggregator="coord_median"), "run.seed", 1)).run()
    np.testing.assert_array_equal(
        np.asarray([m.train_loss for m in b1.history]),
        np.asarray([m.train_loss for m in cold.history]))


# ---------------------------------------------------------------------------
# Slow tier: defense efficacy at quickstart scale (the acceptance probe)
# ---------------------------------------------------------------------------

@pytest.mark.slow
def test_defense_efficacy_quickstart_scale():
    """30% ScaledMalicious at quickstart scale: trimmed_mean and
    coord_median hold within 2 points of the clean-mean accuracy while the
    undefended mean clearly degrades (benchmarks/robust_aggregation.py
    records the same grid as a committed artifact)."""
    from benchmarks.robust_aggregation import ExpConfig, run_grid
    rows = run_grid(ExpConfig(), rates=(0.0, 0.3),
                    aggregators=[("mean", {}), ("coord_median", {}),
                                 ("trimmed_mean", {"beta": 0.35})])
    acc = {(r["attack_rate"], r["aggregator"]): r["final_accuracy"]
           for r in rows}
    clean = acc[(0.0, "mean")]
    assert clean > 0.3                            # the task is learnable
    assert acc[(0.3, "mean")] < clean - 0.05      # attack really bites
    for name in ("coord_median", "trimmed_mean"):
        assert acc[(0.3, name)] >= clean - 0.02, (name, acc)
