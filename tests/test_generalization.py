"""Lemma 1 / Proposition 1: the generalization statement."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core import generalization as G


def test_entropy_uniform_is_log_k():
    for k in (2, 10, 100):
        assert np.isclose(G.entropy(np.ones(k)), np.log(k))


def test_entropy_pointmass_is_zero():
    assert G.entropy([1.0, 0.0, 0.0]) == pytest.approx(0.0, abs=1e-9)


def test_kl_identity_zero_and_decomposition():
    p = np.array([0.5, 0.3, 0.2])
    q = np.array([0.25, 0.5, 0.25])
    assert G.kl_divergence(p, p) == pytest.approx(0.0, abs=1e-9)
    # eq. (38): KL(p||q) = H(q-part) - I(p,q) with I = H(p)+H(q)-CE(p,q)
    kl = G.kl_divergence(p, q)
    decomp = G.entropy(q) - G.mutual_information_term(p, q)
    assert kl == pytest.approx(decomp, rel=1e-9)


def test_phi_zero_when_aligned():
    """Identical train/test label distributions => KL=0 => phi=0."""
    h = np.array([100, 100, 100, 100.0])
    s = G.generalization_statement(h, h)
    assert s.kl == pytest.approx(0.0, abs=1e-12)
    assert s.phi == pytest.approx(0.0, abs=1e-9)


def test_phi_increases_with_skew():
    test_h = np.ones(10) * 100
    mild = np.ones(10) * 100
    mild[0] = 300
    severe = np.ones(10)
    severe[0] = 991
    phi_mild = G.generalization_statement(mild, test_h).phi
    phi_severe = G.generalization_statement(severe, test_h).phi
    assert 0 < phi_mild < phi_severe


def test_phi_caps_on_disjoint_support():
    tr = np.array([100.0, 0, 0])
    te = np.array([0.0, 50, 50])
    s = G.generalization_statement(tr, te)
    assert s.phi == G.PHI_MAX


def test_client_statements_broadcast_test_hist():
    tr = np.abs(np.random.default_rng(0).normal(size=(5, 10))) + 1
    te = np.ones((1, 10))
    phis = G.phis(tr, te)
    assert phis.shape == (5,)
    assert np.all(phis >= 0)


def test_prop1_increment_bound_monotone_in_phi():
    lo = G.generalization_gap_increment_bound(np.array([1.0]), 0.01, 10.0)
    hi = G.generalization_gap_increment_bound(np.array([5.0]), 0.01, 10.0)
    assert hi > lo > 0


@settings(max_examples=100, deadline=None)
@given(st.lists(st.floats(0.01, 1e4), min_size=2, max_size=20),
       st.lists(st.floats(0.01, 1e4), min_size=2, max_size=20))
def test_phi_nonnegative_finite_inputs(tr, te):
    n = min(len(tr), len(te))
    s = G.generalization_statement(np.array(tr[:n]), np.array(te[:n]))
    assert s.phi >= 0
    assert np.isfinite(s.phi)


@settings(max_examples=60, deadline=None)
@given(st.integers(2, 12), st.floats(0.05, 50.0), st.integers(0, 10_000))
def test_kl_nonnegative_property(k, sigma, seed):
    rng = np.random.default_rng(seed)
    p = rng.dirichlet(sigma * np.ones(k)) + 1e-9
    q = rng.dirichlet(sigma * np.ones(k)) + 1e-9
    assert G.kl_divergence(p, q) >= -1e-9
