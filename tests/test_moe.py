"""MoE: routing, grouped capacity dispatch, load-balance aux."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_config
from repro.models.moe import moe_apply, moe_params, pick_groups, route_topk


def _cfg(**kw):
    base = get_config("mixtral-8x22b").reduced()
    return dataclasses.replace(base, **kw)


def test_route_topk_weights_normalized():
    logits = jax.random.normal(jax.random.key(0), (32, 8))
    w, idx, aux = route_topk(logits, 2)
    np.testing.assert_allclose(np.asarray(w.sum(-1)), 1.0, rtol=1e-5)
    assert idx.shape == (32, 2)
    assert float(aux) >= 1.0 - 1e-3  # load-balance loss >= 1 (E * sum f*p)


def test_uniform_router_aux_is_one():
    """Perfectly uniform routing gives the minimal aux loss E*(1/E)*... = 1."""
    logits = jnp.zeros((1024, 4))
    _, _, aux = route_topk(logits, 1)
    assert float(aux) == pytest.approx(1.0, rel=1e-2)


def test_pick_groups_divides():
    for t in (128, 96, 100, 65536, 7):
        g = pick_groups(t)
        assert t % g == 0
        assert 1 <= g <= 64


@pytest.mark.parametrize("groups", [1, 2, 8])
def test_grouped_matches_dense_oracle(groups):
    cfg = _cfg(moe_capacity_factor=8.0)  # large capacity: no drops
    p = moe_params(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
    y, _ = moe_apply(x, p, cfg, groups=groups)

    xt = np.asarray(x.reshape(-1, cfg.d_model))
    logits = xt @ np.asarray(p["router"])
    w, idx = jax.lax.top_k(jax.nn.softmax(jnp.asarray(logits), -1),
                           cfg.experts_per_token)
    w = np.asarray(w / w.sum(-1, keepdims=True))
    idx = np.asarray(idx)
    y_ref = np.zeros_like(xt)
    for e in range(cfg.num_experts):
        h = jax.nn.silu(xt @ p["w_gate"][e]) * (xt @ p["w_up"][e])
        ye = np.asarray(h @ p["w_down"][e])
        for kk in range(cfg.experts_per_token):
            m = idx[:, kk] == e
            y_ref[m] += w[m, kk, None] * ye[m]
    np.testing.assert_allclose(np.asarray(y).reshape(-1, cfg.d_model), y_ref,
                               rtol=2e-4, atol=2e-4)


def test_capacity_drops_tokens_gracefully():
    """Tiny capacity must not produce NaN or crash — dropped tokens get 0."""
    cfg = _cfg(moe_capacity_factor=0.25)
    p = moe_params(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (2, 32, cfg.d_model))
    y, aux = moe_apply(x, p, cfg, groups=2)
    assert bool(jnp.isfinite(y).all())
    # with drops, some token outputs are exactly zero-contribution
    norms = jnp.linalg.norm(y.reshape(-1, cfg.d_model), axis=-1)
    assert float(norms.min()) < float(norms.max())


def test_moe_gradients_flow_to_experts_and_router():
    cfg = _cfg()
    p = moe_params(jax.random.key(0), cfg)
    x = jax.random.normal(jax.random.key(1), (1, 16, cfg.d_model))

    def loss(p_):
        y, aux = moe_apply(x, p_, cfg)
        return (y**2).mean() + 0.01 * aux

    g = jax.grad(loss)(p)
    for name in ("router", "w_gate", "w_up", "w_down"):
        assert float(jnp.abs(g[name]).sum()) > 0, name


@settings(max_examples=15, deadline=None)
@given(st.integers(0, 1000), st.sampled_from([1.0, 2.0, 8.0]))
def test_moe_finite_property(seed, cf):
    cfg = _cfg(moe_capacity_factor=cf)
    p = moe_params(jax.random.key(seed), cfg)
    x = jax.random.normal(jax.random.key(seed + 1), (1, 16, cfg.d_model))
    y, aux = moe_apply(x, p, cfg)
    assert bool(jnp.isfinite(y).all()) and np.isfinite(float(aux))
