"""(P2)-(P5) solvers + Algorithm 1 (AO)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.convergence import BoundConstants, theta
from repro.core.optimizer_ao import AOConfig, solve_p1
from repro.core.ratio import solve_pruning_ratios
from repro.core.resource import (
    allocate_client, solve_round_resources, solve_schedule_resources,
    sca_round_resources, min_client_delay)
from repro.core.selection import solve_selection, round_objective
from repro.wireless import ChannelModel, SystemParams
from repro.wireless.comm import total_delay, total_energy

N = 6


@pytest.fixture
def env():
    sp = SystemParams.table1(N, dataset="mnist")
    ch = ChannelModel(N, seed=0)
    c = BoundConstants(rounds_S=3, batch_Z=32)
    rng = np.random.default_rng(0)
    phi = rng.uniform(0.2, 3.0, N)
    return sp, ch, c, phi


# ---------------- resource allocation (P2) ----------------

def test_allocate_client_respects_budget_and_boxes(env):
    sp, ch, _, _ = env
    t_min = min_client_delay(0, 0.3, ch.uplink, ch.downlink, sp)
    al = allocate_client(0, 0.3, 2.0 * t_min, ch.uplink, ch.downlink, sp)
    assert al.feasible
    assert al.delay <= 2.0 * t_min * (1 + 1e-6)
    assert 0 <= al.power <= sp.p_max[0] + 1e-12
    assert 0 <= al.freq <= sp.f_max[0] + 1e3


def test_allocate_client_infeasible_when_budget_below_min(env):
    sp, ch, _, _ = env
    t_min = min_client_delay(0, 0.0, ch.uplink, ch.downlink, sp)
    al = allocate_client(0, 0.0, 0.5 * t_min, ch.uplink, ch.downlink, sp)
    assert not al.feasible


def test_more_time_less_energy(env):
    """The energy-vs-delay tradeoff is monotone (convexity sanity)."""
    sp, ch, _, _ = env
    t_min = min_client_delay(0, 0.0, ch.uplink, ch.downlink, sp)
    e = [allocate_client(0, 0.0, k * t_min, ch.uplink, ch.downlink, sp).energy
         for k in (1.2, 2.0, 4.0)]
    assert e[0] >= e[1] >= e[2]


def test_analytic_matches_sca(env):
    """The production decomposition and the paper-faithful SCA (eq. 28)
    land on comparable round energies (within 10%)."""
    sp, ch, _, _ = env
    a = np.ones(N)
    lam = 0.2 * np.ones(N)
    t_round = 2.5 * max(min_client_delay(i, 0.2, ch.uplink, ch.downlink, sp)
                        for i in range(N))
    ana = solve_round_resources(a, lam, t_round, ch.uplink, ch.downlink, sp)
    sca = sca_round_resources(a, lam, 1e9, t_round, ch.uplink, ch.downlink, sp)
    assert ana.feasible
    assert ana.energy <= sca.energy * 1.10  # decomposition is exact per client


# ---------------- pruning-ratio LP (P3) ----------------

def test_lp_zero_when_unconstrained(env):
    sp, ch, c, _ = env
    s = c.rounds_S + 1
    a = np.ones((s, N))
    p = 0.3 * np.ones((s, N))
    f = 300e6 * np.ones((s, N))
    lam, info = solve_pruning_ratios(a, p, f, 1e9, 1e9, ch.uplink,
                                     ch.downlink, sp, c)
    assert info["status"] == "optimal"
    np.testing.assert_allclose(lam, 0.0, atol=1e-8)


def test_lp_prunes_exactly_to_feasibility(env):
    sp, ch, c, _ = env
    s = c.rounds_S + 1
    a = np.ones((s, N))
    p = 0.3 * np.ones((s, N))
    f = 300e6 * np.ones((s, N))
    e_free = total_energy(a, np.zeros((s, N)), p, f, ch.uplink, ch.downlink, sp)
    e0 = 0.8 * e_free
    lam, info = solve_pruning_ratios(a, p, f, e0, 1e9, ch.uplink,
                                     ch.downlink, sp, c)
    assert info["status"] == "optimal"
    assert (lam <= sp.lambda_max + 1e-9).all() and (lam >= -1e-9).all()
    e_after = total_energy(a, lam, p, f, ch.uplink, ch.downlink, sp)
    assert e_after <= e0 * (1 + 1e-6)
    assert lam.sum() > 0  # had to prune something


# ---------------- client selection (P5) ----------------

def test_exact_selection_beats_or_matches_paper_heuristic(env):
    sp, ch, c, phi = env
    s = c.rounds_S + 1
    lam = 0.2 * np.ones((s, N))
    t0 = s * 3.0 * max(min_client_delay(i, 0.2, ch.uplink, ch.downlink, sp)
                       for i in range(N))
    a_ex, info_ex = solve_selection(lam, phi, c, 1e9, t0, ch.uplink,
                                    ch.downlink, sp, method="exact")
    a_pp, info_pp = solve_selection(lam, phi, c, 1e9, t0, ch.uplink,
                                    ch.downlink, sp, method="paper")
    assert info_ex["objective"] <= info_pp["objective"] + 1e-9
    assert a_ex.shape == (s, N)
    assert set(np.unique(a_ex)).issubset({0.0, 1.0})


def test_selection_prefers_low_phi(env):
    sp, ch, c, _ = env
    s = c.rounds_S + 1
    phi = np.array([0.1, 0.1, 8.0, 9.0, 10.0, 11.0])
    lam = np.zeros((s, N))
    t0 = s * 3.0 * max(min_client_delay(i, 0.0, ch.uplink, ch.downlink, sp)
                       for i in range(N))
    a, _ = solve_selection(lam, phi, c, 1e9, t0, ch.uplink, ch.downlink, sp)
    # low-phi clients selected at least as often as high-phi ones
    counts = a.sum(axis=0)
    assert counts[0] >= counts[-1]
    assert a.sum() >= s  # at least one client every round


# ---------------- Algorithm 1 ----------------

def test_ao_produces_feasible_nonincreasing_schedule(env):
    sp, ch, c, phi = env
    t0 = (c.rounds_S + 1) * 3.0 * max(
        min_client_delay(i, 0.0, ch.uplink, ch.downlink, sp) for i in range(N))
    sched = solve_p1(phi, 50.0, t0, ch.uplink, ch.downlink, sp, c,
                     AOConfig(outer_iters=3))
    assert sched.feasible
    assert sched.energy <= 50.0 * (1 + 1e-4)
    assert sched.delay <= t0 * (1 + 1e-4)
    # theta consistency
    assert sched.theta == pytest.approx(theta(sched.a, sched.lam, phi, c))
    # incumbent is the best feasible iterate
    feas = [h["theta"] for h in sched.history if h["feasible"]]
    assert sched.theta == pytest.approx(min(feas))


def test_ao_tight_energy_forces_pruning_or_fewer_clients(env):
    sp, ch, c, phi = env
    t0 = (c.rounds_S + 1) * 3.0 * max(
        min_client_delay(i, 0.0, ch.uplink, ch.downlink, sp) for i in range(N))
    loose = solve_p1(phi, 1e9, t0, ch.uplink, ch.downlink, sp, c,
                     AOConfig(outer_iters=2))
    tight = solve_p1(phi, 0.3, t0, ch.uplink, ch.downlink, sp, c,
                     AOConfig(outer_iters=2))
    assert tight.energy <= 0.3 * (1 + 1e-4)
    # under the tight budget the system uses more pruning or fewer clients
    assert (tight.lam.sum() >= loose.lam.sum() - 1e-9) or \
        (tight.a.sum() <= loose.a.sum())
