"""Pallas kernels vs ref.py oracles: shape/dtype sweeps in interpret mode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels import ops, ref

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _qkv(b, s, hq, hkv, d, dtype, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    mk = lambda k, h: jax.random.normal(k, (b, s, h, d), jnp.float32).astype(dtype)
    return mk(ks[0], hq), mk(ks[1], hkv), mk(ks[2], hkv)


# ------------------------- flash attention -------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(1, 128, 4, 4, 64), (2, 256, 8, 2, 64),
                                   (1, 256, 4, 1, 128)])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_sweep(dtype, shape, causal):
    b, s, hq, hkv, d = shape
    q, k, v = _qkv(b, s, hq, hkv, d, dtype)
    out = ops.flash_attention(q, k, v, causal=causal, block_q=64, block_k=64)
    r = ref.flash_attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                                jnp.swapaxes(v, 1, 2), causal=causal)
    r = jnp.swapaxes(r, 1, 2)
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(r, np.float32), **TOL[dtype])


@pytest.mark.parametrize("window", [64, 100])
def test_flash_attention_window(window):
    q, k, v = _qkv(1, 256, 4, 2, 64, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, window=window,
                              block_q=64, block_k=64)
    r = ref.flash_attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                                jnp.swapaxes(v, 1, 2), causal=True,
                                window=window)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.swapaxes(r, 1, 2)),
                               rtol=2e-5, atol=2e-5)


def test_flash_attention_softcap():
    q, k, v = _qkv(1, 128, 4, 4, 64, jnp.float32)
    out = ops.flash_attention(q, k, v, causal=True, cap=20.0,
                              block_q=64, block_k=64)
    r = ref.flash_attention_ref(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                                jnp.swapaxes(v, 1, 2), causal=True, cap=20.0)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.swapaxes(r, 1, 2)),
                               rtol=2e-5, atol=2e-5)


# ------------------------- pruning kernels -------------------------

@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("shape", [(64,), (37, 53), (8, 16, 24), (1000,)])
def test_importance_mask_sweep(dtype, shape):
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=shape), dtype)
    v = jnp.asarray(rng.normal(size=shape), dtype)
    thr = 0.25
    q, m = ops.importance_and_mask(w, v, thr)
    qr, mr = ref.importance_mask_ref(w, v, thr)
    np.testing.assert_allclose(np.asarray(q), np.asarray(qr), **TOL[dtype])
    np.testing.assert_array_equal(np.asarray(m), np.asarray(mr))


@pytest.mark.parametrize("shape", [(129,), (64, 64), (7, 13)])
def test_masked_update_sweep(shape):
    rng = np.random.default_rng(1)
    w = jnp.asarray(rng.normal(size=shape), jnp.float32)
    g = jnp.asarray(rng.normal(size=shape), jnp.float32)
    m = jnp.asarray(rng.integers(0, 2, size=shape), jnp.float32)
    out = ops.masked_update(w, g, m, 0.05)
    expect = ref.masked_update_ref(w, g, m, 0.05)
    np.testing.assert_allclose(np.asarray(out), np.asarray(expect),
                               rtol=1e-6, atol=1e-6)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 500), st.floats(0.0, 2.0), st.integers(0, 9999))
def test_importance_mask_property(n, thr, seed):
    rng = np.random.default_rng(seed)
    w = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(n,)), jnp.float32)
    q, m = ops.importance_and_mask(w, v, thr)
    qr, mr = ref.importance_mask_ref(w, v, thr)
    np.testing.assert_allclose(np.asarray(q), np.asarray(qr), rtol=1e-6)
    np.testing.assert_array_equal(np.asarray(m), np.asarray(mr))


# ------------------------- SSD chunk kernel -------------------------

@pytest.mark.parametrize("dims", [(1, 64, 2, 32, 16), (2, 128, 4, 64, 32)])
def test_ssd_chunk_kernel_vs_ref(dims):
    b, q, h, p, n = dims
    ks = jax.random.split(jax.random.key(0), 4)
    x = jax.random.normal(ks[0], (b, q, h, p)) * 0.3
    bb = jax.random.normal(ks[1], (b, q, n)) * 0.3
    cc = jax.random.normal(ks[2], (b, q, n)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, q, h)))
    a_log = jnp.log(jnp.linspace(1.0, 4.0, h))
    from repro.kernels.ssd_chunk import ssd_chunk
    y, st_, dec = ssd_chunk(x, bb, cc, dt, a_log)
    yr, str_, decr = ref.ssd_chunk_ref(x, bb, cc, dt, a_log)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(st_),
                               np.asarray(jnp.swapaxes(str_, -1, -2)),
                               rtol=2e-5, atol=2e-5)
    np.testing.assert_allclose(np.asarray(dec), np.asarray(decr),
                               rtol=2e-5, atol=2e-5)


def test_ssd_full_sequence_pallas_vs_model_impl():
    import dataclasses
    from repro.configs import get_config
    from repro.models.ssm import ssd_chunked
    b, s, h, p, n = 2, 256, 4, 64, 32
    ks = jax.random.split(jax.random.key(1), 4)
    x = jax.random.normal(ks[0], (b, s, h, p)) * 0.3
    bb = jax.random.normal(ks[1], (b, s, n)) * 0.3
    cc = jax.random.normal(ks[2], (b, s, n)) * 0.3
    dt = jax.nn.softplus(jax.random.normal(ks[3], (b, s, h)))
    a_log = jnp.log(jnp.linspace(1.0, 4.0, h))
    y_pl, f_pl = ops.ssd_chunked_pallas(x, bb, cc, dt, a_log, chunk=64)
    cfg = dataclasses.replace(get_config("mamba2-130m"), ssm_chunk=64,
                              ssm_head_dim=p)
    y_j, f_j = ssd_chunked(x, bb, cc, dt, a_log, jnp.zeros(h), cfg)
    np.testing.assert_allclose(np.asarray(y_pl), np.asarray(y_j),
                               rtol=2e-4, atol=2e-4)
    np.testing.assert_allclose(np.asarray(f_pl), np.asarray(f_j),
                               rtol=2e-4, atol=2e-4)


# ------------------------- decode attention kernel -------------------------

@pytest.mark.parametrize("dims", [(2, 512, 4, 2, 64), (1, 1024, 8, 8, 128)])
@pytest.mark.parametrize("pos_frac", [0.3, 1.0])
def test_decode_attention_kernel(dims, pos_frac):
    b, skv, hq, hkv, d = dims
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, 1, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, skv, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, skv, hkv, d), jnp.float32)
    pos = max(1, int(skv * pos_frac))
    out = ops.decode_attention(q, k, v, pos, block_k=256)
    r = ref.decode_attention_ref(jnp.swapaxes(q, 1, 2), k, v, pos)
    np.testing.assert_allclose(np.asarray(out),
                               np.asarray(jnp.swapaxes(r, 1, 2)),
                               rtol=2e-5, atol=2e-5)


def test_decode_attention_matches_model_path():
    from repro.models.attention import decode_attention as model_decode
    b, skv, hq, hkv, d = 2, 256, 4, 2, 32
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (b, 1, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, skv, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, skv, hkv, d), jnp.float32)
    out_kernel = ops.decode_attention(q, k, v, 200, block_k=64)
    out_model = model_decode(q, k, v, 200)
    np.testing.assert_allclose(np.asarray(out_kernel), np.asarray(out_model),
                               rtol=2e-5, atol=2e-5)
