"""Multi-round block engine: `RoundEngine.block_step` (lax.scan over the
schedule) + the trainer's block partitioning and on-device client store.

Parity contract under test: a K-round block is bit-for-bit equal to K
sequential `round_step` dispatches AND to ``backend="reference"`` on fp32
single-device runs — shared-lambda, per-client-lambda, ragged clients, and
varying AO-style selection included — while compiling a bounded number of
traces and uploading ZERO per-round batch data.

The sharded tests need a multi-device host; scripts/test.sh reruns this
file under XLA_FLAGS=--xla_force_host_platform_device_count=4 (the sharded
smoke leg), which un-skips them and runs every other test here on the
mesh-parallel block path."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _trainer_pair import (assert_trainers_bitwise, make_schedule,
                           run_pair)
from repro.core import ClientData, FederatedTrainer, ParamPack, RoundEngine
from repro.core.client_store import ClientStore
from repro.data import make_dataset
from repro.models import lenet_init, lenet_apply, make_loss_fn
from repro.wireless import ChannelModel, SystemParams

multidevice = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs a multi-device host "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=N)")


def _hetero_env(sizes, seed=0):
    ds = make_dataset("synthetic-mnist", n_train=sum(sizes),
                      n_test=60, seed=seed)
    off = np.cumsum([0] + list(sizes))
    clients = [ClientData(ds.x_train[a:b], ds.y_train[a:b])
               for a, b in zip(off, off[1:])]
    return clients, lenet_init(jax.random.key(seed)), make_loss_fn(lenet_apply)


def _varying_schedule(n, rounds, seed, min_sel=1):
    rng = np.random.default_rng(seed)
    a = np.zeros((rounds, n))
    for s in range(rounds):
        sel = rng.choice(n, size=rng.integers(min_sel, n + 1), replace=False)
        a[s, sel] = 1.0
    return a


# -- client store ------------------------------------------------------------


def test_client_store_matches_host_upload():
    """Gathered batches are bitwise what the per-round path would upload."""
    clients, _, _ = _hetero_env([40, 20, 7])
    store = ClientStore.build(clients)
    assert store.n_clients == 3
    assert list(store.counts) == [40, 20, 7]
    assert store.x.shape[1] == 40 and store.nbytes > 0
    rng = np.random.default_rng(0)
    idx = np.stack([rng.choice(len(c), size=5) for c in clients])
    cids = jnp.asarray([0, 1, 2], jnp.int32)
    xs, ys = store.gather(cids, jnp.asarray(idx, jnp.int32))
    for c in range(3):
        assert bool(jnp.all(xs[c] == jnp.asarray(clients[c].x[idx[c]])))
        assert bool(jnp.all(ys[c] == jnp.asarray(clients[c].y[idx[c]])))
    # dtypes canonicalize exactly like the per-round jnp.asarray upload
    assert xs.dtype == jnp.asarray(clients[0].x).dtype
    assert ys.dtype == jnp.asarray(clients[0].y).dtype


# -- engine-level block parity ----------------------------------------------


@pytest.fixture(scope="module")
def block_env():
    clients, params, loss_fn = _hetero_env([120, 90, 90])
    pack = ParamPack.build(params)
    eng = RoundEngine(loss_fn, pack, eta=0.1, shards=1,
                      weighted_loss_fn=loss_fn.weighted)
    return clients, params, loss_fn, pack, eng


def _draws(clients, cids_row, batch, rng):
    return np.stack([rng.choice(len(clients[c]), size=batch,
                                replace=len(clients[c]) < batch)
                     for c in cids_row]).astype(np.int32)


@pytest.mark.parametrize("family", ["shared", "multi"])
def test_block_step_bitwise_equals_sequential_round_steps(block_env, family):
    clients, params, loss_fn, pack, eng = block_env
    store = ClientStore.build(clients)
    k_rounds, n_c, batch = 4, 3, 8
    rng = np.random.default_rng(0)
    cids = np.broadcast_to(np.arange(n_c, dtype=np.int32),
                           (k_rounds, n_c)).copy()
    idxs = np.stack([_draws(clients, cids[k], batch, rng)
                     for k in range(k_rounds)])
    if family == "shared":
        # per-round varying shared lambda (round 0 warms v at lam=0)
        lams = np.broadcast_to(np.asarray([0.0, 0.2, 0.3, 0.4])[:, None],
                               (k_rounds, n_c)).copy()
    else:
        lams = np.broadcast_to(np.asarray([0.1, 0.3, 0.45]),
                               (k_rounds, n_c)).copy()
    counts = np.full(k_rounds, n_c)

    w0, v0 = eng.init_buffers(params)
    w, v = w0, v0
    seq_losses, seq_thrs = [], []
    for k in range(k_rounds):
        xs = jnp.asarray(np.stack([clients[c].x[idxs[k][c]]
                                   for c in range(n_c)]))
        ys = jnp.asarray(np.stack([clients[c].y[idxs[k][c]]
                                   for c in range(n_c)]))
        w, v, losses, thr, _ = eng.round_step(w, v, xs, ys, lams[k])
        seq_losses.append(np.asarray(losses))
        seq_thrs.append(np.asarray(thr))

    w_b, v_b, losses_b, thr_b = eng.block_step(
        w0, v0, store, cids, idxs, lams, counts)
    assert bool(jnp.all(w_b == w))
    assert bool(jnp.all(v_b == v))
    assert bool(jnp.all(jnp.asarray(np.stack(seq_losses))
                        == losses_b[:, :n_c]))
    if family == "shared":
        assert thr_b.shape == (k_rounds,)
        assert np.array_equal(np.stack(seq_thrs), np.asarray(thr_b))
    else:
        assert thr_b.shape[0] == k_rounds
        assert np.array_equal(np.stack(seq_thrs),
                              np.asarray(thr_b)[:, :n_c])


def test_block_step_validates_inputs(block_env):
    clients, params, _, _, eng = block_env
    store = ClientStore.build(clients)
    w, v = eng.init_buffers(params)
    cids = np.zeros((2, 2), np.int32)
    idxs = np.zeros((2, 2, 4), np.int32)
    with pytest.raises(ValueError):        # lambda out of range
        eng.block_step(w, v, store, cids, idxs, np.full((2, 2), 1.0),
                       np.full(2, 2))
    with pytest.raises(ValueError):        # count exceeds array width
        eng.block_step(w, v, store, cids, idxs, np.full((2, 2), 0.2),
                       np.asarray([2, 3]))
    with pytest.raises(ValueError):        # mixed buckets in one block
        eng.block_step(w, v, store,
                       np.zeros((2, 3), np.int32),
                       np.zeros((2, 3, 4), np.int32),
                       np.full((2, 3), 0.2), np.asarray([1, 3]))


# -- trainer-level block parity ----------------------------------------------


def test_block_trainer_bitwise_vs_reference_varying_schedule():
    """AO-style varying selection (varying C, ragged stragglers, eval
    boundaries) through rounds_per_dispatch=4: bit-for-bit equal to the
    reference backend, zero fallbacks, zero per-round batch uploads, and a
    bounded trace count over the (C, K) bucket grid."""
    sizes = [60, 40, 30, 25, 20, 18, 10, 7, 3]
    clients, params, loss_fn = _hetero_env(sizes)
    a = _varying_schedule(len(sizes), 20, seed=5)
    out = run_pair(clients, params, loss_fn, make_schedule(a, 0.3),
                   shards=1, rounds_per_dispatch=4)
    (tr_ref, h_ref), (tr_pk, h_pk) = out["reference"], out["packed"]
    assert tr_pk.n_fallback_rounds == 0
    assert tr_pk.n_batch_uploads == 0
    assert tr_pk.n_block_dispatches > 0
    for mr, mp in zip(h_ref, h_pk):
        assert mr.train_loss == mp.train_loss
    assert_trainers_bitwise(tr_ref, tr_pk)
    eng = tr_pk.engine
    assert eng.k_buckets_used <= {1, 2, 4}           # pow2 ladder, <= rpd
    assert eng.n_traces <= len(eng.buckets_used) * len(eng.k_buckets_used)


def test_block_trainer_per_client_lambda_bitwise():
    sizes = [60, 40, 30, 20, 10]
    clients, params, loss_fn = _hetero_env(sizes)
    a = _varying_schedule(len(sizes), 12, seed=9, min_sel=2)
    lam = np.broadcast_to(np.linspace(0.1, 0.5, len(sizes)), a.shape)
    out = run_pair(clients, params, loss_fn, make_schedule(a, lam),
                   shards=1, rounds_per_dispatch=8)
    (tr_ref, _), (tr_pk, _) = out["reference"], out["packed"]
    assert tr_pk.n_fallback_rounds == 0
    assert tr_pk.n_batch_uploads == 0
    assert tr_pk.engine.n_traces <= (len(tr_pk.engine.buckets_used)
                                     * len(tr_pk.engine.k_buckets_used))
    assert_trainers_bitwise(tr_ref, tr_pk)


def test_block_mode_matches_per_round_with_eval_and_stop():
    """Eval cadence (blocks must end at eval rounds) and stop conditions
    (schedule truncation) behave identically in block and per-round mode —
    including the eval numbers, which are bitwise because params are."""
    sizes = [60, 40, 30, 20]
    clients, params, loss_fn = _hetero_env(sizes)
    ds = make_dataset("synthetic-mnist", n_train=150, n_test=80, seed=3)
    from repro.models import make_eval_fn
    eval_fn = make_eval_fn(lenet_apply, ds.x_test, ds.y_test)
    n = len(sizes)
    a = np.ones((11, n))
    hists = {}
    for rpd in (1, 8):
        tr = FederatedTrainer(loss_fn, params, clients, eta=0.1,
                              batch_size=16, seed=0, backend="packed",
                              shards=1, rounds_per_dispatch=rpd)
        sp = SystemParams.table1(n)
        ch = ChannelModel(n)
        hists[rpd] = tr.run(make_schedule(a, 0.3), sp, ch.uplink, ch.downlink,
                            eval_fn=eval_fn, eval_every=3,
                            stop_delay=None)
    assert len(hists[1]) == len(hists[8])
    for m1, mb in zip(hists[1], hists[8]):
        assert m1.train_loss == mb.train_loss
        assert m1.test_loss == mb.test_loss
        assert m1.test_accuracy == mb.test_accuracy
    # stop_delay truncation: identical history length + metrics
    for rpd in (1, 8):
        tr = FederatedTrainer(loss_fn, params, clients, eta=0.1,
                              batch_size=16, seed=0, backend="packed",
                              shards=1, rounds_per_dispatch=rpd)
        sp = SystemParams.table1(n)
        ch = ChannelModel(n)
        hists[rpd] = tr.run(make_schedule(a, 0.3), sp, ch.uplink, ch.downlink,
                            stop_delay=hists[1][4].cumulative_delay)
    assert len(hists[1]) == len(hists[8]) == 5
    for m1, mb in zip(hists[1], hists[8]):
        assert m1.train_loss == mb.train_loss


def test_block_mode_empty_rounds_and_fallback_rounds_interleave():
    """Rounds the block path cannot take (empty selection; mixed-length
    batches without a weighted loss) still run exactly as before, with
    blocks resuming around them."""
    sizes = [40, 30, 7]                     # 7 < batch 16 -> ragged
    clients, params, loss_fn = _hetero_env(sizes)
    n = len(sizes)
    a = np.ones((6, n))
    a[2] = 0.0                              # empty round mid-schedule
    # strip the weighted loss: ragged rounds must fall back per-round
    def bare_loss(p, x, y):
        return loss_fn(p, x, y)
    out = run_pair(clients, params, bare_loss, make_schedule(a, 0.3),
                   shards=1, rounds_per_dispatch=4)
    (tr_ref, h_ref), (tr_pk, h_pk) = out["reference"], out["packed"]
    assert tr_pk.n_fallback_rounds == 5     # every non-empty round is mixed
    for mr, mp in zip(h_ref, h_pk):
        assert (np.isnan(mr.train_loss) and np.isnan(mp.train_loss)) \
            or mr.train_loss == mp.train_loss
    assert_trainers_bitwise(tr_ref, tr_pk)


def test_block_auto_resolution():
    sizes = [40, 30]
    clients, params, loss_fn = _hetero_env(sizes)
    tr = FederatedTrainer(loss_fn, params, clients, eta=0.1, batch_size=8,
                          seed=0, backend="packed")
    expect = 1 if jax.default_backend() == "cpu" else 32
    assert tr.rounds_per_dispatch == expect
    tr = FederatedTrainer(loss_fn, params, clients, eta=0.1, batch_size=8,
                          seed=0, backend="reference",
                          rounds_per_dispatch=16)
    assert tr.rounds_per_dispatch == 1      # reference never blocks
    with pytest.raises(ValueError):
        FederatedTrainer(loss_fn, params, clients, eta=0.1, batch_size=8,
                         seed=0, backend="packed", rounds_per_dispatch=0)


def test_trace_bound_over_varying_c_k_lambda():
    """50 AO-style rounds with varying C, varying shared lambda, ragged
    stragglers, rpd=8: compiled traces stay within the (C-bucket x
    K-bucket) grid — no retrace storm from block mode."""
    sizes = [60, 40, 30, 25, 20, 18, 10, 7, 3]
    clients, params, loss_fn = _hetero_env(sizes)
    n = len(sizes)
    a = _varying_schedule(n, 50, seed=11)
    rng = np.random.default_rng(12)
    lam = np.broadcast_to(
        np.round(rng.uniform(0.1, 0.5, size=(50, 1)), 2), a.shape)
    tr = FederatedTrainer(loss_fn, params, clients, eta=0.1, batch_size=16,
                          seed=0, backend="packed", shards=1,
                          rounds_per_dispatch=8)
    sp = SystemParams.table1(n)
    ch = ChannelModel(n)
    tr.run(make_schedule(a, lam), sp, ch.uplink, ch.downlink)
    eng = tr.engine
    assert tr.n_batch_uploads == 0
    assert eng.k_buckets_used <= {1, 2, 4, 8}
    assert eng.n_traces <= len(eng.buckets_used) * len(eng.k_buckets_used)
    # the bound is meaningfully below one-trace-per-round
    assert eng.n_traces < 50


# -- sharded block path (multi-device host) ----------------------------------


@multidevice
def test_sharded_block_matches_sharded_per_round():
    """Block mode on the mesh: bitwise-equal losses to the sharded
    per-round path (identical math modulo program structure) and params
    matching within the same tolerance the sharded per-round tests use."""
    sizes = [60, 30, 20, 10, 7, 3]
    clients, params, loss_fn = _hetero_env(sizes)
    n = len(sizes)
    a = _varying_schedule(n, 8, seed=3, min_sel=2)
    hists, trs = {}, {}
    for rpd in (1, 4):
        tr = FederatedTrainer(loss_fn, params, clients, eta=0.1,
                              batch_size=16, seed=0, backend="packed",
                              rounds_per_dispatch=rpd)
        sp = SystemParams.table1(n)
        ch = ChannelModel(n)
        hists[rpd] = tr.run(make_schedule(a, 0.3), sp, ch.uplink, ch.downlink)
        trs[rpd] = tr
    assert trs[4].engine.mesh is not None
    assert trs[4].n_batch_uploads == 0 and trs[4].n_block_dispatches > 0
    for m1, mb in zip(hists[1], hists[4]):
        assert m1.train_loss == mb.train_loss
    for p1, pb in zip(jax.tree_util.tree_leaves(trs[1].params),
                      jax.tree_util.tree_leaves(trs[4].params)):
        np.testing.assert_allclose(np.asarray(p1), np.asarray(pb),
                                   rtol=1e-6, atol=1e-7)


@multidevice
def test_sharded_block_per_client_lambda():
    sizes = [60, 30, 20, 10]
    clients, params, loss_fn = _hetero_env(sizes)
    n = len(sizes)
    a = np.ones((4, n))
    lam = np.broadcast_to(np.linspace(0.1, 0.4, n), a.shape)
    hists = {}
    for rpd in (1, 4):
        tr = FederatedTrainer(loss_fn, params, clients, eta=0.1,
                              batch_size=16, seed=0, backend="packed",
                              rounds_per_dispatch=rpd)
        sp = SystemParams.table1(n)
        ch = ChannelModel(n)
        hists[rpd] = tr.run(make_schedule(a, lam), sp, ch.uplink, ch.downlink)
    for m1, mb in zip(hists[1], hists[4]):
        assert m1.train_loss == mb.train_loss
