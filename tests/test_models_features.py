"""Architecture-feature tests: softcaps, SW/local-global, SSM equivalences,
hybrid fusion, VLM gates — behaviors beyond shape-correctness."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.models import (Runtime, decode_step, forward, init_cache,
                          init_params, prefill)
from repro.models.layers import softcap

RT = Runtime(attn_impl="naive")


def test_softcap_bounds_and_identity():
    x = jnp.linspace(-100, 100, 101)
    y = softcap(x, 30.0)
    assert float(jnp.abs(y).max()) <= 30.0
    np.testing.assert_allclose(np.asarray(softcap(x, 0.0)), np.asarray(x))
    # near zero it's ~identity
    small = jnp.linspace(-0.1, 0.1, 11)
    np.testing.assert_allclose(np.asarray(softcap(small, 30.0)),
                               np.asarray(small), rtol=1e-3, atol=1e-5)


def test_gemma2_final_softcap_applied():
    cfg = get_config("gemma2-9b").reduced()
    params = init_params(jax.random.key(0), cfg)
    # inflate the head so logits would exceed the cap without capping
    params["embed"] = params["embed"] * 50.0
    toks = jax.random.randint(jax.random.key(1), (1, 16), 0, cfg.vocab_size)
    logits = forward(params, toks, cfg, RT)
    assert float(jnp.abs(logits).max()) <= cfg.final_softcap + 1e-3


def test_sliding_window_localizes_attention():
    """Far-past tokens must not influence a SW layer's decode output."""
    cfg = dataclasses.replace(get_config("mixtral-8x22b").reduced(),
                              sliding_window=8)
    params = init_params(jax.random.key(0), cfg)
    s = 32
    base = jax.random.randint(jax.random.key(1), (1, s), 0, cfg.vocab_size)
    # variant differs ONLY in tokens far outside every window
    variant = base.at[:, :8].set((base[:, :8] + 7) % cfg.vocab_size)

    def last_logits(tokens):
        cache = init_cache(cfg, 1, s)
        _, cache = prefill(params, tokens[:, :-1], cache, cfg, RT, None)
        lg, _ = decode_step(params, tokens[:, -1:], cache, s - 1, cfg, RT)
        return lg

    l1 = last_logits(base)
    l2 = last_logits(variant)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2),
                               rtol=1e-4, atol=1e-4)
    # control: changing tokens INSIDE the window must change the output
    variant_in = base.at[:, -4].set((base[:, -4] + 7) % cfg.vocab_size)
    l3 = last_logits(variant_in)
    assert float(jnp.abs(l1 - l3).max()) > 1e-3


def test_mamba2_long_decode_state_is_constant_size():
    cfg = get_config("mamba2-130m").reduced()
    c1 = init_cache(cfg, 2, 128)
    c2 = init_cache(cfg, 2, 1 << 19)
    s1 = sum(np.prod(x.shape) for x in jax.tree.leaves(c1))
    s2 = sum(np.prod(x.shape) for x in jax.tree.leaves(c2))
    assert s1 == s2  # attention-free: O(1) state in context length


def test_ssm_multi_step_decode_matches_forward():
    """Token-by-token SSM decode == full forward (recurrence correctness)."""
    cfg = get_config("mamba2-130m").reduced()
    params = init_params(jax.random.key(0), cfg)
    s = 20
    toks = jax.random.randint(jax.random.key(1), (1, s), 0, cfg.vocab_size)
    full = forward(params, toks, cfg, RT)
    cache = init_cache(cfg, 1, s)
    _, cache = prefill(params, toks[:, :8], cache, cfg, RT, None)
    outs = []
    for t in range(8, s):
        lg, cache = decode_step(params, toks[:, t:t + 1], cache, t, cfg, RT)
        outs.append(lg)
    # decode at position t returns logits for predicting t+1 == full[:, t]
    for i, t in enumerate(range(8, s)):
        np.testing.assert_allclose(np.asarray(outs[i][0]),
                                   np.asarray(full[0, t]),
                                   rtol=2e-2, atol=2e-2)


def test_hybrid_uses_both_paths():
    """Zeroing the SSM branch must change hymba's output (and same for attn)."""
    cfg = get_config("hymba-1.5b").reduced()
    params = init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, 32), 0, cfg.vocab_size)
    base = forward(params, toks, cfg, RT)
    p2 = jax.tree_util.tree_map_with_path(
        lambda kp, x: jnp.zeros_like(x)
        if "mixer" in jax.tree_util.keystr(kp) and "out_proj" in
        jax.tree_util.keystr(kp) else x, params)
    no_ssm = forward(p2, toks, cfg, RT)
    assert float(jnp.abs(base - no_ssm).max()) > 1e-4
    p3 = jax.tree_util.tree_map_with_path(
        lambda kp, x: jnp.zeros_like(x)
        if "attn" in jax.tree_util.keystr(kp) and "wo" in
        jax.tree_util.keystr(kp) else x, params)
    no_attn = forward(p3, toks, cfg, RT)
    assert float(jnp.abs(base - no_attn).max()) > 1e-4


def test_vlm_vision_tokens_affect_output():
    cfg = get_config("llama-3.2-vision-90b").reduced()
    params = init_params(jax.random.key(0), cfg)
    # gates init at 0 => tanh(0)=0 => vision has NO effect until gates open
    toks = jax.random.randint(jax.random.key(1), (1, 16), 0, cfg.vocab_size)
    v1 = {"vision_embeddings": jnp.ones((1, cfg.vision_tokens, cfg.d_model),
                                        jnp.float32)}
    v2 = {"vision_embeddings": -jnp.ones((1, cfg.vision_tokens, cfg.d_model),
                                         jnp.float32)}
    l1 = forward(params, toks, cfg, RT, v1)
    l2 = forward(params, toks, cfg, RT, v2)
    np.testing.assert_allclose(np.asarray(l1), np.asarray(l2), atol=1e-5)
    # open the gates: vision now matters (llama-3.2 gated cross-attn)
    params2 = jax.tree_util.tree_map_with_path(
        lambda kp, x: jnp.ones_like(x)
        if "gate" in jax.tree_util.keystr(kp) else x, params)
    l1g = forward(params2, toks, cfg, RT, v1)
    l2g = forward(params2, toks, cfg, RT, v2)
    assert float(jnp.abs(l1g - l2g).max()) > 1e-4


def test_whisper_encoder_affects_decoder():
    cfg = get_config("whisper-small").reduced()
    params = init_params(jax.random.key(0), cfg)
    toks = jax.random.randint(jax.random.key(1), (1, 16), 0, cfg.vocab_size)
    # NB: constant inputs are cancelled by the encoder LayerNorm; use random
    e1 = {"encoder_input": jax.random.normal(
        jax.random.key(2), (1, cfg.encoder_tokens, cfg.d_model))}
    e2 = {"encoder_input": jax.random.normal(
        jax.random.key(3), (1, cfg.encoder_tokens, cfg.d_model))}
    l1 = forward(params, toks, cfg, RT, e1)
    l2 = forward(params, toks, cfg, RT, e2)
    assert float(jnp.abs(l1 - l2).max()) > 1e-4


def test_structured_slice_reduces_ffn_width():
    from repro.launch.steps import structured_slice
    cfg = get_config("yi-9b").reduced()
    params = init_params(jax.random.key(0), cfg)
    sliced, _ = structured_slice(params, 0.25)
    w0 = params["blocks"]["mlp"]["w_gate"]
    w1 = sliced["blocks"]["mlp"]["w_gate"]
    assert w1.shape[-1] == int(w0.shape[-1] * 0.75)
    wd0 = params["blocks"]["mlp"]["w_down"]
    wd1 = sliced["blocks"]["mlp"]["w_down"]
    assert wd1.shape[-2] == int(wd0.shape[-2] * 0.75)
    # model still runs with sliced widths
    toks = jax.random.randint(jax.random.key(1), (1, 16), 0, cfg.vocab_size)
    logits = forward(sliced, toks, cfg, RT)
    assert bool(jnp.isfinite(logits).all())


def test_int8_kv_cache_decode_close_to_bf16():
    """Quantized KV decode stays within int8 tolerance of the bf16 path."""
    cfg = get_config("yi-9b").reduced()
    params = init_params(jax.random.key(0), cfg)
    s = 48
    toks = jax.random.randint(jax.random.key(1), (1, s), 0, cfg.vocab_size)
    outs = {}
    for quant in (False, True):
        cache = init_cache(cfg, 1, s, kv_quant=quant)
        _, cache = prefill(params, toks[:, : s - 1], cache, cfg, RT, None)
        lg, _ = decode_step(params, toks[:, -1:], cache, s - 1, cfg, RT)
        outs[quant] = lg
        if quant:
            assert cache["k"].dtype == jnp.int8
    err = float(jnp.abs(outs[True] - outs[False]).max())
    assert err < 0.1, err
    # and the argmax prediction agrees
    assert int(outs[True][0].argmax()) == int(outs[False][0].argmax())
