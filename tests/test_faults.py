"""Fault injection + graceful degradation (core/faults.py, DESIGN.md §10).

Differential coverage mirrors tests/test_scenario_axes.py: every fault
model is bitwise-equal between backend="packed" (shards pinned to 1) and
backend="reference" — the single-device bit-for-bit contract — and between
rounds_per_dispatch=1 and =4 block dispatch under the DEFAULT shard count
(the forced 4-device CI leg runs this file on the mesh). Degradation
semantics get direct tests: an all-dropped round leaves the params bitwise
unchanged and counts as skipped, NaN-poisoned uploads are quarantined by
the engine guard (finite trajectory), and fault_model=None stays a bitwise
no-op vs the pre-fault engine (test_golden pins that separately). Plus
unit coverage for draw determinism / population invariance, the registry
factories, spec round-tripping, counter surfacing through RunResult, and
bit-for-bit checkpoint resume of a faulted run including its counters.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    FAULT_MODELS, DataSpec, Experiment, ExperimentSpec, ModelSpec, RunSpec,
    SchemeSpec, SweepSpec, WirelessSpec, override_field, run_sweep,
)
from repro.core import (
    ClientData, ClientDropout, CorruptUpload, FaultDraw, FederatedTrainer,
    MixedFaults, StragglerTimeout,
)
from repro.models import make_loss_fn
from repro.wireless import ChannelModel, SystemParams

from _trainer_pair import assert_trainers_bitwise, make_schedule

N, ROUNDS, BATCH = 4, 6, 4


def tiny_trainer_inputs():
    rng = np.random.default_rng(0)
    clients = [ClientData(rng.normal(size=(12, 4, 4, 1)).astype(np.float32),
                          rng.integers(0, 3, size=12).astype(np.int32))
               for _ in range(N)]

    def apply_fn(p, x):
        return x.reshape(x.shape[0], -1) @ p["w"]

    params = {"w": jnp.asarray(rng.normal(size=(16, 3)).astype(np.float32))}
    return clients, params, make_loss_fn(apply_fn)


def run_backend_pair(fault_model=None, rounds=ROUNDS):
    """Both backends over the same tiny problem with the SAME fault model;
    packed pinned to one shard (the bit-for-bit contract)."""
    clients, params, loss_fn = tiny_trainer_inputs()
    sched = make_schedule(np.ones((rounds, N)), 0.3)
    sp = SystemParams.table1(N)
    ch = ChannelModel(N)
    out = {}
    for backend in ("reference", "packed"):
        kw = {"shards": 1} if backend == "packed" else {}
        tr = FederatedTrainer(loss_fn, params, clients, eta=0.1,
                              batch_size=BATCH, seed=0, backend=backend,
                              fault_model=fault_model, **kw)
        out[backend] = (tr, tr.run(sched, sp, ch.uplink, ch.downlink))
    return out


def fault_spec(*, backend="packed", shards=None, rpd=1,
               fault_model="none", fault_kwargs=None, **run_kw):
    return ExperimentSpec(
        data=DataSpec(dataset="synthetic-mnist", n_clients=N, sigma=5.0,
                      n_train=160, n_test=60, seed=0),
        model=ModelSpec(name="mlp-edge"),
        wireless=WirelessSpec(e0=1e6, t0=1e6, seed=0,
                              fault_model=fault_model,
                              fault_kwargs=fault_kwargs or {}),
        scheme=SchemeSpec(name="proposed", rounds=ROUNDS, eta=0.1,
                          batch=BATCH, ao={"outer_iters": 1}),
        run=RunSpec(seed=0, eval_every=3, backend=backend, shards=shards,
                    rounds_per_dispatch=rpd, **run_kw))


# ---------------------------------------------------------------------------
# Draw protocol units
# ---------------------------------------------------------------------------

def test_draw_determinism_and_population_invariance():
    m = ClientDropout(rate=0.5, seed=3)
    all_ids = np.arange(8)
    a = m.draw(5, 8, all_ids)
    assert np.array_equal(a.upload_ok, m.draw(5, 8, all_ids).upload_ok)
    # a client's fate is a function of (seed, round, id) — indexing the
    # population draw, NOT a function of which other clients are selected
    sub = m.draw(5, 8, np.array([2, 6]))
    assert np.array_equal(sub.upload_ok, a.upload_ok[[2, 6]])
    # round and seed both move the draw
    assert not np.array_equal(a.upload_ok, m.draw(6, 8, all_ids).upload_ok)
    assert not np.array_equal(
        a.upload_ok, ClientDropout(rate=0.5, seed=4).draw(5, 8, all_ids).upload_ok)
    # rate bounds: 0 never drops, 1 always drops
    assert ClientDropout(rate=0.0).draw(0, 8, all_ids).upload_ok.all()
    assert not ClientDropout(rate=1.0).draw(0, 8, all_ids).upload_ok.any()
    assert ClientDropout(rate=1.0).draw(0, 8, all_ids).n_faulted == 8
    with pytest.raises(ValueError, match="rate"):
        ClientDropout(rate=1.5)


def test_straggler_deadline_semantics():
    m = StragglerTimeout(tolerance=1.0, sigma=0.8, seed=1)
    sel = np.arange(6)
    # no wireless context -> nobody straggles
    assert m.draw(0, 6, sel).upload_ok.all()
    # uniform delays: deadline == each delay, so a client faults iff its
    # drawn slowdown exceeds the tolerance — scale-invariant in the delay
    d = np.full(6, 2.5)
    a = m.draw(0, 6, sel, delays=d, deadline=2.5)
    b = m.draw(0, 6, sel, delays=10 * d, deadline=25.0)
    assert np.array_equal(a.upload_ok, b.upload_ok)
    # a huge tolerance admits everyone; a tiny one excludes everyone
    wide = StragglerTimeout(tolerance=1e9, sigma=0.8, seed=1)
    assert wide.draw(0, 6, sel, delays=d, deadline=2.5).upload_ok.all()
    tight = StragglerTimeout(tolerance=1e-9, sigma=0.8, seed=1)
    assert not tight.draw(0, 6, sel, delays=d, deadline=2.5).upload_ok.any()
    with pytest.raises(ValueError, match="tolerance"):
        StragglerTimeout(tolerance=0.0)


def test_corrupt_draw_modes():
    sel = np.arange(16)
    nan = CorruptUpload(rate=0.5, mode="nan", seed=2).draw(1, 16, sel)
    assert nan.upload_ok.all()                    # uploads DO arrive
    assert np.isnan(nan.corrupt).any() and not np.isnan(nan.corrupt).all()
    assert nan.corrupt.dtype == np.float32
    sc = CorruptUpload(rate=0.5, mode="scale", scale=7.0, seed=2).draw(1, 16, sel)
    # same (seed, round, kind) stream: identical hit set, different payload
    assert np.array_equal(np.isnan(nan.corrupt), sc.corrupt == 7.0)
    assert ((sc.corrupt == 1.0) | (sc.corrupt == 7.0)).all()
    clean = CorruptUpload(rate=0.0).draw(1, 16, sel)
    assert (clean.corrupt == 1.0).all()
    with pytest.raises(ValueError, match="mode"):
        CorruptUpload(mode="wat")


def test_mixed_composes_independent_streams():
    sel = np.arange(12)
    mix = MixedFaults(dropout_rate=0.4, corrupt_rate=0.4, seed=9)
    d = mix.draw(3, 12, sel)
    # each kind reproduces its standalone model's draw at the same key
    assert np.array_equal(
        d.upload_ok, ClientDropout(0.4, seed=9).draw(3, 12, sel).upload_ok)
    assert np.array_equal(
        np.isnan(d.corrupt),
        np.isnan(CorruptUpload(0.4, seed=9).draw(3, 12, sel).corrupt))
    # inactive knobs contribute nothing
    off = MixedFaults(seed=9).draw(3, 12, sel)
    assert off.upload_ok.all() and off.corrupt is None


def test_registry_factories_and_spec_roundtrip():
    assert FAULT_MODELS.get("none")(WirelessSpec()) is None
    w = WirelessSpec(seed=9, fault_model="dropout",
                     fault_kwargs={"rate": 0.2})
    fm = FAULT_MODELS.get(w.fault_model)(w)
    assert isinstance(fm, ClientDropout)
    assert fm.rate == 0.2 and fm.seed == 9        # seed defaults from spec
    w2 = WirelessSpec(fault_model="corrupt",
                      fault_kwargs={"rate": 0.1, "seed": 3})
    assert FAULT_MODELS.get(w2.fault_model)(w2).seed == 3
    assert isinstance(
        FAULT_MODELS.get("straggler")(WirelessSpec(
            fault_model="straggler")), StragglerTimeout)
    assert isinstance(
        FAULT_MODELS.get("mixed")(WirelessSpec(fault_model="mixed")),
        MixedFaults)
    with pytest.raises(KeyError, match="fault model"):
        FAULT_MODELS.get("wat")
    spec = fault_spec(fault_model="mixed",
                      fault_kwargs={"dropout_rate": 0.1, "seed": 4})
    assert ExperimentSpec.from_json(spec.to_json()) == spec


# ---------------------------------------------------------------------------
# Differential: packed vs reference, bitwise (single-device contract)
# ---------------------------------------------------------------------------

FAULT_MODELS_UNDER_TEST = [
    ClientDropout(rate=0.3, seed=5),
    StragglerTimeout(tolerance=1.0, sigma=0.8, seed=5),
    CorruptUpload(rate=0.4, mode="scale", scale=10.0, seed=5),
    CorruptUpload(rate=0.4, mode="nan", seed=5),
    MixedFaults(dropout_rate=0.25, corrupt_rate=0.25, seed=5),
]


@pytest.mark.parametrize(
    "fm", FAULT_MODELS_UNDER_TEST,
    ids=["dropout", "straggler", "corrupt_scale", "corrupt_nan", "mixed"])
def test_fault_packed_vs_reference_bitwise(fm):
    out = run_backend_pair(fault_model=fm)
    (tr_ref, hist_ref), (tr_pk, hist_pk) = out["reference"], out["packed"]
    # NaN-tolerant equality: an all-dropped round's train_loss is nan on
    # both sides
    np.testing.assert_array_equal(
        np.asarray([m.train_loss for m in hist_ref]),
        np.asarray([m.train_loss for m in hist_pk]))
    assert [(m.n_faulted, m.n_quarantined) for m in hist_ref] == \
        [(m.n_faulted, m.n_quarantined) for m in hist_pk]
    assert tr_ref.fault_counters == tr_pk.fault_counters
    assert_trainers_bitwise(tr_ref, tr_pk)
    # the model actually bit (seeds chosen so): finite scale-corruption
    # reaches the aggregate without tripping any counter, so for it we
    # check trajectory divergence from the clean run instead
    if isinstance(fm, CorruptUpload) and fm.mode == "scale":
        clean = run_backend_pair(fault_model=None)
        assert [m.train_loss for m in hist_pk] != \
            [m.train_loss for m in clean["packed"][1]]
    else:
        assert sum(tr_pk.fault_counters.values()) > 0
    # and the params stayed finite through it
    assert all(bool(jnp.isfinite(p).all())
               for p in jax.tree_util.tree_leaves(tr_pk.params))


def test_fault_rate_zero_is_bitwise_noop():
    clean = run_backend_pair(fault_model=None)
    zero = run_backend_pair(fault_model=ClientDropout(rate=0.0, seed=5))
    assert [m.train_loss for m in clean["packed"][1]] == \
        [m.train_loss for m in zero["packed"][1]]
    assert_trainers_bitwise(clean["packed"][0], zero["packed"][0])
    assert zero["packed"][0].fault_counters == \
        {"n_dropped": 0, "n_quarantined": 0, "n_skipped_rounds": 0,
         "n_corrupt_finite": 0}
    # ... and an active model is genuinely a different trajectory
    faulted = run_backend_pair(fault_model=ClientDropout(rate=0.3, seed=5))
    assert [m.train_loss for m in clean["packed"][1]] != \
        [m.train_loss for m in faulted["packed"][1]]


# ---------------------------------------------------------------------------
# Degradation semantics
# ---------------------------------------------------------------------------

def test_all_dropped_round_skips_update_bitwise():
    """rate=1.0: every round loses every client — the engine must skip the
    update (params and global grad bitwise unchanged) instead of dividing
    by zero survivors, and every round counts as skipped."""
    clients, params, loss_fn = tiny_trainer_inputs()
    sched = make_schedule(np.ones((ROUNDS, N)), 0.3)
    sp = SystemParams.table1(N)
    ch = ChannelModel(N)
    for backend, kw in (("reference", {}), ("packed", {"shards": 1})):
        tr = FederatedTrainer(loss_fn, params, clients, eta=0.1,
                              batch_size=BATCH, seed=0, backend=backend,
                              fault_model=ClientDropout(rate=1.0), **kw)
        before = [np.asarray(p).copy()
                  for p in jax.tree_util.tree_leaves(tr.params)]
        hist = tr.run(sched, sp, ch.uplink, ch.downlink)
        for a, b in zip(before, jax.tree_util.tree_leaves(tr.params)):
            assert np.array_equal(a, np.asarray(b)), backend
        assert tr.fault_counters["n_skipped_rounds"] == ROUNDS
        assert tr.fault_counters["n_dropped"] == ROUNDS * N
        assert all(np.isnan(m.train_loss) for m in hist)
        assert all(m.n_faulted == N for m in hist)


def test_nan_uploads_quarantined_and_counted():
    """mode="nan" at a rate that never wipes a whole round: the guard
    drops exactly the poisoned uploads, the trajectory stays finite, and
    the per-round quarantine counts match the draw."""
    fm = CorruptUpload(rate=0.3, mode="nan", seed=11)
    out = run_backend_pair(fault_model=fm)
    tr, hist = out["packed"]
    expected = [int(np.isnan(fm.draw(s, N, np.arange(N)).corrupt).sum())
                for s in range(ROUNDS)]
    assert sum(expected) > 0                      # the seed really poisons
    assert [m.n_quarantined for m in hist] == expected
    assert tr.fault_counters["n_quarantined"] == sum(expected)
    assert all(np.isfinite(m.train_loss) for m in hist)
    assert all(bool(jnp.isfinite(p).all())
               for p in jax.tree_util.tree_leaves(tr.params))
    assert_trainers_bitwise(out["reference"][0], tr)


# ---------------------------------------------------------------------------
# API path: block dispatch, counters in RunResult, resume, sweep axis
# ---------------------------------------------------------------------------

def test_fault_block_dispatch_bitwise():
    """rpd=1 vs rpd=4 under the DEFAULT shard count with the chaos model
    active — the fault masks ride the stacked block operands bitwise."""
    kwargs = {"dropout_rate": 0.25, "corrupt_rate": 0.25, "seed": 7}
    results = {}
    for rpd in (1, 4):
        spec = fault_spec(rpd=rpd, fault_model="mixed", fault_kwargs=kwargs)
        run = Experiment(spec).build()
        results[rpd] = (run, run.run())
    (run1, res1), (run4, res4) = results[1], results[4]
    assert run4.trainer.n_block_dispatches > 0
    np.testing.assert_array_equal(
        np.asarray([m.train_loss for m in res1.history]),
        np.asarray([m.train_loss for m in res4.history]))
    assert res1.summary["faults"] == res4.summary["faults"]
    for a, b in zip(jax.tree_util.tree_leaves(run1.trainer.params),
                    jax.tree_util.tree_leaves(run4.trainer.params)):
        assert bool(jnp.all(a == b))


def test_report_renders_fault_column(tmp_path):
    report = pytest.importorskip("benchmarks.report")
    res = Experiment(fault_spec(fault_model="dropout",
                                fault_kwargs={"rate": 0.4})).run()
    p = res.to_jsonl(str(tmp_path / "run.jsonl"))
    table = report.runs_table([p])
    assert "faults (drop/quar/skip)" in table
    f = res.summary["faults"]
    assert (f"{f['n_dropped']}/{f['n_quarantined']}"
            f"/{f['n_skipped_rounds']}") in table
    clean = Experiment(fault_spec()).run()
    p2 = clean.to_jsonl(str(tmp_path / "clean.jsonl"))
    assert "| — |" in report.runs_table([p2])


def test_counters_surface_in_summary():
    res = Experiment(fault_spec(fault_model="dropout",
                                fault_kwargs={"rate": 0.4})).run()
    f = res.summary["faults"]
    assert set(f) == {"n_dropped", "n_quarantined", "n_skipped_rounds",
                      "n_corrupt_finite"}
    assert f["n_dropped"] == sum(m.n_faulted for m in res.history) > 0
    # a clean run keeps the summary exactly as before the fault layer
    assert "faults" not in Experiment(fault_spec()).run().summary


@pytest.mark.parametrize("rpd", [1, 4])
def test_fault_resume_bitwise_with_counters(tmp_path, rpd):
    """Checkpoint/resume mid-chaos: the resumed trajectory AND the fault
    counters match the uninterrupted run's (draws are round-keyed, and the
    checkpoint carries the counter totals)."""
    kwargs = {"dropout_rate": 0.3, "corrupt_rate": 0.3, "seed": 7}
    base = fault_spec(rpd=rpd, fault_model="mixed", fault_kwargs=kwargs)
    res_a = Experiment(base).run()

    ckpt = str(tmp_path / f"ckpt_rpd{rpd}")
    spec = dataclasses.replace(
        base, run=dataclasses.replace(base.run, checkpoint_dir=ckpt,
                                      checkpoint_every=3))
    Experiment(spec).run()                        # writes checkpoints
    run_b = Experiment(spec).build()
    res_b = run_b.resume(ckpt, step=3)
    assert res_b.summary["resumed_from"] == 3
    np.testing.assert_array_equal(
        np.asarray([m.train_loss for m in res_a.history]),
        np.asarray([m.train_loss for m in res_b.history]))
    assert [(m.n_faulted, m.n_quarantined) for m in res_a.history] == \
        [(m.n_faulted, m.n_quarantined) for m in res_b.history]
    assert res_b.summary["faults"] == res_a.summary["faults"]


def test_fault_kwargs_sweepable():
    # dotted descent INTO the kwargs dict (a dict leaf, not a dataclass)
    spec = fault_spec(fault_model="dropout", fault_kwargs={"rate": 0.1})
    s2 = override_field(spec, "wireless.fault_kwargs.rate", 0.5)
    assert s2.wireless.fault_kwargs == {"rate": 0.5}
    assert spec.wireless.fault_kwargs == {"rate": 0.1}      # no aliasing
    s3 = override_field(spec, "wireless.fault_kwargs.seed", 9)  # new key ok
    assert s3.wireless.fault_kwargs == {"rate": 0.1, "seed": 9}
    # and the axis composes with run_sweep: same env, different trajectory
    sw = SweepSpec(base=fault_spec(),
                   grid={"wireless.fault_model": ["none", "dropout"],
                         "wireless.fault_kwargs.rate": [0.4]})
    res = run_sweep(sw)
    assert res.n_env_builds == 1                 # faults are trainer-level
    a, b = res.results
    assert [m.train_loss for m in a.history] != \
        [m.train_loss for m in b.history]
    assert "faults" not in a.summary and b.summary["faults"]["n_dropped"] > 0
