"""FedSGD engine integration: selection + pruning + masked aggregation
actually learn on a synthetic non-IID task."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import (
    BoundConstants, ClientData, FederatedTrainer, phis, solve_p1, AOConfig,
)
from repro.core.optimizer_ao import Schedule
from repro.data import make_dataset, partition_by_dirichlet
from repro.models import lenet_init, lenet_apply, make_loss_fn, make_eval_fn
from repro.wireless import ChannelModel, SystemParams

N = 4


@pytest.fixture(scope="module")
def setup():
    ds = make_dataset("synthetic-mnist", n_train=1200, n_test=300, seed=0)
    parts = partition_by_dirichlet(ds.y_train, N, sigma=1.0,
                                   rng=np.random.default_rng(0))
    clients = [ClientData(ds.x_train[idx], ds.y_train[idx]) for idx in parts]
    test_hist = np.bincount(ds.y_test, minlength=10).astype(float)
    phi = phis(np.stack([c.label_histogram(10) for c in clients]),
               test_hist[None])
    return ds, clients, phi


def _all_on_schedule(n_rounds, lam=0.0):
    a = np.ones((n_rounds, N))
    return Schedule(a=a, lam=lam * a, power=0.3 * a, freq=3e8 * a,
                    theta=0.0, energy=0.0, delay=0.0, feasible=True)


def test_fedsgd_learns(setup):
    ds, clients, _ = setup
    params = lenet_init(jax.random.key(0))
    loss_fn = make_loss_fn(lenet_apply)
    eval_fn = make_eval_fn(lenet_apply, ds.x_test, ds.y_test)
    tr = FederatedTrainer(loss_fn, params, clients, eta=0.1, batch_size=64)
    sp = SystemParams.table1(N)
    ch = ChannelModel(N)
    hist = tr.run(_all_on_schedule(150), sp, ch.uplink, ch.downlink,
                  eval_fn=eval_fn, eval_every=149)
    first = [m for m in hist if m.test_accuracy is not None][0]
    last = [m for m in hist if m.test_accuracy is not None][-1]
    assert last.test_accuracy > max(0.4, first.test_accuracy)
    assert hist[-1].train_loss < hist[0].train_loss


def test_pruned_training_still_learns_and_uploads_less(setup):
    ds, clients, _ = setup
    params = lenet_init(jax.random.key(0))
    loss_fn = make_loss_fn(lenet_apply)
    tr = FederatedTrainer(loss_fn, params, clients, eta=0.05, batch_size=32)
    sp = SystemParams.table1(N)
    ch = ChannelModel(N)
    hist = tr.run(_all_on_schedule(25, lam=0.4), sp, ch.uplink, ch.downlink)
    assert hist[-1].train_loss < hist[0].train_loss
    assert hist[-1].mean_lambda == pytest.approx(0.4)
    # pruning must cut per-round energy/delay vs unpruned
    tr2 = FederatedTrainer(loss_fn, lenet_init(jax.random.key(0)), clients,
                           eta=0.05, batch_size=32)
    hist0 = tr2.run(_all_on_schedule(2, lam=0.0), sp, ch.uplink, ch.downlink)
    assert hist[0].energy < hist0[0].energy
    assert hist[0].delay < hist0[0].delay


def test_masked_gradients_zero_on_pruned_coords(setup):
    _, clients, _ = setup
    params = lenet_init(jax.random.key(0))
    loss_fn = make_loss_fn(lenet_apply)
    tr = FederatedTrainer(loss_fn, params, clients, eta=0.05, batch_size=16)
    # warm up global gradient so eq.-(4) importance is nonzero
    g, _, _ = tr.client_update(0, 0.0)
    tr.server_step([g])
    grads, masks, _ = tr.client_update(0, 0.5)
    for gm, mm in zip(jax.tree.leaves(grads), jax.tree.leaves(masks)):
        assert float(jnp.abs(np.asarray(gm)[np.asarray(mm) == 0]).sum()
                     if (np.asarray(mm) == 0).any() else 0.0) == 0.0


def test_end_to_end_with_ao_schedule(setup):
    """Full pipeline: phi -> Algorithm 1 -> schedule -> training run."""
    ds, clients, phi = setup
    sp = SystemParams.table1(N)
    ch = ChannelModel(N)
    c = BoundConstants(rounds_S=9, batch_Z=32, eta=0.05)
    from repro.core.resource import min_client_delay
    t0 = 10 * 3.0 * max(min_client_delay(i, 0.0, ch.uplink, ch.downlink, sp)
                        for i in range(N))
    sched = solve_p1(phi, 50.0, t0, ch.uplink, ch.downlink, sp, c,
                     AOConfig(outer_iters=2))
    assert sched.feasible
    params = lenet_init(jax.random.key(0))
    tr = FederatedTrainer(make_loss_fn(lenet_apply), params, clients,
                          eta=0.05, batch_size=32)
    hist = tr.run(sched, sp, ch.uplink, ch.downlink,
                  stop_delay=t0, stop_energy=50.0)
    assert len(hist) >= 1
    assert hist[-1].cumulative_energy <= 50.0 * 1.5
    assert all(len(m.selected) >= 1 for m in hist)
