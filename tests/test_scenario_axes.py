"""Scenario axes: per-client data selection (Albaseer-style, SchemeSpec.
data_selection) and the noisy aggregation channel (Wu-style, WirelessSpec.
noise_model).

Differential coverage (the PR-5 satellite): runs with either axis active
are bitwise-equal between backend="packed" and backend="reference" (shards
pinned to 1 — the single-device bit-for-bit contract), and between
rounds_per_dispatch=1 and =4 block dispatch under the DEFAULT shard count
(so the same tests exercise the mesh path bitwise-vs-itself in the forced
4-device CI leg). Plus unit coverage for the policy filters, the noise
model's round-keyed determinism, and spec round-tripping of the new
fields.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    CHANNEL_NOISE, DATA_SELECTION, DataSpec, Experiment, ExperimentSpec,
    ModelSpec, RunSpec, SchemeSpec, WirelessSpec,
)
from repro.core import ClientData, FederatedTrainer
from repro.core.selection import (
    data_selection_keep_mask, data_selection_scores,
)
from repro.models import make_loss_fn
from repro.wireless import ChannelModel, SystemParams
from repro.wireless.channel import GaussianAggregateNoise

from _trainer_pair import assert_trainers_bitwise, make_schedule

N, ROUNDS, BATCH = 5, 6, 8


def axes_spec(*, backend="packed", shards=None, rpd=1,
              selection="none", selection_kwargs=None,
              noise_model="none", noise_kwargs=None) -> ExperimentSpec:
    return ExperimentSpec(
        data=DataSpec(dataset="synthetic-mnist", n_clients=N, sigma=5.0,
                      n_train=200, n_test=60, seed=0),
        model=ModelSpec(name="mlp-edge"),
        wireless=WirelessSpec(e0=1e6, t0=1e6, seed=0,
                              noise_model=noise_model,
                              noise_kwargs=noise_kwargs or {}),
        scheme=SchemeSpec(name="proposed", rounds=ROUNDS, eta=0.1,
                          batch=BATCH, ao={"outer_iters": 1},
                          data_selection=selection,
                          data_selection_kwargs=selection_kwargs or {}),
        run=RunSpec(seed=0, eval_every=3, backend=backend, shards=shards,
                    rounds_per_dispatch=rpd))


def tiny_trainer_inputs():
    rng = np.random.default_rng(0)
    clients = [ClientData(rng.normal(size=(12, 4, 4, 1)).astype(np.float32),
                          rng.integers(0, 3, size=12).astype(np.int32))
               for _ in range(4)]

    def apply_fn(p, x):
        return x.reshape(x.shape[0], -1) @ p["w"]

    params = {"w": jnp.asarray(rng.normal(size=(16, 3)).astype(np.float32))}
    return clients, params, make_loss_fn(apply_fn)


# ---------------------------------------------------------------------------
# Data-selection policy units
# ---------------------------------------------------------------------------

def test_data_selection_scores_deterministic_and_classwise():
    rng = np.random.default_rng(3)
    x = rng.normal(size=(20, 4)).astype(np.float32)
    y = rng.integers(0, 3, size=20)
    s1, s2 = data_selection_scores(x, y), data_selection_scores(x, y)
    assert np.array_equal(s1, s2)
    assert (s1 >= 0).all()
    # a single-sample class sits exactly on its own centroid
    x1 = np.vstack([x, np.ones((1, 4), np.float32)])
    y1 = np.concatenate([y, [7]])
    assert data_selection_scores(x1, y1)[-1] == 0.0


def test_keep_mask_fine_grained_fraction_and_order():
    rng = np.random.default_rng(4)
    x = rng.normal(size=(10, 3))
    y = np.zeros(10, int)
    keep = data_selection_keep_mask(x, y, policy="fine_grained",
                                    keep_frac=0.5)
    assert keep.sum() == 5
    scores = data_selection_scores(x, y)
    assert scores[keep].max() <= scores[~keep].min()     # most typical kept
    # keep_frac=1.0 keeps everything; tiny fractions keep at least one
    assert data_selection_keep_mask(x, y, policy="fine_grained",
                                    keep_frac=1.0).all()
    assert data_selection_keep_mask(x, y, policy="fine_grained",
                                    keep_frac=1e-9).sum() == 1


def test_keep_mask_threshold_and_errors():
    rng = np.random.default_rng(5)
    x = rng.normal(size=(16, 3))
    y = np.zeros(16, int)
    keep = data_selection_keep_mask(x, y, policy="threshold", tau=1.0)
    scores = data_selection_scores(x, y)
    assert np.array_equal(keep, scores <= scores.mean())
    assert 1 <= keep.sum() < 16
    # an enormous tau excludes nothing
    assert data_selection_keep_mask(x, y, policy="threshold",
                                    tau=1e9).all()
    with pytest.raises(ValueError, match="unknown data-selection"):
        data_selection_keep_mask(x, y, policy="wat")
    with pytest.raises(ValueError, match="tau"):
        data_selection_keep_mask(x, y, policy="threshold", tau=0.0)
    with pytest.raises(ValueError, match="keep_frac"):
        data_selection_keep_mask(x, y, policy="fine_grained", keep_frac=0.0)


def test_data_selection_registry_filters_clients():
    assert DATA_SELECTION.get("none")(SchemeSpec()) is None
    sc = SchemeSpec(data_selection="fine_grained",
                    data_selection_kwargs={"keep_frac": 0.5})
    apply = DATA_SELECTION.get(sc.data_selection)(sc)
    rng = np.random.default_rng(0)
    clients = [ClientData(rng.normal(size=(10, 2, 2, 1)).astype(np.float32),
                          rng.integers(0, 2, size=10).astype(np.int32))]
    out = apply(clients)
    assert len(out) == 1 and 1 <= len(out[0]) < 10
    with pytest.raises(KeyError, match="data-selection"):
        DATA_SELECTION.get("wat")


# ---------------------------------------------------------------------------
# Channel-noise units
# ---------------------------------------------------------------------------

def test_gaussian_noise_round_keyed_determinism():
    nz = GaussianAggregateNoise(std=0.1, seed=3)
    a = nz.sample_packed(5, (4, 128))
    assert np.array_equal(a, nz.sample_packed(5, (4, 128)))   # same round
    assert not np.array_equal(a, nz.sample_packed(6, (4, 128)))
    assert not np.array_equal(
        a, GaussianAggregateNoise(std=0.1, seed=4).sample_packed(5, (4, 128)))
    assert a.dtype == np.float32
    # valid mask zeroes padding lanes
    valid = np.zeros((4, 128), np.float32)
    valid[:2] = 1.0
    masked = nz.sample_packed(5, (4, 128), valid)
    assert (masked[2:] == 0).all() and (masked[:2] == a[:2]).all()
    # std scales linearly over the same underlying draw
    b = GaussianAggregateNoise(std=0.2, seed=3).sample_packed(5, (4, 128))
    np.testing.assert_allclose(b, 2.0 * a, rtol=1e-6)


def test_channel_noise_registry_and_spec_roundtrip():
    assert CHANNEL_NOISE.get("none")(WirelessSpec()) is None
    w = WirelessSpec(seed=9, noise_model="gaussian",
                     noise_kwargs={"std": 0.01})
    nz = CHANNEL_NOISE.get(w.noise_model)(w)
    assert nz.std == 0.01 and nz.seed == 9          # seed defaults from spec
    w2 = WirelessSpec(noise_model="gaussian",
                      noise_kwargs={"std": 0.01, "seed": 3})
    assert CHANNEL_NOISE.get(w2.noise_model)(w2).seed == 3
    spec = axes_spec(noise_model="gaussian", noise_kwargs={"std": 0.01},
                     selection="threshold", selection_kwargs={"tau": 2.0})
    assert ExperimentSpec.from_json(spec.to_json()) == spec


# ---------------------------------------------------------------------------
# Differential: packed vs reference, bitwise (single-device contract)
# ---------------------------------------------------------------------------

def run_backend_pair(channel_noise=None):
    """Both backends over the same tiny problem; packed pinned to one
    shard (the bit-for-bit contract is single-device)."""
    clients, params, loss_fn = tiny_trainer_inputs()
    sched = make_schedule(np.ones((ROUNDS, 4)), 0.3)
    sp = SystemParams.table1(4)
    ch = ChannelModel(4)
    out = {}
    for backend in ("reference", "packed"):
        kw = {"shards": 1} if backend == "packed" else {}
        tr = FederatedTrainer(loss_fn, params, clients, eta=0.1,
                              batch_size=4, seed=0, backend=backend,
                              channel_noise=channel_noise, **kw)
        out[backend] = (tr, tr.run(sched, sp, ch.uplink, ch.downlink))
    return out


def test_noise_packed_vs_reference_bitwise():
    noise = GaussianAggregateNoise(std=1e-2, seed=7)
    out = run_backend_pair(channel_noise=noise)
    (tr_ref, hist_ref), (tr_pk, hist_pk) = out["reference"], out["packed"]
    assert [m.train_loss for m in hist_ref] == \
        [m.train_loss for m in hist_pk]
    assert_trainers_bitwise(tr_ref, tr_pk)
    # and the noise really is a different trajectory than the clean channel
    clean = run_backend_pair(channel_noise=None)
    assert [m.train_loss for m in clean["packed"][1]] != \
        [m.train_loss for m in hist_pk]


def test_selection_policy_packed_vs_reference_bitwise_api():
    """Full API path: identical specs except run.backend, with a data-
    selection policy active (filtered shards go ragged through the padded
    weighted-loss path on both backends)."""
    results = {}
    for backend in ("reference", "packed"):
        spec = axes_spec(backend=backend, shards=1, selection="fine_grained",
                         selection_kwargs={"keep_frac": 0.6})
        run = Experiment(spec).build()
        results[backend] = (run, run.run())
    (run_r, res_r), (run_p, res_p) = results["reference"], results["packed"]
    # the policy actually filtered: every client lost samples vs the env
    assert all(len(c) < len(e) for c, e in
               zip(run_p.trainer.clients, run_p.env.clients))
    assert [m.train_loss for m in res_r.history] == \
        [m.train_loss for m in res_p.history]
    assert [m.test_accuracy for m in res_r.history] == \
        [m.test_accuracy for m in res_p.history]
    assert_trainers_bitwise(run_r.trainer, run_p.trainer)


def test_noise_packed_vs_reference_bitwise_api():
    results = {}
    for backend in ("reference", "packed"):
        spec = axes_spec(backend=backend, shards=1, noise_model="gaussian",
                         noise_kwargs={"std": 1e-3})
        results[backend] = Experiment(spec).build().run()
    assert [m.train_loss for m in results["reference"].history] == \
        [m.train_loss for m in results["packed"].history]
    assert [m.test_loss for m in results["reference"].history] == \
        [m.test_loss for m in results["packed"].history]


# ---------------------------------------------------------------------------
# Differential: rpd=1 vs rpd=4 block dispatch (default shards — the forced
# 4-device CI leg runs this file on the mesh, where both sides shard)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("axis_kw", [
    {"selection": "fine_grained", "selection_kwargs": {"keep_frac": 0.6}},
    {"noise_model": "gaussian", "noise_kwargs": {"std": 1e-3}},
])
def test_axes_block_dispatch_bitwise(axis_kw):
    results = {}
    for rpd in (1, 4):
        spec = axes_spec(rpd=rpd, **axis_kw)
        run = Experiment(spec).build()
        results[rpd] = (run, run.run())
    (run1, res1), (run4, res4) = results[1], results[4]
    assert run4.trainer.n_block_dispatches > 0       # blocks actually ran
    assert [m.train_loss for m in res1.history] == \
        [m.train_loss for m in res4.history]
    assert [m.test_accuracy for m in res1.history] == \
        [m.test_accuracy for m in res4.history]
    for a, b in zip(jax.tree_util.tree_leaves(run1.trainer.params),
                    jax.tree_util.tree_leaves(run4.trainer.params)):
        assert bool(jnp.all(a == b))


@pytest.mark.slow
def test_combined_axes_packed_vs_reference_bitwise_lenet():
    """Slow-tier (scripts/test.sh --all): both axes ACTIVE AT ONCE on the
    conv model — selection-filtered ragged clients AND a noisy channel,
    packed vs reference, bitwise."""
    results = {}
    for backend in ("reference", "packed"):
        spec = axes_spec(backend=backend, shards=1,
                         selection="threshold", selection_kwargs={"tau": 1.2},
                         noise_model="gaussian", noise_kwargs={"std": 1e-3})
        spec = dataclasses.replace(spec, model=ModelSpec(name="lenet"))
        run = Experiment(spec).build()
        results[backend] = (run, run.run())
    (run_r, res_r), (run_p, res_p) = results["reference"], results["packed"]
    assert [m.train_loss for m in res_r.history] == \
        [m.train_loss for m in res_p.history]
    assert_trainers_bitwise(run_r.trainer, run_p.trainer)


def test_noise_composes_with_sweep_axes():
    """noise_std is sweepable like any other field path, and the noise
    axis changes the trajectory while sharing one environment."""
    from repro.api import SweepSpec, run_sweep
    sw = SweepSpec(base=axes_spec(),
                   grid={"wireless.noise_model": ["none", "gaussian"]})
    res = run_sweep(sw)
    assert res.n_env_builds == 1                 # noise is trainer-level
    a, b = res.results
    assert [m.train_loss for m in a.history] != \
        [m.train_loss for m in b.history]
