"""Shared packed-vs-reference trainer harness for the round-engine suites.

One copy of the run-both-backends-and-compare-bitwise plumbing, imported by
tests/test_packing.py and tests/test_round_engine.py (the tests/ directory
is on sys.path via conftest.py).
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.core import FederatedTrainer
from repro.core.optimizer_ao import Schedule
from repro.wireless import ChannelModel, SystemParams


def make_schedule(a, lam):
    """All-wireless-defaults Schedule from a selection matrix [S, N] and a
    scalar / per-client / per-round-per-client lambda."""
    a = np.asarray(a, float)
    lam = np.broadcast_to(np.asarray(lam, float), a.shape).copy()
    lam[a == 0] = 0.0
    return Schedule(a=a, lam=lam, power=0.3 * np.ones_like(a),
                    freq=3e8 * np.ones_like(a), theta=0.0, energy=0.0,
                    delay=0.0, feasible=True)


def run_pair(clients, params, loss_fn, sched, *, batch_size=16, both_kw=None,
             **packed_kw):
    """Run the same schedule on both backends from the same init; returns
    {backend: (trainer, history)}. packed_kw reaches only the packed
    trainer (e.g. shards=1 to pin the bit-for-bit single-device path);
    both_kw reaches both (e.g. local_scheme, which each backend must
    honor for the comparison to make sense)."""
    out = {}
    n = len(clients)
    for backend in ("reference", "packed"):
        kw = dict(both_kw or {})
        if backend == "packed":
            kw.update(packed_kw)
        tr = FederatedTrainer(loss_fn, params, clients, eta=0.1,
                              batch_size=batch_size, seed=0, backend=backend,
                              **kw)
        sp = SystemParams.table1(n)
        ch = ChannelModel(n)
        out[backend] = (tr, tr.run(sched, sp, ch.uplink, ch.downlink))
    return out


def assert_trainers_bitwise(tr_ref, tr_pk):
    for a, b in zip(jax.tree_util.tree_leaves(tr_ref.params),
                    jax.tree_util.tree_leaves(tr_pk.params)):
        assert bool(jnp.all(a == b))
    for a, b in zip(jax.tree_util.tree_leaves(tr_ref.global_grad),
                    jax.tree_util.tree_leaves(tr_pk.global_grad)):
        assert bool(jnp.all(a == b))
