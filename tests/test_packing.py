"""ParamPack: exact round-trips, prunable layout, and packed-vs-reference
bit-for-bit parity of the round engine on a small LeNet."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _trainer_pair import (assert_trainers_bitwise, make_schedule,
                           run_pair)
from repro.core import ClientData, FederatedTrainer, ParamPack, pruning
from repro.core.round_engine import kth_smallest_threshold
from repro.data import make_dataset, partition_by_dirichlet
from repro.models import lenet_init, lenet_apply, make_loss_fn
from repro.wireless import ChannelModel, SystemParams


def _mixed_tree(seed=0):
    rng = np.random.default_rng(seed)
    return {
        "embed_table": jnp.asarray(rng.normal(size=(7, 5)), jnp.float32),
        "w_attn": jnp.asarray(rng.normal(size=(3, 5, 7)), jnp.bfloat16),
        "bias": jnp.asarray(rng.normal(size=(11,)), jnp.float16),
        "counts": jnp.asarray(rng.integers(-50, 50, size=(4,)), jnp.int32),
        "scalar_scale": jnp.asarray(1.5, jnp.float32),
        "blocks": [
            {"w": jnp.asarray(rng.normal(size=(13,)), jnp.float32)},
            {"w": jnp.asarray(rng.normal(size=(1, 1)), jnp.float32)},
        ],
    }


def test_pack_unpack_round_trip_exact_mixed_dtypes():
    tree = _mixed_tree()
    pack = ParamPack.build(tree)
    buf = pack.pack(tree)
    assert buf.shape == (pack.rows, 128)
    assert buf.dtype == jnp.float32
    assert pack.rows % 256 == 0           # padded to the kernel row block
    out = pack.unpack(buf)
    flat_in, td_in = jax.tree_util.tree_flatten(tree)
    flat_out, td_out = jax.tree_util.tree_flatten(out)
    assert td_in == td_out
    for a, b in zip(flat_in, flat_out):
        assert a.shape == b.shape and a.dtype == b.dtype
        assert bool(jnp.all(a == b))


def test_pack_pads_with_zeros_and_tracks_sizes():
    tree = {"w": jnp.ones((3, 3), jnp.float32)}
    pack = ParamPack.build(tree)
    assert pack.n_total == 9
    buf = np.asarray(pack.pack(tree))
    assert buf.ravel()[:9].tolist() == [1.0] * 9
    assert float(np.abs(buf.ravel()[9:]).sum()) == 0.0


def test_prunable_mask_matches_prune_spec():
    tree = _mixed_tree()
    pack = ParamPack.build(tree)           # default PruneSpec
    pm = np.asarray(pack.prunable_mask()).ravel()
    for path, off, size, prunable in zip(pack.paths, pack.offsets,
                                         pack.sizes, pack.prunable_leaf):
        expect = pruning.default_prunable(path)
        assert prunable == expect, path
        assert (pm[off:off + size] == (1.0 if expect else 0.0)).all(), path
    # padding coordinates are never prunable
    assert (pm[pack.n_total:] == 0.0).all()
    assert pack.n_prunable == int(pm.sum())
    # embed/bias/scale protected; attention weights and plain 'w' prunable
    by_path = dict(zip(pack.paths, pack.prunable_leaf))
    assert not by_path["['embed_table']"]
    assert not by_path["['bias']"]
    assert by_path["['w_attn']"]


def test_pack_is_differentiable():
    tree = {"a": jnp.arange(4.0), "b": jnp.ones((2, 2))}
    pack = ParamPack.build(tree)

    def f(t):
        return jnp.sum(pack.pack(t) ** 2)

    g = jax.grad(f)(tree)
    np.testing.assert_allclose(np.asarray(g["a"]), 2 * np.arange(4.0))
    np.testing.assert_allclose(np.asarray(g["b"]), 2 * np.ones((2, 2)))


@pytest.mark.parametrize("coarse", ["bisect", "histogram"])
@pytest.mark.parametrize("scale", [1.0, 10.0, 1e6])
@pytest.mark.parametrize("lam", [0.0, 0.1, 0.37, 0.9])
def test_device_threshold_matches_host_global_threshold(lam, scale, coarse):
    """`scale` > 2 guards the bit-pattern binary search against int32
    midpoint overflow (bit patterns >= 2^30 for values >= 2.0); both the
    31-pass bisection and the 24-pass exponent-histogram variant must be
    exact."""
    rng = np.random.default_rng(3)
    imp = {"w1": jnp.asarray(scale * rng.random((33, 7)), jnp.float32),
           "norm_scale": jnp.asarray(rng.random((16,)), jnp.float32),
           "w2": jnp.asarray(scale * rng.random((257,)), jnp.float32)}
    thr_host = pruning.global_threshold(imp, lam)
    pack = ParamPack.build(imp)
    q = pack.pack(imp)
    k = int(np.floor(lam * pack.n_prunable))
    thr_dev = kth_smallest_threshold(
        q, jnp.asarray(pack.prunable_mask()), jnp.asarray(k, jnp.int32),
        coarse=coarse)
    if thr_host == -np.inf:
        assert float(thr_dev) == -np.inf
    else:
        assert np.float32(thr_host) == np.float32(thr_dev)


@pytest.mark.parametrize("coarse", ["bisect", "histogram"])
@pytest.mark.parametrize("scale", [1e-38, 1e-18, 1e18, 1e30])
def test_device_threshold_extreme_exponents(scale, coarse):
    """Both search modes must stay exact across the whole fp32 exponent
    range (subnormal-adjacent through near-overflow), ties included."""
    rng = np.random.default_rng(11)
    vals = (scale * rng.random((1025,))).astype(np.float32)
    vals[::7] = 0.0                              # ties at the bottom bin
    imp = {"w": jnp.asarray(vals)}
    pack = ParamPack.build(imp)
    q = pack.pack(imp)
    for lam in (0.1, 0.37, 0.9):
        thr_host = pruning.global_threshold(imp, lam)
        k = int(np.floor(lam * pack.n_prunable))
        thr_dev = kth_smallest_threshold(
            q, jnp.asarray(pack.prunable_mask()), jnp.asarray(k, jnp.int32),
            coarse=coarse)
        assert np.float32(thr_host) == np.float32(thr_dev), (scale, lam)
    # the vector-k (per-client) form agrees with per-scalar calls
    ks = jnp.asarray([0, 100, 700], jnp.int32)
    vec = kth_smallest_threshold(q, jnp.asarray(pack.prunable_mask()), ks,
                                 coarse=coarse)
    for i, k in enumerate([0, 100, 700]):
        one = kth_smallest_threshold(q, jnp.asarray(pack.prunable_mask()),
                                     jnp.asarray(k, jnp.int32), coarse=coarse)
        assert np.float32(vec[i]) == np.float32(one)
    # k at / beyond the valid count (out of round_step's lam < 1 contract
    # but the function is public): both modes agree — the histogram's bin
    # clamp keeps it from overflowing the exponent shift
    for k in (pack.n_prunable, pack.n_prunable + 5):
        got = kth_smallest_threshold(q, jnp.asarray(pack.prunable_mask()),
                                     jnp.asarray(k, jnp.int32), coarse=coarse)
        ref = kth_smallest_threshold(q, jnp.asarray(pack.prunable_mask()),
                                     jnp.asarray(k, jnp.int32),
                                     coarse="bisect")
        # k > count saturates the search (NaN for both modes); equal_nan
        # compares the in-range k == count case exactly
        assert np.array_equal(np.float32(got), np.float32(ref),
                              equal_nan=True), (scale, k)


def test_weighted_loss_matches_plain_mean_bitwise():
    """make_loss_fn's weighted companion with all-ones weights is bitwise
    equal to the plain mean (value and gradients) — the property that lets
    the packed engine thread sample weights unconditionally."""
    from repro.models import lenet_apply, make_loss_fn
    rng = np.random.default_rng(2)
    params = lenet_init(jax.random.key(2))
    loss = make_loss_fn(lenet_apply)
    x = jnp.asarray(rng.normal(size=(16, 28, 28, 1)), jnp.float32)
    y = jnp.asarray(rng.integers(0, 10, size=16))
    l0, g0 = jax.jit(jax.value_and_grad(loss))(params, x, y)
    l1, g1 = jax.jit(jax.value_and_grad(loss.weighted))(
        params, x, y, jnp.ones(16, jnp.float32))
    assert bool(l0 == l1)
    for a, b in zip(jax.tree_util.tree_leaves(g0),
                    jax.tree_util.tree_leaves(g1)):
        assert bool(jnp.all(a == b))


# -- packed engine vs reference trainer, bit for bit ------------------------

N = 3


@pytest.fixture(scope="module")
def small_env():
    ds = make_dataset("synthetic-mnist", n_train=360, n_test=120, seed=0)
    parts = partition_by_dirichlet(ds.y_train, N, sigma=1.0,
                                   rng=np.random.default_rng(0))
    clients = [ClientData(ds.x_train[i], ds.y_train[i]) for i in parts]
    return clients, lenet_init(jax.random.key(0)), make_loss_fn(lenet_apply)


def _sched(n_rounds, lam):
    return make_schedule(np.ones((n_rounds, N)), lam)


@pytest.mark.parametrize("lam", [0.0, 0.4])
def test_packed_round_matches_reference_bitwise(small_env, lam):
    clients, params, loss_fn = small_env
    out = run_pair(clients, params, loss_fn, _sched(4, lam))
    (tr_ref, h_ref), (tr_pk, h_pk) = out["reference"], out["packed"]
    for mr, mp in zip(h_ref, h_pk):
        assert mr.train_loss == mp.train_loss          # exact, per round
    assert_trainers_bitwise(tr_ref, tr_pk)


def test_packed_per_client_lambda_matches_reference_bitwise(small_env):
    clients, params, loss_fn = small_env
    lam_row = np.asarray([0.0, 0.25, 0.6])
    sched = _sched(3, 1.0)
    sched.lam[:] = lam_row[None, :]
    out = run_pair(clients, params, loss_fn, sched)
    assert_trainers_bitwise(out["reference"][0], out["packed"][0])


def test_packed_same_threshold_and_selected_coordinates(small_env):
    """One warm round, then compare the device threshold and keep-mask
    against pruning.global_threshold / build_masks exactly."""
    clients, params, loss_fn = small_env
    tr = FederatedTrainer(loss_fn, params, clients, eta=0.1, batch_size=16,
                          seed=0, backend="packed")
    sp = SystemParams.table1(N)
    ch = ChannelModel(N)
    tr.run(_sched(1, 0.0), sp, ch.uplink, ch.downlink)   # make v nonzero
    lam = 0.5
    imp = pruning.taylor_importance(tr.params, tr.global_grad)
    thr_host = pruning.global_threshold(imp, lam, tr.prune_spec)
    masks_host = pruning.build_masks(imp, lam, tr.prune_spec)

    from repro.kernels import ops
    k = int(np.floor(lam * tr.pack.n_prunable))
    thr_dev = kth_smallest_threshold(
        (tr._w * tr._v) ** 2, tr.engine.prunable, jnp.asarray(k, jnp.int32))
    assert np.float32(thr_host) == np.float32(thr_dev)
    _, mask_dev = ops.packed_importance_mask(
        tr._w, tr._v, tr.engine.prunable, thr_dev)
    valid = jnp.asarray(tr.pack.valid_mask())
    mask_host_packed = tr.pack.pack(masks_host)
    assert bool(jnp.all(mask_dev * valid == mask_host_packed * valid))


def test_unroll_axis_also_bitwise(small_env):
    clients, params, loss_fn = small_env
    out = run_pair(clients, params, loss_fn, _sched(3, 0.3),
                    client_axis="unroll")
    assert_trainers_bitwise(out["reference"][0], out["packed"][0])
