"""Data pipeline, optimizers, checkpointing."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.checkpoint import CheckpointManager, load_checkpoint, save_checkpoint
from repro.data import (batches, dirichlet_label_proportions, make_dataset,
                        partition_by_dirichlet)
from repro.optim import adam, apply_updates, momentum, sgd, global_norm


# ------------------------------ data ------------------------------

def test_dirichlet_proportions_row_stochastic():
    p = dirichlet_label_proportions(8, 10, 0.5, np.random.default_rng(0))
    assert p.shape == (8, 10)
    np.testing.assert_allclose(p.sum(axis=1), 1.0, rtol=1e-9)


def test_partition_covers_everything_once():
    labels = np.random.default_rng(0).integers(0, 10, 3000)
    parts = partition_by_dirichlet(labels, 5, sigma=0.5,
                                   rng=np.random.default_rng(1))
    allidx = np.concatenate(parts)
    assert len(allidx) == len(labels)
    assert len(np.unique(allidx)) == len(labels)


@settings(max_examples=20, deadline=None)
@given(st.floats(0.05, 50.0), st.integers(2, 8), st.integers(0, 9999))
def test_partition_property(sigma, n_clients, seed):
    labels = np.random.default_rng(seed).integers(0, 10, 1000)
    parts = partition_by_dirichlet(labels, n_clients, sigma=sigma,
                                   rng=np.random.default_rng(seed))
    assert sum(len(p) for p in parts) == 1000
    assert min(len(p) for p in parts) >= 1


def test_low_sigma_more_skew():
    """Smaller Dirichlet concentration => more heterogeneous label splits
    (paper Fig. 3)."""
    labels = np.random.default_rng(0).integers(0, 10, 20000)

    def mean_kl(sigma):
        parts = partition_by_dirichlet(labels, 8, sigma=sigma,
                                       rng=np.random.default_rng(2))
        glob = np.bincount(labels, minlength=10) / len(labels)
        kls = []
        for p in parts:
            h = np.bincount(labels[p], minlength=10) + 1e-9
            h = h / h.sum()
            kls.append(np.sum(h * np.log(h / glob)))
        return np.mean(kls)

    assert mean_kl(0.1) > mean_kl(10.0)


def test_synthetic_dataset_learnable_shapes():
    ds = make_dataset("synthetic-mnist", n_train=128, n_test=32, seed=0)
    assert ds.x_train.shape == (128, 28, 28, 1)
    assert ds.x_test.shape == (32, 28, 28, 1)
    assert set(np.unique(ds.y_train)).issubset(set(range(10)))
    ds2 = make_dataset("synthetic-cifar10", n_train=16, n_test=8)
    assert ds2.x_train.shape == (16, 32, 32, 3)


def test_batches_drop_remainder_and_cover():
    x = np.arange(103)[:, None].astype(np.float32)
    y = np.arange(103)
    seen = []
    for xb, yb in batches(x, y, 10, rng=np.random.default_rng(0)):
        assert xb.shape == (10, 1)
        seen.extend(yb.tolist())
    assert len(seen) == 100
    assert len(set(seen)) == 100


# ------------------------------ optim ------------------------------

def _quad_problem():
    target = jnp.asarray(np.random.default_rng(0).normal(size=(8,)),
                         jnp.float32)
    params = {"w": jnp.zeros(8)}

    def loss(p):
        return jnp.sum((p["w"] - target)**2)

    return params, loss, target


@pytest.mark.parametrize("opt", [sgd(0.1), momentum(0.05), adam(0.2)])
def test_optimizers_converge_on_quadratic(opt):
    params, loss, target = _quad_problem()
    state = opt.init(params)
    for _ in range(200):
        g = jax.grad(loss)(params)
        upd, state = opt.update(g, state, params)
        params = apply_updates(params, upd)
    assert float(loss(params)) < 1e-3


def test_global_norm():
    t = {"a": jnp.asarray([3.0]), "b": jnp.asarray([4.0])}
    assert float(global_norm(t)) == pytest.approx(5.0)


# ------------------------------ checkpoint ------------------------------

def test_checkpoint_roundtrip(tmp_path):
    tree = {"layer": {"w": jnp.asarray(np.random.default_rng(0).normal(
        size=(4, 4)), jnp.float32), "b": jnp.zeros(4)},
        "scale": jnp.ones(())}
    path = str(tmp_path / "ck")
    save_checkpoint(path, tree, step=7, sharding_meta={"layer/w": "P('model')"})
    restored, meta = load_checkpoint(path, tree)
    assert meta["step"] == 7
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_shape_mismatch_raises(tmp_path):
    tree = {"w": jnp.zeros((4,))}
    path = str(tmp_path / "ck")
    save_checkpoint(path, tree)
    with pytest.raises(ValueError):
        load_checkpoint(path, {"w": jnp.zeros((5,))})


def test_manager_keeps_latest_k(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2)
    tree = {"w": jnp.zeros((2,))}
    for s in (1, 2, 3, 4):
        mgr.save(s, jax.tree.map(lambda x: x + s, tree))
    assert mgr.latest_step() == 4
    restored, meta = mgr.restore(tree)
    np.testing.assert_allclose(np.asarray(restored["w"]), 4.0)
    files = [f for f in os.listdir(tmp_path) if f.endswith(".npz")]
    assert len(files) == 2


def _truncate(path, keep_bytes=40):
    with open(path, "rb") as f:
        head = f.read(keep_bytes)
    with open(path, "wb") as f:
        f.write(head)


def test_truncated_checkpoint_detected_and_skipped(tmp_path):
    from repro.checkpoint import CheckpointCorruptError, verify_checkpoint

    mgr = CheckpointManager(str(tmp_path), keep=3)
    tree = {"w": jnp.zeros((8,))}
    for s in (1, 2, 3):
        mgr.save(s, jax.tree.map(lambda x: x + s, tree))
    # simulate a kill mid-write of the newest npz (torn copy: the atomic
    # rename means this can't happen through save itself)
    _truncate(mgr._name(3) + ".npz")
    with pytest.raises(CheckpointCorruptError, match="truncated or corrupt"):
        verify_checkpoint(mgr._name(3))
    # an explicitly requested corrupt step raises — no silent fallback
    with pytest.raises(CheckpointCorruptError):
        mgr.restore(tree, step=3)
    # latest-by-default falls back to the previous INTACT step
    assert mgr.latest_intact_step() == 2
    restored, meta = mgr.restore(tree)
    assert meta["step"] == 2
    np.testing.assert_allclose(np.asarray(restored["w"]), 2.0)


def test_truncated_metadata_detected(tmp_path):
    from repro.checkpoint import CheckpointCorruptError

    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.zeros((4,))}
    mgr.save(1, tree)
    mgr.save(2, tree)
    _truncate(mgr.meta_path(2), keep_bytes=10)
    with pytest.raises(CheckpointCorruptError, match="not valid JSON"):
        load_checkpoint(mgr._name(2), tree)
    assert mgr.latest_intact_step() == 1
    _, meta = mgr.restore(tree)
    assert meta["step"] == 1


def test_all_checkpoints_corrupt_raises(tmp_path):
    from repro.checkpoint import CheckpointCorruptError

    mgr = CheckpointManager(str(tmp_path))
    tree = {"w": jnp.zeros((4,))}
    mgr.save(1, tree)
    _truncate(mgr._name(1) + ".npz")
    assert mgr.latest_intact_step() is None
    with pytest.raises(CheckpointCorruptError):
        mgr.restore(tree)
    with pytest.raises(FileNotFoundError):
        CheckpointManager(str(tmp_path / "empty")).restore(tree)
