"""Per-architecture smoke tests: reduced variant of each assigned arch runs
one forward + one train step + one prefill/decode step on CPU, asserting
output shapes and finiteness (assignment requirement f)."""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config, list_configs
from repro.configs.registry import INPUT_SHAPES, shape_applicable
from repro.models import (
    init_params, forward, loss_fn, init_cache, prefill, decode_step,
    Runtime, param_count, active_param_count,
)

RT = Runtime(attn_impl="naive")
B, S = 2, 64


def _extra(cfg, batch):
    if cfg.family == "audio":
        return {"encoder_input": jnp.ones(
            (batch, cfg.encoder_tokens, cfg.d_model), jnp.dtype(cfg.dtype))}
    if cfg.family == "vlm":
        return {"vision_embeddings": jnp.ones(
            (batch, cfg.vision_tokens, cfg.d_model), jnp.dtype(cfg.dtype))}
    return None


@pytest.fixture(scope="module")
def key():
    return jax.random.key(0)


@pytest.mark.parametrize("arch", list_configs())
def test_reduced_forward_and_train_step(arch, key):
    cfg = get_config(arch).reduced()
    assert cfg.num_layers <= 2 and cfg.d_model <= 512
    if cfg.num_experts:
        assert cfg.num_experts <= 4
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    labels = jnp.roll(toks, -1, axis=1)
    extra = _extra(cfg, B)

    logits = forward(params, toks, cfg, RT, extra)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.isfinite(logits).all()), "NaN/inf in logits"

    loss, grads = jax.value_and_grad(loss_fn)(params, toks, labels, cfg, RT,
                                              extra)
    assert np.isfinite(float(loss))
    gnorm = sum(float(jnp.sum(jnp.abs(g))) for g in jax.tree.leaves(grads))
    assert np.isfinite(gnorm) and gnorm > 0
    # one SGD step moves the loss
    new = jax.tree.map(lambda w, g: w - 0.1 * g.astype(w.dtype), params, grads)
    loss2 = loss_fn(new, toks, labels, cfg, RT, extra)
    assert np.isfinite(float(loss2))


@pytest.mark.parametrize("arch", list_configs())
def test_reduced_prefill_decode(arch, key):
    cfg = get_config(arch).reduced()
    params = init_params(key, cfg)
    toks = jax.random.randint(key, (B, S), 0, cfg.vocab_size)
    extra = _extra(cfg, B)
    cache = init_cache(cfg, B, S)
    lg, cache = prefill(params, toks[:, : S - 1], cache, cfg, RT, extra)
    assert lg.shape == (B, cfg.vocab_size)
    lg2, cache = decode_step(params, toks[:, -1:], cache, S - 1, cfg, RT)
    assert lg2.shape == (B, cfg.vocab_size)
    assert bool(jnp.isfinite(lg2).all())


@pytest.mark.parametrize("arch", list_configs())
def test_decode_matches_forward(arch, key):
    """Teacher-forced decode must reproduce full-forward logits."""
    cfg = get_config(arch).reduced()
    if cfg.family in ("audio", "vlm"):
        pytest.skip("cross-attn caches validated in test_archs_smoke decode")
    params = init_params(key, cfg)
    s = 24
    toks = jax.random.randint(key, (1, s), 0, cfg.vocab_size)
    full = forward(params, toks, cfg, RT, None)

    cache = init_cache(cfg, 1, s)
    _, cache = prefill(params, toks[:, : s - 1], cache, cfg, RT, None)
    lg, _ = decode_step(params, toks[:, s - 1:], cache, s - 1, cfg, RT)
    np.testing.assert_allclose(np.asarray(lg[0]), np.asarray(full[0, -1]),
                               rtol=2e-2, atol=2e-2)


def test_param_counts_sane_fullsize():
    """Full configs land near their nameplate sizes (abstract shapes only)."""
    expect = {
        "yi-9b": (8e9, 10e9),
        "gemma2-9b": (8e9, 11e9),
        "qwen2.5-3b": (2.5e9, 4e9),
        "granite-3-2b": (2e9, 3.3e9),
        "mamba2-130m": (0.1e9, 0.2e9),
        "hymba-1.5b": (1.2e9, 2.2e9),
        "mixtral-8x22b": (130e9, 150e9),
        "arctic-480b": (430e9, 520e9),
        "llama-3.2-vision-90b": (80e9, 100e9),
        "whisper-small": (0.2e9, 0.35e9),
    }
    for arch, (lo, hi) in expect.items():
        n = param_count(get_config(arch))
        assert lo <= n <= hi, f"{arch}: {n:,} outside [{lo:.1e},{hi:.1e}]"


def test_moe_active_params_much_smaller():
    for arch in ("mixtral-8x22b", "arctic-480b"):
        cfg = get_config(arch)
        assert active_param_count(cfg) < 0.5 * param_count(cfg)


def test_long_context_skip_rules():
    long = INPUT_SHAPES["long_500k"]
    runs = {a: shape_applicable(get_config(a), long)[0] for a in list_configs()}
    assert runs["mamba2-130m"] and runs["hymba-1.5b"]
    assert runs["mixtral-8x22b"] and runs["gemma2-9b"]
    for a in ("yi-9b", "qwen2.5-3b", "granite-3-2b", "arctic-480b",
              "llama-3.2-vision-90b", "whisper-small"):
        assert not runs[a], f"{a} should skip long_500k"
