"""Local-update scheme zoo (FedAvg / FedProx / FedDyn, DESIGN.md §14).

Covers: scheme construction + validation (including the FedSGD trivial
path where ``make_local_scheme("fedavg", steps=1)`` returns None), bitwise
packed-vs-reference parity for all three schemes on the per-round AND the
rounds_per_dispatch>1 block path, FedDyn's per-client correction state
(equality across backends, checkpoint kill/resume restoring it bit-for-
bit, streamed-cohort slab parity vs the replicated store, and the loud
error on the unsupported streamed+sharded combination), the sweep-pool
reset regression (a pooled trainer must not leak FedDyn state between
cells), spec/registry plumbing, the report's tolerance for mixed-vintage
summaries, and the CLI's actionable errors for bad --resume / --checkpoints
paths.
"""
import dataclasses
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    Callback, DataSpec, Experiment, ExperimentSpec, JsonlDirSink, ModelSpec,
    RunSpec, SchemeSpec, SweepSpec, WirelessSpec, run_sweep,
)
from repro.api import cli
from repro.core import FederatedTrainer
from repro.core.local import LocalScheme, local_spec_key, make_local_scheme
from repro.wireless import ChannelModel, SystemParams

from _trainer_pair import assert_trainers_bitwise, make_schedule, run_pair

SINGLE_DEVICE = len(jax.devices()) == 1


# ---------------------------------------------------------------------------
# Scheme construction and validation
# ---------------------------------------------------------------------------

def test_fedavg_single_step_is_the_trivial_fedsgd_path():
    # FedSGD identity BY CONSTRUCTION: the factory returns None, which
    # routes every caller through the untouched single-gradient code, so
    # the committed golden cannot drift no matter what the scan does
    assert make_local_scheme("fedavg", steps=1) is None
    assert make_local_scheme() is None
    assert local_spec_key(None) == ("fedsgd",)


def test_scheme_properties_and_buckets():
    ls = make_local_scheme("fedavg", steps=3)
    assert isinstance(ls, LocalScheme)
    assert ls.steps == 3 and ls.steps_bucket == 4
    assert not ls.stateful and ls.coeff == 0.0
    prox = make_local_scheme("fedprox", steps=5, mu=0.05)
    assert prox.steps_bucket == 8 and prox.coeff == 0.05
    dyn = make_local_scheme("feddyn", steps=1, alpha=0.1)
    assert dyn is not None, "feddyn E=1 is NOT trivial (carries h state)"
    assert dyn.stateful and dyn.coeff == pytest.approx(0.1)
    assert dyn.steps_bucket == 1
    # pow2 steps land exactly on their own bucket (no padded steps)
    assert make_local_scheme("fedavg", steps=4).steps_bucket == 4


def test_scheme_validation_errors():
    with pytest.raises(ValueError, match="unknown local scheme"):
        make_local_scheme("scaffold", steps=2)
    with pytest.raises(ValueError, match="local_steps"):
        make_local_scheme("fedavg", steps=0)
    with pytest.raises(ValueError, match="unknown local scheme kwargs"):
        make_local_scheme("fedprox", steps=2, mue=0.1)
    with pytest.raises(ValueError, match="mu must be >= 0"):
        make_local_scheme("fedprox", steps=2, mu=-1.0)
    with pytest.raises(ValueError, match="alpha must be >= 0"):
        make_local_scheme("feddyn", steps=2, alpha=-0.5)


def test_scheme_spec_roundtrip_carries_local_fields():
    spec = ExperimentSpec(scheme=SchemeSpec(
        local_scheme="fedprox", local_steps=3, local_kwargs={"mu": 0.05}))
    d = spec.to_dict()
    assert d["scheme"]["local_scheme"] == "fedprox"
    spec2 = ExperimentSpec.from_dict(d)
    assert spec2 == spec and spec2.to_dict() == d


# ---------------------------------------------------------------------------
# Bitwise packed-vs-reference parity, per-round and block paths
# ---------------------------------------------------------------------------

_rng = np.random.default_rng(0)
D = 5


class _Toy:
    def __init__(self, n):
        self.x = _rng.normal(size=(n, D)).astype(np.float32)
        self.y = _rng.integers(0, 2, size=n).astype(np.int32)

    def __len__(self):
        return len(self.y)


def _toy_problem(n_clients=4):
    clients = [_Toy(12 + 3 * i) for i in range(n_clients)]
    params = {"w": jnp.asarray(_rng.normal(size=(D,)).astype(np.float32)),
              "b": jnp.zeros((), jnp.float32)}

    def loss_fn(p, x, y):
        logits = x @ p["w"] + p["b"]
        return jnp.mean(jnp.log1p(jnp.exp(-(2.0 * y - 1.0) * logits)))

    return clients, params, loss_fn


@pytest.mark.parametrize("name,kw", [
    ("fedavg", dict(steps=3)),           # E=3 pads to bucket 4
    ("fedprox", dict(steps=3, mu=0.05)),
    ("feddyn", dict(steps=2, alpha=0.1)),
])
def test_packed_matches_reference_bitwise(name, kw):
    clients, params, loss_fn = _toy_problem()
    sched = make_schedule(np.ones((5, 4)), 0.3)
    ls = make_local_scheme(name, **kw)
    out = run_pair(clients, params, loss_fn, sched, batch_size=8,
                   both_kw=dict(local_scheme=ls), shards=1)
    tr_r, hist_r = out["reference"]
    tr_p, hist_p = out["packed"]
    assert_trainers_bitwise(tr_r, tr_p)
    losses = [m.train_loss for m in hist_r]
    assert [m.train_loss for m in hist_p] == losses
    if name == "feddyn":
        assert tr_r._h is not None and tr_p._h is not None
        assert bool(jnp.all(tr_r._h == tr_p._h))
        assert float(jnp.abs(tr_p._h).sum()) > 0, "h never updated"
    else:
        assert tr_p._h is None

    # the rpd=4 block path replays the SAME trajectory bit-for-bit
    out4 = run_pair(clients, params, loss_fn, sched, batch_size=8,
                    both_kw=dict(local_scheme=ls), shards=1,
                    rounds_per_dispatch=4)
    tr_p4, hist_p4 = out4["packed"]
    assert_trainers_bitwise(tr_r, tr_p4)
    assert [m.train_loss for m in hist_p4] == losses
    if name == "feddyn":
        assert bool(jnp.all(tr_r._h == tr_p4._h))


def test_fedprox_zero_mu_matches_fedavg_bitwise():
    """mu=0 FedProx is algebraically FedAvg; the packed engine realizes
    it that way bit-for-bit (the proximal FMA contributes an exact +0)."""
    clients, params, loss_fn = _toy_problem()
    sched = make_schedule(np.ones((3, 4)), 0.3)
    runs = {}
    for name, kw in (("fedavg", {}), ("fedprox", dict(mu=0.0))):
        ls = make_local_scheme(name, steps=2, **kw)
        tr = FederatedTrainer(loss_fn, params, clients, eta=0.1,
                              batch_size=8, seed=0, backend="packed",
                              shards=1, local_scheme=ls)
        sp = SystemParams.table1(4)
        ch = ChannelModel(4)
        hist = tr.run(sched, sp, ch.uplink, ch.downlink)
        runs[name] = (tr, [m.train_loss for m in hist])
    assert runs["fedavg"][1] == runs["fedprox"][1]
    assert_trainers_bitwise(runs["fedavg"][0], runs["fedprox"][0])


# ---------------------------------------------------------------------------
# Spec-level: trivial path == FedSGD, FedDyn checkpoint resume
# ---------------------------------------------------------------------------

N, ROUNDS = 5, 8


def small_spec(**kw) -> ExperimentSpec:
    scheme_kw = {k: kw.pop(k) for k in
                 ("local_scheme", "local_steps", "local_kwargs")
                 if k in kw}
    return ExperimentSpec(
        data=DataSpec(dataset="synthetic-mnist", n_clients=N, sigma=5.0,
                      n_train=200, n_test=60, seed=0),
        model=ModelSpec(name="mlp-edge"),
        wireless=WirelessSpec(e0=1e6, t0=1e6, seed=0),
        scheme=SchemeSpec(name="proposed", rounds=ROUNDS, eta=0.1, batch=8,
                          ao={"outer_iters": 1}, **scheme_kw),
        run=RunSpec(seed=0, eval_every=4, shards=1, **kw))


def test_explicit_fedavg_e1_spec_reproduces_fedsgd_bitwise():
    """`local_scheme="fedavg", local_steps=1` spelled out in a spec is
    byte-identical to the default spec (the factory collapses it to the
    trivial path — the committed FedSGD golden stays pinned)."""
    res_a = Experiment(small_spec()).run()
    res_b = Experiment(small_spec(local_scheme="fedavg", local_steps=1,
                                  local_kwargs={})).run()
    assert [m.train_loss for m in res_b.history] == \
        [m.train_loss for m in res_a.history]
    assert res_b.summary == res_a.summary


class _KillAt(Callback):
    def __init__(self, round_, every):
        self.round_ = round_
        self.checkpoint_every = every

    def on_checkpoint(self, m, trainer):
        if m.round == self.round_:
            raise RuntimeError("simulated mid-run kill")


@pytest.mark.parametrize("rpd", [1, 4])
def test_feddyn_kill_resume_restores_h_bitwise(tmp_path, rpd):
    """Kill a FedDyn run after a checkpoint; the resumed run must replay
    the uninterrupted trajectory bit-for-bit INCLUDING the per-client
    correction state h (the new checkpoint leaf)."""
    base = small_spec(local_scheme="feddyn", local_steps=2,
                      local_kwargs={"alpha": 0.1}, rounds_per_dispatch=rpd)
    run_a = Experiment(base).build()
    res_a = run_a.run()
    assert run_a.trainer._h is not None
    assert float(jnp.abs(run_a.trainer._h).sum()) > 0

    ckpt = str(tmp_path / f"ckpt_rpd{rpd}")
    spec = dataclasses.replace(
        base, run=dataclasses.replace(base.run, checkpoint_dir=ckpt,
                                      checkpoint_every=4))
    with pytest.raises(RuntimeError, match="simulated"):
        Experiment(spec).build().run(callbacks=[_KillAt(4, 4)])

    run_b = Experiment(spec).build()
    res_b = run_b.resume(ckpt)
    assert res_b.summary["resumed_from"] == 4
    for fld in ("train_loss", "test_loss", "test_accuracy",
                "cumulative_energy", "selected"):
        assert [getattr(m, fld) for m in res_b.history] == \
            [getattr(m, fld) for m in res_a.history], fld
    for a, b in zip(jax.tree_util.tree_leaves(run_a.trainer.params),
                    jax.tree_util.tree_leaves(run_b.trainer.params)):
        assert bool(jnp.all(a == b))
    assert bool(jnp.all(run_a.trainer._h == run_b.trainer._h)), \
        "per-client correction state drifted across kill/resume"


# ---------------------------------------------------------------------------
# FedDyn x streamed cohorts
# ---------------------------------------------------------------------------

def test_feddyn_streamed_cohorts_match_replicated_bitwise():
    """The h-slab swap protocol: a FedDyn run over streamed cohorts
    (rotating partial selection, so cohorts differ per block) equals the
    replicated-store run bit-for-bit, including the full h buffer."""
    clients, params, loss_fn = _toy_problem(n_clients=6)
    a = np.zeros((6, 6))
    for s in range(6):
        a[s, [(s + j) % 6 for j in range(4)]] = 1.0
    sched = make_schedule(a, 0.3)
    ls = make_local_scheme("feddyn", steps=2, alpha=0.1)
    out = {}
    for store in ("replicated", "streamed"):
        tr = FederatedTrainer(loss_fn, params, clients, eta=0.1,
                              batch_size=8, seed=0, backend="packed",
                              shards=1, rounds_per_dispatch=2,
                              local_scheme=ls, client_store=store)
        sp = SystemParams.table1(6)
        ch = ChannelModel(6)
        hist = tr.run(sched, sp, ch.uplink, ch.downlink)
        out[store] = (tr, [m.train_loss for m in hist])
    tr_r, losses_r = out["replicated"]
    tr_s, losses_s = out["streamed"]
    assert losses_r == losses_s
    assert_trainers_bitwise(tr_r, tr_s)
    assert bool(jnp.all(tr_r._h == tr_s._h)), "h slab scatter-back drifted"
    assert float(jnp.abs(tr_s._h).sum()) > 0


@pytest.mark.skipif(SINGLE_DEVICE,
                    reason="data-sharded cohort store needs >1 device")
def test_feddyn_streamed_sharded_raises():
    clients, params, loss_fn = _toy_problem(n_clients=6)
    sched = make_schedule(np.ones((2, 6)), 0.3)
    ls = make_local_scheme("feddyn", steps=2, alpha=0.1)
    tr = FederatedTrainer(loss_fn, params, clients, eta=0.1, batch_size=8,
                          seed=0, backend="packed", shards=2,
                          rounds_per_dispatch=2, local_scheme=ls,
                          client_store="streamed")
    sp = SystemParams.table1(6)
    ch = ChannelModel(6)
    with pytest.raises(ValueError, match="data-sharded cohort store"):
        tr.run(sched, sp, ch.uplink, ch.downlink)


# ---------------------------------------------------------------------------
# Sweep-pool reset regression (satellite: pooled state leak)
# ---------------------------------------------------------------------------

def test_reset_clears_per_client_optimizer_state():
    clients, params, loss_fn = _toy_problem()
    ls = make_local_scheme("feddyn", steps=2, alpha=0.1)
    tr = FederatedTrainer(loss_fn, params, clients, eta=0.1, batch_size=8,
                          seed=0, backend="packed", shards=1,
                          local_scheme=ls)
    sp = SystemParams.table1(4)
    ch = ChannelModel(4)
    tr.run(make_schedule(np.ones((2, 4)), 0.3), sp, ch.uplink, ch.downlink)
    assert tr._h is not None and float(jnp.abs(tr._h).sum()) > 0
    tr.reset(params, seed=0)
    assert tr._h is None, "reset must drop the FedDyn correction buffer"


def test_pooled_sweep_cells_match_cold_built_trainers():
    """REGRESSION: the sweep service reuses one pooled trainer across
    cells; before the reset fix, cell 2 started from cell 1's leftover
    FedDyn h buffer. Every pooled cell must equal the same spec run cold
    in a fresh Experiment."""
    base = small_spec(local_scheme="feddyn", local_steps=2,
                      local_kwargs={"alpha": 0.1})
    sw = SweepSpec(base=base, seeds=[0, 1])
    res = run_sweep(sw)
    assert len(res.results) == 2
    assert res.n_trainer_builds == 1, "cells must share ONE pooled trainer"
    for cell, swept in zip(res.cells, res.results):
        cold = Experiment(cell.spec).run()
        assert [m.train_loss for m in swept.history] == \
            [m.train_loss for m in cold.history], cell.name
        assert swept.summary == cold.summary, cell.name


# ---------------------------------------------------------------------------
# Report: mixed-vintage summaries (satellite: runs_table robustness)
# ---------------------------------------------------------------------------

def test_report_tolerates_mixed_summaries(tmp_path):
    report = pytest.importorskip("benchmarks.report")
    # a real export (no faults/aggregation/fleet sections at all)
    res = Experiment(small_spec()).run()
    paths = [res.to_jsonl(str(tmp_path / "plain.jsonl"))]

    # a mixed-vintage export: sections null / reshaped / missing, metrics
    # null (strict-JSON nan) — the shapes older writers actually produced
    header = {"kind": "experiment",
              "spec": {"data": {"dataset": "synthetic-mnist"},
                       "model": {"name": "mlp-edge"},
                       "scheme": {"name": "proposed"}},
              "summary": {"rounds_run": 3, "final_accuracy": None,
                          "faults": None, "aggregation": "trimmed",
                          "fleet": {}, "theta": None}}
    vintage = str(tmp_path / "vintage.jsonl")
    with open(vintage, "w") as f:
        f.write(json.dumps(header) + "\n")
    paths.append(vintage)

    # one with every optional section present
    rich = str(tmp_path / "rich.jsonl")
    header2 = {"kind": "experiment", "spec": {},
               "summary": {"rounds_run": 2, "final_accuracy": 0.5,
                           "final_accuracy_round": 1,
                           "cumulative_energy": 1.5, "cumulative_delay": 2.0,
                           "theta": 0.25, "feasible": True,
                           "faults": {"n_dropped": 3, "n_quarantined": 1,
                                      "n_skipped_rounds": 0},
                           "aggregation": {"aggregator": "trimmed_mean",
                                           "n_adjusted": 4},
                           "fleet": {"n_cohort_swaps": 2,
                                     "h2d_bytes": 2 ** 20,
                                     "prefetch_stall_s": 0.5}}}
    with open(rich, "w") as f:
        f.write(json.dumps(header2) + "\n")
    paths.append(rich)

    # and a sweep index contributing a failed-cell row
    idx = str(tmp_path / "sweep.jsonl")
    with open(idx, "w") as f:
        f.write(json.dumps({"kind": "sweep_error", "name": "cell_x",
                            "error_kind": "timeout", "error": "boom"}) + "\n")
    paths.append(idx)

    table = report.runs_table(paths)
    lines = table.splitlines()
    assert len(lines) == 2 + 4  # header+rule, 3 runs + 1 error row
    assert "nan" not in table
    assert "3/1/0" in table          # rich faults counters
    assert "trimmed_mean" in table
    assert "TIMEOUT" in table
    vintage_row = next(ln for ln in lines if "vintage" in ln)
    # absent/null/reshaped sections and null metrics all render em-dashes
    assert vintage_row.count("—") >= 5


# ---------------------------------------------------------------------------
# CLI: actionable errors for bad paths (satellite)
# ---------------------------------------------------------------------------

def test_cli_sweep_resume_without_manifest_fails_loudly(tmp_path):
    spec_path = small_spec().save(str(tmp_path / "spec.json"))
    out_dir = str(tmp_path / "not_a_sweep")
    os.makedirs(out_dir)
    with pytest.raises(SystemExit) as exc:
        cli.main(["sweep", spec_path, "--seeds", "0", "--out-dir", out_dir,
                  "--resume"])
    msg = str(exc.value)
    assert "no sweep manifest" in msg and out_dir in msg
    # and nothing was written to the directory it refused to resume into
    assert os.listdir(out_dir) == []


def test_cli_validate_nonexistent_checkpoint_dir(tmp_path, capsys):
    missing = str(tmp_path / "nope" / "ckpts")
    rc = cli.main(["validate", "--checkpoints", missing])
    assert rc == 1
    err = capsys.readouterr().err
    assert missing in err and "does not exist" in err
    # the probe must NOT leave an empty decoy directory behind
    assert not os.path.exists(missing)


def test_cli_validate_empty_checkpoint_dir(tmp_path, capsys):
    empty = str(tmp_path / "empty_ckpts")
    os.makedirs(empty)
    rc = cli.main(["validate", "--checkpoints", empty])
    assert rc == 1
    err = capsys.readouterr().err
    assert empty in err and "no checkpoints" in err
