"""Wireless substrate: rates/delay/energy (eqs. 8-15)."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.wireless import (
    SystemParams, ChannelModel, uplink_rate, downlink_rate,
    computation_delay, communication_delay, round_delay, total_delay,
    computation_energy, upload_energy, round_energy, total_energy,
)

N = 5


@pytest.fixture
def sp():
    return SystemParams.table1(N, dataset="mnist")


@pytest.fixture
def ch():
    return ChannelModel(N, seed=1)


def test_shannon_rate_formula(sp, ch):
    p = 0.1 * np.ones(N)
    r = uplink_rate(p, ch.uplink, sp)
    manual = sp.bandwidth * np.log2(1 + p * ch.uplink / (sp.bandwidth * sp.noise_psd))
    np.testing.assert_allclose(r, manual)
    assert (r > 0).all()


def test_rate_monotone_in_power(sp, ch):
    r1 = uplink_rate(0.05 * np.ones(N), ch.uplink, sp)
    r2 = uplink_rate(0.5 * np.ones(N), ch.uplink, sp)
    assert (r2 > r1).all()


def test_pruning_reduces_delay_and_energy(sp, ch):
    p = 0.2 * np.ones(N)
    f = 200e6 * np.ones(N)
    lam0, lam5 = np.zeros(N), 0.5 * np.ones(N)
    assert (computation_delay(lam5, f, sp) < computation_delay(lam0, f, sp)).all()
    assert (computation_energy(lam5, f, sp) < computation_energy(lam0, f, sp)).all()
    d0 = communication_delay(lam0, p, ch.uplink, ch.downlink, sp)
    d5 = communication_delay(lam5, p, ch.uplink, ch.downlink, sp)
    assert (d5 < d0).all()
    assert (upload_energy(lam5, p, ch.uplink, sp)
            < upload_energy(lam0, p, ch.uplink, sp)).all()


def test_round_delay_is_straggler_max(sp, ch):
    a = np.array([1, 1, 0, 0, 0.0])
    lam = np.zeros(N)
    p = 0.2 * np.ones(N)
    f = 100e6 * np.ones(N)
    per = computation_delay(lam, f, sp) + communication_delay(
        lam, p, ch.uplink, ch.downlink, sp)
    assert round_delay(a, lam, p, f, ch.uplink, ch.downlink, sp) == \
        pytest.approx(max(per[0], per[1]))


def test_totals_accumulate_over_rounds(sp, ch):
    s = 4
    a = np.ones((s, N))
    lam = np.zeros((s, N))
    p = 0.2 * np.ones((s, N))
    f = 100e6 * np.ones((s, N))
    t1 = total_delay(a[:1], lam[:1], p[:1], f[:1], ch.uplink, ch.downlink, sp)
    ts = total_delay(a, lam, p, f, ch.uplink, ch.downlink, sp)
    assert ts == pytest.approx(s * t1, rel=1e-9)
    e1 = total_energy(a[:1], lam[:1], p[:1], f[:1], ch.uplink, ch.downlink, sp)
    es = total_energy(a, lam, p, f, ch.uplink, ch.downlink, sp)
    assert es == pytest.approx(s * e1, rel=1e-9)


def test_unselected_clients_cost_nothing_but_broadcast(sp, ch):
    a = np.zeros(N)
    lam = np.zeros(N)
    p = 0.2 * np.ones(N)
    f = 100e6 * np.ones(N)
    e = round_energy(a, lam, p, f, ch.uplink, ch.downlink, sp)
    from repro.wireless.comm import broadcast_energy
    assert e == pytest.approx(broadcast_energy(ch.downlink, sp))


def test_rayleigh_gain_mean_close_to_path_loss():
    from repro.wireless.channel import rayleigh_gains
    g = rayleigh_gains(200_000, path_loss=1e-5,
                       rng=np.random.default_rng(0))
    assert np.mean(g) == pytest.approx(1e-5, rel=0.02)


@settings(max_examples=50, deadline=None)
@given(st.floats(0.01, 0.5), st.floats(0.0, 0.7), st.floats(1e7, 5e8))
def test_energy_delay_positive_property(power, lam, freq):
    sp = SystemParams.table1(3, dataset="mnist")
    ch = ChannelModel(3, seed=0)
    p = power * np.ones(3)
    la = lam * np.ones(3)
    f = freq * np.ones(3)
    a = np.ones(3)
    assert round_delay(a, la, p, f, ch.uplink, ch.downlink, sp) > 0
    assert round_energy(a, la, p, f, ch.uplink, ch.downlink, sp) > 0
