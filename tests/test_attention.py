"""Attention implementations agree: naive / chunked / flash_vjp / ring decode."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.models.attention import (
    naive_attention, chunked_attention, decode_attention,
    decode_attention_ring, fill_ring, ring_slots)
from repro.models.flash_vjp import chunked_attention_vjp

KEY = jax.random.key(7)


def _qkv(b=2, s=128, hq=4, hkv=2, d=32, seed=0):
    ks = jax.random.split(jax.random.key(seed), 3)
    q = jax.random.normal(ks[0], (b, s, hq, d), jnp.float32)
    k = jax.random.normal(ks[1], (b, s, hkv, d), jnp.float32)
    v = jax.random.normal(ks[2], (b, s, hkv, d), jnp.float32)
    return q, k, v


@pytest.mark.parametrize("causal,window", [(True, 0), (False, 0), (True, 48)])
@pytest.mark.parametrize("cap", [0.0, 15.0])
def test_chunked_matches_naive(causal, window, cap):
    q, k, v = _qkv()
    ref = naive_attention(q, k, v, causal=causal, window=window, cap=cap)
    out = chunked_attention(q, k, v, causal=causal, window=window, cap=cap,
                            q_chunk=32, kv_chunk=32)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("kw", [dict(causal=True), dict(causal=False),
                                dict(causal=True, window=48),
                                dict(causal=True, cap=15.0)])
def test_flash_vjp_forward_and_gradients(kw):
    q, k, v = _qkv()

    def f_ref(q, k, v):
        return (chunked_attention(q, k, v, q_chunk=32, kv_chunk=32, **kw)**2).sum()

    def f_new(q, k, v):
        return (chunked_attention_vjp(q, k, v, q_chunk=32, kv_chunk=32,
                                      **kw)**2).sum()

    g_ref = jax.grad(f_ref, argnums=(0, 1, 2))(q, k, v)
    g_new = jax.grad(f_new, argnums=(0, 1, 2))(q, k, v)
    for a, b in zip(g_ref, g_new):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   rtol=1e-4, atol=1e-4)


def test_decode_matches_last_row_of_full():
    q, k, v = _qkv(s=96)
    pos = 77
    ref = naive_attention(q[:, :pos + 1], k[:, :pos + 1], v[:, :pos + 1],
                          causal=True)[:, pos:pos + 1]
    out = decode_attention(q[:, pos:pos + 1], k, v, pos + 1)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


@pytest.mark.parametrize("pos", [10, 63, 64, 100])
def test_ring_decode_matches_windowed(pos):
    w = 64
    q, k, v = _qkv(s=128)
    ref = naive_attention(q[:, :pos + 1], k[:, :pos + 1], v[:, :pos + 1],
                          causal=True, window=w)[:, pos:pos + 1]
    rk = fill_ring(k[:, :pos + 1], w)
    rv = fill_ring(v[:, :pos + 1], w)
    out = decode_attention_ring(q[:, pos:pos + 1], rk, rv, pos)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=1e-5, atol=1e-5)


def test_ring_slots_invariants():
    w = 16
    for pos in (0, 5, 15, 16, 33):
        slots = np.asarray(ring_slots(pos, w))
        valid = slots[slots >= 0]
        # every valid slot holds a position in (pos-w, pos]
        assert (valid <= pos).all() and (valid > pos - w).all()
        # slot i holds a position congruent to i
        for i, p in enumerate(slots):
            if p >= 0:
                assert p % w == i


def test_ring_incremental_write_consistency():
    """fill_ring(prefill) + one decode write == fill_ring(prefill+1)."""
    w = 32
    _, k, _ = _qkv(s=80)
    pos = 50
    ring = fill_ring(k[:, :pos], w)          # tokens 0..pos-1
    slot = pos % w
    ring = jax.lax.dynamic_update_slice(ring, k[:, pos:pos + 1], (0, slot, 0, 0))
    expect = fill_ring(k[:, :pos + 1], w)
    np.testing.assert_allclose(np.asarray(ring), np.asarray(expect))


@settings(max_examples=25, deadline=None)
@given(st.integers(1, 4), st.sampled_from([32, 64, 128]),
       st.sampled_from([(4, 1), (4, 2), (4, 4), (6, 3)]),
       st.sampled_from([16, 32, 64]), st.integers(0, 10_000))
def test_chunked_naive_property(b, s, heads, d, seed):
    hq, hkv = heads
    q, k, v = _qkv(b=b, s=s, hq=hq, hkv=hkv, d=d, seed=seed)
    ref = naive_attention(q, k, v, causal=True)
    out = chunked_attention(q, k, v, causal=True, q_chunk=16, kv_chunk=16)
    np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                               rtol=3e-5, atol=3e-5)
