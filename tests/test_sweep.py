"""Multi-seed sweep engine (repro.api.sweep, DESIGN.md §9).

Covers: SweepSpec dict/JSON round-trip identity on randomized trees
(hypothesis, stub-compatible offline), unknown-key field-path errors for
sweep axes, deterministic matrix expansion (same template -> same matrix
order), axis nesting/zip semantics, the execution engine's environment +
trainer reuse (build-counter-asserted), streaming-sink ordering, bitwise
equality of swept runs vs the same spec run standalone through `cli run`,
the `cli sweep` subcommand, and the report's seed-aggregated mean±std
section.
"""
import dataclasses
import json
import os

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import (
    DataSpec, Experiment, ExperimentSpec, JsonlDirSink, ModelSpec, RunSink,
    RunResult, RunSpec, SchemeSpec, SpecError, SweepSpec, WirelessSpec,
    build_environment, override_field, run_sweep,
)
from repro.api import cli

N_CLIENTS, ROUNDS, BATCH = 5, 4, 8


def base_spec(**run_kw) -> ExperimentSpec:
    return ExperimentSpec(
        data=DataSpec(dataset="synthetic-mnist", n_clients=N_CLIENTS,
                      sigma=5.0, n_train=200, n_test=60, seed=0),
        model=ModelSpec(name="mlp-edge"),
        wireless=WirelessSpec(e0=1e6, t0=1e6, seed=0),
        scheme=SchemeSpec(name="proposed", rounds=ROUNDS, eta=0.1,
                          batch=BATCH, ao={"outer_iters": 1}),
        run=RunSpec(seed=0, eval_every=2, **run_kw))


# ---------------------------------------------------------------------------
# Property-based: spec round-trips + expansion determinism
# ---------------------------------------------------------------------------

@settings(max_examples=15, deadline=None)
@given(st.floats(min_value=0.1, max_value=10.0),
       st.integers(min_value=2, max_value=40),
       st.integers(min_value=1, max_value=200),
       st.sampled_from(["proposed", "no_gen", "fixed_pruning",
                        "proposed_exact"]),
       st.sampled_from(["lenet", "mlp-edge", "resnet"]),
       st.sampled_from(["none", "threshold", "fine_grained"]),
       st.sampled_from(["none", "gaussian"]))
def test_experiment_spec_roundtrip_randomized(sigma, n_clients, rounds,
                                              scheme, model, selection,
                                              noise_model):
    spec = ExperimentSpec(
        data=DataSpec(sigma=sigma, n_clients=n_clients, seed=n_clients),
        model=ModelSpec(name=model),
        wireless=WirelessSpec(e0=float(rounds), noise_model=noise_model,
                              noise_kwargs={"std": sigma / 100.0}),
        scheme=SchemeSpec(name=scheme, rounds=rounds,
                          data_selection=selection,
                          data_selection_kwargs={"keep_frac": 0.5}),
        run=RunSpec(seed=rounds))
    d = spec.to_dict()
    assert ExperimentSpec.from_dict(d) == spec
    assert ExperimentSpec.from_dict(d).to_dict() == d
    assert ExperimentSpec.from_json(spec.to_json()) == spec


@settings(max_examples=15, deadline=None)
@given(st.lists(st.integers(min_value=0, max_value=99), min_size=0,
                max_size=4),
       st.lists(st.floats(min_value=0.1, max_value=9.0), min_size=1,
                max_size=3),
       st.sampled_from([[], ["proposed"], ["proposed", "no_gen"]]))
def test_sweep_spec_roundtrip_and_deterministic_expansion(seeds, sigmas,
                                                          schemes):
    sw = SweepSpec(base=base_spec(), seeds=seeds, schemes=list(schemes),
                   grid={"data.sigma": list(sigmas)})
    d = sw.to_dict()
    assert SweepSpec.from_dict(d) == sw
    assert SweepSpec.from_dict(d).to_dict() == d
    back = SweepSpec.from_json(sw.to_json())
    assert back == sw

    cells_a = sw.expand()
    cells_b = sw.expand()
    cells_c = back.expand()
    assert [(c.index, c.name, c.spec) for c in cells_a] == \
        [(c.index, c.name, c.spec) for c in cells_b] == \
        [(c.index, c.name, c.spec) for c in cells_c]
    expect = len(sigmas) * max(len(schemes), 1) * max(len(seeds), 1)
    assert len(cells_a) == expect
    assert len({c.name for c in cells_a}) == len(cells_a)   # names unique


# ---------------------------------------------------------------------------
# Field-path overrides + axis semantics
# ---------------------------------------------------------------------------

def test_override_field_paths():
    spec = base_spec()
    assert override_field(spec, "data.sigma", 0.5).data.sigma == 0.5
    assert override_field(spec, "scheme.name", "no_gen").scheme.name == \
        "no_gen"
    assert override_field(spec, "run.seed", 7).run.seed == 7
    # the original spec is never mutated
    assert spec.data.sigma == 5.0 and spec.run.seed == 0


def test_override_unknown_paths_error_with_context():
    spec = base_spec()
    with pytest.raises(SpecError) as e:
        override_field(spec, "data.bogus", 1)
    msg = str(e.value)
    assert "ExperimentSpec.data" in msg and "bogus" in msg
    assert "sigma" in msg                       # lists the valid keys
    with pytest.raises(SpecError, match="banana"):
        override_field(spec, "banana.sigma", 1)
    with pytest.raises(SpecError, match="cannot descend"):
        override_field(spec, "data.sigma.deeper", 1)
    with pytest.raises(SpecError, match="empty"):
        override_field(spec, "", 1)
    # a bad axis path fails at expand() time, before any run executes
    with pytest.raises(SpecError, match="wat"):
        SweepSpec(base=spec, grid={"data.wat": [1, 2]}).expand()


def test_axis_nesting_order_and_names():
    sw = SweepSpec(base=base_spec(), seeds=[0, 1],
                   schemes=["proposed", "no_gen"],
                   grid={"data.sigma": [0.5, 5.0]})
    names = [c.name for c in sw.expand()]
    # grid outermost, schemes next, seeds fastest
    assert names == [
        "000_sigma=0.5_scheme=proposed_seed=0",
        "001_sigma=0.5_scheme=proposed_seed=1",
        "002_sigma=0.5_scheme=no_gen_seed=0",
        "003_sigma=0.5_scheme=no_gen_seed=1",
        "004_sigma=5.0_scheme=proposed_seed=0",
        "005_sigma=5.0_scheme=proposed_seed=1",
        "006_sigma=5.0_scheme=no_gen_seed=0",
        "007_sigma=5.0_scheme=no_gen_seed=1",
    ]
    specs = [c.spec for c in sw.expand()]
    assert specs[0].data.sigma == 0.5 and specs[4].data.sigma == 5.0
    assert specs[2].scheme.name == "no_gen" and specs[3].run.seed == 1


def test_zip_axis_lockstep_and_mismatch():
    sw = SweepSpec(base=base_spec(),
                   zip={"wireless.e0": [2.0, 4.0],
                        "wireless.t0": [20.0, 40.0]})
    cells = sw.expand()
    assert len(cells) == 2                      # ONE composite axis
    assert cells[0].spec.wireless.e0 == 2.0
    assert cells[0].spec.wireless.t0 == 20.0
    assert cells[1].spec.wireless.e0 == 4.0
    assert cells[1].spec.wireless.t0 == 40.0
    with pytest.raises(SpecError, match="equal lengths"):
        SweepSpec(base=base_spec(),
                  zip={"wireless.e0": [1.0],
                       "wireless.t0": [1.0, 2.0]}).expand()


def test_empty_sweep_is_single_base_run():
    cells = SweepSpec(base=base_spec()).expand()
    assert len(cells) == 1
    assert cells[0].spec == base_spec()


# ---------------------------------------------------------------------------
# Execution: reuse accounting, streaming, bitwise parity with cli run
# ---------------------------------------------------------------------------

class RecordingSink(RunSink):
    """Asserts streaming: every write happens one-at-a-time as runs finish,
    with the result fully formed at write time."""

    def __init__(self):
        self.names, self.rounds_seen, self.closed = [], [], False

    def write(self, name, result):
        self.names.append(name)
        self.rounds_seen.append(len(result.history))

    def close(self):
        self.closed = True


@pytest.fixture(scope="module")
def sweep_result(tmp_path_factory):
    d = str(tmp_path_factory.mktemp("sweep"))
    sw = SweepSpec(base=base_spec(), seeds=[0, 1],
                   schemes=["proposed", "no_gen"])
    sink = JsonlDirSink(d)
    rec = RecordingSink()

    class Both(RunSink):
        def write(self, name, result):
            sink.write(name, result)
            rec.write(name, result)
            # STREAMING: the per-run file exists the moment the run ends
            assert os.path.exists(os.path.join(d, f"{name}.jsonl"))

        def close(self):
            sink.close()
            rec.close()

    n0 = build_environment.n_builds
    res = run_sweep(sw, sink=Both())
    return sw, res, sink, rec, d, build_environment.n_builds - n0


def test_sweep_reuses_environment_and_trainer(sweep_result):
    sw, res, sink, rec, d, env_delta = sweep_result
    assert len(res.results) == 4
    # ONE environment serves 2 schemes x 2 seeds (build-counter-asserted)
    assert res.n_env_builds == 1 and env_delta == 1
    # ONE trainer pool entry serves all 4 runs (reset between runs)
    assert res.n_trainer_builds == 1


def test_sweep_streams_results_incrementally(sweep_result):
    sw, res, sink, rec, d, _ = sweep_result
    assert rec.names == [c.name for c in res.cells]    # matrix order
    assert rec.rounds_seen == [ROUNDS] * 4             # fully formed
    assert rec.closed
    assert len(sink.paths) == 4
    with open(sink.index_path) as f:
        index = [json.loads(line) for line in f]
    assert [r["kind"] for r in index] == ["sweep_run"] * 4
    assert [r["name"] for r in index] == rec.names


def test_swept_run_bitwise_equals_standalone_cli_run(sweep_result, tmp_path):
    """Acceptance: every swept cell == the same spec run via `cli run`."""
    sw, res, sink, rec, d, _ = sweep_result
    cell = res.cells[3]                    # no_gen, seed 1: a reused-
    spec_path = cell.spec.save(str(tmp_path / "cell.json"))   # trainer run
    out = str(tmp_path / "solo.jsonl")
    assert cli.main(["run", spec_path, "--out", out]) == 0
    solo = RunResult.from_jsonl(out)
    swept = res.results[3]
    assert [m.train_loss for m in solo.history] == \
        [m.train_loss for m in swept.history]
    assert [m.test_accuracy for m in solo.history] == \
        [m.test_accuracy for m in swept.history]
    assert [m.cumulative_energy for m in solo.history] == \
        [m.cumulative_energy for m in swept.history]
    assert solo.summary == swept.summary


def test_sweep_jsonl_roundtrip_and_report_aggregation(sweep_result):
    report = pytest.importorskip("benchmarks.report")
    sw, res, sink, rec, d, _ = sweep_result
    paths = sorted(os.path.join(d, p) for p in os.listdir(d))
    table = report.runs_table(paths)
    assert "no_gen" in table and "proposed" in table
    # seed aggregation: 2 groups (one per scheme), each n=2, mean ± std
    rows = report.aggregate_runs(paths)
    assert [row["n"] for row in rows] == [2, 2]
    for row in rows:
        mean, std, n = row["final_accuracy"]
        assert n == 2 and np.isfinite(mean) and std >= 0.0
    agg = report.sweep_table(paths)
    assert "±" in agg and "| 2 |" in agg
    # the index file is skipped on ingest, not misparsed as a run
    assert all("sweep.jsonl" not in p or True for p in paths)
    assert len(report._parseable_runs(paths)) == 4


def test_cli_sweep_end_to_end(tmp_path, capsys):
    spec_path = base_spec().save(str(tmp_path / "base.json"))
    out_dir = str(tmp_path / "runs")
    assert cli.main(["sweep", spec_path, "--seeds", "0,1",
                     "--schemes", "proposed",
                     "--out-dir", out_dir]) == 0
    out = capsys.readouterr().out
    assert "sweep matrix: 2 run(s)" in out
    assert "environments built 1" in out
    files = sorted(os.listdir(out_dir))
    run_files = [f for f in files
                 if f not in ("sweep.jsonl", "sweep_manifest.json")]
    assert len(run_files) == 2
    assert "sweep.jsonl" in files
    assert "sweep_manifest.json" in files  # elastic-resume manifest (§12)
    r = RunResult.from_jsonl(os.path.join(out_dir, run_files[0]))
    assert r.summary["rounds_run"] == ROUNDS


def test_cli_sweep_expand_only_and_sweepspec_file(tmp_path, capsys):
    sw = SweepSpec(base=base_spec(), seeds=[0, 1, 2],
                   grid={"data.sigma": [0.5, 5.0]})
    path = sw.save(str(tmp_path / "sweep.json"))
    assert cli.main(["sweep", path, "--expand-only"]) == 0
    out = capsys.readouterr().out
    assert "sweep matrix: 6 run(s)" in out
    assert out.count("sigma=0.5") == 3 and out.count("seed=2") == 2


def test_build_trainer_reuse_rejects_mismatch():
    spec = base_spec()
    run = Experiment(spec).build()
    other = dataclasses.replace(
        spec, scheme=dataclasses.replace(spec.scheme, eta=0.2))
    with pytest.raises(ValueError, match="scheme.eta"):
        Experiment(other).build(env=run.env, trainer=run.trainer)


# ---------------------------------------------------------------------------
# Cell failure isolation (the robustness satellite): one crashing cell must
# not abandon the rest of the matrix
# ---------------------------------------------------------------------------

from repro.api import Callback  # noqa: E402


class FlakyOnce(Callback):
    """Raises on the first round it ever sees, then behaves — a transient
    failure --max-retries should absorb."""

    def __init__(self):
        self.fired = False

    def on_round_end(self, m, trainer):
        if not self.fired:
            self.fired = True
            raise RuntimeError("transient glitch")


def test_sweep_cell_failure_isolated(tmp_path):
    d = str(tmp_path / "runs")
    # model axis outermost: cells 0-1 are valid, cells 2-3 hit an unknown
    # registry key at build time
    sw = SweepSpec(base=base_spec(), seeds=[0, 1],
                   grid={"model.name": ["mlp-edge", "wat"]})
    res = run_sweep(sw, sink=JsonlDirSink(d))
    assert len(res.results) == 4                  # positions preserved
    assert res.results[0] is not None and res.results[1] is not None
    assert res.results[2] is None and res.results[3] is None
    assert [e["name"] for e in res.errors] == \
        [res.cells[2].name, res.cells[3].name]
    assert all("KeyError" in e["error"] and "wat" in e["error"]
               for e in res.errors)
    assert all("Traceback" in e["traceback"] for e in res.errors)
    # summary_rows silently covers only the completed cells
    assert len(res.summary_rows()) == 2
    # the index records both outcomes, in matrix order
    with open(os.path.join(d, "sweep.jsonl")) as f:
        index = [json.loads(line) for line in f]
    assert [r["kind"] for r in index] == \
        ["sweep_run", "sweep_run", "sweep_error", "sweep_error"]
    assert index[2]["name"] == res.cells[2].name
    assert "wat" in index[2]["error"] and "Traceback" in index[2]["traceback"]
    assert index[2]["spec"]["model"]["name"] == "wat"


def test_sweep_max_retries_absorbs_transient_failure():
    sw = SweepSpec(base=base_spec(), seeds=[0, 1])
    oracle = run_sweep(sw)

    # without retries the glitched first cell is recorded, second still runs
    res0 = run_sweep(sw, callbacks=[FlakyOnce()])
    assert res0.results[0] is None and res0.results[1] is not None
    assert len(res0.errors) == 1 and "transient glitch" in res0.errors[0]["error"]

    # with one retry the glitch is absorbed; the retried cell's trainer was
    # evicted mid-round, so the rebuild must reproduce the clean run exactly
    res1 = run_sweep(sw, callbacks=[FlakyOnce()], max_retries=1)
    assert res1.errors == [] and all(r is not None for r in res1.results)
    assert res1.n_trainer_builds == 2             # fresh build after eviction
    for a, b in zip(oracle.results, res1.results):
        assert [m.train_loss for m in a.history] == \
            [m.train_loss for m in b.history]


def test_sweep_keyboard_interrupt_still_aborts():
    class Interrupt(Callback):
        def on_round_end(self, m, trainer):
            raise KeyboardInterrupt

    with pytest.raises(KeyboardInterrupt):
        run_sweep(SweepSpec(base=base_spec()), callbacks=[Interrupt()],
                  max_retries=5)


def test_cli_sweep_failed_cell_exits_nonzero(tmp_path, capsys):
    spec_path = base_spec().save(str(tmp_path / "base.json"))
    out_dir = str(tmp_path / "runs")
    rc = cli.main(["sweep", spec_path, "--grid", "model.name=mlp-edge,wat",
                   "--out-dir", out_dir, "--max-retries", "1"])
    assert rc == 1
    cap = capsys.readouterr()
    assert "FAILED" in cap.err and "wat" in cap.err
    assert "1 cell(s) failed" in cap.err
    # the surviving cell's artifacts are still on disk next to the record
    files = os.listdir(out_dir)
    assert "sweep.jsonl" in files and "sweep_manifest.json" in files
    assert len(files) == 3
