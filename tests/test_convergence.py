"""Theorem 1: the theta bound and its structural properties."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.convergence import BoundConstants, theta, theta_decomposition, round_term


def _c(**kw):
    return BoundConstants(rounds_S=4, batch_Z=16, **kw)


def test_constants_match_paper_formulas():
    c = BoundConstants(lipschitz_L=3.0, grad_bound_A2=7.0, model_bound_B2=2.0,
                       loss_gap=5.0, eta=0.05, batch_Z=8, rounds_S=9)
    sp1 = 10
    assert c.alpha == pytest.approx(2 * 5.0 / (0.05 * sp1))
    assert c.beta == pytest.approx(0.05**3 * 7.0 * 4.0 / (8 * sp1))
    assert c.gamma1 == pytest.approx(0.05 * 7.0 / (8 * sp1))
    assert c.gamma2 == pytest.approx(9.0 * 2.0 / sp1)


def test_theta_decomposition_sums_to_total():
    c = _c()
    rng = np.random.default_rng(0)
    n, s = 6, c.rounds_S + 1
    a = (rng.random((s, n)) > 0.3).astype(float)
    a[:, 0] = 1  # ensure nonempty rounds
    lam = rng.uniform(0, 0.5, (s, n))
    phi = rng.uniform(0, 3, n)
    d = theta_decomposition(a, lam, phi, c)
    assert d["total"] == pytest.approx(theta(a, lam, phi, c), rel=1e-9)


def test_theta_monotone_in_pruning():
    """More pruning => larger bound (gamma2 term), all else equal."""
    c = _c()
    n, s = 4, c.rounds_S + 1
    a = np.ones((s, n))
    phi = np.ones(n)
    t_low = theta(a, 0.1 * np.ones((s, n)), phi, c)
    t_high = theta(a, 0.5 * np.ones((s, n)), phi, c)
    assert t_high > t_low


def test_theta_prefers_low_phi_clients():
    c = _c()
    n, s = 2, c.rounds_S + 1
    lam = np.zeros((s, n))
    phi = np.array([0.1, 10.0])
    a_good = np.zeros((s, n)); a_good[:, 0] = 1
    a_bad = np.zeros((s, n)); a_bad[:, 1] = 1
    assert theta(a_good, lam, phi, c) < theta(a_bad, lam, phi, c)


def test_empty_round_is_infinite():
    c = _c()
    assert round_term(np.zeros(3), np.zeros(3), np.ones(3), c) == np.inf


@settings(max_examples=60, deadline=None)
@given(st.integers(1, 8), st.integers(0, 6), st.integers(0, 99999))
def test_theta_finite_and_above_alpha(n, s_rounds, seed):
    c = BoundConstants(rounds_S=s_rounds, batch_Z=4)
    rng = np.random.default_rng(seed)
    s = s_rounds + 1
    a = np.ones((s, n))
    lam = rng.uniform(0, 0.7, (s, n))
    phi = rng.uniform(0, 5, n)
    t = theta(a, lam, phi, c)
    assert np.isfinite(t)
    assert t >= c.alpha  # every added term is nonnegative


@settings(max_examples=40, deadline=None)
@given(st.integers(2, 8), st.integers(0, 99999))
def test_more_clients_tighten_participation_term(n, seed):
    """With phi=0 and lam=0, theta strictly improves with more clients."""
    c = _c()
    s = c.rounds_S + 1
    lam = np.zeros((s, n))
    phi = np.zeros(n)
    a1 = np.zeros((s, n)); a1[:, 0] = 1
    t1 = theta(a1, lam, phi, c)
    t_all = theta(np.ones((s, n)), lam, phi, c)
    assert t_all < t1 or n == 1
