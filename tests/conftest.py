"""Test-session bootstrap.

Ensures `import repro` works without an installed package (prepends src/),
and falls back to the vendored hypothesis shim when the real package is not
installed (offline containers) so collection never fails on the import line.
"""
import os
import sys

_HERE = os.path.dirname(os.path.abspath(__file__))
_ROOT = os.path.dirname(_HERE)
_SRC = os.path.join(_ROOT, "src")

for p in (_SRC, _ROOT, _HERE):
    if p not in sys.path:
        sys.path.insert(0, p)

try:
    import hypothesis  # noqa: F401  (real package, preferred)
except ModuleNotFoundError:
    import _hypothesis_stub

    _hypothesis_stub.install()


def pytest_collection_modifyitems(items):
    """Tiering (pytest.ini): anything not explicitly marked `slow` is
    tier-1, so `-m tier1` == `-m "not slow"` and new tests are fast-tier
    by default."""
    import pytest

    for item in items:
        if "slow" not in item.keywords:
            item.add_marker(pytest.mark.tier1)
