"""Pruning: eq. (3)/(4) importance, masks, Assumption 4."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st
from scipy import stats

from repro.core import pruning


def _tree(seed=0, shapes=((8, 8), (16,), (4, 4, 4))):
    rng = np.random.default_rng(seed)
    return {f"w{i}": jnp.asarray(rng.normal(size=s), jnp.float32)
            for i, s in enumerate(shapes)}


def test_taylor_importance_formula():
    p = _tree(0)
    g = _tree(1)
    q = pruning.taylor_importance(p, g)
    for k in p:
        np.testing.assert_allclose(np.asarray(q[k]),
                                   (np.asarray(p[k]) * np.asarray(g[k]))**2)


def test_exact_importance_agrees_with_taylor_on_quadratic():
    """For a linear-gradient (quadratic) loss, first-order Taylor importance
    ranks parameters like the exact leave-one-out score."""
    rng = np.random.default_rng(3)
    a = jnp.asarray(rng.normal(size=(6,)), jnp.float32)
    params = {"w": jnp.asarray(rng.normal(size=(6,)), jnp.float32)}

    def loss_fn(p):
        return jnp.sum(a * p["w"]) + 0.001 * jnp.sum(p["w"]**2)

    g = jax.grad(loss_fn)(params)
    q_taylor = np.asarray(pruning.taylor_importance(params, g)["w"]).ravel()
    q_exact = np.asarray(pruning.exact_importance(loss_fn, params)["w"]).ravel()
    rho = stats.spearmanr(q_taylor, q_exact).statistic
    assert rho > 0.99


@pytest.mark.parametrize("lam", [0.0, 0.1, 0.25, 0.5, 0.9])
def test_mask_realizes_requested_ratio(lam):
    rng = np.random.default_rng(0)
    imp = {"a": jnp.asarray(rng.random((32, 32)), jnp.float32),
           "b": jnp.asarray(rng.random((128,)), jnp.float32)}
    masks = pruning.build_masks(imp, lam)
    realized = pruning.actual_ratio(masks)
    total = 32 * 32 + 128
    assert abs(realized - lam) <= 1.0 / total + 1e-9


def test_protected_tensors_never_pruned():
    imp = {"embed_table": jnp.zeros((8, 8)),     # zero importance but protected
           "attn_wq": jnp.ones((8, 8))}
    masks = pruning.build_masks(imp, 0.5)
    assert float(jnp.min(masks["embed_table"])) == 1.0
    assert float(jnp.sum(masks["attn_wq"] == 0)) > 0


def test_prunes_lowest_importance_first():
    imp = {"w": jnp.asarray(np.arange(100, dtype=np.float32))}
    masks = pruning.build_masks(imp, 0.3)
    m = np.asarray(masks["w"])
    assert (m[:30] == 0).all() and (m[30:] == 1).all()


def test_apply_masks_zeroes_and_preserves_dtype():
    p = _tree(0)
    masks = pruning.build_masks(pruning.taylor_importance(p, p), 0.4)
    pruned = pruning.apply_masks(p, masks)
    for k in p:
        assert pruned[k].dtype == p[k].dtype
        np.testing.assert_allclose(np.asarray(pruned[k]),
                                   np.asarray(p[k]) * np.asarray(masks[k]))


def test_assumption4_magnitude_pruning():
    """Pruning the smallest |w*g| with g ~ w direction: ||w - w~||^2 <=
    lam ||w||^2 (Assumption 4) holds when importance correlates with
    magnitude; verify statistically with g = w (importance = |w|^4)."""
    rng = np.random.default_rng(0)
    p = {"w": jnp.asarray(rng.normal(size=(4096,)), jnp.float32)}
    imp = pruning.taylor_importance(p, p)  # (w*w)^2 ranks by |w|
    for lam in (0.1, 0.3, 0.5):
        masks = pruning.build_masks(imp, lam)
        d2, n2 = pruning.pruning_distortion(p, masks)
        assert d2 <= lam * n2 + 1e-6


@settings(max_examples=40, deadline=None)
@given(st.floats(0.0, 0.9), st.integers(0, 10_000))
def test_mask_binary_and_ratio_property(lam, seed):
    rng = np.random.default_rng(seed)
    imp = {"x": jnp.asarray(rng.random((64, 16)), jnp.float32)}
    masks = pruning.build_masks(imp, lam)
    m = np.asarray(masks["x"])
    assert set(np.unique(m)).issubset({0.0, 1.0})
    assert abs(pruning.actual_ratio(masks) - lam) <= 1.0 / m.size + 1e-9
