"""Unified experiment API (repro.api, DESIGN.md §8).

Covers: spec dict/JSON round-tripping, registry error messages, the
spec-path-vs-hand-wiring bit-for-bit equivalence (the old quickstart
wiring IS the oracle), callback firing points, mid-run kill + bit-for-bit
resume (per-round and multi-round-block execution), RunResult JSONL
round-tripping, the CLI entry points, and the final_accuracy satellite.
"""
import dataclasses
import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.api import (
    Callback, DataSpec, Experiment, ExperimentSpec, ModelSpec, MODELS,
    DATASETS, SCHEMES, RunResult, RunSpec, SchemeSpec, SpecError,
    WirelessSpec, register_model, resume_from_checkpoint,
)
from repro.api import cli
from repro.core import (
    AOConfig, BoundConstants, ClientData, FederatedTrainer, phis, solve_p1,
)
from repro.data import make_dataset, partition_by_dirichlet
from repro.models import lenet_apply, lenet_init, make_eval_fn, make_loss_fn
from repro.wireless import ChannelModel, SystemParams

from _trainer_pair import make_schedule

N, SIGMA, ROUNDS, BATCH = 5, 5.0, 10, 8
E0 = T0 = 1e6  # non-binding budgets: every schedule round runs


def small_spec(model: str = "lenet", **run_kw) -> ExperimentSpec:
    return ExperimentSpec(
        data=DataSpec(dataset="synthetic-mnist", n_clients=N, sigma=SIGMA,
                      n_train=300, n_test=80, seed=0),
        model=ModelSpec(name=model),
        wireless=WirelessSpec(e0=E0, t0=T0, seed=0),
        scheme=SchemeSpec(name="proposed_exact", rounds=ROUNDS, eta=0.1,
                          batch=BATCH, ao={"outer_iters": 1}),
        run=RunSpec(seed=0, eval_every=5, **run_kw))


def params_equal(a, b) -> bool:
    return all(bool(jnp.all(x == y)) for x, y in
               zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


# ---------------------------------------------------------------------------
# Spec serialization
# ---------------------------------------------------------------------------

def test_spec_dict_roundtrip_identity():
    spec = small_spec(checkpoint_dir="/tmp/x", checkpoint_every=3)
    d = spec.to_dict()
    spec2 = ExperimentSpec.from_dict(d)
    assert spec2 == spec
    assert spec2.to_dict() == d          # dict -> spec -> dict identity
    # and the default-constructed spec too
    d0 = ExperimentSpec().to_dict()
    assert ExperimentSpec.from_dict(d0).to_dict() == d0


def test_spec_json_roundtrip():
    spec = small_spec()
    assert ExperimentSpec.from_json(spec.to_json()) == spec


def test_spec_file_roundtrip(tmp_path):
    spec = small_spec()
    path = spec.save(str(tmp_path / "spec.json"))
    assert ExperimentSpec.from_file(path) == spec


def test_spec_unknown_keys_raise_with_context():
    with pytest.raises(SpecError) as e:
        ExperimentSpec.from_dict({"data": {"n_cleints": 3}})
    msg = str(e.value)
    assert "n_cleints" in msg and "n_clients" in msg and ".data" in msg
    with pytest.raises(SpecError) as e:
        ExperimentSpec.from_dict({"banana": {}})
    assert "banana" in str(e.value) and "scheme" in str(e.value)
    with pytest.raises(SpecError):
        ExperimentSpec.from_dict({"data": "not-a-dict"})


# ---------------------------------------------------------------------------
# Registries
# ---------------------------------------------------------------------------

def test_registry_unknown_key_messages():
    for reg, known in ((MODELS, "lenet"), (DATASETS, "synthetic-mnist"),
                       (SCHEMES, "proposed")):
        with pytest.raises(KeyError) as e:
            reg.get("no-such-component")
        msg = str(e.value)
        assert "no-such-component" in msg and known in msg and reg.kind in msg
        assert known in reg and "no-such-component" not in reg


def test_registry_register_and_duplicate():
    @register_model("test-api-dummy")
    def _dummy(spec, dataset):
        return (lambda key: {"w": jnp.zeros((2, 2))},
                lambda p, x: x.reshape(x.shape[0], -1)[:, :2] @ p["w"])

    assert MODELS.get("test-api-dummy") is _dummy
    with pytest.raises(ValueError, match="already registered"):
        register_model("test-api-dummy", lambda s, d: None)
    register_model("test-api-dummy", _dummy, override=True)  # explicit wins


def test_scheme_registry_matches_legacy_scheme_config():
    common = pytest.importorskip("benchmarks.common")
    assert common.scheme_config("proposed") == AOConfig(
        outer_iters=3, selection_method="paper", phi_coupling="mean")
    assert common.scheme_config("proposed_exact") == AOConfig(outer_iters=3)
    # ao overrides win over the scheme definition
    ao = SCHEMES.get("proposed")(SchemeSpec(name="proposed",
                                            ao={"outer_iters": 1}))
    assert ao.outer_iters == 1 and ao.selection_method == "paper"


# ---------------------------------------------------------------------------
# Spec path == hand wiring (the old quickstart pipeline, bit for bit)
# ---------------------------------------------------------------------------

@pytest.fixture(scope="module")
def hand_wired():
    """The pre-API seven-step wiring, exactly as examples/quickstart.py
    spelled it out before PR 4 — the equivalence oracle."""
    ds = make_dataset("synthetic-mnist", n_train=300, n_test=80, seed=0)
    parts = partition_by_dirichlet(ds.y_train, N, SIGMA,
                                   rng=np.random.default_rng(0))
    clients = [ClientData(ds.x_train[i], ds.y_train[i]) for i in parts]
    test_hist = np.bincount(ds.y_test, minlength=10).astype(float)
    phi = phis(np.stack([c.label_histogram(10) for c in clients]),
               test_hist[None])
    sp = SystemParams.table1(N, dataset="mnist", batch_size=BATCH)
    ch = ChannelModel(N, seed=0)
    consts = BoundConstants(rounds_S=ROUNDS - 1, batch_Z=BATCH, eta=0.1)
    sched = solve_p1(phi, E0, T0, ch.uplink, ch.downlink, sp, consts,
                     AOConfig(outer_iters=1))
    trainer = FederatedTrainer(make_loss_fn(lenet_apply),
                               lenet_init(jax.random.key(0)), clients,
                               eta=0.1, batch_size=BATCH, seed=0)
    eval_fn = make_eval_fn(lenet_apply, ds.x_test, ds.y_test)
    hist = trainer.run(sched, sp, ch.uplink, ch.downlink, eval_fn=eval_fn,
                       eval_every=5, stop_delay=T0, stop_energy=E0)
    return sched, trainer, hist


@pytest.fixture(scope="module")
def api_result():
    run = Experiment(small_spec()).build()
    return run, run.run()


def test_spec_path_matches_hand_wiring_bitwise(hand_wired, api_result):
    sched_h, trainer_h, hist_h = hand_wired
    run, res = api_result
    # same solved schedule
    for field in ("a", "lam", "power", "freq"):
        assert np.array_equal(getattr(sched_h, field),
                              getattr(run.schedule, field)), field
    assert sched_h.theta == run.schedule.theta
    # same per-round trajectory, to the last bit
    assert [m.round for m in res.history] == [m.round for m in hist_h]
    assert [m.train_loss for m in res.history] == \
        [m.train_loss for m in hist_h]
    assert [m.test_loss for m in res.history] == \
        [m.test_loss for m in hist_h]
    assert [m.test_accuracy for m in res.history] == \
        [m.test_accuracy for m in hist_h]
    assert [m.cumulative_energy for m in res.history] == \
        [m.cumulative_energy for m in hist_h]
    # same final model, bitwise
    assert params_equal(trainer_h.params, run.trainer.params)
    assert params_equal(trainer_h.global_grad, run.trainer.global_grad)


# ---------------------------------------------------------------------------
# Callback firing points
# ---------------------------------------------------------------------------

class Recorder(Callback):
    def __init__(self):
        self.round_end, self.evals, self.blocks, self.ckpts = [], [], [], []

    def on_round_end(self, m, trainer):
        self.round_end.append(m.round)
        assert not np.isnan(m.train_loss) or not m.selected

    def on_eval(self, m, trainer):
        self.evals.append(m.round)
        assert m.test_accuracy is not None

    def on_block_end(self, start, n_rounds, trainer):
        self.blocks.append((start, n_rounds))

    def on_checkpoint(self, m, trainer):
        self.ckpts.append(m.round)


def test_callbacks_fire_at_materialization_points():
    rec = Recorder()
    rec.checkpoint_every = 3
    run = Experiment(small_spec("mlp-edge", rounds_per_dispatch=4)).build()
    run.run(callbacks=[rec])
    assert rec.round_end == list(range(ROUNDS))   # every round, in order
    assert rec.evals == [0, 5, ROUNDS - 1]        # eval cadence + last round
    assert rec.ckpts == [0, 3, 6, 9]
    # block dispatches cover disjoint in-order spans within the schedule
    covered = [s for start, k in rec.blocks for s in range(start, start + k)]
    assert covered == sorted(set(covered)) and len(covered) <= ROUNDS


def test_trainer_level_callbacks_reference_backend():
    """The callbacks= hook is a FederatedTrainer feature, not an API-layer
    one: it must work on the reference backend and without eval_fn."""
    rng = np.random.default_rng(0)
    clients = [ClientData(rng.normal(size=(12, 4, 4, 1)).astype(np.float32),
                          rng.integers(0, 3, size=12).astype(np.int32))
               for _ in range(3)]

    def apply_fn(p, x):
        return x.reshape(x.shape[0], -1) @ p["w"]

    params = {"w": jnp.asarray(rng.normal(size=(16, 3)).astype(np.float32))}
    rec = Recorder()
    rec.checkpoint_every = 2
    tr = FederatedTrainer(make_loss_fn(apply_fn), params, clients, eta=0.1,
                          batch_size=4, seed=0, backend="reference")
    sched = make_schedule(np.ones((5, 3)), 0.3)
    sp = SystemParams.table1(3)
    ch = ChannelModel(3)
    tr.run(sched, sp, ch.uplink, ch.downlink, callbacks=[rec])
    assert rec.round_end == [0, 1, 2, 3, 4]
    assert rec.ckpts == [0, 2, 4]
    assert rec.evals == [] and rec.blocks == []


# ---------------------------------------------------------------------------
# Kill / resume: bit-for-bit trajectory equality
# ---------------------------------------------------------------------------

class KillAt(Callback):
    """Simulates a mid-run crash right AFTER the checkpoint at `round` is
    written (the CheckpointCallback is ordered first)."""

    def __init__(self, round_, every):
        self.round_ = round_
        self.checkpoint_every = every

    def on_checkpoint(self, m, trainer):
        if m.round == self.round_:
            raise RuntimeError("simulated mid-run kill")


@pytest.mark.parametrize("rpd", [1, 4])
def test_kill_resume_bitwise(tmp_path, rpd):
    base = small_spec("mlp-edge", rounds_per_dispatch=rpd)
    # the uninterrupted oracle (no checkpointing at all)
    run_a = Experiment(base).build()
    res_a = run_a.run()
    assert res_a.summary["rounds_run"] == ROUNDS

    ckpt = str(tmp_path / f"ckpt_rpd{rpd}")
    spec = dataclasses.replace(
        base, run=dataclasses.replace(base.run, checkpoint_dir=ckpt,
                                      checkpoint_every=3))
    with pytest.raises(RuntimeError, match="simulated"):
        Experiment(spec).build().run(callbacks=[KillAt(3, 3)])

    # fresh process-equivalent: rebuild everything from the spec, restore
    run_b = Experiment(spec).build()
    res_b = run_b.resume(ckpt)
    assert res_b.summary["resumed_from"] == 3
    assert [m.round for m in res_b.history] == list(range(ROUNDS))

    # the resumed trajectory is EXACTLY the uninterrupted one (0.0 diff)
    for fld in ("train_loss", "test_loss", "test_accuracy",
                "cumulative_delay", "cumulative_energy", "selected"):
        assert [getattr(m, fld) for m in res_b.history] == \
            [getattr(m, fld) for m in res_a.history], fld
    assert params_equal(run_a.trainer.params, run_b.trainer.params)
    assert params_equal(run_a.trainer.global_grad, run_b.trainer.global_grad)
    diff = max(float(jnp.max(jnp.abs(x - y))) for x, y in zip(
        jax.tree_util.tree_leaves(run_a.trainer.params),
        jax.tree_util.tree_leaves(run_b.trainer.params)))
    assert diff == 0.0

    # resume_from_checkpoint rebuilds from the spec stored in the ckpt
    res_c = resume_from_checkpoint(ckpt, step=3)
    assert [m.train_loss for m in res_c.history] == \
        [m.train_loss for m in res_a.history]


def test_resume_restores_rng_and_counters(tmp_path):
    """The checkpoint carries the numpy RNG state and budget counters:
    a resumed trainer draws the SAME batch indices the uninterrupted one
    would, and the ledger continues seamlessly."""
    ckpt = str(tmp_path / "ckpt")
    base = small_spec("mlp-edge")
    spec = dataclasses.replace(
        base, run=dataclasses.replace(base.run, checkpoint_dir=ckpt,
                                      checkpoint_every=4))
    run_a = Experiment(spec).build()
    res_a = run_a.run()
    rng_after = run_a.trainer.rng.bit_generator.state

    run_b = Experiment(spec).build()
    res_b = run_b.resume(ckpt, step=4)
    assert res_b.summary["resumed_from"] == 4
    assert run_b.trainer.rng.bit_generator.state == rng_after
    assert [m.cumulative_energy for m in res_b.history] == \
        [m.cumulative_energy for m in res_a.history]


def test_resume_skips_truncated_checkpoint(tmp_path):
    """Crash-safety satellite: a truncated latest checkpoint (torn copy /
    pre-atomic write) is detected and resume falls back to the previous
    INTACT step — still reproducing the uninterrupted run bit-for-bit."""
    import glob

    from repro.checkpoint import CheckpointCorruptError

    ckpt = str(tmp_path / "ckpt")
    base = small_spec("mlp-edge")
    spec = dataclasses.replace(
        base, run=dataclasses.replace(base.run, checkpoint_dir=ckpt,
                                      checkpoint_every=3))
    res_a = Experiment(spec).run()

    # truncate the newest checkpoint npz (round 9)
    latest = sorted(glob.glob(f"{ckpt}/ckpt_*.npz"))[-1]
    assert "00000009" in latest
    with open(latest, "rb") as f:
        head = f.read(64)
    with open(latest, "wb") as f:
        f.write(head)

    # asking for the corrupt step explicitly surfaces the corruption
    with pytest.raises(CheckpointCorruptError, match="truncated"):
        resume_from_checkpoint(ckpt, step=9)

    # default resume lands on round 6, the newest intact step (and, being
    # checkpointed itself, atomically re-writes an intact round 9)
    res_b = resume_from_checkpoint(ckpt)
    assert res_b.summary["resumed_from"] == 6
    assert [m.train_loss for m in res_b.history] == \
        [m.train_loss for m in res_a.history]
    res_c = resume_from_checkpoint(ckpt)          # the repair took
    assert res_c.summary["resumed_from"] == 9


# ---------------------------------------------------------------------------
# RunResult JSONL
# ---------------------------------------------------------------------------

def test_runresult_jsonl_roundtrip(tmp_path, api_result):
    _, res = api_result
    path = str(tmp_path / "run.jsonl")
    res.to_jsonl(path)
    back = RunResult.from_jsonl(path)
    assert back.spec == res.spec
    assert back.summary == res.summary
    assert len(back.history) == len(res.history)
    assert [dataclasses.asdict(m) for m in back.history] == \
        [dataclasses.asdict(m) for m in res.history]
    # every line is valid standalone JSON with a kind tag
    with open(path) as f:
        kinds = [json.loads(line)["kind"] for line in f]
    assert kinds[0] == "experiment" and set(kinds[1:]) == {"round"}


def test_jsonl_is_strict_json(tmp_path, api_result):
    """Non-finite floats must export as null, not bare NaN tokens."""
    run, res = api_result
    broke = RunResult(spec=res.spec,
                      summary={**res.summary, "final_accuracy": float("nan")},
                      history=res.history)
    path = str(tmp_path / "nan.jsonl")
    broke.to_jsonl(path)
    with open(path) as f:
        for line in f:
            assert "NaN" not in line
            json.loads(line)   # every line parses strictly
    back = RunResult.from_jsonl(path)
    assert back.summary["final_accuracy"] is None


def test_env_reuse_rejects_mismatched_axes(api_result):
    run, _ = api_result
    other = dataclasses.replace(
        run.spec, scheme=dataclasses.replace(run.spec.scheme, batch=16))
    with pytest.raises(ValueError, match="scheme.batch"):
        Experiment(other).build(env=run.env)
    # budgets MAY vary across a reused environment (the scheme sweep does)
    budgets = dataclasses.replace(
        run.spec, wireless=dataclasses.replace(run.spec.wireless, e0=123.0))
    Experiment(budgets).build(env=run.env)


def test_checkpoint_dir_alone_defaults_cadence(tmp_path):
    """A checkpoint_dir without checkpoint_every still checkpoints (at
    the eval cadence) — the CLI --checkpoint-dir flag relies on this."""
    ckpt = str(tmp_path / "ckpt")
    spec = small_spec("mlp-edge", checkpoint_dir=ckpt)
    run = Experiment(spec).build()
    run.run()
    from repro.api import load_run_state
    step, extra = load_run_state(ckpt)
    assert step == ROUNDS - 1 or step % spec.run.eval_every == 0
    assert extra["round"] == step


def test_raising_hook_clears_trainer_callbacks(tmp_path):
    ckpt = str(tmp_path / "ckpt")
    spec = small_spec("mlp-edge", checkpoint_dir=ckpt, checkpoint_every=3)
    run = Experiment(spec).build()
    with pytest.raises(RuntimeError):
        run.run(callbacks=[KillAt(3, 3)])
    assert run.trainer._callbacks == ()


def test_report_ingests_runresult(tmp_path, api_result):
    report = pytest.importorskip("benchmarks.report")
    _, res = api_result
    path = str(tmp_path / "run.jsonl")
    res.to_jsonl(path)
    table = report.runs_table([path])
    assert "synthetic-mnist" in table and "proposed_exact" in table
    assert f"{res.summary['final_accuracy']:.3f}" in table


# ---------------------------------------------------------------------------
# CLI
# ---------------------------------------------------------------------------

def test_cli_run_validate_resume(tmp_path, capsys):
    spec = small_spec("mlp-edge")
    spec = dataclasses.replace(
        spec, scheme=dataclasses.replace(spec.scheme, rounds=4),
        run=dataclasses.replace(spec.run, eval_every=2, checkpoint_every=2))
    spec_path = spec.save(str(tmp_path / "spec.json"))
    ckpt = str(tmp_path / "ckpt")
    out1, out2 = str(tmp_path / "run.jsonl"), str(tmp_path / "res.jsonl")

    assert cli.main(["validate", spec_path]) == 0
    assert cli.main(["run", spec_path, "--out", out1,
                     "--checkpoint-dir", ckpt]) == 0
    assert cli.main(["resume", ckpt, "--out", out2]) == 0
    capsys.readouterr()

    full = RunResult.from_jsonl(out1)
    resumed = RunResult.from_jsonl(out2)
    assert full.summary["rounds_run"] == 4
    assert resumed.summary["resumed_from"] == 2   # latest ckpt: round 2
    assert [m.train_loss for m in resumed.history] == \
        [m.train_loss for m in full.history]


def test_cli_validate_catches_unknown_component(tmp_path):
    bad = small_spec()
    bad = dataclasses.replace(bad, model=ModelSpec(name="wat"))
    path = bad.save(str(tmp_path / "bad.json"))
    with pytest.raises(KeyError, match="unknown model 'wat'"):
        cli.main(["validate", path])


# ---------------------------------------------------------------------------
# final_accuracy satellite
# ---------------------------------------------------------------------------

def test_final_accuracy_tolerates_empty_and_reports_round(api_result):
    common = pytest.importorskip("benchmarks.common")
    for empty in ([], None):
        acc, rnd = common.final_accuracy(empty)
        assert np.isnan(acc) and rnd == -1
    _, res = api_result
    acc, rnd = common.final_accuracy(res.history)
    assert acc == res.summary["final_accuracy"]
    assert rnd == res.summary["final_accuracy_round"] == ROUNDS - 1
    # never-evaluated history: still (nan, -1), no raise
    no_eval = [m for m in res.history if m.test_accuracy is None]
    acc, rnd = common.final_accuracy(no_eval)
    assert np.isnan(acc) and rnd == -1
