"""LM packing pipeline: packing invariants, determinism, host disjointness."""
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.data.lm_pipeline import (PackedLMIterator, ShardSpec,
                                    SyntheticDocumentSource, pack_documents)

VOCAB = 1000


def _iter(host=0, hosts=2, step=0, batch=4, seq=256, seed=0):
    it = PackedLMIterator(SyntheticDocumentSource(VOCAB, seed=seed),
                          ShardSpec(host, hosts), batch=batch, seq=seq)
    it.seek(step)
    return it


def test_pack_shapes_and_label_shift():
    src = SyntheticDocumentSource(VOCAB, mean_len=40, seed=0)
    pb = pack_documents((src.doc(i) for i in range(50)), 4, 128)
    assert pb.tokens.shape == pb.labels.shape == (4, 128)
    # labels are next-token within each row
    joint = np.zeros((4, 129), np.int32)
    joint[:, :128] = pb.tokens
    joint[:, 128] = 0  # unknown tail; check the prefix shift only
    np.testing.assert_array_equal(pb.labels[:, :-1], pb.tokens[:, 1:])


def test_segments_are_contiguous_and_positions_reset():
    src = SyntheticDocumentSource(VOCAB, mean_len=30, seed=1)
    pb = pack_documents((src.doc(i) for i in range(80)), 2, 256)
    for b in range(2):
        seg = pb.segment_ids[b]
        pos = pb.positions[b]
        # positions restart at each segment change
        for t in range(1, 256):
            if seg[t] != seg[t - 1]:
                assert pos[t] == 0 or seg[t] == 0
            elif seg[t] != 0:
                assert pos[t] == pos[t - 1] + 1
        # segments appear in increasing order, no interleaving
        nz = seg[seg > 0]
        assert (np.diff(nz) >= 0).all()


def test_iterator_deterministic_and_seekable():
    a = next(_iter(step=3))
    b = next(_iter(step=3))
    np.testing.assert_array_equal(a.tokens, b.tokens)
    it = _iter(step=0)
    for _ in range(3):
        next(it)
    c = next(it)
    np.testing.assert_array_equal(a.tokens, c.tokens)  # seek == advance


def test_hosts_disjoint_documents():
    src = SyntheticDocumentSource(VOCAB, seed=0)
    i0 = PackedLMIterator(src, ShardSpec(0, 2), batch=2, seq=128)
    i1 = PackedLMIterator(src, ShardSpec(1, 2), batch=2, seq=128)
    b0, b1 = next(i0), next(i1)
    assert not np.array_equal(b0.tokens, b1.tokens)


@settings(max_examples=20, deadline=None)
@given(st.integers(1, 4), st.sampled_from([64, 128, 256]), st.integers(0, 50))
def test_packing_property(batch, seq, step):
    it = _iter(batch=batch, seq=seq, step=step, hosts=3, host=step % 3)
    pb = next(it)
    assert pb.tokens.shape == (batch, seq)
    # padding (segment 0) only at row tails
    for b in range(batch):
        seg = pb.segment_ids[b]
        if (seg == 0).any():
            first0 = int(np.argmax(seg == 0))
            assert (seg[first0:] == 0).all()
