"""Fleet-scale cohort streaming (DESIGN.md §13).

Covers: the frozen per-client draw protocol (labels-only replay bitwise
equal to full generation), roster laziness/sizing, cohort build + remap
equivalence against a replicated ClientStore on randomized populations and
ragged sample counts (hypothesis, stub-compatible offline), trainer-level
streamed-vs-replicated bitwise parity through the experiment API (history
records AND final params — streamed summaries carry wall-clock counters so
summary bytes are deliberately NOT compared), kill/resume with streaming
active, the client-store budget policy (auto-mode resolution and the
actionable StoreBudgetError), and the `summary["fleet"]` only-when-active
contract. Under a forced-multi-device run (scripts/test.sh sets
XLA_FLAGS=--xla_force_host_platform_device_count=4) the same parity tests
exercise the sharded cohort path — client rows partitioned over the data
axis instead of replicated.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.api import (
    DataSpec, Experiment, ExperimentSpec, ModelSpec, RunSpec, SchemeSpec,
    WirelessSpec,
)
from repro.api.callbacks import Callback
from repro.core import (
    ClientStore, CohortStore, FederatedTrainer, StoreBudgetError,
    estimated_store_nbytes, solve_random,
)
from repro.data import make_fleet

POP, ROUNDS, BATCH = 24, 6, 8


def fleet_spec(mode: str = "auto", *, population: int = POP,
               rounds: int = ROUNDS, k: int = 5, **run_kw) -> ExperimentSpec:
    return ExperimentSpec(
        data=DataSpec(dataset="synthetic-fleet", n_clients=population,
                      n_train=24 * population, n_test=64, seed=5),
        model=ModelSpec(name="mlp-edge", kwargs={"hidden": 16}),
        wireless=WirelessSpec(e0=1e6, t0=1e6, seed=0),
        scheme=SchemeSpec(name="random_k", rounds=rounds, batch=BATCH,
                          ao={"k": k, "seed": 1}),
        run=RunSpec(seed=2, eval_every=3, stop_on_budget=False,
                    client_store=mode, **run_kw))


def history_records(res):
    """The bitwise parity payload: every numeric field of every round,
    via repr so float equality is exact — but never the summary (streamed
    summaries carry wall-clock stall counters)."""
    return [(m.round, repr(m.train_loss), tuple(int(i) for i in m.selected),
             repr(m.energy), repr(m.delay), repr(m.cumulative_energy),
             repr(m.cumulative_delay), repr(m.test_loss),
             repr(m.test_accuracy)) for m in res.history]


def params_equal(a, b) -> bool:
    return all(bool(jnp.all(x == y)) for x, y in
               zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)))


# ---------------------------------------------------------------------------
# Roster: frozen draw protocol, laziness, sizing
# ---------------------------------------------------------------------------

def test_roster_labels_replay_bitwise():
    ds = make_fleet(population=30, n_train=600, n_test=32, seed=3)
    r = ds.roster
    assert len(r) == 30 and len(r.counts) == 30
    for cid in (0, 7, 29):
        c = r[cid]
        assert len(c) == int(r.counts[cid])
        # labels-only replay draws the same stream prefix as generation
        assert np.array_equal(r.client_labels(cid), c.y)
    # sizing never materializes data, and matches the generic estimator
    assert r.store_nbytes() == estimated_store_nbytes(r)
    hists = r.label_histograms()
    assert hists.shape == (30, r.n_classes)
    assert np.array_equal(hists[7],
                          np.bincount(r[7].y, minlength=r.n_classes))


def test_roster_deterministic_and_cached():
    a = make_fleet(population=12, n_train=240, n_test=16, seed=9).roster
    b = make_fleet(population=12, n_train=240, n_test=16, seed=9).roster
    assert np.array_equal(a.counts, b.counts)
    assert np.array_equal(a[4].x, b[4].x) and np.array_equal(a[4].y, b[4].y)
    assert a[4] is a[4]                    # LRU hit returns the same object


def test_fleet_dataset_has_no_dense_train_split():
    ds = make_fleet(population=8, n_train=80, n_test=16, seed=0)
    with pytest.raises(AttributeError, match="virtual"):
        ds.x_train
    with pytest.raises(AttributeError, match="virtual"):
        ds.y_train


# ---------------------------------------------------------------------------
# Property-based: cohort rows are byte-copies of replicated-store rows
# ---------------------------------------------------------------------------

@settings(max_examples=8, deadline=None)
@given(st.integers(min_value=6, max_value=40),
       st.integers(min_value=1, max_value=5),
       st.integers(min_value=0, max_value=1000))
def test_cohort_rows_match_replicated_store(population, k_rounds, seed):
    ds = make_fleet(population=population, n_train=8 * population,
                    n_test=8, seed=seed % 17)
    roster = ds.roster
    rng = np.random.default_rng(seed)
    # a trainer-shaped block plan: per-round selections, rows padded by
    # replicating the round's last real client (exactly _block_cids)
    c_real = [int(rng.integers(1, population + 1)) for _ in range(k_rounds)]
    c_max = max(c_real)
    cids = np.empty((k_rounds, c_max), np.int32)
    for k, c in enumerate(c_real):
        sel = np.sort(rng.choice(population, size=c, replace=False))
        cids[k, :c] = sel
        cids[k, c:] = sel[-1]
    store = CohortStore(roster, max_clients=population)
    store.schedule([(0, cids, np.asarray(c_real))])
    cohort = store.acquire(0)
    local = cohort.remap(cids)
    xs = np.asarray(cohort.x)
    ys = np.asarray(cohort.y)
    for k in range(k_rounds):
        for j in range(c_max):
            gid, lid = int(cids[k, j]), int(local[k, j])
            c = roster[gid]
            n = len(c)
            assert cohort.counts[lid] == n
            assert np.array_equal(xs[lid, :n], c.x)     # byte-copy rows
            assert np.array_equal(ys[lid, :n], c.y)
    # peak device bytes track the cohort, not the population
    rep = ClientStore.build(list(roster))
    assert cohort.nbytes <= int(rep.x.nbytes + rep.y.nbytes)
    assert store.counters["h2d_bytes"] == cohort.nbytes
    assert store.counters["n_cohort_swaps"] == 1
    store.close()


def test_vectorized_client_store_build_matches_rows():
    roster = make_fleet(population=10, n_train=150, n_test=8, seed=4).roster
    store = ClientStore.build(list(roster))
    for cid in range(10):
        c = roster[cid]
        n = len(c)
        assert np.array_equal(np.asarray(store.x)[cid, :n], c.x)
        assert np.array_equal(np.asarray(store.y)[cid, :n], c.y)
        assert not np.asarray(store.x)[cid, n:].any()   # zero padding rows


# ---------------------------------------------------------------------------
# Trainer-level parity: streamed bitwise equal to replicated
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("rpd", [2, 4])
def test_streamed_parity_bitwise(rpd):
    run_rep = Experiment(
        fleet_spec("replicated", rounds_per_dispatch=rpd)).build()
    res_rep = run_rep.run()
    run_str = Experiment(
        fleet_spec("streamed", rounds_per_dispatch=rpd)).build()
    res_str = run_str.run()
    assert res_rep.summary["rounds_run"] == ROUNDS
    assert history_records(res_rep) == history_records(res_str)
    assert params_equal(run_rep.trainer.params, run_str.trainer.params)
    # observability: counters only where streaming was active
    assert "fleet" not in res_rep.summary
    fleet = res_str.summary["fleet"]
    assert fleet["n_cohort_swaps"] >= 1
    assert fleet["h2d_bytes"] > 0 and fleet["peak_cohort_bytes"] > 0
    # at most two cohorts (current + prefetching) ever device-resident,
    # so with >= 2 swaps the peak is bounded by the total H2D traffic
    if fleet["n_cohort_swaps"] >= 2:
        assert fleet["peak_cohort_bytes"] <= fleet["h2d_bytes"]


def test_streamed_parity_with_faults_and_eval():
    def with_faults(mode):
        s = fleet_spec(mode, rounds_per_dispatch=3)
        return dataclasses.replace(s, wireless=dataclasses.replace(
            s.wireless, fault_model="dropout", fault_kwargs={"rate": 0.3}))
    res_rep = Experiment(with_faults("replicated")).build().run()
    res_str = Experiment(with_faults("streamed")).build().run()
    assert history_records(res_rep) == history_records(res_str)
    assert res_rep.summary["faults"] == res_str.summary["faults"]


# ---------------------------------------------------------------------------
# Kill / resume with streaming on: bit-for-bit continuation
# ---------------------------------------------------------------------------

class KillAt(Callback):
    def __init__(self, round_, every):
        self.round_ = round_
        self.checkpoint_every = every

    def on_checkpoint(self, m, trainer):
        if m.round == self.round_:
            raise RuntimeError("simulated mid-run kill")


def test_streamed_kill_resume_bitwise(tmp_path):
    base = fleet_spec("streamed", rounds_per_dispatch=2)
    res_a = Experiment(base).build().run()    # uninterrupted oracle

    ckpt = str(tmp_path / "ckpt")
    spec = dataclasses.replace(base, run=dataclasses.replace(
        base.run, checkpoint_dir=ckpt, checkpoint_every=2))
    with pytest.raises(RuntimeError, match="simulated"):
        Experiment(spec).build().run(callbacks=[KillAt(2, 2)])
    res_b = Experiment(spec).build().resume(ckpt)
    assert res_b.summary["resumed_from"] == 2
    assert history_records(res_a) == history_records(res_b)
    # the resumed leg streams too — same cohort schedule, fewer swaps
    assert res_b.summary["fleet"]["n_cohort_swaps"] >= 1


# ---------------------------------------------------------------------------
# Budget policy: auto resolution + the actionable OOM guard
# ---------------------------------------------------------------------------

def test_auto_mode_resolves_on_budget():
    run = Experiment(fleet_spec("auto", rounds_per_dispatch=2)).build()
    tr = run.trainer
    assert tr.store_mode() == "replicated"    # tiny roster fits 1 GiB
    tr2 = Experiment(fleet_spec(
        "auto", rounds_per_dispatch=2,
        device_mem_budget=1024)).build().trainer
    assert tr2.store_mode() == "streamed"     # forced under a 1 KiB budget
    res = Experiment(fleet_spec(
        "auto", rounds_per_dispatch=2,
        device_mem_budget=1024)).build().run()
    assert "fleet" in res.summary             # auto actually streamed


def test_store_budget_error_is_actionable():
    with pytest.raises(StoreBudgetError) as ei:
        Experiment(fleet_spec("replicated", rounds_per_dispatch=2,
                              device_mem_budget=1024)).build()
    msg = str(ei.value)
    assert str(POP) in msg                    # names the population
    assert "client_store" in msg and "streamed" in msg
    assert "REPRO_DEVICE_MEM_BUDGET" in msg


def test_trainer_rejects_unknown_store_mode():
    roster = make_fleet(population=4, n_train=40, n_test=8, seed=0).roster
    with pytest.raises(ValueError, match="client_store"):
        FederatedTrainer(lambda p, x, y: 0.0, {"w": jnp.zeros(3)}, roster,
                         eta=0.1, batch_size=4, client_store="sometimes")


def test_data_selection_rejected_on_roster():
    spec = fleet_spec("streamed", rounds_per_dispatch=2)
    spec = dataclasses.replace(spec, scheme=dataclasses.replace(
        spec.scheme, data_selection="threshold"))
    with pytest.raises(ValueError, match="roster"):
        Experiment(spec).build()


# ---------------------------------------------------------------------------
# random_k: the fleet-feasible baseline scheme
# ---------------------------------------------------------------------------

def test_solve_random_schedule_shape_and_determinism():
    n, s = 50, 7
    phi = np.full(n, 0.1)
    from repro.core import BoundConstants
    from repro.wireless import ChannelModel, SystemParams
    sp = SystemParams.table1(n)
    ch = ChannelModel(n, seed=0)
    consts = BoundConstants(rounds_S=s, batch_Z=BATCH, eta=0.1)
    a = solve_random(phi, 1e6, 1e6, ch.uplink, ch.downlink, sp, consts,
                     k=5, seed=3)
    b = solve_random(phi, 1e6, 1e6, ch.uplink, ch.downlink, sp, consts,
                     k=5, seed=3)
    assert a.a.shape == (s + 1, n)
    assert (a.a.sum(axis=1) == 5).all()
    assert np.array_equal(a.a, b.a) and a.feasible
