"""End-to-end system tests, including the dry-run path on a tiny host mesh.

The production 16x16 / 2x16x16 dry-runs run via
`python -m repro.launch.dryrun` (they need 512 forced host devices at
process start); here the SAME code path is exercised end-to-end on an 8-device
mesh in a subprocess, per architecture family.
"""
import json
import os
import subprocess
import sys
import textwrap

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run_sub(code: str, *, devices: int = 8, mesh: str = "4,2") -> str:
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={devices}"
    env["REPRO_FORCE_MESH"] = mesh
    env["PYTHONPATH"] = os.path.join(REPO, "src")
    out = subprocess.run([sys.executable, "-c", code], env=env,
                         capture_output=True, text=True, timeout=1200)
    assert out.returncode == 0, f"stderr:\n{out.stderr[-3000:]}"
    return out.stdout


@pytest.mark.parametrize("arch,shape_kind", [
    ("yi-9b", "train"),          # dense + FSDP
    ("mixtral-8x22b", "train"),  # MoE grouped dispatch
    ("mamba2-130m", "decode"),   # SSM state cache
    ("gemma2-9b", "prefill"),    # local/global + softcaps
    ("whisper-small", "decode"),  # enc-dec cross-attn cache
    ("llama-3.2-vision-90b", "train"),  # vlm groups
])
def test_dryrun_path_small_mesh(arch, shape_kind):
    """lower().compile() through the real dryrun code on a 4x2 mesh."""
    code = textwrap.dedent(f"""
        from repro.configs.registry import InputShape
        import repro.launch.dryrun as dr
        dr.INPUT_SHAPES = dict(dr.INPUT_SHAPES)
        dr.INPUT_SHAPES["tiny"] = InputShape("tiny", 256, 8, "{shape_kind}")
        orig = dr.get_config
        dr.get_config = lambda n: orig(n).reduced(layers=2, d_model=256)
        lowered, meta = dr.lower_step("{arch}", "tiny")
        c = lowered.compile()
        cost = c.cost_analysis()
        assert cost.get("flops", 0) > 0
        stats = dr.collective_stats(c.as_text())
        print("OK", meta["mode"], int(cost["flops"]),
              int(stats["total_bytes"]))
    """)
    out = _run_sub(code)
    assert out.startswith("OK")


def test_sharded_train_step_matches_single_device():
    """The distributed train step computes the same loss as unsharded."""
    code = textwrap.dedent("""
        import jax, numpy as np, jax.numpy as jnp
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.configs.registry import get_config
        from repro.launch.steps import make_train_step
        from repro.launch.mesh import make_production_mesh
        from repro.sharding import rules
        from repro.models import init_params
        from repro.models.blocks import Runtime
        import dataclasses

        cfg = get_config("granite-3-2b").reduced(layers=2, d_model=256)
        cfg = dataclasses.replace(cfg, dtype="float32")
        rt = Runtime(attn_impl="naive")
        params = init_params(jax.random.key(0), cfg)
        masks = jax.tree.map(
            lambda w: jnp.ones(w.shape, jnp.uint8), params)
        rng = np.random.default_rng(0)
        batch = {
            "tokens": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 64)),
                                  jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab_size, (8, 64)),
                                  jnp.int32),
        }
        step = make_train_step(cfg, rt, microbatches=1)
        loss_ref, new_ref = jax.jit(step)(params, masks, batch)

        mesh = make_production_mesh()
        pol = rules.make_policy(cfg, mesh, "train")
        pshard = rules.param_shardings(cfg, pol)
        bshard = {k: NamedSharding(mesh, rules.batch_spec(8, pol))
                  for k in batch}
        with jax.sharding.set_mesh(mesh):
            jitted = jax.jit(step, in_shardings=(pshard, pshard, bshard),
                             out_shardings=(NamedSharding(mesh, P()), pshard))
            loss_sh, new_sh = jitted(params, masks, batch)
        np.testing.assert_allclose(float(loss_ref), float(loss_sh),
                                   rtol=2e-4)
        d = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(new_ref),
                                jax.tree.leaves(new_sh)))
        assert d < 5e-4, d
        print("OK", float(loss_ref), float(loss_sh), d)
    """)
    out = _run_sub(code)
    assert out.startswith("OK")


def test_dryrun_artifacts_exist_and_complete():
    """The production sweep left one record per (arch x shape x mesh)."""
    d = os.path.join(REPO, "experiments", "dryrun")
    if not os.path.isdir(d) or len(os.listdir(d)) < 80:
        pytest.skip("production dry-run sweep not yet executed")
    recs = []
    for fn in os.listdir(d):
        with open(os.path.join(d, fn)) as f:
            recs.append(json.load(f))
    assert len(recs) >= 80
    assert not [r for r in recs if r["status"] == "error"]
    ok = [r for r in recs if r["status"] == "ok"]
    # every ok record carries the roofline ingredients
    for r in ok:
        assert r["cost"].get("flops", 0) > 0
        assert "total_bytes" in r["collectives"]
        assert r["memory"]["temp_size_in_bytes"] >= 0
    # the 2-pod mesh must shard the pod axis: train memory should not grow
    by_key = {(r["arch"], r["shape"], r["mesh"]): r for r in ok}
    improved = total = 0
    for (arch, shape, mesh), r in by_key.items():
        if mesh != "16x16" or r["mode"] != "train":
            continue
        r2 = by_key.get((arch, shape, "2x16x16"))
        if r2:
            total += 1
            if r2["memory"]["temp_size_in_bytes"] <= \
                    r["memory"]["temp_size_in_bytes"] * 1.05:
                improved += 1
    assert total == 0 or improved >= total * 0.8
