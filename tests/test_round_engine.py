"""Device-resident round engine: API, kernel impl parity, client-axis
strategies, and the perf harness itself."""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import ClientData, FederatedTrainer, ParamPack, RoundEngine
from repro.data import make_dataset, partition_by_dirichlet
from repro.kernels import ops
from repro.models import lenet_init, lenet_apply, make_loss_fn


@pytest.fixture(scope="module")
def env():
    ds = make_dataset("synthetic-mnist", n_train=300, n_test=100, seed=1)
    parts = partition_by_dirichlet(ds.y_train, 3, sigma=1.0,
                                   rng=np.random.default_rng(1))
    clients = [ClientData(ds.x_train[i], ds.y_train[i]) for i in parts]
    params = lenet_init(jax.random.key(1))
    loss_fn = make_loss_fn(lenet_apply)
    return clients, params, loss_fn


def _batches(clients, batch, seed=0):
    rng = np.random.default_rng(seed)
    xs, ys = [], []
    for c in clients:
        idx = rng.choice(len(c), size=batch, replace=len(c) < batch)
        xs.append(c.x[idx])
        ys.append(c.y[idx])
    return jnp.asarray(np.stack(xs)), jnp.asarray(np.stack(ys))


def test_round_step_shapes_and_state(env):
    clients, params, loss_fn = env
    pack = ParamPack.build(params)
    eng = RoundEngine(loss_fn, pack, eta=0.1)
    w, v = eng.init_buffers(params)
    xs, ys = _batches(clients, 8)
    w2, v2, losses, thr, step = eng.round_step(w, v, xs, ys, np.full(3, 0.2))
    assert w2.shape == w.shape and v2.shape == w.shape
    assert losses.shape == (3,)
    assert np.isfinite(np.asarray(losses)).all()
    assert bool(jnp.any(w2 != w))          # the step moved the params
    # v starts at zero -> importance all zero -> update = plain FedSGD mean
    assert float(jnp.max(jnp.abs(v2))) > 0.0


def test_round_step_rejects_bad_lambda(env):
    clients, params, loss_fn = env
    pack = ParamPack.build(params)
    eng = RoundEngine(loss_fn, pack, eta=0.1)
    w, v = eng.init_buffers(params)
    xs, ys = _batches(clients, 4)
    with pytest.raises(ValueError):
        eng.round_step(w, v, xs, ys, np.full(3, 1.0))
    with pytest.raises(ValueError):
        eng.round_step(w, v, xs, ys, np.full(3, -0.1))


def test_kernel_impls_bitwise_equal(env):
    """interpret-mode Pallas kernels and the XLA mirror agree exactly."""
    _, params, loss_fn = env
    pack = ParamPack.build(params)
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(pack.rows, 128)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(pack.rows, 128)), jnp.float32)
    pr = jnp.asarray(pack.prunable_mask())
    thr = jnp.float32(0.2)
    q_p, m_p = ops.packed_importance_mask(w, v, pr, thr, impl="pallas")
    q_x, m_x = ops.packed_importance_mask(w, v, pr, thr, impl="xla")
    assert bool(jnp.all(q_p == q_x)) and bool(jnp.all(m_p == m_x))

    thrs = jnp.asarray([0.0, 0.2, 1.5], jnp.float32)
    qb_p, mb_p = ops.packed_importance_masks(w, v, pr, thrs, impl="pallas")
    qb_x, mb_x = ops.packed_importance_masks(w, v, pr, thrs, impl="xla")
    assert bool(jnp.all(qb_p == qb_x)) and bool(jnp.all(mb_p == mb_x))
    # batched kernel row c == single-threshold kernel at thresholds[c]
    for c, t in enumerate(np.asarray(thrs)):
        _, m_one = ops.packed_importance_mask(w, v, pr, jnp.float32(t),
                                              impl="pallas")
        assert bool(jnp.all(mb_p[c] == m_one))

    grads = jnp.asarray(rng.normal(size=(4, pack.rows, 128)), jnp.float32)
    w2_p, g_p, s_p = ops.packed_fedsgd_update(w, grads, 0.05, impl="pallas")
    w2_x, g_x, s_x = ops.packed_fedsgd_update(w, grads, 0.05, impl="xla")
    assert bool(jnp.all(g_p == g_x))
    assert bool(jnp.all(s_p == s_x))
    # the fused kernel may FMA-contract the final w - eta*g (skipping the
    # product rounding the fenced xla path performs): 1-ulp tolerance
    np.testing.assert_allclose(np.asarray(w2_p), np.asarray(w2_x),
                               rtol=1e-6, atol=1e-8)

    mask = (jnp.asarray(rng.random((pack.rows, 128))) > 0.5).astype(jnp.float32)
    u_p = ops.packed_masked_update(w, g_p, mask, 0.05, impl="pallas")
    u_x = ops.packed_masked_update(w, g_p, mask, 0.05, impl="xla")
    assert bool(jnp.all(u_p == u_x))


@pytest.mark.parametrize("axis", ["unroll", "scan", "vmap"])
def test_client_axis_strategies_agree(env, axis):
    clients, params, loss_fn = env
    pack = ParamPack.build(params)
    ref_eng = RoundEngine(loss_fn, pack, eta=0.1, client_axis="unroll")
    eng = RoundEngine(loss_fn, pack, eta=0.1, client_axis=axis)
    w, v = ref_eng.init_buffers(params)
    xs, ys = _batches(clients, 8)
    # warm v so pruning is active
    w1, v1, _, _, _ = ref_eng.round_step(w, v, xs, ys, np.full(3, 0.0))
    ref = ref_eng.round_step(w1, v1, xs, ys, np.full(3, 0.3))
    got = eng.round_step(w1, v1, xs, ys, np.full(3, 0.3))
    if axis == "vmap":
        # vmap batches the backward pass; ulp-level reassociation allowed
        np.testing.assert_allclose(np.asarray(got[0]), np.asarray(ref[0]),
                                   rtol=0, atol=1e-6)
    else:
        assert bool(jnp.all(got[0] == ref[0]))
        assert bool(jnp.all(got[1] == ref[1]))


def test_trainer_packed_state_roundtrip(env):
    """params / global_grad setters write through to the packed buffers."""
    clients, params, loss_fn = env
    tr = FederatedTrainer(loss_fn, params, clients, eta=0.1, batch_size=8,
                          seed=0, backend="packed")
    p0 = tr.params
    doubled = jax.tree.map(lambda x: 2.0 * x, p0)
    tr.params = doubled
    for a, b in zip(jax.tree_util.tree_leaves(tr.params),
                    jax.tree_util.tree_leaves(doubled)):
        assert bool(jnp.all(a == b))


# -- the perf harness itself -------------------------------------------------

def test_benchmark_harness_smoke(tmp_path):
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks import round_engine as bench

    out = tmp_path / "BENCH_round_engine.json"
    report = bench.run_benchmark(configs=[("lenet", 2, 8)],
                                 equiv_cfg=("lenet", 2, 8, 3),
                                 rounds=2, warmup=1, n_train=240,
                                 out_path=str(out))
    assert out.exists()
    (r,) = report["results"]
    assert r["reference_s_per_round"] > 0
    assert r["packed_s_per_round"] > 0
    assert r["speedup"] > 0
    assert report["equivalence"]["abs_diff"] <= 1e-5
