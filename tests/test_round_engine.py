"""Device-resident round engine: API, kernel impl parity, client-axis
strategies, bucketed/ragged/sharded rounds, and the perf harness itself.

The sharded tests need a multi-device host; scripts/test.sh reruns this
file under XLA_FLAGS=--xla_force_host_platform_device_count=4 (the sharded
smoke leg), which un-skips them and also exercises every other test here on
the mesh-parallel round path."""
import os
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _trainer_pair import (assert_trainers_bitwise, make_schedule,
                           run_pair)
from repro.core import ClientData, FederatedTrainer, ParamPack, RoundEngine
from repro.data import make_dataset, partition_by_dirichlet
from repro.kernels import ops
from repro.models import lenet_init, lenet_apply, make_loss_fn
from repro.wireless import ChannelModel, SystemParams

multidevice = pytest.mark.skipif(
    len(jax.devices()) < 2,
    reason="needs a multi-device host "
           "(XLA_FLAGS=--xla_force_host_platform_device_count=N)")


@pytest.fixture(scope="module")
def env():
    ds = make_dataset("synthetic-mnist", n_train=300, n_test=100, seed=1)
    parts = partition_by_dirichlet(ds.y_train, 3, sigma=1.0,
                                   rng=np.random.default_rng(1))
    clients = [ClientData(ds.x_train[i], ds.y_train[i]) for i in parts]
    params = lenet_init(jax.random.key(1))
    loss_fn = make_loss_fn(lenet_apply)
    return clients, params, loss_fn


def _batches(clients, batch, seed=0):
    rng = np.random.default_rng(seed)
    xs, ys = [], []
    for c in clients:
        idx = rng.choice(len(c), size=batch, replace=len(c) < batch)
        xs.append(c.x[idx])
        ys.append(c.y[idx])
    return jnp.asarray(np.stack(xs)), jnp.asarray(np.stack(ys))


def test_round_step_shapes_and_state(env):
    clients, params, loss_fn = env
    pack = ParamPack.build(params)
    eng = RoundEngine(loss_fn, pack, eta=0.1)
    w, v = eng.init_buffers(params)
    xs, ys = _batches(clients, 8)
    w2, v2, losses, thr, step = eng.round_step(w, v, xs, ys, np.full(3, 0.2))
    assert w2.shape == w.shape and v2.shape == w.shape
    assert losses.shape == (3,)
    assert np.isfinite(np.asarray(losses)).all()
    assert bool(jnp.any(w2 != w))          # the step moved the params
    # v starts at zero -> importance all zero -> update = plain FedSGD mean
    assert float(jnp.max(jnp.abs(v2))) > 0.0


def test_round_step_rejects_bad_lambda(env):
    clients, params, loss_fn = env
    pack = ParamPack.build(params)
    eng = RoundEngine(loss_fn, pack, eta=0.1)
    w, v = eng.init_buffers(params)
    xs, ys = _batches(clients, 4)
    with pytest.raises(ValueError):
        eng.round_step(w, v, xs, ys, np.full(3, 1.0))
    with pytest.raises(ValueError):
        eng.round_step(w, v, xs, ys, np.full(3, -0.1))


def test_kernel_impls_bitwise_equal(env):
    """interpret-mode Pallas kernels and the XLA mirror agree exactly."""
    _, params, loss_fn = env
    pack = ParamPack.build(params)
    rng = np.random.default_rng(0)
    w = jnp.asarray(rng.normal(size=(pack.rows, 128)), jnp.float32)
    v = jnp.asarray(rng.normal(size=(pack.rows, 128)), jnp.float32)
    pr = jnp.asarray(pack.prunable_mask())
    thr = jnp.float32(0.2)
    q_p, m_p = ops.packed_importance_mask(w, v, pr, thr, impl="pallas")
    q_x, m_x = ops.packed_importance_mask(w, v, pr, thr, impl="xla")
    assert bool(jnp.all(q_p == q_x)) and bool(jnp.all(m_p == m_x))

    thrs = jnp.asarray([0.0, 0.2, 1.5], jnp.float32)
    qb_p, mb_p = ops.packed_importance_masks(w, v, pr, thrs, impl="pallas")
    qb_x, mb_x = ops.packed_importance_masks(w, v, pr, thrs, impl="xla")
    assert bool(jnp.all(qb_p == qb_x)) and bool(jnp.all(mb_p == mb_x))
    # batched kernel row c == single-threshold kernel at thresholds[c]
    for c, t in enumerate(np.asarray(thrs)):
        _, m_one = ops.packed_importance_mask(w, v, pr, jnp.float32(t),
                                              impl="pallas")
        assert bool(jnp.all(mb_p[c] == m_one))

    grads = jnp.asarray(rng.normal(size=(4, pack.rows, 128)), jnp.float32)
    w2_p, g_p, s_p = ops.packed_fedsgd_update(w, grads, 0.05, impl="pallas")
    w2_x, g_x, s_x = ops.packed_fedsgd_update(w, grads, 0.05, impl="xla")
    assert bool(jnp.all(g_p == g_x))
    assert bool(jnp.all(s_p == s_x))
    # the fused kernel may FMA-contract the final w - eta*g (skipping the
    # product rounding the fenced xla path performs): 1-ulp tolerance
    np.testing.assert_allclose(np.asarray(w2_p), np.asarray(w2_x),
                               rtol=1e-6, atol=1e-8)

    mask = (jnp.asarray(rng.random((pack.rows, 128))) > 0.5).astype(jnp.float32)
    u_p = ops.packed_masked_update(w, g_p, mask, 0.05, impl="pallas")
    u_x = ops.packed_masked_update(w, g_p, mask, 0.05, impl="xla")
    assert bool(jnp.all(u_p == u_x))


@pytest.mark.parametrize("axis", ["unroll", "scan", "vmap"])
def test_client_axis_strategies_agree(env, axis):
    clients, params, loss_fn = env
    pack = ParamPack.build(params)
    ref_eng = RoundEngine(loss_fn, pack, eta=0.1, client_axis="unroll")
    eng = RoundEngine(loss_fn, pack, eta=0.1, client_axis=axis)
    w, v = ref_eng.init_buffers(params)
    xs, ys = _batches(clients, 8)
    # warm v so pruning is active
    w1, v1, _, _, _ = ref_eng.round_step(w, v, xs, ys, np.full(3, 0.0))
    ref = ref_eng.round_step(w1, v1, xs, ys, np.full(3, 0.3))
    got = eng.round_step(w1, v1, xs, ys, np.full(3, 0.3))
    if axis == "vmap":
        # vmap batches the backward pass; ulp-level reassociation allowed
        np.testing.assert_allclose(np.asarray(got[0]), np.asarray(ref[0]),
                                   rtol=0, atol=1e-6)
    else:
        assert bool(jnp.all(got[0] == ref[0]))
        assert bool(jnp.all(got[1] == ref[1]))


def test_trainer_packed_state_roundtrip(env):
    """params / global_grad setters write through to the packed buffers."""
    clients, params, loss_fn = env
    tr = FederatedTrainer(loss_fn, params, clients, eta=0.1, batch_size=8,
                          seed=0, backend="packed")
    p0 = tr.params
    doubled = jax.tree.map(lambda x: 2.0 * x, p0)
    tr.params = doubled
    for a, b in zip(jax.tree_util.tree_leaves(tr.params),
                    jax.tree_util.tree_leaves(doubled)):
        assert bool(jnp.all(a == b))


def test_weighted_aggregate_matches_unweighted_and_skips_padding(env):
    """The weighted kernel with 0/1 weights == unweighted kernel on the real
    prefix, for both impls — and zero-weight clients are skipped so even a
    NaN padding gradient cannot leak into the update."""
    _, params, _ = env
    pack = ParamPack.build(params)
    rng = np.random.default_rng(7)
    w = jnp.asarray(rng.normal(size=(pack.rows, 128)), jnp.float32)
    grads = jnp.asarray(rng.normal(size=(3, pack.rows, 128)), jnp.float32)
    ref = ops.packed_fedsgd_update(w, grads, 0.05, impl="xla")

    padded = jnp.concatenate(
        [grads, jnp.full((2, pack.rows, 128), jnp.nan, jnp.float32)])
    cw = jnp.asarray([1.0, 1.0, 1.0, 0.0, 0.0], jnp.float32)
    inv = np.float32(1.0 / 3)
    # the oracle step: eta times the *materialized* mean, exactly what the
    # eager reference trainer computes (the fence exists to preserve this
    # inside fused graphs; the legacy op's w2/step may differ by 1 ulp at
    # the op level because its trace-time-constant 1/C licenses a constant
    # reassociation the runtime inv blocks)
    eager_step = jnp.float32(0.05) * ref[1]
    for impl in ("xla", "pallas"):
        w2, g, step = ops.packed_fedsgd_update_weighted(
            w, padded, cw, inv, 0.05, impl=impl)
        assert bool(jnp.all(g == ref[1])), impl
        assert bool(jnp.all(step == eager_step)), impl
        np.testing.assert_allclose(np.asarray(w2), np.asarray(ref[0]),
                                   rtol=1e-6, atol=1e-8)
    # pallas and xla mirrors agree exactly on mean and step; w2 may differ
    # by 1 ulp (the fused kernel can FMA-contract the final w - step, same
    # caveat as the unweighted aggregate)
    outs = [ops.packed_fedsgd_update_weighted(w, padded, cw, inv, 0.05,
                                              impl=i) for i in ("xla", "pallas")]
    assert bool(jnp.all(outs[0][1] == outs[1][1]))
    assert bool(jnp.all(outs[0][2] == outs[1][2]))
    np.testing.assert_allclose(np.asarray(outs[0][0]), np.asarray(outs[1][0]),
                               rtol=1e-6, atol=1e-8)


def test_exponent_histogram_kernel_matches_xla(env):
    """The Pallas exponent-histogram kernel (per-block bin counts in VMEM
    scratch, no scatter-add) is bin-for-bin equal to the scatter-add
    mirror, and kth_smallest_threshold(coarse="histogram") gives the same
    threshold through either hist impl as the pure bisection."""
    _, params, _ = env
    pack = ParamPack.build(params)
    rng = np.random.default_rng(3)
    q = jnp.asarray(np.square(rng.normal(size=(pack.rows, 128))), jnp.float32)
    pr = jnp.asarray(pack.prunable_mask())
    h_x = ops.packed_exponent_histogram(q, pr, impl="xla")
    h_p = ops.packed_exponent_histogram(q, pr, impl="pallas")
    assert bool(jnp.all(h_x == h_p))
    assert int(h_x.sum()) == int(pr.sum())
    # zeros / tiny / huge importances land in the right bins
    q2 = q.at[0, 0].set(0.0).at[0, 1].set(1e-38).at[0, 2].set(3e38)
    assert bool(jnp.all(ops.packed_exponent_histogram(q2, pr, impl="xla")
                        == ops.packed_exponent_histogram(q2, pr,
                                                         impl="pallas")))
    from repro.core.round_engine import kth_smallest_threshold
    n_valid = int(pr.sum())
    for k in (0, 1, n_valid // 3, n_valid):
        kk = jnp.int32(k)
        t_ref = kth_smallest_threshold(q, pr, kk, coarse="bisect")
        for impl in ("xla", "pallas"):
            t = kth_smallest_threshold(q, pr, kk, coarse="histogram",
                                       hist_impl=impl)
            assert bool(t == t_ref), (k, impl)
    # vector k (per-client thresholds) through the kernel path
    ks = jnp.asarray([0, 5, n_valid // 2, n_valid], jnp.int32)
    t_ref = kth_smallest_threshold(q, pr, ks, coarse="bisect")
    t_pal = kth_smallest_threshold(q, pr, ks, coarse="histogram",
                                   hist_impl="pallas")
    assert bool(jnp.all(t_ref == t_pal))


# -- bucketed client axis: ragged batches + varying selection ----------------


def _hetero_env(sizes, seed=0):
    """Clients with the given sample counts (deliberately heterogeneous)."""
    ds = make_dataset("synthetic-mnist", n_train=sum(sizes),
                      n_test=60, seed=seed)
    off = np.cumsum([0] + list(sizes))
    clients = [ClientData(ds.x_train[a:b], ds.y_train[a:b])
               for a, b in zip(off, off[1:])]
    return clients, lenet_init(jax.random.key(seed)), make_loss_fn(lenet_apply)


def test_bucket_sizes_power_of_two_per_shard():
    clients, params, loss_fn = _hetero_env([40, 20])
    pack = ParamPack.build(params)
    eng = RoundEngine(loss_fn, pack, eta=0.1, shards=1)
    assert [eng.bucket_size(c) for c in (1, 2, 3, 5, 8, 9, 17)] == \
        [1, 2, 4, 8, 8, 16, 32]
    flat = RoundEngine(loss_fn, pack, eta=0.1, shards=1, bucket=False)
    assert [flat.bucket_size(c) for c in (1, 3, 7)] == [1, 3, 7]
    # shard-count multiples: per-shard counts are power-of-two padded
    eng.shards = 4          # formula check only (no 4-device mesh needed)
    assert [eng.bucket_size(c) for c in (1, 4, 5, 9, 17)] == \
        [4, 4, 8, 16, 32]
    # population cap: full participation never pads past the roster
    capped = RoundEngine(loss_fn, pack, eta=0.1, shards=1, max_clients=20)
    assert [capped.bucket_size(c) for c in (3, 10, 17, 20)] == [4, 16, 20, 20]
    capped.shards = 4
    assert capped.bucket_size(20) == 20 and capped.bucket_size(17) == 20


def test_ragged_clients_stay_packed_and_bitwise():
    """Clients smaller than the batch size run packed (no reference
    fallback) and match the reference trainer bit for bit."""
    clients, params, loss_fn = _hetero_env([60, 10, 7, 3])
    a = np.ones((6, 4))
    out = run_pair(clients, params, loss_fn, make_schedule(a, 0.3), shards=1)
    (tr_ref, h_ref), (tr_pk, h_pk) = out["reference"], out["packed"]
    assert tr_pk.n_fallback_rounds == 0
    for mr, mp in zip(h_ref, h_pk):
        assert mr.train_loss == mp.train_loss
    assert_trainers_bitwise(tr_ref, tr_pk)


def test_varying_selection_bounded_traces_and_bitwise():
    """solve_p1-style schedules select a different client count every round;
    the bucketed engine must compile at most one trace per bucket size and
    stay bit-for-bit equal to the reference loop — including ragged
    stragglers in the mix."""
    sizes = [60, 40, 30, 25, 20, 18, 10, 7, 3]   # last three ragged at B=16
    clients, params, loss_fn = _hetero_env(sizes)
    rng = np.random.default_rng(5)
    n, rounds = len(sizes), 50
    a = np.zeros((rounds, n))
    for s in range(rounds):
        sel = rng.choice(n, size=rng.integers(1, n + 1), replace=False)
        a[s, sel] = 1.0
    out = run_pair(clients, params, loss_fn, make_schedule(a, 0.3), shards=1)
    (tr_ref, h_ref), (tr_pk, h_pk) = out["reference"], out["packed"]
    assert tr_pk.n_fallback_rounds == 0
    eng = tr_pk.engine
    counts = {int(r.sum()) for r in a}
    assert eng.buckets_used == {eng.bucket_size(c) for c in counts}
    assert eng.n_traces <= len(eng.buckets_used)      # zero retrace storms
    for mr, mp in zip(h_ref, h_pk):
        assert mr.train_loss == mp.train_loss
    assert_trainers_bitwise(tr_ref, tr_pk)


def test_varying_selection_per_client_lambda_bounded_traces():
    """Same bound for the per-client-lambda (batched threshold) family."""
    sizes = [60, 40, 30, 20, 10]
    clients, params, loss_fn = _hetero_env(sizes)
    rng = np.random.default_rng(9)
    n, rounds = len(sizes), 12
    a = np.zeros((rounds, n))
    for s in range(rounds):
        sel = rng.choice(n, size=rng.integers(2, n + 1), replace=False)
        a[s, sel] = 1.0
    lam = np.broadcast_to(np.linspace(0.1, 0.5, n), a.shape)
    out = run_pair(clients, params, loss_fn, make_schedule(a, lam), shards=1)
    (tr_ref, _), (tr_pk, _) = out["reference"], out["packed"]
    assert tr_pk.n_fallback_rounds == 0
    assert tr_pk.engine.n_traces <= len(tr_pk.engine.buckets_used)
    assert_trainers_bitwise(tr_ref, tr_pk)


def test_packed_losses_stay_on_device(env):
    """S1: _round returns the per-client losses as a device array (no host
    sync inside the round loop); run() materializes them lazily."""
    clients, params, loss_fn = env
    tr = FederatedTrainer(loss_fn, params, clients, eta=0.1, batch_size=8,
                          seed=0, backend="packed", shards=1)
    losses, n_ok, ast = tr._round([0, 1, 2], np.full(3, 0.2))
    assert isinstance(losses, jax.Array)
    assert losses.shape == (3,)
    assert isinstance(n_ok, jax.Array)    # survivor count stays lazy too
    assert ast is None                    # no robust aggregator active
    sp = SystemParams.table1(3)
    ch = ChannelModel(3)
    hist = tr.run(make_schedule(np.ones((3, 3)), 0.2), sp, ch.uplink, ch.downlink)
    assert all(np.isfinite(m.train_loss) for m in hist)


# -- sharded client axis (multi-device host) ---------------------------------


@multidevice
def test_sharded_engine_first_round_matches_single_device(env):
    clients, params, loss_fn = env
    pack = ParamPack.build(params)
    eng1 = RoundEngine(loss_fn, pack, eta=0.1, shards=1)
    engn = RoundEngine(loss_fn, pack, eta=0.1)        # all local devices
    assert engn.mesh is not None and engn.shards == len(jax.devices())
    w, v = eng1.init_buffers(params)
    xs, ys = _batches(clients, 8)
    o1 = eng1.round_step(w, v, xs, ys, np.full(3, 0.2))
    on = engn.round_step(w, v, xs, ys, np.full(3, 0.2))
    # per-client forward/backward is identical math; only the cross-shard
    # reduction reassociates, so losses are exact and w within ~1 ulp
    assert bool(jnp.all(o1[2] == on[2]))
    assert float(jnp.max(jnp.abs(o1[3] - on[3]))) == 0.0   # same threshold
    np.testing.assert_allclose(np.asarray(o1[0]), np.asarray(on[0]),
                               rtol=1e-6, atol=1e-7)
    # per-client-lambda family on the sharded path
    m1 = eng1.round_step(o1[0], o1[1], xs, ys, np.asarray([0.0, 0.2, 0.5]))
    mn = engn.round_step(on[0], on[1], xs, ys, np.asarray([0.0, 0.2, 0.5]))
    np.testing.assert_allclose(np.asarray(m1[0]), np.asarray(mn[0]),
                               rtol=1e-6, atol=1e-7)


@multidevice
def test_sharded_trainer_trajectory_equivalent():
    """Auto-sharded trainer stays within ulp-level drift of the
    single-device packed trainer over a short run, ragged clients and
    varying selection included."""
    sizes = [60, 30, 20, 10, 7, 3]
    clients, params, loss_fn = _hetero_env(sizes)
    rng = np.random.default_rng(3)
    n, rounds = len(sizes), 6
    a = np.zeros((rounds, n))
    for s in range(rounds):
        sel = rng.choice(n, size=rng.integers(2, n + 1), replace=False)
        a[s, sel] = 1.0
    hists = {}
    trs = {}
    for shards in (1, None):                 # None = auto (all devices)
        tr = FederatedTrainer(loss_fn, params, clients, eta=0.1,
                              batch_size=16, seed=0, backend="packed",
                              shards=shards)
        sp = SystemParams.table1(n)
        ch = ChannelModel(n)
        hists[shards] = tr.run(make_schedule(a, 0.3), sp, ch.uplink, ch.downlink)
        trs[shards] = tr
    assert trs[None].engine.mesh is not None
    assert trs[None].n_fallback_rounds == 0
    for m1, mn in zip(hists[1], hists[None]):
        assert abs(m1.train_loss - mn.train_loss) < 1e-5
    for p1, pn in zip(jax.tree_util.tree_leaves(trs[1].params),
                      jax.tree_util.tree_leaves(trs[None].params)):
        np.testing.assert_allclose(np.asarray(p1), np.asarray(pn),
                                   rtol=1e-5, atol=1e-6)


# -- the perf harness itself -------------------------------------------------

def test_benchmark_compare_reports():
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks import round_engine as bench

    def rep(s_fast, s_slow):
        return {"meta": {"git_rev": "abc"}, "results": [
            {"model": "lenet", "n_clients": 4, "batch": 8,
             "packed_s_per_round": 0.1, "speedup": s_fast},
            {"model": "lenet", "n_clients": 8, "batch": 8,
             "packed_s_per_round": 0.2, "speedup": s_slow},
            {"model": "only-prev", "n_clients": 1, "batch": 1,
             "packed_s_per_round": 1.0, "speedup": 1.0}]}

    prev = rep(2.0, 2.0)
    cur = rep(2.2, 1.5)                       # one improved, one regressed
    cur["results"] = cur["results"][:2]       # dropped config is skipped
    rows = bench.compare_reports(prev, cur)
    assert len(rows) == 2
    assert not rows[0]["regressed"] and rows[0]["speedup_delta_pct"] > 0
    assert rows[1]["regressed"] and rows[1]["speedup_delta_pct"] < -10
    bench.print_compare(rows, prev["meta"])   # smoke the printer


def test_benchmark_harness_smoke(tmp_path):
    sys.path.insert(0, os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))))
    from benchmarks import round_engine as bench

    out = tmp_path / "BENCH_round_engine.json"
    report = bench.run_benchmark(configs=[("lenet", 2, 8)],
                                 equiv_cfg=("lenet", 2, 8, 3),
                                 rounds=2, warmup=1, n_train=240,
                                 out_path=str(out))
    assert out.exists()
    (r,) = report["results"]
    assert r["reference_s_per_round"] > 0
    assert r["packed_s_per_round"] > 0
    assert r["speedup"] > 0
    assert report["equivalence"]["abs_diff"] <= 1e-5
