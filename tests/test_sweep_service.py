"""Elastic sweep service (repro.api.sweep, DESIGN.md §12).

Covers the service guarantees layered over the PR-5 engine: worker-count
invariance (workers=1 vs workers=N yield bitwise-identical per-run JSONL
and identical summary_rows ordering), the sweep_manifest protocol
(atomic header, spec-hash verification, mismatch rejection without index
loss), kill-mid-sweep -> resume -> bitwise-equal matrix (completed cells
skipped, the interrupted cell continued from its newest intact
checkpoint), re-run of missing/corrupt per-run files, cell timeouts
under concurrent workers (recorded, not retried, others unaffected),
worker-crash requeue, the interrupt-tolerant JsonlDirSink (idempotent
close, context manager, lazy index, sweep_interrupted records), and the
report's FAILED/TIMEOUT rendering.
"""
import glob
import json
import os
import shutil
import threading

import pytest

from repro.api import (
    Callback, CellTimeout, DataSpec, Experiment, ExperimentSpec,
    JsonlDirSink, ModelSpec, RunResult, RunSink, RunSpec, SchemeSpec,
    SpecError, SweepSpec, WirelessSpec, load_manifest, run_sweep,
    spec_hash, verify_cell_run,
)
from repro.api import cli
from benchmarks import report

N_CLIENTS, ROUNDS, BATCH = 5, 4, 8


def base_spec(**run_kw) -> ExperimentSpec:
    # shards=1 pins the engine collective-free so the worker-pool tests
    # exercise REAL thread parallelism even on the forced-4-device CI
    # leg — with auto shards the collective-safety gate (run_sweep)
    # would quietly serialize them there (test_collective_safety_gate)
    run_kw.setdefault("shards", 1)
    return ExperimentSpec(
        data=DataSpec(dataset="synthetic-mnist", n_clients=N_CLIENTS,
                      sigma=5.0, n_train=200, n_test=60, seed=0),
        model=ModelSpec(name="mlp-edge"),
        wireless=WirelessSpec(e0=1e6, t0=1e6, seed=0),
        scheme=SchemeSpec(name="proposed", rounds=ROUNDS, eta=0.1,
                          batch=BATCH, ao={"outer_iters": 1}),
        run=RunSpec(seed=0, eval_every=2, **run_kw))


def matrix(**run_kw) -> SweepSpec:
    return SweepSpec(base=base_spec(**run_kw), seeds=[0, 1],
                     schemes=["proposed", "no_gen"])


def run_file_bytes(directory: str) -> dict[str, bytes]:
    out = {}
    for p in sorted(glob.glob(os.path.join(directory, "0*.jsonl"))):
        with open(p, "rb") as f:
            out[os.path.basename(p)] = f.read()
    return out


def index_kinds(directory: str) -> list[str]:
    with open(os.path.join(directory, "sweep.jsonl")) as f:
        return [json.loads(line)["kind"] for line in f if line.strip()]


# ---------------------------------------------------------------------------
# Worker-count invariance
# ---------------------------------------------------------------------------

def test_worker_invariance_bitwise(tmp_path):
    sw = matrix()
    d1, d4 = str(tmp_path / "w1"), str(tmp_path / "w4")
    r1 = run_sweep(sw, sink=JsonlDirSink(d1), workers=1)
    r4 = run_sweep(sw, sink=JsonlDirSink(d4), workers=4)
    assert r1.errors == [] and r4.errors == []
    assert all(r is not None for r in r4.results)
    b1, b4 = run_file_bytes(d1), run_file_bytes(d4)
    assert len(b1) == 4 and b1 == b4       # per-run records: bitwise equal
    # summary_rows come back in matrix order regardless of completion order
    assert r1.summary_rows() == r4.summary_rows()
    # env cache is shared across workers: still exactly one build
    assert r4.n_env_builds == 1
    assert r4.n_worker_crashes == 0 and r4.n_skipped == 0


# ---------------------------------------------------------------------------
# Manifest protocol
# ---------------------------------------------------------------------------

def test_manifest_written_and_cells_verify(tmp_path):
    sw = matrix()
    d = str(tmp_path / "runs")
    run_sweep(sw, sink=JsonlDirSink(d))
    man = load_manifest(d)
    cells = sw.expand()
    assert man["kind"] == "sweep_manifest" and man["n_cells"] == 4
    assert [c["name"] for c in man["cells"]] == [c.name for c in cells]
    for rec, cell in zip(man["cells"], cells):
        assert rec["spec_hash"] == spec_hash(cell.spec)
        path = os.path.join(d, f"{cell.name}.jsonl")
        res = verify_cell_run(path, rec["spec_hash"])
        assert res is not None and res.summary["rounds_run"] == ROUNDS
        # a wrong hash (different sweep) rejects the same file
        assert verify_cell_run(path, "0" * 64) is None
    # spec_hash is stable across the JSON round-trip the verifier relies on
    spec = cells[0].spec
    assert spec_hash(spec) == spec_hash(json.loads(json.dumps(
        spec.to_dict())))


def test_verify_rejects_truncated_and_garbage(tmp_path):
    sw = SweepSpec(base=base_spec())
    d = str(tmp_path / "runs")
    run_sweep(sw, sink=JsonlDirSink(d))
    cell = sw.expand()[0]
    path = os.path.join(d, f"{cell.name}.jsonl")
    h = spec_hash(cell.spec)
    assert verify_cell_run(path, h) is not None
    with open(path) as f:
        lines = f.readlines()
    # whole trailing rounds lost: summary claims more rounds than present
    with open(path, "w") as f:
        f.writelines(lines[:2])
    assert verify_cell_run(path, h) is None
    # line torn mid-record: unparsable JSON
    with open(path, "w") as f:
        f.write("".join(lines)[:-20])
    assert verify_cell_run(path, h) is None
    assert verify_cell_run(os.path.join(d, "nope.jsonl"), h) is None


# ---------------------------------------------------------------------------
# Kill mid-sweep -> resume -> bitwise-equal matrix
# ---------------------------------------------------------------------------

class InterruptAfterRounds(Callback):
    """Raise KeyboardInterrupt once `n` round-end events were seen across
    the whole sweep — a deterministic in-process stand-in for SIGTERM."""

    def __init__(self, n: int):
        self.n = int(n)
        self.seen = 0

    def on_round_end(self, m, trainer) -> None:
        self.seen += 1
        if self.seen >= self.n:
            raise KeyboardInterrupt


def test_kill_midsweep_then_resume_bitwise(tmp_path):
    sw = matrix(checkpoint_every=1)
    oracle_dir = str(tmp_path / "oracle")
    run_sweep(sw, sink=JsonlDirSink(oracle_dir), workers=1)
    oracle = run_file_bytes(oracle_dir)
    assert len(oracle) == 4

    # interrupt during cell 1 (cell 0 done + 2 rounds into cell 1)
    d = str(tmp_path / "elastic")
    with pytest.raises(KeyboardInterrupt):
        run_sweep(sw, sink=JsonlDirSink(d),
                  callbacks=[InterruptAfterRounds(ROUNDS + 2)])
    cells = sw.expand()
    partial = run_file_bytes(d)
    assert list(partial) == [f"{cells[0].name}.jsonl"]
    assert "sweep_interrupted" in index_kinds(d)
    # the interrupted cell checkpointed mid-run under the sink directory
    ck = os.path.join(d, "ckpt", cells[1].name)
    assert glob.glob(os.path.join(ck, "ckpt_*.npz"))

    res = run_sweep(sw, sink=JsonlDirSink(d), resume=True)
    assert res.n_skipped == 1                  # cell 0 verified, not re-run
    assert res.errors == [] and all(r is not None for r in res.results)
    assert run_file_bytes(d) == oracle         # the acceptance criterion
    assert res.summary_rows() == [
        {"name": c.name, **json.loads(oracle[f"{c.name}.jsonl"]
                                      .split(b"\n")[0])["summary"]}
        for c in cells]
    # completed cells' resume checkpoints were cleaned up
    assert not glob.glob(os.path.join(ck, "ckpt_*.npz"))
    kinds = index_kinds(d)
    assert kinds.count("sweep_skip") == 1 and kinds.count("sweep_run") == 4


def test_resume_reruns_missing_and_corrupt_cells(tmp_path):
    sw = matrix()
    d = str(tmp_path / "runs")
    run_sweep(sw, sink=JsonlDirSink(d))
    oracle = run_file_bytes(d)
    cells = sw.expand()
    os.unlink(os.path.join(d, f"{cells[1].name}.jsonl"))
    with open(os.path.join(d, f"{cells[2].name}.jsonl"), "r+") as f:
        f.truncate(40)                         # torn header line
    res = run_sweep(sw, sink=JsonlDirSink(d), resume=True)
    assert res.n_skipped == 2                  # cells 0 and 3 verified
    assert run_file_bytes(d) == oracle


def test_resume_with_different_matrix_rejected(tmp_path):
    d = str(tmp_path / "runs")
    run_sweep(SweepSpec(base=base_spec(), seeds=[0, 1]),
              sink=JsonlDirSink(d))
    before = index_kinds(d)
    with pytest.raises(SpecError, match="different sweep matrix"):
        run_sweep(SweepSpec(base=base_spec(), seeds=[0, 1, 2]),
                  sink=JsonlDirSink(d), resume=True)
    # the rejected resume destroyed nothing: index + manifest untouched
    assert index_kinds(d) == before
    assert load_manifest(d)["n_cells"] == 2


# ---------------------------------------------------------------------------
# Timeouts + worker crashes under concurrency
# ---------------------------------------------------------------------------

class TimeoutSlowCells(Callback):
    """Deterministic stand-in for a blown deadline: raise CellTimeout on
    the slow family (eta 0.05) and count first-round events per eta so
    the test can assert timed-out cells were NOT retried."""

    def __init__(self):
        self.starts: dict[float, int] = {}
        self._lock = threading.Lock()

    def on_round_end(self, m, trainer) -> None:
        if m.round == 0:
            with self._lock:
                self.starts[trainer.eta] = \
                    self.starts.get(trainer.eta, 0) + 1
        if trainer.eta == 0.05:
            raise CellTimeout("synthetic deadline")


def test_cell_timeout_under_concurrent_workers(tmp_path):
    sw = SweepSpec(base=base_spec(), seeds=[0, 1],
                   grid={"scheme.eta": [0.1, 0.05]})
    d = str(tmp_path / "runs")
    probe = TimeoutSlowCells()
    res = run_sweep(sw, sink=JsonlDirSink(d), workers=2, callbacks=[probe],
                    max_retries=2)
    # slow cells recorded as timeouts; fast cells unaffected
    assert [e["kind"] for e in res.errors] == ["timeout", "timeout"]
    assert [r is not None for r in res.results] == [True, True, False,
                                                    False]
    # NOT retried despite max_retries=2: one attempt per timed-out cell
    assert probe.starts == {0.1: 2, 0.05: 2}
    errs = [json.loads(line) for line
            in open(os.path.join(d, "sweep.jsonl")) if line.strip()]
    assert sorted(e["error_kind"] for e in errs
                  if e["kind"] == "sweep_error") == ["timeout", "timeout"]


class CrashOnceSink(RunSink):
    """A sink whose first write dies — the worker-crash injection."""

    def __init__(self):
        self.written: list[str] = []
        self.crashed = False

    def write(self, name: str, result) -> None:
        if not self.crashed:
            self.crashed = True
            raise RuntimeError("sink storage died")
        self.written.append(name)


def test_worker_crash_requeues_cell_on_survivors(tmp_path):
    sw = matrix()
    sink = CrashOnceSink()
    res = run_sweep(sw, sink=sink, workers=2)
    assert res.n_worker_crashes == 1
    assert res.errors == [] and all(r is not None for r in res.results)
    # the crashed worker's cell was re-run and written by a survivor
    assert sorted(sink.written) == [c.name for c in sw.expand()]


# ---------------------------------------------------------------------------
# Interrupt-tolerant JsonlDirSink
# ---------------------------------------------------------------------------

def test_sink_idempotent_close_context_manager_lazy_index(tmp_path):
    d = str(tmp_path / "sink")
    cells = SweepSpec(base=base_spec()).expand()
    with JsonlDirSink(d) as sink:
        sink.begin(cells)
        # manifest lands immediately; the index only on the first append —
        # a rejected resume can never have truncated the previous index
        assert os.path.exists(os.path.join(d, "sweep_manifest.json"))
        assert not os.path.exists(sink.index_path)
        sink.write_interrupted(KeyboardInterrupt("test"))
        assert index_kinds(d) == ["sweep_interrupted"]
    sink.close()                                # second close: no-op
    sink.close()
    with pytest.raises(ValueError, match="closed"):
        sink.write_interrupted(KeyboardInterrupt("late"))


def test_sink_write_skipped_records_cell(tmp_path):
    d = str(tmp_path / "runs")
    sw = SweepSpec(base=base_spec())
    run_sweep(sw, sink=JsonlDirSink(d))
    cell = sw.expand()[0]
    res = RunResult.from_jsonl(os.path.join(d, f"{cell.name}.jsonl"))
    sink = JsonlDirSink(d)
    sink.begin(sw.expand(), resume=True)        # append mode: keep history
    sink.write_skipped(cell.name, res)
    sink.close()
    assert index_kinds(d) == ["sweep_run", "sweep_skip"]
    assert sink.paths == [os.path.join(d, f"{cell.name}.jsonl")]


# ---------------------------------------------------------------------------
# run_or_resume + report rendering
# ---------------------------------------------------------------------------

def test_run_or_resume_fresh_equals_run_and_is_idempotent(tmp_path):
    spec = base_spec(checkpoint_every=1)
    oracle = Experiment(spec).build().run()
    d = str(tmp_path / "ck")
    run = Experiment(spec).build()
    a = run.run_or_resume(d)                    # fresh dir: a plain run
    b = run.run_or_resume(d)                    # done dir: resume-at-end
    pa, pb, po = (str(tmp_path / n) for n in ("a.jsonl", "b.jsonl",
                                              "o.jsonl"))
    a.to_jsonl(pa), b.to_jsonl(pb), oracle.to_jsonl(po)
    assert open(pa, "rb").read() == open(po, "rb").read()
    assert open(pb, "rb").read() == open(po, "rb").read()


def test_report_renders_failed_and_timeout_cells(tmp_path):
    spec_path = base_spec().save(str(tmp_path / "base.json"))
    out_dir = str(tmp_path / "runs")
    rc = cli.main(["sweep", spec_path, "--seeds", "0,1",
                   "--grid", "model.name=mlp-edge,wat",
                   "--out-dir", out_dir])
    assert rc == 1
    paths = sorted(glob.glob(os.path.join(out_dir, "*.jsonl")))
    table = report.runs_table(paths)
    assert table.count("| ok |") == 2 and table.count("FAILED") == 2
    assert "wat" in table
    rows = report.aggregate_runs(paths)
    assert sorted((r["n"], r.get("n_failed", 0)) for r in rows) == \
        [(0, 2), (2, 0)]
    agg = report.sweep_table(rows=rows)
    assert "| failed |" in agg.splitlines()[0]
    # synthetic timeout record renders as TIMEOUT with the cell's axes
    rec = {"kind": "sweep_error", "error_kind": "timeout",
           "name": "007_x", "spec": base_spec().to_dict(),
           "error": "CellTimeout: deadline"}
    assert "TIMEOUT" in report.runs_table([], errors=[rec])


# ---------------------------------------------------------------------------
# Collective-safety gate: sharded engines must not dispatch concurrently
# ---------------------------------------------------------------------------

def test_collective_safe_predicate():
    from repro.api.sweep import _collective_safe
    # explicit shards=1: collective-free, parallel dispatch allowed
    assert _collective_safe(matrix().expand())
    # explicit shards=2: the engine WILL shard_map -> unsafe
    assert not _collective_safe(matrix(shards=2).expand())
    # the eager reference backend never runs collectives
    assert _collective_safe(matrix(shards=2, backend="reference").expand())


def test_collective_safety_gate_serializes_workers(tmp_path, monkeypatch):
    # force the gate's answer rather than the shard resolution: patching
    # resolve_shards would also change the engines the cells then build
    import repro.api.sweep as sweep_mod
    monkeypatch.setattr(sweep_mod, "_collective_safe", lambda cells: False)
    logs = []
    d = str(tmp_path / "runs")
    res = run_sweep(matrix(), sink=JsonlDirSink(d), workers=4,
                    log=logs.append)
    assert res.errors == [] and all(r is not None for r in res.results)
    assert any("serialized" in m for m in logs)
    # serial drain = one worker-local trainer pool, like workers=1
    assert res.n_trainer_builds == 1
    assert len(run_file_bytes(d)) == 4
