"""Golden-trajectory regression: re-run the committed fixed-seed fixture
and assert BITWISE-equal per-round history on fp32.

The fixture (tests/golden/run_mlp_edge.jsonl, regenerated only
deliberately via scripts/make_golden.py) carries its own spec in the
header record, so this one test pins the entire pipeline — dataset
generation, Dirichlet partition, phi, Table-I system, channel draw, the
P1 solve, and the packed/block round engines — against silent numeric
drift: any change to any of those layers that moves a single ulp in any
round's mean train loss, eval metric, or ledger entry fails here with the
exact round named.

Float comparison is exact by construction: JSON serializes doubles via
repr (shortest round-trip), so the parsed golden values are the bitwise
floats the original run produced.
"""
import os

import jax
import numpy as np
import pytest

from repro.api import Experiment, ExperimentSpec, RunResult

GOLDEN = os.path.join(os.path.dirname(__file__), "golden",
                      "run_mlp_edge.jsonl")
GOLDEN_FEDPROX = os.path.join(os.path.dirname(__file__), "golden",
                              "run_mlp_edge_fedprox.jsonl")

# The TRAINING trajectory (losses, selection, ledger) is bitwise on any
# host: the fixture pins shards=1, so the engine math is single-device
# regardless of how many devices XLA exposes. The EVAL reduction
# (make_eval_fn's mean over the test set) is outside that contract — its
# compiled reduction order follows the host's device count — so eval
# metrics are held bitwise only on 1-device hosts and to float tolerance
# on forced-multi-device CI hosts.
EXACT_FIELDS = ("train_loss", "mean_lambda", "delay", "energy",
                "cumulative_delay", "cumulative_energy")
EVAL_FIELDS = ("test_loss", "test_accuracy")
SINGLE_DEVICE = len(jax.devices()) == 1


@pytest.fixture(scope="module")
def golden():
    return RunResult.from_jsonl(GOLDEN)


@pytest.fixture(scope="module")
def golden_fedprox():
    return RunResult.from_jsonl(GOLDEN_FEDPROX)


def _assert_trajectory_matches(golden, res):
    assert len(res.history) == len(golden.history)
    for got, want in zip(res.history, golden.history):
        r = want.round
        assert got.round == r
        assert got.selected == want.selected, f"round {r}: selection"
        for field in EXACT_FIELDS + (EVAL_FIELDS if SINGLE_DEVICE else ()):
            a, b = getattr(got, field), getattr(want, field)
            if isinstance(b, float) and np.isnan(b):
                assert isinstance(a, float) and np.isnan(a), \
                    f"round {r}: {field}"
            else:
                assert a == b, (f"round {r}: {field} drifted "
                                f"{b!r} -> {a!r}")
        if not SINGLE_DEVICE:
            for field in EVAL_FIELDS:
                a, b = getattr(got, field), getattr(want, field)
                if b is not None:
                    np.testing.assert_allclose(a, b, rtol=1e-5,
                                               err_msg=f"round {r}: {field}")
    if SINGLE_DEVICE:
        # the summary (incl. the solved schedule's theta/energy/delay) too
        assert res.summary == golden.summary
    else:
        assert res.summary["rounds_run"] == golden.summary["rounds_run"]
        assert res.summary["theta"] == golden.summary["theta"]


def test_golden_fixture_shape(golden):
    assert golden.spec, "golden fixture must embed its spec"
    assert golden.summary["rounds_run"] == len(golden.history) > 0
    # the fixture pins the single-device engine + block dispatch
    assert golden.spec["run"]["shards"] == 1
    assert golden.spec["run"]["rounds_per_dispatch"] == 2


def test_golden_trajectory_bitwise(golden):
    spec = ExperimentSpec.from_dict(golden.spec)
    _assert_trajectory_matches(golden, Experiment(spec).run())


def _rerun_reference(golden):
    """The golden trajectory is also the REFERENCE backend's trajectory
    (the fixture pins shards=1, where packed == reference bit-for-bit):
    one more angle on the same fixture that catches a drift in either
    backend even if both engines drift together on the packed side."""
    import dataclasses

    spec = ExperimentSpec.from_dict(golden.spec)
    spec = dataclasses.replace(
        spec, run=dataclasses.replace(spec.run, backend="reference"))
    res = Experiment(spec).run()
    assert [m.train_loss for m in res.history] == \
        [m.train_loss for m in golden.history]
    if SINGLE_DEVICE:
        assert [m.test_accuracy for m in res.history] == \
            [m.test_accuracy for m in golden.history]


def test_golden_rerun_through_reference_backend(golden):
    _rerun_reference(golden)


def test_fedprox_golden_fixture_shape(golden_fedprox):
    assert golden_fedprox.spec, "golden fixture must embed its spec"
    assert golden_fedprox.summary["rounds_run"] == \
        len(golden_fedprox.history) > 0
    sc = golden_fedprox.spec["scheme"]
    # the local-epoch fixture pins FedProx with E=3 (a non-pow2 step count,
    # so the padded-step no-op gating is inside the pinned trajectory)
    assert sc["local_scheme"] == "fedprox"
    assert sc["local_steps"] == 3
    assert sc["local_kwargs"] == {"mu": 0.05}
    assert golden_fedprox.spec["run"]["shards"] == 1
    assert golden_fedprox.spec["run"]["rounds_per_dispatch"] == 2


def test_fedprox_golden_trajectory_bitwise(golden_fedprox):
    spec = ExperimentSpec.from_dict(golden_fedprox.spec)
    _assert_trajectory_matches(golden_fedprox, Experiment(spec).run())


def test_fedprox_golden_rerun_through_reference_backend(golden_fedprox):
    _rerun_reference(golden_fedprox)
