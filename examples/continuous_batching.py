"""Continuous-batching serving: many requests with ragged prompt lengths
stream through a fixed pool of decode slots (repro.serving.ServingEngine).

HONEST CPU caveat: the engine's win on accelerators comes from amortizing
the (memory-bound) weight reads across the in-flight batch; on one CPU core
compute scales with batch, so wall-clock does NOT show the speedup — the
demonstration here is the *scheduling* behavior (slot utilization, requests
in flight, time-to-first-token under load) plus exactness (tests prove
engine output == sequential generation).

    PYTHONPATH=src python examples/continuous_batching.py
"""
import time

import jax
import numpy as np

from repro.configs import get_config
from repro.models import Runtime, init_params
from repro.serving import ServingEngine

ARCH = "granite-3-2b"
N_REQUESTS = 12
MAX_NEW = 12

cfg = get_config(ARCH).reduced()
params = init_params(jax.random.key(0), cfg)
rng = np.random.default_rng(0)
prompts = [rng.integers(0, cfg.vocab_size,
                        size=int(rng.integers(8, 30))).astype(np.int32)
           for _ in range(N_REQUESTS)]

# --- continuous batching: 4 slots shared by 12 requests ---------------------
eng = ServingEngine(params, cfg, max_batch=4, max_seq=128,
                    rt=Runtime(attn_impl="naive"), prompt_buckets=(32,))
eng.submit(prompts[0], max_new_tokens=2)
eng.run_to_completion()                      # warm compiles
eng.finished.clear()

t0 = time.time()
for pr in prompts:
    eng.submit(pr, max_new_tokens=MAX_NEW)
active_trace = []
while eng.active or eng.queue:
    active_trace.append(eng.step())
done = eng.finished
dt_cb = time.time() - t0
total_tokens = sum(len(st.generated) for st in done)
steps = len(active_trace)
print(f"continuous batching: {len(done)} requests, {total_tokens} tokens, "
      f"{steps} engine steps ({total_tokens / max(steps,1):.2f} tok/step; "
      f"sequential would need {total_tokens} steps)")
print(f"mean slots active: {np.mean([a for a in active_trace if a]):.2f}/4")

# --- naive: one request at a time (batch 1, same engine => no recompiles) ---
one = ServingEngine(params, cfg, max_batch=1, max_seq=128,
                    rt=Runtime(attn_impl="naive"), prompt_buckets=(32,))
one.submit(prompts[0], max_new_tokens=MAX_NEW)
one.run_to_completion()                    # warm the compile caches
t0 = time.time()
for pr in prompts:
    one.submit(pr, max_new_tokens=MAX_NEW)
    one.run_to_completion()
dt_naive = time.time() - t0
print(f"one-by-one (warm, CPU): {total_tokens} tokens in {dt_naive:.1f}s — "
      f"faster on CPU (compute ~ batch); on TPU the engine's "
      f"{total_tokens / max(steps,1):.2f} tok/step amortizes the "
      f"memory-bound weight reads (see §Roofline: decode is memory-bound)")

# per-request latency stats
waits = [st.t_first_token - st.t_enqueue for st in done]
print(f"time-to-first-token: mean {np.mean(waits)*1e3:.0f} ms, "
      f"p99 {np.percentile(waits, 99)*1e3:.0f} ms")
