"""Quickstart: the paper's pipeline as ONE declarative spec.

The unified experiment API (repro.api, DESIGN.md §8) replaces the seven
manually-wired steps this file used to spell out (dataset -> Dirichlet
partition -> phis -> SystemParams/ChannelModel -> solve_p1 ->
FederatedTrainer -> run): an `ExperimentSpec` names the components through
string-keyed registries, `Experiment.build()` resolves and solves them,
and `Run.run()` executes the schedule and returns a structured,
JSONL-exportable `RunResult`. The spec path is bit-for-bit identical to
the old hand wiring (asserted in tests/test_api.py); the scheme below,
`proposed_exact`, is the plain `AOConfig(outer_iters=3)` the original
quickstart used.

    PYTHONPATH=src python examples/quickstart.py

Checkpoint/resume the same run from the command line:

    PYTHONPATH=src python -m repro.api.cli run spec.json \
        --checkpoint-dir ckpts --checkpoint-every 10
    PYTHONPATH=src python -m repro.api.cli resume ckpts
"""
import numpy as np

from repro.api import (DataSpec, Experiment, ExperimentSpec, ModelSpec,
                       RunSpec, SchemeSpec, WirelessSpec)

N_CLIENTS, SIGMA, ROUNDS = 10, 5.0, 40
E0, T0 = 250.0, 150.0  # paper Table-I MNIST budgets [J], [s]

spec = ExperimentSpec(
    # 1. data + federation: Dirichlet-non-IID over a synthetic dataset
    data=DataSpec(dataset="synthetic-mnist", n_clients=N_CLIENTS,
                  sigma=SIGMA, n_train=4000, n_test=800, seed=0),
    model=ModelSpec(name="lenet"),
    # 2. Table-I wireless system + the paper's budgets
    wireless=WirelessSpec(e0=E0, t0=T0, seed=0),
    # 3. joint problem (P1, Algorithm 1) via the scheme registry
    scheme=SchemeSpec(name="proposed_exact", rounds=ROUNDS, eta=0.1,
                      batch=32),
    # 4. parameter-efficient FedSGD: rounds_per_dispatch="auto" (default)
    # consumes the AO schedule in multi-round lax.scan blocks on
    # accelerators and per-round dispatches on CPU — bit-for-bit either way
    run=RunSpec(seed=0, eval_every=10))

run = Experiment(spec).build()
print("phi per client:", np.round(run.env.phi, 2))
sched = run.schedule
print(f"schedule: theta={sched.theta:.2f} E={sched.energy:.1f}J "
      f"T={sched.delay:.1f}s feasible={sched.feasible}")
print("clients/round:", sched.a.sum(axis=1)[:8], "...")
print("mean pruning ratio:", float(sched.lam[sched.a > 0].mean()))

result = run.run()
for m in result.history:
    if m.test_accuracy is not None:
        print(f"round {m.round:3d}  loss {m.train_loss:.3f}  "
              f"acc {m.test_accuracy:.3f}  E {m.cumulative_energy:6.1f}J  "
              f"T {m.cumulative_delay:6.1f}s")
s = result.summary
print(f"final acc {s['final_accuracy']:.3f} @ round "
      f"{s['final_accuracy_round']} after {s['rounds_run']} rounds")
