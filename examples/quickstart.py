"""Quickstart: the paper's pipeline in ~60 lines.

1. Build a Dirichlet-non-IID federation over a synthetic dataset.
2. Compute each client's generalization statement phi_n (Lemma 1).
3. Solve the joint problem (P1) for {a, lambda, p, f} (Algorithm 1).
4. Run parameter-efficient FedSGD under the resulting schedule.

    PYTHONPATH=src python examples/quickstart.py
"""
import jax
import numpy as np

from repro.core import (AOConfig, BoundConstants, ClientData,
                        FederatedTrainer, phis, solve_p1)
from repro.data import make_dataset, partition_by_dirichlet
from repro.models import lenet_init, lenet_apply, make_eval_fn, make_loss_fn
from repro.wireless import ChannelModel, SystemParams

N_CLIENTS, SIGMA, ROUNDS = 10, 5.0, 40
E0, T0 = 250.0, 150.0  # paper Table-I MNIST budgets [J], [s]

# 1. data + federation ------------------------------------------------------
ds = make_dataset("synthetic-mnist", n_train=4000, n_test=800, seed=0)
parts = partition_by_dirichlet(ds.y_train, N_CLIENTS, SIGMA,
                               rng=np.random.default_rng(0))
clients = [ClientData(ds.x_train[i], ds.y_train[i]) for i in parts]

# 2. generalization statements (Lemma 1) ------------------------------------
test_hist = np.bincount(ds.y_test, minlength=10).astype(float)
phi = phis(np.stack([c.label_histogram(10) for c in clients]),
           test_hist[None])
print("phi per client:", np.round(phi, 2))

# 3. joint optimization (P1, Algorithm 1) ------------------------------------
sp = SystemParams.table1(N_CLIENTS, dataset="mnist")
ch = ChannelModel(N_CLIENTS, seed=0)
consts = BoundConstants(rounds_S=ROUNDS - 1, batch_Z=32, eta=0.1)
sched = solve_p1(phi, E0, T0, ch.uplink, ch.downlink, sp, consts,
                 AOConfig(outer_iters=3))
print(f"schedule: theta={sched.theta:.2f} E={sched.energy:.1f}J "
      f"T={sched.delay:.1f}s feasible={sched.feasible}")
print("clients/round:", sched.a.sum(axis=1)[:8], "...")
print("mean pruning ratio:", float(sched.lam[sched.a > 0].mean()))

# 4. parameter-efficient FedSGD ----------------------------------------------
# rounds_per_dispatch="auto" (the default) consumes the AO schedule in
# multi-round blocks on accelerators — client data lives on device and K
# rounds run per jitted dispatch (lax.scan) with batches sampled on device;
# on CPU it resolves to the classic one-dispatch-per-round loop. Any int
# (e.g. rounds_per_dispatch=32) forces block execution; the trajectory is
# bit-for-bit identical either way on fp32 single-device runs.
trainer = FederatedTrainer(make_loss_fn(lenet_apply),
                           lenet_init(jax.random.key(0)), clients,
                           eta=0.1, batch_size=32,
                           rounds_per_dispatch="auto")
eval_fn = make_eval_fn(lenet_apply, ds.x_test, ds.y_test)
history = trainer.run(sched, sp, ch.uplink, ch.downlink,
                      eval_fn=eval_fn, eval_every=10,
                      stop_delay=T0, stop_energy=E0)
for m in history:
    if m.test_accuracy is not None:
        print(f"round {m.round:3d}  loss {m.train_loss:.3f}  "
              f"acc {m.test_accuracy:.3f}  E {m.cumulative_energy:6.1f}J  "
              f"T {m.cumulative_delay:6.1f}s")
