"""End-to-end paper reproduction driver: the proposed scheme vs the five
baselines (Sec. V), a few hundred FedSGD rounds on the synthetic MNIST-class
task, reporting the Fig. 5/7-style results.

    PYTHONPATH=src python examples/feel_paper_reproduction.py [--rounds 200]
"""
import argparse
import json
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))

from benchmarks.common import (SCHEMES, ExpConfig, build_env, final_accuracy,
                               run_scheme)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--rounds", type=int, default=60)
    ap.add_argument("--sigma", type=float, default=1.0)
    ap.add_argument("--out", default="experiments/paper_repro.json")
    args = ap.parse_args()

    cfg = ExpConfig(sigma=args.sigma, rounds=args.rounds, n_train=4000,
                    n_test=800)
    env = build_env(cfg)
    print(f"phi: min={env.phi.min():.2f} max={env.phi.max():.2f}")

    results = {}
    for scheme in SCHEMES:
        t0 = time.time()
        sched, hist = run_scheme(env, scheme, eval_every=25)
        acc, acc_round = final_accuracy(hist)
        results[scheme] = {
            "final_accuracy": acc,
            "final_accuracy_round": acc_round,
            "final_loss": hist[-1].train_loss,
            "rounds_completed": len(hist),
            "energy_used": hist[-1].cumulative_energy,
            "delay_used": hist[-1].cumulative_delay,
            "mean_clients_per_round": float(sched.a.sum(axis=1).mean()),
            "mean_lambda": float(sched.lam[sched.a > 0].mean())
            if sched.a.sum() else 0.0,
        }
        print(f"{scheme:16s} acc={acc:.3f} loss={hist[-1].train_loss:.3f} "
              f"rounds={len(hist)} E={hist[-1].cumulative_energy:.0f}J "
              f"({time.time() - t0:.0f}s)")

    os.makedirs(os.path.dirname(args.out), exist_ok=True)
    with open(args.out, "w") as f:
        json.dump(results, f, indent=2)
    print("saved", args.out)

    best_baseline = max(v["final_accuracy"] for k, v in results.items()
                        if k != "proposed")
    print(f"\nproposed {results['proposed']['final_accuracy']:.3f} vs best "
          f"baseline {best_baseline:.3f} "
          f"({'WIN' if results['proposed']['final_accuracy'] >= best_baseline else 'LOSS'})")


if __name__ == "__main__":
    main()
