"""Batched serving example over the assigned architectures (reduced configs):
prefill a batch of prompts, decode with per-family KV/SSM caches, report
tokens/s — exercising ring-buffer SWA caches, SSM state caches, and
cross-attention caches through the public API.

    PYTHONPATH=src python examples/serve_batched.py [--arch mamba2-130m]
"""
import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config, list_configs
from repro.models import Runtime, decode_step, init_cache, init_params, prefill

ARCHS_DEMO = ["granite-3-2b", "mamba2-130m", "mixtral-8x22b", "gemma2-9b",
              "whisper-small"]


def serve_one(arch: str, batch=2, prompt_len=48, gen=16):
    cfg = get_config(arch).reduced()
    rt = Runtime(attn_impl="naive")
    rng = np.random.default_rng(0)
    params = init_params(jax.random.key(0), cfg)
    extra = None
    if cfg.family == "audio":
        extra = {"encoder_input": jnp.asarray(
            rng.normal(size=(batch, cfg.encoder_tokens, cfg.d_model)),
            jnp.dtype(cfg.dtype))}
    if cfg.family == "vlm":
        extra = {"vision_embeddings": jnp.asarray(
            rng.normal(size=(batch, cfg.vision_tokens, cfg.d_model)),
            jnp.dtype(cfg.dtype))}
    prompts = jnp.asarray(rng.integers(0, cfg.vocab_size,
                                       (batch, prompt_len)), jnp.int32)
    cache = init_cache(cfg, batch, prompt_len + gen)

    p_jit = jax.jit(lambda p, t, c: prefill(p, t, c, cfg, rt, extra))
    d_jit = jax.jit(lambda p, t, c, pos: decode_step(p, t, c, pos, cfg, rt))

    logits, cache = p_jit(params, prompts, cache)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    out = [tok]
    t0 = time.time()
    for i in range(gen - 1):
        logits, cache = d_jit(params, tok, cache, prompt_len + i)
        tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        out.append(tok)
    jax.block_until_ready(out[-1])
    dt = time.time() - t0
    toks = np.asarray(jnp.concatenate(out, axis=1))
    print(f"{arch:16s} [{cfg.family:6s}] {batch * (gen - 1) / dt:7.1f} tok/s"
          f"  sample: {toks[0][:8].tolist()}")


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_configs() + ["demo"],
                    default="demo")
    args = ap.parse_args()
    archs = ARCHS_DEMO if args.arch == "demo" else [args.arch]
    for arch in archs:
        serve_one(arch)


if __name__ == "__main__":
    main()
