"""Roofline analysis per (arch x shape x mesh) — EXPERIMENTS.md §Roofline.

Three terms per pair, in seconds per step:

    compute    = FLOPs_per_chip / 197e12       (bf16 peak, TPU v5e)
    memory     = HBM_bytes_per_chip / 819e9
    collective = coll_bytes_per_chip / 50e9    (ICI link bw)

IMPORTANT measurement note: XLA's HloCostAnalysis counts while-loop bodies
ONCE (verified: a 4-step microbatch scan divides reported flops by 4), so
`compiled.cost_analysis()` under-reports every lax.scan-ed layer stack. The
terms below are therefore ANALYTIC — derived from the architecture equations
(matmul + attention + SSD + MoE + CE) and the sharding layout — and the
HLO-measured numbers ride along as `hlo_*` fields for sanity (they are exact
for the non-loop portion). tests/test_roofline.py validates the analytic
per-layer FLOPs against the HLO slope of 1- vs 2-layer unrolled variants.

Collective bytes come from the dry-run HLO parse (per-device shapes) for
top-level collectives, plus analytic in-loop terms (FSDP gathers, TP
all-reduces, MoE all-to-all) that live inside the scanned layer body.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any

import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.registry import (INPUT_SHAPES, InputShape, get_config,
                                    list_configs, shape_applicable)
from repro.launch.mesh import PEAK_FLOPS_BF16, HBM_BW, ICI_BW_PER_LINK
from repro.launch.steps import train_microbatches
from repro.models.transformer import active_param_count, param_count

DRYRUN_DIR = os.path.join(os.path.dirname(__file__), "..", "experiments",
                          "dryrun")

BYTES = 2  # bf16


@dataclasses.dataclass
class Roofline:
    arch: str
    shape: str
    mesh: str
    flops_chip: float
    hbm_bytes_chip: float
    coll_bytes_chip: float
    compute_s: float
    memory_s: float
    collective_s: float
    dominant: str
    model_flops: float          # 6*N_active*T (dense) — the paper-standard
    useful_ratio: float         # model_flops / total analytic flops
    note: str = ""
    hlo: dict | None = None

    def terms(self):
        return {"compute": self.compute_s, "memory": self.memory_s,
                "collective": self.collective_s}


def _mesh_sizes(mesh: str):
    if mesh == "2x16x16":
        return 512, 32, 16   # chips, batch-shards, model-shards
    return 256, 16, 16


def _attn_layers(cfg: ModelConfig):
    """[(n_layers, kind)] with kind in full|window|none + cross-attn info."""
    if cfg.family == "ssm":
        return [], 0
    if cfg.family == "vlm":
        k = cfg.cross_attn_every
        n_groups = cfg.num_layers // k
        return [(n_groups * (k - 1), "full")], n_groups  # cross layers extra
    if cfg.local_global:
        half = cfg.num_layers // 2
        return [(half, "window"), (half, "full")], 0
    kind = "window" if cfg.sliding_window else "full"
    return [(cfg.num_layers, kind)], 0


def analytic_roofline(cfg: ModelConfig, shape: InputShape, mesh: str,
                      *, swa_only: bool | None = None) -> Roofline:
    chips, bshards, mshards = _mesh_sizes(mesh)
    b, s = shape.global_batch, shape.seq_len
    kind = shape.kind
    if swa_only is None:
        swa_only = shape.name == "long_500k" and cfg.local_global

    n_total = param_count(cfg)
    n_active = active_param_count(cfg)
    p_bytes = BYTES * n_total

    t_tokens = b * s if kind != "decode" else b
    # ---- matmul flops (params-driven) ----
    if kind == "train":
        mm = 8.0 * n_active * t_tokens     # 2 fwd + 4 bwd + 2 remat re-fwd
    else:
        mm = 2.0 * n_active * t_tokens

    # ---- attention flops ----
    layers, n_cross = _attn_layers(cfg)
    q_chunk = 512
    attn = 0.0
    w = cfg.sliding_window or 4096
    for (nl, k_) in layers:
        if kind == "decode":
            ctx = s if (k_ == "full" and not swa_only) else min(w, s)
            per = 4.0 * b * cfg.num_heads * cfg.head_dim * ctx
        else:
            ctx = s if (k_ == "full" and not swa_only) else min(w + q_chunk, s)
            per = 4.0 * b * cfg.num_heads * cfg.head_dim * s * ctx
            if kind == "train":
                per *= 4.0                 # flash fwd + recompute-heavy bwd
            elif k_ == "full":
                per *= 0.5                 # prefill causal triangle skip
        attn += nl * per
    if n_cross:  # vlm gated cross layers
        enc = cfg.vision_tokens
        qlen = 1 if kind == "decode" else s
        per = 4.0 * b * cfg.num_heads * cfg.head_dim * qlen * enc
        attn += n_cross * per * (4.0 if kind == "train" else 1.0)
    if cfg.family == "audio":
        enc = cfg.encoder_tokens
        qlen = 1 if kind == "decode" else s
        attn += cfg.num_layers * 4.0 * b * cfg.num_heads * cfg.head_dim \
            * qlen * enc * (4.0 if kind == "train" else 1.0)
        if kind != "decode":  # encoder self-attn
            attn += cfg.encoder_layers * 4.0 * b * cfg.num_heads \
                * cfg.head_dim * enc * enc * (4.0 if kind == "train" else 1.0)

    # ---- SSD flops ----
    ssd = 0.0
    if cfg.ssm_state:
        h_, p_, n_ = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
        q_ = cfg.ssm_chunk
        if kind == "decode":
            per_tok = 2.0 * h_ * p_ * n_ * 2
        else:
            per_tok = 2.0 * h_ * (q_ * 1.0 + q_ * (p_ + n_) / 2 + n_ * p_)
        ssd = cfg.num_layers * t_tokens * per_tok \
            * (3.0 if kind == "train" else 1.0)

    flops = (mm + attn + ssd) / chips

    # ---- HBM bytes per chip ----
    d = cfg.d_model
    t_local = t_tokens / bshards
    if kind == "train":
        # param shard RW (grads, masks, update) + per-layer gathered weights
        hbm = 6.0 * p_bytes / chips + 3.0 * p_bytes / mshards
        hbm += 16.0 * t_local * d * max(cfg.num_layers, 1)   # activations
    elif kind == "prefill":
        hbm = p_bytes / mshards + 8.0 * t_local * d * max(cfg.num_layers, 1)
        hbm += _cache_bytes(cfg, shape, swa_only) / chips    # cache write
    else:
        hbm = p_bytes / mshards                              # weights read
        hbm += _cache_bytes(cfg, shape, swa_only) / chips    # cache read
        hbm += 8.0 * t_local * d * max(cfg.num_layers, 1)

    # ---- collective bytes per chip ----
    if kind == "train":
        coll = 3.0 * p_bytes / mshards               # FSDP AG x2 + RS
        coll += 2.0 * 2.0 * cfg.num_layers * t_local * d * BYTES  # TP ARs
    else:
        coll = 2.0 * 2.0 * cfg.num_layers * t_local * d * BYTES
        if dataclasses.asdict(cfg).get("num_experts"):
            pass
    if cfg.num_experts:
        k_top = cfg.experts_per_token
        coll += 4.0 * t_local * d * BYTES * k_top * cfg.num_layers \
            * (2.0 if kind == "train" else 1.0)      # a2a dispatch+combine

    compute_s = flops / PEAK_FLOPS_BF16
    memory_s = hbm / HBM_BW
    collective_s = coll / ICI_BW_PER_LINK
    model_flops = (6.0 if kind == "train" else 2.0) * n_active * t_tokens
    total = flops * chips
    dominant = max({"compute": compute_s, "memory": memory_s,
                    "collective": collective_s}.items(), key=lambda kv: kv[1])
    return Roofline(
        arch=cfg.name, shape=shape.name, mesh=mesh,
        flops_chip=flops, hbm_bytes_chip=hbm, coll_bytes_chip=coll,
        compute_s=compute_s, memory_s=memory_s, collective_s=collective_s,
        dominant=dominant[0], model_flops=model_flops,
        useful_ratio=model_flops / max(total, 1e-9),
    )


def _cache_bytes(cfg: ModelConfig, shape: InputShape, swa_only: bool) -> float:
    b, s = shape.global_batch, shape.seq_len
    w = cfg.sliding_window or 4096
    per_tok = BYTES * 2 * cfg.num_kv_heads * cfg.head_dim
    if cfg.family == "ssm":
        return cfg.num_layers * b * cfg.ssm_heads * cfg.ssm_head_dim \
            * cfg.ssm_state * 4.0
    total = 0.0
    if cfg.family == "hybrid":
        total += cfg.num_layers * b * min(w, s) * per_tok
        total += cfg.num_layers * b * cfg.ssm_heads * cfg.ssm_head_dim \
            * cfg.ssm_state * 4.0
        return total
    layers, n_cross = _attn_layers(cfg)
    for nl, k_ in layers:
        ctx = s if (k_ == "full" and not swa_only) else min(w, s)
        total += nl * b * ctx * per_tok
    return total


def load_dryrun(arch: str, shape: str, mesh: str) -> dict | None:
    path = os.path.join(DRYRUN_DIR, f"{arch}__{shape}__{mesh}.json")
    if not os.path.exists(path):
        return None
    with open(path) as f:
        return json.load(f)


def full_table(mesh: str = "16x16") -> list[Roofline]:
    rows = []
    for arch in list_configs():
        cfg = get_config(arch)
        for sname, shape in INPUT_SHAPES.items():
            ok, why = shape_applicable(cfg, shape)
            if not ok:
                rows.append(Roofline(arch, sname, mesh, 0, 0, 0, 0, 0, 0,
                                     "skipped", 0, 0, note=why))
                continue
            r = analytic_roofline(cfg, shape, mesh)
            rec = load_dryrun(arch, sname, mesh)
            if rec and rec.get("status") == "ok":
                r.hlo = {
                    "flops": rec["cost"].get("flops"),
                    "bytes": rec["cost"].get("bytes accessed"),
                    "coll_bytes": rec["collectives"]["total_bytes"],
                    "temp_gb": rec["memory"]["temp_size_in_bytes"] / 1e9,
                    "compile_s": rec.get("compile_s"),
                }
            rows.append(r)
    return rows


def markdown_table(rows: list[Roofline]) -> str:
    out = ["| arch | shape | compute s | memory s | collective s | dominant | "
           "MODEL_FLOPS | useful | mem/dev GB |",
           "|---|---|---|---|---|---|---|---|---|"]
    for r in rows:
        if r.dominant == "skipped":
            out.append(f"| {r.arch} | {r.shape} | — | — | — | skip | — | — "
                       f"| — |")
            continue
        tg = f"{r.hlo['temp_gb']:.1f}" if r.hlo else "?"
        out.append(
            f"| {r.arch} | {r.shape} | {r.compute_s:.2e} | {r.memory_s:.2e} "
            f"| {r.collective_s:.2e} | **{r.dominant}** "
            f"| {r.model_flops:.2e} | {r.useful_ratio:.2f} | {tg} |")
    return "\n".join(out)


def main(fast: bool = False):
    import time
    t0 = time.time()
    rows = full_table("16x16")
    us = (time.time() - t0) * 1e6 / max(len(rows), 1)
    print("name,us_per_call,derived")
    for r in rows:
        if r.dominant == "skipped":
            print(f"roofline_{r.arch}_{r.shape},0,skipped")
            continue
        print(f"roofline_{r.arch}_{r.shape},{us:.0f},"
              f"compute={r.compute_s:.3e};memory={r.memory_s:.3e};"
              f"collective={r.collective_s:.3e};dominant={r.dominant};"
              f"useful={r.useful_ratio:.2f}")
    return rows


if __name__ == "__main__":
    main()
