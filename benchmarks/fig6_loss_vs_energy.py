"""Fig. 6: training loss vs cumulative system energy, all six schemes."""
from __future__ import annotations

import time

from benchmarks.common import SCHEMES, ExpConfig, build_env, run_scheme


def run(rounds=60, fast=False):
    cfg = ExpConfig(rounds=rounds)
    env = build_env(cfg)
    out = {}
    for scheme in SCHEMES:
        _, hist = run_scheme(env, scheme, eval_every=10**9)
        out[scheme] = [(m.cumulative_energy, m.train_loss) for m in hist]
    return out


def main(fast: bool = False):
    # fast trims SWEEP POINTS only: shrinking rounds/dataset leaves the
    # calibrated binding-budget regime and scrambles the scheme ordering
    t0 = time.time()
    curves = run(rounds=60, fast=fast)
    us = (time.time() - t0) * 1e6 / max(len(curves), 1)
    print("name,us_per_call,derived")
    for scheme, pts in curves.items():
        e_final, l_final = pts[-1]
        print(f"fig6_{scheme},{us:.0f},"
              f"final_loss={l_final:.4f};energy_used={e_final:.1f}J")
    return curves


if __name__ == "__main__":
    main()
