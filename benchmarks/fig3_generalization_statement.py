"""Fig. 3: data heterogeneity (Dirichlet sigma) -> label skew + phi spread."""
from __future__ import annotations

import time

import numpy as np

from repro.core import phis
from repro.data import make_dataset, partition_by_dirichlet


def run(sigmas=(0.1, 0.5, 1.0, 5.0, 100.0), n_clients=10, seed=0):
    ds = make_dataset("synthetic-mnist", n_train=4000, n_test=800, seed=seed)
    test_hist = np.bincount(ds.y_test, minlength=10).astype(float)
    rows = []
    for sigma in sigmas:
        parts = partition_by_dirichlet(ds.y_train, n_clients, sigma,
                                       rng=np.random.default_rng(seed))
        hists = np.stack([np.bincount(ds.y_train[p], minlength=10)
                          for p in parts]).astype(float)
        ph = phis(hists, test_hist[None])
        skew = np.std(hists / hists.sum(axis=1, keepdims=True), axis=1).mean()
        rows.append({
            "sigma": sigma,
            "label_skew": float(skew),
            "phi_mean": float(ph.mean()),
            "phi_std": float(ph.std()),
            "phi_max": float(ph.max()),
        })
    return rows


def main(fast: bool = False):
    t0 = time.time()
    rows = run(sigmas=(0.1, 1.0, 5.0) if fast else (0.1, 0.5, 1.0, 5.0, 100.0))
    us = (time.time() - t0) * 1e6 / max(len(rows), 1)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"fig3_sigma_{r['sigma']},{us:.0f},"
              f"skew={r['label_skew']:.4f};phi_mean={r['phi_mean']:.3g};"
              f"phi_std={r['phi_std']:.3g}")
    # monotonicity check: higher sigma => more balance => smaller phi spread
    assert rows[0]["phi_mean"] >= rows[-1]["phi_mean"]
    return rows


if __name__ == "__main__":
    main()
