"""Selection ablation: quantify the Theorem-1 coupling finding.

Three variants of the proposed scheme differing ONLY in (P5):
  paper+mean   paper heuristic, mean-coupled phi term   (benchmark default)
  paper+sum    paper heuristic, literal Thm-1 summand
  exact+sum    2^N-exact minimizer of the literal summand (degenerates)

Reports theta (the bound each minimizes), clients/round and final accuracy —
showing that the LOWEST bound value trains WORST (EXPERIMENTS.md §Paper
finding 1 made quantitative).
"""
from __future__ import annotations

import dataclasses
import time

import numpy as np

from benchmarks.common import ExpConfig, build_env, final_accuracy
from repro.core import AOConfig, BoundConstants, FederatedTrainer, solve_p1
import jax


def run(rounds=60):
    cfg = ExpConfig(rounds=rounds)
    env = build_env(cfg)
    c = BoundConstants(rounds_S=cfg.rounds - 1, batch_Z=cfg.batch, eta=cfg.eta)
    variants = {
        "paper+mean": AOConfig(outer_iters=3, selection_method="paper",
                               phi_coupling="mean"),
        "paper+sum": AOConfig(outer_iters=3, selection_method="paper",
                              phi_coupling="sum"),
        "exact+sum": AOConfig(outer_iters=3, selection_method="exact",
                              phi_coupling="sum"),
    }
    rows = {}
    for name, ao in variants.items():
        sched = solve_p1(env.phi, cfg.e0, cfg.t0, env.ch.uplink,
                         env.ch.downlink, env.sp, c, ao)
        tr = FederatedTrainer(env.loss_fn, env.init_fn(jax.random.key(0)),
                              env.clients, eta=cfg.eta, batch_size=cfg.batch,
                              seed=cfg.seed)
        hist = tr.run(sched, env.sp, env.ch.uplink, env.ch.downlink,
                      eval_fn=env.eval_fn, eval_every=cfg.rounds - 1,
                      stop_delay=cfg.t0, stop_energy=cfg.e0)
        acc, acc_round = final_accuracy(hist)
        rows[name] = {
            "theta": sched.theta,
            "clients_per_round": float(sched.a.sum(axis=1).mean()),
            "final_accuracy": acc,
            "final_accuracy_round": acc_round,
        }
    return rows


def main(fast: bool = False):
    t0 = time.time()
    rows = run()
    us = (time.time() - t0) * 1e6 / max(len(rows), 1)
    print("name,us_per_call,derived")
    for name, r in rows.items():
        print(f"selection_{name},{us:.0f},theta={r['theta']:.3f};"
              f"clients={r['clients_per_round']:.1f};"
              f"acc={r['final_accuracy']:.3f}")
    # the structural finding: exact+sum achieves the smallest bound value
    assert rows["exact+sum"]["theta"] <= rows["paper+mean"]["theta"] + 1e-6
    assert rows["exact+sum"]["clients_per_round"] <= \
        rows["paper+mean"]["clients_per_round"]
    return rows


if __name__ == "__main__":
    main()
