"""Empirical validation of the paper's theory (beyond the figures):

* Lemma 1: measure ||grad_train - grad_test|| / ||grad_train|| during
  training per client and check it's bounded by phi_n (and correlates with
  phi_n in *ranking* — the property the selection rule actually uses).
* Proposition 1: measure the per-round generalization-gap increment
  |phi^(s+1) - phi^(s)| := |(L_train - L_test)^(s+1) - (...)^(s)| and check
  the Prop-1 upper bound holds.
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np
from scipy import stats

from repro.core import (ClientData, FederatedTrainer,
                        generalization_gap_increment_bound, phis)
from repro.core.optimizer_ao import Schedule
from repro.data import make_dataset, partition_by_dirichlet
from repro.models import lenet_apply, lenet_init, make_loss_fn
from repro.wireless import ChannelModel, SystemParams

N = 8


def run(rounds=30, sigma=0.5, seed=0):
    ds = make_dataset("synthetic-mnist", n_train=3000, n_test=600, seed=seed)
    parts = partition_by_dirichlet(ds.y_train, N, sigma,
                                   rng=np.random.default_rng(seed))
    clients = [ClientData(ds.x_train[i], ds.y_train[i]) for i in parts]
    test_hist = np.bincount(ds.y_test, minlength=10).astype(float)
    phi = phis(np.stack([c.label_histogram(10) for c in clients]),
               test_hist[None])

    loss_fn = make_loss_fn(lenet_apply)
    grad_fn = jax.jit(jax.grad(loss_fn))
    loss_jit = jax.jit(loss_fn)
    xt, yt = jnp.asarray(ds.x_test), jnp.asarray(ds.y_test)

    def gnorm(tree):
        return float(jnp.sqrt(sum(jnp.sum(jnp.square(g))
                                  for g in jax.tree.leaves(tree))))

    def gdiff(a, b):
        return float(jnp.sqrt(sum(jnp.sum(jnp.square(x - y)) for x, y in zip(
            jax.tree.leaves(a), jax.tree.leaves(b)))))

    trainer = FederatedTrainer(loss_fn, lenet_init(jax.random.key(seed)),
                               clients, eta=0.1, batch_size=32, seed=seed)
    a = np.ones((rounds, N))
    sched = Schedule(a=a, lam=0.0 * a, power=0.3 * a, freq=3e8 * a,
                     theta=0, energy=0, delay=0, feasible=True)
    sp = SystemParams.table1(N)
    ch = ChannelModel(N)

    # Lemma 1: per-client gradient discrepancy ratios mid-training
    trainer.run(sched, sp, ch.uplink, ch.downlink)  # warm training
    params = trainer.params
    g_test = grad_fn(params, xt, yt)
    ratios = []
    for n in range(N):
        xc = jnp.asarray(clients[n].x)
        yc = jnp.asarray(clients[n].y)
        g_tr = grad_fn(params, xc, yc)
        ratios.append(gdiff(g_tr, g_test) / max(gnorm(g_tr), 1e-9))
    rho = stats.spearmanr(ratios, phi).statistic
    bounded = all(r <= max(p, 1.0) for r, p in zip(ratios, phi))

    # Proposition 1: gap-increment bound along a fresh run
    trainer2 = FederatedTrainer(loss_fn, lenet_init(jax.random.key(seed)),
                                clients, eta=0.1, batch_size=32, seed=seed)
    gaps, bounds = [], []
    xtr_all = jnp.asarray(ds.x_train)
    ytr_all = jnp.asarray(ds.y_train)
    prev_gap = None
    holds = 0
    total = 0
    for s in range(rounds):
        grads, losses = [], []
        for n in range(N):
            g, _, loss = trainer2.client_update(n, 0.0)
            grads.append(g)
        trainer2.server_step(grads)
        l_tr = float(loss_jit(trainer2.params, xtr_all, ytr_all))
        l_te = float(loss_jit(trainer2.params, xt, yt))
        gap = l_tr - l_te
        if prev_gap is not None:
            g_sq = gnorm(trainer2.global_grad) ** 2
            bound = generalization_gap_increment_bound(phi, 0.1, g_sq)
            total += 1
            if gap - prev_gap <= bound + 1e-9:
                holds += 1
            gaps.append(gap - prev_gap)
            bounds.append(bound)
        prev_gap = gap
    return {
        "lemma1_spearman": float(rho),
        "lemma1_bounded": bool(bounded),
        "prop1_holds_frac": holds / max(total, 1),
        "mean_gap_increment": float(np.mean(gaps)),
        "mean_bound": float(np.mean(bounds)),
    }


def main(fast: bool = False):
    t0 = time.time()
    # 30 warm rounds regardless of profile: a half-trained model's
    # gradient ratios are noise and the Lemma-1 Spearman signal vanishes
    r = run(rounds=30)
    us = (time.time() - t0) * 1e6
    print("name,us_per_call,derived")
    print(f"theory_lemma1,{us:.0f},spearman={r['lemma1_spearman']:.3f};"
          f"bounded={r['lemma1_bounded']}")
    print(f"theory_prop1,{us:.0f},holds_frac={r['prop1_holds_frac']:.2f};"
          f"mean_increment={r['mean_gap_increment']:.2e};"
          f"mean_bound={r['mean_bound']:.2e}")
    return r


if __name__ == "__main__":
    main()
