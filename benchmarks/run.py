"""Benchmark entry point — one harness per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--full | --smoke]

Default is the fast profile (reduced sigmas/budgets/rounds) so the whole
suite completes on one CPU core; --full reproduces the paper-scale sweeps.
--smoke is the CI profile: the round-engine harness, the sweep-service
scaling probe, and the fleet-streaming probe, tiny configs, with reports
diffed against the committed BENCH_round_engine.json /
BENCH_sweep_scaling.json / BENCH_fleet_scaling.json (the cross-PR compare
mode) so perf regressions surface without running the whole suite.
Output: ``name,us_per_call,derived`` CSV per harness.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: round_engine only, compared against the "
                         "committed BENCH_round_engine.json")
    ap.add_argument("--only", default=None,
                    help="comma list: fig3,...,fig8,theory,selection,"
                         "roofline,round_engine,sweep_scaling,fleet_scaling")
    args = ap.parse_args()
    fast = not args.full

    if args.smoke:
        from benchmarks import round_engine
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        report = round_engine.main(
            fast=True, smoke=True,
            compare=os.path.join(root, "BENCH_round_engine.json"))
        rows = report.get("compare", {}).get("rows", [])
        if not rows:
            # a gate that silently checked nothing must not stay green
            print("FAILED: smoke compare produced no rows (baseline "
                  "missing or no overlapping configs)")
            sys.exit(1)
        # block-mode gates: the sweep must have run, must have been
        # compared against the committed baseline, and the block path must
        # not have uploaded any per-round batch data
        block = report.get("block_sweep")
        if not block:
            print("FAILED: smoke did not run the rounds_per_dispatch sweep")
            sys.exit(1)
        if not any(r["config"].startswith("block/") for r in rows):
            print("FAILED: no block-mode rows in the compare (committed "
                  "baseline predates the block sweep? re-run the fast "
                  "profile to refresh BENCH_round_engine.json)")
            sys.exit(1)
        leaky = [rpd for rpd, p in block["per_rpd"].items()
                 if rpd != "1" and p["batch_h2d_uploads_per_round"] != 0]
        if leaky:
            print("FAILED: block path uploaded per-round batch data at "
                  "rounds_per_dispatch", leaky)
            sys.exit(1)
        # Block speedups are throttle-sensitive in a way the interleaved
        # packed-vs-reference ratio is not: one K-round dispatch is a long
        # uninterrupted compute burst, so cgroup CFS throttling hits it
        # harder than K short dispatches whose host gaps refill the quota
        # (measured on this box: 1.65x quiet -> 0.93x under load at rpd=8,
        # see ROADMAP). The 10% delta rule therefore only WARNS for block
        # rows; the hard gate is an absolute floor that load noise never
        # reaches but structural regressions (a reintroduced per-round
        # sync/upload, a per-block retrace storm) do.
        block_floor = 0.75
        warned = [r["config"] for r in rows
                  if r["config"].startswith("block/") and r["regressed"]]
        if warned:
            print("WARNING: block speedup below committed baseline "
                  "(throttle-sensitive, not gated):", warned)
        # the floor is an absolute ratio from THIS run, so it needs no
        # baseline overlap — every swept rpd leg is covered even when the
        # committed report predates a change to the rpd ladder
        floored = [f"rpd{r}" for r, p in block["per_rpd"].items()
                   if r != "1" and p["speedup_vs_1"] < block_floor]
        if floored:
            print(f"FAILED: block speedup below the {block_floor} floor "
                  "(structural regression):", floored)
            sys.exit(1)
        regressed = [r["config"] for r in rows
                     if r["regressed"] and not r["config"].startswith("block/")]
        if regressed:
            print("FAILED: speedup regression vs committed report:",
                  regressed)
            sys.exit(1)
        # sweep-service gates: parity is checked inside main() (it raises
        # on a bitwise violation); the speedup ratio only fails on a
        # structural collapse vs the committed baseline
        from benchmarks import sweep_scaling
        sc = sweep_scaling.main(
            fast=True,
            compare=os.path.join(root, "BENCH_sweep_scaling.json"))
        if sc.get("compare", {}).get("regressed_floor"):
            print("FAILED: sweep-service worker-pool speedup collapsed vs "
                  "committed BENCH_sweep_scaling.json")
            sys.exit(1)
        # fleet-streaming gates: streamed-vs-replicated parity and the
        # flat-peak invariant are checked inside main() (it raises on
        # either violation); the compare adds the committed-baseline peak
        # gate — peak device bytes growing past the flat factor is a HARD
        # failure (cohort residency regressing toward population
        # residency), wall-clock deltas warn inside _compare only
        from benchmarks import fleet_scaling
        fs = fleet_scaling.main(
            fast=True,
            compare=os.path.join(root, "BENCH_fleet_scaling.json"))
        if fs.get("compare", {}).get("peak_regressed"):
            print("FAILED: fleet-streaming peak device bytes regressed vs "
                  "committed BENCH_fleet_scaling.json")
            sys.exit(1)
        return

    from benchmarks import (fig3_generalization_statement, fig4_accuracy_vs_sigma,
                            fig5_loss_vs_time, fig6_loss_vs_energy,
                            fig7_accuracy_vs_delay, fig8_accuracy_vs_energy,
                            fleet_scaling, roofline, round_engine,
                            selection_ablation, sweep_scaling,
                            theory_validation)
    suite = {
        "fig3": fig3_generalization_statement.main,
        "fig4": fig4_accuracy_vs_sigma.main,
        "fig5": fig5_loss_vs_time.main,
        "fig6": fig6_loss_vs_energy.main,
        "fig7": fig7_accuracy_vs_delay.main,
        "fig8": fig8_accuracy_vs_energy.main,
        "theory": theory_validation.main,
        "selection": selection_ablation.main,
        "roofline": roofline.main,
        "round_engine": round_engine.main,
        "sweep_scaling": sweep_scaling.main,
        "fleet_scaling": fleet_scaling.main,
    }
    only = set(args.only.split(",")) if args.only else set(suite)
    failures = []
    for name, fn in suite.items():
        if name not in only:
            continue
        print(f"== {name} ==", flush=True)
        t0 = time.time()
        try:
            fn(fast=fast)
        except Exception:
            failures.append(name)
            traceback.print_exc()
        print(f"== {name} done in {time.time() - t0:.1f}s ==", flush=True)
    if failures:
        print("FAILED:", failures)
        sys.exit(1)


if __name__ == "__main__":
    main()
