"""Benchmark entry point — one harness per paper table/figure + roofline.

    PYTHONPATH=src python -m benchmarks.run [--full | --smoke]

Default is the fast profile (reduced sigmas/budgets/rounds) so the whole
suite completes on one CPU core; --full reproduces the paper-scale sweeps.
--smoke is the CI profile: only the round-engine harness, tiny config, with
its report diffed against the committed BENCH_round_engine.json (the
cross-PR compare mode) so perf regressions surface without running the
whole suite. Output: ``name,us_per_call,derived`` CSV per harness.
"""
from __future__ import annotations

import argparse
import os
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--smoke", action="store_true",
                    help="CI smoke: round_engine only, compared against the "
                         "committed BENCH_round_engine.json")
    ap.add_argument("--only", default=None,
                    help="comma list: fig3,...,fig8,theory,selection,"
                         "roofline,round_engine")
    args = ap.parse_args()
    fast = not args.full

    if args.smoke:
        from benchmarks import round_engine
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        report = round_engine.main(
            fast=True, smoke=True,
            compare=os.path.join(root, "BENCH_round_engine.json"))
        rows = report.get("compare", {}).get("rows", [])
        if not rows:
            # a gate that silently checked nothing must not stay green
            print("FAILED: smoke compare produced no rows (baseline "
                  "missing or no overlapping configs)")
            sys.exit(1)
        regressed = [r["config"] for r in rows if r["regressed"]]
        if regressed:
            print("FAILED: speedup regression vs committed report:",
                  regressed)
            sys.exit(1)
        return

    from benchmarks import (fig3_generalization_statement, fig4_accuracy_vs_sigma,
                            fig5_loss_vs_time, fig6_loss_vs_energy,
                            fig7_accuracy_vs_delay, fig8_accuracy_vs_energy,
                            roofline, round_engine, selection_ablation,
                            theory_validation)
    suite = {
        "fig3": fig3_generalization_statement.main,
        "fig4": fig4_accuracy_vs_sigma.main,
        "fig5": fig5_loss_vs_time.main,
        "fig6": fig6_loss_vs_energy.main,
        "fig7": fig7_accuracy_vs_delay.main,
        "fig8": fig8_accuracy_vs_energy.main,
        "theory": theory_validation.main,
        "selection": selection_ablation.main,
        "roofline": roofline.main,
        "round_engine": round_engine.main,
    }
    only = set(args.only.split(",")) if args.only else set(suite)
    failures = []
    for name, fn in suite.items():
        if name not in only:
            continue
        print(f"== {name} ==", flush=True)
        t0 = time.time()
        try:
            fn(fast=fast)
        except Exception:
            failures.append(name)
            traceback.print_exc()
        print(f"== {name} done in {time.time() - t0:.1f}s ==", flush=True)
    if failures:
        print("FAILED:", failures)
        sys.exit(1)


if __name__ == "__main__":
    main()
