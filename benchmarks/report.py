"""Generate the data-driven sections of EXPERIMENTS.md from artifacts:
§Dry-run table (experiments/dryrun/*.json), §Roofline table, and §Runs —
a summary of RunResult JSON-lines files (the shared metrics format the
experiment API's `RunResult.to_jsonl` and `benchmarks.common.run_scheme(
out=...)` both emit).

    PYTHONPATH=src python -m benchmarks.report > experiments/report.md
    PYTHONPATH=src python -m benchmarks.report --runs 'experiments/runs/*.jsonl'
"""
from __future__ import annotations

import argparse
import glob
import json
import os

from benchmarks.roofline import DRYRUN_DIR, full_table, load_dryrun
from repro.configs.registry import INPUT_SHAPES, list_configs

DEFAULT_RUNS_GLOB = "experiments/runs/*.jsonl"


def dryrun_table() -> str:
    out = ["| arch | shape | mesh | status | compile s | args GB | temp GB | "
           "HLO flops (body-once) | coll GB (HLO) | coll ops |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for arch in list_configs():
        for shape in INPUT_SHAPES:
            for mesh in ("16x16", "2x16x16"):
                r = load_dryrun(arch, shape, mesh)
                if r is None:
                    out.append(f"| {arch} | {shape} | {mesh} | MISSING | | | | | | |")
                    continue
                if r["status"] == "skipped":
                    out.append(f"| {arch} | {shape} | {mesh} | skip (DESIGN §5) "
                               f"| | | | | | |")
                    continue
                mem = r["memory"]
                counts = r["collectives"]["counts"]
                cstr = " ".join(f"{k.split('-')[-1] if False else k}:{v}"
                                for k, v in sorted(counts.items()))
                out.append(
                    f"| {arch} | {shape} | {mesh} | ok | {r['compile_s']} "
                    f"| {mem['argument_size_in_bytes']/1e9:.2f} "
                    f"| {mem['temp_size_in_bytes']/1e9:.2f} "
                    f"| {r['cost'].get('flops', 0):.2e} "
                    f"| {r['collectives']['total_bytes']/1e9:.2f} "
                    f"| {cstr} |")
    return "\n".join(out)


def roofline_md() -> str:
    from benchmarks.roofline import markdown_table
    return markdown_table(full_table("16x16"))


def load_run(path: str):
    """Ingest one RunResult JSON-lines file (repro.api.RunResult)."""
    from repro.api import RunResult
    return RunResult.from_jsonl(path)


def runs_table(paths) -> str:
    """Markdown summary of RunResult JSONL exports, one row per run."""
    out = ["| run | dataset | model | scheme | rounds | final acc @ round | "
           "E used [J] | T used [s] | theta | feasible |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for path in sorted(paths):
        r = load_run(path)
        s = r.summary
        spec = r.spec or {}
        name = os.path.splitext(os.path.basename(path))[0]

        def num(key, default=float("nan")):
            # strict-JSON exports write nan as null -> json None
            v = s.get(key)
            return default if v is None else v

        out.append(
            f"| {name} "
            f"| {spec.get('data', {}).get('dataset', '?')} "
            f"| {spec.get('model', {}).get('name', '?')} "
            f"| {spec.get('scheme', {}).get('name', '?')} "
            f"| {s.get('rounds_run', len(r.history))} "
            f"| {num('final_accuracy'):.3f} @ "
            f"{num('final_accuracy_round', -1)} "
            f"| {num('cumulative_energy', 0.0):.2f} "
            f"| {num('cumulative_delay', 0.0):.2f} "
            f"| {num('theta'):.3f} "
            f"| {s.get('feasible', '?')} |")
    return "\n".join(out)


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--runs", default=DEFAULT_RUNS_GLOB,
                   help="glob of RunResult JSONL files to summarize")
    args = p.parse_args(argv)
    print("## §Dry-run — 10 archs x 4 shapes x {16x16, 2x16x16}\n")
    print(dryrun_table())
    print("\n\n## §Roofline — single-pod (16x16), analytic terms\n")
    print(roofline_md())
    run_paths = glob.glob(args.runs)
    if run_paths:
        print(f"\n\n## §Runs — {len(run_paths)} RunResult export(s) "
              f"({args.runs})\n")
        print(runs_table(run_paths))


if __name__ == "__main__":
    main()
