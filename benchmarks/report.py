"""Generate the data-driven sections of EXPERIMENTS.md from artifacts:
§Dry-run table (experiments/dryrun/*.json), §Roofline table, and §Runs —
a summary of RunResult JSON-lines files (the shared metrics format the
experiment API's `RunResult.to_jsonl` and `benchmarks.common.run_scheme(
out=...)` both emit).

    PYTHONPATH=src python -m benchmarks.report > experiments/report.md
    PYTHONPATH=src python -m benchmarks.report --runs 'experiments/runs/*.jsonl'
"""
from __future__ import annotations

import argparse
import copy
import glob
import json
import math
import os
import re

from benchmarks.roofline import DRYRUN_DIR, full_table, load_dryrun
from repro.configs.registry import INPUT_SHAPES, list_configs

DEFAULT_RUNS_GLOB = "experiments/runs/*.jsonl"


def dryrun_table() -> str:
    out = ["| arch | shape | mesh | status | compile s | args GB | temp GB | "
           "HLO flops (body-once) | coll GB (HLO) | coll ops |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for arch in list_configs():
        for shape in INPUT_SHAPES:
            for mesh in ("16x16", "2x16x16"):
                r = load_dryrun(arch, shape, mesh)
                if r is None:
                    out.append(f"| {arch} | {shape} | {mesh} | MISSING | | | | | | |")
                    continue
                if r["status"] == "skipped":
                    out.append(f"| {arch} | {shape} | {mesh} | skip (DESIGN §5) "
                               f"| | | | | | |")
                    continue
                mem = r["memory"]
                counts = r["collectives"]["counts"]
                cstr = " ".join(f"{k.split('-')[-1] if False else k}:{v}"
                                for k, v in sorted(counts.items()))
                out.append(
                    f"| {arch} | {shape} | {mesh} | ok | {r['compile_s']} "
                    f"| {mem['argument_size_in_bytes']/1e9:.2f} "
                    f"| {mem['temp_size_in_bytes']/1e9:.2f} "
                    f"| {r['cost'].get('flops', 0):.2e} "
                    f"| {r['collectives']['total_bytes']/1e9:.2f} "
                    f"| {cstr} |")
    return "\n".join(out)


def roofline_md() -> str:
    from benchmarks.roofline import markdown_table
    return markdown_table(full_table("16x16"))


def load_run(path: str):
    """Ingest one RunResult JSON-lines file (repro.api.RunResult)."""
    from repro.api import RunResult
    return RunResult.from_jsonl(path)


def _parseable_runs(paths) -> list:
    """(path, RunResult) pairs, skipping files that are not RunResult
    exports (e.g. a sweep directory's `sweep.jsonl` index, whose records
    `RunResult.from_jsonl` ignores, leaving an empty shell)."""
    out = []
    for path in sorted(paths):
        r = load_run(path)
        if r.spec or r.summary or r.history:
            out.append((path, r))
    return out


def load_sweep_errors(paths) -> list[dict]:
    """`sweep_error` records from any sweep index files among `paths`
    (JsonlDirSink appends one per permanently failed cell, with
    error_kind "error" or "timeout"). Non-index files contribute nothing;
    unparsable lines are skipped, mirroring RunResult.from_jsonl's
    forward-compatible ingestion."""
    out = []
    for path in sorted(paths):
        try:
            with open(path) as f:
                for line in f:
                    line = line.strip()
                    if not line:
                        continue
                    try:
                        rec = json.loads(line)
                    except json.JSONDecodeError:
                        continue
                    if isinstance(rec, dict) and \
                            rec.get("kind") == "sweep_error":
                        out.append(rec)
        except OSError:
            continue
    return out


def runs_table(paths, errors=None) -> str:
    """Markdown summary of RunResult JSONL exports, one row per run.
    FAILED/TIMEOUT cells (sweep_error records from a sweep index among
    `paths`, or passed via `errors=`) render as rows too — a partial
    sweep is visible in the report instead of silently shrinking it."""
    if errors is None:
        errors = load_sweep_errors(paths)
    out = ["| run | dataset | model | scheme | status | rounds | "
           "final acc @ round | E used [J] | T used [s] | theta | feasible "
           "| faults (drop/quar/skip) | aggregation "
           "| fleet (swaps/H2D MB/stall s) |",
           "|---|---|---|---|---|---|---|---|---|---|---|---|---|---|"]
    rows = []
    for path, r in _parseable_runs(paths):
        s = r.summary
        spec = r.spec or {}
        name = os.path.splitext(os.path.basename(path))[0]

        def num(key, fmt, default=None):
            # strict-JSON exports write nan as null -> json None; a run
            # without the field at all (no-eval runs, older exports, a
            # torn file missing its summary record) renders an em-dash
            # instead of leaking "nan" into the table
            v = s.get(key, default)
            if v is None or not isinstance(v, (int, float)) \
                    or (isinstance(v, float) and math.isnan(v)):
                return "—"
            return format(v, fmt)

        # degradation counters ride the summary only when a fault model
        # was active (or something was actually quarantined); the
        # isinstance guards keep a mixed-vintage directory (sections
        # absent, null, or reshaped by older writers) from crashing the
        # whole report
        f = s.get("faults")
        faults = ("—" if not isinstance(f, dict) or not f else
                  f"{f.get('n_dropped', 0)}/{f.get('n_quarantined', 0)}"
                  f"/{f.get('n_skipped_rounds', 0)}")
        # robust-aggregation counters ride the summary only when a
        # non-mean aggregator was active (core/aggregators.py)
        a = s.get("aggregation")
        agg = ("—" if not isinstance(a, dict) or not a else
               a.get("aggregator", "?") + " " + " ".join(
                   f"{k}={v}" for k, v in sorted(a.items())
                   if k != "aggregator"))
        # cohort-streaming counters ride the summary only when the run
        # actually streamed (core/cohort_store.py)
        fl = s.get("fleet")
        fleet = ("—" if not isinstance(fl, dict) or not fl else
                 f"{fl.get('n_cohort_swaps', 0)}"
                 f"/{fl.get('h2d_bytes', 0) / 2**20:.1f}"
                 f"/{fl.get('prefetch_stall_s', 0.0):.3f}")
        acc = num("final_accuracy", ".3f")
        if acc != "—":
            acc = f"{acc} @ {s.get('final_accuracy_round', -1)}"
        rows.append((name,
            f"| {name} "
            f"| {spec.get('data', {}).get('dataset', '?')} "
            f"| {spec.get('model', {}).get('name', '?')} "
            f"| {spec.get('scheme', {}).get('name', '?')} "
            f"| ok "
            f"| {s.get('rounds_run', len(r.history))} "
            f"| {acc} "
            f"| {num('cumulative_energy', '.2f', 0.0)} "
            f"| {num('cumulative_delay', '.2f', 0.0)} "
            f"| {num('theta', '.3f')} "
            f"| {s.get('feasible', '?')} "
            f"| {faults} | {agg} | {fleet} |"))
    for rec in errors:
        name = rec.get("name", "?")
        spec = rec.get("spec") or {}
        status = ("TIMEOUT" if rec.get("error_kind") == "timeout"
                  else "FAILED")
        err = (rec.get("error") or "").split("\n")[0]
        rows.append((name,
            f"| {name} "
            f"| {spec.get('data', {}).get('dataset', '?')} "
            f"| {spec.get('model', {}).get('name', '?')} "
            f"| {spec.get('scheme', {}).get('name', '?')} "
            f"| {status}: {err} "
            f"| — | — | — | — | — | — | — | — | — |"))
    # failed cells sort into matrix position (names share the NNN_ index
    # prefix), not into a separate trailing block
    out.extend(row for _, row in sorted(rows))
    return "\n".join(out)


def _seedless_key(spec: dict) -> str:
    """Canonical grouping key for seed aggregation: the spec with every
    seed field (data / wireless / run) and the checkpoint dir stripped.
    Runs that differ ONLY in seeds are repetitions of one scenario."""
    s = copy.deepcopy(spec) if spec else {}
    for section, key in (("data", "seed"), ("wireless", "seed"),
                         ("run", "seed")):
        s.get(section, {}).pop(key, None)
    s.get("run", {}).pop("checkpoint_dir", None)
    return json.dumps(s, sort_keys=True)


def _mean_std(values) -> tuple[float, float, int]:
    vals = [v for v in values if v is not None and not math.isnan(v)]
    n = len(vals)
    if not n:
        return float("nan"), float("nan"), 0
    mean = sum(vals) / n
    std = math.sqrt(sum((v - mean) ** 2 for v in vals) / n)
    return mean, std, n


def aggregate_runs(paths, errors=None) -> list[dict]:
    """Group RunResult exports by seed-stripped spec and summarize each
    group with per-seed variance: final_accuracy / energy / delay as
    (mean, std, n) instead of a bare scalar. Groups of one pass through
    (std 0, n 1) so the caller can render a uniform table. sweep_error
    records (auto-loaded from index files among `paths` when `errors` is
    None) count into their scenario's `n_failed` so a partial sweep's
    aggregates say how many seeds are missing."""
    if errors is None:
        errors = load_sweep_errors(paths)
    failed: dict[str, int] = {}
    for rec in errors:
        key = _seedless_key(rec.get("spec") or {})
        failed[key] = failed.get(key, 0) + 1
    groups: dict[str, list] = {}
    for path, r in _parseable_runs(paths):
        groups.setdefault(_seedless_key(r.spec), []).append((path, r))
    rows = []
    for key in sorted(set(groups) | set(failed)):
        if key not in groups:
            # every seed of this scenario failed: synthesize a row from
            # the error record so the scenario still shows up
            rec = next(e for e in errors
                       if _seedless_key(e.get("spec") or {}) == key)
            spec = rec.get("spec") or {}
            nan3 = (float("nan"), float("nan"), 0)
            rows.append({
                "group": rec.get("name", "?"),
                "dataset": spec.get("data", {}).get("dataset", "?"),
                "model": spec.get("model", {}).get("name", "?"),
                "scheme": spec.get("scheme", {}).get("name", "?"),
                "n": 0, "n_failed": failed[key],
                "final_accuracy": nan3, "cumulative_energy": nan3,
                "cumulative_delay": nan3,
            })
            continue
        runs = groups[key]
        spec = runs[0][1].spec or {}
        names = [os.path.splitext(os.path.basename(p))[0] for p, _ in runs]
        # scenario label: the first member's name minus the parts that vary
        # within the group (the sweep's NNN_ matrix index and seed=N axis
        # segments) — "003_sigma=0.5_scheme=no_gen_seed=1" -> the scenario
        # "sigma=0.5_scheme=no_gen"
        label = re.sub(r"^\d+_", "", names[0])
        label = re.sub(r"(^|_)seed=[^_]+", "", label).strip("_") or names[0]
        row = {
            "group": label + (f" (n={len(runs)})" if len(runs) > 1 else ""),
            "dataset": spec.get("data", {}).get("dataset", "?"),
            "model": spec.get("model", {}).get("name", "?"),
            "scheme": spec.get("scheme", {}).get("name", "?"),
            "n": len(runs),
            "n_failed": failed.get(key, 0),
        }
        for field in ("final_accuracy", "cumulative_energy",
                      "cumulative_delay"):
            row[field] = _mean_std(r.summary.get(field) for _, r in runs)
        rows.append(row)
    return rows


def sweep_table(paths=None, *, rows=None) -> str:
    """Markdown seed-aggregated summary (mean ± std, n) of RunResult
    exports — the §Runs companion for sweep output directories. Pass
    `rows=` (an `aggregate_runs` result) to render without re-parsing."""
    if rows is None:
        rows = aggregate_runs(paths)
    out = ["| scenario | dataset | model | scheme | n | failed | "
           "final acc (mean ± std) | E used [J] | T used [s] |",
           "|---|---|---|---|---|---|---|---|---|"]

    def ms(t, digits):
        mean, std, n = t
        if n == 0:
            return "—"
        return f"{mean:.{digits}f} ± {std:.{digits}f}"

    for row in rows:
        nf = row.get("n_failed", 0)
        out.append(
            f"| {row['group']} | {row['dataset']} | {row['model']} "
            f"| {row['scheme']} | {row['n']} | {nf if nf else '—'} "
            f"| {ms(row['final_accuracy'], 3)} "
            f"| {ms(row['cumulative_energy'], 2)} "
            f"| {ms(row['cumulative_delay'], 2)} |")
    return "\n".join(out)


def main(argv=None):
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--runs", default=DEFAULT_RUNS_GLOB,
                   help="glob of RunResult JSONL files to summarize")
    args = p.parse_args(argv)
    print("## §Dry-run — 10 archs x 4 shapes x {16x16, 2x16x16}\n")
    print(dryrun_table())
    print("\n\n## §Roofline — single-pod (16x16), analytic terms\n")
    print(roofline_md())
    run_paths = glob.glob(args.runs)
    if run_paths:
        errors = load_sweep_errors(run_paths)
        print(f"\n\n## §Runs — {len(run_paths)} RunResult export(s) "
              f"({args.runs})"
              + (f", {len(errors)} FAILED/TIMEOUT cell(s)" if errors
                 else "") + "\n")
        print(runs_table(run_paths, errors))
        rows = aggregate_runs(run_paths, errors)
        # failures force the aggregated section too: that is where the
        # per-scenario failed counts live
        if any(row["n"] > 1 or row.get("n_failed") for row in rows):
            print("\n\n## §Runs, seed-aggregated — mean ± std over "
                  "seed-only repetitions\n")
            print(sweep_table(rows=rows))


if __name__ == "__main__":
    main()
