"""Generate the data-driven sections of EXPERIMENTS.md from artifacts:
§Dry-run table (experiments/dryrun/*.json) and §Roofline table.

    PYTHONPATH=src python -m benchmarks.report > experiments/report.md
"""
from __future__ import annotations

import json
import os

from benchmarks.roofline import DRYRUN_DIR, full_table, load_dryrun
from repro.configs.registry import INPUT_SHAPES, list_configs


def dryrun_table() -> str:
    out = ["| arch | shape | mesh | status | compile s | args GB | temp GB | "
           "HLO flops (body-once) | coll GB (HLO) | coll ops |",
           "|---|---|---|---|---|---|---|---|---|---|"]
    for arch in list_configs():
        for shape in INPUT_SHAPES:
            for mesh in ("16x16", "2x16x16"):
                r = load_dryrun(arch, shape, mesh)
                if r is None:
                    out.append(f"| {arch} | {shape} | {mesh} | MISSING | | | | | | |")
                    continue
                if r["status"] == "skipped":
                    out.append(f"| {arch} | {shape} | {mesh} | skip (DESIGN §5) "
                               f"| | | | | | |")
                    continue
                mem = r["memory"]
                counts = r["collectives"]["counts"]
                cstr = " ".join(f"{k.split('-')[-1] if False else k}:{v}"
                                for k, v in sorted(counts.items()))
                out.append(
                    f"| {arch} | {shape} | {mesh} | ok | {r['compile_s']} "
                    f"| {mem['argument_size_in_bytes']/1e9:.2f} "
                    f"| {mem['temp_size_in_bytes']/1e9:.2f} "
                    f"| {r['cost'].get('flops', 0):.2e} "
                    f"| {r['collectives']['total_bytes']/1e9:.2f} "
                    f"| {cstr} |")
    return "\n".join(out)


def roofline_md() -> str:
    from benchmarks.roofline import markdown_table
    return markdown_table(full_table("16x16"))


def main():
    print("## §Dry-run — 10 archs x 4 shapes x {16x16, 2x16x16}\n")
    print(dryrun_table())
    print("\n\n## §Roofline — single-pod (16x16), analytic terms\n")
    print(roofline_md())


if __name__ == "__main__":
    main()
