"""Round-engine benchmark: reference per-client loop vs packed device engine.

Times one full FedSGD round (selection -> importance -> threshold -> masks ->
client gradients -> aggregate -> update) for both `FederatedTrainer` backends
across client counts and model sizes, and checks that the two backends
produce numerically equivalent trajectories (the packed engine is bit-exact
on fp32 models, so the test-loss gap at round 10 must be ~0).

    PYTHONPATH=src python -m benchmarks.round_engine [--smoke | --full]
                                                     [--out BENCH_round_engine.json]

Output: ``name,us_per_call,derived`` CSV rows per config plus a JSON report
(default: BENCH_round_engine.json in the repo root) with per-round timings,
speedups, and the trajectory-equivalence check.
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.core import ClientData, FederatedTrainer
from repro.core.optimizer_ao import Schedule
from repro.data import make_dataset, partition_by_dirichlet
from repro.models import (lenet_init, lenet_apply, resnet_init, resnet_apply,
                          make_loss_fn, make_eval_fn)
from repro.wireless import ChannelModel, SystemParams

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _lenet_apply_seed(params, x):
    """The seed repo's LeNet forward (generic lax.conv + reduce_window),
    kept verbatim as the pre-PR baseline: the packed engine's end-to-end
    win is measured against this (host thresholds + this model), while the
    `speedup` column compares same-model reference vs packed."""
    import jax.lax as lax

    def conv(x, w):
        return lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))

    def pool(x):
        return lax.reduce_window(x, -jnp.inf, lax.max, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID")

    x = jax.nn.relu(conv(x, params["conv1"]))
    x = pool(x)
    x = jax.nn.relu(conv(x, params["conv2"]))
    x = pool(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"] + params["b1"])
    x = jax.nn.relu(x @ params["fc2"] + params["b2"])
    return x @ params["fc3"] + params["b3"]


MODELS = {
    "lenet": ("synthetic-mnist",
              lambda key: lenet_init(key, in_channels=1), lenet_apply),
    "lenet-seed": ("synthetic-mnist",
                   lambda key: lenet_init(key, in_channels=1),
                   _lenet_apply_seed),
    "resnet20": ("synthetic-cifar10",
                 lambda key: resnet_init(key, depth=20, in_channels=3),
                 resnet_apply),
}


def _all_on_schedule(n_rounds: int, n_clients: int, lam: float) -> Schedule:
    a = np.ones((n_rounds, n_clients))
    return Schedule(a=a, lam=lam * a, power=0.3 * a, freq=3e8 * a,
                    theta=0.0, energy=0.0, delay=0.0, feasible=True)


def _build(model: str, n_clients: int, *, n_train: int, batch: int,
           seed: int = 0):
    dataset, init_fn, apply_fn = MODELS[model]
    ds = make_dataset(dataset, n_train=n_train, n_test=max(200, n_train // 4),
                      seed=seed)
    parts = partition_by_dirichlet(ds.y_train, n_clients, sigma=1.0,
                                   rng=np.random.default_rng(seed))
    clients = [ClientData(ds.x_train[i], ds.y_train[i]) for i in parts]
    loss_fn = make_loss_fn(apply_fn)
    eval_fn = make_eval_fn(apply_fn, ds.x_test, ds.y_test)
    params = init_fn(jax.random.key(seed))
    return params, loss_fn, eval_fn, clients


def _make_trainer(backend, model, n_clients, *, batch, n_train, seed=0):
    params, loss_fn, _, clients = _build(model, n_clients, n_train=n_train,
                                         batch=batch, seed=seed)
    return FederatedTrainer(loss_fn, params, clients, eta=0.1,
                            batch_size=batch, seed=seed, backend=backend)


def _timed_round(tr, lam, n_clients):
    lam_s = np.full(n_clients, lam)
    t0 = time.perf_counter()
    tr._round(list(range(n_clients)), lam_s)
    jax.block_until_ready(tr._w if tr.backend == "packed"
                          else jax.tree_util.tree_leaves(tr.params))
    return time.perf_counter() - t0


def time_backends(model: str, n_clients: int, *, rounds: int, warmup: int,
                  lam: float, batch: int, n_train: int, seed: int = 0,
                  backends=("reference", "packed"), ref_model=None) -> dict:
    """Median wall seconds per round for each backend.

    Rounds are timed individually and *interleaved* across backends so
    machine load spikes hit both paths equally; the median discards the
    remaining outliers. `ref_model` overrides the model for the reference
    backend (used for the seed-baseline comparison)."""
    trainers = {}
    for b in backends:
        m = ref_model if (b == "reference" and ref_model) else model
        trainers[b] = _make_trainer(b, m, n_clients, batch=batch,
                                    n_train=n_train, seed=seed)
    times = {b: [] for b in backends}
    for _ in range(warmup):
        for b in backends:
            _timed_round(trainers[b], lam, n_clients)
    for _ in range(rounds):
        for b in backends:
            times[b].append(_timed_round(trainers[b], lam, n_clients))
    return {b: float(np.median(ts)) for b, ts in times.items()}


def check_equivalence(model: str, n_clients: int, *, rounds: int, lam: float,
                      batch: int, n_train: int, seed: int = 0) -> dict:
    """Same-seed trajectories for both backends; test loss at final round."""
    out = {}
    for backend in ("reference", "packed"):
        params, loss_fn, eval_fn, clients = _build(
            model, n_clients, n_train=n_train, batch=batch, seed=seed)
        tr = FederatedTrainer(loss_fn, params, clients, eta=0.1,
                              batch_size=batch, seed=seed, backend=backend)
        sp = SystemParams.table1(n_clients)
        ch = ChannelModel(n_clients, seed=seed)
        hist = tr.run(_all_on_schedule(rounds, n_clients, lam), sp, ch.uplink,
                      ch.downlink, eval_fn=eval_fn, eval_every=rounds - 1)
        out[backend] = [m.test_loss for m in hist if m.test_loss is not None][-1]
    out["abs_diff"] = abs(out["reference"] - out["packed"])
    out["rounds"] = rounds
    return out


def run_benchmark(*, configs, equiv_cfg, rounds: int, warmup: int,
                  lam: float = 0.3, n_train: int = 2000,
                  out_path: str | None = None) -> dict:
    results = []
    for model, n_clients, batch in configs:
        per = time_backends(model, n_clients, rounds=rounds, warmup=warmup,
                            lam=lam, batch=batch, n_train=n_train)
        speedup = per["reference"] / per["packed"]
        results.append({
            "model": model, "n_clients": n_clients, "rounds": rounds,
            "lam": lam, "batch": batch,
            "reference_s_per_round": per["reference"],
            "packed_s_per_round": per["packed"],
            "speedup": speedup,
        })
        print(csv_row(f"round_engine/{model}/c{n_clients}/b{batch}/packed",
                      per["packed"] * 1e6, f"speedup={speedup:.2f}x"))

    model, n_clients, batch, eq_rounds = equiv_cfg
    equivalence = check_equivalence(model, n_clients, rounds=eq_rounds,
                                    lam=lam, batch=batch, n_train=n_train)
    print(csv_row(f"round_engine/equivalence/{model}/c{n_clients}", 0.0,
                  f"test_loss_abs_diff={equivalence['abs_diff']:.2e}"))

    # End-to-end win of this PR at the acceptance config: the pre-PR
    # baseline (seed LeNet forward + host-threshold reference loop) vs the
    # packed engine on the optimized model.
    seed_comparison = None
    if any(r["model"] == "lenet" for r in results):
        per = time_backends("lenet", n_clients, rounds=rounds, warmup=warmup,
                            lam=lam, batch=batch, n_train=n_train,
                            ref_model="lenet-seed")
        seed_comparison = {
            "n_clients": n_clients, "batch": batch,
            "seed_reference_s_per_round": per["reference"],
            "packed_s_per_round": per["packed"],
            "speedup": per["reference"] / per["packed"],
        }
        print(csv_row(f"round_engine/vs_seed/lenet/c{n_clients}",
                      per["reference"] * 1e6,
                      f"speedup={seed_comparison['speedup']:.2f}x"))

    report = {"backend": jax.default_backend(), "results": results,
              "equivalence": equivalence,
              "seed_comparison": seed_comparison}
    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {out_path}")
    return report


def main(fast: bool = True, smoke: bool | None = None,
         out_path: str | None = None) -> dict:
    """`fast` is the benchmarks/run.py suite profile; --smoke is stricter
    still (single tiny config, <60 s on one CPU core)."""
    if smoke is None:
        smoke = False
    if out_path is None:
        # smoke gets its own file so a CI smoke run never clobbers the
        # committed full-profile report
        name = "BENCH_round_engine_smoke.json" if smoke \
            else "BENCH_round_engine.json"
        out_path = os.path.join(_ROOT, name)
    if smoke:
        return run_benchmark(configs=[("lenet", 4, 32)],
                             equiv_cfg=("lenet", 4, 32, 6),
                             rounds=5, warmup=2, n_train=800,
                             out_path=out_path)
    if fast:
        return run_benchmark(configs=[("lenet", 2, 32), ("lenet", 5, 32),
                                      ("lenet", 10, 32), ("lenet", 10, 8),
                                      ("lenet", 20, 8)],
                             equiv_cfg=("lenet", 10, 32, 10),
                             rounds=10, warmup=2, n_train=2000,
                             out_path=out_path)
    return run_benchmark(configs=[("lenet", 2, 32), ("lenet", 5, 32),
                                  ("lenet", 10, 32), ("lenet", 10, 8),
                                  ("lenet", 20, 8), ("lenet", 50, 8),
                                  ("resnet20", 5, 32), ("resnet20", 10, 32)],
                         equiv_cfg=("lenet", 10, 32, 10),
                         rounds=15, warmup=3, n_train=4000,
                         out_path=out_path)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny single-config run (<60 s on CPU)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweep incl. resnet20")
    ap.add_argument("--out", default=None, help="JSON report path")
    args = ap.parse_args()
    main(fast=not args.full, smoke=args.smoke, out_path=args.out)
