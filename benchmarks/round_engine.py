"""Round-engine benchmark: reference per-client loop vs packed device engine.

Times one full FedSGD round (selection -> importance -> threshold -> masks ->
client gradients -> aggregate -> update) for both `FederatedTrainer` backends
across client counts and model sizes, and checks that the two backends
produce numerically equivalent trajectories (the packed engine is bit-exact
on fp32 models, so the test-loss gap at round 10 must be ~0).

    PYTHONPATH=src python -m benchmarks.round_engine [--smoke | --full]
                                                     [--out BENCH_round_engine.json]
                                                     [--compare PREV.json]

Regression awareness: every report records its environment (`meta`:
n_devices, client_axis, bucket sizes, git rev), and ``--compare PREV.json``
prints per-config deltas against a previous report. A config is flagged
REGRESSED when its packed-vs-reference *speedup* dropped by more than 10%
— speedup is measured interleaved within one run, so shared-box throttling
cancels out of it; absolute per-round time deltas are printed for
information only. The bench trajectory thus accumulates across PRs instead
of being overwritten blind.

Sharded scaling: the full/fast profiles also measure the mesh-parallel
round (client axis shard_mapped over forced host devices) by re-running a
probe of this module under ``XLA_FLAGS=--xla_force_host_platform_device_
count=N`` subprocesses and comparing per-round time and the round-10 test
loss across device counts.

Block sweep: every profile (smoke included) times the multi-round block
engine at ``rounds_per_dispatch`` 1 vs 8 vs 32 on the 20-client edge
config — amortized per-round wall time at each dispatch granularity,
per-dispatch sync, repeats interleaved across modes — and records the
block/bucket metadata plus the per-round H2D batch-upload count (zero on
the block path). The block speedups feed the same --compare regression
rule as the packed-vs-reference speedups.

Output: ``name,us_per_call,derived`` CSV rows per config plus a JSON report
(default: BENCH_round_engine.json in the repo root) with per-round timings,
speedups, and the trajectory-equivalence check.
"""
from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import csv_row
from repro.core import ClientData, FederatedTrainer
from repro.core.optimizer_ao import Schedule
from repro.data import make_dataset, partition_by_dirichlet
from repro.models import (lenet_init, lenet_apply, mlp_edge_init,
                          mlp_edge_apply, resnet_init, resnet_apply,
                          make_loss_fn, make_eval_fn)
from repro.wireless import ChannelModel, SystemParams

_ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _git_rev() -> str:
    try:
        rev = subprocess.check_output(
            ["git", "rev-parse", "--short", "HEAD"], cwd=_ROOT,
            text=True, stderr=subprocess.DEVNULL).strip()
        dirty = subprocess.run(
            ["git", "diff", "--quiet", "HEAD"], cwd=_ROOT,
            stderr=subprocess.DEVNULL).returncode != 0
        return rev + ("-dirty" if dirty else "")
    except Exception:
        return "unknown"


def _lenet_apply_seed(params, x):
    """The seed repo's LeNet forward (generic lax.conv + reduce_window),
    kept verbatim as the pre-PR baseline: the packed engine's end-to-end
    win is measured against this (host thresholds + this model), while the
    `speedup` column compares same-model reference vs packed."""
    import jax.lax as lax

    def conv(x, w):
        return lax.conv_general_dilated(
            x, w, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC"))

    def pool(x):
        return lax.reduce_window(x, -jnp.inf, lax.max, (1, 2, 2, 1),
                                 (1, 2, 2, 1), "VALID")

    x = jax.nn.relu(conv(x, params["conv1"]))
    x = pool(x)
    x = jax.nn.relu(conv(x, params["conv2"]))
    x = pool(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"] + params["b1"])
    x = jax.nn.relu(x @ params["fc2"] + params["b2"])
    return x @ params["fc3"] + params["b3"]


MODELS = {
    "lenet": ("synthetic-mnist",
              lambda key: lenet_init(key, in_channels=1), lenet_apply),
    "lenet-seed": ("synthetic-mnist",
                   lambda key: lenet_init(key, in_channels=1),
                   _lenet_apply_seed),
    # mlp-edge (repro.models, promoted from this file in PR 4): the
    # dispatch-bound edge model for the block sweep. A LeNet round on this
    # 2-core CPU box is gradient-FLOP-bound (~3.5 ms/client even at batch
    # 1), which drowns the per-round dispatch + H2D + sync overhead the
    # block engine removes; the MLP round is cheap enough that the
    # overhead is a measurable fraction — the same regime real
    # accelerators put ANY of these models in (device compute shrinks, the
    # host round-trip does not).
    "mlp-edge": ("synthetic-mnist", mlp_edge_init, mlp_edge_apply),
    "resnet20": ("synthetic-cifar10",
                 lambda key: resnet_init(key, depth=20, in_channels=3),
                 resnet_apply),
}


def _all_on_schedule(n_rounds: int, n_clients: int, lam: float) -> Schedule:
    a = np.ones((n_rounds, n_clients))
    return Schedule(a=a, lam=lam * a, power=0.3 * a, freq=3e8 * a,
                    theta=0.0, energy=0.0, delay=0.0, feasible=True)


def _build(model: str, n_clients: int, *, n_train: int, batch: int,
           seed: int = 0):
    dataset, init_fn, apply_fn = MODELS[model]
    ds = make_dataset(dataset, n_train=n_train, n_test=max(200, n_train // 4),
                      seed=seed)
    parts = partition_by_dirichlet(ds.y_train, n_clients, sigma=1.0,
                                   rng=np.random.default_rng(seed))
    clients = [ClientData(ds.x_train[i], ds.y_train[i]) for i in parts]
    loss_fn = make_loss_fn(apply_fn)
    eval_fn = make_eval_fn(apply_fn, ds.x_test, ds.y_test)
    params = init_fn(jax.random.key(seed))
    return params, loss_fn, eval_fn, clients


def _make_trainer(backend, model, n_clients, *, batch, n_train, seed=0,
                  rounds_per_dispatch=1):
    params, loss_fn, _, clients = _build(model, n_clients, n_train=n_train,
                                         batch=batch, seed=seed)
    return FederatedTrainer(loss_fn, params, clients, eta=0.1,
                            batch_size=batch, seed=seed, backend=backend,
                            rounds_per_dispatch=rounds_per_dispatch)


def _timed_round(tr, lam, n_clients):
    lam_s = np.full(n_clients, lam)
    t0 = time.perf_counter()
    tr._round(list(range(n_clients)), lam_s)
    jax.block_until_ready(tr._w if tr.backend == "packed"
                          else jax.tree_util.tree_leaves(tr.params))
    return time.perf_counter() - t0


def time_backends(model: str, n_clients: int, *, rounds: int, warmup: int,
                  lam: float, batch: int, n_train: int, seed: int = 0,
                  backends=("reference", "packed"), ref_model=None) -> dict:
    """Median wall seconds per round for each backend.

    Rounds are timed individually and *interleaved* across backends so
    machine load spikes hit both paths equally; the median discards the
    remaining outliers. `ref_model` overrides the model for the reference
    backend (used for the seed-baseline comparison)."""
    trainers = {}
    for b in backends:
        m = ref_model if (b == "reference" and ref_model) else model
        trainers[b] = _make_trainer(b, m, n_clients, batch=batch,
                                    n_train=n_train, seed=seed)
    times = {b: [] for b in backends}
    for _ in range(warmup):
        for b in backends:
            _timed_round(trainers[b], lam, n_clients)
    for _ in range(rounds):
        for b in backends:
            times[b].append(_timed_round(trainers[b], lam, n_clients))
    per = {b: float(np.median(ts)) for b, ts in times.items()}
    if "packed" in trainers and trainers["packed"].engine is not None:
        eng = trainers["packed"].engine
        per["_packed_info"] = {"bucket_sizes": sorted(eng.buckets_used),
                               "n_traces": eng.n_traces,
                               "shards": eng.shards}
    return per


def check_equivalence(model: str, n_clients: int, *, rounds: int, lam: float,
                      batch: int, n_train: int, seed: int = 0) -> dict:
    """Same-seed trajectories for both backends; test loss at final round."""
    out = {}
    for backend in ("reference", "packed"):
        params, loss_fn, eval_fn, clients = _build(
            model, n_clients, n_train=n_train, batch=batch, seed=seed)
        tr = FederatedTrainer(loss_fn, params, clients, eta=0.1,
                              batch_size=batch, seed=seed, backend=backend)
        sp = SystemParams.table1(n_clients)
        ch = ChannelModel(n_clients, seed=seed)
        hist = tr.run(_all_on_schedule(rounds, n_clients, lam), sp, ch.uplink,
                      ch.downlink, eval_fn=eval_fn, eval_every=rounds - 1)
        out[backend] = [m.test_loss for m in hist if m.test_loss is not None][-1]
    out["abs_diff"] = abs(out["reference"] - out["packed"])
    out["rounds"] = rounds
    return out


def run_benchmark(*, configs, equiv_cfg, rounds: int, warmup: int,
                  lam: float = 0.3, n_train: int = 2000,
                  out_path: str | None = None) -> dict:
    results = []
    for model, n_clients, batch in configs:
        per = time_backends(model, n_clients, rounds=rounds, warmup=warmup,
                            lam=lam, batch=batch, n_train=n_train)
        speedup = per["reference"] / per["packed"]
        results.append({
            "model": model, "n_clients": n_clients, "rounds": rounds,
            "lam": lam, "batch": batch,
            "reference_s_per_round": per["reference"],
            "packed_s_per_round": per["packed"],
            "speedup": speedup,
            **per.get("_packed_info", {}),
        })
        print(csv_row(f"round_engine/{model}/c{n_clients}/b{batch}/packed",
                      per["packed"] * 1e6, f"speedup={speedup:.2f}x"))

    model, n_clients, batch, eq_rounds = equiv_cfg
    equivalence = check_equivalence(model, n_clients, rounds=eq_rounds,
                                    lam=lam, batch=batch, n_train=n_train)
    print(csv_row(f"round_engine/equivalence/{model}/c{n_clients}", 0.0,
                  f"test_loss_abs_diff={equivalence['abs_diff']:.2e}"))

    # End-to-end win of this PR at the acceptance config: the pre-PR
    # baseline (seed LeNet forward + host-threshold reference loop) vs the
    # packed engine on the optimized model.
    seed_comparison = None
    if any(r["model"] == "lenet" for r in results):
        per = time_backends("lenet", n_clients, rounds=rounds, warmup=warmup,
                            lam=lam, batch=batch, n_train=n_train,
                            ref_model="lenet-seed")
        seed_comparison = {
            "n_clients": n_clients, "batch": batch,
            "seed_reference_s_per_round": per["reference"],
            "packed_s_per_round": per["packed"],
            "speedup": per["reference"] / per["packed"],
        }
        print(csv_row(f"round_engine/vs_seed/lenet/c{n_clients}",
                      per["reference"] * 1e6,
                      f"speedup={seed_comparison['speedup']:.2f}x"))

    report = {"backend": jax.default_backend(),
              "meta": {"n_devices": len(jax.devices()),
                       "client_axis": "auto",
                       "git_rev": _git_rev(),
                       "bucket_sizes": sorted({b for r in results
                                               for b in r.get("bucket_sizes",
                                                              [])})},
              "results": results,
              "equivalence": equivalence,
              "seed_comparison": seed_comparison}
    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {out_path}")
    return report


# -- multi-round blocks: rounds_per_dispatch sweep ---------------------------


def _timed_block(tr, lam, n_clients, k_rounds):
    """One K-round block dispatch timed to completion — index drawing,
    the single jitted lax.scan dispatch, and the sync, i.e. everything a
    block costs. The rpd=1 leg uses `_timed_round` (this file's standard
    per-dispatch-sync protocol), so the two legs measure the same thing at
    different dispatch granularities."""
    lam_s = np.full(n_clients, lam)
    sel = list(range(n_clients))
    infos = [(sel, lam_s)] * k_rounds
    t0 = time.perf_counter()
    out: dict = {}
    tr._exec_block(0, k_rounds, infos, out)
    jax.block_until_ready(tr._w)
    return time.perf_counter() - t0


def block_sweep(*, model: str = "mlp-edge", n_clients: int = 20,
                batch: int = 8, lam: float = 0.3, n_train: int = 2000,
                rounds: int = 32, rpds=(1, 8, 32), repeats: int = 5) -> dict:
    """Amortized per-round time vs rounds_per_dispatch (the block engine).

    Every mode executes `rounds` rounds as ceil(rounds/rpd) dispatches,
    each timed to completion (per-dispatch sync — the protocol every
    committed number in this file uses); repeats are *interleaved* across
    modes so shared-box load spikes hit all of them equally and the
    speedup ratio stays load-invariant; medians discard the rest. The
    rpd>1 legs draw only batch INDICES on host — `batch_h2d_uploads_per_
    round` records that zero per-round stacked-batch transfers happen on
    the block path (the per-round leg pays one per round)."""
    trainers = {
        r: _make_trainer("packed", model, n_clients, batch=batch,
                         n_train=n_train, rounds_per_dispatch=r)
        for r in rpds}
    times: dict[int, list[float]] = {r: [] for r in rpds}
    executed = {r: 0 for r in rpds}
    for rep in range(repeats + 1):           # rep 0 = compile warmup
        for r, tr in trainers.items():
            total, done = 0.0, 0
            while done < rounds:
                k = min(r, rounds - done)
                if r == 1:
                    total += _timed_round(tr, lam, n_clients)
                else:
                    total += _timed_block(tr, lam, n_clients, k)
                done += k
            executed[r] += rounds
            if rep:
                times[r].append(total / rounds)
    per_rpd = {}
    base = float(np.median(times[rpds[0]]))
    for r in rpds:
        tr = trainers[r]
        med = float(np.median(times[r]))
        per_rpd[str(r)] = {
            "s_per_round": med,
            "s_per_round_samples": times[r],
            "speedup_vs_1": base / med,
            "batch_h2d_uploads_per_round":
                tr.n_batch_uploads / executed[r],
            "block_dispatches": tr.n_block_dispatches,
            "bucket_sizes": sorted(tr.engine.buckets_used),
            "k_buckets": sorted(tr.engine.k_buckets_used),
            "n_traces": tr.engine.n_traces,
        }
        print(csv_row(f"round_engine/block/{model}/c{n_clients}/b{batch}"
                      f"/rpd{r}", med * 1e6,
                      f"speedup_vs_rpd1={base / med:.2f}x "
                      f"h2d_batches_per_round="
                      f"{per_rpd[str(r)]['batch_h2d_uploads_per_round']:.1f}"))
    return {
        "model": model, "n_clients": n_clients, "batch": batch,
        "lam": lam, "n_train": n_train, "rounds": rounds,
        "repeats": repeats,
        "protocol": "per-dispatch sync, interleaved medians",
        "per_rpd": per_rpd,
        "speedup_at_max_rpd": per_rpd[str(max(rpds))]["speedup_vs_1"],
    }


# -- cross-PR regression tracking --------------------------------------------


def compare_reports(prev: dict, cur: dict, *, threshold: float = 0.10) -> list[dict]:
    """Per-config deltas vs a previous BENCH_round_engine.json report.

    A config regresses when its packed-vs-reference *speedup* dropped by
    more than `threshold` (fraction). Speedup is the load-invariant metric:
    both backends are timed interleaved in the same run, so shared-box /
    cgroup throttling cancels out of the ratio, whereas absolute per-round
    times (reported as `time_delta_pct` for information) swing with
    whatever else the host is doing. Configs present in only one report are
    skipped; the bench trajectory accumulates across PRs instead of
    resetting."""
    prev_by = {(r["model"], r["n_clients"], r["batch"]): r
               for r in prev.get("results", [])}
    rows = []
    for r in cur.get("results", []):
        p = prev_by.get((r["model"], r["n_clients"], r["batch"]))
        if p is None:
            continue
        t_delta = r["packed_s_per_round"] / p["packed_s_per_round"] - 1.0
        s_delta = r["speedup"] / p["speedup"] - 1.0
        rows.append({
            "config": f"{r['model']}/c{r['n_clients']}/b{r['batch']}",
            "prev_packed_s_per_round": p["packed_s_per_round"],
            "packed_s_per_round": r["packed_s_per_round"],
            "time_delta_pct": 100.0 * t_delta,
            "prev_speedup": p["speedup"],
            "speedup": r["speedup"],
            "speedup_delta_pct": 100.0 * s_delta,
            "regressed": bool(s_delta < -threshold),
        })
    # block-mode rows: the block-vs-per-round speedup at each
    # rounds_per_dispatch is tracked with the same regression rule (it is
    # just as load-invariant — both legs of the ratio are interleaved)
    pb, cb = prev.get("block_sweep"), cur.get("block_sweep")
    if pb and cb and (pb.get("model"), pb.get("n_clients"), pb.get("batch")) \
            == (cb.get("model"), cb.get("n_clients"), cb.get("batch")):
        for rpd, c in cb["per_rpd"].items():
            p = pb["per_rpd"].get(rpd)
            if p is None or rpd == "1":
                continue
            t_delta = c["s_per_round"] / p["s_per_round"] - 1.0
            s_delta = c["speedup_vs_1"] / p["speedup_vs_1"] - 1.0
            rows.append({
                "config": f"block/{cb['model']}/c{cb['n_clients']}"
                          f"/b{cb['batch']}/rpd{rpd}",
                "prev_packed_s_per_round": p["s_per_round"],
                "packed_s_per_round": c["s_per_round"],
                "time_delta_pct": 100.0 * t_delta,
                "prev_speedup": p["speedup_vs_1"],
                "speedup": c["speedup_vs_1"],
                "speedup_delta_pct": 100.0 * s_delta,
                "regressed": bool(s_delta < -threshold),
            })
    return rows


def print_compare(rows: list[dict], prev_meta: dict | None = None) -> None:
    rev = (prev_meta or {}).get("git_rev", "?")
    for r in rows:
        tag = "REGRESSED" if r["regressed"] else "ok"
        print(csv_row(f"round_engine/compare/{r['config']}",
                      r["packed_s_per_round"] * 1e6,
                      f"speedup {r['prev_speedup']:.2f}x->{r['speedup']:.2f}x "
                      f"({r['speedup_delta_pct']:+.1f}%) "
                      f"dt={r['time_delta_pct']:+.1f}% vs {rev} {tag}"))


# -- sharded scaling: forced host-device counts via subprocess probes --------
#
# The host platform device count is fixed at jax init, so each point of the
# scaling curve runs in its own subprocess with XLA_FLAGS set; the child
# prints one sentinel-prefixed JSON line that the parent collects.

_PROBE_SENTINEL = "ROUND_ENGINE_PROBE_RESULT "


def probe_main(cfg: dict) -> None:
    """Child-process body: run a short trajectory, then time packed rounds,
    on whatever device count XLA_FLAGS forced. One build + one engine: the
    trajectory doubles as compile warmup (per-round cost is
    state-independent), so the subprocess pays dataset synthesis and XLA
    compilation once."""
    model, n_clients, batch = cfg["model"], cfg["n_clients"], cfg["batch"]
    lam, n_train = cfg["lam"], cfg["n_train"]
    params, loss_fn, eval_fn, clients = _build(
        model, n_clients, n_train=n_train, batch=batch)
    tr = FederatedTrainer(loss_fn, params, clients, eta=0.1,
                          batch_size=batch, seed=0, backend="packed")

    traj_rounds = cfg["traj_rounds"]
    test_loss = None
    if traj_rounds:
        sp = SystemParams.table1(n_clients)
        ch = ChannelModel(n_clients, seed=0)
        hist = tr.run(_all_on_schedule(traj_rounds, n_clients, lam), sp,
                      ch.uplink, ch.downlink, eval_fn=eval_fn,
                      eval_every=max(1, traj_rounds - 1))
        test_loss = float(
            [m.test_loss for m in hist if m.test_loss is not None][-1])

    for _ in range(cfg["warmup"]):
        _timed_round(tr, lam, n_clients)
    ts = [_timed_round(tr, lam, n_clients) for _ in range(cfg["rounds"])]
    print(_PROBE_SENTINEL + json.dumps({
        "n_devices": len(jax.devices()),
        "shards": tr.engine.shards,
        "bucket_sizes": sorted(tr.engine.buckets_used),
        "s_per_round": float(np.median(ts)),
        "test_loss_final": test_loss,
        "traj_rounds": traj_rounds,
    }))


def _run_probe(cfg: dict, n_devices: int, py_path: str) -> dict:
    env = dict(os.environ)
    # the probe measures *host-platform* scaling: pin JAX to CPU so an
    # accelerator host doesn't silently run every device count on the same
    # GPU/TPU and publish a flat curve as a scaling result, and drop any
    # inherited shard-count override for the same reason
    env["JAX_PLATFORMS"] = "cpu"
    env.pop("REPRO_ROUND_SHARDS", None)
    # appended AFTER any inherited flags: XLA takes the last occurrence of a
    # duplicated flag, so a force-count already in the caller's environment
    # must not override the probe's
    env["XLA_FLAGS"] = (env.get("XLA_FLAGS", "") +
                        f" --xla_force_host_platform_device_count="
                        f"{n_devices}").strip()
    env["PYTHONPATH"] = py_path
    out = subprocess.run(
        [sys.executable, "-m", "benchmarks.round_engine",
         "--probe", json.dumps(cfg)],
        capture_output=True, text=True, env=env, cwd=_ROOT, timeout=1800)
    lines = [l for l in out.stdout.splitlines()
             if l.startswith(_PROBE_SENTINEL)]
    if out.returncode != 0 or not lines:
        raise RuntimeError(f"sharded probe at {n_devices} devices failed:\n"
                           f"{out.stdout}\n{out.stderr}")
    res = json.loads(lines[-1][len(_PROBE_SENTINEL):])
    if res["n_devices"] != n_devices or res["shards"] != n_devices:
        raise RuntimeError(
            f"probe asked for {n_devices} host devices but ran with "
            f"{res['n_devices']} devices / {res['shards']} shards — "
            "force-count or shard override not honored")
    return res


def sharded_scaling(*, model: str = "lenet", n_clients: int = 20,
                    batch: int = 8, lam: float = 0.3, n_train: int = 2000,
                    rounds: int = 8, warmup: int = 2, traj_rounds: int = 10,
                    device_counts=(1, 2, 4), repeats: int = 3) -> dict:
    """Per-round time vs forced host-device count, via one subprocess per
    (device count, repeat). Repeats are *interleaved* across device counts
    (d1, d2, d4, d1, d2, ...) so load spikes on a shared box hit every
    count equally, and the per-count median discards the rest; the
    trajectory check runs once per count."""
    cfg = {"model": model, "n_clients": n_clients, "batch": batch,
           "lam": lam, "n_train": n_train, "rounds": rounds,
           "warmup": warmup, "traj_rounds": traj_rounds}
    py_path = os.pathsep.join(
        p for p in (os.path.join(_ROOT, "src"), _ROOT,
                    os.environ.get("PYTHONPATH")) if p)
    per: dict[str, dict] = {}
    times: dict[str, list[float]] = {str(d): [] for d in device_counts}
    for rep in range(repeats):
        for d in device_counts:
            probe_cfg = dict(cfg, traj_rounds=traj_rounds if rep == 0 else 0)
            res = _run_probe(probe_cfg, d, py_path)
            times[str(d)].append(res["s_per_round"])
            if rep == 0:
                per[str(d)] = res
    for d in device_counts:
        per[str(d)]["s_per_round"] = float(np.median(times[str(d)]))
        per[str(d)]["s_per_round_samples"] = times[str(d)]
        print(csv_row(f"round_engine/sharded/{model}/c{n_clients}/b{batch}"
                      f"/d{d}", per[str(d)]["s_per_round"] * 1e6,
                      f"shards={per[str(d)]['shards']}"))
    base = per[str(device_counts[0])]
    peak = per[str(max(device_counts))]
    traj_diff = (abs(base["test_loss_final"] - peak["test_loss_final"])
                 if traj_rounds else None)
    result = {
        "config": cfg,
        "per_device_count": per,
        "speedup_at_max_devices": base["s_per_round"] / peak["s_per_round"],
        "traj_test_loss_abs_diff": traj_diff,
    }
    traj_note = (f"traj_dloss={traj_diff:.2e}" if traj_diff is not None
                 else "traj_skipped")
    print(csv_row(f"round_engine/sharded/{model}/c{n_clients}/b{batch}"
                  f"/scaling", peak["s_per_round"] * 1e6,
                  f"speedup_d{max(device_counts)}_vs_d{device_counts[0]}="
                  f"{result['speedup_at_max_devices']:.2f}x {traj_note}"))
    return result


def main(fast: bool = True, smoke: bool | None = None,
         out_path: str | None = None, compare: str | None = None,
         sharded: bool | None = None) -> dict:
    """`fast` is the benchmarks/run.py suite profile; --smoke is stricter
    still (single tiny config, <60 s on one CPU core). `compare` points at
    a previous report for the cross-PR delta table; `sharded` adds the
    forced-host-device scaling probe (default: on for fast/full profiles,
    off for smoke)."""
    if smoke is None:
        smoke = False
    if sharded is None:
        sharded = not smoke
    if out_path is None:
        # smoke gets its own file so a CI smoke run never clobbers the
        # committed full-profile report
        name = "BENCH_round_engine_smoke.json" if smoke \
            else "BENCH_round_engine.json"
        out_path = os.path.join(_ROOT, name)
    if smoke:
        # smoke times a config the committed fast-profile report also
        # contains — same n_train too, so the client partition (and hence
        # the ragged-vs-full batch path) matches the baseline and the
        # --compare speedup delta compares like with like (time deltas are
        # cross-profile and informational only)
        report = run_benchmark(configs=[("lenet", 5, 32)],
                               equiv_cfg=("lenet", 5, 32, 6),
                               rounds=5, warmup=2, n_train=2000,
                               out_path=out_path)
    elif fast:
        report = run_benchmark(configs=[("lenet", 2, 32), ("lenet", 5, 32),
                                        ("lenet", 10, 32), ("lenet", 10, 8),
                                        ("lenet", 20, 8)],
                               equiv_cfg=("lenet", 10, 32, 10),
                               rounds=10, warmup=2, n_train=2000,
                               out_path=out_path)
    else:
        report = run_benchmark(configs=[("lenet", 2, 32), ("lenet", 5, 32),
                                        ("lenet", 10, 32), ("lenet", 10, 8),
                                        ("lenet", 20, 8), ("lenet", 50, 8),
                                        ("resnet20", 5, 32),
                                        ("resnet20", 10, 32)],
                               equiv_cfg=("lenet", 10, 32, 10),
                               rounds=15, warmup=3, n_train=4000,
                               out_path=out_path)
    # rounds_per_dispatch sweep: always runs (smoke included — it is the
    # regression gate for block mode) on the dispatch-bound 20-client edge
    # config; the paper-scale profile adds the FLOP-bound LeNet config for
    # the record (its CPU speedup is ~1x by design — see _mlp_edge_init).
    report["block_sweep"] = block_sweep(repeats=3 if smoke else 5)
    if not fast and not smoke:
        report["block_sweep_lenet"] = block_sweep(model="lenet",
                                                  repeats=3)
    if sharded:
        report["sharded"] = sharded_scaling()
    if compare:
        if not os.path.exists(compare):
            print(f"WARNING: --compare baseline {compare!r} not found; "
                  "no regression check ran")
        else:
            with open(compare) as f:
                prev = json.load(f)
            rows = compare_reports(prev, report)
            if not rows:
                print(f"WARNING: no overlapping configs with {compare!r}; "
                      "no regression check ran")
            print_compare(rows, prev.get("meta"))
            report["compare"] = {
                "against": compare,
                "prev_git_rev": prev.get("meta", {}).get("git_rev"),
                "rows": rows}
    # rewrite: the sweep/sharded/compare sections were added after
    # run_benchmark's first dump
    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=2)
        print(f"wrote {out_path}")
    return report


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--smoke", action="store_true",
                    help="tiny single-config run (<60 s on CPU)")
    ap.add_argument("--full", action="store_true",
                    help="paper-scale sweep incl. resnet20")
    ap.add_argument("--out", default=None, help="JSON report path")
    ap.add_argument("--compare", default=None,
                    help="previous BENCH_round_engine.json to diff against")
    ap.add_argument("--sharded", action=argparse.BooleanOptionalAction,
                    default=None,
                    help="run the sharded scaling probe (default: on unless "
                         "--smoke; --no-sharded skips the ~12 subprocesses)")
    ap.add_argument("--probe", default=None, help=argparse.SUPPRESS)
    args = ap.parse_args()
    if args.probe:
        probe_main(json.loads(args.probe))
    else:
        main(fast=not args.full, smoke=args.smoke, out_path=args.out,
             compare=args.compare, sharded=args.sharded)
