"""Fig. 8: final accuracy vs system energy budget E0, all six schemes."""
from __future__ import annotations

import time

from benchmarks.common import (SCHEMES, ExpConfig, build_env, final_accuracy,
                               run_scheme)


def run(e0s=(1.0, 2.0, 4.0, 8.0), rounds=60, fast=False):
    cfg = ExpConfig(rounds=rounds)
    env = build_env(cfg)
    rows = []
    for e0 in e0s:
        row = {"e0": e0}
        for scheme in SCHEMES:
            _, hist = run_scheme(env, scheme, e0=e0, eval_every=20)
            row[scheme], row[f"{scheme}_round"] = final_accuracy(hist)
        rows.append(row)
    return rows


def main(fast: bool = False):
    # fast trims SWEEP POINTS only: shrinking rounds/dataset leaves the
    # calibrated binding-budget regime and scrambles the scheme ordering
    t0 = time.time()
    rows = run(e0s=(2.0, 4.0) if fast else (1.0, 2.0, 4.0, 8.0),
               rounds=60, fast=fast)
    us = (time.time() - t0) * 1e6 / max(len(rows), 1)
    print("name,us_per_call,derived")
    for r in rows:
        vals = ";".join(f"{s}={r[s]:.3f}" for s in SCHEMES)
        print(f"fig8_E0_{r['e0']},{us:.0f},{vals}")
    return rows


if __name__ == "__main__":
    main()
