"""Fig. 7: final accuracy vs system delay budget T0, all six schemes."""
from __future__ import annotations

import time

from benchmarks.common import (SCHEMES, ExpConfig, build_env, final_accuracy,
                               run_scheme)


def run(t0s=(15.0, 25.0, 40.0, 60.0), rounds=60, fast=False):
    cfg = ExpConfig(rounds=rounds)
    env = build_env(cfg)
    rows = []
    for t0 in t0s:
        row = {"t0": t0}
        for scheme in SCHEMES:
            _, hist = run_scheme(env, scheme, t0=t0, eval_every=20)
            row[scheme], row[f"{scheme}_round"] = final_accuracy(hist)
        rows.append(row)
    return rows


def main(fast: bool = False):
    # fast trims SWEEP POINTS only: shrinking rounds/dataset leaves the
    # calibrated binding-budget regime and scrambles the scheme ordering
    t0 = time.time()
    rows = run(t0s=(25.0, 40.0) if fast else (15.0, 25.0, 40.0, 60.0),
               rounds=60, fast=fast)
    us = (time.time() - t0) * 1e6 / max(len(rows), 1)
    print("name,us_per_call,derived")
    for r in rows:
        vals = ";".join(f"{s}={r[s]:.3f}" for s in SCHEMES)
        print(f"fig7_T0_{r['t0']},{us:.0f},{vals}")
    return rows


if __name__ == "__main__":
    main()
