"""Fig. 4: accuracy vs Dirichlet sigma, with vs without the generalization
statement in the joint optimizer."""
from __future__ import annotations

import dataclasses
import time

from benchmarks.common import ExpConfig, build_env, run_scheme, final_accuracy


def run(sigmas=(0.5, 1.0, 5.0, 100.0), rounds=60, fast=False):
    rows = []
    for sigma in sigmas:
        cfg = ExpConfig(sigma=sigma, rounds=rounds)
        env = build_env(cfg)
        _, h_with = run_scheme(env, "proposed")
        _, h_wo = run_scheme(env, "no_gen")
        acc_with, round_with = final_accuracy(h_with)
        acc_wo, round_wo = final_accuracy(h_wo)
        rows.append({
            "sigma": sigma,
            "acc_with_phi": acc_with,
            "acc_without_phi": acc_wo,
            "eval_round_with_phi": round_with,
            "eval_round_without_phi": round_wo,
        })
    return rows


def main(fast: bool = False):
    # fast trims SWEEP POINTS only: shrinking rounds/dataset leaves the
    # calibrated binding-budget regime and scrambles the scheme ordering
    t0 = time.time()
    rows = run(sigmas=(1.0, 5.0) if fast else (0.5, 1.0, 5.0, 100.0),
               rounds=60, fast=fast)
    us = (time.time() - t0) * 1e6 / max(len(rows), 1)
    print("name,us_per_call,derived")
    for r in rows:
        print(f"fig4_sigma_{r['sigma']},{us:.0f},"
              f"with={r['acc_with_phi']:.3f};without={r['acc_without_phi']:.3f}")
    return rows


if __name__ == "__main__":
    main()
