"""Shared experiment harness for the paper-figure benchmarks (Sec. V).

Builds the FEEL environment (synthetic dataset + Dirichlet(sigma) clients +
Table-I wireless system), runs one of the six schemes, and returns the round
history. The six schemes are exactly the paper's comparisons:

  proposed         joint (P1) with generalization statement
  no_gen           conventional bound (phi = 0 in the optimizer) [31]
  fixed_pruning    lambda = 0 (no pruning)
  fixed_selection  a_n = 1 every round
  fixed_power      p_n = 0.5 W
  fixed_clock      f_n = f_max
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import numpy as np

from repro.core import (
    AOConfig, BoundConstants, ClientData, FederatedTrainer, phis, solve_p1,
)
from repro.data import make_dataset, partition_by_dirichlet
from repro.models import (
    lenet_init, lenet_apply, resnet_init, resnet_apply,
    make_loss_fn, make_eval_fn,
)
from repro.wireless import ChannelModel, SystemParams

SCHEMES = ("proposed", "no_gen", "fixed_pruning", "fixed_selection",
           "fixed_power", "fixed_clock")


@dataclasses.dataclass
class ExpConfig:
    dataset: str = "synthetic-mnist"     # or synthetic-cifar10
    n_clients: int = 10
    sigma: float = 1.0
    rounds: int = 60
    eta: float = 0.1
    batch: int = 32
    n_train: int = 4000
    n_test: int = 800
    # Budgets are calibrated to the *binding* regime for the synthetic
    # substrate (paper Table-I budgets of 250 J / 150 s are sized for real
    # MNIST workloads; with them every scheme converges unconstrained and
    # ties — EXPERIMENTS.md §Paper). Same budget:per-round-cost ratio as the
    # knee region of the paper's Fig. 7/8.
    e0: float = 4.0                      # [J]
    t0: float = 40.0                     # [s]
    seed: int = 0
    # "auto": per-round dispatch on CPU, multi-round lax.scan blocks on
    # accelerators (core/round_engine.block_step); any int forces it
    rounds_per_dispatch: int | str = "auto"


@dataclasses.dataclass
class Env:
    cfg: ExpConfig
    clients: list
    phi: np.ndarray
    sp: SystemParams
    ch: ChannelModel
    init_fn: Callable
    apply_fn: Callable
    eval_fn: Callable
    loss_fn: Callable


def build_env(cfg: ExpConfig) -> Env:
    ds = make_dataset(cfg.dataset, n_train=cfg.n_train, n_test=cfg.n_test,
                      seed=cfg.seed)
    parts = partition_by_dirichlet(ds.y_train, cfg.n_clients, cfg.sigma,
                                   rng=np.random.default_rng(cfg.seed))
    clients = [ClientData(ds.x_train[i], ds.y_train[i]) for i in parts]
    test_hist = np.bincount(ds.y_test, minlength=10).astype(float)
    phi = phis(np.stack([c.label_histogram(10) for c in clients]),
               test_hist[None])
    table = "mnist" if "mnist" in cfg.dataset else "cifar10"
    sp = SystemParams.table1(cfg.n_clients, dataset=table,
                             batch_size=cfg.batch)
    ch = ChannelModel(cfg.n_clients, seed=cfg.seed)
    if table == "mnist":
        init_fn = lambda key: lenet_init(key, in_channels=1)
        apply_fn = lenet_apply
    else:
        init_fn = lambda key: resnet_init(key, depth=20, in_channels=3)
        apply_fn = resnet_apply
    return Env(cfg=cfg, clients=clients, phi=phi, sp=sp, ch=ch,
               init_fn=init_fn, apply_fn=apply_fn,
               eval_fn=make_eval_fn(apply_fn, ds.x_test, ds.y_test),
               loss_fn=make_loss_fn(apply_fn))


def scheme_config(scheme: str) -> AOConfig:
    # selection_method="paper": the paper's iterative (P5) prefix sweep.
    # The exact enumerator finds a LOWER theta but degenerates to 1-2
    # clients/round (the bound's quadratic phi-coupling over-penalizes
    # participation) and trains worse — see EXPERIMENTS.md §Paper findings.
    base = dict(outer_iters=3, selection_method="paper",
                phi_coupling="mean")
    return {
        "proposed": AOConfig(**base),
        "proposed_exact": AOConfig(outer_iters=3, selection_method="exact"),
        "no_gen": AOConfig(use_phi=False, **base),
        "fixed_pruning": AOConfig(fix_lambda=0.0, **base),
        "fixed_selection": AOConfig(fix_selection=True, **base),
        "fixed_power": AOConfig(fix_power=0.5, **base),
        "fixed_clock": AOConfig(fix_freq=True, **base),
    }[scheme]


def run_scheme(env: Env, scheme: str, *, e0: float | None = None,
               t0: float | None = None, eval_every: int = 10):
    cfg = env.cfg
    e0 = cfg.e0 if e0 is None else e0
    t0 = cfg.t0 if t0 is None else t0
    c = BoundConstants(rounds_S=cfg.rounds - 1, batch_Z=cfg.batch,
                       eta=cfg.eta)
    sched = solve_p1(env.phi, e0, t0, env.ch.uplink, env.ch.downlink,
                     env.sp, c, scheme_config(scheme))
    trainer = FederatedTrainer(env.loss_fn, env.init_fn(jax.random.key(cfg.seed)),
                               env.clients, eta=cfg.eta, batch_size=cfg.batch,
                               seed=cfg.seed,
                               rounds_per_dispatch=cfg.rounds_per_dispatch)
    hist = trainer.run(sched, env.sp, env.ch.uplink, env.ch.downlink,
                       eval_fn=env.eval_fn, eval_every=eval_every,
                       stop_delay=t0, stop_energy=e0)
    return sched, hist


def final_accuracy(hist) -> float:
    accs = [m.test_accuracy for m in hist if m.test_accuracy is not None]
    return accs[-1] if accs else float("nan")


def csv_row(name: str, wall_us: float, derived: str) -> str:
    return f"{name},{wall_us:.1f},{derived}"
