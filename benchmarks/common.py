"""Shared experiment harness for the paper-figure benchmarks (Sec. V) —
now a THIN WRAPPER over the unified experiment API (repro.api).

`ExpConfig`/`Env`/`run_scheme` keep their pre-API shapes so the figure
scripts are unchanged in behavior, but the wiring lives in one place:
`spec_from_config` maps an ExpConfig onto an `ExperimentSpec`, `build_env`
delegates to `repro.api.build_environment`, and `run_scheme` executes a
per-scheme spec against the shared environment via `Experiment.build(env=
...).run()`. The six schemes are exactly the paper's comparisons:

  proposed         joint (P1) with generalization statement
  no_gen           conventional bound (phi = 0 in the optimizer) [31]
  fixed_pruning    lambda = 0 (no pruning)
  fixed_selection  a_n = 1 every round
  fixed_power      p_n = 0.5 W
  fixed_clock      f_n = f_max

(The registry also carries `proposed_exact`, the 2^N-exact (P5) minimizer
kept out of the figure set — see EXPERIMENTS.md §Paper findings.)
"""
from __future__ import annotations

import dataclasses
from typing import Callable

import numpy as np

from repro.api import (
    DataSpec, Environment, Experiment, ExperimentSpec, ModelSpec, RunSpec,
    SchemeSpec, WirelessSpec, build_environment,
)
from repro.api import SCHEMES as _SCHEME_REGISTRY
from repro.core.optimizer_ao import AOConfig
from repro.wireless import ChannelModel, SystemParams

SCHEMES = ("proposed", "no_gen", "fixed_pruning", "fixed_selection",
           "fixed_power", "fixed_clock")


@dataclasses.dataclass
class ExpConfig:
    dataset: str = "synthetic-mnist"     # or synthetic-cifar10
    n_clients: int = 10
    sigma: float = 1.0
    rounds: int = 60
    eta: float = 0.1
    batch: int = 32
    n_train: int = 4000
    n_test: int = 800
    # Budgets are calibrated to the *binding* regime for the synthetic
    # substrate (paper Table-I budgets of 250 J / 150 s are sized for real
    # MNIST workloads; with them every scheme converges unconstrained and
    # ties — EXPERIMENTS.md §Paper). Same budget:per-round-cost ratio as the
    # knee region of the paper's Fig. 7/8.
    e0: float = 4.0                      # [J]
    t0: float = 40.0                     # [s]
    seed: int = 0
    # "auto": per-round dispatch on CPU, multi-round lax.scan blocks on
    # accelerators (core/round_engine.block_step); any int forces it
    rounds_per_dispatch: int | str = "auto"


def spec_from_config(cfg: ExpConfig, scheme: str = "proposed", *,
                     e0: float | None = None, t0: float | None = None,
                     eval_every: int = 10) -> ExperimentSpec:
    """Map the benchmark ExpConfig onto a declarative ExperimentSpec."""
    return ExperimentSpec(
        data=DataSpec(dataset=cfg.dataset, n_clients=cfg.n_clients,
                      sigma=cfg.sigma, n_train=cfg.n_train,
                      n_test=cfg.n_test, seed=cfg.seed),
        model=ModelSpec(name="lenet" if "mnist" in cfg.dataset else "resnet"),
        wireless=WirelessSpec(e0=cfg.e0 if e0 is None else e0,
                              t0=cfg.t0 if t0 is None else t0,
                              seed=cfg.seed),
        scheme=SchemeSpec(name=scheme, rounds=cfg.rounds, eta=cfg.eta,
                          batch=cfg.batch),
        run=RunSpec(seed=cfg.seed, eval_every=eval_every,
                    rounds_per_dispatch=cfg.rounds_per_dispatch))


@dataclasses.dataclass
class Env:
    cfg: ExpConfig
    clients: list
    phi: np.ndarray
    sp: SystemParams
    ch: ChannelModel
    init_fn: Callable
    apply_fn: Callable
    eval_fn: Callable
    loss_fn: Callable
    core: Environment | None = None      # the API-side environment


def build_env(cfg: ExpConfig) -> Env:
    core = build_environment(spec_from_config(cfg))
    return Env(cfg=cfg, clients=core.clients, phi=core.phi, sp=core.sp,
               ch=core.ch, init_fn=core.init_fn, apply_fn=core.apply_fn,
               eval_fn=core.eval_fn, loss_fn=core.loss_fn, core=core)


def scheme_config(scheme: str) -> AOConfig:
    """The scheme's AOConfig, resolved through the API scheme registry."""
    return _SCHEME_REGISTRY.get(scheme)(SchemeSpec(name=scheme))


def run_scheme(env: Env, scheme: str, *, e0: float | None = None,
               t0: float | None = None, eval_every: int = 10,
               out: str | None = None):
    """Solve (P1) for `scheme` over `env` and train under the schedule.

    Returns (schedule, history) exactly as before; `out=` additionally
    exports the full RunResult as JSON-lines (the shared metrics format —
    benchmarks/report.py ingests these)."""
    spec = spec_from_config(env.cfg, scheme, e0=e0, t0=t0,
                            eval_every=eval_every)
    result = Experiment(spec).build(env=env.core).run()
    if out:
        result.to_jsonl(out)
    return result.schedule, result.history


def final_accuracy(hist) -> tuple[float, int]:
    """Last evaluated accuracy and the round it was measured at.

    Tolerates an empty (or never-evaluated) history: returns
    (nan, -1) instead of raising."""
    evals = [(m.test_accuracy, m.round) for m in (hist or [])
             if m.test_accuracy is not None]
    return evals[-1] if evals else (float("nan"), -1)


def csv_row(name: str, wall_us: float, derived: str) -> str:
    return f"{name},{wall_us:.1f},{derived}"
