"""Attack-ablation probe: attacker fraction x aggregator -> accuracy.

Runs the quickstart-scale federation (benchmarks.common.ExpConfig) under a
`ScaledMalicious` upload attack at each attacker rate, once per registered
robust aggregator (plus the undefended mean), and reports final accuracy
and wall-clock per cell — the defense-efficacy evidence for DESIGN.md §11:
at a 30% attacker fraction the trimmed mean and coordinate-wise median
stay within a couple points of the clean-mean accuracy while the
undefended mean visibly degrades.

The scheme pins `fixed_selection` (a_n = 1 every round) so every round
aggregates the full federation: robust rank statistics need enough valid
lanes per round for floor(beta*n) >= the attacker count, and full
participation makes the attacker fraction exact rather than a draw over a
small selected subset. Budgets are lifted so the schedule, not E0/T0,
ends the run.

The attack draw uses `exact=True` — exactly round(rate * n) attackers per
round (membership still rotates), the standard f-of-n Byzantine threat
model. The Bernoulli mode at rate 0.3 over 10 clients exceeds n/2
attackers in ~15% of rounds, past the breakdown point of EVERY robust
reducer (the median tolerates only f < n/2) — no aggregator defends a
round the adversary already owns, so that regime measures nothing.

    PYTHONPATH=src python -m benchmarks.robust_aggregation \
        [--out experiments/robust_aggregation.json] [--quick]

`run_grid` is importable — tests/test_aggregators.py's slow-tier efficacy
test asserts on the same cells this script records.
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import time

from benchmarks.common import ExpConfig, final_accuracy, spec_from_config
from repro.api import Experiment, build_environment

# (aggregator, kwargs): beta sized so floor(beta*10) = 3 trims each tail
# at the 30% attack point; multi_krum budgets the same f=3
AGGREGATORS = [
    ("mean", {}),
    ("coord_median", {}),
    ("trimmed_mean", {"beta": 0.35}),
    ("norm_clip", {}),
    ("multi_krum", {"f": 3}),
]
RATES = (0.0, 0.3)
# +10x magnitude attack (the model's canonical mode): attacked uploads keep
# the honest direction but dominate the average — the undefended mean takes
# a ~(1 + 0.3*(scale-1)) = 3.7x step every round and diverges at quickstart
# eta, while rank reducers trim the oversized uploads and train clean. A
# NEGATIVE scale (ascent attack) is strictly nastier for per-coordinate
# rank reducers: even a perfect trim leaves a kept-window bias of order the
# honest inter-client spread per round (see DESIGN.md §11 limits), which at
# quickstart heterogeneity (Dirichlet sigma=1) swamps learning.
ATTACK_SCALE = 10.0


def attack_spec(cfg: ExpConfig, aggregator: str, kwargs: dict, rate: float):
    spec = spec_from_config(cfg, "fixed_selection", e0=1e6, t0=1e6,
                            eval_every=10)
    wireless = spec.wireless
    if rate > 0.0:
        wireless = dataclasses.replace(
            wireless, fault_model="scaled_malicious",
            fault_kwargs={"rate": rate, "scale": ATTACK_SCALE,
                          "exact": True})
    return dataclasses.replace(
        spec, wireless=wireless,
        scheme=dataclasses.replace(spec.scheme, aggregator=aggregator,
                                   aggregator_kwargs=dict(kwargs)))


def run_grid(cfg: ExpConfig | None = None, *, rates=RATES,
             aggregators=AGGREGATORS, log=None) -> list[dict]:
    """Execute the rate x aggregator grid over ONE shared environment;
    returns one record per cell (spec axes, final accuracy, aggregation /
    fault counters, wall seconds)."""
    cfg = cfg or ExpConfig()
    env = build_environment(attack_spec(cfg, "mean", {}, 0.0))
    rows = []
    for rate in rates:
        for name, kwargs in aggregators:
            spec = attack_spec(cfg, name, kwargs, rate)
            t0 = time.perf_counter()
            res = Experiment(spec).build(env=env).run()
            wall = time.perf_counter() - t0
            acc, at = final_accuracy(res.history)
            row = {
                "aggregator": name, "aggregator_kwargs": dict(kwargs),
                "attack_rate": rate, "attack_scale": ATTACK_SCALE,
                "final_accuracy": acc, "final_accuracy_round": at,
                "rounds_run": res.summary.get("rounds_run"),
                "aggregation": res.summary.get("aggregation"),
                "faults": res.summary.get("faults"),
                "wall_s": round(wall, 2),
            }
            rows.append(row)
            if log is not None:
                log(f"rate={rate:.0%} {name:>13} acc={acc:.3f} "
                    f"({wall:.1f}s) {row['aggregation'] or ''}")
    return rows


def main(argv=None) -> int:
    p = argparse.ArgumentParser(description=__doc__)
    p.add_argument("--out", default="experiments/robust_aggregation.json",
                   help="write the grid records as JSON here")
    p.add_argument("--quick", action="store_true",
                   help="tiny federation (smoke the wiring, not evidence)")
    args = p.parse_args(argv)
    cfg = ExpConfig(n_clients=6, rounds=10, n_train=600, n_test=200) \
        if args.quick else ExpConfig()
    rows = run_grid(cfg, log=print)
    out = {"config": dataclasses.asdict(cfg), "rows": rows}
    with open(args.out, "w") as f:
        json.dump(out, f, indent=2)
        f.write("\n")
    print(f"wrote {args.out}")
    return 0


if __name__ == "__main__":
    import sys
    sys.exit(main())
