"""Sweep-service scaling probe: wall-clock for a seed x scheme matrix at
1 worker vs N, plus the bitwise worker-invariance gate (DESIGN.md §12).

Runs the same 4-seed x 4-scheme matrix (synthetic-mnist, quickstart
scale) through `run_sweep` serially and with a worker pool, after an
untimed warm-up pass that charges all XLA compilation up front (the
per-process trace cache would otherwise gift the second timed sweep the
first one's compiles and fake the speedup). Records wall-clock, the
speedup ratio, and — the part that is a hard regression gate —
whether the per-run JSONL files of the two timed sweeps are BYTE
IDENTICAL: `workers=N` must change scheduling only, never results.

On the CPU boxes this repo benches on, all cells share one XLA device
and the GIL (the CI box exposes a single core), so a pool cannot beat
the serial loop — the speedup ratio here documents the pool's overhead
(per-worker trainer builds + contention), and on a 1-core box it sits
below 1.0 by design. The committed BENCH_sweep_scaling.json compare
therefore mirrors BENCH_round_engine.json's discipline: speedup deltas
WARN (load-sensitive on a cgroup-throttled box) and only a structural
collapse — speedup halving vs the committed baseline — or a parity
violation fails hard.

    PYTHONPATH=src python -m benchmarks.sweep_scaling \
        [--out BENCH_sweep_scaling.json] [--compare BENCH_sweep_scaling.json]
        [--workers N] [--full]
"""
from __future__ import annotations

import argparse
import glob
import json
import os
import tempfile
import time

import jax

from repro.api import (
    DataSpec, ExperimentSpec, JsonlDirSink, ModelSpec, RunSpec, SchemeSpec,
    SweepSpec, WirelessSpec, run_sweep,
)

SCHEMES = ["proposed", "no_gen", "fixed_pruning", "fixed_selection"]
SEEDS = [0, 1, 2, 3]

# speedup falling below this fraction of the committed baseline is a
# structural regression (a worker pool that serializes harder than it
# did — e.g. a new lock around device dispatch), not load noise; an
# absolute floor would be wrong here because the achievable ratio is a
# property of the box's core count, not the code
FLOOR_FRAC = 0.5


def _matrix(fast: bool) -> SweepSpec:
    rounds = 4 if fast else 12
    base = ExperimentSpec(
        data=DataSpec(dataset="synthetic-mnist", n_clients=5, sigma=5.0,
                      n_train=200, n_test=60, seed=0),
        model=ModelSpec(name="mlp-edge"),
        wireless=WirelessSpec(e0=1e6, t0=1e6, seed=0),
        scheme=SchemeSpec(name="proposed", rounds=rounds, eta=0.1, batch=8,
                          ao={"outer_iters": 1}),
        # shards=1 keeps the cells collective-free so the pool really runs
        # parallel on multi-device hosts too — with auto shards the
        # collective-safety gate would serialize the workers=N pass and
        # this probe would measure the gate, not the pool
        run=RunSpec(seed=0, eval_every=2, shards=1))
    return SweepSpec(base=base, seeds=list(SEEDS), schemes=list(SCHEMES))


def _run_file_bytes(directory: str) -> dict[str, bytes]:
    out = {}
    for p in sorted(glob.glob(os.path.join(directory, "0*.jsonl"))):
        with open(p, "rb") as f:
            out[os.path.basename(p)] = f.read()
    return out


def _timed_sweep(sweep: SweepSpec, directory: str, workers: int) -> dict:
    t0 = time.perf_counter()
    res = run_sweep(sweep, sink=JsonlDirSink(directory), workers=workers)
    wall = time.perf_counter() - t0
    assert not res.errors, res.errors
    return {"wall_s": round(wall, 3),
            "n_env_builds": res.n_env_builds,
            "n_trainer_builds": res.n_trainer_builds}


def main(fast: bool = True, out_path: str | None = None,
         compare: str | None = None, workers: int = 4) -> dict:
    sweep = _matrix(fast)
    n_cells = len(sweep.expand())
    with tempfile.TemporaryDirectory() as tmp:
        # untimed warm-up: compile every scheme family's traces once so
        # both timed passes run warm (the trace cache is per-process)
        print(f"warmup: {n_cells} cells ...", flush=True)
        run_sweep(sweep, sink=JsonlDirSink(os.path.join(tmp, "warm")))
        d1 = os.path.join(tmp, "w1")
        dn = os.path.join(tmp, f"w{workers}")
        per_workers = {
            "1": _timed_sweep(sweep, d1, 1),
            str(workers): _timed_sweep(sweep, dn, workers),
        }
        parity = _run_file_bytes(d1) == _run_file_bytes(dn)
    speedup = per_workers["1"]["wall_s"] / per_workers[str(workers)]["wall_s"]
    report = {
        "kind": "sweep_scaling",
        "meta": {"backend": jax.default_backend(),
                 "n_devices": jax.device_count(),
                 "cpu_count": os.cpu_count(),
                 "matrix": f"{len(SEEDS)} seeds x {len(SCHEMES)} schemes",
                 "rounds": sweep.base.scheme.rounds,
                 "profile": "fast" if fast else "full"},
        "n_cells": n_cells,
        "workers": workers,
        "per_workers": per_workers,
        "speedup": round(speedup, 3),
        "parity_bitwise": parity,
    }
    for w, r in per_workers.items():
        print(f"sweep_scaling/workers{w},{r['wall_s'] * 1e6:.0f},"
              f"trainers_built={r['n_trainer_builds']}")
    print(f"sweep_scaling/speedup,{speedup:.3f},"
          f"parity_bitwise={parity}")
    if not parity:
        raise AssertionError(
            "workers>1 changed per-run record bytes — the worker pool "
            "violated the bitwise invariance contract (DESIGN.md §12)")
    if compare is not None:
        if not os.path.exists(compare):
            print(f"WARNING: --compare baseline {compare!r} not found; "
                  f"skipping regression check")
        else:
            with open(compare) as f:
                prev = json.load(f)
            report["compare"] = _compare(prev, report)
    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")
        print(f"wrote {out_path}")
    return report


def _compare(prev: dict, cur: dict) -> dict:
    """Speedup-ratio regression check against a committed report. The
    delta WARNS only (wall clocks on the throttled 2-core box move with
    load); `regressed_floor` is the hard signal run.py gates on."""
    prev_s, cur_s = prev.get("speedup"), cur["speedup"]
    out = {"prev_speedup": prev_s, "cur_speedup": cur_s,
           "regressed_floor": bool(prev_s) and cur_s < FLOOR_FRAC * prev_s}
    if prev_s:
        out["delta"] = round(cur_s - prev_s, 3)
        if out["regressed_floor"]:
            print(f"FAILED: speedup {cur_s:.3f} is less than {FLOOR_FRAC} "
                  f"of the committed {prev_s:.3f} — the worker pool is "
                  f"serializing harder than it did at the baseline")
        elif cur_s < 0.9 * prev_s:
            print(f"WARNING: sweep-scaling speedup {cur_s:.3f} below "
                  f"committed {prev_s:.3f} (throttle-sensitive, not gated)")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--compare", default=None)
    ap.add_argument("--workers", type=int, default=4)
    a = ap.parse_args()
    rep = main(fast=not a.full, out_path=a.out, compare=a.compare,
               workers=a.workers)
    if rep.get("compare", {}).get("regressed_floor"):
        raise SystemExit(1)
