"""Fleet-scale cohort-streaming probe: populations 1e2 -> 1e5 through the
packed engine with the streamed client store (DESIGN.md §13).

For each population the harness builds a `synthetic-fleet` roster (lazy,
host-side), runs the same short `random_k` schedule with
``client_store="streamed"``, and records rounds/sec, H2D bytes, peak
device-resident cohort bytes, and prefetch-stall seconds. At the resident
scales (replicated store <= a few hundred MB) it ALSO runs the replicated
oracle and asserts the streamed trajectory is BITWISE identical — the
cohort store's core contract, checked here at every bench run, not just in
tests.

The headline structural claim — peak device bytes track the COHORT (the
clients the schedule actually touches per block), not the population — is
the compare gate: `peak_cohort_bytes` must stay FLAT (within
``PEAK_FLAT_FACTOR``) across the whole population ladder, and must not
grow past the committed baseline's peak by more than the same factor.
Wall-clock (rounds/sec) deltas WARN only, as everywhere else in this
bench suite (the CI box is cgroup-throttled).

1e6 clients is the documented full-scale point (--full): the roster stays
lazy (O(population) scalars), the phi pass is the only O(population) work
per build, and per-block device cost is unchanged — the fast ladder's
flat-peak gate is what makes that extrapolation sound.

    PYTHONPATH=src python -m benchmarks.fleet_scaling \
        [--out BENCH_fleet_scaling.json] [--compare BENCH_fleet_scaling.json]
        [--full]
"""
from __future__ import annotations

import argparse
import json
import os
import time

import jax
import numpy as np

from repro.api import (
    DataSpec, Experiment, ExperimentSpec, ModelSpec, RunSpec, SchemeSpec,
    WirelessSpec,
)

POPULATIONS_FAST = [100, 1_000, 10_000, 100_000]
POPULATIONS_FULL = [100, 1_000, 10_000, 100_000, 1_000_000]
# replicated-oracle parity legs: populations whose full ClientStore is
# small enough to build alongside the streamed run
PARITY_MAX_POP = 1_000
ROUNDS, K, RPD = 8, 8, 4
# peak cohort bytes may wiggle with bucket-ladder rounding across
# populations, but must never scale with the population; 4x covers one
# pow2 bucket step plus n_max jitter from the per-client count draw
PEAK_FLAT_FACTOR = 4.0


def _spec(population: int, mode: str) -> ExperimentSpec:
    return ExperimentSpec(
        # ~2 samples/client keeps per-cohort n_max flat across the ladder,
        # so the probe isolates how cost scales with POPULATION
        data=DataSpec(dataset="synthetic-fleet", n_clients=population,
                      n_train=2 * population, n_test=64, seed=7),
        model=ModelSpec(name="mlp-edge", kwargs={"hidden": 16}),
        wireless=WirelessSpec(e0=1e6, t0=1e6, seed=0),
        scheme=SchemeSpec(name="random_k", rounds=ROUNDS, batch=4,
                          ao={"k": K, "seed": 1}),
        run=RunSpec(seed=2, evaluate=False, stop_on_budget=False,
                    rounds_per_dispatch=RPD, client_store=mode))


def _records(res) -> list:
    return [(m.round, repr(m.train_loss), tuple(int(i) for i in m.selected))
            for m in res.history]


def _run_population(population: int) -> dict:
    t0 = time.perf_counter()
    run = Experiment(_spec(population, "streamed")).build()
    build_s = time.perf_counter() - t0
    est = run.trainer.store_nbytes()     # replicated store this AVOIDS
    t0 = time.perf_counter()
    res = run.run()
    wall = time.perf_counter() - t0
    fleet = res.summary["fleet"]
    row = {
        "population": population,
        "env_build_s": round(build_s, 3),
        "train_wall_s": round(wall, 3),
        "rounds_per_s": round(ROUNDS / wall, 2),
        "replicated_store_bytes": int(est),
        "h2d_bytes": int(fleet["h2d_bytes"]),
        "peak_cohort_bytes": int(fleet["peak_cohort_bytes"]),
        "prefetch_stall_s": round(float(fleet["prefetch_stall_s"]), 4),
        "n_cohort_swaps": int(fleet["n_cohort_swaps"]),
    }
    if population <= PARITY_MAX_POP:
        oracle = Experiment(_spec(population, "replicated")).build().run()
        row["parity_bitwise"] = _records(oracle) == _records(res)
        if not row["parity_bitwise"]:
            raise AssertionError(
                f"streamed trajectory diverged from the replicated oracle "
                f"at population {population} — the cohort store broke the "
                f"bitwise contract")
    return row


def main(fast: bool = True, out_path: str | None = None,
         compare: str | None = None) -> dict:
    pops = POPULATIONS_FAST if fast else POPULATIONS_FULL
    rows = []
    for pop in pops:
        rows.append(_run_population(pop))
        r = rows[-1]
        print(f"fleet_scaling/pop{pop},{r['train_wall_s'] * 1e6:.0f},"
              f"rounds_per_s={r['rounds_per_s']} "
              f"peak_cohort_bytes={r['peak_cohort_bytes']} "
              f"h2d_bytes={r['h2d_bytes']} "
              f"stall_s={r['prefetch_stall_s']}", flush=True)
    peaks = [r["peak_cohort_bytes"] for r in rows]
    flat = max(peaks) <= PEAK_FLAT_FACTOR * min(peaks)
    report = {
        "kind": "fleet_scaling",
        "meta": {"backend": jax.default_backend(),
                 "n_devices": jax.device_count(),
                 "cpu_count": os.cpu_count(),
                 "rounds": ROUNDS, "k": K, "rounds_per_dispatch": RPD,
                 "profile": "fast" if fast else "full"},
        "rows": rows,
        "peak_flat": flat,
        "peak_spread": round(max(peaks) / min(peaks), 3),
    }
    print(f"fleet_scaling/peak_flat,{report['peak_spread']:.3f},"
          f"flat={flat}")
    if not flat:
        raise AssertionError(
            f"peak cohort bytes spread {report['peak_spread']:.2f}x across "
            f"populations {pops[0]}..{pops[-1]} — device residency is "
            f"scaling with the population, not the cohort")
    if compare is not None:
        if not os.path.exists(compare):
            print(f"WARNING: --compare baseline {compare!r} not found; "
                  f"skipping regression check")
        else:
            with open(compare) as f:
                prev = json.load(f)
            report["compare"] = _compare(prev, report)
    if out_path:
        with open(out_path, "w") as f:
            json.dump(report, f, indent=2)
            f.write("\n")
        print(f"wrote {out_path}")
    return report


def _compare(prev: dict, cur: dict) -> dict:
    """Regression check against a committed report. Peak device bytes are
    the HARD gate (structural: a peak that grew past the flat factor means
    cohort residency regressed toward population residency); rounds/sec
    deltas WARN only (wall clocks on the throttled CI box move with
    load)."""
    prev_rows = {r["population"]: r for r in prev.get("rows", [])}
    peak_regressed, slow = [], []
    for r in cur["rows"]:
        p = prev_rows.get(r["population"])
        if p is None:
            continue
        if r["peak_cohort_bytes"] > PEAK_FLAT_FACTOR * p["peak_cohort_bytes"]:
            peak_regressed.append(r["population"])
        if r["rounds_per_s"] < 0.5 * p["rounds_per_s"]:
            slow.append(r["population"])
    out = {"n_overlap": len(set(prev_rows) & {r["population"]
                                              for r in cur["rows"]}),
           "peak_regressed": peak_regressed}
    if peak_regressed:
        print("FAILED: peak cohort bytes regressed vs committed baseline "
              "at populations", peak_regressed)
    if slow:
        print("WARNING: rounds/sec below half the committed baseline at "
              "populations", slow, "(throttle-sensitive, not gated)")
    return out


if __name__ == "__main__":
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--out", default=None)
    ap.add_argument("--compare", default=None)
    a = ap.parse_args()
    rep = main(fast=not a.full, out_path=a.out, compare=a.compare)
    if rep.get("compare", {}).get("peak_regressed"):
        raise SystemExit(1)
