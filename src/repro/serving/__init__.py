"""Serving runtime: continuous-batching engine over the decode-step API."""
from repro.serving.engine import Request, RequestState, ServingEngine

__all__ = ["Request", "RequestState", "ServingEngine"]
