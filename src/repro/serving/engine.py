"""Continuous-batching serving engine (vLLM-style slot scheduler, JAX-native).

A fixed pool of `max_batch` decode slots shares one KV cache. Requests queue
in; when a slot frees, the next request is prefilled into that slot (its KV
written at the slot's batch row) and joins the in-flight decode batch. Every
engine step decodes ONE token for all active slots with a single jitted
`decode_step` call — no per-request recompilation, no padding churn
(prompt lengths are bucketed to `prompt_buckets` to bound prefill variants).

Works with every architecture family through the transformer public API:
dense/MoE KV caches, SSM state caches, hybrid, cross-attention caches.

Differences vs a datacenter deployment, recorded for honesty:
  * slot KV regions are per-row in one cache (no paged blocks);
  * per-slot position tracking uses a shared `pos` clock per slot via
    row-masked updates — decode writes at each slot's own position using a
    vectorized scatter (positions vector), implemented with per-row
    dynamic updates inside the jitted step.
"""
from __future__ import annotations

import dataclasses
import itertools
import time
from collections import deque
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import transformer as T
from repro.models.blocks import Runtime

PyTree = Any


@dataclasses.dataclass
class Request:
    uid: int
    prompt: np.ndarray            # [P] int32 token ids
    max_new_tokens: int = 32
    eos_id: int | None = None
    temperature: float = 0.0


@dataclasses.dataclass
class RequestState:
    request: Request
    slot: int
    pos: int                      # tokens written so far (prompt + generated)
    generated: list[int] = dataclasses.field(default_factory=list)
    next_token: int = 0           # token to feed at the next decode step
    t_enqueue: float = 0.0
    t_first_token: float | None = None
    t_done: float | None = None

    @property
    def done(self) -> bool:
        r = self.request
        if len(self.generated) >= r.max_new_tokens:
            return True
        return bool(self.generated and r.eos_id is not None
                    and self.generated[-1] == r.eos_id)


def _bucket(n: int, buckets: tuple[int, ...]) -> int:
    for b in buckets:
        if n <= b:
            return b
    return buckets[-1]


class ServingEngine:
    """Slot-based continuous batching over (prefill, decode_step)."""

    def __init__(
        self,
        params: PyTree,
        cfg: ModelConfig,
        *,
        max_batch: int = 8,
        max_seq: int = 512,
        rt: Runtime = Runtime(attn_impl="naive"),
        prompt_buckets: tuple[int, ...] = (32, 64, 128, 256),
        extra: dict | None = None,
        seed: int = 0,
    ):
        self.params = params
        self.cfg = cfg
        self.rt = rt
        self.max_batch = max_batch
        self.max_seq = max_seq
        self.prompt_buckets = tuple(b for b in prompt_buckets if b <= max_seq) \
            or (max_seq,)
        self.extra = extra
        self.cache = T.init_cache(cfg, max_batch, max_seq)
        self.key = jax.random.key(seed)

        self.queue: deque[Request] = deque()
        self.active: dict[int, RequestState] = {}   # slot -> state
        self.free_slots = list(range(max_batch))
        self.finished: list[RequestState] = []
        self._uid = itertools.count()

        self._decode = jax.jit(self._decode_impl)
        self._prefill_jits: dict[int, Callable] = {}

    # ---------------- cache row plumbing ----------------

    @staticmethod
    def _batch_axis(path: str, leaf: jnp.ndarray) -> int:
        """Batch dim index from the cache leaf's role (size-matching is
        ambiguous: num_layers can equal max_batch)."""
        pth = path.lower()
        if "scale" in pth:
            return leaf.ndim - 3          # [*, B, S, H]
        if "'k'" in pth or "'v'" in pth:
            return leaf.ndim - 4          # [*, B, S, Hkv, Dh]
        if "ssm" in pth:
            return leaf.ndim - 4          # [L, B, H, P, N]
        if "conv" in pth:
            return leaf.ndim - 3          # [L, B, W-1, Cd]
        if "enc_out" in pth or "vision" in pth:
            return 0                      # [B, T, D]
        raise ValueError(f"unknown cache leaf {path} {leaf.shape}")

    def _row_cache(self, cache, slot):
        return jax.tree_util.tree_map_with_path(
            lambda kp, c: jax.lax.dynamic_slice_in_dim(
                c, slot, 1,
                axis=self._batch_axis(jax.tree_util.keystr(kp), c)), cache)

    def _write_row(self, cache, row, slot):
        return jax.tree_util.tree_map_with_path(
            lambda kp, c, r: jax.lax.dynamic_update_slice_in_dim(
                c, r.astype(c.dtype), slot,
                axis=self._batch_axis(jax.tree_util.keystr(kp), c)),
            cache, row)

    # ---------------- public API ----------------

    def submit(self, prompt: np.ndarray, **kw) -> int:
        req = Request(uid=next(self._uid), prompt=np.asarray(prompt,
                                                             np.int32), **kw)
        self.queue.append(req)
        return req.uid

    def _admit(self):
        while self.queue and self.free_slots:
            req = self.queue.popleft()
            slot = self.free_slots.pop()
            p = len(req.prompt)
            # prefill prompt[:-1] right-padded to a bucket; the engine's
            # first decode step feeds prompt[-1] at pos = p-1, so pad KV
            # beyond the real length is never attended (kpos < pos). SSM /
            # hybrid state has no positional mask, so those families use the
            # exact length (one jit per distinct length).
            if self.cfg.family in ("ssm", "hybrid"):
                bucket = max(p - 1, 1)
            else:
                bucket = _bucket(max(p - 1, 1), self.prompt_buckets)
            padded = np.zeros(bucket, np.int32)
            padded[: p - 1] = req.prompt[: p - 1]
            if bucket not in self._prefill_jits:
                self._prefill_jits[bucket] = jax.jit(
                    lambda prm, tok, rc: T.prefill(prm, tok, rc, self.cfg,
                                                   self.rt, self.extra))
            row = self._row_cache(self.cache, slot)
            _, row = self._prefill_jits[bucket](
                self.params, jnp.asarray(padded)[None], row)
            self.cache = self._write_row(self.cache, row, slot)
            st = RequestState(request=req, slot=slot, pos=p - 1,
                              t_enqueue=time.time())
            st.next_token = int(req.prompt[-1])
            self.active[slot] = st

    def _decode_impl(self, params, cache, tokens, positions):
        """One decode token for every slot (inactive slots compute garbage
        that is ignored). tokens [B,1]; positions [B]."""
        # per-row decode with its own position: vmap-free approach — run the
        # batched decode_step at a common position is WRONG for ragged slots,
        # so we decode each row against the shared cache via scan over slots.
        def row_step(cache_in, xs):
            tok, pos, slot = xs
            row = self._row_cache(cache_in, slot)
            logits, row2 = T.decode_step(params, tok.reshape(1, 1), row, pos,
                                         self.cfg, self.rt)
            cache_out = self._write_row(cache_in, row2, slot)
            return cache_out, logits[0]

        slots = jnp.arange(self.max_batch)
        cache, logits = jax.lax.scan(row_step, cache,
                                     (tokens[:, 0], positions, slots))
        return logits, cache

    def step(self) -> int:
        """Admit + one decode token for all active slots. Returns #active."""
        self._admit()
        if not self.active:
            return 0
        tokens = np.zeros((self.max_batch, 1), np.int32)
        positions = np.zeros((self.max_batch,), np.int32)
        for slot, st in self.active.items():
            tokens[slot, 0] = st.next_token
            positions[slot] = st.pos
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tokens),
                                          jnp.asarray(positions))
        logits = np.asarray(logits)
        done_slots = []
        for slot, st in self.active.items():
            if st.request.temperature > 0:
                self.key, sub = jax.random.split(self.key)
                tok = int(jax.random.categorical(
                    sub, jnp.asarray(logits[slot]) / st.request.temperature))
            else:
                tok = int(logits[slot].argmax())
            st.generated.append(tok)
            st.next_token = tok
            if st.t_first_token is None:
                st.t_first_token = time.time()
            st.pos += 1
            if st.done or st.pos >= self.max_seq - 1:
                st.t_done = time.time()
                done_slots.append(slot)
        for slot in done_slots:
            self.finished.append(self.active.pop(slot))
            self.free_slots.append(slot)
        return len(self.active)

    def run_to_completion(self, max_steps: int = 10_000) -> list[RequestState]:
        for _ in range(max_steps):
            self._admit()
            if not self.active and not self.queue:
                break
            self.step()
        return self.finished
