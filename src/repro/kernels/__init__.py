"""Pallas TPU kernels for the perf-critical compute layers.

  flash_attention.py   — GQA flash attention (causal/SWA/softcap)
  decode_attention.py  — flash-decoding: one query vs a long KV cache
  pruning_mask.py      — fused eq.-(4) importance + mask (per-tensor and
                         batched per-client), fused pruned-SGD step, fused
                         eq.-(6)/(7) gradient aggregate + FedSGD update
  ssd_chunk.py         — mamba2 SSD intra-chunk kernel

Each has a pure-jnp oracle in ref.py and a jitted wrapper in ops.py; all are
validated in interpret mode on CPU (tests/test_kernels.py,
tests/test_round_engine.py) and target TPU VMEM/MXU tiling. The pruning /
aggregate kernels also have packed-buffer entry points consumed by the
device-resident round engine (DESIGN.md §5).
"""
