"""Pure-jnp oracles for every Pallas kernel (the allclose targets)."""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def flash_attention_ref(q, k, v, *, causal=True, window=0, cap=0.0):
    """q [B,Hq,Sq,D], k/v [B,Hkv,Skv,D] -> [B,Hq,Sq,D] (fp32 math)."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    g = hq // hkv
    qr = q.reshape(b, hkv, g, sq, d).astype(jnp.float32)
    s = jnp.einsum("bhgqd,bhkd->bhgqk", qr, k.astype(jnp.float32)) / np.sqrt(d)
    if cap:
        s = cap * jnp.tanh(s / cap)
    qpos = jnp.arange(sq)[:, None]
    kpos = jnp.arange(skv)[None, :]
    mask = jnp.ones((sq, skv), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask[None, None, None], s, -2.0**30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgqk,bhkd->bhgqd", w, v.astype(jnp.float32))
    return o.reshape(b, hq, sq, d).astype(q.dtype)


def importance_mask_ref(w, v, threshold):
    """Eq. (4) Taylor importance + binary keep-mask.

    Returns (importance (w*v)^2 as fp32, mask {0,1} of the same shape)."""
    q = (w.astype(jnp.float32) * v.astype(jnp.float32)) ** 2
    return q, (q >= threshold).astype(jnp.float32)


def masked_update_ref(w, g, mask, eta):
    """Fused pruned-SGD update: (w - eta g) * mask."""
    out = (w.astype(jnp.float32) - eta * g.astype(jnp.float32)) \
        * mask.astype(jnp.float32)
    return out.astype(w.dtype)


def ssd_chunk_ref(x, b, c, dt, a_log):
    """Intra-chunk SSD for ONE chunk (the Pallas kernel's unit of work).

    x [B,Q,H,P], b/c [B,Q,N], dt [B,Q,H] (post-softplus), a_log [H].
    Returns (y_intra [B,Q,H,P], state_contrib [B,H,P,N], decay_out [B,H]):
      y_intra       = (L ∘ C Bᵀ) (dt·x), L[s,r] = exp(acum_s - acum_r) 1[r<=s]
      state_contrib = sum_r exp(acum_Q - acum_r) dt_r B_r ⊗ x_r
      decay_out     = exp(acum_Q)  (carried-state multiplier)
    """
    bsz, q, h, p = x.shape
    a = -jnp.exp(a_log.astype(jnp.float32))
    ld = dt.astype(jnp.float32) * a
    acum = jnp.cumsum(ld, axis=1)
    diff = acum[:, :, None, :] - acum[:, None, :, :]
    tril = jnp.tril(jnp.ones((q, q), bool))
    lmat = jnp.exp(jnp.where(tril[None, :, :, None], diff, -1e30))
    cb = jnp.einsum("bsn,brn->bsr", c.astype(jnp.float32), b.astype(jnp.float32))
    xdt = x.astype(jnp.float32) * dt.astype(jnp.float32)[..., None]
    y = jnp.einsum("bsrh,brhp->bshp", cb[..., None] * lmat, xdt)
    atot = acum[:, -1]
    decay_r = jnp.exp(atot[:, None] - acum)
    state = jnp.einsum("brn,brhp,brh->bhpn", b.astype(jnp.float32), xdt, decay_r)
    return y.astype(x.dtype), state, jnp.exp(atot)


def decode_attention_ref(q, k, v, pos):
    """One-query decode oracle. q [B,Hq,1,D]; k/v [B,Skv,Hkv,D]; pos scalar.
    Returns [B,Hq,1,D]."""
    b, hq, _, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qr = q.reshape(b, hkv, g, d).astype(jnp.float32)
    kt = jnp.swapaxes(k, 1, 2).astype(jnp.float32)   # [B,Hkv,S,D]
    vt = jnp.swapaxes(v, 1, 2).astype(jnp.float32)
    s = jnp.einsum("bhgd,bhkd->bhgk", qr, kt) / np.sqrt(d)
    valid = jnp.arange(skv) < pos
    s = jnp.where(valid[None, None, None], s, -2.0**30)
    w = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhgk,bhkd->bhgd", w, vt)
    return o.reshape(b, hq, 1, d).astype(q.dtype)
