"""Pallas kernel for the SSD intra-chunk hot spot (mamba2 / hymba).

Per grid step (batch, head) the kernel computes, for one chunk of length Q:
  scores  = (C Bᵀ) ∘ L          (L = causal decay matrix from cumsum(dt·A))
  y       = scores @ (dt ∘ X)   [Q, P]
  state   = (decay_out ∘ B)ᵀ @ (dt ∘ X)   [N, P]  (chunk state contribution)

VMEM tiling: Q defaults to 128 (sublane-aligned); P, N are 64/128 for the
assigned configs — all MXU-friendly. The inter-chunk recurrence (sequential
by nature) stays a lax.scan on the host side (ops.ssd_chunked_pallas).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG = -1e30


def _ssd_chunk_kernel(x_ref, b_ref, c_ref, dt_ref, alog_ref,
                      y_ref, st_ref, dec_ref, *, q: int):
    # refs: x [1,Q,1,P], b/c [1,Q,N], dt [1,Q,1], alog [1]
    x = x_ref[0, :, 0, :].astype(jnp.float32)          # [Q, P]
    bmat = b_ref[0].astype(jnp.float32)                # [Q, N]
    cmat = c_ref[0].astype(jnp.float32)                # [Q, N]
    dt = dt_ref[0, :, 0].astype(jnp.float32)           # [Q]
    a = -jnp.exp(alog_ref[0].astype(jnp.float32))      # scalar
    ld = dt * a
    acum = jnp.cumsum(ld)                              # [Q]
    diff = acum[:, None] - acum[None, :]               # [Q, Q]
    row = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    col = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    lmat = jnp.exp(jnp.where(col <= row, diff, NEG))
    cb = jax.lax.dot_general(cmat, bmat, (((1,), (1,)), ((), ())))  # [Q, Q]
    xdt = x * dt[:, None]                              # [Q, P]
    y = jax.lax.dot(cb * lmat, xdt)                    # [Q, P]
    atot = acum[q - 1]
    decay_r = jnp.exp(atot - acum)                     # [Q]
    bw = bmat * decay_r[:, None]                       # [Q, N]
    state = jax.lax.dot_general(bw, xdt, (((0,), (0,)), ((), ())))  # [N, P]
    y_ref[0, :, 0, :] = y.astype(y_ref.dtype)
    st_ref[0, 0] = state.astype(st_ref.dtype)
    dec_ref[0, 0] = jnp.exp(atot).astype(dec_ref.dtype)


def ssd_chunk(x, b, c, dt, a_log, *, interpret: bool | None = None):
    """One chunk, all batches/heads. x [B,Q,H,P], b/c [B,Q,N], dt [B,Q,H],
    a_log [H] -> (y [B,Q,H,P], state [B,H,N,P], decay [B,H])."""
    bsz, q, h, p = x.shape
    n = b.shape[-1]
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    kernel = functools.partial(_ssd_chunk_kernel, q=q)
    y, st, dec = pl.pallas_call(
        kernel,
        grid=(bsz, h),
        in_specs=[
            pl.BlockSpec((1, q, 1, p), lambda bi, hi: (bi, 0, hi, 0)),
            pl.BlockSpec((1, q, n), lambda bi, hi: (bi, 0, 0)),
            pl.BlockSpec((1, q, n), lambda bi, hi: (bi, 0, 0)),
            pl.BlockSpec((1, q, 1), lambda bi, hi: (bi, 0, hi)),
            pl.BlockSpec((1,), lambda bi, hi: (hi,)),
        ],
        out_specs=[
            pl.BlockSpec((1, q, 1, p), lambda bi, hi: (bi, 0, hi, 0)),
            pl.BlockSpec((1, 1, n, p), lambda bi, hi: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1), lambda bi, hi: (bi, hi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bsz, q, h, p), x.dtype),
            jax.ShapeDtypeStruct((bsz, h, n, p), jnp.float32),
            jax.ShapeDtypeStruct((bsz, h), jnp.float32),
        ],
        interpret=interpret,
    )(x, b, c, dt, a_log)
    return y, st, dec
