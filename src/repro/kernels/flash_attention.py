"""Pallas TPU flash attention (GQA, causal, sliding window, softcap).

TPU-native design (not a CUDA port):
  * grid = (batch, q_heads, q_blocks, kv_blocks); the kv_blocks axis is
    `arbitrary` (sequential) so the online-softmax accumulators can live in
    VMEM scratch across kv steps — the MXU consumes [BQ, D] x [D, BK] tiles.
  * BlockSpecs tile q/o as [1, 1, BQ, D] and k/v as [1, 1, BK, D] with an
    index map translating q-head -> kv-head (GQA: h // group).
  * block shapes default to 128 (MXU native); accumulation is fp32.
  * causal/window blocks that are fully masked are skipped with pl.when
    (structural zero-work, not just masking).

Validated in interpret mode on CPU against ref.flash_attention_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0**30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_scr, l_scr, acc_scr, *,
                  causal: bool, window: int, cap: float, bq: int, bk: int,
                  nk: int, scale: float):
    ki = pl.program_id(3)
    qi = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    q_start = qi * bq
    k_start = ki * bk
    # structural skip: block fully outside the causal/window band
    live = jnp.bool_(True)
    if causal:
        live = jnp.logical_and(live, k_start <= q_start + bq - 1)
    if window:
        live = jnp.logical_and(live, k_start + bk - 1 > q_start - window)

    @pl.when(live)
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [BQ, D]
        k = k_ref[0, 0].astype(jnp.float32)          # [BK, D]
        v = v_ref[0, 0].astype(jnp.float32)          # [BK, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        if cap:
            s = cap * jnp.tanh(s / cap)
        qpos = q_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        mask = jnp.ones((bq, bk), bool)
        if causal:
            mask &= kpos <= qpos
        if window:
            mask &= kpos > qpos - window
        s = jnp.where(mask, s, NEG_INF)

        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(p, v)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-20)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def flash_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, *,
    causal: bool = True, window: int = 0, cap: float = 0.0,
    block_q: int = 128, block_k: int = 128, interpret: bool | None = None,
) -> jnp.ndarray:
    """q [B,Hq,Sq,D]; k/v [B,Hkv,Skv,D] -> [B,Hq,Sq,D]."""
    b, hq, sq, d = q.shape
    hkv, skv = k.shape[1], k.shape[2]
    if hq % hkv:
        raise ValueError("GQA requires Hq % Hkv == 0")
    g = hq // hkv
    bq = min(block_q, sq)
    bk = min(block_k, skv)
    if sq % bq or skv % bk:
        raise ValueError(f"seq ({sq},{skv}) must divide blocks ({bq},{bk})")
    nq, nk = sq // bq, skv // bk
    if interpret is None:
        interpret = jax.default_backend() != "tpu"

    kernel = functools.partial(
        _flash_kernel, causal=causal, window=window, cap=cap,
        bq=bq, bk=bk, nk=nk, scale=1.0 / np.sqrt(d))

    return pl.pallas_call(
        kernel,
        grid=(b, hq, nq, nk),
        in_specs=[
            pl.BlockSpec((1, 1, bq, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, qi, ki: (b_, h // g, ki, 0)),
            pl.BlockSpec((1, 1, bk, d), lambda b_, h, qi, ki: (b_, h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, bq, d), lambda b_, h, qi, ki: (b_, h, qi, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),     # running max m
            pltpu.VMEM((bq,), jnp.float32),     # running denom l
            pltpu.VMEM((bq, d), jnp.float32),   # fp32 output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
