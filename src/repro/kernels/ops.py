"""Jitted public wrappers around the Pallas kernels.

These adapt model-layout tensors to kernel layouts, handle padding to tile
multiples, and fall back to interpret mode off-TPU automatically.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_attention as _fa
from repro.kernels import pruning_mask as _pm
from repro.kernels import ssd_chunk as _sc

PyTree = Any
LANES = _pm.LANES


# ---------------------------------------------------------------------------
# Flash attention: model layout [B, S, H, D] <-> kernel layout [B, H, S, D]
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("causal", "window", "cap",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, window=0, cap=0.0,
                    block_q=128, block_k=128):
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    o = _fa.flash_attention(qt, kt, vt, causal=causal, window=window, cap=cap,
                            block_q=block_q, block_k=block_k)
    return jnp.swapaxes(o, 1, 2)


# ---------------------------------------------------------------------------
# Pruning: arbitrary pytree leaves -> padded [R, LANES] tiles
# ---------------------------------------------------------------------------

def _to_tiles(x: jnp.ndarray) -> tuple[jnp.ndarray, int]:
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % LANES
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, LANES), n


def _from_tiles(t: jnp.ndarray, n: int, shape, dtype) -> jnp.ndarray:
    return t.reshape(-1)[:n].reshape(shape).astype(dtype)


@jax.jit
def importance_and_mask(w: jnp.ndarray, v: jnp.ndarray, threshold):
    """Fused eq.-(4) importance + keep-mask for one tensor (any shape)."""
    wt, n = _to_tiles(w)
    vt, _ = _to_tiles(v)
    q, m = _pm.importance_mask_2d(wt, vt, threshold,
                                  block_rows=_packed_block_rows(wt.shape[0]))
    return (_from_tiles(q, n, w.shape, jnp.float32),
            _from_tiles(m, n, w.shape, jnp.float32))


@jax.jit
def masked_update(w: jnp.ndarray, g: jnp.ndarray, mask: jnp.ndarray, eta):
    """Fused pruned-SGD step for one tensor."""
    wt, n = _to_tiles(w)
    gt, _ = _to_tiles(g)
    mt, _ = _to_tiles(mask)
    out = _pm.masked_update_2d(wt, gt, mt, eta,
                               block_rows=_packed_block_rows(wt.shape[0]))
    return _from_tiles(out, n, w.shape, w.dtype)


# ---------------------------------------------------------------------------
# Packed-buffer entry points (core/packing.py layout: [R, 128], R % block == 0)
#
# The packed round engine hands whole-model buffers straight to the kernels —
# no per-leaf flatten/pad, one launch per model per operation. Each entry
# point takes `impl`:
#
#   * "pallas" — the fused Pallas kernels (interpret mode off-TPU);
#   * "xla"    — an op-for-op jnp mirror with the same reduction order
#                (bit-identical results); faster on CPU, where interpret-mode
#                Pallas adds per-launch emulation overhead;
#   * "auto"   — pallas on TPU, xla elsewhere.
# ---------------------------------------------------------------------------

def _packed_block_rows(rows: int) -> int:
    return next(c for c in (256, 128, 64, 32, 16, 8, 4, 2, 1) if rows % c == 0)


def _resolve_impl(impl: str) -> str:
    if impl == "auto":
        return "pallas" if jax.default_backend() == "tpu" else "xla"
    if impl not in ("pallas", "xla"):
        raise ValueError(f"unknown impl {impl!r}")
    return impl


@functools.partial(jax.jit, static_argnames=("impl",))
def packed_importance_mask(w, v, prunable, threshold, *, impl="auto"):
    """Shared-threshold path: one fused importance+mask pass for the whole
    packed model (the single-tensor kernel, previously orphaned, applied to
    the [R, 128] packed buffer). Protected/padding coordinates (prunable == 0)
    are always kept. Returns (importance fp32, mask fp32), both [R, 128]."""
    if _resolve_impl(impl) == "pallas":
        q, keep = _pm.importance_mask_2d(
            w, v, threshold, block_rows=_packed_block_rows(w.shape[0]))
    else:
        q = jnp.square(w.astype(jnp.float32) * v.astype(jnp.float32))
        keep = (q >= threshold).astype(jnp.float32)
    return q, jnp.where(prunable > 0, keep, 1.0)


@functools.partial(jax.jit, static_argnames=("impl",))
def packed_importance_masks(w, v, prunable, thresholds, *, impl="auto"):
    """Per-client-threshold path: (importance [R,128], masks [C,R,128])."""
    if _resolve_impl(impl) == "pallas":
        return _pm.importance_mask_batched(
            w, v, prunable, thresholds,
            block_rows=_packed_block_rows(w.shape[0]))
    q = jnp.square(w.astype(jnp.float32) * v.astype(jnp.float32))
    keep = (q[None] >= thresholds[:, None, None]).astype(jnp.float32)
    return q, jnp.where(prunable[None] > 0, keep, 1.0)


@functools.partial(jax.jit, static_argnames=("impl",))
def packed_exponent_histogram(q, prunable, *, impl="auto"):
    """256-bin histogram of fp32 exponent bytes over valid coordinates.

    The coarse first pass of ``kth_smallest_threshold(coarse="histogram")``
    (core/round_engine.py): bin b counts coordinates with
    ``bits(q) >> 23 == b`` and prunable > 0. ``impl="pallas"`` runs the
    tiled kernel (per-block bin counts in VMEM scratch, compare-reduce
    instead of scatter — requires the packed [R, 128*k] layout and falls
    back to the mirror otherwise); "xla" is the scatter-add mirror, exact
    everywhere but ~130 ns/element on CPU (why coarse="auto" keeps plain
    bisection there, see ROADMAP)."""
    if _resolve_impl(impl) == "pallas" and q.ndim == 2 \
            and q.shape[1] % LANES == 0:
        return _pm.exponent_histogram(
            q, prunable, block_rows=_packed_block_rows(q.shape[0]))
    bits = jax.lax.bitcast_convert_type(q.reshape(-1), jnp.int32)
    valid = prunable.reshape(-1) > 0
    return jnp.zeros((256,), jnp.int32).at[bits >> 23].add(
        valid.astype(jnp.int32))


def _rounded_product(eta, g):
    """eta * g rounded to fp32 *before* any consumer sees it.

    A plain `w - eta * g` inside a jitted graph is contracted by XLA:CPU
    into an FMA, skipping the product's intermediate rounding and breaking
    bit-parity with the eager reference update (two separate dispatches).
    Neither `optimization_barrier` nor multi-use outputs survive fusion
    duplication, but a while loop whose trip count the compiler cannot
    prove to be 1 does: the product is materialized in the loop carry, so
    the subtraction can only consume the rounded value. The bound is
    derived from runtime data (1, or 2 on a NaN input — the body is
    idempotent) precisely so it is not constant-foldable."""
    n = jnp.int32(1) + jnp.isnan(g[0, 0]).astype(jnp.int32)

    def body(carry):
        i, _ = carry
        return i + 1, eta * g

    _, step = jax.lax.while_loop(lambda c: c[0] < n, body,
                                 (jnp.int32(0), jnp.zeros_like(g)))
    return step


# public name: callers outside the fused aggregate (e.g. tests) sometimes
# need the bare fence
rounded_step = _rounded_product


def packed_local_delta(g, u, u0, coeff, hm=None):
    """Per-local-step update direction for the scheme zoo (DESIGN.md §14).

    d = g + coeff*(u - u0) [- hm], with the regularizer product FMA-fenced:
    the eager reference computes ``coeff * (u - u0)`` as its own dispatch
    (rounded to fp32) before adding g, so the fused graph must materialize
    the rounded product too or the `g + coeff*(u-u0)` add contracts into an
    FMA and drifts by an ulp.  The subtraction ``u - u0`` and the optional
    ``- hm`` (FedDyn's masked correction state) are single ops on both
    backends — exact, no fence needed.

    coeff == 0.0 would fence a zero product; callers skip the call for the
    plain-FedAvg direction instead of passing 0.
    """
    d = g + _rounded_product(jnp.float32(coeff), u - u0)
    if hm is not None:
        d = d - hm
    return d


def packed_apply_mean_update(w, gsum, inv, eta, noise=None):
    """g = gsum * inv (+ noise), then the FMA-fenced FedSGD step:
    (w', g, step).

    The single tail shared by the weighted aggregate's XLA mirror and the
    sharded round engine (which applies it after the cross-shard psum) —
    one copy of the fence-sensitive sequence, not three.

    `noise` models a noisy aggregation channel (the server only observes
    mean(g) + noise): it is added BEFORE the update and becomes part of the
    broadcast g. The mean product is fenced on that path so the add cannot
    be FMA-contracted with it — the eager reference sequence (scale, then
    add, two dispatches) rounds each op, and bit-parity requires the fused
    graph to do the same."""
    if noise is None:
        g = gsum * inv
    else:
        g = _rounded_product(inv, gsum) + noise
    step = _rounded_product(eta, g)
    return (w.astype(jnp.float32) - step).astype(w.dtype), g, step


def packed_client_quarantine(grads, cweights, inv):
    """Always-on non-finite upload guard (DESIGN.md §10): per-client
    isfinite flags over the stacked masked gradients [C, R, 128], returning
    ``(cw_eff, inv_eff, n_ok, alive)`` for the weighted aggregate.

    * cw_eff  — cweights with non-finite clients zeroed. With every upload
      finite (the default path) this is ``cweights * 1.0`` — the exact same
      0/1 values, so the downstream weighted sum is bitwise unchanged.
    * inv_eff — the mean's 1/n. When nobody is quarantined it passes the
      HOST-computed `inv` through untouched (the bit-for-bit contract's
      value); with survivors missing it renormalizes to 1/n_ok on device —
      which equals the host convention ``float32(1/n)`` exactly, because
      binary64->binary32 double rounding is safe for division (p=53 >=
      2*24+2); all clients quarantined yields 0 (the caller skips the
      update entirely via `alive`).
    * n_ok    — int32 count of surviving (weighted AND finite) clients,
      surfaced per round as RoundEngine.last_n_ok -> the n_quarantined /
      n_skipped_rounds counters.
    * alive   — scalar bool, False when no client survives: the caller
      carries (w, v) unchanged through the round (params untouched).

    Zero-weight clients (client-axis padding, host-dropped faults) are
    excluded from both counts by construction (their cw is already 0).

    Contract — the guard detects NON-FINITE uploads only. A *finite*
    corrupted or adversarial upload (`CorruptUpload(mode="scale")`,
    `SignFlip`, `ScaledMalicious` — core/faults.py) passes unflagged BY
    DESIGN: finiteness is the only property checkable without a model of
    honest gradients, so the quarantine is a crash barrier, not a defense.
    Bounding finite adversaries is the robust aggregators' job
    (core/aggregators.py / packed_robust_aggregate); reporting keeps the
    two failure classes distinct (`summary["faults"]["n_quarantined"]` vs
    `n_corrupt_finite` — core/federated.py)."""
    cw = cweights.astype(jnp.float32)
    fin = jnp.isfinite(grads).all(axis=(1, 2))
    cw_eff = cw * fin.astype(jnp.float32)
    n_w = cw.sum()
    n_ok = cw_eff.sum()
    inv_eff = jnp.where(
        n_ok == n_w, jnp.asarray(inv, jnp.float32),
        jnp.where(n_ok > 0.0, 1.0 / jnp.maximum(n_ok, 1.0), 0.0))
    return cw_eff, inv_eff, n_ok.astype(jnp.int32), n_ok > 0.0


def packed_weighted_grad_sum(grads, cweights):
    """sum_c cweights[c] * grads[c] in client-stack order, [C,R,128]->[R,128].

    Zero-weight (padding) clients are *skipped* via `where` rather than
    multiplied in, so garbage gradients from replicated padding batches can
    never reach the update (not even as NaN), and weight-1 clients
    accumulate as `acc + 1.0*g` — bit-identical to the unweighted
    reference sum. Used per shard by the sharded round engine (the psum
    over shards is the round's single collective) and by the XLA mirror of
    the weighted aggregate."""
    acc = jnp.zeros(grads.shape[1:], jnp.float32)
    cw = cweights.astype(jnp.float32)
    for c in range(grads.shape[0]):          # static unroll: same summation
        acc = jnp.where(cw[c] > 0.0,          # order as the reference
                        acc + cw[c] * grads[c].astype(jnp.float32), acc)
    return acc


_INT32_MAX = 2**31 - 1


def _order_keys(x):
    """Monotone int32 total-order keys for fp32 values: ``b ^ ((b >> 31) &
    0x7fffffff)`` on the bit pattern (an involution) maps IEEE-754 floats
    to integers that compare like the values, negatives included — the
    same bit-pattern machinery the PR-1 k-th-smallest threshold search
    uses, here driving client-axis rank selection. -0.0 orders strictly
    below +0.0 (distinct keys), so ties always carry identical bits and
    any sort — stable, unstable, or a sort network — produces the same
    per-rank values."""
    b = jax.lax.bitcast_convert_type(x.astype(jnp.float32), jnp.int32)
    return b ^ ((b >> 31) & jnp.int32(0x7FFFFFFF))


def packed_client_rank_sort(grads, cweights, *, impl="auto"):
    """Per-coordinate rank sort along the client axis of a [C, R, 128]
    gradient stack; zero-weight (padding / quarantined) clients are keyed
    to INT32_MAX so every rank < n_valid holds a real value and ranks >=
    n_valid hold don't-cares the weight-aware reducers never read. "pallas"
    runs the odd-even transposition-network kernel
    (pruning_mask.client_rank_sort); "xla" a stable `lax.sort` on the same
    keys — both emit bitwise-identical per-rank values (ties share bit
    patterns). Valid lanes cannot collide with the sentinel: a key of
    INT32_MAX is a NaN bit pattern, and non-finite clients are quarantined
    to weight 0 before rank selection."""
    if _resolve_impl(impl) == "pallas":
        return _pm.client_rank_sort(
            grads, cweights, block_rows=_packed_block_rows(grads.shape[1]))
    g = grads.astype(jnp.float32)
    key = _order_keys(g)
    invalid = ~(cweights.astype(jnp.float32) > 0.0)
    key = jnp.where(invalid[:, None, None], jnp.int32(_INT32_MAX), key)
    _, sv = jax.lax.sort((key, g), dimension=0, num_keys=1, is_stable=True)
    return sv


def _sorted_median(sorted_vals, nn):
    """Midpoint of ranks (nn-1)//2 and nn//2 of a rank-sorted stack — the
    median over the nn valid lanes ((a+a)*0.5 is exact for odd counts, so
    odd-count medians are the rank value bit-for-bit)."""
    lo = jax.lax.dynamic_index_in_dim(sorted_vals, (nn - 1) // 2, axis=0,
                                      keepdims=False)
    hi = jax.lax.dynamic_index_in_dim(sorted_vals, nn // 2, axis=0,
                                      keepdims=False)
    return (lo + hi) * 0.5


def packed_robust_aggregate(grads, cweights, *, kind, impl="auto",
                            beta=0.1, tau=None, f=1, m=None):
    """Weight-aware Byzantine-robust reduction of a packed gradient stack.

    grads: [C, R, 128] stacked per-client masked gradients; cweights: [C]
    effective validity weights — 0 marks client-axis padding, host-dropped
    uploads, AND quarantined (non-finite) clients, exactly the `cw_eff`
    ops.packed_client_quarantine emits. Returns ``(ghat, stat)``: the
    robust aggregate [R, 128] fp32 (already survivor-normalized — the
    caller applies it with inv=1.0 through the FMA-fenced update tail) and
    an int32 diagnostic count (clients trimmed / clipped / excluded this
    round, 0 for an all-faulted round).

    Weight-aware contract: zero-weight lanes are excluded from ranks,
    norms, and distance scores — their (garbage) values cannot influence
    any output bit — and every mean renormalizes over the lanes that
    actually contributed. All client-axis reductions are ordered
    where-accumulates (or monolithic dots) over the valid prefix, so the
    result is invariant to the bucket capacity C and bitwise identical
    between the packed graph, the eager reference backend, and the
    all-gather sharded path (DESIGN.md §11).

    Kinds (core/aggregators.py wraps these as registry entries):
      * "coord_median"     — coordinate-wise median over valid lanes via
        rank sort (Pallas sort network on TPU, stable lax.sort mirror
        elsewhere — `packed_client_rank_sort`).
      * "trimmed_mean"     — drop the floor(beta*n) smallest and largest
        values per coordinate, mean the middle; beta in [0, 0.5).
      * "norm_clip"        — scale client c by min(1, tau/||g_c||); tau
        None/0 = adaptive median-of-norms over valid clients.
      * "multi_krum"       — Blanchard-style selection: per-client score =
        sum of its n-f-2 smallest squared distances to other valid
        clients (one Gram matmul, invalid pairs +inf), keep the m
        lowest-scoring clients (default n-f), mean them.
    """
    g = grads.astype(jnp.float32)
    cw = cweights.astype(jnp.float32)
    valid = cw > 0.0
    n = valid.astype(jnp.int32).sum()
    nn = jnp.maximum(n, 1)
    c_b = g.shape[0]
    if kind == "coord_median":
        sv = packed_client_rank_sort(g, cw, impl=impl)
        ghat = _sorted_median(sv, nn)
        # clients outside the (one- or two-element) median window
        stat = jnp.maximum(n - 2 + (n & 1), 0)
    elif kind == "trimmed_mean":
        if not 0.0 <= beta < 0.5:
            raise ValueError(f"trimmed_mean beta must be in [0, 0.5), "
                             f"got {beta}")
        sv = packed_client_rank_sort(g, cw, impl=impl)
        t = jnp.floor(jnp.float32(beta)
                      * nn.astype(jnp.float32)).astype(jnp.int32)
        keep = jnp.maximum(nn - 2 * t, 1)
        acc = jnp.zeros(g.shape[1:], jnp.float32)
        for c in range(c_b):                 # static unroll: rank order
            acc = jnp.where((c >= t) & (c < nn - t), acc + sv[c], acc)
        ghat = acc * (1.0 / keep.astype(jnp.float32))
        stat = jnp.minimum(2 * t, n)
    elif kind == "norm_clip":
        # per-client L2 norms as monolithic dots (deterministic reduction
        # order for a given [C, R, L] shape on every backend)
        sq = jnp.einsum("crl,crl->c", g, g)
        norms = jnp.sqrt(sq)
        if tau is None or float(tau) <= 0.0:
            key = jnp.where(valid, _order_keys(norms),
                            jnp.int32(_INT32_MAX))
            _, sn = jax.lax.sort((key, norms), dimension=0, num_keys=1,
                                 is_stable=True)
            lo = jax.lax.dynamic_index_in_dim(sn, (nn - 1) // 2, axis=0,
                                              keepdims=False)
            hi = jax.lax.dynamic_index_in_dim(sn, nn // 2, axis=0,
                                              keepdims=False)
            tau_t = (lo + hi) * 0.5
        else:
            tau_t = jnp.float32(tau)
        # a quarantined client's NaN norm fails both compares: factor 1.0,
        # and its weight is already 0 in the sum
        clipped = valid & (norms > tau_t)
        factor = jnp.where(norms > tau_t, tau_t / norms, jnp.float32(1.0))
        gsum = packed_weighted_grad_sum(g * factor[:, None, None], cw)
        ghat = gsum * (1.0 / nn.astype(jnp.float32))
        stat = clipped.astype(jnp.int32).sum()
    elif kind == "multi_krum":
        if int(f) < 0:
            raise ValueError(f"multi_krum f must be >= 0, got {f}")
        if m is not None and int(m) < 1:
            raise ValueError(f"multi_krum m must be >= 1, got {m}")
        gm = g.reshape(c_b, -1)
        gram = gm @ gm.T                     # one dot: all pairwise inners
        sq = jnp.diagonal(gram)
        # 2*gram is exact (x2 never rounds), so the expression cannot be
        # perturbed by FMA contraction of the subtract
        d2 = sq[:, None] + sq[None, :] - 2.0 * gram
        inf = jnp.float32(jnp.inf)
        pair_ok = valid[:, None] & valid[None, :] \
            & ~jnp.eye(c_b, dtype=bool)
        sd = jnp.sort(jnp.where(pair_ok, d2, inf), axis=1)
        # each valid row has n-1 finite entries, and k_nb <= n-2, so no
        # +inf sentinel can reach a valid client's score
        k_nb = jnp.clip(n - jnp.int32(int(f)) - 2, 1, max(c_b - 1, 1))
        score = jnp.zeros((c_b,), jnp.float32)
        for j in range(c_b):                 # static unroll: rank order
            score = jnp.where(j < k_nb, score + sd[:, j], score)
        score = jnp.where(valid, score, inf)
        # valid clients first even on tied +inf scores (the sentinel is
        # strictly above the +inf key), stable on remaining ties
        skey = jnp.where(valid, _order_keys(score), jnp.int32(_INT32_MAX))
        m_sel = jnp.clip(
            n - jnp.int32(int(f)) if m is None else jnp.int32(int(m)),
            1, nn)
        _, order = jax.lax.sort(
            (skey, jnp.arange(c_b, dtype=jnp.int32)), dimension=0,
            num_keys=1, is_stable=True)
        sel = jnp.zeros((c_b,), jnp.float32).at[order].set(
            (jnp.arange(c_b) < m_sel).astype(jnp.float32))
        gsum = packed_weighted_grad_sum(g, sel * cw)
        ghat = gsum * (1.0 / m_sel.astype(jnp.float32))
        stat = jnp.maximum(n - m_sel, 0)
    else:
        raise ValueError(f"unknown robust aggregate kind {kind!r}")
    return ghat, stat.astype(jnp.int32)


@functools.partial(jax.jit, static_argnames=("impl",))
def packed_fedsgd_update(w, grads, eta, *, impl="auto"):
    """Fused eqs. (6)-(7): average stacked masked gradients [C,R,128] and
    apply the FedSGD step, returning (w', mean_grad, step).

    The "xla" path reproduces the eager reference loop bit-for-bit (same
    summation order, FMA-fenced update — see `_rounded_product`). The
    "pallas" kernel keeps the update fully fused in one pass; on real TPU
    hardware the contraction there may differ from the reference by 1 ulp."""
    if _resolve_impl(impl) == "pallas":
        return _pm.fedsgd_aggregate(
            w, grads, eta, block_rows=_packed_block_rows(w.shape[0]))
    g = grads[0].astype(jnp.float32)
    for c in range(1, grads.shape[0]):       # same summation order as the
        g = g + grads[c].astype(jnp.float32)  # kernel / reference trainer
    g = g * (1.0 / grads.shape[0])
    step = _rounded_product(eta, g)
    return (w.astype(jnp.float32) - step).astype(w.dtype), g, step


@functools.partial(jax.jit, static_argnames=("impl",))
def packed_fedsgd_update_weighted(w, grads, cweights, inv, eta, *,
                                  impl="auto"):
    """Weighted eqs. (6)-(7): g = (sum_c cw[c]*grads[c]) * inv, w' = w -
    eta*g, returning (w', g, step). The bucketed round engine's aggregate:
    cweights marks real clients (1) vs client-axis padding (0) and inv =
    1/#real is host-computed, so one compiled graph serves every selected
    count in a bucket. With 0/1 weights this reproduces
    `packed_fedsgd_update` — and hence the eager reference loop — bit for
    bit on the real-client prefix (same summation order, `1.0*g` exact,
    same FMA-fenced update; see `packed_weighted_grad_sum`)."""
    if _resolve_impl(impl) == "pallas":
        return _pm.fedsgd_aggregate_weighted(
            w, grads, cweights, inv, eta,
            block_rows=_packed_block_rows(w.shape[0]))
    return packed_apply_mean_update(
        w, packed_weighted_grad_sum(grads, cweights), inv, eta)


@functools.partial(jax.jit, static_argnames=("impl",))
def packed_masked_update(w, g, mask, eta, *, impl="auto"):
    """Fused (w - eta*g)*mask on a packed buffer (masked_update_2d, one
    launch for the whole model). Not used by the round engine — the
    FedSGD server update never masks w (see packed_fedsgd_update); this is
    the packed form of the per-leaf `masked_update` for pruned-checkpoint
    workflows (launch/train.py style)."""
    if _resolve_impl(impl) == "pallas":
        return _pm.masked_update_2d(
            w, g, mask, eta, block_rows=_packed_block_rows(w.shape[0]))
    return ((w.astype(jnp.float32) - eta * g.astype(jnp.float32))
            * mask).astype(w.dtype)


# ---------------------------------------------------------------------------
# SSD: full sequence via kernel-per-chunk + host scan for the recurrence
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_chunked_pallas(x, b, c, dt, a_log, *, chunk=128):
    """Drop-in for models.ssm.ssd_chunked's core (no D-skip, zero init state).

    x [B,S,H,P], b/c [B,S,N], dt [B,S,H] -> (y [B,S,H,P], final [B,H,P,N])."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    if s % q:
        raise ValueError(f"seq {s} must divide chunk {q}")
    nc = s // q
    xr = jnp.moveaxis(x.reshape(bsz, nc, q, h, p), 1, 0)
    br = jnp.moveaxis(b.reshape(bsz, nc, q, n), 1, 0)
    cr = jnp.moveaxis(c.reshape(bsz, nc, q, n), 1, 0)
    dtr = jnp.moveaxis(dt.reshape(bsz, nc, q, h), 1, 0)

    def body(state, xs):
        xc, bc, cc, dtc = xs
        y_intra, st_contrib, dec = _sc.ssd_chunk(xc, bc, cc, dtc, a_log)
        # inter-chunk term: y_inter[s] = C_s . state * exp(acum_s)
        a = -jnp.exp(a_log.astype(jnp.float32))
        acum = jnp.cumsum(dtc.astype(jnp.float32) * a, axis=1)  # [B,q,H]
        y_inter = jnp.einsum("bqn,bhpn,bqh->bqhp",
                             cc.astype(jnp.float32), state, jnp.exp(acum))
        state_new = state * dec[..., None, None] \
            + jnp.swapaxes(st_contrib, -1, -2)       # [B,H,P,N]
        return state_new, y_intra.astype(jnp.float32) + y_inter

    state0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    final, ys = jax.lax.scan(body, state0, (xr, br, cr, dtr))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, p).astype(x.dtype)
    return y, final


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k, v, pos, *, block_k=512):
    """Flash-decoding kernel: q [B,1,Hq,D] (model layout), k/v [B,S,Hkv,D],
    pos = valid cache length. Returns [B,1,Hq,D]."""
    from repro.kernels import decode_attention as _da
    qt = jnp.swapaxes(q, 1, 2)            # [B,Hq,1,D]
    o = _da.decode_attention(qt, k, v, pos, block_k=block_k)
    return jnp.swapaxes(o, 1, 2)
