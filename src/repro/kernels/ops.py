"""Jitted public wrappers around the Pallas kernels.

These adapt model-layout tensors to kernel layouts, handle padding to tile
multiples, and fall back to interpret mode off-TPU automatically.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels import flash_attention as _fa
from repro.kernels import pruning_mask as _pm
from repro.kernels import ssd_chunk as _sc

PyTree = Any
LANES = _pm.LANES


# ---------------------------------------------------------------------------
# Flash attention: model layout [B, S, H, D] <-> kernel layout [B, H, S, D]
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("causal", "window", "cap",
                                             "block_q", "block_k"))
def flash_attention(q, k, v, *, causal=True, window=0, cap=0.0,
                    block_q=128, block_k=128):
    qt = jnp.swapaxes(q, 1, 2)
    kt = jnp.swapaxes(k, 1, 2)
    vt = jnp.swapaxes(v, 1, 2)
    o = _fa.flash_attention(qt, kt, vt, causal=causal, window=window, cap=cap,
                            block_q=block_q, block_k=block_k)
    return jnp.swapaxes(o, 1, 2)


# ---------------------------------------------------------------------------
# Pruning: arbitrary pytree leaves -> padded [R, LANES] tiles
# ---------------------------------------------------------------------------

def _to_tiles(x: jnp.ndarray) -> tuple[jnp.ndarray, int]:
    flat = x.reshape(-1)
    n = flat.shape[0]
    pad = (-n) % LANES
    if pad:
        flat = jnp.pad(flat, (0, pad))
    return flat.reshape(-1, LANES), n


def _from_tiles(t: jnp.ndarray, n: int, shape, dtype) -> jnp.ndarray:
    return t.reshape(-1)[:n].reshape(shape).astype(dtype)


@jax.jit
def importance_and_mask(w: jnp.ndarray, v: jnp.ndarray, threshold):
    """Fused eq.-(4) importance + keep-mask for one tensor (any shape)."""
    wt, n = _to_tiles(w)
    vt, _ = _to_tiles(v)
    r = wt.shape[0]
    br = r
    for cand in (256, 128, 64, 32, 16, 8, 4, 2, 1):
        if r % cand == 0:
            br = cand
            break
    q, m = _pm.importance_mask_2d(wt, vt, threshold, block_rows=br)
    return (_from_tiles(q, n, w.shape, jnp.float32),
            _from_tiles(m, n, w.shape, jnp.float32))


@jax.jit
def masked_update(w: jnp.ndarray, g: jnp.ndarray, mask: jnp.ndarray, eta):
    """Fused pruned-SGD step for one tensor."""
    wt, n = _to_tiles(w)
    gt, _ = _to_tiles(g)
    mt, _ = _to_tiles(mask)
    r = wt.shape[0]
    br = next(c for c in (256, 128, 64, 32, 16, 8, 4, 2, 1) if r % c == 0)
    out = _pm.masked_update_2d(wt, gt, mt, eta, block_rows=br)
    return _from_tiles(out, n, w.shape, w.dtype)


# ---------------------------------------------------------------------------
# SSD: full sequence via kernel-per-chunk + host scan for the recurrence
# ---------------------------------------------------------------------------

@functools.partial(jax.jit, static_argnames=("chunk",))
def ssd_chunked_pallas(x, b, c, dt, a_log, *, chunk=128):
    """Drop-in for models.ssm.ssd_chunked's core (no D-skip, zero init state).

    x [B,S,H,P], b/c [B,S,N], dt [B,S,H] -> (y [B,S,H,P], final [B,H,P,N])."""
    bsz, s, h, p = x.shape
    n = b.shape[-1]
    q = min(chunk, s)
    if s % q:
        raise ValueError(f"seq {s} must divide chunk {q}")
    nc = s // q
    xr = jnp.moveaxis(x.reshape(bsz, nc, q, h, p), 1, 0)
    br = jnp.moveaxis(b.reshape(bsz, nc, q, n), 1, 0)
    cr = jnp.moveaxis(c.reshape(bsz, nc, q, n), 1, 0)
    dtr = jnp.moveaxis(dt.reshape(bsz, nc, q, h), 1, 0)

    def body(state, xs):
        xc, bc, cc, dtc = xs
        y_intra, st_contrib, dec = _sc.ssd_chunk(xc, bc, cc, dtc, a_log)
        # inter-chunk term: y_inter[s] = C_s . state * exp(acum_s)
        a = -jnp.exp(a_log.astype(jnp.float32))
        acum = jnp.cumsum(dtc.astype(jnp.float32) * a, axis=1)  # [B,q,H]
        y_inter = jnp.einsum("bqn,bhpn,bqh->bqhp",
                             cc.astype(jnp.float32), state, jnp.exp(acum))
        state_new = state * dec[..., None, None] \
            + jnp.swapaxes(st_contrib, -1, -2)       # [B,H,P,N]
        return state_new, y_intra.astype(jnp.float32) + y_inter

    state0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    final, ys = jax.lax.scan(body, state0, (xr, br, cr, dtr))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, p).astype(x.dtype)
    return y, final


@functools.partial(jax.jit, static_argnames=("block_k",))
def decode_attention(q, k, v, pos, *, block_k=512):
    """Flash-decoding kernel: q [B,1,Hq,D] (model layout), k/v [B,S,Hkv,D],
    pos = valid cache length. Returns [B,1,Hq,D]."""
    from repro.kernels import decode_attention as _da
    qt = jnp.swapaxes(q, 1, 2)            # [B,Hq,1,D]
    o = _da.decode_attention(qt, k, v, pos, block_k=block_k)
    return jnp.swapaxes(o, 1, 2)
