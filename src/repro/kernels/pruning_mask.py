"""Pallas kernels for the paper's pruning hot spot (eq. 4 over O(10^9) weights).

Fused kernels, all tiled [BLOCK_R, 128] (lane-width aligned for the VPU):

  * importance_mask: Q = (w * v)^2 and keep-mask (Q >= threshold) in one pass
    — one read of (w, v), two writes; the unfused jnp version materializes Q
    twice (once for the threshold compare, once for the mask multiply).
  * masked_update:  w' = (w - eta * g) * mask — the pruned-FedSGD server
    update (eq. 7) fused with mask application, saving one full parameter
    read+write per round.

  * importance_mask_batched: the packed-engine generalization of
    importance_mask — one threshold per client plus a prunable-coordinate
    mask, emitting every per-client keep-mask from a single read of (w, v).
  * fedsgd_aggregate: eqs. (6)-(7) fused — sum the stacked per-client
    gradients, average, and take the FedSGD step in one launch, replacing
    the O(clients) `jax.tree.map` accumulation.
  * fedsgd_aggregate_weighted: the bucketed/sharded generalization — each
    stacked gradient carries a per-client validity weight (0 for padding
    clients on the bucketed client axis, 1 for real ones; fractional
    weights supported for weighted FedAvg), and the mean divisor 1/C is an
    operand instead of a shape-derived constant, so one compiled launch
    serves every selected-client count in the bucket.

Per-leaf inputs of arbitrary shape are flattened and padded to tiles by
ops.py; the packed round engine (core/packing.py + core/round_engine.py)
hands whole-model [R, 128] buffers to the batched/aggregate kernels directly
— one launch per model instead of one per leaf (DESIGN.md §5).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANES = 128


def _importance_mask_kernel(w_ref, v_ref, thr_ref, q_ref, m_ref):
    w = w_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    q = jnp.square(w * v)
    q_ref[...] = q
    m_ref[...] = (q >= thr_ref[0]).astype(jnp.float32)


def importance_mask_2d(w, v, threshold, *, block_rows: int = 256,
                       interpret: bool | None = None):
    """w, v: [R, 128*k]; threshold scalar -> (importance fp32, mask fp32)."""
    r, c = w.shape
    if c % LANES:
        raise ValueError(f"last dim must be a multiple of {LANES}")
    br = min(block_rows, r)
    if r % br:
        raise ValueError(f"rows {r} must divide block {br}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    thr = jnp.asarray([threshold], jnp.float32)
    grid = (r // br,)
    spec = pl.BlockSpec((br, c), lambda i: (i, 0))
    return pl.pallas_call(
        _importance_mask_kernel,
        grid=grid,
        in_specs=[spec, spec, pl.BlockSpec(memory_space=pl.MemorySpace.ANY)],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((r, c), jnp.float32),
                   jax.ShapeDtypeStruct((r, c), jnp.float32)],
        interpret=interpret,
    )(w, v, thr)


def _importance_mask_batched_kernel(w_ref, v_ref, pr_ref, thr_ref,
                                    q_ref, m_ref):
    w = w_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    pr = pr_ref[...] > 0
    q = jnp.square(w * v)
    q_ref[...] = q
    for c in range(m_ref.shape[0]):          # static unroll over clients
        keep = (q >= thr_ref[c]).astype(jnp.float32)
        m_ref[c] = jnp.where(pr, keep, 1.0)


def importance_mask_batched(w, v, prunable, thresholds, *,
                            block_rows: int = 256,
                            interpret: bool | None = None):
    """Per-client masks from one read of the packed buffers.

    w, v, prunable: [R, 128*k]; thresholds: [C] fp32 (one per client).
    Returns (importance fp32 [R, 128*k], masks fp32 [C, R, 128*k]); mask is 1
    wherever `prunable` is 0 (protected / padding coordinates are kept)."""
    r, c = w.shape
    n_clients = thresholds.shape[0]
    if c % LANES:
        raise ValueError(f"last dim must be a multiple of {LANES}")
    br = min(block_rows, r)
    if r % br:
        raise ValueError(f"rows {r} must divide block {br}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    thr = thresholds.astype(jnp.float32)
    spec = pl.BlockSpec((br, c), lambda i: (i, 0))
    mspec = pl.BlockSpec((n_clients, br, c), lambda i: (0, i, 0))
    return pl.pallas_call(
        _importance_mask_batched_kernel,
        grid=(r // br,),
        in_specs=[spec, spec, spec,
                  pl.BlockSpec(memory_space=pl.MemorySpace.ANY)],
        out_specs=[spec, mspec],
        out_shape=[jax.ShapeDtypeStruct((r, c), jnp.float32),
                   jax.ShapeDtypeStruct((n_clients, r, c), jnp.float32)],
        interpret=interpret,
    )(w, v, prunable, thr)


def _fedsgd_aggregate_kernel(w_ref, g_ref, eta_ref, o_ref, gm_ref, st_ref):
    acc = g_ref[0].astype(jnp.float32)
    for c in range(1, g_ref.shape[0]):       # static unroll: same summation
        acc = acc + g_ref[c].astype(jnp.float32)   # order as the reference
    g = acc * (1.0 / g_ref.shape[0])
    gm_ref[...] = g
    # The step eta*g is written to its own output: giving the multiply a
    # second consumer stops the compiler from contracting it with the
    # subtraction into an FMA, so the update rounds exactly like the eager
    # reference loop (bit-for-bit reproducibility contract).
    step = eta_ref[0] * g
    st_ref[...] = step
    o_ref[...] = (w_ref[...].astype(jnp.float32) - step).astype(o_ref.dtype)


def fedsgd_aggregate(w, grads, eta, *, block_rows: int = 256,
                     interpret: bool | None = None):
    """Eqs. (6)-(7) fused on packed buffers.

    w: [R, 128*k]; grads: [C, R, 128*k] stacked per-client (already masked)
    gradients. Returns (updated w, mean gradient fp32, applied step
    eta*mean fp32), all [R, 128*k], in one launch — the mean doubles as the
    next round's broadcast v."""
    r, c = w.shape
    n_clients = grads.shape[0]
    if c % LANES:
        raise ValueError(f"last dim must be a multiple of {LANES}")
    br = min(block_rows, r)
    if r % br:
        raise ValueError(f"rows {r} must divide block {br}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    eta_arr = jnp.asarray([eta], jnp.float32)
    spec = pl.BlockSpec((br, c), lambda i: (i, 0))
    gspec = pl.BlockSpec((n_clients, br, c), lambda i: (0, i, 0))
    return pl.pallas_call(
        _fedsgd_aggregate_kernel,
        grid=(r // br,),
        in_specs=[spec, gspec,
                  pl.BlockSpec(memory_space=pl.MemorySpace.ANY)],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((r, c), w.dtype),
                   jax.ShapeDtypeStruct((r, c), jnp.float32),
                   jax.ShapeDtypeStruct((r, c), jnp.float32)],
        interpret=interpret,
    )(w, grads, eta_arr)


def _fedsgd_aggregate_weighted_kernel(w_ref, g_ref, cw_ref, sc_ref,
                                      o_ref, gm_ref, st_ref):
    acc = jnp.zeros(w_ref.shape, jnp.float32)
    for c in range(g_ref.shape[0]):          # static unroll: same summation
        wc = cw_ref[c]                       # order as the reference; the
        # `where` (not acc + 0*g) skips zero-weight clients entirely, so a
        # padding client's gradient can never leak in — not even as a NaN —
        # and `acc + 1.0*g` keeps the 0/1 case bit-identical to the
        # unweighted kernel on the real-client prefix.
        acc = jnp.where(wc > 0.0,
                        acc + wc * g_ref[c].astype(jnp.float32), acc)
    g = acc * sc_ref[0]
    gm_ref[...] = g
    # The step eta*g is written to its own output: giving the multiply a
    # second consumer stops the compiler from contracting it with the
    # subtraction into an FMA, so the update rounds exactly like the eager
    # reference loop (bit-for-bit reproducibility contract).
    step = sc_ref[1] * g
    st_ref[...] = step
    o_ref[...] = (w_ref[...].astype(jnp.float32) - step).astype(o_ref.dtype)


def fedsgd_aggregate_weighted(w, grads, cweights, inv, eta, *,
                              block_rows: int = 256,
                              interpret: bool | None = None):
    """Weighted eqs. (6)-(7) fused on packed buffers.

    w: [R, 128*k]; grads: [C, R, 128*k] stacked per-client (already masked)
    gradients; cweights: [C] per-client weights (0 = padding client);
    inv: scalar 1/sum(cweights) (host-computed so the mean matches the
    reference's 1/len(grads) exactly). Returns (updated w, weighted mean
    gradient fp32, applied step eta*mean fp32) in one launch — the mean
    doubles as the next round's broadcast v."""
    r, c = w.shape
    n_clients = grads.shape[0]
    if c % LANES:
        raise ValueError(f"last dim must be a multiple of {LANES}")
    br = min(block_rows, r)
    if r % br:
        raise ValueError(f"rows {r} must divide block {br}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    cw = jnp.asarray(cweights, jnp.float32)
    scal = jnp.stack([jnp.asarray(inv, jnp.float32),
                      jnp.asarray(eta, jnp.float32)])
    spec = pl.BlockSpec((br, c), lambda i: (i, 0))
    gspec = pl.BlockSpec((n_clients, br, c), lambda i: (0, i, 0))
    return pl.pallas_call(
        _fedsgd_aggregate_weighted_kernel,
        grid=(r // br,),
        in_specs=[spec, gspec,
                  pl.BlockSpec(memory_space=pl.MemorySpace.ANY),
                  pl.BlockSpec(memory_space=pl.MemorySpace.ANY)],
        out_specs=[spec, spec, spec],
        out_shape=[jax.ShapeDtypeStruct((r, c), w.dtype),
                   jax.ShapeDtypeStruct((r, c), jnp.float32),
                   jax.ShapeDtypeStruct((r, c), jnp.float32)],
        interpret=interpret,
    )(w, grads, cw, scal)


def _client_rank_sort_kernel(g_ref, cw_ref, o_ref):
    """Per-coordinate client-axis sort for the robust reducers.

    Loads the [C, br, c] gradient block, maps each lane to the monotone
    int32 total-order key of its fp32 bits (the PR-1 bit-pattern trick:
    ``b ^ ((b >> 31) & 0x7fffffff)`` orders like the float value), replaces
    every zero-weight client's keys with INT32_MAX so padding / quarantined
    lanes sort strictly after all real values (valid lanes can never reach
    the sentinel — non-finite uploads are quarantined to weight 0 first),
    and runs an odd-even transposition network over the STATIC client axis
    — C compare-exchange passes of lane-parallel selects, no data-dependent
    control flow. Rank r of the output holds the r-th smallest valid value
    per coordinate; ranks >= n_valid hold don't-care values the weight-
    aware reducers never read. Ties carry identical bit patterns, so the
    network's output is bitwise equal to a stable sort's."""
    n_clients = g_ref.shape[0]
    sentinel = jnp.int32(2**31 - 1)
    vals, keys = [], []
    for i in range(n_clients):
        v = g_ref[i].astype(jnp.float32)
        b = jax.lax.bitcast_convert_type(v, jnp.int32)
        k = b ^ ((b >> 31) & jnp.int32(0x7FFFFFFF))
        vals.append(v)
        keys.append(jnp.where(cw_ref[i] > 0.0, k, sentinel))
    for p in range(n_clients):
        for i in range(p % 2, n_clients - 1, 2):
            ki, kj, vi, vj = keys[i], keys[i + 1], vals[i], vals[i + 1]
            swap = ki > kj
            keys[i] = jnp.where(swap, kj, ki)
            keys[i + 1] = jnp.where(swap, ki, kj)
            vals[i] = jnp.where(swap, vj, vi)
            vals[i + 1] = jnp.where(swap, vi, vj)
    for i in range(n_clients):
        o_ref[i] = vals[i]


def client_rank_sort(grads, cweights, *, block_rows: int = 256,
                     interpret: bool | None = None):
    """Client-axis rank sort on packed gradient stacks.

    grads: [C, R, 128*k] stacked per-client gradients; cweights: [C]
    validity weights (0 = padding / quarantined). Returns the [C, R, 128*k]
    fp32 stack sorted per coordinate along the client axis, zero-weight
    clients last — the shared first stage of `coord_median` and
    `trimmed_mean` (kernels/ops.packed_robust_aggregate)."""
    c_clients, r, c = grads.shape
    if c % LANES:
        raise ValueError(f"last dim must be a multiple of {LANES}")
    br = min(block_rows, r)
    if r % br:
        raise ValueError(f"rows {r} must divide block {br}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    cw = jnp.asarray(cweights, jnp.float32)
    gspec = pl.BlockSpec((c_clients, br, c), lambda i: (0, i, 0))
    return pl.pallas_call(
        _client_rank_sort_kernel,
        grid=(r // br,),
        in_specs=[gspec, pl.BlockSpec(memory_space=pl.MemorySpace.ANY)],
        out_specs=gspec,
        out_shape=jax.ShapeDtypeStruct((c_clients, r, c), jnp.float32),
        interpret=interpret,
    )(grads, cw)


def _exponent_histogram_kernel(q_ref, pr_ref, hist_ref, acc_ref):
    """256-bin histogram over the exponent byte of non-negative fp32 q.

    Per grid block: bin counts accumulate in the VMEM scratch `acc_ref`
    (laid out (2, 128) so the bin axis tiles the VPU lanes), built by a
    compare-against-bin-iota reduction over row chunks — no scatter-add,
    which XLA:CPU serializes at ~130 ns/element and which TPU lowers
    poorly for int32. Grid steps are sequential on TPU, so the running
    total in `hist_ref` (same output block every step) is race-free."""
    rows = q_ref.shape[0]
    chunk = min(rows, 8)
    while rows % chunk:
        chunk -= 1
    # bins as a 2D iota (TPU requires >= 2D); bin id = 128*sub + lane
    bins = jax.lax.broadcasted_iota(jnp.int32, (256, 1), 0)

    acc_ref[...] = jnp.zeros((2, 128), jnp.int32)

    def body(c, carry):
        q = q_ref[pl.ds(c * chunk, chunk), :].astype(jnp.float32)
        valid = pr_ref[pl.ds(c * chunk, chunk), :] > 0
        byte = jax.lax.bitcast_convert_type(q, jnp.int32) >> 23
        flat = byte.reshape(1, -1)
        ones = jnp.where(valid.reshape(1, -1), 1, 0)
        acc_ref[...] += jnp.sum(jnp.where(flat == bins, ones, 0),
                                axis=1).reshape(2, 128)
        return carry

    jax.lax.fori_loop(0, rows // chunk, body, 0)

    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        hist_ref[...] = acc_ref[...]

    @pl.when(i > 0)
    def _accum():
        hist_ref[...] += acc_ref[...]


def exponent_histogram(q, prunable, *, block_rows: int = 256,
                       interpret: bool | None = None):
    """Counts of valid coordinates per fp32 exponent byte.

    q (non-negative fp32), prunable: [R, 128*k] -> [256] int32, where bin
    b counts coordinates with ``bits(q) >> 23 == b`` and prunable > 0 —
    the coarse first pass of `kth_smallest_threshold(coarse="histogram")`
    (core/round_engine.py), whose cumulative sum pins the top 8 bits of
    the k-th smallest importance in one data scan."""
    r, c = q.shape
    if c % LANES:
        raise ValueError(f"last dim must be a multiple of {LANES}")
    br = min(block_rows, r)
    if r % br:
        raise ValueError(f"rows {r} must divide block {br}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    spec = pl.BlockSpec((br, c), lambda i: (i, 0))
    hist = pl.pallas_call(
        _exponent_histogram_kernel,
        grid=(r // br,),
        in_specs=[spec, spec],
        out_specs=pl.BlockSpec((2, 128), lambda i: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((2, 128), jnp.int32),
        scratch_shapes=[pltpu.VMEM((2, 128), jnp.int32)],
        interpret=interpret,
    )(q, prunable)
    return hist.reshape(256)


def _masked_update_kernel(w_ref, g_ref, m_ref, eta_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    o_ref[...] = ((w - eta_ref[0] * g) * m).astype(o_ref.dtype)


def masked_update_2d(w, g, mask, eta, *, block_rows: int = 256,
                     interpret: bool | None = None):
    """Fused (w - eta g) * mask on [R, 128*k] tiles."""
    r, c = w.shape
    if c % LANES:
        raise ValueError(f"last dim must be a multiple of {LANES}")
    br = min(block_rows, r)
    if r % br:
        raise ValueError(f"rows {r} must divide block {br}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    eta_arr = jnp.asarray([eta], jnp.float32)
    spec = pl.BlockSpec((br, c), lambda i: (i, 0))
    return pl.pallas_call(
        _masked_update_kernel,
        grid=(r // br,),
        in_specs=[spec, spec, spec,
                  pl.BlockSpec(memory_space=pl.MemorySpace.ANY)],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((r, c), w.dtype),
        interpret=interpret,
    )(w, g, mask, eta_arr)
