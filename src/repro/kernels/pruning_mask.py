"""Pallas kernels for the paper's pruning hot spot (eq. 4 over O(10^9) weights).

Two fused kernels, both tiled [BLOCK_R, 128] (lane-width aligned for the VPU):

  * importance_mask: Q = (w * v)^2 and keep-mask (Q >= threshold) in one pass
    — one read of (w, v), two writes; the unfused jnp version materializes Q
    twice (once for the threshold compare, once for the mask multiply).
  * masked_update:  w' = (w - eta * g) * mask — the pruned-FedSGD server
    update (eq. 7) fused with mask application, saving one full parameter
    read+write per round.

Inputs of arbitrary shape are flattened and padded to tiles by ops.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LANES = 128


def _importance_mask_kernel(w_ref, v_ref, thr_ref, q_ref, m_ref):
    w = w_ref[...].astype(jnp.float32)
    v = v_ref[...].astype(jnp.float32)
    q = jnp.square(w * v)
    q_ref[...] = q
    m_ref[...] = (q >= thr_ref[0]).astype(jnp.float32)


def importance_mask_2d(w, v, threshold, *, block_rows: int = 256,
                       interpret: bool | None = None):
    """w, v: [R, 128*k]; threshold scalar -> (importance fp32, mask fp32)."""
    r, c = w.shape
    if c % LANES:
        raise ValueError(f"last dim must be a multiple of {LANES}")
    br = min(block_rows, r)
    if r % br:
        raise ValueError(f"rows {r} must divide block {br}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    thr = jnp.asarray([threshold], jnp.float32)
    grid = (r // br,)
    spec = pl.BlockSpec((br, c), lambda i: (i, 0))
    return pl.pallas_call(
        _importance_mask_kernel,
        grid=grid,
        in_specs=[spec, spec, pl.BlockSpec(memory_space=pl.MemorySpace.ANY)],
        out_specs=[spec, spec],
        out_shape=[jax.ShapeDtypeStruct((r, c), jnp.float32),
                   jax.ShapeDtypeStruct((r, c), jnp.float32)],
        interpret=interpret,
    )(w, v, thr)


def _masked_update_kernel(w_ref, g_ref, m_ref, eta_ref, o_ref):
    w = w_ref[...].astype(jnp.float32)
    g = g_ref[...].astype(jnp.float32)
    m = m_ref[...].astype(jnp.float32)
    o_ref[...] = ((w - eta_ref[0] * g) * m).astype(o_ref.dtype)


def masked_update_2d(w, g, mask, eta, *, block_rows: int = 256,
                     interpret: bool | None = None):
    """Fused (w - eta g) * mask on [R, 128*k] tiles."""
    r, c = w.shape
    if c % LANES:
        raise ValueError(f"last dim must be a multiple of {LANES}")
    br = min(block_rows, r)
    if r % br:
        raise ValueError(f"rows {r} must divide block {br}")
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    eta_arr = jnp.asarray([eta], jnp.float32)
    spec = pl.BlockSpec((br, c), lambda i: (i, 0))
    return pl.pallas_call(
        _masked_update_kernel,
        grid=(r // br,),
        in_specs=[spec, spec, spec,
                  pl.BlockSpec(memory_space=pl.MemorySpace.ANY)],
        out_specs=spec,
        out_shape=jax.ShapeDtypeStruct((r, c), w.dtype),
        interpret=interpret,
    )(w, g, mask, eta_arr)
