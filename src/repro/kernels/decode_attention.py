"""Pallas TPU decode attention: ONE query against a long KV cache.

Flash-decoding-style layout: grid = (batch, q_heads, kv_blocks); the kv axis
is sequential with VMEM scratch carrying the online-softmax state — the
memory-bound inner loop streams [BK, D] cache tiles through VMEM exactly
once (this op IS the §Roofline memory term for every decode shape). GQA via
the q-head -> kv-head index map; positions >= `pos` (the valid length) are
masked via the block index so trailing cache garbage never contributes.

Validated in interpret mode against ref.decode_attention_ref.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -2.0**30


def _decode_kernel(pos_ref, q_ref, k_ref, v_ref, o_ref, m_scr, l_scr,
                   acc_scr, *, bk: int, nk: int, scale: float):
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_scr[...] = jnp.full_like(m_scr, NEG_INF)
        l_scr[...] = jnp.zeros_like(l_scr)
        acc_scr[...] = jnp.zeros_like(acc_scr)

    pos = pos_ref[0]
    k_start = ki * bk

    @pl.when(k_start < pos)  # skip blocks entirely past the valid length
    def _compute():
        q = q_ref[0, 0].astype(jnp.float32)          # [1, D]
        k = k_ref[0].astype(jnp.float32)             # [BK, D]
        v = v_ref[0].astype(jnp.float32)             # [BK, D]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ()))) * scale
        kpos = k_start + jax.lax.broadcasted_iota(jnp.int32, (1, bk), 1)
        s = jnp.where(kpos < pos, s, NEG_INF)
        m_prev = m_scr[...]
        m_new = jnp.maximum(m_prev, s.max(axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m_prev - m_new)
        l_scr[...] = l_scr[...] * corr + p.sum(axis=1)
        acc_scr[...] = acc_scr[...] * corr[:, None] + jax.lax.dot(p, v)
        m_scr[...] = m_new

    @pl.when(ki == nk - 1)
    def _finalize():
        denom = jnp.maximum(l_scr[...], 1e-20)[:, None]
        o_ref[0, 0] = (acc_scr[...] / denom).astype(o_ref.dtype)


def decode_attention(
    q: jnp.ndarray, k: jnp.ndarray, v: jnp.ndarray, pos, *,
    block_k: int = 512, interpret: bool | None = None,
) -> jnp.ndarray:
    """q [B,Hq,1,D]; k/v [B,Skv,Hkv,D]; pos: valid cache length (scalar).

    Returns [B,Hq,1,D]."""
    b, hq, _, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    bk = min(block_k, skv)
    if skv % bk:
        raise ValueError(f"cache len {skv} must divide block_k {bk}")
    nk = skv // bk
    if interpret is None:
        interpret = jax.default_backend() != "tpu"
    kt = jnp.swapaxes(k, 1, 2).reshape(b * hkv, skv, d)
    vt = jnp.swapaxes(v, 1, 2).reshape(b * hkv, skv, d)
    pos_arr = jnp.asarray([pos], jnp.int32)

    kernel = functools.partial(_decode_kernel, bk=bk, nk=nk,
                               scale=1.0 / np.sqrt(d))
    out = pl.pallas_call(
        kernel,
        grid=(b, hq, nk),
        in_specs=[
            pl.BlockSpec(memory_space=pl.MemorySpace.ANY),
            pl.BlockSpec((1, 1, 1, d), lambda bi, h, ki: (bi, h, 0, 0)),
            pl.BlockSpec((1, bk, d),
                         lambda bi, h, ki: (bi * hkv + h // g, ki, 0)),
            pl.BlockSpec((1, bk, d),
                         lambda bi, h, ki: (bi * hkv + h // g, ki, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, 1, d), lambda bi, h, ki: (bi, h, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, 1, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1,), jnp.float32),
            pltpu.VMEM((1, d), jnp.float32),
        ],
        interpret=interpret,
    )(pos_arr, q, kt, vt)
    return out
