"""Functional optimizers over pytrees (optax-style, self-contained)."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class Optimizer(NamedTuple):
    init: Callable[[PyTree], PyTree]
    update: Callable[[PyTree, PyTree, PyTree], tuple[PyTree, PyTree]]


def global_norm(tree: PyTree) -> jnp.ndarray:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree.leaves(tree)]
    return jnp.sqrt(sum(leaves)) if leaves else jnp.zeros(())


def clip_by_global_norm(tree: PyTree, max_norm: float) -> PyTree:
    norm = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / (norm + 1e-12))
    return jax.tree.map(lambda g: g * scale.astype(g.dtype), tree)


def apply_updates(params: PyTree, updates: PyTree) -> PyTree:
    return jax.tree.map(lambda p, u: (p + u.astype(p.dtype)), params, updates)


def sgd(lr: float) -> Optimizer:
    """Plain SGD: the paper's FedSGD server update (eq. 7)."""
    def init(params):
        return ()

    def update(grads, state, params=None):
        return jax.tree.map(lambda g: -lr * g, grads), state

    return Optimizer(init, update)


def momentum(lr: float, beta: float = 0.9, nesterov: bool = False) -> Optimizer:
    def init(params):
        return jax.tree.map(jnp.zeros_like, params)

    def update(grads, state, params=None):
        new_v = jax.tree.map(lambda v, g: beta * v + g, state, grads)
        if nesterov:
            upd = jax.tree.map(lambda v, g: -lr * (beta * v + g), new_v, grads)
        else:
            upd = jax.tree.map(lambda v: -lr * v, new_v)
        return upd, new_v

    return Optimizer(init, update)


def adam(lr: float, b1: float = 0.9, b2: float = 0.999, eps: float = 1e-8,
         weight_decay: float = 0.0) -> Optimizer:
    def init(params):
        return {
            "mu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "nu": jax.tree.map(lambda p: jnp.zeros_like(p, jnp.float32), params),
            "t": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params=None):
        t = state["t"] + 1
        mu = jax.tree.map(lambda m, g: b1 * m + (1 - b1) * g.astype(jnp.float32),
                          state["mu"], grads)
        nu = jax.tree.map(lambda n, g: b2 * n + (1 - b2) * jnp.square(
            g.astype(jnp.float32)), state["nu"], grads)
        bc1 = 1 - b1**t.astype(jnp.float32)
        bc2 = 1 - b2**t.astype(jnp.float32)

        def upd(m, n, p):
            step = -lr * (m / bc1) / (jnp.sqrt(n / bc2) + eps)
            if weight_decay:
                step = step - lr * weight_decay * p.astype(jnp.float32)
            return step

        if params is None:
            params = jax.tree.map(jnp.zeros_like, mu)
        updates = jax.tree.map(upd, mu, nu, params)
        return updates, {"mu": mu, "nu": nu, "t": t}

    return Optimizer(init, update)
