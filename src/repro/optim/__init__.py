"""Optimizers (no optax in this environment): SGD, momentum-SGD, Adam.

Each optimizer is a pair (init_fn, update_fn) over parameter pytrees, in the
functional style:

    state = opt.init(params)
    updates, state = opt.update(grads, state, params)
    params = apply_updates(params, updates)
"""
from repro.optim.optimizers import (
    Optimizer, sgd, momentum, adam, apply_updates, clip_by_global_norm,
    global_norm,
)

__all__ = ["Optimizer", "sgd", "momentum", "adam", "apply_updates",
           "clip_by_global_norm", "global_norm"]
