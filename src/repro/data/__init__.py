"""Data substrate: synthetic datasets, Dirichlet non-IID partitioning, batching."""
from repro.data.dirichlet import dirichlet_label_proportions, partition_by_dirichlet
from repro.data.synthetic import SyntheticImageDataset, make_dataset
from repro.data.loader import batches
from repro.data.fleet import FleetDataset, FleetRoster, make_fleet

__all__ = [
    "dirichlet_label_proportions", "partition_by_dirichlet",
    "SyntheticImageDataset", "make_dataset", "batches",
    "FleetDataset", "FleetRoster", "make_fleet",
]
