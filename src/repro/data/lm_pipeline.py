"""LM training data pipeline: document packing + deterministic sharding.

Production-shaped substrate for the assigned-architecture training path
(launch/train.py): variable-length token documents are packed into fixed
[batch, seq] examples with EOS separators and cross-document attention-mask
boundaries (segment ids), sharded deterministically per host so every data-
parallel worker sees a disjoint stream and any step is reproducible from
(seed, step) alone — no data state in checkpoints beyond the step counter.
"""
from __future__ import annotations

import dataclasses
from typing import Iterator, Sequence

import numpy as np


@dataclasses.dataclass(frozen=True)
class PackedBatch:
    tokens: np.ndarray       # [B, S] int32
    labels: np.ndarray       # [B, S] int32 (next token; EOS at doc ends)
    segment_ids: np.ndarray  # [B, S] int32 (0 = padding; 1.. = document id)
    positions: np.ndarray    # [B, S] int32 (position within document)


class SyntheticDocumentSource:
    """Deterministic stream of variable-length token documents.

    Stands in for a tokenized corpus reader (the container is offline); the
    interface — `doc(index) -> np.ndarray` — matches what a real
    shard-indexed reader provides, so packing/sharding logic is the real
    thing.
    """

    def __init__(self, vocab_size: int, *, mean_len: int = 384,
                 min_len: int = 16, seed: int = 0):
        self.vocab_size = vocab_size
        self.mean_len = mean_len
        self.min_len = min_len
        self.seed = seed

    def doc(self, index: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed << 32) ^ index)
        n = self.min_len + int(rng.exponential(self.mean_len))
        return rng.integers(1, self.vocab_size,
                            size=min(n, 8 * self.mean_len)).astype(np.int32)


def pack_documents(
    docs: Iterator[np.ndarray], batch: int, seq: int, *, eos_id: int = 0,
) -> PackedBatch | None:
    """Greedy first-fit packing of documents into a [batch, seq] example."""
    tokens = np.zeros((batch, seq + 1), np.int32)
    seg = np.zeros((batch, seq + 1), np.int32)
    pos = np.zeros((batch, seq + 1), np.int32)
    fill = [0] * batch
    next_seg = [1] * batch
    for doc in docs:
        doc = np.concatenate([doc, [eos_id]]).astype(np.int32)
        placed = False
        for b in range(batch):
            room = seq + 1 - fill[b]
            if len(doc) <= room:
                s, e = fill[b], fill[b] + len(doc)
                tokens[b, s:e] = doc
                seg[b, s:e] = next_seg[b]
                pos[b, s:e] = np.arange(len(doc))
                fill[b] = e
                next_seg[b] += 1
                placed = True
                break
        if not placed:  # truncate into the emptiest row
            b = int(np.argmin(fill))
            room = seq + 1 - fill[b]
            if room <= 0:
                break
            s = fill[b]
            tokens[b, s:] = doc[:room]
            seg[b, s:] = next_seg[b]
            pos[b, s:] = np.arange(room)
            fill[b] = seq + 1
        if min(fill) >= seq + 1:
            break
    if max(fill) == 0:
        return None
    return PackedBatch(
        tokens=tokens[:, :seq],
        labels=tokens[:, 1:],
        segment_ids=seg[:, :seq],
        positions=pos[:, :seq],
    )


@dataclasses.dataclass(frozen=True)
class ShardSpec:
    host_index: int
    host_count: int

    def __post_init__(self):
        if not (0 <= self.host_index < self.host_count):
            raise ValueError("host_index out of range")


class PackedLMIterator:
    """Deterministic per-host packed-batch stream.

    Document index for (host, step, k) is a bijective interleave:
    `index = (step * docs_per_step + k) * host_count + host_index`, so hosts
    never overlap and `state == step` (restart-safe)."""

    def __init__(self, source: SyntheticDocumentSource, spec: ShardSpec, *,
                 batch: int, seq: int, docs_per_step: int | None = None,
                 eos_id: int = 0):
        self.source = source
        self.spec = spec
        self.batch = batch
        self.seq = seq
        self.eos_id = eos_id
        # heuristic: enough docs to fill batch*seq tokens with slack
        self.docs_per_step = docs_per_step or max(
            2 * batch * seq // max(source.mean_len, 1), batch)
        self.step = 0

    def seek(self, step: int) -> None:
        self.step = step

    def __iter__(self):
        return self

    def __next__(self) -> PackedBatch:
        base = self.step * self.docs_per_step
        docs = (self.source.doc((base + k) * self.spec.host_count
                                + self.spec.host_index)
                for k in range(self.docs_per_step))
        out = pack_documents(docs, self.batch, self.seq, eos_id=self.eos_id)
        self.step += 1
        if out is None:
            raise StopIteration
        return out
