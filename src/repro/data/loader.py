"""Minimal batching utilities (shuffle + drop-remainder batching)."""
from __future__ import annotations

from typing import Iterator

import numpy as np


def batches(
    x: np.ndarray, y: np.ndarray, batch_size: int,
    *, rng: np.random.Generator | None = None, shuffle: bool = True,
) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Yield (x, y) minibatches; drops the ragged tail."""
    n = len(y)
    idx = np.arange(n)
    if shuffle:
        (rng or np.random.default_rng(0)).shuffle(idx)
    for start in range(0, n - batch_size + 1, batch_size):
        sel = idx[start: start + batch_size]
        yield x[sel], y[sel]
