"""Fleet-scale synthetic client roster: 1e2..1e6 clients, host-side (DESIGN.md §13).

`make_dataset` (synthetic.py) materializes the whole federation as two dense
arrays — fine for tens of clients, impossible for the fleet-scale populations
the paper's selection machinery (P1/P5) is motivated by. `FleetRoster` keeps
the population *virtual*: each client's shard is a pure function of
``(seed, cid)`` and is generated on first touch (LRU-cached, thread-safe so
the cohort prefetcher can materialize from a background thread). Nothing is
ever resident for the whole fleet except O(population) scalars (sample
counts, optional label histograms).

The per-client draw protocol is FROZEN — the cohort store's bitwise
streamed-vs-replicated guarantee rests on every consumer seeing identical
bytes for client ``cid``:

    rng = default_rng(SeedSequence([seed & 0xFFFFFFFF, 1 + cid]))
    p     = rng.dirichlet(alpha)                      # alpha = sigma * ones
    y     = rng.choice(n_classes, size=count, p=p)    # non-IID labels
    t_idx = rng.integers(0, n_templates, size=count)
    mix   = rng.uniform(0.6, 1.0, size=(count,1,1,1))
    eps   = rng.normal(size=(count, *shape))
    x     = clip(mix * templates[y, t_idx] + noise * eps, 0, 1); normalize

A labels-only replay (``client_labels``) draws the same stream prefix and
stops before the image tensors, so phi/label-histogram passes cost O(count)
ints per client, not O(count * H * W).
"""
from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Sequence

import numpy as np

from repro.data.synthetic import SyntheticImageDataset, _smooth_templates

_SHAPES = {
    "synthetic-fleet": (28, 28, 1),
    "synthetic-fleet-cifar": (32, 32, 3),
}


def _client_rng(seed: int, cid: int) -> np.random.Generator:
    # 1 + cid keeps client streams disjoint from the roster-level stream
    # (templates / test set / counts), which uses the bare seed
    return np.random.default_rng(
        np.random.SeedSequence([seed & 0xFFFFFFFF, 1 + int(cid)]))


class FleetRoster(Sequence):
    """A lazy, immutable Sequence of ClientData over a virtual population.

    ``roster[cid]`` materializes client ``cid``'s shard (cached); ``counts``
    is host-resident for the whole population so schedulers, the trainer's
    store-size estimate, and the cohort planner never touch image data.
    """

    def __init__(self, population: int, shape: tuple[int, int, int],
                 n_classes: int, templates: np.ndarray, counts: np.ndarray,
                 *, sigma: float, noise: float, seed: int,
                 norm: tuple[float, float], cache_size: int = 4096):
        self.population = int(population)
        self.shape = tuple(shape)
        self.n_classes = int(n_classes)
        self.templates = templates
        self.counts = np.asarray(counts, dtype=np.int64)
        self.sigma = float(sigma)
        self.noise = float(noise)
        self.seed = int(seed)
        self.norm = (float(norm[0]), float(norm[1]))
        self.cache_size = int(cache_size)
        self._cache: OrderedDict[int, "ClientData"] = OrderedDict()
        self._lock = threading.Lock()
        self._hists: np.ndarray | None = None

    # --- Sequence protocol -------------------------------------------------
    def __len__(self) -> int:
        return self.population

    def __getitem__(self, cid):
        if isinstance(cid, slice):
            return [self[i] for i in range(*cid.indices(self.population))]
        cid = int(cid)
        if cid < 0:
            cid += self.population
        if not 0 <= cid < self.population:
            raise IndexError(cid)
        with self._lock:
            hit = self._cache.get(cid)
            if hit is not None:
                self._cache.move_to_end(cid)
                return hit
        data = self._generate(cid)
        with self._lock:
            self._cache[cid] = data
            self._cache.move_to_end(cid)
            while len(self._cache) > self.cache_size:
                self._cache.popitem(last=False)
        return data

    # --- generation --------------------------------------------------------
    def _alpha(self) -> np.ndarray:
        return np.full(self.n_classes, max(self.sigma, 1e-3))

    def client_labels(self, cid: int) -> np.ndarray:
        """Labels only: replays the frozen stream prefix (p, y) and stops."""
        rng = _client_rng(self.seed, cid)
        p = rng.dirichlet(self._alpha())
        return rng.choice(self.n_classes, size=int(self.counts[cid]),
                          p=p).astype(np.int32)

    def _generate(self, cid: int) -> "ClientData":
        from repro.core.federated import ClientData
        rng = _client_rng(self.seed, cid)
        count = int(self.counts[cid])
        p = rng.dirichlet(self._alpha())
        y = rng.choice(self.n_classes, size=count, p=p).astype(np.int32)
        t_idx = rng.integers(0, self.templates.shape[1], size=count)
        mix = rng.uniform(0.6, 1.0, size=(count, 1, 1, 1)).astype(np.float32)
        x = mix * self.templates[y, t_idx] + self.noise * rng.normal(
            size=(count, *self.shape)).astype(np.float32)
        x = np.clip(x, 0.0, 1.0).astype(np.float32)
        mu, sd = self.norm
        x = ((x - mu) / sd).astype(np.float32)
        return ClientData(x, y)

    def label_histograms(self) -> np.ndarray:
        """[population, n_classes] float histograms via the labels-only path."""
        if self._hists is None:
            h = np.zeros((self.population, self.n_classes))
            for cid in range(self.population):
                h[cid] = np.bincount(self.client_labels(cid),
                                     minlength=self.n_classes)
            self._hists = h
        return self._hists

    # --- sizing ------------------------------------------------------------
    @property
    def max_count(self) -> int:
        return int(self.counts.max())

    def store_nbytes(self) -> int:
        """Device bytes a replicated ClientStore for this roster would need
        (padded [population, max_count, ...]; fp32 x, int32 y)."""
        per_sample = 4 * int(np.prod(self.shape)) + 4
        return self.population * self.max_count * per_sample


class FleetDataset(SyntheticImageDataset):
    """SyntheticImageDataset-shaped view over a FleetRoster.

    Exposes the test split (small, eagerly drawn) plus ``roster``;
    ``x_train``/``y_train`` are intentionally absent-by-contract — touching
    them raises, because at fleet scale there is no dense train tensor.
    """

    def __init__(self, roster: FleetRoster, x_test: np.ndarray,
                 y_test: np.ndarray, name: str):
        self.roster = roster
        self.x_test = x_test
        self.y_test = y_test
        self.num_classes = roster.n_classes
        self.name = name

    @property
    def image_shape(self) -> tuple[int, int, int]:
        return self.roster.shape

    def _no_dense(self, attr: str):
        raise AttributeError(
            f"FleetDataset has no dense {attr}: the {self.roster.population}"
            "-client train split is virtual (see FleetRoster)")

    @property
    def x_train(self):
        self._no_dense("x_train")

    @property
    def y_train(self):
        self._no_dense("y_train")


def make_fleet(
    name: str = "synthetic-fleet",
    *,
    population: int,
    n_train: int = 6000,
    n_test: int = 1000,
    sigma: float = 0.5,
    noise: float = 0.35,
    seed: int = 0,
    cache_size: int = 4096,
) -> FleetDataset:
    """Build a fleet dataset. ``n_train`` is the TOTAL sample budget across
    the federation (same semantic as make_dataset + Dirichlet partition):
    per-client counts are drawn uniformly in [ceil(m/2), ceil(3m/2)] for
    m = n_train / population, min 1 — ragged by construction."""
    if name not in _SHAPES:
        raise ValueError(f"unknown fleet dataset {name!r}; "
                         f"options: {sorted(_SHAPES)}")
    if population < 1:
        raise ValueError(f"population must be >= 1, got {population}")
    shape = _SHAPES[name]
    n_classes = 10
    # roster-level stream, fixed draw order: templates -> test set -> counts
    rng = np.random.default_rng(seed & 0xFFFFFFFF)
    templates = _smooth_templates(n_classes, shape, n_templates=4, rng=rng)
    y_te = rng.integers(0, n_classes, size=n_test)
    t_idx = rng.integers(0, templates.shape[1], size=n_test)
    mix = rng.uniform(0.6, 1.0, size=(n_test, 1, 1, 1)).astype(np.float32)
    x_te = mix * templates[y_te, t_idx] + noise * rng.normal(
        size=(n_test, *shape)).astype(np.float32)
    x_te = np.clip(x_te, 0.0, 1.0).astype(np.float32)
    # normalization constants come from the (deterministic, small) test
    # draw — train statistics would require materializing the fleet; both
    # estimate the same population moments
    mu, sd = float(x_te.mean()), float(x_te.std()) + 1e-8
    x_te = ((x_te - mu) / sd).astype(np.float32)
    m = max(1.0, n_train / population)
    lo = max(1, int(np.ceil(m / 2)))
    hi = max(lo, int(np.ceil(1.5 * m)))
    counts = rng.integers(lo, hi + 1, size=population)
    roster = FleetRoster(population, shape, n_classes, templates, counts,
                         sigma=sigma, noise=noise, seed=seed,
                         norm=(mu, sd), cache_size=cache_size)
    return FleetDataset(roster, x_te, y_te.astype(np.int32), name)
