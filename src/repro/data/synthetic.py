"""Synthetic class-conditional image datasets (MNIST-/CIFAR-shaped).

The container is offline (no MNIST/CIFAR binaries), so the paper's datasets
are replaced by *learnable* synthetic classification problems with the same
tensor shapes and class counts (DESIGN.md §7). Each class is a mixture of
smooth random template images plus noise; difficulty is controlled by the
template-to-noise ratio, giving non-trivial accuracy curves that separate the
six benchmark schemes.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticImageDataset:
    x_train: np.ndarray  # [N, H, W, C] float32 in [0, 1]
    y_train: np.ndarray  # [N] int32
    x_test: np.ndarray
    y_test: np.ndarray
    num_classes: int
    name: str

    @property
    def image_shape(self) -> tuple[int, int, int]:
        return self.x_train.shape[1:]


def _smooth_templates(
    n_classes: int, shape: tuple[int, int, int], n_templates: int,
    rng: np.random.Generator,
) -> np.ndarray:
    """Per-class smooth random images: low-frequency Fourier noise."""
    h, w, c = shape
    fy = np.fft.fftfreq(h)[:, None]
    fx = np.fft.fftfreq(w)[None, :]
    lowpass = 1.0 / (1.0 + 64.0 * (fy**2 + fx**2))
    t = rng.normal(size=(n_classes, n_templates, h, w, c))
    spec = np.fft.fft2(t, axes=(2, 3)) * lowpass[None, None, :, :, None]
    img = np.real(np.fft.ifft2(spec, axes=(2, 3)))
    img -= img.min(axis=(2, 3, 4), keepdims=True)
    img /= img.max(axis=(2, 3, 4), keepdims=True) + 1e-9
    return img.astype(np.float32)


def make_dataset(
    name: str = "synthetic-mnist",
    *,
    n_train: int = 6000,
    n_test: int = 1000,
    noise: float = 0.35,
    seed: int = 0,
) -> SyntheticImageDataset:
    """Build a synthetic dataset. Names: synthetic-mnist | synthetic-cifar10."""
    shapes = {
        "synthetic-mnist": (28, 28, 1),
        "synthetic-cifar10": (32, 32, 3),
    }
    if name not in shapes:
        raise ValueError(f"unknown dataset {name!r}; options: {sorted(shapes)}")
    shape = shapes[name]
    n_classes = 10
    rng = np.random.default_rng(seed)
    templates = _smooth_templates(n_classes, shape, n_templates=4, rng=rng)

    def sample(n: int) -> tuple[np.ndarray, np.ndarray]:
        y = rng.integers(0, n_classes, size=n)
        t_idx = rng.integers(0, templates.shape[1], size=n)
        mix = rng.uniform(0.6, 1.0, size=(n, 1, 1, 1)).astype(np.float32)
        x = mix * templates[y, t_idx] + noise * rng.normal(
            size=(n, *shape)).astype(np.float32)
        return np.clip(x, 0.0, 1.0).astype(np.float32), y.astype(np.int32)

    x_tr, y_tr = sample(n_train)
    x_te, y_te = sample(n_test)
    # standardize with train statistics: plain (Fed)SGD on the unnormalized
    # low-contrast images stalls (conditioning), matching how the paper's
    # MNIST/CIFAR pipelines normalize inputs
    mu, sd = x_tr.mean(), x_tr.std() + 1e-8
    x_tr = (x_tr - mu) / sd
    x_te = (x_te - mu) / sd
    return SyntheticImageDataset(x_tr, y_tr, x_te, y_te, n_classes, name)
