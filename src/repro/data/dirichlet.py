"""Dirichlet(sigma) non-IID partitioning (paper Sec. V).

"splits non-IID data by sampling label proportions for clients from a
Dirichlet distribution p_{n,z} ~ Dirichlet(sigma), where the concentration
parameter sigma controls data heterogeneity."
"""
from __future__ import annotations

import numpy as np


def dirichlet_label_proportions(
    n_clients: int, n_classes: int, sigma: float,
    rng: np.random.Generator | None = None,
) -> np.ndarray:
    """[n_clients, n_classes] row-stochastic label proportions."""
    if sigma <= 0:
        raise ValueError("Dirichlet concentration must be positive")
    rng = rng or np.random.default_rng(0)
    return rng.dirichlet(sigma * np.ones(n_classes), size=n_clients)


def partition_by_dirichlet(
    labels: np.ndarray, n_clients: int, sigma: float,
    *, rng: np.random.Generator | None = None, min_per_client: int = 1,
) -> list[np.ndarray]:
    """Split sample indices among clients with Dirichlet label skew.

    Standard construction: for each class, split its indices among clients
    proportionally to a Dirichlet(sigma) draw over clients. Every client is
    guaranteed at least `min_per_client` samples (re-draws otherwise).
    """
    rng = rng or np.random.default_rng(0)
    labels = np.asarray(labels)
    classes = np.unique(labels)
    for _attempt in range(100):
        parts: list[list[int]] = [[] for _ in range(n_clients)]
        for cls in classes:
            idx = np.flatnonzero(labels == cls)
            rng.shuffle(idx)
            props = rng.dirichlet(sigma * np.ones(n_clients))
            cuts = (np.cumsum(props)[:-1] * len(idx)).astype(int)
            for n, chunk in enumerate(np.split(idx, cuts)):
                parts[n].extend(chunk.tolist())
        if min(len(p) for p in parts) >= min_per_client:
            return [np.array(sorted(p)) for p in parts]
    raise RuntimeError("could not satisfy min_per_client after 100 draws; "
                       "increase sigma or dataset size")
