"""Jittable step functions + abstract input specs for every (arch x shape).

`make_train_step` realizes the paper's parameter-efficient FedSGD on the
production mesh: the per-client pruning masks ride with the parameters
(identically sharded), gradients are masked before the cross-client
(data-axis) aggregation — the TPU analogue of the pruned-gradient upload
(DESIGN.md §3) — and the server SGD update (eq. 7) is fused in.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.configs.registry import InputShape
from repro.models import transformer as T
from repro.models.blocks import Runtime

PyTree = Any


# ---------------------------------------------------------------------------
# Per-shape config/runtime specialization
# ---------------------------------------------------------------------------

def specialize(cfg: ModelConfig, shape: InputShape) -> tuple[ModelConfig, Runtime]:
    """Adapt config + runtime to an input shape (DESIGN.md §5)."""
    # flash_vjp: hand-written O(S) attention backward — the autodiff backward
    # of the chunked forward stores every [BQ,BK] probability block
    # (EXPERIMENTS.md §Perf, train-memory iteration 1)
    # prefill uses the causal triangle-skip scan (§Perf prefill iteration:
    # exact, 1.6x wall-clock on attention-bound prefill)
    impl = {"train": "flash_vjp", "prefill": "chunked_skip",
            "decode": "chunked"}[shape.kind]
    rt = Runtime(attn_impl=impl, q_chunk=512, kv_chunk=512,
                 loss_chunk=256, remat=(shape.kind == "train"))
    if shape.name == "long_500k" and cfg.local_global:
        rt = dataclasses.replace(rt, swa_only=True)
    if cfg.family == "audio" and shape.seq_len > cfg.max_seq:
        cfg = dataclasses.replace(cfg, max_seq=shape.seq_len)
    return cfg, rt


# ---------------------------------------------------------------------------
# Step factories
# ---------------------------------------------------------------------------

def train_microbatches(cfg: ModelConfig) -> int:
    """Gradient-accumulation factor: bounds per-device activation memory for
    the widest archs (d_model >= 6144: mixtral, llama-vision-90b, arctic;
    arctic additionally needs x8 — 128 experts' dispatch buffers).
    EXPERIMENTS.md §Perf train-memory iteration 3."""
    if cfg.num_experts >= 64:
        return 8
    return 4 if cfg.d_model >= 6144 else 1


def structured_slice(params: PyTree, lam: float) -> tuple[PyTree, "ModelConfig | None"]:
    """Structured (width) pruning: drop the trailing lam fraction of every
    FFN hidden dimension by *slicing* the weights — unlike elementwise masks,
    this removes the FLOPs/bytes/collectives on TPU (the MXU cannot exploit
    unstructured zeros). Beyond-paper §Perf iteration: the paper's eq.-(2)
    compression realized structurally.

    Returns (sliced params, None); the config is unchanged because the FFN
    width is read from the weights."""
    if lam <= 0:
        return params, None

    def slc(path, w):
        pth = jax.tree_util.keystr(path)
        if any(k in pth for k in ("w_gate", "w_up")) and w.ndim >= 2:
            f = w.shape[-1]
            return jax.lax.slice_in_dim(w, 0, max(1, int(f * (1 - lam))), axis=w.ndim - 1)
        if "w_down" in pth and w.ndim >= 2:
            f = w.shape[-2]
            return jax.lax.slice_in_dim(w, 0, max(1, int(f * (1 - lam))), axis=w.ndim - 2)
        return w

    return jax.tree_util.tree_map_with_path(slc, params), None


def make_train_step(cfg: ModelConfig, rt: Runtime, *, eta: float = 1e-2,
                    microbatches: int | None = None,
                    structured_lambda: float = 0.0):
    """(params, masks, batch) -> (loss, new_params): masked-FedSGD step.

    With microbatches > 1 the global batch is processed in accumulation
    steps (lax.scan), dividing activation memory by the factor; gradients
    accumulate in fp32 at the parameter sharding. structured_lambda > 0
    additionally width-prunes the FFNs (structured_slice)."""
    mb = train_microbatches(cfg) if microbatches is None else microbatches
    # >=100B params: bf16 gradient accumulation (an f32 accumulator at the
    # FSDP sharding is 7.5 GB/device for arctic-480b)
    from repro.models.transformer import param_count
    acc_dtype = jnp.bfloat16 if param_count(cfg) > 100e9 else jnp.float32

    def masked_loss(p, masks, tokens, labels, extra):
        pm = jax.tree.map(lambda w, m: w * m.astype(w.dtype), p, masks)
        if structured_lambda > 0:
            pm, _ = structured_slice(pm, structured_lambda)
        return T.loss_fn(pm, tokens, labels, cfg, rt, extra or None)

    def train_step(params, masks, batch):
        extra_keys = [k for k in batch if k not in ("tokens", "labels")]
        if mb == 1:
            loss, grads = jax.value_and_grad(masked_loss)(
                params, masks, batch["tokens"], batch["labels"],
                {k: batch[k] for k in extra_keys})
        else:
            mb_batch = {k: v.reshape(mb, v.shape[0] // mb, *v.shape[1:])
                        for k, v in batch.items()}

            def body(acc, mbx):
                g_acc, l_acc = acc
                l, g = jax.value_and_grad(masked_loss)(
                    params, masks, mbx["tokens"], mbx["labels"],
                    {k: mbx[k] for k in extra_keys})
                g_acc = jax.tree.map(
                    lambda a, gg: a + gg.astype(a.dtype), g_acc, g)
                return (g_acc, l_acc + l), None

            g0 = jax.tree.map(
                lambda p: jnp.zeros(p.shape, acc_dtype), params)
            (grads, loss), _ = jax.lax.scan(
                body, (g0, jnp.zeros((), jnp.float32)), mb_batch, length=mb)
            grads = jax.tree.map(lambda g: g / mb, grads)
            loss = loss / mb
        # pruned coordinates neither upload nor update (eq. 5-7)
        new_params = jax.tree.map(
            lambda w, g, m: w - eta * (g * m.astype(g.dtype)).astype(w.dtype),
            params, grads, masks)
        return loss, new_params

    return train_step


def make_prefill_step(cfg: ModelConfig, rt: Runtime):
    def prefill_step(params, batch, cache):
        extra = {k: v for k, v in batch.items() if k != "tokens"} or None
        return T.prefill(params, batch["tokens"], cache, cfg, rt, extra)

    return prefill_step


def make_serve_step(cfg: ModelConfig, rt: Runtime):
    def serve_step(params, cache, token, pos):
        return T.decode_step(params, token, cache, pos, cfg, rt)

    return serve_step


# ---------------------------------------------------------------------------
# Abstract inputs (ShapeDtypeStruct; no allocation)
# ---------------------------------------------------------------------------

def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def batch_specs(cfg: ModelConfig, shape: InputShape, *,
                with_labels: bool) -> dict[str, jax.ShapeDtypeStruct]:
    b, s = shape.global_batch, shape.seq_len
    d = {"tokens": _sds((b, s), jnp.int32)}
    if with_labels:
        d["labels"] = _sds((b, s), jnp.int32)
    if cfg.family == "audio":
        d["encoder_input"] = _sds((b, cfg.encoder_tokens, cfg.d_model),
                                  jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        d["vision_embeddings"] = _sds((b, cfg.vision_tokens, cfg.d_model),
                                      jnp.dtype(cfg.dtype))
    return d


def input_specs(cfg: ModelConfig, shape: InputShape, rt: Runtime) -> dict:
    """All abstract inputs for the shape's step function.

    train:   params, masks, batch{tokens, labels, extra}
    prefill: params, batch{tokens, extra}, cache
    decode:  params, cache, token [B,1], pos scalar
    """
    pshapes = T.param_shapes(cfg)
    if shape.kind == "train":
        # masks: {0,1} per weight, stored uint8 (a bf16 mask tree doubles
        # parameter memory — 0.96 TB at arctic scale)
        masks = jax.tree.map(
            lambda l: jax.ShapeDtypeStruct(l.shape, jnp.uint8), pshapes)
        return {
            "params": pshapes,
            "masks": masks,
            "batch": batch_specs(cfg, shape, with_labels=True),
        }
    if shape.kind == "prefill":
        cache = jax.eval_shape(
            lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len,
                                 swa_only=rt.swa_only))
        return {
            "params": pshapes,
            "batch": batch_specs(cfg, shape, with_labels=False),
            "cache": cache,
        }
    # decode: ONE new token against a seq_len-deep cache
    cache = jax.eval_shape(
        lambda: T.init_cache(cfg, shape.global_batch, shape.seq_len,
                             swa_only=rt.swa_only))
    return {
        "params": pshapes,
        "cache": cache,
        "token": _sds((shape.global_batch, 1), jnp.int32),
        "pos": _sds((), jnp.int32),
    }
