"""Training launcher: real (host-scale) runs of the FEEL train step.

On this CPU container it runs REDUCED configs end-to-end (the full configs
are exercised by dryrun.py); on a TPU cluster the same entry point drives the
production mesh.

    PYTHONPATH=src python -m repro.launch.train --arch qwen2.5-3b \
        --steps 20 --batch 8 --seq 128 [--reduced]
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, list_configs
from repro.models import transformer as T
from repro.models.blocks import Runtime
from repro.launch.steps import make_train_step
from repro.core import pruning


def packed_batch(it, cfg, batch, seq):
    """Document-packed batch from the deterministic LM pipeline."""
    pb = next(it)
    out = {"tokens": jnp.asarray(pb.tokens), "labels": jnp.asarray(pb.labels)}
    return _add_extra(out, np.random.default_rng(0), cfg, batch)


def _add_extra(out, rng, cfg, batch):
    if cfg.family == "audio":
        out["encoder_input"] = jnp.asarray(
            rng.normal(size=(batch, cfg.encoder_tokens, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    if cfg.family == "vlm":
        out["vision_embeddings"] = jnp.asarray(
            rng.normal(size=(batch, cfg.vision_tokens, cfg.d_model)),
            jnp.dtype(cfg.dtype))
    return out


def synthetic_batch(rng, cfg, batch, seq):
    tokens = rng.integers(0, cfg.vocab_size, size=(batch, seq + 1))
    out = {"tokens": jnp.asarray(tokens[:, :-1], jnp.int32),
           "labels": jnp.asarray(tokens[:, 1:], jnp.int32)}
    return _add_extra(out, rng, cfg, batch)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_configs(), required=True)
    ap.add_argument("--steps", type=int, default=10)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--lam", type=float, default=0.3,
                    help="pruning ratio (paper eq. 2)")
    ap.add_argument("--eta", type=float, default=1e-2)
    ap.add_argument("--full", action="store_true",
                    help="full-size config (TPU clusters only)")
    ap.add_argument("--data", choices=("random", "packed"), default="packed",
                    help="packed: document-packed deterministic LM pipeline")
    ap.add_argument("--ckpt-dir", default=None,
                    help="checkpoint directory (keeps latest 3)")
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    rt = Runtime(attn_impl="naive" if args.seq <= 512 else "chunked")
    rng = np.random.default_rng(0)
    params = T.init_params(jax.random.key(0), cfg)

    # importance masks from eq. (4), using a warmup gradient as v^(s-1)
    batch = synthetic_batch(rng, cfg, args.batch, args.seq)
    extra = {k: v for k, v in batch.items() if k not in ("tokens", "labels")}
    g0 = jax.grad(lambda p: T.loss_fn(p, batch["tokens"], batch["labels"],
                                      cfg, rt, extra or None))(params)
    imp = pruning.taylor_importance(params, g0)
    masks = pruning.build_masks(imp, args.lam)
    masks = jax.tree.map(lambda m: m.astype(jnp.uint8), masks)
    print(f"arch={cfg.name} params={T.param_count(cfg):,} "
          f"realized lambda={pruning.actual_ratio(masks):.3f}")

    data_it = None
    if args.data == "packed":
        from repro.data.lm_pipeline import (PackedLMIterator, ShardSpec,
                                            SyntheticDocumentSource)
        data_it = PackedLMIterator(
            SyntheticDocumentSource(cfg.vocab_size, seed=0),
            ShardSpec(0, 1), batch=args.batch, seq=args.seq)
    mgr = None
    if args.ckpt_dir:
        from repro.checkpoint import CheckpointManager
        mgr = CheckpointManager(args.ckpt_dir, keep=3)

    step = jax.jit(make_train_step(cfg, rt, eta=args.eta, microbatches=1))
    for i in range(args.steps):
        t0 = time.time()
        if data_it is not None:
            batch = packed_batch(data_it, cfg, args.batch, args.seq)
        else:
            batch = synthetic_batch(rng, cfg, args.batch, args.seq)
        loss, params = step(params, masks, batch)
        print(f"step {i:3d} loss {float(loss):.4f} "
              f"({time.time() - t0:.2f}s)")
        if mgr is not None and (i + 1) % 10 == 0:
            mgr.save(i + 1, params)
    if mgr is not None:
        mgr.save(args.steps, params)
        print("checkpointed to", args.ckpt_dir)


if __name__ == "__main__":
    main()
