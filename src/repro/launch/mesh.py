"""Production meshes.

Defined as FUNCTIONS (not module constants) so importing this module never
touches jax device state. The dry-run launcher sets
XLA_FLAGS=--xla_force_host_platform_device_count=512 before any jax import.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    """16x16 single pod (256 chips) or 2x16x16 two-pod (512 chips).

    REPRO_FORCE_MESH="d,m" (or "p,d,m") overrides the shape — used only by
    tests to exercise the full dry-run path with few host devices."""
    import os
    forced = os.environ.get("REPRO_FORCE_MESH")
    if forced:
        shape = tuple(int(x) for x in forced.split(","))
        axes = ("pod", "data", "model")[-len(shape):]
        return jax.make_mesh(shape, axes)
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(*, model: int | None = None, data: int | None = None):
    """Whatever fits the local device count (tests / smoke): (n//m, m).

    `data` caps the data axis to the first ``data * m`` local devices — the
    round engine uses this to shard the client axis over a subset of the
    host devices (REPRO_ROUND_SHARDS override, see core/round_engine.py)."""
    import numpy as np
    from jax.sharding import Mesh

    n = len(jax.devices())
    m = model or (2 if n % 2 == 0 and n > 1 else 1)
    if data is None:
        return jax.make_mesh((n // m, m), ("data", "model"))
    if data * m > n:
        raise ValueError(
            f"data={data} x model={m} exceeds {n} local devices")
    devs = np.asarray(jax.devices()[:data * m]).reshape(data, m)
    return Mesh(devs, ("data", "model"))


def replicate(tree, mesh):
    """device_put every array in `tree` fully replicated over `mesh`.

    Used for operands that every shard reads whole — e.g. the block
    engine's `ClientStore` buffers: committing them once with an empty
    PartitionSpec means the jitted shard_map step never has to re-transfer
    or re-lay-out the data on each dispatch."""
    from jax.sharding import NamedSharding, PartitionSpec

    sharding = NamedSharding(mesh, PartitionSpec())
    return jax.tree.map(lambda a: jax.device_put(a, sharding), tree)


# TPU v5e hardware constants (per chip) for the roofline (EXPERIMENTS.md).
PEAK_FLOPS_BF16 = 197e12       # FLOP/s
HBM_BW = 819e9                 # bytes/s
ICI_BW_PER_LINK = 50e9         # bytes/s per link (~)
HBM_BYTES = 16e9
