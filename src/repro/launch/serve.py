"""Serving launcher: batched prefill + decode with the KV-cache runtime.

    PYTHONPATH=src python -m repro.launch.serve --arch granite-3-2b \
        --batch 4 --prompt-len 64 --gen 32
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.registry import get_config, list_configs
from repro.models import transformer as T
from repro.models.blocks import Runtime


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_configs(), required=True)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--full", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if not args.full:
        cfg = cfg.reduced()
    rt = Runtime(attn_impl="naive")
    rng = np.random.default_rng(0)
    params = T.init_params(jax.random.key(0), cfg)
    max_seq = args.prompt_len + args.gen

    extra = None
    if cfg.family == "audio":
        extra = {"encoder_input": jnp.asarray(
            rng.normal(size=(args.batch, cfg.encoder_tokens, cfg.d_model)),
            jnp.dtype(cfg.dtype))}
    if cfg.family == "vlm":
        extra = {"vision_embeddings": jnp.asarray(
            rng.normal(size=(args.batch, cfg.vision_tokens, cfg.d_model)),
            jnp.dtype(cfg.dtype))}

    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab_size, size=(args.batch, args.prompt_len)),
        jnp.int32)
    cache = T.init_cache(cfg, args.batch, max_seq)

    prefill = jax.jit(lambda p, t, c: T.prefill(p, t, c, cfg, rt, extra))
    decode = jax.jit(lambda p, t, c, pos: T.decode_step(p, t, c, pos, cfg, rt))

    t0 = time.time()
    logits, cache = prefill(params, prompts, cache)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    print(f"prefill {args.batch}x{args.prompt_len}: {t_prefill:.2f}s "
          f"({args.batch * args.prompt_len / t_prefill:.0f} tok/s)")

    key = jax.random.key(1)
    tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    generated = [tok]
    t0 = time.time()
    for i in range(args.gen - 1):
        pos = args.prompt_len + i
        logits, cache = decode(params, tok, cache, pos)
        if args.temperature > 0:
            key, sub = jax.random.split(key)
            tok = jax.random.categorical(
                sub, logits / args.temperature)[:, None].astype(jnp.int32)
        else:
            tok = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
        generated.append(tok)
    jax.block_until_ready(generated[-1])
    dt = time.time() - t0
    toks = jnp.concatenate(generated, axis=1)
    print(f"decode {args.gen - 1} steps: {dt:.2f}s "
          f"({args.batch * (args.gen - 1) / max(dt, 1e-9):.0f} tok/s)")
    print("sample token ids:", np.asarray(toks[0])[:16].tolist())


if __name__ == "__main__":
    main()
