import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "") +
                           " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x input-shape) on the
production meshes, extract memory/cost/collective analyses.

MUST be run as its own process (the XLA flag above is read at first jax
init). Usage:

    PYTHONPATH=src python -m repro.launch.dryrun --arch yi-9b --shape train_4k
    PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod]

Results land in experiments/dryrun/<arch>__<shape>__<mesh>.json and feed
EXPERIMENTS.md §Dry-run / §Roofline.
"""
import argparse
import json
import re
import time

import jax
import numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.configs.registry import (
    get_config, list_configs, INPUT_SHAPES, shape_applicable)
from repro.launch import mesh as mesh_lib
from repro.launch.steps import (
    specialize, input_specs, make_train_step, make_prefill_step,
    make_serve_step)
from repro.sharding import rules

OUT_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "..",
                       "experiments", "dryrun")


# ---------------------------------------------------------------------------
# Collective-bytes extraction from the (SPMD, per-device) HLO text
# ---------------------------------------------------------------------------

_COLL_RE = re.compile(
    r"(\w[\w.\-]*)\s*=\s*((?:\([^)]*\))|(?:[a-z0-9_]+\[[^\]]*\]))\s*"
    r"(all-gather|all-reduce|reduce-scatter|all-to-all|collective-permute)"
    r"(?:-start)?\(")
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

# ring-factor per collective kind (bytes on the wire per byte of result)
_KIND_FACTOR = {
    "all-reduce": 2.0, "all-gather": 1.0, "reduce-scatter": 1.0,
    "all-to-all": 1.0, "collective-permute": 1.0,
}


def _shape_bytes(text: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(text):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        if dims:
            for d in dims.split(","):
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_stats(hlo_text: str) -> dict:
    """Per-device bytes moved per collective kind (ring-model estimate)."""
    by_kind: dict[str, float] = {}
    counts: dict[str, int] = {}
    for m in _COLL_RE.finditer(hlo_text):
        shape_txt, kind = m.group(2), m.group(3)
        b = _shape_bytes(shape_txt) * _KIND_FACTOR[kind]
        by_kind[kind] = by_kind.get(kind, 0.0) + b
        counts[kind] = counts.get(kind, 0) + 1
    return {"bytes_by_kind": by_kind, "counts": counts,
            "total_bytes": sum(by_kind.values())}


# ---------------------------------------------------------------------------
# Dry-run of one (arch, shape, mesh)
# ---------------------------------------------------------------------------

def lower_step(arch: str, shape_name: str, *, multi_pod: bool = False):
    """Build mesh + shardings, lower the step. Returns (lowered, meta)."""
    cfg = get_config(arch)
    shape = INPUT_SHAPES[shape_name]
    ok, why = shape_applicable(cfg, shape)
    if not ok:
        return None, {"skipped": why}
    cfg, rt = specialize(cfg, shape)
    mesh = mesh_lib.make_production_mesh(multi_pod=multi_pod)
    mode = "train" if shape.kind == "train" else "serve"
    pol = rules.make_policy(cfg, mesh, mode)
    specs = input_specs(cfg, shape, rt)

    pspec = rules.param_specs(cfg, pol, specs["params"])
    pshard = jax.tree.map(lambda s: NamedSharding(mesh, s), pspec)

    def nshard(spec_tree):
        return jax.tree.map(lambda s: NamedSharding(mesh, s), spec_tree)

    with jax.sharding.set_mesh(mesh):
        if shape.kind == "train":
            step = make_train_step(cfg, rt)
            bspec = {k: rules.batch_spec(v.shape[0], pol, rank=len(v.shape))
                     for k, v in specs["batch"].items()}
            jitted = jax.jit(step,
                             in_shardings=(pshard, pshard, nshard(bspec)),
                             out_shardings=(NamedSharding(mesh, P()), pshard),
                             donate_argnums=(0,))  # new params alias old
            lowered = jitted.lower(specs["params"], specs["masks"],
                                   specs["batch"])
        elif shape.kind == "prefill":
            step = make_prefill_step(cfg, rt)
            bspec = {k: rules.batch_spec(v.shape[0], pol, rank=len(v.shape))
                     for k, v in specs["batch"].items()}
            cspec = rules.cache_specs(cfg, pol, specs["cache"],
                                      shape.global_batch)
            jitted = jax.jit(
                step,
                in_shardings=(pshard, nshard(bspec), nshard(cspec)),
                out_shardings=(NamedSharding(mesh, P()), nshard(cspec)))
            lowered = jitted.lower(specs["params"], specs["batch"],
                                   specs["cache"])
        else:
            step = make_serve_step(cfg, rt)
            cspec = rules.cache_specs(cfg, pol, specs["cache"],
                                      shape.global_batch)
            tok_spec = rules.batch_spec(shape.global_batch, pol, rank=2)
            jitted = jax.jit(
                step,
                in_shardings=(pshard, nshard(cspec),
                              NamedSharding(mesh, tok_spec),
                              NamedSharding(mesh, P())),
                out_shardings=(NamedSharding(mesh, P(*tok_spec[:1], None)),
                               nshard(cspec)))
            lowered = jitted.lower(specs["params"], specs["cache"],
                                   specs["token"], specs["pos"])
    meta = {"arch": arch, "shape": shape_name,
            "mesh": "2x16x16" if multi_pod else "16x16",
            "mode": shape.kind, "fsdp": pol.fsdp}
    return lowered, meta


def run_one(arch: str, shape_name: str, *, multi_pod: bool = False,
            out_dir: str = OUT_DIR) -> dict:
    t0 = time.time()
    lowered, meta = lower_step(arch, shape_name, multi_pod=multi_pod)
    if lowered is None:
        rec = dict(meta, status="skipped")
        _save(rec, arch, shape_name, multi_pod, out_dir)
        return rec
    t_lower = time.time() - t0
    t0 = time.time()
    compiled = lowered.compile()
    t_compile = time.time() - t0

    mem = compiled.memory_analysis()
    mem_rec = {k: int(getattr(mem, k, 0)) for k in
               ("argument_size_in_bytes", "output_size_in_bytes",
                "temp_size_in_bytes", "generated_code_size_in_bytes",
                "alias_size_in_bytes")}
    cost = compiled.cost_analysis() or {}
    cost_rec = {k: float(v) for k, v in cost.items()
                if isinstance(v, (int, float)) and k in
                ("flops", "bytes accessed", "transcendentals",
                 "utilization operand 0 {}", "bytes accessed output {}")}
    coll = collective_stats(compiled.as_text())

    rec = dict(
        meta, status="ok",
        lower_s=round(t_lower, 2), compile_s=round(t_compile, 2),
        memory=mem_rec, cost=cost_rec, collectives=coll,
    )
    _save(rec, arch, shape_name, multi_pod, out_dir)
    return rec


def _save(rec: dict, arch: str, shape_name: str, multi_pod: bool,
          out_dir: str):
    os.makedirs(out_dir, exist_ok=True)
    mesh_tag = "2x16x16" if multi_pod else "16x16"
    path = os.path.join(out_dir, f"{arch}__{shape_name}__{mesh_tag}.json")
    with open(path, "w") as f:
        json.dump(rec, f, indent=2)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", choices=list_configs())
    ap.add_argument("--shape", choices=sorted(INPUT_SHAPES))
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--out", default=OUT_DIR)
    args = ap.parse_args()

    pairs = []
    if args.all:
        pairs = [(a, s) for a in list_configs() for s in INPUT_SHAPES]
    else:
        if not args.arch or not args.shape:
            ap.error("--arch and --shape required unless --all")
        pairs = [(args.arch, args.shape)]

    for arch, shape_name in pairs:
        try:
            rec = run_one(arch, shape_name, multi_pod=args.multi_pod,
                          out_dir=args.out)
        except Exception as e:  # record and continue the sweep
            rec = {"arch": arch, "shape": shape_name, "status": "error",
                   "error": f"{type(e).__name__}: {e}"}
            _save(rec, arch, shape_name, args.multi_pod, args.out)
            print(f"[FAIL] {arch} x {shape_name}: {rec['error'][:160]}")
            continue
        if rec["status"] == "skipped":
            print(f"[skip] {arch} x {shape_name}: {rec.get('skipped')}")
            continue
        mem = rec["memory"]
        per_dev = (mem["argument_size_in_bytes"] + mem["temp_size_in_bytes"]
                   + mem["output_size_in_bytes"])
        print(f"[ok]   {arch} x {shape_name} ({rec['mesh']}): "
              f"compile {rec['compile_s']}s, "
              f"mem/dev {per_dev/1e9:.2f} GB, "
              f"flops/dev {rec['cost'].get('flops', 0):.3e}, "
              f"coll {rec['collectives']['total_bytes']/1e9:.3f} GB")


if __name__ == "__main__":
    main()
