"""Pytree checkpointing with path-keyed npz storage + JSON metadata.

Stores each leaf under its tree path; restores into the same structure.
Sharding metadata (PartitionSpec strings) rides along so a multi-host restore
can re-shard without guessing. Atomic via write-to-temp + rename.
"""
from __future__ import annotations

import json
import os
import tempfile
from typing import Any

import jax
import numpy as np

PyTree = Any


def _path_dict(tree: PyTree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for kp, leaf in flat:
        key = jax.tree_util.keystr(kp)
        out[key] = np.asarray(leaf)
    return out


def save_checkpoint(
    path: str, params: PyTree, *, step: int = 0,
    sharding_meta: dict[str, str] | None = None,
    extra: dict | None = None,
) -> None:
    """Atomically save a pytree (+ metadata json) to `path` (.npz appended)."""
    os.makedirs(os.path.dirname(os.path.abspath(path)) or ".", exist_ok=True)
    arrays = _path_dict(params)
    meta = {
        "step": step,
        "keys": sorted(arrays),
        "sharding": sharding_meta or {},
        "extra": extra or {},
    }
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)) or ".",
                               suffix=".npz.tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **{k.replace("/", "⁄"): v for k, v in arrays.items()})
        os.replace(tmp, path if path.endswith(".npz") else path + ".npz")
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)
    meta_path = (path[:-4] if path.endswith(".npz") else path) + ".meta.json"
    with open(meta_path, "w") as f:
        json.dump(meta, f, indent=2)


def load_checkpoint(path: str, like: PyTree) -> tuple[PyTree, dict]:
    """Restore a pytree saved by save_checkpoint into the structure of `like`."""
    npz_path = path if path.endswith(".npz") else path + ".npz"
    meta_path = (path[:-4] if path.endswith(".npz") else path) + ".meta.json"
    with np.load(npz_path) as data:
        arrays = {k.replace("⁄", "/"): data[k] for k in data.files}
    meta = {}
    if os.path.exists(meta_path):
        with open(meta_path) as f:
            meta = json.load(f)
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for kp, leaf in flat:
        key = jax.tree_util.keystr(kp)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if arr.shape != np.shape(leaf):
            raise ValueError(f"shape mismatch for {key}: "
                             f"ckpt {arr.shape} vs model {np.shape(leaf)}")
        leaves.append(arr.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


class CheckpointManager:
    """Keeps the latest k checkpoints under a directory."""

    def __init__(self, directory: str, *, keep: int = 3, prefix: str = "ckpt"):
        self.directory = directory
        self.keep = keep
        self.prefix = prefix
        os.makedirs(directory, exist_ok=True)

    def _name(self, step: int) -> str:
        return os.path.join(self.directory, f"{self.prefix}_{step:08d}")

    def meta_path(self, step: int) -> str:
        """Path of the JSON metadata sidecar for `step` (readable without
        reconstructing the pytree — the CLI resume path uses this)."""
        return self._name(step) + ".meta.json"

    def save(self, step: int, params: PyTree, **kw) -> str:
        path = self._name(step)
        save_checkpoint(path, params, step=step, **kw)
        self._gc()
        return path + ".npz"

    def latest_step(self) -> int | None:
        steps = self._steps()
        return steps[-1] if steps else None

    def restore(self, like: PyTree, step: int | None = None) -> tuple[PyTree, dict]:
        step = self.latest_step() if step is None else step
        if step is None:
            raise FileNotFoundError("no checkpoints found")
        return load_checkpoint(self._name(step), like)

    def _steps(self) -> list[int]:
        out = []
        for fn in os.listdir(self.directory):
            if fn.startswith(self.prefix) and fn.endswith(".npz"):
                try:
                    out.append(int(fn[len(self.prefix) + 1:-4]))
                except ValueError:
                    pass
        return sorted(out)

    def _gc(self) -> None:
        steps = self._steps()
        for s in steps[:-self.keep]:
            for suffix in (".npz", ".meta.json"):
                p = self._name(s) + suffix
                if os.path.exists(p):
                    os.unlink(p)
