"""Pytree checkpointing with path-keyed npz storage + JSON metadata.

Stores each leaf under its tree path; restores into the same structure.
Sharding metadata (PartitionSpec strings) rides along so a multi-host restore
can re-shard without guessing.

Crash safety: both files of a step are written via mkstemp + os.replace, so
a step is either fully present or absent — never half-written under its
final name. The meta JSON is renamed BEFORE the npz: `_steps()` lists steps
by their .npz, so a listed step always has its metadata (a crash between
the two renames leaves only an orphaned .meta.json, which nothing lists).
A torn file copied in from a dirty filesystem still surfaces as
`CheckpointCorruptError`; `CheckpointManager.restore(step=None)` skips such
steps and falls back to the newest intact one.
"""
from __future__ import annotations

import json
import os
import tempfile
import zipfile
from typing import Any

import jax
import numpy as np

PyTree = Any


class CheckpointCorruptError(RuntimeError):
    """A checkpoint file is truncated or unreadable — typically a process
    killed mid-write before the atomic renames existed, or a torn copy.
    `CheckpointManager.restore(step=None)` catches this and resumes from
    the previous intact step; an explicitly requested step re-raises."""


def _path_dict(tree: PyTree) -> dict[str, np.ndarray]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for kp, leaf in flat:
        key = jax.tree_util.keystr(kp)
        out[key] = np.asarray(leaf)
    return out


def atomic_write_text(path: str, text: str) -> None:
    """Write `text` to `path` via mkstemp + os.replace in the target
    directory: the file is either fully present under its final name or
    absent, never torn. Shared by checkpoint metadata and the sweep
    service's manifest (repro.api.sweep.write_manifest)."""
    d = os.path.dirname(os.path.abspath(path)) or "."
    fd, tmp = tempfile.mkstemp(dir=d, suffix=".tmp")
    try:
        with os.fdopen(fd, "w") as f:
            f.write(text)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


_atomic_write_text = atomic_write_text  # original (private) name


def save_checkpoint(
    path: str, params: PyTree, *, step: int = 0,
    sharding_meta: dict[str, str] | None = None,
    extra: dict | None = None,
) -> None:
    """Atomically save a pytree (+ metadata json) to `path` (.npz appended)."""
    os.makedirs(os.path.dirname(os.path.abspath(path)) or ".", exist_ok=True)
    arrays = _path_dict(params)
    meta = {
        "step": step,
        "keys": sorted(arrays),
        "sharding": sharding_meta or {},
        "extra": extra or {},
    }
    # meta first (see module docstring): once the .npz rename makes the
    # step visible to _steps(), its metadata is guaranteed on disk
    meta_path = (path[:-4] if path.endswith(".npz") else path) + ".meta.json"
    _atomic_write_text(meta_path, json.dumps(meta, indent=2))
    fd, tmp = tempfile.mkstemp(dir=os.path.dirname(os.path.abspath(path)) or ".",
                               suffix=".npz.tmp")
    os.close(fd)
    try:
        with open(tmp, "wb") as f:
            np.savez(f, **{k.replace("/", "⁄"): v for k, v in arrays.items()})
        os.replace(tmp, path if path.endswith(".npz") else path + ".npz")
    finally:
        if os.path.exists(tmp):
            os.unlink(tmp)


def verify_checkpoint(path: str) -> None:
    """Cheap integrity probe: raise CheckpointCorruptError when the npz
    zip at `path` fails its CRC walk or the meta JSON is missing/unparsable
    (save writes meta first, so an intact step always has one). Does not
    reconstruct the pytree."""
    npz_path = path if path.endswith(".npz") else path + ".npz"
    meta_path = (path[:-4] if path.endswith(".npz") else path) + ".meta.json"
    if not os.path.exists(npz_path):
        raise FileNotFoundError(npz_path)
    try:
        with zipfile.ZipFile(npz_path) as z:
            bad = z.testzip()
        if bad is not None:
            raise CheckpointCorruptError(
                f"checkpoint {npz_path!r}: member {bad!r} fails its CRC — "
                f"truncated or corrupt file, likely interrupted mid-write")
    except (zipfile.BadZipFile, EOFError, OSError) as e:
        raise CheckpointCorruptError(
            f"checkpoint {npz_path!r} is truncated or corrupt "
            f"({type(e).__name__}: {e}) — likely interrupted mid-write"
        ) from e
    if not os.path.exists(meta_path):
        raise CheckpointCorruptError(
            f"checkpoint {npz_path!r} has no metadata sidecar "
            f"{meta_path!r} — torn write from a pre-atomic save")
    try:
        with open(meta_path) as f:
            json.load(f)
    except json.JSONDecodeError as e:
        raise CheckpointCorruptError(
            f"checkpoint metadata {meta_path!r} is not valid JSON "
            f"({e}) — truncated or corrupt file") from e


def load_checkpoint(path: str, like: PyTree) -> tuple[PyTree, dict]:
    """Restore a pytree saved by save_checkpoint into the structure of
    `like`. Raises CheckpointCorruptError (not a raw zip/JSON error) when
    the files are truncated, so callers can fall back to an older step."""
    npz_path = path if path.endswith(".npz") else path + ".npz"
    meta_path = (path[:-4] if path.endswith(".npz") else path) + ".meta.json"
    try:
        with np.load(npz_path) as data:
            arrays = {k.replace("⁄", "/"): data[k] for k in data.files}
    except FileNotFoundError:
        raise
    except (zipfile.BadZipFile, EOFError, OSError, ValueError) as e:
        raise CheckpointCorruptError(
            f"checkpoint {npz_path!r} is truncated or corrupt "
            f"({type(e).__name__}: {e}) — likely interrupted mid-write; "
            f"resume from an earlier step") from e
    meta = {}
    if os.path.exists(meta_path):
        try:
            with open(meta_path) as f:
                meta = json.load(f)
        except json.JSONDecodeError as e:
            raise CheckpointCorruptError(
                f"checkpoint metadata {meta_path!r} is not valid JSON "
                f"({e}) — truncated or corrupt file") from e
    flat, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for kp, leaf in flat:
        key = jax.tree_util.keystr(kp)
        if key not in arrays:
            raise KeyError(f"checkpoint missing leaf {key}")
        arr = arrays[key]
        if arr.shape != np.shape(leaf):
            raise ValueError(f"shape mismatch for {key}: "
                             f"ckpt {arr.shape} vs model {np.shape(leaf)}")
        leaves.append(arr.astype(np.asarray(leaf).dtype))
    return jax.tree_util.tree_unflatten(treedef, leaves), meta


class CheckpointManager:
    """Keeps the latest k checkpoints under a directory."""

    def __init__(self, directory: str, *, keep: int = 3, prefix: str = "ckpt"):
        self.directory = directory
        self.keep = keep
        self.prefix = prefix
        os.makedirs(directory, exist_ok=True)

    def _name(self, step: int) -> str:
        return os.path.join(self.directory, f"{self.prefix}_{step:08d}")

    def meta_path(self, step: int) -> str:
        """Path of the JSON metadata sidecar for `step` (readable without
        reconstructing the pytree — the CLI resume path uses this)."""
        return self._name(step) + ".meta.json"

    def save(self, step: int, params: PyTree, **kw) -> str:
        path = self._name(step)
        save_checkpoint(path, params, step=step, **kw)
        self._gc()
        return path + ".npz"

    def latest_step(self) -> int | None:
        steps = self._steps()
        return steps[-1] if steps else None

    def latest_intact_step(self) -> int | None:
        """Newest step that passes `verify_checkpoint` — the step
        `restore(step=None)` will land on after corruption fallback.
        None when no step is usable."""
        for s in reversed(self._steps()):
            try:
                verify_checkpoint(self._name(s))
                return s
            except CheckpointCorruptError:
                continue
        return None

    def restore(self, like: PyTree, step: int | None = None) -> tuple[PyTree, dict]:
        if step is not None:
            # explicitly requested step: corruption is an error the caller
            # asked to see, no silent fallback
            return load_checkpoint(self._name(step), like)
        steps = self._steps()
        if not steps:
            raise FileNotFoundError("no checkpoints found")
        last_err: CheckpointCorruptError | None = None
        for s in reversed(steps):
            try:
                verify_checkpoint(self._name(s))
                return load_checkpoint(self._name(s), like)
            except CheckpointCorruptError as e:
                last_err = e  # fall back to the previous intact step
        raise last_err

    def clear(self) -> None:
        """Delete every checkpoint step (npz + metadata) under this
        manager's prefix. The sweep service calls this once a cell's
        final result is durable in the sink: its mid-cell resume
        checkpoints are dead weight, and a stale step would shadow a
        later sweep's same-named cell."""
        for s in self._steps():
            for suffix in (".npz", ".meta.json"):
                p = self._name(s) + suffix
                if os.path.exists(p):
                    os.unlink(p)

    def _steps(self) -> list[int]:
        out = []
        for fn in os.listdir(self.directory):
            if fn.startswith(self.prefix) and fn.endswith(".npz"):
                try:
                    out.append(int(fn[len(self.prefix) + 1:-4]))
                except ValueError:
                    pass
        return sorted(out)

    def _gc(self) -> None:
        steps = self._steps()
        for s in steps[:-self.keep]:
            for suffix in (".npz", ".meta.json"):
                p = self._name(s) + suffix
                if os.path.exists(p):
                    os.unlink(p)
