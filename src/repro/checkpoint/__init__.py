"""Checkpointing: npz-based pytree save/restore with sharding metadata."""
from repro.checkpoint.io import (
    CheckpointCorruptError,
    CheckpointManager,
    load_checkpoint,
    save_checkpoint,
    verify_checkpoint,
)

__all__ = [
    "save_checkpoint", "load_checkpoint", "verify_checkpoint",
    "CheckpointManager", "CheckpointCorruptError",
]
