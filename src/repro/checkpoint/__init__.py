"""Checkpointing: npz-based pytree save/restore with sharding metadata."""
from repro.checkpoint.io import save_checkpoint, load_checkpoint, CheckpointManager

__all__ = ["save_checkpoint", "load_checkpoint", "CheckpointManager"]
