"""Wireless edge substrate: channels, rates, delay and energy models (Sec. II-B/C)."""
from repro.wireless.channel import ChannelModel, rayleigh_gains
from repro.wireless.comm import (
    SystemParams,
    uplink_rate,
    downlink_rate,
    computation_delay,
    communication_delay,
    per_client_delay,
    round_delay,
    total_delay,
    computation_energy,
    upload_energy,
    round_energy,
    total_energy,
)

__all__ = [
    "ChannelModel", "rayleigh_gains", "SystemParams",
    "uplink_rate", "downlink_rate",
    "computation_delay", "communication_delay", "per_client_delay",
    "round_delay", "total_delay",
    "computation_energy", "upload_energy", "round_energy", "total_energy",
]
