"""Delay and energy models — paper eqs. (8)-(15), vectorized over clients.

Conventions: all arrays are shape [N] (per client). Rates in bits/s, delay in
seconds, energy in joules. A selection vector `a` in {0,1}^N gates every
per-client quantity, matching eqs. (12) and (15).
"""
from __future__ import annotations

import dataclasses

import numpy as np

_EPS = 1e-30


@dataclasses.dataclass(frozen=True)
class SystemParams:
    """Static system parameters (Table I of the paper).

    Per-client arrays have shape [N]; scalars are shared.
    """

    bandwidth: np.ndarray          # c_n  [Hz]
    noise_psd: float               # U_0  [W/Hz]
    grad_bits: np.ndarray          # H_n  [bits] unpruned gradient payload
    flops_per_sample: np.ndarray   # e_n  [FLOPs]
    flops_per_cycle: np.ndarray    # q_n
    pue: np.ndarray                # kappa_n
    switched_cap: np.ndarray       # varpi_n  [effective capacitance]
    batch_size: np.ndarray         # Z_n
    server_power: float            # p_hat [W]
    server_bandwidth: float        # c_hat [Hz]
    p_max: np.ndarray              # [W]
    f_max: np.ndarray              # [Hz]
    lambda_max: float              # max pruning ratio

    @staticmethod
    def table1(
        n: int,
        *,
        dataset: str = "mnist",
        batch_size: int = 32,
    ) -> "SystemParams":
        """Exact Table-I parameterization for the paper's two setups."""
        ones = np.ones(n)
        # Power coefficients {varpi_n} from Table I (cycled if n > 10).
        base = np.array([0.88, 0.84, 1.41, 1.33, 0.94, 1.37, 1.8, 1.91, 0.92,
                         0.93, 1.13, 1.01, 0.26, 0.96])
        varpi = np.resize(base, n)
        if dataset == "mnist":
            return SystemParams(
                bandwidth=100e3 * ones,
                noise_psd=3.98e-21,
                grad_bits=1.42e6 * ones,
                flops_per_sample=1.8e6 * ones,
                flops_per_cycle=4 * ones,
                pue=ones,
                switched_cap=varpi * 1e-27,
                batch_size=batch_size * np.ones(n, dtype=int),
                server_power=0.5,
                server_bandwidth=100e3 * n,
                p_max=0.5 * ones,
                f_max=500e6 * ones,
                lambda_max=0.5,
            )
        if dataset == "cifar10":
            return SystemParams(
                bandwidth=2e6 * ones,
                noise_psd=3.98e-21,
                grad_bits=21.07e6 * ones,
                flops_per_sample=0.59e9 * ones,
                flops_per_cycle=8 * ones,
                pue=ones,
                switched_cap=varpi * 1e-28,
                batch_size=batch_size * np.ones(n, dtype=int),
                server_power=0.5,
                server_bandwidth=2e6 * n,
                p_max=0.5 * ones,
                f_max=2000e6 * ones,
                lambda_max=0.7,
            )
        raise ValueError(f"unknown dataset {dataset!r}")


# --------------------------------------------------------------------------
# Rates — eqs. (8), (9)
# --------------------------------------------------------------------------

def uplink_rate(p: np.ndarray, h: np.ndarray, sp: SystemParams) -> np.ndarray:
    """r_n(p_n) = c_n log2(1 + p_n h_n / (c_n U_0))  [bits/s], eq. (8)."""
    p = np.asarray(p, dtype=np.float64)
    snr = p * h / (sp.bandwidth * sp.noise_psd)
    return sp.bandwidth * np.log2(1.0 + snr)


def downlink_rate(h_down: np.ndarray, sp: SystemParams) -> np.ndarray:
    """r^_n = c^ log2(1 + p^ h^_n / (c^ U_0))  [bits/s], eq. (9) (multicast)."""
    snr = sp.server_power * h_down / (sp.server_bandwidth * sp.noise_psd)
    return sp.server_bandwidth * np.log2(1.0 + snr)


# --------------------------------------------------------------------------
# Delay — eqs. (10)-(12)
# --------------------------------------------------------------------------

def computation_delay(lam: np.ndarray, f: np.ndarray, sp: SystemParams) -> np.ndarray:
    """tau_n = (1-lam) Z e_n / (f q_n), eq. (10)."""
    f = np.maximum(np.asarray(f, dtype=np.float64), _EPS)
    return (1.0 - lam) * sp.batch_size * sp.flops_per_sample / (f * sp.flops_per_cycle)


def communication_delay(
    lam: np.ndarray, p: np.ndarray, h_up: np.ndarray, h_down: np.ndarray,
    sp: SystemParams,
) -> np.ndarray:
    """tau^_n = (1-lam) H_n / r_n(p) + H_n / r^_n, eq. (11)."""
    r_up = np.maximum(uplink_rate(p, h_up, sp), _EPS)
    r_down = np.maximum(downlink_rate(h_down, sp), _EPS)
    return (1.0 - lam) * sp.grad_bits / r_up + sp.grad_bits / r_down


def per_client_delay(
    lam: np.ndarray, p: np.ndarray, f: np.ndarray,
    h_up: np.ndarray, h_down: np.ndarray, sp: SystemParams,
) -> np.ndarray:
    """tau_n + tau^_n per client [N] — the quantity eq. (12) maxes over.

    Exposed so the straggler fault model (core/faults.py) judges each
    selected client's scheduled delay against the same round deadline
    `round_delay` reports — exclusion couples to the paper's T constraint.
    """
    return (computation_delay(lam, f, sp)
            + communication_delay(lam, p, h_up, h_down, sp))


def round_delay(
    a: np.ndarray, lam: np.ndarray, p: np.ndarray, f: np.ndarray,
    h_up: np.ndarray, h_down: np.ndarray, sp: SystemParams,
) -> float:
    """max_n a_n (tau_n + tau^_n): the per-round straggler latency."""
    gated = np.asarray(a, dtype=np.float64) * per_client_delay(
        lam, p, f, h_up, h_down, sp)
    return float(gated.max()) if gated.size else 0.0


def total_delay(
    a: np.ndarray, lam: np.ndarray, p: np.ndarray, f: np.ndarray,
    h_up: np.ndarray, h_down: np.ndarray, sp: SystemParams,
) -> float:
    """T = sum_s max_n ..., eq. (12). Inputs are [S+1, N] arrays."""
    a, lam = np.atleast_2d(a), np.atleast_2d(lam)
    p, f = np.atleast_2d(p), np.atleast_2d(f)
    return float(sum(
        round_delay(a[s], lam[s], p[s], f[s], h_up, h_down, sp)
        for s in range(a.shape[0])))


# --------------------------------------------------------------------------
# Energy — eqs. (13)-(15)
# --------------------------------------------------------------------------

def computation_energy(lam: np.ndarray, f: np.ndarray, sp: SystemParams) -> np.ndarray:
    """E~_n = (1-lam) kappa varpi f^2 Z e_n / q_n, eq. (13)."""
    f = np.asarray(f, dtype=np.float64)
    return ((1.0 - lam) * sp.pue * sp.switched_cap * f**2
            * sp.batch_size * sp.flops_per_sample / sp.flops_per_cycle)


def upload_energy(
    lam: np.ndarray, p: np.ndarray, h_up: np.ndarray, sp: SystemParams
) -> np.ndarray:
    """E^_n = (1-lam) p H_n / r_n(p), eq. (14)."""
    r_up = np.maximum(uplink_rate(p, h_up, sp), _EPS)
    return (1.0 - lam) * np.asarray(p, dtype=np.float64) * sp.grad_bits / r_up


def broadcast_energy(h_down: np.ndarray, sp: SystemParams) -> float:
    """p^ * max_n H_n / r^_n: server multicast energy per round (eq. 15)."""
    r_down = np.maximum(downlink_rate(h_down, sp), _EPS)
    return float(sp.server_power * np.max(sp.grad_bits / r_down))


def round_energy(
    a: np.ndarray, lam: np.ndarray, p: np.ndarray, f: np.ndarray,
    h_up: np.ndarray, h_down: np.ndarray, sp: SystemParams,
) -> float:
    """One summand of eq. (15)."""
    a = np.asarray(a, dtype=np.float64)
    e = computation_energy(lam, f, sp) + upload_energy(lam, p, h_up, sp)
    return float((a * e).sum() + broadcast_energy(h_down, sp))


def total_energy(
    a: np.ndarray, lam: np.ndarray, p: np.ndarray, f: np.ndarray,
    h_up: np.ndarray, h_down: np.ndarray, sp: SystemParams,
) -> float:
    """E = eq. (15) over all rounds. Inputs are [S+1, N]."""
    a, lam = np.atleast_2d(a), np.atleast_2d(lam)
    p, f = np.atleast_2d(p), np.atleast_2d(f)
    return float(sum(
        round_energy(a[s], lam[s], p[s], f[s], h_up, h_down, sp)
        for s in range(a.shape[0])))
