"""Channel model: IID Rayleigh fading with average path loss (Sec. V setup).

The paper: "Channel coefficients are modeled as IID Rayleigh fading with an
average path loss of 1e-5, and remain constant during all rounds."

Beyond the paper's noiseless-aggregation assumption, `GaussianAggregateNoise`
models a noisy uplink aggregation channel (Wu et al., "Information-Theoretic
Generalization Analysis for Topology-aware Heterogeneous FEEL over Noisy
Channels"): the server observes the averaged gradient plus AWGN,
``y^(s) = (1/C) sum_n g_n^(s) + n^(s)``, and both broadcasts and updates
with the noisy aggregate. The noise is drawn per round on host, keyed ONLY
by ``(seed, round)`` — so the draw is identical whether the round executes
through the per-round path, a multi-round block, or a checkpoint resume —
and generated directly in the packed ``[R, 128]`` buffer layout so the
device-resident engines consume it without restructuring (the reference
backend unpacks the same buffer; see core/federated.py).
"""
from __future__ import annotations

import dataclasses

import numpy as np


def rayleigh_gains(
    n: int, *, path_loss: float = 1e-5, rng: np.random.Generator | None = None
) -> np.ndarray:
    """Draw n channel power gains h = path_loss * |CN(0,1)|^2.

    |CN(0,1)|^2 is exponential(1), so E[h] = path_loss.
    """
    rng = rng or np.random.default_rng(0)
    return path_loss * rng.exponential(scale=1.0, size=n)


@dataclasses.dataclass
class ChannelModel:
    """Holds uplink/downlink gains for N clients, constant across rounds."""

    n_clients: int
    path_loss: float = 1e-5
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.uplink = rayleigh_gains(self.n_clients, path_loss=self.path_loss, rng=rng)
        self.downlink = rayleigh_gains(self.n_clients, path_loss=self.path_loss, rng=rng)

    def gains(self) -> tuple[np.ndarray, np.ndarray]:
        return self.uplink, self.downlink


@dataclasses.dataclass(frozen=True)
class GaussianAggregateNoise:
    """AWGN on the aggregated gradient: v^(s) <- mean(g) + std * N(0, I).

    The per-round draw is a pure function of ``(seed, round)`` — NOT of a
    shared stream position — which is what makes the trajectory invariant
    to dispatch grouping (rounds_per_dispatch=1 vs K) and to checkpoint
    resume. ``sample_packed`` emits the noise in the packed ``[rows, 128]``
    fp32 layout; ``valid`` (ParamPack.valid_mask) zeroes the padding lanes
    so noise can never leak into the buffer tail that real coordinates
    don't occupy. The default std is a mild perturbation relative to the
    engines' O(1) gradient scales — spec files set their own via
    ``WirelessSpec.noise_kwargs={"std": ...}``.
    """

    std: float = 1e-3
    seed: int = 0

    def sample_packed(self, round_index: int, shape: tuple[int, int],
                      valid: np.ndarray | None = None) -> np.ndarray:
        rng = np.random.default_rng(
            np.random.SeedSequence([self.seed & 0xFFFFFFFF,
                                    int(round_index)]))
        nz = (self.std * rng.standard_normal(shape)).astype(np.float32)
        if valid is not None:
            nz *= valid
        return nz
