"""Channel model: IID Rayleigh fading with average path loss (Sec. V setup).

The paper: "Channel coefficients are modeled as IID Rayleigh fading with an
average path loss of 1e-5, and remain constant during all rounds."
"""
from __future__ import annotations

import dataclasses

import numpy as np


def rayleigh_gains(
    n: int, *, path_loss: float = 1e-5, rng: np.random.Generator | None = None
) -> np.ndarray:
    """Draw n channel power gains h = path_loss * |CN(0,1)|^2.

    |CN(0,1)|^2 is exponential(1), so E[h] = path_loss.
    """
    rng = rng or np.random.default_rng(0)
    return path_loss * rng.exponential(scale=1.0, size=n)


@dataclasses.dataclass
class ChannelModel:
    """Holds uplink/downlink gains for N clients, constant across rounds."""

    n_clients: int
    path_loss: float = 1e-5
    seed: int = 0

    def __post_init__(self):
        rng = np.random.default_rng(self.seed)
        self.uplink = rayleigh_gains(self.n_clients, path_loss=self.path_loss, rng=rng)
        self.downlink = rayleigh_gains(self.n_clients, path_loss=self.path_loss, rng=rng)

    def gains(self) -> tuple[np.ndarray, np.ndarray]:
        return self.uplink, self.downlink
