"""String-keyed component registries backing the declarative specs.

Seven registries resolve the spec's string fields into build-time factories:

  MODELS          name -> factory(spec: ModelSpec, dataset) -> (init, apply)
  DATASETS        name -> factory(spec: DataSpec) -> SyntheticImageDataset-like
  SCHEMES         name -> factory(spec: SchemeSpec) -> AOConfig
  DATA_SELECTION  name -> factory(spec: SchemeSpec) -> (clients -> clients)
                  or None ("none"): per-client sample curation applied once
                  per run before training (core/selection.py, Albaseer)
  CHANNEL_NOISE   name -> factory(spec: WirelessSpec) -> channel-noise model
                  or None ("none"): noisy-aggregation axis consumed by the
                  trainer per round (wireless/channel.py, Wu)
  FAULT_MODELS    name -> factory(spec: WirelessSpec) -> fault model or
                  None ("none"): client fault-injection axis — per-round
                  dropout / straggler / corrupt-upload draws consumed by
                  the trainer with graceful degradation (core/faults.py)
  LOCAL_SCHEMES   name -> factory(spec: SchemeSpec) -> LocalScheme or
                  None (single-step fedavg): the client-local update rule
                  between uploads (core/local.py) — "fedavg" / "fedprox"
                  / "feddyn", with SchemeSpec.local_steps/local_kwargs
                  reaching the factory

Register new components with the `register_model` / `register_dataset` /
`register_scheme` / `register_data_selection` / `register_channel_noise` /
`register_fault_model` / `register_local_scheme` decorators (or call them with the factory
directly); an unknown key raises a KeyError that names the registry and
lists what IS registered, so a typo in a spec file fails with an
actionable message.

Seeded here: the paper's evaluation models (lenet, resnet) plus the
dispatch-bound mlp-edge model, both synthetic datasets, the seven
benchmark schemes (the paper's six Sec.-V comparisons + `proposed_exact`,
the 2^N-exact (P5) minimizer — see benchmarks/common.py for the finding
that motivates keeping both selection variants), the two Albaseer-style
data-selection policies, the Gaussian aggregation-noise model, and the
four client fault models.
"""
from __future__ import annotations

from typing import Any, Callable

from repro.api.spec import DataSpec, ModelSpec, SchemeSpec, WirelessSpec
from repro.core.optimizer_ao import AOConfig
from repro.data import make_dataset
from repro.models import (
    lenet_apply, lenet_init, mlp_edge_apply, mlp_edge_init,
    resnet_apply, resnet_init,
)


class Registry:
    """A named string -> factory map with helpful unknown-key errors."""

    def __init__(self, kind: str):
        self.kind = kind
        self._items: dict[str, Callable] = {}

    def register(self, name: str, factory: Callable | None = None,
                 *, override: bool = False):
        """Register `factory` under `name`; usable as a decorator."""
        def _do(fn: Callable) -> Callable:
            if name in self._items and not override:
                raise ValueError(
                    f"{self.kind} {name!r} is already registered; pass "
                    f"override=True to replace it")
            self._items[name] = fn
            return fn
        return _do if factory is None else _do(factory)

    def get(self, name: str) -> Callable:
        try:
            return self._items[name]
        except KeyError:
            raise KeyError(
                f"unknown {self.kind} {name!r}; registered {self.kind}s: "
                f"{self.names()}") from None

    def names(self) -> list[str]:
        return sorted(self._items)

    def __contains__(self, name: str) -> bool:
        return name in self._items


MODELS = Registry("model")
DATASETS = Registry("dataset")
SCHEMES = Registry("scheme")
DATA_SELECTION = Registry("data-selection policy")
CHANNEL_NOISE = Registry("channel-noise model")
FAULT_MODELS = Registry("fault model")
LOCAL_SCHEMES = Registry("local-update scheme")

register_model = MODELS.register
register_dataset = DATASETS.register
register_scheme = SCHEMES.register
register_data_selection = DATA_SELECTION.register
register_channel_noise = CHANNEL_NOISE.register
register_fault_model = FAULT_MODELS.register
register_local_scheme = LOCAL_SCHEMES.register


# ---------------------------------------------------------------------------
# Seed models. A model factory receives the (resolved) dataset so image
# shape / class count flow in from the data side; ModelSpec.kwargs carries
# the model's own knobs (depth, hidden, ...).
# ---------------------------------------------------------------------------

@register_model("lenet")
def _lenet(spec: ModelSpec, dataset) -> tuple[Callable, Callable]:
    in_ch = int(dataset.image_shape[2])
    nc = int(dataset.num_classes)
    kw = dict(spec.kwargs)
    return (lambda key: lenet_init(key, in_channels=in_ch, num_classes=nc,
                                   **kw),
            lenet_apply)


@register_model("mlp-edge")
def _mlp_edge(spec: ModelSpec, dataset) -> tuple[Callable, Callable]:
    h, w, c = dataset.image_shape
    nc = int(dataset.num_classes)
    kw = dict(spec.kwargs)
    return (lambda key: mlp_edge_init(key, in_dim=h * w * c, num_classes=nc,
                                      **kw),
            mlp_edge_apply)


@register_model("resnet")
def _resnet(spec: ModelSpec, dataset) -> tuple[Callable, Callable]:
    in_ch = int(dataset.image_shape[2])
    nc = int(dataset.num_classes)
    kw = {"depth": 20, **spec.kwargs}
    return (lambda key: resnet_init(key, in_channels=in_ch, num_classes=nc,
                                    **kw),
            resnet_apply)


# ---------------------------------------------------------------------------
# Seed datasets: the two synthetic substrates (the container is offline, so
# MNIST/CIFAR shapes come from learnable synthetic problems — data/synthetic).
# ---------------------------------------------------------------------------

def _make_synthetic(name: str):
    def factory(spec: DataSpec):
        return make_dataset(name, n_train=spec.n_train, n_test=spec.n_test,
                            noise=spec.noise, seed=spec.seed)
    return factory


for _name in ("synthetic-mnist", "synthetic-cifar10"):
    register_dataset(_name, _make_synthetic(_name))


def _make_fleet_dataset(name: str):
    """Fleet-scale virtual rosters (data/fleet.py): DataSpec.n_clients IS
    the population (1e2..1e6); clients generate lazily on first touch, so
    building the dataset costs O(population) scalars, not samples."""
    def factory(spec: DataSpec):
        from repro.data.fleet import make_fleet
        return make_fleet(name, population=spec.n_clients,
                          n_train=spec.n_train, n_test=spec.n_test,
                          sigma=spec.sigma, noise=spec.noise, seed=spec.seed)
    return factory


for _name in ("synthetic-fleet", "synthetic-fleet-cifar"):
    register_dataset(_name, _make_fleet_dataset(_name))


# ---------------------------------------------------------------------------
# Seed schemes: the paper's Sec.-V comparisons. `_PAPER_BASE` is the
# benchmark default (paper (P5) prefix-sweep selection, mean-coupled phi —
# see EXPERIMENTS.md §Paper findings for why the exact enumerator is kept
# as a separate scheme rather than the default). SchemeSpec.ao overrides
# win over the scheme definition.
# ---------------------------------------------------------------------------

_PAPER_BASE: dict[str, Any] = dict(outer_iters=3, selection_method="paper",
                                   phi_coupling="mean")


def _scheme(**fields):
    def factory(spec: SchemeSpec) -> AOConfig:
        return AOConfig(**{**fields, **spec.ao})
    return factory


register_scheme("proposed", _scheme(**_PAPER_BASE))
register_scheme("proposed_exact", _scheme(outer_iters=3,
                                          selection_method="exact"))
register_scheme("no_gen", _scheme(use_phi=False, **_PAPER_BASE))
register_scheme("fixed_pruning", _scheme(fix_lambda=0.0, **_PAPER_BASE))
register_scheme("fixed_selection", _scheme(fix_selection=True, **_PAPER_BASE))
register_scheme("fixed_power", _scheme(fix_power=0.5, **_PAPER_BASE))
register_scheme("fixed_clock", _scheme(fix_freq=True, **_PAPER_BASE))


@register_scheme("random_k")
def _random_k(spec: SchemeSpec):
    """Fleet-scale baseline scheme: the factory returns a CALLABLE solver
    (not an AOConfig) — Experiment.build dispatches on that and skips
    Algorithm 1, whose subproblems run per-client host solves and are
    infeasible at 1e5+ clients. SchemeSpec.ao carries the knobs:
    {"k": clients per round, "lam": fixed pruning ratio, "seed": draw}."""
    from repro.core.optimizer_ao import solve_random
    k = int(spec.ao.get("k", 8))
    lam = float(spec.ao.get("lam", 0.0))
    seed = int(spec.ao.get("seed", 0))

    def solve(phi, e0, t0, h_up, h_down, sp, consts):
        return solve_random(phi, e0, t0, h_up, h_down, sp, consts,
                            k=k, lam=lam, seed=seed)
    return solve


# ---------------------------------------------------------------------------
# Data-selection policies (SchemeSpec.data_selection). A factory receives
# the SchemeSpec and returns a clients -> clients transform (or None for
# the identity): each client's shard is filtered ONCE, deterministically,
# before the trainer is built — phi and the wireless system stay computed
# on the full federation (the policy models energy-saving curation at
# training time, not a change of the underlying distributions), which is
# also what keeps the scheme-independent Environment reusable across
# policies in a sweep.
# ---------------------------------------------------------------------------

@register_data_selection("none")
def _data_selection_none(spec: SchemeSpec):
    return None


def _data_selection_policy(policy: str):
    def factory(spec: SchemeSpec):
        from repro.core.federated import ClientData
        from repro.core.selection import data_selection_keep_mask
        kw = dict(spec.data_selection_kwargs)

        def apply(clients):
            out = []
            for c in clients:
                keep = data_selection_keep_mask(c.x, c.y, policy=policy, **kw)
                out.append(ClientData(c.x[keep], c.y[keep]))
            return out
        return apply
    return factory


register_data_selection("threshold", _data_selection_policy("threshold"))
register_data_selection("fine_grained", _data_selection_policy("fine_grained"))


# ---------------------------------------------------------------------------
# Channel-noise models (WirelessSpec.noise_model): the noisy-aggregation
# axis. A factory receives the WirelessSpec and returns an object with the
# `sample_packed(round, shape, valid)` protocol (or None for the paper's
# noiseless channel); the trainer draws per-round noise from it keyed by
# the round index only, so trajectories are invariant to dispatch grouping
# and checkpoint resume.
# ---------------------------------------------------------------------------

@register_channel_noise("none")
def _channel_noise_none(spec: WirelessSpec):
    return None


@register_channel_noise("gaussian")
def _channel_noise_gaussian(spec: WirelessSpec):
    from repro.wireless.channel import GaussianAggregateNoise
    kw = dict(spec.noise_kwargs)
    kw.setdefault("seed", spec.seed)
    return GaussianAggregateNoise(**kw)


# ---------------------------------------------------------------------------
# Fault models (WirelessSpec.fault_model): the client fault-injection axis.
# A factory receives the WirelessSpec and returns an object with the
# core/faults.FaultModel `draw(round, n_clients, selected, ...)` protocol
# (or None for the paper's always-reliable clients); the trainer draws
# per-round faults keyed (seed, round, kind) only, so fault trajectories
# are invariant to dispatch grouping and checkpoint resume, and applies
# them identically on both execution backends.
# ---------------------------------------------------------------------------

@register_fault_model("none")
def _fault_none(spec: WirelessSpec):
    return None


def _fault_factory(cls_name: str):
    def factory(spec: WirelessSpec):
        from repro.core import faults
        kw = dict(spec.fault_kwargs)
        kw.setdefault("seed", spec.seed)
        return getattr(faults, cls_name)(**kw)
    return factory


register_fault_model("dropout", _fault_factory("ClientDropout"))
register_fault_model("straggler", _fault_factory("StragglerTimeout"))
register_fault_model("corrupt", _fault_factory("CorruptUpload"))
register_fault_model("mixed", _fault_factory("MixedFaults"))
# adversarial (byzantine) upload models — the attack side of the robust
# aggregation axis (SchemeSpec.aggregator / core/aggregators.py); same
# FaultModel protocol and (seed, round, kind) draw invariance
register_fault_model("sign_flip", _fault_factory("SignFlip"))
register_fault_model("scaled_malicious", _fault_factory("ScaledMalicious"))
register_fault_model("gaussian_poison", _fault_factory("GaussianPoison"))


# ---------------------------------------------------------------------------
# Local-update schemes (SchemeSpec.local_scheme): what each client runs
# between uploads. A factory receives the SchemeSpec and returns a
# core/local.LocalScheme (or None — single-step fedavg IS FedSGD and the
# None route keeps it on the byte-identical seed code path). Unknown
# local_kwargs keys raise at build time, so sweep-grid typos fail loudly.
# ---------------------------------------------------------------------------

def _local_scheme_factory(name: str):
    def factory(spec: SchemeSpec):
        from repro.core.local import make_local_scheme
        return make_local_scheme(name, steps=spec.local_steps,
                                 **spec.local_kwargs)
    return factory


for _name in ("fedavg", "fedprox", "feddyn"):
    register_local_scheme(_name, _local_scheme_factory(_name))
