"""Experiment CLI: run / resume / validate declarative spec files.

    PYTHONPATH=src python -m repro.api.cli run spec.json \
        [--out run.jsonl] [--checkpoint-dir DIR] [--checkpoint-every N]
    PYTHONPATH=src python -m repro.api.cli resume DIR [--step N] [--out ...]
    PYTHONPATH=src python -m repro.api.cli validate spec.json

`run` executes a spec end-to-end (data -> phi -> P1 -> federated training)
and optionally exports the RunResult as JSON-lines. `resume` rebuilds the
experiment from the spec stored inside the checkpoint directory and
continues it bit-for-bit from the checkpointed round. `validate` parses a
spec, resolves every registry key, and prints the normalized JSON — a dry
syntax/typo check that runs no training.
"""
from __future__ import annotations

import argparse
import dataclasses
import sys

from repro.api.experiment import (
    Experiment, RunResult, resume_from_checkpoint,
)
from repro.api.registry import DATASETS, MODELS, SCHEMES
from repro.api.spec import ExperimentSpec


def _print_result(res: RunResult) -> None:
    s = res.summary
    print(f"schedule: theta={s['theta']:.3f} E={s['energy']:.2f}J "
          f"T={s['delay']:.2f}s feasible={s['feasible']}")
    for m in res.history:
        if m.test_accuracy is not None:
            print(f"round {m.round:4d}  loss {m.train_loss:.4f}  "
                  f"acc {m.test_accuracy:.3f}  "
                  f"E {m.cumulative_energy:8.2f}J  "
                  f"T {m.cumulative_delay:8.2f}s")
    tail = (f" (resumed from round {s['resumed_from']})"
            if s.get("resumed_from") is not None else "")
    print(f"done: {s['rounds_run']} rounds, final acc "
          f"{s['final_accuracy']:.3f} @ round {s['final_accuracy_round']}"
          + tail)


def _cmd_run(args) -> int:
    spec = ExperimentSpec.from_file(args.spec)
    run_spec = spec.run
    if args.checkpoint_dir is not None:
        run_spec = dataclasses.replace(run_spec,
                                       checkpoint_dir=args.checkpoint_dir)
    if args.checkpoint_every is not None:
        run_spec = dataclasses.replace(run_spec,
                                       checkpoint_every=args.checkpoint_every)
    spec = dataclasses.replace(spec, run=run_spec)
    res = Experiment(spec).run()
    _print_result(res)
    if args.out:
        print(f"wrote {res.to_jsonl(args.out)}")
    return 0


def _cmd_resume(args) -> int:
    res = resume_from_checkpoint(args.checkpoint_dir, step=args.step)
    _print_result(res)
    if args.out:
        print(f"wrote {res.to_jsonl(args.out)}")
    return 0


def _cmd_validate(args) -> int:
    spec = ExperimentSpec.from_file(args.spec)
    DATASETS.get(spec.data.dataset)
    MODELS.get(spec.model.name)
    SCHEMES.get(spec.scheme.name)
    print(spec.to_json())
    return 0


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.api.cli",
        description="Run / resume / validate declarative FEEL experiments.")
    sub = p.add_subparsers(dest="cmd", required=True)

    pr = sub.add_parser("run", help="execute a spec file end-to-end")
    pr.add_argument("spec", help="path to an ExperimentSpec JSON file")
    pr.add_argument("--out", help="export the RunResult as JSON-lines")
    pr.add_argument("--checkpoint-dir",
                    help="override spec.run.checkpoint_dir")
    pr.add_argument("--checkpoint-every", type=int,
                    help="override spec.run.checkpoint_every")
    pr.set_defaults(fn=_cmd_run)

    ps = sub.add_parser("resume",
                        help="continue a checkpointed run bit-for-bit")
    ps.add_argument("checkpoint_dir")
    ps.add_argument("--step", type=int,
                    help="checkpoint round to resume from (default latest)")
    ps.add_argument("--out", help="export the RunResult as JSON-lines")
    ps.set_defaults(fn=_cmd_resume)

    pv = sub.add_parser("validate",
                        help="parse a spec + resolve registry keys, no run")
    pv.add_argument("spec")
    pv.set_defaults(fn=_cmd_validate)

    args = p.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
