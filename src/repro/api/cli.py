"""Experiment CLI: run / resume / validate / sweep declarative spec files.

    PYTHONPATH=src python -m repro.api.cli run spec.json \
        [--out run.jsonl] [--checkpoint-dir DIR] [--checkpoint-every N]
    PYTHONPATH=src python -m repro.api.cli resume DIR [--step N] [--out ...]
    PYTHONPATH=src python -m repro.api.cli validate spec.json \
        [--checkpoints DIR]
    PYTHONPATH=src python -m repro.api.cli sweep sweep.json --out-dir DIR \
        [--seeds 0,1,2] [--schemes proposed,no_gen] \
        [--grid data.sigma=0.5,5.0] [--expand-only] \
        [--max-retries N --retry-backoff S] [--cell-timeout S] \
        [--workers N] [--resume]

`run` executes a spec end-to-end (data -> phi -> P1 -> federated training)
and optionally exports the RunResult as JSON-lines. `resume` rebuilds the
experiment from the spec stored inside the checkpoint directory and
continues it bit-for-bit from the checkpointed round. `validate` parses a
spec, resolves every registry key, and prints the normalized JSON — a dry
syntax/typo check that runs no training. `sweep` expands a SweepSpec (or
an ExperimentSpec used as the base template with axes given by flags) into
its deterministic run matrix and executes it with environment / trainer
reuse, streaming per-run JSONL files into --out-dir as runs finish
(repro.api.sweep).
"""
from __future__ import annotations

import argparse
import dataclasses
import json
import os
import sys

from repro.api.experiment import (
    Experiment, RunResult, resume_from_checkpoint,
)
from repro.api.registry import DATASETS, LOCAL_SCHEMES, MODELS, SCHEMES
from repro.api.spec import ExperimentSpec
from repro.api.sweep import MANIFEST_NAME, JsonlDirSink, SweepSpec, run_sweep
from repro.core.aggregators import make_aggregator


def _print_result(res: RunResult) -> None:
    s = res.summary
    print(f"schedule: theta={s['theta']:.3f} E={s['energy']:.2f}J "
          f"T={s['delay']:.2f}s feasible={s['feasible']}")
    for m in res.history:
        if m.test_accuracy is not None:
            print(f"round {m.round:4d}  loss {m.train_loss:.4f}  "
                  f"acc {m.test_accuracy:.3f}  "
                  f"E {m.cumulative_energy:8.2f}J  "
                  f"T {m.cumulative_delay:8.2f}s")
    tail = (f" (resumed from round {s['resumed_from']})"
            if s.get("resumed_from") is not None else "")
    print(f"done: {s['rounds_run']} rounds, final acc "
          f"{s['final_accuracy']:.3f} @ round {s['final_accuracy_round']}"
          + tail)


def _cmd_run(args) -> int:
    spec = ExperimentSpec.from_file(args.spec)
    run_spec = spec.run
    if args.checkpoint_dir is not None:
        run_spec = dataclasses.replace(run_spec,
                                       checkpoint_dir=args.checkpoint_dir)
    if args.checkpoint_every is not None:
        run_spec = dataclasses.replace(run_spec,
                                       checkpoint_every=args.checkpoint_every)
    spec = dataclasses.replace(spec, run=run_spec)
    res = Experiment(spec).run()
    _print_result(res)
    if args.out:
        print(f"wrote {res.to_jsonl(args.out)}")
    return 0


def _cmd_resume(args) -> int:
    res = resume_from_checkpoint(args.checkpoint_dir, step=args.step)
    _print_result(res)
    if args.out:
        print(f"wrote {res.to_jsonl(args.out)}")
    return 0


def _cmd_validate(args) -> int:
    rc = 0
    if args.spec is not None:
        spec = ExperimentSpec.from_file(args.spec)
        DATASETS.get(spec.data.dataset)
        MODELS.get(spec.model.name)
        SCHEMES.get(spec.scheme.name)
        make_aggregator(spec.scheme.aggregator,
                        **spec.scheme.aggregator_kwargs)
        # resolving the factory also validates local_steps/local_kwargs
        LOCAL_SCHEMES.get(spec.scheme.local_scheme)(spec.scheme)
        print(spec.to_json())
    if args.checkpoints is not None:
        rc = max(rc, _validate_checkpoints(args.checkpoints))
    if args.spec is None and args.checkpoints is None:
        raise SystemExit("validate: pass a spec file, --checkpoints DIR, "
                         "or both")
    return rc


def _validate_checkpoints(directory: str) -> int:
    """Run verify_checkpoint over every step in a checkpoint directory;
    print one line per step and return 1 when any step is corrupt (so CI
    and pre-resume probes can gate on the exit code). A nonexistent
    directory fails BEFORE CheckpointManager touches it — the manager
    mkdirs its directory on construction, and a validate probe must never
    leave an empty decoy dir at a mistyped path."""
    from repro.checkpoint import CheckpointManager
    from repro.checkpoint.io import CheckpointCorruptError, verify_checkpoint
    if not os.path.isdir(directory):
        print(f"validate: checkpoint directory {directory!r} does not "
              f"exist — check the path", file=sys.stderr)
        return 1
    manager = CheckpointManager(directory)
    steps = manager._steps()
    if not steps:
        print(f"validate: no checkpoints under {directory!r} — empty "
              f"directory (wrong path, or the run never checkpointed)",
              file=sys.stderr)
        return 1
    n_bad = 0
    for s in steps:
        try:
            verify_checkpoint(manager._name(s))
            print(f"step {s:8d}  intact")
        except CheckpointCorruptError as e:
            n_bad += 1
            print(f"step {s:8d}  CORRUPT: {e}")
    print(f"{directory}: {len(steps) - n_bad}/{len(steps)} step(s) intact")
    return 1 if n_bad else 0


def _parse_values(raw: str) -> list:
    """Comma-separated axis values; each parsed as JSON when possible
    (numbers, booleans) and kept as a string otherwise."""
    out = []
    for tok in raw.split(","):
        tok = tok.strip()
        try:
            out.append(json.loads(tok))
        except json.JSONDecodeError:
            out.append(tok)
    return out


def _cmd_sweep(args) -> int:
    with open(args.spec) as f:
        d = json.load(f)
    # a SweepSpec file carries a "base" template; a plain ExperimentSpec
    # file IS the base, with axes supplied by flags
    sweep = (SweepSpec.from_dict(d) if "base" in d
             else SweepSpec(base=ExperimentSpec.from_dict(d)))
    if args.seeds:
        sweep = dataclasses.replace(sweep, seeds=_parse_values(args.seeds))
    if args.schemes:
        sweep = dataclasses.replace(
            sweep, schemes=[str(s) for s in _parse_values(args.schemes)])
    for axis in args.grid or ():
        path, _, raw = axis.partition("=")
        if not raw:
            raise SystemExit(f"--grid expects PATH=V1,V2,..., got {axis!r}")
        sweep = dataclasses.replace(
            sweep, grid={**sweep.grid, path: _parse_values(raw)})
    cells = sweep.expand()
    print(f"sweep matrix: {len(cells)} run(s)")
    if args.expand_only:
        for c in cells:
            print(f"  {c.name}")
        return 0
    if args.resume and not args.out_dir:
        raise SystemExit("sweep --resume requires --out-dir (the sink "
                         "directory holds the manifest and prior results)")
    if args.resume:
        # fail BEFORE run_sweep: a manifest-less dir (pre-manifest sweep,
        # or a typo'd path) would otherwise verify nothing and silently
        # re-run — and append to — whatever is there
        manifest = os.path.join(args.out_dir, MANIFEST_NAME)
        if not os.path.exists(manifest):
            raise SystemExit(
                f"sweep --resume: no sweep manifest at {manifest!r} — "
                "not a resumable sweep directory; drop --resume to start "
                "fresh or point --out-dir at the original sweep dir")
    sink = JsonlDirSink(args.out_dir) if args.out_dir else None
    try:
        res = run_sweep(sweep, sink=sink, log=print,
                        max_retries=args.max_retries,
                        retry_backoff=args.retry_backoff,
                        cell_timeout=args.cell_timeout,
                        workers=args.workers, resume=args.resume)
    except KeyboardInterrupt:
        print("sweep interrupted — completed cells are preserved; "
              "relaunch with --resume to continue", file=sys.stderr)
        return 130
    n_ok = sum(r is not None for r in res.results)
    n_ran = n_ok - res.n_skipped
    if args.resume:
        print(f"resume: skipped {res.n_skipped} verified cell(s), "
              f"ran {len(res.results) - res.n_skipped}")
    print(f"done: {n_ok}/{len(res.results)} runs; environments built "
          f"{res.n_env_builds}, trainers built {res.n_trainer_builds} "
          f"(reused across {n_ran - res.n_trainer_builds} runs)")
    if res.n_worker_crashes:
        print(f"{res.n_worker_crashes} worker(s) crashed; their cells "
              f"were requeued and completed elsewhere", file=sys.stderr)
    if sink is not None:
        print(f"wrote {len(sink.paths)} run files + index under "
              f"{sink.directory}")
    if res.errors:
        for e in res.errors:
            print(f"FAILED {e['name']}: {e['error']}", file=sys.stderr)
        print(f"{len(res.errors)} cell(s) failed (errors recorded in "
              f"sweep.jsonl)", file=sys.stderr)
        return 1
    return 0


def main(argv: list[str] | None = None) -> int:
    p = argparse.ArgumentParser(
        prog="python -m repro.api.cli",
        description="Run / resume / validate declarative FEEL experiments.")
    sub = p.add_subparsers(dest="cmd", required=True)

    pr = sub.add_parser("run", help="execute a spec file end-to-end")
    pr.add_argument("spec", help="path to an ExperimentSpec JSON file")
    pr.add_argument("--out", help="export the RunResult as JSON-lines")
    pr.add_argument("--checkpoint-dir",
                    help="override spec.run.checkpoint_dir")
    pr.add_argument("--checkpoint-every", type=int,
                    help="override spec.run.checkpoint_every")
    pr.set_defaults(fn=_cmd_run)

    ps = sub.add_parser("resume",
                        help="continue a checkpointed run bit-for-bit")
    ps.add_argument("checkpoint_dir")
    ps.add_argument("--step", type=int,
                    help="checkpoint round to resume from (default latest)")
    ps.add_argument("--out", help="export the RunResult as JSON-lines")
    ps.set_defaults(fn=_cmd_resume)

    pv = sub.add_parser("validate",
                        help="parse a spec + resolve registry keys, no run; "
                             "optionally verify a checkpoint directory")
    pv.add_argument("spec", nargs="?", default=None,
                    help="ExperimentSpec JSON file (optional with "
                         "--checkpoints)")
    pv.add_argument("--checkpoints", metavar="DIR",
                    help="run verify_checkpoint over every step under DIR; "
                         "exit nonzero when any step is corrupt")
    pv.set_defaults(fn=_cmd_validate)

    pw = sub.add_parser(
        "sweep", help="expand + execute a run matrix with env/trainer reuse")
    pw.add_argument("spec", help="SweepSpec JSON (with 'base') or an "
                                 "ExperimentSpec JSON used as the template")
    pw.add_argument("--out-dir", help="stream per-run JSONL files (+ a "
                                      "sweep.jsonl index) here as runs finish")
    pw.add_argument("--seeds", help="override the run.seed axis, e.g. 0,1,2")
    pw.add_argument("--schemes", help="override the scheme.name axis")
    pw.add_argument("--grid", action="append", metavar="PATH=V1,V2",
                    help="add a cartesian axis over a spec field path "
                         "(repeatable)")
    pw.add_argument("--expand-only", action="store_true",
                    help="print the deterministic matrix, run nothing")
    pw.add_argument("--max-retries", type=int, default=0,
                    help="retry a failing cell up to N times before "
                         "recording the failure and moving on (default 0)")
    pw.add_argument("--retry-backoff", type=float, default=0.5,
                    help="base seconds for the jittered exponential "
                         "backoff between retry attempts (default 0.5)")
    pw.add_argument("--cell-timeout", type=float, default=None,
                    help="per-cell wall-clock deadline in seconds; a cell "
                         "past it is recorded as a timeout (not retried) "
                         "and the sweep moves on")
    pw.add_argument("--workers", type=int, default=1,
                    help="run up to N independent cells concurrently "
                         "(default 1 = serial; per-run records are "
                         "bitwise identical for any N)")
    pw.add_argument("--resume", action="store_true",
                    help="skip cells whose per-run JSONL in --out-dir "
                         "verifies against the recorded sweep manifest; "
                         "re-run missing/corrupt/failed cells and continue "
                         "interrupted ones from their newest intact "
                         "checkpoint")
    pw.set_defaults(fn=_cmd_sweep)

    args = p.parse_args(argv)
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
