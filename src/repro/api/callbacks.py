"""Run lifecycle hooks and the checkpoint/resume state protocol.

Callbacks compose with the device-resident round/block engines by firing
at MATERIALIZATION points only (DESIGN.md §8): the trainer keeps per-round
losses as lazy device arrays so consecutive rounds pipeline, and drains
them in batches at eval rounds, checkpoint rounds, and run end.  A hook
therefore never forces a per-round device->host sync:

  on_round_end(m, trainer)      once per round, in round order, but BATCHED
                                at the next materialization point (m.train_
                                loss is materialized; trainer state may be
                                AHEAD of m.round mid-batch)
  on_eval(m, trainer)           at eval rounds, right after eval_fn; the
                                trainer state is coherent with m.round
  on_block_end(start, k, trainer)  after each multi-round block dispatch
                                (packed backend, rounds_per_dispatch > 1);
                                losses for the block are still lazy
  on_checkpoint(m, trainer)     at rounds where m.round % checkpoint_every
                                == 0; the trainer treats these rounds as
                                block boundaries, so params / global grad /
                                batch rng are exactly the state after round
                                m.round — what bit-for-bit resume requires

A callback opts into checkpoint rounds by setting `checkpoint_every`; the
trainer unions those rounds with the eval cadence when planning blocks, so
checkpointing never splits the middle of a compiled block.

Checkpoint contents (`save_trainer_state`): packed params + global grad v
(as pytrees through CheckpointManager's npz layer) plus JSON `extra` with
the numpy batch-RNG state, the wireless budget counters, the round index,
the originating spec, and the materialized history — everything needed to
resume an interrupted run bit-for-bit on fp32 (tests/test_api.py).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Sequence

from repro.checkpoint import CheckpointManager
from repro.core.federated import RoundMetrics


class Callback:
    """Base lifecycle hook set; subclass and override what you need."""

    # When set (int >= 1), the trainer fires on_checkpoint at rounds where
    # round % checkpoint_every == 0 with state coherent at that round.
    checkpoint_every: int | None = None

    def on_round_end(self, m: RoundMetrics, trainer) -> None:
        pass

    def on_eval(self, m: RoundMetrics, trainer) -> None:
        pass

    def on_block_end(self, start: int, n_rounds: int, trainer) -> None:
        pass

    def on_checkpoint(self, m: RoundMetrics, trainer) -> None:
        pass


class StopOnEvent(Callback):
    """Cooperative interrupt: raise `exc_type` at the next materialization
    point once `event` (a threading.Event) is set. The sweep service arms
    one per cell with its process-wide interrupt event, so SIGTERM /
    Ctrl-C stops every worker at a round/block boundary — never mid-
    dispatch — leaving the last checkpoint intact for bit-for-bit resume.
    Fires at the same points as the deadline callback: cooperative
    because the device-resident engines pipeline whole blocks."""

    def __init__(self, event, exc_type=KeyboardInterrupt):
        self.event = event
        self.exc_type = exc_type

    def _check(self) -> None:
        if self.event.is_set():
            raise self.exc_type

    def on_round_end(self, m: RoundMetrics, trainer) -> None:
        self._check()

    def on_block_end(self, start: int, n_rounds: int, trainer) -> None:
        self._check()


def metrics_to_dict(m: RoundMetrics) -> dict:
    return dataclasses.asdict(m)


def metrics_from_dict(d: dict) -> RoundMetrics:
    if d.get("train_loss") is None:
        # strict-JSON exports write nan as null (see RunResult.to_jsonl)
        d = {**d, "train_loss": float("nan")}
    return RoundMetrics(**d)


def save_trainer_state(
    manager: CheckpointManager, trainer, m: RoundMetrics, *,
    spec: dict | None = None, history: Sequence[RoundMetrics] = (),
) -> str:
    """Checkpoint the full resume state after round `m.round`.

    Must be called at a coherent point (on_checkpoint / on_eval): the
    trainer's params, global gradient, and batch RNG have to reflect
    exactly the state after round m.round."""
    tree = {"params": trainer.params, "v": trainer.global_grad}
    if getattr(trainer, "_h", None) is not None:
        # per-client optimizer state (FedDyn correction buffer): an fp32
        # array leaf like the rest, so resume restores it bit-for-bit
        tree["h"] = trainer._h
    extra = {
        "round": int(m.round),
        "rng_state": trainer.rng.bit_generator.state,
        "cumulative_delay": float(m.cumulative_delay),
        "cumulative_energy": float(m.cumulative_energy),
        "spec": spec,
        "history": [metrics_to_dict(h) for h in history],
        # counters accumulate only over EXECUTED rounds, so a resumed run
        # must start from the checkpointed totals to match an
        # uninterrupted run's (tests/test_faults.py, test_aggregators.py)
        "fault_counters": dict(getattr(trainer, "fault_counters", {})),
        "agg_counters": dict(getattr(trainer, "agg_counters", {})),
    }
    return manager.save(int(m.round), tree, extra=extra)


def restore_trainer_state(
    manager: CheckpointManager, trainer, *, step: int | None = None,
) -> dict:
    """Load a checkpoint into `trainer` (params, global grad, batch RNG)
    and return the JSON `extra` dict (round index, counters, spec,
    history). The restored fp32 leaves are exact, so continuing from
    extra["round"] + 1 replays the uninterrupted trajectory bit-for-bit."""
    like = {"params": trainer.params, "v": trainer.global_grad}
    ls = getattr(trainer, "local_scheme", None)
    if ls is not None and ls.stateful:
        like["h"] = trainer._ensure_h()
    tree, meta = manager.restore(like, step=step)
    trainer.params = tree["params"]
    trainer.global_grad = tree["v"]
    if "h" in like:
        trainer._h = tree["h"]
    extra = meta.get("extra", {})
    if "rng_state" in extra:
        trainer.rng.bit_generator.state = extra["rng_state"]
    if extra.get("fault_counters"):
        trainer.fault_counters = dict(extra["fault_counters"])
    if extra.get("agg_counters"):
        trainer.agg_counters = dict(extra["agg_counters"])
    return extra


def load_run_state(directory: str, *, step: int | None = None,
                   prefix: str = "ckpt") -> tuple[int, dict]:
    """Read a checkpoint's JSON metadata WITHOUT building a trainer —
    (step, extra). The CLI uses this to recover the originating spec.
    With step=None picks the newest INTACT checkpoint (skipping truncated
    ones), matching the step `restore_trainer_state` will load."""
    manager = CheckpointManager(directory, prefix=prefix)
    step = manager.latest_intact_step() if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints under {directory!r}")
    with open(manager.meta_path(step)) as f:
        meta = json.load(f)
    return step, meta.get("extra", {})


class CheckpointCallback(Callback):
    """Periodic bit-for-bit resume checkpoints through CheckpointManager.

    Accumulates the materialized history via on_round_end (the objects are
    updated in place when eval fills in test metrics, so the saved history
    carries them) and snapshots the full resume state every
    `checkpoint_every` rounds. Pass `history=` when resuming so later
    checkpoints keep the full from-round-0 history."""

    def __init__(self, directory: str, every: int, *,
                 spec: dict | None = None, keep: int = 3,
                 history: Sequence[RoundMetrics] = ()):
        if every < 1:
            raise ValueError(f"checkpoint_every must be >= 1, got {every}")
        self.manager = CheckpointManager(directory, keep=keep)
        self.checkpoint_every = int(every)
        self.spec = spec
        self.history: list[RoundMetrics] = list(history)
        self.saved_paths: list[str] = []

    def on_round_end(self, m: RoundMetrics, trainer) -> None:
        self.history.append(m)

    def on_checkpoint(self, m: RoundMetrics, trainer) -> None:
        self.saved_paths.append(save_trainer_state(
            self.manager, trainer, m, spec=self.spec, history=self.history))
