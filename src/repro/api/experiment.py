"""Experiment -> Run -> RunResult: the unified entry point (DESIGN.md §8).

Replaces the seven manually-wired steps (dataset -> Dirichlet partition ->
phis -> SystemParams/ChannelModel -> solve_p1 -> FederatedTrainer -> run)
with one declarative flow:

    spec = ExperimentSpec(...)            # or ExperimentSpec.from_file(p)
    run = Experiment(spec).build()        # resolves registries, solves P1
    result = run.run()                    # RunResult (JSONL-exportable)
    result = run.resume("ckpt_dir")       # bit-for-bit continuation

`Experiment.build` is deterministic in the spec (every RNG is seeded from
it), so the same spec always yields the same schedule and trajectory —
which is what makes checkpoint resume (`Run.resume`) reconstructible from
the spec stored inside the checkpoint. The environment half (dataset,
clients, phi, wireless system, model/loss/eval functions) is scheme-
independent and reusable across schemes via `build(env=...)` — the
benchmark harness sweeps the seven schemes over one environment that way.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Any, Callable, Sequence

import jax
import numpy as np

from repro.api.callbacks import (
    Callback, CheckpointCallback, metrics_from_dict, metrics_to_dict,
    restore_trainer_state,
)
from repro.api.registry import (
    CHANNEL_NOISE, DATA_SELECTION, DATASETS, FAULT_MODELS, LOCAL_SCHEMES,
    MODELS, SCHEMES,
)
from repro.api.spec import ExperimentSpec
from repro.checkpoint import CheckpointManager
from repro.core import (
    BoundConstants, ClientData, FederatedTrainer, RoundMetrics, phis,
    solve_p1,
)
from repro.core.aggregators import make_aggregator
from repro.core.local import local_spec_key
from repro.core.optimizer_ao import Schedule
from repro.data import partition_by_dirichlet
from repro.models import make_eval_fn, make_loss_fn
from repro.wireless import ChannelModel, SystemParams


@dataclasses.dataclass
class Environment:
    """The scheme-independent half of a built experiment."""

    spec: ExperimentSpec
    dataset: Any                      # SyntheticImageDataset-like
    clients: Sequence                 # list[ClientData], or a lazy roster
    phi: np.ndarray                   # [N] generalization statements (Lemma 1)
    sp: SystemParams
    ch: ChannelModel
    init_fn: Callable
    apply_fn: Callable
    loss_fn: Callable
    eval_fn: Callable


def build_environment(spec: ExperimentSpec) -> Environment:
    """Steps 1-4 of the pipeline: data, federation, phi, wireless system,
    model/loss/eval functions — everything the scheme solver and trainer
    consume. Pure in the spec (all randomness seeded from it).
    `build_environment.n_builds` counts invocations — the sweep engine's
    env-reuse tests assert on it."""
    build_environment.n_builds += 1
    d = spec.data
    dataset = DATASETS.get(d.dataset)(d)
    nc = int(dataset.num_classes)
    test_hist = np.bincount(dataset.y_test, minlength=nc).astype(float)
    roster = getattr(dataset, "roster", None)
    if roster is not None:
        # fleet-scale virtual population (data/fleet.py): the roster IS the
        # client sequence (lazy, host-side) and already non-IID per client,
        # so the Dirichlet partition is skipped; phi comes from the
        # labels-only histogram pass — O(population) ints, no image data
        clients: Sequence = roster
        phi = phis(roster.label_histograms(), test_hist[None])
    else:
        parts = partition_by_dirichlet(dataset.y_train, d.n_clients, d.sigma,
                                       rng=np.random.default_rng(d.seed))
        clients = [ClientData(dataset.x_train[i], dataset.y_train[i])
                   for i in parts]
        phi = phis(np.stack([c.label_histogram(nc) for c in clients]),
                   test_hist[None])
    table = spec.wireless.table
    if table == "auto":
        table = "mnist" if "mnist" in d.dataset else "cifar10"
    sp = SystemParams.table1(d.n_clients, dataset=table,
                             batch_size=spec.scheme.batch)
    ch = ChannelModel(d.n_clients, path_loss=spec.wireless.path_loss,
                      seed=spec.wireless.seed)
    init_fn, apply_fn = MODELS.get(spec.model.name)(spec.model, dataset)
    return Environment(
        spec=spec, dataset=dataset, clients=clients, phi=phi, sp=sp, ch=ch,
        init_fn=init_fn, apply_fn=apply_fn,
        loss_fn=make_loss_fn(apply_fn),
        eval_fn=make_eval_fn(apply_fn, dataset.x_test, dataset.y_test))


build_environment.n_builds = 0


def _json_finite(obj):
    """Replace non-finite floats with None, recursively (strict JSON)."""
    if isinstance(obj, float):
        return obj if np.isfinite(obj) else None
    if isinstance(obj, dict):
        return {k: _json_finite(v) for k, v in obj.items()}
    if isinstance(obj, (list, tuple)):
        return [_json_finite(v) for v in obj]
    return obj


@dataclasses.dataclass
class RunResult:
    """Structured outcome of a run: the solved schedule, the per-round
    history (train losses, selections, the energy/delay ledger, eval
    points), and a summary block. Serializes to JSON-lines — one header
    record then one record per round — so figure scripts, the bench
    harness, and external tooling share one metrics format
    (benchmarks/report.py ingests these)."""

    spec: dict
    summary: dict
    history: list[RoundMetrics]
    schedule: Schedule | None = None   # arrays kept in-process only

    @classmethod
    def build(cls, spec: ExperimentSpec, schedule: Schedule,
              history: list[RoundMetrics], *,
              resumed_from: int | None = None,
              faults: dict | None = None,
              aggregation: dict | None = None,
              fleet: dict | None = None) -> "RunResult":
        evals = [(m.test_accuracy, m.round) for m in history
                 if m.test_accuracy is not None]
        acc, acc_round = evals[-1] if evals else (float("nan"), -1)
        last = history[-1] if history else None
        summary = {
            "theta": float(schedule.theta),
            "energy": float(schedule.energy),
            "delay": float(schedule.delay),
            "feasible": bool(schedule.feasible),
            "rounds_run": len(history),
            "final_accuracy": acc,
            "final_accuracy_round": acc_round,
            "cumulative_delay": last.cumulative_delay if last else 0.0,
            "cumulative_energy": last.cumulative_energy if last else 0.0,
            "resumed_from": resumed_from,
        }
        if faults:
            # present only when a fault model is active or the always-on
            # guard actually fired — a healthy fault-free run's summary
            # stays byte-identical to pre-fault-layer outputs (the golden
            # test compares the whole dict)
            summary["faults"] = dict(faults)
        if aggregation:
            # present only under a robust (non-mean) aggregator, by the
            # same golden-stability argument: clean mean summaries stay
            # byte-identical
            summary["aggregation"] = dict(aggregation)
        if fleet:
            # present only when cohort streaming was active this run
            # (same only-when-active contract as faults/aggregation, so
            # replicated-store summaries stay byte-identical); note the
            # stall-seconds counter is wall-clock and NOT byte-stable —
            # parity tests compare round records, never summary bytes
            summary["fleet"] = dict(fleet)
        return cls(spec=spec.to_dict(), summary=summary, history=history,
                   schedule=schedule)

    def to_jsonl(self, path: str) -> str:
        # strict JSON: non-finite floats (nan train_loss of an empty
        # round, nan final_accuracy of an eval-free run) become null so
        # jq/JS/log pipelines can parse every line, not just Python
        with open(path, "w") as f:
            f.write(json.dumps(_json_finite(
                {"kind": "experiment", "spec": self.spec,
                 "summary": self.summary}), allow_nan=False) + "\n")
            for m in self.history:
                f.write(json.dumps(_json_finite(
                    {"kind": "round", **metrics_to_dict(m)}),
                    allow_nan=False) + "\n")
        return path

    @classmethod
    def from_jsonl(cls, path: str) -> "RunResult":
        spec: dict = {}
        summary: dict = {}
        history: list[RoundMetrics] = []
        with open(path) as f:
            for line in f:
                line = line.strip()
                if not line:
                    continue
                rec = json.loads(line)
                kind = rec.pop("kind", "round")
                if kind == "experiment":
                    spec, summary = rec["spec"], rec["summary"]
                elif kind == "round":
                    history.append(metrics_from_dict(rec))
                # unknown kinds (e.g. a sweep index's "sweep_run" records)
                # are skipped for forward compatibility
        return cls(spec=spec, summary=summary, history=history)


class Run:
    """A built experiment: environment + solved schedule + trainer.

    `.run()` executes the schedule from round 0; `.resume(dir)` restores
    the latest (or a chosen) checkpoint and continues from the next round,
    returning the FULL from-round-0 history (checkpointed prefix + newly
    executed rounds). Both honor RunSpec's eval cadence, budget stops, and
    checkpoint policy."""

    def __init__(self, spec: ExperimentSpec, env: Environment,
                 schedule: Schedule, trainer: FederatedTrainer):
        self.spec = spec
        self.env = env
        self.schedule = schedule
        self.trainer = trainer

    def run(self, *, callbacks: Sequence[Callback] = (),
            checkpoint_dir: str | None = None) -> RunResult:
        """Execute from round 0. `checkpoint_dir=` overrides where
        periodic checkpoints land WITHOUT touching the spec — the sweep
        service uses it so exported per-run headers (which embed the
        spec) stay byte-identical across sink directories."""
        return self._execute(start_round=0, prefix=[], callbacks=callbacks,
                             checkpoint_dir=checkpoint_dir)

    def resume(self, directory: str | None = None, *,
               step: int | None = None,
               callbacks: Sequence[Callback] = (),
               checkpoint_dir: str | None = None) -> RunResult:
        directory = directory or self.spec.run.checkpoint_dir
        if not directory:
            raise ValueError("no checkpoint directory: pass resume(dir) or "
                             "set spec.run.checkpoint_dir")
        manager = CheckpointManager(directory)
        extra = restore_trainer_state(manager, self.trainer, step=step)
        start = int(extra["round"]) + 1
        prefix = [metrics_from_dict(d) for d in extra.get("history", [])]
        return self._execute(start_round=start, prefix=prefix,
                             callbacks=callbacks,
                             resumed_from=int(extra["round"]),
                             checkpoint_dir=checkpoint_dir)

    def run_or_resume(self, directory: str | None = None, *,
                      callbacks: Sequence[Callback] = ()) -> RunResult:
        """Elastic entry point: `run()` when `directory` holds no intact
        checkpoint, otherwise `resume()` from its newest intact step
        (CheckpointManager.latest_intact_step — torn steps from a kill
        mid-write are skipped). Either way further checkpoints land in
        `directory`, and the result's summary has `resumed_from`
        normalized to None, so an interrupted-then-resumed run exports
        byte-identical JSONL to an uninterrupted one — the contract the
        sweep service's `--resume` is built on."""
        directory = directory or self.spec.run.checkpoint_dir
        if not directory:
            raise ValueError("no checkpoint directory: pass "
                             "run_or_resume(dir) or set "
                             "spec.run.checkpoint_dir")
        step = None
        if os.path.isdir(directory):
            step = CheckpointManager(directory).latest_intact_step()
        if step is None:
            return self.run(callbacks=callbacks, checkpoint_dir=directory)
        res = self.resume(directory, step=step, callbacks=callbacks,
                          checkpoint_dir=directory)
        res.summary["resumed_from"] = None
        return res

    def _execute(self, *, start_round: int, prefix: list[RoundMetrics],
                 callbacks: Sequence[Callback],
                 resumed_from: int | None = None,
                 checkpoint_dir: str | None = None) -> RunResult:
        rs = self.spec.run
        ckpt_dir = checkpoint_dir or rs.checkpoint_dir
        cbs: list[Callback] = []
        if ckpt_dir:
            # a directory alone is an explicit request to checkpoint:
            # default the cadence to the eval cadence rather than
            # silently writing nothing. The checkpointer goes FIRST so a
            # user hook that raises at the same round (e.g. a kill in
            # tests) observes the saved state.
            cbs.append(CheckpointCallback(
                ckpt_dir, rs.checkpoint_every or rs.eval_every,
                spec=self.spec.to_dict(), history=prefix))
        cbs.extend(callbacks)
        history = self.trainer.run(
            self.schedule, self.env.sp, self.env.ch.uplink,
            self.env.ch.downlink,
            eval_fn=self.env.eval_fn if rs.evaluate else None,
            eval_every=rs.eval_every,
            stop_delay=self.spec.wireless.t0 if rs.stop_on_budget else None,
            stop_energy=self.spec.wireless.e0 if rs.stop_on_budget else None,
            callbacks=cbs, start_round=start_round)
        fc = dict(self.trainer.fault_counters)
        include = self.trainer.fault_model is not None or any(fc.values())
        agg = None
        if self.trainer.aggregator is not None:
            agg = {"aggregator": self.trainer.aggregator.name,
                   **{k: int(v)
                      for k, v in self.trainer.agg_counters.items()}}
        fleet = (dict(self.trainer.fleet_counters)
                 if getattr(self.trainer, "streaming", False) else None)
        return RunResult.build(self.spec, self.schedule, prefix + history,
                               resumed_from=resumed_from,
                               faults=fc if include else None,
                               aggregation=agg, fleet=fleet)


class Experiment:
    """Declarative front door: resolve an ExperimentSpec into a Run."""

    def __init__(self, spec: ExperimentSpec):
        self.spec = spec

    @classmethod
    def from_dict(cls, d: dict) -> "Experiment":
        return cls(ExperimentSpec.from_dict(d))

    @classmethod
    def from_file(cls, path: str) -> "Experiment":
        return cls(ExperimentSpec.from_file(path))

    def build(self, *, env: Environment | None = None,
              trainer: FederatedTrainer | None = None) -> Run:
        """Resolve registries, solve (P1), and construct the trainer.

        `env=` reuses a previously built scheme-independent environment
        (same data/model/wireless axes) so scheme sweeps don't rebuild the
        dataset or re-draw the channel.

        `trainer=` additionally reuses a previously built trainer over the
        SAME environment and (eta, batch, backend, shards, data-selection)
        wiring: its compiled engine traces and device-resident ClientStore
        survive while `FederatedTrainer.reset` reinitializes params, the
        global gradient, the batch RNG, and every counter from this spec —
        bit-for-bit a cold build. The sweep engine (repro.api.sweep) pools
        trainers this way; it owns the compatibility bookkeeping beyond
        the cheap scalar checks asserted here."""
        spec = self.spec
        if env is None:
            env = build_environment(spec)
        else:
            # The environment is scheme-independent EXCEPT for the batch
            # size baked into SystemParams (Table-I bookkeeping): reusing
            # one across specs is only sound when the data/model/wireless
            # axes and the batch agree (budgets e0/t0 — and the trainer-
            # level noise/selection axes — are fine to vary: they only
            # reach solve_p1, the stop conditions, and the trainer).
            es = env.spec
            mismatch = [name for name, a, b in (
                ("data", es.data, spec.data),
                ("model", es.model, spec.model),
                ("scheme.batch", es.scheme.batch, spec.scheme.batch),
                ("wireless.table", es.wireless.table, spec.wireless.table),
                ("wireless.path_loss", es.wireless.path_loss,
                 spec.wireless.path_loss),
                ("wireless.seed", es.wireless.seed, spec.wireless.seed),
            ) if a != b]
            if mismatch:
                raise ValueError(
                    "build(env=...) reuse requires matching environment "
                    f"axes; spec differs from env.spec on: {mismatch}")
        sc = spec.scheme
        consts = BoundConstants(rounds_S=sc.rounds - 1, batch_Z=sc.batch,
                                eta=sc.eta, **sc.bound)
        ao = SCHEMES.get(sc.name)(sc)
        if callable(ao):
            # a scheme factory may return a solver callable instead of an
            # AOConfig (e.g. `random_k`): it replaces Algorithm 1 outright
            # — the paper schemes all run O(N) per-client host solves in
            # the (P2)-(P4) subproblems, infeasible at fleet scale
            schedule = ao(env.phi, spec.wireless.e0, spec.wireless.t0,
                          env.ch.uplink, env.ch.downlink, env.sp, consts)
        else:
            schedule = solve_p1(env.phi, spec.wireless.e0, spec.wireless.t0,
                                env.ch.uplink, env.ch.downlink, env.sp,
                                consts, ao)
        noise = CHANNEL_NOISE.get(spec.wireless.noise_model)(spec.wireless)
        fault = FAULT_MODELS.get(spec.wireless.fault_model)(spec.wireless)
        select = DATA_SELECTION.get(sc.data_selection)(sc)
        # robust aggregation (core/aggregators.py): resolved here, like the
        # other string axes; None ("mean") keeps the builtin path
        aggregator = make_aggregator(sc.aggregator, **sc.aggregator_kwargs)
        agg_key = (aggregator.spec_key if aggregator is not None else "mean")
        local = LOCAL_SCHEMES.get(sc.local_scheme)(sc)
        params = env.init_fn(jax.random.key(spec.run.seed))
        if trainer is not None:
            bad = [name for name, a, b in (
                ("scheme.eta", trainer.eta, sc.eta),
                ("scheme.batch", trainer.batch_size, sc.batch),
                ("run.backend", trainer.backend, spec.run.backend),
                # the aggregator is traced into every round graph — a
                # different reducer means a different engine, not a reset
                ("scheme.aggregator", trainer.aggregator_key, agg_key),
                # so is the local-update scheme (step count, coefficients,
                # statefulness all shape the round graph)
                ("scheme.local", trainer.local_key, local_spec_key(local)),
                # the store mode decides replicated-vs-streamed wiring at
                # run(); pooling across modes would silently flip it
                ("run.client_store", trainer.client_store,
                 spec.run.client_store),
            ) if a != b]
            if bad:
                raise ValueError(
                    f"build(trainer=...) reuse requires matching {bad}")
            trainer.reset(params, spec.run.seed, channel_noise=noise,
                          fault_model=fault)
        else:
            if select is not None and hasattr(env.clients, "store_nbytes"):
                raise ValueError(
                    "data-selection policies materialize every client's "
                    "samples and cannot run over a lazy fleet roster "
                    f"(population {len(env.clients)}); use "
                    "scheme.data_selection='none' with fleet datasets")
            clients = select(env.clients) if select is not None \
                else env.clients
            trainer = FederatedTrainer(
                env.loss_fn, params, clients,
                eta=sc.eta, batch_size=sc.batch, seed=spec.run.seed,
                backend=spec.run.backend, shards=spec.run.shards,
                rounds_per_dispatch=spec.run.rounds_per_dispatch,
                channel_noise=noise, fault_model=fault,
                aggregator=aggregator, local_scheme=local,
                client_store=spec.run.client_store,
                device_mem_budget=spec.run.device_mem_budget)
            # spec-time OOM guard: fail at build (with the actionable
            # StoreBudgetError) rather than mid-run at the first dispatch
            trainer.check_store_budget()
        return Run(spec, env, schedule, trainer)

    def run(self, **kw) -> RunResult:
        """Convenience: build() then run()."""
        return self.build().run(**kw)


def resume_from_checkpoint(directory: str, *, step: int | None = None,
                           callbacks: Sequence[Callback] = ()) -> RunResult:
    """Rebuild the experiment from the spec stored INSIDE the checkpoint
    and continue it — the `python -m repro.api.cli resume` entry point."""
    from repro.api.callbacks import load_run_state
    step, extra = load_run_state(directory, step=step)
    if not extra.get("spec"):
        raise ValueError(f"checkpoint {directory!r} step {step} carries no "
                         "spec; resume via Experiment(spec).build()."
                         "resume(dir) instead")
    spec = ExperimentSpec.from_dict(extra["spec"])
    run = Experiment(spec).build()
    return run.resume(directory, step=step, callbacks=callbacks)
