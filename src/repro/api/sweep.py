"""Multi-seed sweep engine over ExperimentSpec templates (DESIGN.md §9, §12).

The paper's claims are statistical — Figs. 4-8 are means over seeds and
over scenario knobs (sigma, budgets, heterogeneity) — so the unit of
reproduction above a single run is a *matrix* of runs. `SweepSpec` takes a
base `ExperimentSpec` template plus axis overrides and expands it into a
deterministic run matrix:

    sweep = SweepSpec(
        base=ExperimentSpec(...),
        seeds=[0, 1, 2],                       # run.seed axis
        schemes=["proposed", "no_gen"],        # scheme.name axis
        grid={"data.sigma": [0.5, 5.0]},       # cartesian over field paths
        zip={"wireless.e0": [2.0, 4.0],        # paths varied in lockstep
             "wireless.t0": [20.0, 40.0]})     # (one composite axis)
    result = run_sweep(sweep, sink=JsonlDirSink("runs/"))

Expansion is pure and deterministic in the spec: axes nest in the order
grid (insertion order) -> zip -> schemes -> seeds, with the later axes
varying fastest, and every cell gets a stable, filename-safe name
(`expand()` twice yields the identical matrix — property-tested). Field
paths are validated against the spec tree; a typo fails with the field
path and the valid keys, like every other spec error.

Execution exploits what single runs cannot: one scheme-independent
`Environment` is built per distinct (data, model, wireless, batch) group
and reused through `Experiment.build(env=...)`, and one `FederatedTrainer`
is pooled per (environment, eta, batch, backend, shards, rounds-per-
dispatch, data-selection) family and re-seeded via `FederatedTrainer.
reset` — its compiled engine traces and device-resident ClientStore
survive across the matrix, so an S-seed sweep costs far less than S cold
runs while every cell stays bit-for-bit equal to the same spec run
standalone (test-asserted). Each finished `RunResult` is streamed to the
sink AS RUNS FINISH (one per-run JSONL file plus an appended, flushed
index record), so long sweeps are observable and interruptible without
losing completed cells.

Execution is an elastic service (DESIGN.md §12):

  * `workers=N` runs independent cells concurrently on a thread pool.
    Environments are shared across workers (one build per `_env_key`,
    guarded by per-key locks); trainer pools are worker-LOCAL, so a
    pooled trainer is never driven from two threads. Per-run records are
    bitwise independent of N (each cell's trajectory depends only on its
    own spec); only sink *index order* and the trainer-build count vary.
  * `resume=True` verifies previously completed cells in the sink
    directory against the `sweep_manifest.json` spec hashes, skips the
    intact ones, re-runs missing/corrupt/failed cells, and picks up
    interrupted cells from their newest intact checkpoint
    (`<dir>/ckpt/<cell>/`, written when the base spec sets
    run.checkpoint_every) — bitwise equal to an uninterrupted run.
  * SIGTERM / KeyboardInterrupt stop every worker cooperatively at the
    next round/block boundary, flush a `sweep_interrupted` index record,
    and re-raise KeyboardInterrupt, so a killed sweep is always
    resumable.

CLI: `python -m repro.api.cli sweep sweep.json --out-dir DIR
[--workers N] [--resume]` (`benchmarks/report.py --runs 'DIR/*.jsonl'`
aggregates mean±std over the seed axis and renders FAILED/TIMEOUT cells).
"""
from __future__ import annotations

import collections
import dataclasses
import hashlib
import itertools
import json
import os
import random
import re
import signal
import threading
import time
import traceback
from typing import Any, Callable, Sequence

from repro.api.callbacks import Callback, StopOnEvent
from repro.api.experiment import (
    Environment, Experiment, RunResult, build_environment, _json_finite,
)
from repro.api.spec import ExperimentSpec, SpecError, _SpecBase
from repro.checkpoint import CheckpointManager
from repro.checkpoint.io import atomic_write_text


# ---------------------------------------------------------------------------
# Field-path overrides
# ---------------------------------------------------------------------------

def override_field(spec: ExperimentSpec, path: str, value: Any):
    """Return a copy of `spec` with the dotted `path` (e.g. "data.sigma",
    "scheme.name", "run.backend") replaced by `value`. Unknown segments
    fail with the offending field path and the valid keys at that level —
    sweep axes get the same actionable errors as spec files.

    Dict-valued fields (the `*_kwargs` factory knobs) descend one more
    level: "wireless.fault_kwargs.rate" replaces just that key in a copy
    of the dict — accuracy-vs-dropout-rate is a one-line sweep axis. Dict
    keys are free-form (they are factory kwargs), so a new key is created
    rather than rejected; scalar leaves still refuse to descend."""
    parts = path.split(".")

    def rec(node, i: int):
        where = ".".join([type(spec).__name__] + parts[:i])
        key = parts[i]
        if isinstance(node, dict):
            new = dict(node)
            if i == len(parts) - 1:
                new[key] = value
            else:
                sub = node.get(key, {})
                if not isinstance(sub, dict):
                    raise SpecError(
                        f"{where}: cannot descend into non-dict entry "
                        f"{key!r} with {'.'.join(parts[i + 1:])!r}")
                new[key] = rec(sub, i + 1)
            return new
        if not dataclasses.is_dataclass(node):
            raise SpecError(
                f"{where}: cannot descend into non-spec field with "
                f"{'.'.join(parts[i:])!r}")
        valid = {f.name for f in dataclasses.fields(node)}
        if key not in valid:
            raise SpecError(
                f"{where}: unknown field {key!r} in sweep axis path "
                f"{path!r}; valid keys: {sorted(valid)}")
        if i == len(parts) - 1:
            return dataclasses.replace(node, **{key: value})
        return dataclasses.replace(node,
                                   **{key: rec(getattr(node, key), i + 1)})

    if not path:
        raise SpecError("empty sweep axis path")
    return rec(spec, 0)


def _axis_label(path: str, value: Any) -> str:
    parts = path.split(".")
    # "scheme.name" -> "scheme=...": a bare "name=" label says nothing
    tail = parts[-2] if parts[-1] == "name" and len(parts) > 1 else parts[-1]
    v = value if isinstance(value, (str, int, float, bool)) else \
        json.dumps(value, sort_keys=True)
    return f"{tail}={v}"


def _sanitize(name: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.=+-]+", "-", name)


@dataclasses.dataclass(frozen=True)
class SweepCell:
    """One expanded run: a stable filename-safe name + its full spec."""

    index: int
    name: str
    spec: ExperimentSpec


# ---------------------------------------------------------------------------
# SweepSpec
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SweepSpec(_SpecBase):
    """A base ExperimentSpec template + axis overrides.

    seeds    run.seed values (the innermost / fastest axis);
    schemes  scheme.name values;
    grid     {field path: [values]} — cartesian product, axes nest in
             insertion order;
    zip      {field path: [values]} — all paths varied in lockstep as ONE
             composite axis (every list must have the same length).

    Empty axes are skipped; with no axes at all the sweep is the single
    base run. Round-trips through dict/JSON like every spec."""

    base: ExperimentSpec = dataclasses.field(default_factory=ExperimentSpec)
    seeds: list = dataclasses.field(default_factory=list)
    schemes: list = dataclasses.field(default_factory=list)
    grid: dict = dataclasses.field(default_factory=dict)
    zip: dict = dataclasses.field(default_factory=dict)

    _NESTED = {"base": ExperimentSpec}

    @classmethod
    def from_file(cls, path: str) -> "SweepSpec":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")
        return path

    # -- expansion ----------------------------------------------------------

    def axes(self) -> list[tuple[tuple[str, ...], list[tuple]]]:
        """The ordered axis list: [(paths, [value-tuples])]. grid axes come
        first (insertion order, one path each), then the zip composite
        (all its paths at once), then schemes, then seeds."""
        axes: list[tuple[tuple[str, ...], list[tuple]]] = []
        for path, values in self.grid.items():
            if not isinstance(values, (list, tuple)) or not values:
                raise SpecError(
                    f"sweep grid axis {path!r} needs a non-empty value "
                    f"list, got {values!r}")
            axes.append(((path,), [(v,) for v in values]))
        if self.zip:
            lens = {p: len(v) for p, v in self.zip.items()}
            if len(set(lens.values())) > 1:
                raise SpecError(
                    f"sweep zip axes must have equal lengths, got {lens}")
            if not next(iter(lens.values())):
                raise SpecError("sweep zip axes need non-empty value lists")
            paths = tuple(self.zip)
            axes.append((paths,
                         [tuple(vals) for vals in zip(*self.zip.values())]))
        if self.schemes:
            axes.append((("scheme.name",), [(s,) for s in self.schemes]))
        if self.seeds:
            axes.append((("run.seed",), [(int(s),) for s in self.seeds]))
        return axes

    def expand(self) -> list[SweepCell]:
        """Materialize the deterministic run matrix. The same template
        always yields the same cells in the same order (itertools.product
        over the ordered axes, later axes fastest)."""
        axes = self.axes()
        # validate every path once up front so a typo fails before any run
        for paths, values in axes:
            for p, v in zip(paths, values[0]):
                override_field(self.base, p, v)
        cells: list[SweepCell] = []
        combos = itertools.product(*[vals for _, vals in axes]) if axes \
            else iter([()])
        for i, combo in enumerate(combos):
            spec = self.base
            labels: list[str] = []
            for (paths, _), vals in zip(axes, combo):
                for p, v in zip(paths, vals):
                    spec = override_field(spec, p, v)
                    labels.append(_axis_label(p, v))
            name = _sanitize("_".join(labels)) if labels else "base"
            cells.append(SweepCell(index=i, name=f"{i:03d}_{name}",
                                   spec=spec))
        return cells


# ---------------------------------------------------------------------------
# Manifest + per-cell verification (the elastic-resume protocol)
# ---------------------------------------------------------------------------

MANIFEST_NAME = "sweep_manifest.json"


def spec_hash(spec) -> str:
    """Canonical content hash of an ExperimentSpec (or its dict form):
    sha256 over the sorted-key JSON. Stable across a JSON round-trip —
    floats reparse to the same float, so a cell hashed at expansion time
    matches the spec read back from its per-run JSONL header."""
    d = spec.to_dict() if hasattr(spec, "to_dict") else spec
    return hashlib.sha256(
        json.dumps(d, sort_keys=True).encode()).hexdigest()


def write_manifest(directory: str, cells: Sequence[SweepCell]) -> str:
    """Atomically record the expanded matrix — (index, name, spec hash)
    per cell — as `<directory>/sweep_manifest.json` BEFORE execution
    starts, so a later `--resume` can verify it is continuing the same
    sweep and check each completed cell's output against its hash."""
    payload = {
        "kind": "sweep_manifest",
        "n_cells": len(cells),
        "cells": [{"index": c.index, "name": c.name,
                   "spec_hash": spec_hash(c.spec)} for c in cells],
    }
    path = os.path.join(directory, MANIFEST_NAME)
    atomic_write_text(path, json.dumps(payload, indent=2) + "\n")
    return path


def load_manifest(directory: str) -> dict | None:
    """The recorded manifest, or None when the directory has none (or an
    unreadable one — a torn manifest means nothing can be verified, which
    resume treats the same as absent)."""
    path = os.path.join(directory, MANIFEST_NAME)
    try:
        with open(path) as f:
            return json.load(f)
    except (OSError, json.JSONDecodeError):
        return None


def verify_cell_run(path: str, expected_hash: str) -> RunResult | None:
    """Parse a cell's per-run JSONL and verify it is the COMPLETE output
    of the expected spec: header present, spec hash matches the manifest,
    and the round history is as long as the summary claims (a truncated
    file fails that). Returns the parsed RunResult, or None when the file
    is missing/corrupt/mismatched — the caller re-runs the cell."""
    try:
        res = RunResult.from_jsonl(path)
    except (OSError, ValueError, TypeError, KeyError):
        return None
    if not res.spec or not res.summary:
        return None
    if spec_hash(res.spec) != expected_hash:
        return None
    if res.summary.get("rounds_run") != len(res.history):
        return None
    return res


# ---------------------------------------------------------------------------
# Streaming sinks
# ---------------------------------------------------------------------------

class RunSink:
    """Streaming consumer of finished runs: `write(name, result)` is
    called AS EACH RUN FINISHES (never post-sweep), `close()` once after
    the last run. Subclass for custom streaming (DBs, sockets, ...).

    The elastic service adds lifecycle hooks, all optional: `begin` fires
    once before execution with the full matrix, `write_skipped` when
    resume verifies a previously completed cell, `write_interrupted` when
    the sweep is stopped by SIGTERM/KeyboardInterrupt, and `resume_scan`
    returns previously completed results to skip. Sinks are context
    managers (`close` on exit) and must tolerate a second `close`."""

    def begin(self, cells: Sequence[SweepCell], *,
              resume: bool = False) -> None:
        """Called once with the expanded matrix before any cell runs."""

    def write(self, name: str, result: RunResult) -> None:
        raise NotImplementedError

    def write_error(self, name: str, spec, exc: BaseException,
                    tb: str, *, kind: str = "error") -> None:
        """Called when a cell fails permanently (after retries). `kind` is
        "error" for an exception and "timeout" for a cell that blew its
        wall-clock deadline (run_sweep cell_timeout). Default: ignore —
        sinks that persist (JsonlDirSink) record the failure."""

    def write_skipped(self, name: str, result: RunResult) -> None:
        """Called (in matrix order, before execution) for each cell that
        resume verified as already complete. Default: ignore."""

    def write_interrupted(self, exc: BaseException) -> None:
        """Called once when the sweep is interrupted, before close()."""

    def resume_scan(self, cells: Sequence[SweepCell]) -> dict[int, RunResult]:
        """{cell index: verified RunResult} for cells this sink already
        holds complete output for. Default: nothing to skip."""
        return {}

    def close(self) -> None:
        pass

    def __enter__(self) -> "RunSink":
        return self

    def __exit__(self, *exc) -> bool:
        self.close()
        return False


class JsonlDirSink(RunSink):
    """The standard JSONL sink: each finished run lands as
    `<dir>/<name>.jsonl` (the full RunResult — header + per-round records,
    complete and parseable the moment `write` returns) plus one summary
    record appended AND FLUSHED to `<dir>/sweep.jsonl`, so a running sweep
    can be tailed and a killed one keeps every completed cell.
    `benchmarks/report.py --runs '<dir>/*.jsonl'` ingests the per-run
    files (the index's `sweep_run` records are skipped on ingest).

    Concurrency + interruption guarantees (DESIGN.md §12): index appends
    are serialized under a lock and written as one flushed line each, so
    N workers never interleave bytes mid-record and a kill loses at most
    the record being written; per-run files are per-cell (unique names),
    so they never contend. `begin` records the matrix manifest atomically
    (write_manifest) and truncates the index for a FRESH sweep but
    appends for a resumed one — a rejected resume therefore never
    destroys the old index. `close` is idempotent."""

    def __init__(self, directory: str, *, index_name: str = "sweep.jsonl"):
        os.makedirs(directory, exist_ok=True)
        self.directory = directory
        self.paths: list[str] = []
        self.index_path = os.path.join(directory, index_name)
        self._index = None          # opened lazily, on the first append
        self._mode = "w"
        self._lock = threading.Lock()
        self._closed = False

    def begin(self, cells: Sequence[SweepCell], *,
              resume: bool = False) -> None:
        self._mode = "a" if resume else "w"
        write_manifest(self.directory, cells)

    def resume_scan(self, cells: Sequence[SweepCell]) -> dict[int, RunResult]:
        """Verify previously completed cells against the recorded
        manifest: {index: RunResult} for every cell whose per-run JSONL
        is intact and hash-matched (verify_cell_run). Raises SpecError
        when the directory holds a DIFFERENT sweep's manifest — resuming
        would silently mix two matrices' results. A directory without a
        manifest (or with a torn one) verifies nothing."""
        manifest = load_manifest(self.directory)
        if manifest is None:
            return {}
        recorded = {c.get("index"): c for c in manifest.get("cells", [])}
        expected = {c.index: {"index": c.index, "name": c.name,
                              "spec_hash": spec_hash(c.spec)} for c in cells}
        if recorded != expected:
            raise SpecError(
                f"resume: {self.directory!r} holds the manifest of a "
                f"different sweep matrix ({len(recorded)} cell(s) recorded "
                f"vs {len(expected)} expanded); refusing to mix results — "
                f"use a fresh --out-dir or drop --resume to overwrite")
        done: dict[int, RunResult] = {}
        for c in cells:
            path = os.path.join(self.directory, f"{c.name}.jsonl")
            if not os.path.exists(path):
                continue
            res = verify_cell_run(path, expected[c.index]["spec_hash"])
            if res is not None:
                done[c.index] = res
        return done

    def _append(self, record: dict) -> None:
        line = json.dumps(_json_finite(record), allow_nan=False) + "\n"
        with self._lock:
            if self._closed:
                raise ValueError(f"sink {self.directory!r} is closed")
            if self._index is None:
                self._index = open(self.index_path, self._mode)
            # one write() of a full line + flush: concurrent workers
            # never interleave bytes, and a tailing consumer (or a kill)
            # always sees whole records
            self._index.write(line)
            self._index.flush()

    def write(self, name: str, result: RunResult) -> None:
        path = os.path.join(self.directory, f"{name}.jsonl")
        result.to_jsonl(path)
        self._append({"kind": "sweep_run", "name": name,
                      "spec": result.spec, "summary": result.summary})
        with self._lock:
            self.paths.append(path)

    def write_error(self, name: str, spec, exc: BaseException,
                    tb: str, *, kind: str = "error") -> None:
        # flushed immediately, like sweep_run records: a tailing consumer
        # (or a post-mortem) sees the failure the moment the cell dies
        self._append(
            {"kind": "sweep_error", "error_kind": kind, "name": name,
             "spec": spec.to_dict() if hasattr(spec, "to_dict") else spec,
             "error": f"{type(exc).__name__}: {exc}",
             "traceback": tb})

    def write_skipped(self, name: str, result: RunResult) -> None:
        # the per-run file already exists (it is what was verified); the
        # index records the skip so a resumed sweep's index still names
        # every cell of the matrix
        self._append({"kind": "sweep_skip", "name": name,
                      "spec": result.spec, "summary": result.summary})
        with self._lock:
            self.paths.append(os.path.join(self.directory, f"{name}.jsonl"))

    def write_interrupted(self, exc: BaseException) -> None:
        self._append({"kind": "sweep_interrupted",
                      "error": f"{type(exc).__name__}: {exc}"})

    def close(self) -> None:
        with self._lock:
            self._closed = True
            if self._index is not None and not self._index.closed:
                try:
                    self._index.flush()
                finally:
                    self._index.close()


# ---------------------------------------------------------------------------
# Execution: an elastic service with env/trainer reuse across the matrix
# ---------------------------------------------------------------------------

class CellTimeout(RuntimeError):
    """A sweep cell exceeded its wall-clock deadline (run_sweep
    cell_timeout). Deliberately NOT retried: a deterministic cell that
    times out once will time out again, and re-running it just doubles
    the wasted wall-clock."""


class SweepInterrupted(BaseException):
    """The sweep was stopped by SIGTERM / KeyboardInterrupt. A
    BaseException (like KeyboardInterrupt itself) so the per-cell
    `except Exception` retry machinery can never absorb it — an
    interrupt always stops the whole matrix, never burns retries."""


class _DeadlineCallback(Callback):
    """Cooperative per-cell deadline: raises CellTimeout at the next
    materialization point past the deadline. Cooperative because the
    device-resident engines pipeline whole blocks — the check fires at
    round/block boundaries, so a cell can overshoot by at most one
    compiled block, never hang detection mid-sweep."""

    def __init__(self, seconds: float):
        self.deadline = time.monotonic() + float(seconds)
        self.seconds = float(seconds)

    def _check(self) -> None:
        if time.monotonic() > self.deadline:
            raise CellTimeout(
                f"sweep cell exceeded its {self.seconds:g}s wall-clock "
                f"deadline")

    def on_round_end(self, m, trainer) -> None:
        self._check()

    def on_block_end(self, start: int, n_rounds: int, trainer) -> None:
        self._check()


def _env_key(spec: ExperimentSpec) -> str:
    """Runs sharing this key may share one Environment: the data / model
    axes, the wireless channel draw, and the batch baked into Table-I
    bookkeeping. Budgets (e0/t0) and the trainer-level noise / selection
    axes deliberately stay OUT of the key — they vary freely over a
    reused environment (mirrors Experiment.build's env-reuse contract)."""
    w = spec.wireless
    return json.dumps([spec.data.to_dict(), spec.model.to_dict(),
                       w.table, w.path_loss, w.seed, spec.scheme.batch],
                      sort_keys=True)


def _trainer_key(spec: ExperimentSpec) -> str:
    """Runs sharing an environment AND this key may share one trainer
    (reset between runs): everything that shapes the compiled engine or
    the client roster. channel noise is NOT included — it is per-round
    host data, swapped by `reset(channel_noise=...)`."""
    sc, r = spec.scheme, spec.run
    return json.dumps([sc.eta, sc.batch, r.backend, r.shards,
                       r.rounds_per_dispatch, sc.data_selection,
                       sc.data_selection_kwargs, sc.aggregator,
                       sc.aggregator_kwargs, sc.local_scheme, sc.local_steps,
                       sc.local_kwargs, r.client_store,
                       r.device_mem_budget], sort_keys=True)


@dataclasses.dataclass
class SweepResult:
    """Outcome of `run_sweep`: results in matrix order + reuse accounting
    (the env/trainer build counters the acceptance tests assert on).
    A failed cell holds None at its matrix position (so indices line up
    with `cells`) and an error record — {"name", "kind", "error",
    "traceback"} with kind "error" or "timeout" — in `errors`; a sweep
    with any error should exit nonzero (the CLI does). `n_skipped` counts
    cells resume verified and did not re-run (their parsed RunResults sit
    in `results`); `n_worker_crashes` counts workers lost to exceptions
    OUTSIDE the per-cell retry machinery (their in-flight cells were
    requeued on surviving workers)."""

    cells: list[SweepCell]
    results: list[RunResult | None]
    n_env_builds: int
    n_trainer_builds: int
    errors: list[dict] = dataclasses.field(default_factory=list)
    n_skipped: int = 0
    n_worker_crashes: int = 0

    def summary_rows(self) -> list[dict]:
        return [{"name": c.name, **r.summary}
                for c, r in zip(self.cells, self.results) if r is not None]


class _CellRunner:
    """Shared execution state for one run_sweep call: the pending-cell
    queue, the cross-worker environment cache, per-worker trainer pools,
    and lock-serialized sink/log access. One instance is driven either
    serially (workers=1 — today's loop, bit-and-behavior identical) or by
    N daemon worker threads (run_parallel)."""

    def __init__(self, cells: Sequence[SweepCell], *, sink, log, callbacks,
                 max_retries: int, retry_backoff: float,
                 cell_timeout: float | None, interrupt: threading.Event,
                 skipped: dict[int, RunResult]):
        self.cells = list(cells)
        self.sink = sink
        self.log = log
        self.callbacks = list(callbacks)
        self.max_retries = int(max_retries)
        self.retry_backoff = float(retry_backoff)
        self.cell_timeout = cell_timeout
        self.interrupt = interrupt
        self.skipped = dict(skipped)
        self.results: list[RunResult | None] = [None] * len(self.cells)
        self.errors: dict[int, dict] = {}
        self.n_env = 0
        self.n_trainer = 0
        self.n_worker_crashes = 0
        self.n_done = 0
        self.queue = collections.deque(
            i for i in range(len(self.cells)) if i not in self.skipped)
        self._qlock = threading.Lock()
        # serializes sink + log + counter access: custom RunSinks need no
        # thread safety of their own (JsonlDirSink has its own lock too,
        # for direct use), and progress lines never interleave
        self._io = threading.Lock()
        self.envs: dict[str, Environment] = {}
        self._env_locks: dict[str, threading.Lock] = {}
        self._env_master = threading.Lock()

    # -- shared environment cache ------------------------------------------

    def _get_env(self, ek: str, spec: ExperimentSpec) -> Environment:
        """One build per env key, even under N workers: a per-key lock
        makes the second worker of a family wait for (then reuse) the
        first one's build instead of duplicating it."""
        with self._env_master:
            lock = self._env_locks.setdefault(ek, threading.Lock())
        with lock:
            env = self.envs.get(ek)
            if env is None:
                env = build_environment(spec)
                self.envs[ek] = env
                with self._io:
                    self.n_env += 1
            return env

    # -- queue --------------------------------------------------------------

    def _next(self) -> int | None:
        if self.interrupt.is_set():
            return None
        with self._qlock:
            return self.queue.popleft() if self.queue else None

    def _requeue(self, idx: int) -> None:
        with self._qlock:
            self.queue.appendleft(idx)

    # -- per-cell checkpointing (mid-cell elastic resume) -------------------

    def _ckpt_dir(self, cell: SweepCell) -> str | None:
        """The service-managed checkpoint directory for a cell —
        `<sink dir>/ckpt/<cell name>` — active only when the sink is
        directory-backed and the cell's spec opts into checkpointing
        (run.checkpoint_every set) without naming its own directory. The
        cell SPEC is never mutated: the path rides the checkpoint_dir=
        override of Run.run/run_or_resume, so per-run JSONL headers stay
        byte-identical across sink directories and standalone runs."""
        d = getattr(self.sink, "directory", None)
        rs = cell.spec.run
        if not d or rs.checkpoint_dir or not rs.checkpoint_every:
            return None
        return os.path.join(d, "ckpt", cell.name)

    # -- execution ----------------------------------------------------------

    def record_skip(self, idx: int) -> None:
        cell, res = self.cells[idx], self.skipped[idx]
        self.results[idx] = res
        with self._io:
            self.n_done += 1
            if self.sink is not None:
                self.sink.write_skipped(cell.name, res)
            if self.log is not None:
                self.log(f"[{cell.name}] verified complete — "
                         f"skipped (resume)")

    def run_cell(self, idx: int, trainers: dict) -> None:
        """Execute one cell with the retry/backoff/timeout machinery,
        record the outcome, and maintain the caller's (worker-local)
        trainer pool. Raises SweepInterrupted when the sweep is being
        stopped; lets sink failures escape (the worker loop treats those
        as worker crashes and requeues the cell)."""
        cell = self.cells[idx]
        ek = _env_key(cell.spec)
        tk = ek + "\x00" + _trainer_key(cell.spec)
        ckpt_dir = self._ckpt_dir(cell)
        res = last_exc = last_tb = None
        kind = "error"
        for attempt in range(self.max_retries + 1):
            if self.interrupt.is_set():
                raise SweepInterrupted
            if attempt:
                # exponential backoff, jittered to [0.5, 1.5)x
                delay = (self.retry_backoff * 2.0 ** (attempt - 1)
                         * (0.5 + random.random()))
                time.sleep(delay)
            trainer = trainers.get(tk)
            cbs = list(self.callbacks)
            cbs.append(StopOnEvent(self.interrupt, SweepInterrupted))
            if self.cell_timeout is not None:
                cbs.append(_DeadlineCallback(self.cell_timeout))
            try:
                env = self._get_env(ek, cell.spec)
                run = Experiment(cell.spec).build(env=env, trainer=trainer)
                if trainer is None:
                    trainers[tk] = run.trainer
                    with self._io:
                        self.n_trainer += 1
                if ckpt_dir is not None:
                    res = run.run_or_resume(ckpt_dir, callbacks=cbs)
                else:
                    res = run.run(callbacks=cbs)
                break
            except CellTimeout as exc:
                trainers.pop(tk, None)
                last_exc, last_tb = exc, traceback.format_exc()
                kind = "timeout"
                self._log(f"[{cell.name}] timed out: {exc}")
                break
            except SweepInterrupted:
                trainers.pop(tk, None)     # stopped mid-round: state torn
                raise
            except Exception as exc:
                trainers.pop(tk, None)
                last_exc, last_tb = exc, traceback.format_exc()
                kind = "error"
                self._log(f"[{cell.name}] attempt {attempt + 1} failed: "
                          f"{type(exc).__name__}: {exc}")
        if res is None:
            self.errors[idx] = {"name": cell.name, "kind": kind,
                                "error": (f"{type(last_exc).__name__}: "
                                          f"{last_exc}"),
                                "traceback": last_tb}
            with self._io:
                if self.sink is not None:
                    self.sink.write_error(cell.name, cell.spec, last_exc,
                                          last_tb, kind=kind)
            return
        self.results[idx] = res
        if ckpt_dir is not None and os.path.isdir(ckpt_dir):
            # the result is about to be durable in the sink; the cell's
            # resume checkpoints are dead weight (and would shadow a later
            # sweep's same-named cell). Best-effort: a racing cleanup must
            # not fail the cell.
            try:
                CheckpointManager(ckpt_dir).clear()
            except OSError:
                pass
        with self._io:
            # sink first: if the write dies (worker crash, cell requeued
            # and re-run), the done counter hasn't ticked for it yet
            if self.sink is not None:
                self.sink.write(cell.name, res)
            self.n_done += 1
            if self.log is not None:
                s = res.summary
                self.log(f"[{self.n_done}/{len(self.cells)}] {cell.name}: "
                         f"{s['rounds_run']} rounds, acc "
                         f"{s['final_accuracy']:.3f}")

    def _log(self, msg: str) -> None:
        if self.log is not None:
            with self._io:
                self.log(msg)

    def run_serial(self) -> None:
        """Drain the queue in the calling thread (workers=1, and the
        leftover fallback when every worker thread crashed). Exceptions
        escape to the caller — exactly the pre-elastic behavior."""
        trainers: dict = {}
        while True:
            idx = self._next()
            if idx is None:
                return
            self.run_cell(idx, trainers)

    def _worker_main(self) -> None:
        trainers: dict = {}
        while not self.interrupt.is_set():
            idx = self._next()
            if idx is None:
                return
            try:
                self.run_cell(idx, trainers)
            except SweepInterrupted:
                return           # in-flight cell stays un-recorded: resumable
            except BaseException as exc:
                # a failure OUTSIDE the per-cell machinery (e.g. the sink
                # died mid-write): this worker is done — its pooled
                # trainers go with it — but the matrix is not: the
                # in-flight cell is requeued for a surviving worker (or
                # the serial fallback)
                with self._io:
                    self.n_worker_crashes += 1
                    if self.log is not None:
                        self.log(f"worker crashed on "
                                 f"[{self.cells[idx].name}] "
                                 f"({type(exc).__name__}: {exc}); requeued")
                self._requeue(idx)
                return

    def run_parallel(self, workers: int) -> None:
        """Drive the queue with `workers` daemon threads; on return the
        queue is empty or the sweep was interrupted. Cells left behind by
        crashed workers are drained serially in the calling thread (same
        guarantees as workers=1)."""
        with self._qlock:
            n = min(int(workers), len(self.queue))
        threads = [threading.Thread(target=self._worker_main, daemon=True,
                                    name=f"sweep-worker-{i}")
                   for i in range(n)]
        for t in threads:
            t.start()
        for t in threads:
            while t.is_alive():
                try:
                    t.join()
                except KeyboardInterrupt:
                    # Ctrl-C in the main thread: stop cooperatively, keep
                    # joining so no worker outlives the sweep
                    self.interrupt.set()
        self.run_serial()


def _collective_safe(cells: Sequence[SweepCell]) -> bool:
    """True when thread-parallel cell dispatch cannot deadlock. Concurrent
    launches of jitted programs that contain COLLECTIVES over the same
    devices have no cross-thread ordering: two in-flight shard_map psums
    can interleave their per-device programs so the rendezvous never
    completes (observed wedging the forced-4-device CPU leg with
    workers=2 — every thread futex-parked at ~0 CPU). Collective-free
    programs (shards == 1, or the eager reference backend) dispatch
    concurrently fine, so the gate resolves each cell's shard count
    exactly the way its RoundEngine would."""
    from repro.core.round_engine import resolve_shards
    for cell in cells:
        r = cell.spec.run
        if r.backend == "packed" and resolve_shards(r.shards) > 1:
            return False
    return True


def _install_sigterm(interrupt: threading.Event):
    """Install a SIGTERM -> cooperative-stop handler (main thread only —
    Python forbids signal.signal elsewhere, and library callers running
    run_sweep in a thread keep their own handling). Returns the previous
    handler to restore, or None when not installed."""
    if threading.current_thread() is not threading.main_thread():
        return None
    try:
        return signal.signal(signal.SIGTERM,
                             lambda signum, frame: interrupt.set())
    except ValueError:
        return None


def run_sweep(sweep: SweepSpec, *, sink: RunSink | None = None,
              log: Callable[[str], None] | None = None,
              callbacks: Sequence = (), max_retries: int = 0,
              retry_backoff: float = 0.5,
              cell_timeout: float | None = None,
              workers: int = 1, resume: bool = False) -> SweepResult:
    """Execute the full matrix, streaming each RunResult to `sink` as it
    finishes. With `workers=1` (default) cells run serially in matrix
    order — today's behavior, bit-for-bit; `workers=N` runs independent
    cells concurrently (worker-local trainer pools + a shared per-key-
    locked environment cache), which changes no per-run record bits, only
    index completion order and the trainer-build count. When any cell's
    engine would shard_map over more than one device, `workers` caps to 1
    with a log note — concurrent dispatch of collective programs over a
    shared mesh has no cross-thread ordering and can deadlock
    (`_collective_safe`); true multi-device cell parallelism needs
    disjoint mesh slices (ROADMAP follow-up). Environments and
    trainers are pooled by `_env_key` / `_trainer_key`, which preserves
    bit-for-bit equality with standalone runs (reset re-derives every
    piece of run state from the cell's own spec). `callbacks` are passed
    to every run (careful with stateful hooks — one instance sees all
    cells, possibly from several threads).

    Cell failures are ISOLATED: a raising cell is retried up to
    `max_retries` times (for transient failures), sleeping
    `retry_backoff * 2**attempt`, jittered, between attempts so retries
    against a shared resource (filesystem sink, device under contention)
    decorrelate; then recorded — in the sink's index via `write_error`
    and in `SweepResult.errors` — and the rest of the matrix still runs.
    A failed cell's pooled trainer is evicted (the exception may have
    left it mid-round), so retries and later cells build fresh. A crash
    OUTSIDE the cell machinery (e.g. a dying sink) costs one worker: its
    in-flight cell is requeued on the survivors (serially in the main
    thread when none survive, where the failure then surfaces).

    `cell_timeout` (seconds) bounds each cell's wall clock via a
    cooperative deadline checked at round/block materialization points; a
    cell past its deadline raises CellTimeout, is NOT retried
    (deterministic cells time out deterministically), and is recorded
    with kind="timeout".

    `resume=True` asks the sink for previously completed cells
    (`resume_scan` — JsonlDirSink verifies per-run files against the
    sweep_manifest.json spec hashes), emits `write_skipped` for them in
    matrix order, and re-runs only the rest; cells that checkpointed
    mid-run (spec run.checkpoint_every + a directory sink) continue from
    their newest intact step. SIGTERM and KeyboardInterrupt stop all
    workers at the next materialization point, write a
    `sweep_interrupted` sink record, close the sink, and re-raise
    KeyboardInterrupt — a killed sweep is always resumable."""
    cells = sweep.expand()
    workers = int(workers)
    if workers > 1 and not _collective_safe(cells):
        if log is not None:
            log("sweep: engine shard_maps over >1 device — cell workers "
                "serialized (concurrent collective dispatch can deadlock); "
                "running with workers=1")
        workers = 1
    skipped: dict[int, RunResult] = {}
    if resume and sink is not None:
        skipped = sink.resume_scan(cells)
    if sink is not None:
        # after resume_scan: a rejected resume (manifest mismatch) must
        # not have overwritten the old manifest or truncated the index
        sink.begin(cells, resume=resume)
    interrupt = threading.Event()
    runner = _CellRunner(cells, sink=sink, log=log, callbacks=callbacks,
                         max_retries=max_retries,
                         retry_backoff=retry_backoff,
                         cell_timeout=cell_timeout, interrupt=interrupt,
                         skipped=skipped)
    prev_handler = _install_sigterm(interrupt)
    interrupted = False
    try:
        try:
            for idx in sorted(skipped):
                runner.record_skip(idx)
            if workers <= 1:
                runner.run_serial()
            else:
                runner.run_parallel(workers)
        except (KeyboardInterrupt, SweepInterrupted):
            interrupted = True
            interrupt.set()
        interrupted = interrupted or interrupt.is_set()
    finally:
        if prev_handler is not None:
            signal.signal(signal.SIGTERM, prev_handler)
        try:
            if interrupted and sink is not None:
                sink.write_interrupted(
                    KeyboardInterrupt("sweep interrupted"))
        finally:
            if sink is not None:
                sink.close()
    if interrupted:
        raise KeyboardInterrupt(
            "sweep interrupted — completed cells are preserved in the "
            "sink; relaunch with resume to continue")
    return SweepResult(cells=cells, results=runner.results,
                       n_env_builds=runner.n_env,
                       n_trainer_builds=runner.n_trainer,
                       errors=[runner.errors[i]
                               for i in sorted(runner.errors)],
                       n_skipped=len(skipped),
                       n_worker_crashes=runner.n_worker_crashes)
