"""Multi-seed sweep engine over ExperimentSpec templates (DESIGN.md §9).

The paper's claims are statistical — Figs. 4-8 are means over seeds and
over scenario knobs (sigma, budgets, heterogeneity) — so the unit of
reproduction above a single run is a *matrix* of runs. `SweepSpec` takes a
base `ExperimentSpec` template plus axis overrides and expands it into a
deterministic run matrix:

    sweep = SweepSpec(
        base=ExperimentSpec(...),
        seeds=[0, 1, 2],                       # run.seed axis
        schemes=["proposed", "no_gen"],        # scheme.name axis
        grid={"data.sigma": [0.5, 5.0]},       # cartesian over field paths
        zip={"wireless.e0": [2.0, 4.0],        # paths varied in lockstep
             "wireless.t0": [20.0, 40.0]})     # (one composite axis)
    result = run_sweep(sweep, sink=JsonlDirSink("runs/"))

Expansion is pure and deterministic in the spec: axes nest in the order
grid (insertion order) -> zip -> schemes -> seeds, with the later axes
varying fastest, and every cell gets a stable, filename-safe name
(`expand()` twice yields the identical matrix — property-tested). Field
paths are validated against the spec tree; a typo fails with the field
path and the valid keys, like every other spec error.

Execution exploits what single runs cannot: one scheme-independent
`Environment` is built per distinct (data, model, wireless, batch) group
and reused through `Experiment.build(env=...)`, and one `FederatedTrainer`
is pooled per (environment, eta, batch, backend, shards, rounds-per-
dispatch, data-selection) family and re-seeded via `FederatedTrainer.
reset` — its compiled engine traces and device-resident ClientStore
survive across the matrix, so an S-seed sweep costs far less than S cold
runs while every cell stays bit-for-bit equal to the same spec run
standalone (test-asserted). Each finished `RunResult` is streamed to the
sink AS RUNS FINISH (one per-run JSONL file plus an appended, flushed
index record), so long sweeps are observable and interruptible without
losing completed cells.

CLI: `python -m repro.api.cli sweep sweep.json --out-dir DIR`
(`benchmarks/report.py --runs 'DIR/*.jsonl'` aggregates mean±std over the
seed axis).
"""
from __future__ import annotations

import dataclasses
import itertools
import json
import os
import random
import re
import time
import traceback
from typing import Any, Callable, Sequence

from repro.api.callbacks import Callback
from repro.api.experiment import (
    Environment, Experiment, RunResult, build_environment, _json_finite,
)
from repro.api.spec import ExperimentSpec, SpecError, _SpecBase


# ---------------------------------------------------------------------------
# Field-path overrides
# ---------------------------------------------------------------------------

def override_field(spec: ExperimentSpec, path: str, value: Any):
    """Return a copy of `spec` with the dotted `path` (e.g. "data.sigma",
    "scheme.name", "run.backend") replaced by `value`. Unknown segments
    fail with the offending field path and the valid keys at that level —
    sweep axes get the same actionable errors as spec files.

    Dict-valued fields (the `*_kwargs` factory knobs) descend one more
    level: "wireless.fault_kwargs.rate" replaces just that key in a copy
    of the dict — accuracy-vs-dropout-rate is a one-line sweep axis. Dict
    keys are free-form (they are factory kwargs), so a new key is created
    rather than rejected; scalar leaves still refuse to descend."""
    parts = path.split(".")

    def rec(node, i: int):
        where = ".".join([type(spec).__name__] + parts[:i])
        key = parts[i]
        if isinstance(node, dict):
            new = dict(node)
            if i == len(parts) - 1:
                new[key] = value
            else:
                sub = node.get(key, {})
                if not isinstance(sub, dict):
                    raise SpecError(
                        f"{where}: cannot descend into non-dict entry "
                        f"{key!r} with {'.'.join(parts[i + 1:])!r}")
                new[key] = rec(sub, i + 1)
            return new
        if not dataclasses.is_dataclass(node):
            raise SpecError(
                f"{where}: cannot descend into non-spec field with "
                f"{'.'.join(parts[i:])!r}")
        valid = {f.name for f in dataclasses.fields(node)}
        if key not in valid:
            raise SpecError(
                f"{where}: unknown field {key!r} in sweep axis path "
                f"{path!r}; valid keys: {sorted(valid)}")
        if i == len(parts) - 1:
            return dataclasses.replace(node, **{key: value})
        return dataclasses.replace(node,
                                   **{key: rec(getattr(node, key), i + 1)})

    if not path:
        raise SpecError("empty sweep axis path")
    return rec(spec, 0)


def _axis_label(path: str, value: Any) -> str:
    parts = path.split(".")
    # "scheme.name" -> "scheme=...": a bare "name=" label says nothing
    tail = parts[-2] if parts[-1] == "name" and len(parts) > 1 else parts[-1]
    v = value if isinstance(value, (str, int, float, bool)) else \
        json.dumps(value, sort_keys=True)
    return f"{tail}={v}"


def _sanitize(name: str) -> str:
    return re.sub(r"[^A-Za-z0-9_.=+-]+", "-", name)


@dataclasses.dataclass(frozen=True)
class SweepCell:
    """One expanded run: a stable filename-safe name + its full spec."""

    index: int
    name: str
    spec: ExperimentSpec


# ---------------------------------------------------------------------------
# SweepSpec
# ---------------------------------------------------------------------------

@dataclasses.dataclass
class SweepSpec(_SpecBase):
    """A base ExperimentSpec template + axis overrides.

    seeds    run.seed values (the innermost / fastest axis);
    schemes  scheme.name values;
    grid     {field path: [values]} — cartesian product, axes nest in
             insertion order;
    zip      {field path: [values]} — all paths varied in lockstep as ONE
             composite axis (every list must have the same length).

    Empty axes are skipped; with no axes at all the sweep is the single
    base run. Round-trips through dict/JSON like every spec."""

    base: ExperimentSpec = dataclasses.field(default_factory=ExperimentSpec)
    seeds: list = dataclasses.field(default_factory=list)
    schemes: list = dataclasses.field(default_factory=list)
    grid: dict = dataclasses.field(default_factory=dict)
    zip: dict = dataclasses.field(default_factory=dict)

    _NESTED = {"base": ExperimentSpec}

    @classmethod
    def from_file(cls, path: str) -> "SweepSpec":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")
        return path

    # -- expansion ----------------------------------------------------------

    def axes(self) -> list[tuple[tuple[str, ...], list[tuple]]]:
        """The ordered axis list: [(paths, [value-tuples])]. grid axes come
        first (insertion order, one path each), then the zip composite
        (all its paths at once), then schemes, then seeds."""
        axes: list[tuple[tuple[str, ...], list[tuple]]] = []
        for path, values in self.grid.items():
            if not isinstance(values, (list, tuple)) or not values:
                raise SpecError(
                    f"sweep grid axis {path!r} needs a non-empty value "
                    f"list, got {values!r}")
            axes.append(((path,), [(v,) for v in values]))
        if self.zip:
            lens = {p: len(v) for p, v in self.zip.items()}
            if len(set(lens.values())) > 1:
                raise SpecError(
                    f"sweep zip axes must have equal lengths, got {lens}")
            if not next(iter(lens.values())):
                raise SpecError("sweep zip axes need non-empty value lists")
            paths = tuple(self.zip)
            axes.append((paths,
                         [tuple(vals) for vals in zip(*self.zip.values())]))
        if self.schemes:
            axes.append((("scheme.name",), [(s,) for s in self.schemes]))
        if self.seeds:
            axes.append((("run.seed",), [(int(s),) for s in self.seeds]))
        return axes

    def expand(self) -> list[SweepCell]:
        """Materialize the deterministic run matrix. The same template
        always yields the same cells in the same order (itertools.product
        over the ordered axes, later axes fastest)."""
        axes = self.axes()
        # validate every path once up front so a typo fails before any run
        for paths, values in axes:
            for p, v in zip(paths, values[0]):
                override_field(self.base, p, v)
        cells: list[SweepCell] = []
        combos = itertools.product(*[vals for _, vals in axes]) if axes \
            else iter([()])
        for i, combo in enumerate(combos):
            spec = self.base
            labels: list[str] = []
            for (paths, _), vals in zip(axes, combo):
                for p, v in zip(paths, vals):
                    spec = override_field(spec, p, v)
                    labels.append(_axis_label(p, v))
            name = _sanitize("_".join(labels)) if labels else "base"
            cells.append(SweepCell(index=i, name=f"{i:03d}_{name}",
                                   spec=spec))
        return cells


# ---------------------------------------------------------------------------
# Streaming sinks
# ---------------------------------------------------------------------------

class RunSink:
    """Streaming consumer of finished runs: `write(name, result)` is
    called AS EACH RUN FINISHES (never post-sweep), `close()` once after
    the last run. Subclass for custom streaming (DBs, sockets, ...)."""

    def write(self, name: str, result: RunResult) -> None:
        raise NotImplementedError

    def write_error(self, name: str, spec, exc: BaseException,
                    tb: str, *, kind: str = "error") -> None:
        """Called when a cell fails permanently (after retries). `kind` is
        "error" for an exception and "timeout" for a cell that blew its
        wall-clock deadline (run_sweep cell_timeout). Default: ignore —
        sinks that persist (JsonlDirSink) record the failure."""

    def close(self) -> None:
        pass


class JsonlDirSink(RunSink):
    """The standard JSONL sink: each finished run lands as
    `<dir>/<name>.jsonl` (the full RunResult — header + per-round records,
    complete and parseable the moment `write` returns) plus one summary
    record appended AND FLUSHED to `<dir>/sweep.jsonl`, so a running sweep
    can be tailed and a killed one keeps every completed cell.
    `benchmarks/report.py --runs '<dir>/*.jsonl'` ingests the per-run
    files (the index's `sweep_run` records are skipped on ingest)."""

    def __init__(self, directory: str, *, index_name: str = "sweep.jsonl"):
        os.makedirs(directory, exist_ok=True)
        self.directory = directory
        self.paths: list[str] = []
        self.index_path = os.path.join(directory, index_name)
        self._index = open(self.index_path, "w")

    def write(self, name: str, result: RunResult) -> None:
        path = os.path.join(self.directory, f"{name}.jsonl")
        result.to_jsonl(path)
        self.paths.append(path)
        self._index.write(json.dumps(_json_finite(
            {"kind": "sweep_run", "name": name, "spec": result.spec,
             "summary": result.summary}), allow_nan=False) + "\n")
        self._index.flush()

    def write_error(self, name: str, spec, exc: BaseException,
                    tb: str, *, kind: str = "error") -> None:
        # flushed immediately, like sweep_run records: a tailing consumer
        # (or a post-mortem) sees the failure the moment the cell dies
        self._index.write(json.dumps(_json_finite(
            {"kind": "sweep_error", "error_kind": kind, "name": name,
             "spec": spec.to_dict() if hasattr(spec, "to_dict") else spec,
             "error": f"{type(exc).__name__}: {exc}",
             "traceback": tb}), allow_nan=False) + "\n")
        self._index.flush()

    def close(self) -> None:
        if not self._index.closed:
            self._index.close()


# ---------------------------------------------------------------------------
# Execution: env + trainer reuse across the matrix
# ---------------------------------------------------------------------------

class CellTimeout(RuntimeError):
    """A sweep cell exceeded its wall-clock deadline (run_sweep
    cell_timeout). Deliberately NOT retried: a deterministic cell that
    times out once will time out again, and re-running it just doubles
    the wasted wall-clock."""


class _DeadlineCallback(Callback):
    """Cooperative per-cell deadline: raises CellTimeout at the next
    materialization point past the deadline. Cooperative because the
    device-resident engines pipeline whole blocks — the check fires at
    round/block boundaries, so a cell can overshoot by at most one
    compiled block, never hang detection mid-sweep."""

    def __init__(self, seconds: float):
        self.deadline = time.monotonic() + float(seconds)
        self.seconds = float(seconds)

    def _check(self) -> None:
        if time.monotonic() > self.deadline:
            raise CellTimeout(
                f"sweep cell exceeded its {self.seconds:g}s wall-clock "
                f"deadline")

    def on_round_end(self, m, trainer) -> None:
        self._check()

    def on_block_end(self, start: int, n_rounds: int, trainer) -> None:
        self._check()

def _env_key(spec: ExperimentSpec) -> str:
    """Runs sharing this key may share one Environment: the data / model
    axes, the wireless channel draw, and the batch baked into Table-I
    bookkeeping. Budgets (e0/t0) and the trainer-level noise / selection
    axes deliberately stay OUT of the key — they vary freely over a
    reused environment (mirrors Experiment.build's env-reuse contract)."""
    w = spec.wireless
    return json.dumps([spec.data.to_dict(), spec.model.to_dict(),
                       w.table, w.path_loss, w.seed, spec.scheme.batch],
                      sort_keys=True)


def _trainer_key(spec: ExperimentSpec) -> str:
    """Runs sharing an environment AND this key may share one trainer
    (reset between runs): everything that shapes the compiled engine or
    the client roster. channel noise is NOT included — it is per-round
    host data, swapped by `reset(channel_noise=...)`."""
    sc, r = spec.scheme, spec.run
    return json.dumps([sc.eta, sc.batch, r.backend, r.shards,
                       r.rounds_per_dispatch, sc.data_selection,
                       sc.data_selection_kwargs, sc.aggregator,
                       sc.aggregator_kwargs], sort_keys=True)


@dataclasses.dataclass
class SweepResult:
    """Outcome of `run_sweep`: results in matrix order + reuse accounting
    (the env/trainer build counters the acceptance tests assert on).
    A failed cell holds None at its matrix position (so indices line up
    with `cells`) and an error record — {"name", "kind", "error",
    "traceback"} with kind "error" or "timeout" — in `errors`; a sweep
    with any error should exit nonzero (the CLI does)."""

    cells: list[SweepCell]
    results: list[RunResult | None]
    n_env_builds: int
    n_trainer_builds: int
    errors: list[dict] = dataclasses.field(default_factory=list)

    def summary_rows(self) -> list[dict]:
        return [{"name": c.name, **r.summary}
                for c, r in zip(self.cells, self.results) if r is not None]


def run_sweep(sweep: SweepSpec, *, sink: RunSink | None = None,
              log: Callable[[str], None] | None = None,
              callbacks: Sequence = (), max_retries: int = 0,
              retry_backoff: float = 0.5,
              cell_timeout: float | None = None) -> SweepResult:
    """Execute the full matrix, streaming each RunResult to `sink` as it
    finishes. Runs execute in matrix order; environments and trainers are
    pooled by `_env_key` / `_trainer_key`, which preserves bit-for-bit
    equality with standalone runs (reset re-derives every piece of run
    state from the cell's own spec). `callbacks` are passed to every run
    (careful with stateful hooks — one instance sees all cells).

    Cell failures are ISOLATED: a raising cell is retried up to
    `max_retries` times (for transient failures), sleeping
    `retry_backoff * 2**attempt`, jittered, between attempts so retries
    against a shared resource (filesystem sink, device under contention)
    decorrelate; then recorded — in the sink's index via `write_error`
    and in `SweepResult.errors` — and the rest of the matrix still runs.
    A failed cell's pooled trainer is evicted (the exception may have
    left it mid-round), so retries and later cells build fresh.

    `cell_timeout` (seconds) bounds each cell's wall clock via a
    cooperative deadline checked at round/block materialization points; a
    cell past its deadline raises CellTimeout, is NOT retried
    (deterministic cells time out deterministically), and is recorded
    with kind="timeout". KeyboardInterrupt still aborts the sweep."""
    cells = sweep.expand()
    envs: dict[str, Environment] = {}
    trainers: dict[str, Any] = {}
    n_env = n_trainer = 0
    results: list[RunResult | None] = []
    errors: list[dict] = []
    try:
        for cell in cells:
            ek = _env_key(cell.spec)
            tk = ek + "\x00" + _trainer_key(cell.spec)
            res = last_exc = last_tb = None
            kind = "error"
            for attempt in range(int(max_retries) + 1):
                if attempt:
                    # exponential backoff, jittered to [0.5, 1.5)x
                    delay = (float(retry_backoff) * 2.0 ** (attempt - 1)
                             * (0.5 + random.random()))
                    time.sleep(delay)
                trainer = trainers.get(tk)
                cbs = list(callbacks)
                if cell_timeout is not None:
                    cbs.append(_DeadlineCallback(cell_timeout))
                try:
                    env = envs.get(ek)
                    if env is None:
                        env = envs[ek] = build_environment(cell.spec)
                        n_env += 1
                    run = Experiment(cell.spec).build(env=env,
                                                      trainer=trainer)
                    if trainer is None:
                        trainers[tk] = run.trainer
                        n_trainer += 1
                    res = run.run(callbacks=cbs)
                    break
                except CellTimeout as exc:
                    trainers.pop(tk, None)
                    last_exc, last_tb = exc, traceback.format_exc()
                    kind = "timeout"
                    if log is not None:
                        log(f"[{cell.name}] timed out: {exc}")
                    break
                except Exception as exc:
                    trainers.pop(tk, None)
                    last_exc, last_tb = exc, traceback.format_exc()
                    kind = "error"
                    if log is not None:
                        log(f"[{cell.name}] attempt {attempt + 1} failed: "
                            f"{type(exc).__name__}: {exc}")
            results.append(res)
            if res is None:
                errors.append({"name": cell.name, "kind": kind,
                               "error": (f"{type(last_exc).__name__}: "
                                         f"{last_exc}"),
                               "traceback": last_tb})
                if sink is not None:
                    sink.write_error(cell.name, cell.spec, last_exc,
                                     last_tb, kind=kind)
                continue
            if sink is not None:
                sink.write(cell.name, res)
            if log is not None:
                s = res.summary
                log(f"[{len(results)}/{len(cells)}] {cell.name}: "
                    f"{s['rounds_run']} rounds, acc "
                    f"{s['final_accuracy']:.3f}")
    finally:
        if sink is not None:
            sink.close()
    return SweepResult(cells=cells, results=results, n_env_builds=n_env,
                       n_trainer_builds=n_trainer, errors=errors)
