"""Declarative experiment specs (DESIGN.md §8).

One `ExperimentSpec` captures everything the paper's pipeline needs — data
federation, model, wireless system, optimization scheme, and run policy —
as a tree of plain dataclasses that round-trips losslessly through
dict/JSON (`to_dict`/`from_dict`, `to_json`/`from_json`).  String-valued
fields (`data.dataset`, `model.name`, `scheme.name`) are resolved through
the component registries (repro.api.registry) at build time, so new
datasets / models / schemes plug in without touching the pipeline wiring.

The spec is *inert*: constructing one performs no work and imports no
heavyweight machinery.  `repro.api.experiment.Experiment` turns it into a
built `Run`.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Any


class SpecError(ValueError):
    """A spec dict does not match the declared schema."""


def _check_keys(cls, d: dict, where: str) -> None:
    if not isinstance(d, dict):
        raise SpecError(f"{where}: expected a dict, got {type(d).__name__}")
    valid = {f.name for f in dataclasses.fields(cls)}
    unknown = sorted(set(d) - valid)
    if unknown:
        raise SpecError(
            f"{where}: unknown key(s) {unknown}; valid keys: {sorted(valid)}")


class _SpecBase:
    """Shared dict/JSON plumbing. Subclasses set _NESTED for spec-typed
    fields so `from_dict` recurses with per-field error context."""

    _NESTED: dict[str, type] = {}

    def to_dict(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_dict(cls, d: dict, *, _where: str | None = None):
        where = _where or cls.__name__
        _check_keys(cls, d, where)
        kw: dict[str, Any] = {}
        for k, v in d.items():
            sub = cls._NESTED.get(k)
            kw[k] = (sub.from_dict(v, _where=f"{where}.{k}")
                     if sub is not None else v)
        return cls(**kw)

    def to_json(self, *, indent: int | None = 2) -> str:
        return json.dumps(self.to_dict(), indent=indent)

    @classmethod
    def from_json(cls, s: str):
        return cls.from_dict(json.loads(s))


@dataclasses.dataclass
class DataSpec(_SpecBase):
    """The federated data substrate: dataset + Dirichlet(sigma) partition."""

    dataset: str = "synthetic-mnist"   # registry key (repro.api.registry)
    n_clients: int = 10
    sigma: float = 1.0                 # Dirichlet concentration (non-IIDness)
    n_train: int = 4000
    n_test: int = 800
    noise: float = 0.35                # synthetic template-to-noise ratio
    seed: int = 0                      # dataset generation + partition rng


@dataclasses.dataclass
class ModelSpec(_SpecBase):
    """The client model; `kwargs` reach the registered init factory
    (e.g. {"depth": 20} for resnet, {"hidden": 128} for mlp-edge)."""

    name: str = "lenet"                # registry key
    kwargs: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class WirelessSpec(_SpecBase):
    """The wireless edge system (paper Table I) and the run budgets.

    `noise_model` picks a registered aggregation-channel noise model
    (repro.api.registry CHANNEL_NOISE; "none" = the paper's noiseless
    aggregation, "gaussian" = AWGN on the averaged gradient à la Wu et
    al.); `noise_kwargs` reach its factory (e.g. {"std": 1e-3} — the draw
    seed defaults to this spec's `seed`).

    `fault_model` picks a registered client fault model (repro.api.registry
    FAULT_MODELS; "none" = the paper's always-reliable clients, "dropout" /
    "straggler" / "corrupt" / "mixed" = core/faults.py injections);
    `fault_kwargs` reach its factory (e.g. {"rate": 0.2} — the draw seed
    defaults to this spec's `seed`). Like the noise axis it is sweepable:
    accuracy-vs-dropout-rate is a one-line `cli sweep` over
    `wireless.fault_kwargs.rate`."""

    table: str = "auto"                # "mnist" | "cifar10" | "auto" (by dataset)
    e0: float = 4.0                    # energy budget E0 [J]
    t0: float = 40.0                   # delay budget T0 [s]
    path_loss: float = 1e-5
    seed: int = 0                      # Rayleigh channel draw
    noise_model: str = "none"          # registry key (CHANNEL_NOISE)
    noise_kwargs: dict = dataclasses.field(default_factory=dict)
    fault_model: str = "none"          # registry key (FAULT_MODELS)
    fault_kwargs: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class SchemeSpec(_SpecBase):
    """The joint-optimization scheme (P1 / Algorithm 1) and its constants.

    `name` picks one of the registered schemes (the paper's six comparisons
    plus `proposed_exact`); `ao` overrides AOConfig fields on top of the
    scheme's definition (e.g. {"outer_iters": 1} for smoke runs) and
    `bound` overrides BoundConstants fields beyond the ones derived from
    (rounds, batch, eta). `data_selection` picks a registered per-client
    data-selection policy (repro.api.registry DATA_SELECTION; "none",
    "threshold", "fine_grained" — Albaseer-style sample curation applied
    once per run, see core/selection.py) with `data_selection_kwargs`
    reaching its factory (e.g. {"keep_frac": 0.8}).

    `aggregator` picks the server-side reduction of the per-client
    gradient stack (core/aggregators.py AGGREGATORS; "mean" = the paper's
    weighted mean and the bitwise-identical default, "coord_median" /
    "trimmed_mean" / "norm_clip" / "multi_krum" = the Byzantine-robust
    reducers) with `aggregator_kwargs` reaching its factory (e.g.
    {"beta": 0.2}). Sweepable like every other axis — attacker fraction x
    aggregator is a two-axis `cli sweep` (benchmarks/robust_aggregation.py
    runs exactly that grid).

    `local_scheme` picks the client-local update rule between uploads
    (repro.api.registry LOCAL_SCHEMES; "fedavg" = plain local SGD — with
    `local_steps=1` it IS the paper's FedSGD and rides the identical code
    path bit for bit — "fedprox" / "feddyn" = the proximal / dynamic-
    regularizer multi-epoch baselines, core/local.py + DESIGN.md §14);
    `local_steps` is E, the local gradient steps per round, and
    `local_kwargs` reach the scheme factory (e.g. {"mu": 0.01} for
    fedprox, {"alpha": 0.1} for feddyn). Sweepable like every other axis:
    generalization-gap-vs-E is a one-line `cli sweep` over
    `scheme.local_steps`, mu/alpha via `scheme.local_kwargs.mu`."""

    name: str = "proposed"             # registry key
    rounds: int = 60                   # S+1 (schedule length)
    eta: float = 0.1
    batch: int = 32
    ao: dict = dataclasses.field(default_factory=dict)
    bound: dict = dataclasses.field(default_factory=dict)
    data_selection: str = "none"       # registry key (DATA_SELECTION)
    data_selection_kwargs: dict = dataclasses.field(default_factory=dict)
    aggregator: str = "mean"           # registry key (core AGGREGATORS)
    aggregator_kwargs: dict = dataclasses.field(default_factory=dict)
    local_scheme: str = "fedavg"       # registry key (LOCAL_SCHEMES)
    local_steps: int = 1               # E local gradient steps per round
    local_kwargs: dict = dataclasses.field(default_factory=dict)


@dataclasses.dataclass
class RunSpec(_SpecBase):
    """Execution policy: backends, eval cadence, checkpointing.

    `client_store` picks how client data reaches the device on the block
    path: "replicated" = the PR-3 full on-device ClientStore, "streamed" =
    per-block cohort prefetch for fleet-scale populations
    (core/cohort_store.py), "auto" (default) = replicated while the
    estimated store footprint fits `device_mem_budget` (bytes; None = the
    REPRO_DEVICE_MEM_BUDGET env or 1 GiB), streamed beyond it. Streaming
    moves data only — trajectories are bitwise the replicated ones."""

    seed: int = 0                      # trainer batch rng + model init key
    eval_every: int = 10
    evaluate: bool = True              # run test-set eval at the cadence
    stop_on_budget: bool = True        # stop when cumulative E/T pass E0/T0
    backend: str = "packed"            # FederatedTrainer backend
    rounds_per_dispatch: int | str = "auto"
    shards: int | None = None          # client-axis shard count (None = auto)
    client_store: str = "auto"         # "auto" | "replicated" | "streamed"
    device_mem_budget: int | None = None   # bytes; None = env or 1 GiB
    checkpoint_dir: str | None = None
    # rounds between checkpoints; None with a checkpoint_dir set falls
    # back to the eval cadence (a dir alone is a request to checkpoint)
    checkpoint_every: int | None = None


@dataclasses.dataclass
class ExperimentSpec(_SpecBase):
    """The full declarative experiment: data x model x wireless x scheme x run."""

    data: DataSpec = dataclasses.field(default_factory=DataSpec)
    model: ModelSpec = dataclasses.field(default_factory=ModelSpec)
    wireless: WirelessSpec = dataclasses.field(default_factory=WirelessSpec)
    scheme: SchemeSpec = dataclasses.field(default_factory=SchemeSpec)
    run: RunSpec = dataclasses.field(default_factory=RunSpec)

    _NESTED = {"data": DataSpec, "model": ModelSpec, "wireless": WirelessSpec,
               "scheme": SchemeSpec, "run": RunSpec}

    @classmethod
    def from_file(cls, path: str) -> "ExperimentSpec":
        with open(path) as f:
            return cls.from_dict(json.load(f))

    def save(self, path: str) -> str:
        with open(path, "w") as f:
            f.write(self.to_json() + "\n")
        return path
