"""Unified experiment API (DESIGN.md §8): declarative specs, component
registries, run lifecycle hooks, and bit-for-bit resumable runs.

    from repro.api import ExperimentSpec, Experiment
    result = Experiment(ExperimentSpec.from_file("spec.json")).run()

CLI: `python -m repro.api.cli run spec.json` / `resume CKPT_DIR`.
"""
from repro.api.spec import (
    DataSpec, ExperimentSpec, ModelSpec, RunSpec, SchemeSpec, SpecError,
    WirelessSpec,
)
from repro.api.registry import (
    CHANNEL_NOISE, DATA_SELECTION, DATASETS, FAULT_MODELS, MODELS, SCHEMES,
    Registry, register_channel_noise, register_data_selection,
    register_dataset, register_fault_model, register_model, register_scheme,
)
from repro.api.callbacks import (
    Callback, CheckpointCallback, StopOnEvent, load_run_state,
    restore_trainer_state, save_trainer_state,
)
from repro.api.experiment import (
    Environment, Experiment, Run, RunResult, build_environment,
    resume_from_checkpoint,
)
from repro.api.sweep import (
    CellTimeout, JsonlDirSink, RunSink, SweepCell, SweepInterrupted,
    SweepResult, SweepSpec, load_manifest, override_field, run_sweep,
    spec_hash, verify_cell_run, write_manifest,
)

__all__ = [
    "DataSpec", "ModelSpec", "WirelessSpec", "SchemeSpec", "RunSpec",
    "ExperimentSpec", "SpecError",
    "Registry", "MODELS", "DATASETS", "SCHEMES",
    "DATA_SELECTION", "CHANNEL_NOISE", "FAULT_MODELS",
    "register_model", "register_dataset", "register_scheme",
    "register_data_selection", "register_channel_noise",
    "register_fault_model",
    "Callback", "CheckpointCallback", "StopOnEvent",
    "save_trainer_state", "restore_trainer_state", "load_run_state",
    "Environment", "build_environment", "Experiment", "Run", "RunResult",
    "resume_from_checkpoint",
    "SweepSpec", "SweepCell", "SweepResult", "RunSink", "JsonlDirSink",
    "run_sweep", "override_field", "CellTimeout", "SweepInterrupted",
    "spec_hash", "write_manifest", "load_manifest", "verify_cell_run",
]
