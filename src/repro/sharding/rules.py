"""Partition rules: parameter/activation/cache PartitionSpecs per arch.

Logical axes:
  * "batch"  -> ("pod", "data") on the multi-pod mesh, ("data",) single-pod.
  * "model"  -> tensor-parallel axis.

Modes:
  * train: FSDP + TP — every big weight shards its non-TP dim over the batch
    axes (ZeRO-3 style; XLA all-gathers per scanned layer). MoE experts shard
    E over "model" when divisible, else (F->"model", D->"data").
  * serve: TP-first; weights additionally shard over "data" only when a
    single TP shard exceeds the per-device HBM budget (llama-vision-90b,
    arctic, mixtral — DESIGN.md §6). KV caches shard batch over "batch" and
    cache-sequence over "model" when divisible.

Rules are *divisibility-guarded*: a dim that does not divide its mesh axis is
left unsharded (e.g. hymba's 25 heads, granite's 49155 vocab).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig

PyTree = Any


@dataclasses.dataclass(frozen=True)
class MeshAxes:
    batch: tuple[str, ...]   # ("pod","data") or ("data",)
    model: str               # "model"

    @staticmethod
    def from_mesh(mesh: Mesh) -> "MeshAxes":
        names = mesh.axis_names
        batch = tuple(n for n in names if n in ("pod", "data"))
        return MeshAxes(batch=batch, model="model")

    def size(self, mesh: Mesh, axis) -> int:
        if axis is None:
            return 1
        if isinstance(axis, tuple):
            return int(np.prod([mesh.shape[a] for a in axis]))
        return mesh.shape[axis]


def _div(n: int, k: int) -> bool:
    return k > 0 and n % k == 0


@dataclasses.dataclass(frozen=True)
class ShardingPolicy:
    """Resolved per-(arch, mode) policy."""

    mode: str                 # "train" | "serve"
    fsdp: bool                # shard weight non-TP dims over batch axes
    axes: MeshAxes
    mesh: Mesh

    def batch_axis(self):
        return self.axes.batch if self.axes.batch else None

    def batch_size_divisor(self) -> int:
        return self.axes.size(self.mesh, self.axes.batch)

    def model_size(self) -> int:
        return self.axes.size(self.mesh, self.axes.model)


# Per-device HBM budget used to decide serve-time FSDP (bf16 bytes).
HBM_BUDGET_BYTES = 16e9
SERVE_PARAM_BUDGET = 0.5 * HBM_BUDGET_BYTES


def make_policy(cfg: ModelConfig, mesh: Mesh, mode: str) -> ShardingPolicy:
    axes = MeshAxes.from_mesh(mesh)
    if mode == "train":
        fsdp = True
    else:
        from repro.models.transformer import param_count
        tp_bytes = 2 * param_count(cfg) / max(mesh.shape["model"], 1)
        fsdp = tp_bytes > SERVE_PARAM_BUDGET
    return ShardingPolicy(mode=mode, fsdp=fsdp, axes=axes, mesh=mesh)


# ---------------------------------------------------------------------------
# Parameter specs
# ---------------------------------------------------------------------------

def _spec_for(path: str, shape: tuple[int, ...], cfg: ModelConfig,
              pol: ShardingPolicy) -> P:
    """PartitionSpec for one parameter leaf (path is the keystr)."""
    m = pol.axes.model
    msize = pol.model_size()
    baxis = pol.batch_axis()
    bsize = pol.batch_size_divisor()
    pth = path.lower()

    def fsdp_axis(dim: int):
        return baxis if (pol.fsdp and baxis and _div(shape[dim], bsize)) else None

    ndim = len(shape)

    # ---- embeddings / heads ------------------------------------------------
    if "embed" in pth and "pos" not in pth or pth.endswith("['lm_head']"):
        vdim, ddim = (0, 1) if "lm_head" not in pth else (1, 0)
        # embed: [V, D]; lm_head: [D, V]
        if "lm_head" in pth:
            vdim, ddim = 1, 0
        spec = [None] * ndim
        if _div(shape[vdim], msize):
            spec[vdim] = m
        elif _div(shape[ddim], msize):
            spec[ddim] = m
        if pol.fsdp and spec[ddim] is None and baxis and _div(shape[ddim], bsize):
            spec[ddim] = baxis
        return P(*spec)
    if "pos_embed" in pth or "vision_proj" in pth:
        return P()

    # ---- MoE expert weights [L, E, D, F] / [L, E, F, D] --------------------
    if "moe" in pth and any(w in pth for w in ("w_gate", "w_up", "w_down")):
        l_, e_, a_, b_ = 0, 1, 2, 3
        spec = [None] * 4
        if pol.mode == "train" and _div(shape[e_], msize):
            spec[e_] = m                      # expert parallel
            spec[a_] = fsdp_axis(a_)
        else:
            # (F -> model, D -> batch-axes): works for E < model shards and
            # bounds serve memory (DESIGN.md §6)
            f_dim = b_ if "w_down" not in pth else a_
            d_dim = a_ if "w_down" not in pth else b_
            if _div(shape[f_dim], msize):
                spec[f_dim] = m
            if baxis and (pol.fsdp or pol.mode == "serve") and \
                    _div(shape[d_dim], bsize):
                spec[d_dim] = baxis
            if spec == [None] * 4 and _div(shape[e_], msize):
                spec[e_] = m
        return P(*spec)
    if "router" in pth:
        return P()

    # ---- attention projections ---------------------------------------------
    if any(k in pth for k in ("['wq']", "['wk']", "['wv']")):
        spec = [None] * ndim
        if _div(shape[-1], msize):
            spec[-1] = m                      # heads (flattened) -> TP
        spec[-2] = fsdp_axis(ndim - 2)
        return P(*spec)
    if "['wo']" in pth:
        spec = [None] * ndim
        if _div(shape[-2], msize):
            spec[-2] = m
        spec[-1] = fsdp_axis(ndim - 1)
        return P(*spec)
    if any(k in pth for k in ("['bq']", "['bk']", "['bv']")):
        spec = [None] * ndim
        if _div(shape[-1], msize):
            spec[-1] = m
        return P(*spec)

    # ---- MLPs ---------------------------------------------------------------
    if any(k in pth for k in ("w_gate", "w_up", "w_in")):
        spec = [None] * ndim
        if _div(shape[-1], msize):
            spec[-1] = m
        spec[-2] = fsdp_axis(ndim - 2)
        return P(*spec)
    if any(k in pth for k in ("w_down", "w_out")):
        spec = [None] * ndim
        if _div(shape[-2], msize):
            spec[-2] = m
        spec[-1] = fsdp_axis(ndim - 1)
        return P(*spec)
    if "b_in" in pth:
        spec = [None] * ndim
        if _div(shape[-1], msize):
            spec[-1] = m
        return P(*spec)

    # ---- SSM ----------------------------------------------------------------
    if "in_proj" in pth:
        spec = [None] * ndim
        if _div(shape[-1], msize):
            spec[-1] = m
        spec[-2] = fsdp_axis(ndim - 2)
        return P(*spec)
    if "out_proj" in pth:
        spec = [None] * ndim
        if _div(shape[-2], msize):
            spec[-2] = m
        spec[-1] = fsdp_axis(ndim - 1)
        return P(*spec)

    # norms, scalars, conv, gates, biases: replicated
    return P()


def param_specs(cfg: ModelConfig, pol: ShardingPolicy,
                shapes: PyTree | None = None) -> PyTree:
    """PartitionSpec pytree congruent with the parameter pytree."""
    from repro.models.transformer import param_shapes
    shapes = shapes if shapes is not None else param_shapes(cfg)
    flat, treedef = jax.tree_util.tree_flatten_with_path(shapes)
    specs = [
        _spec_for(jax.tree_util.keystr(kp), tuple(leaf.shape), cfg, pol)
        for kp, leaf in flat
    ]
    return jax.tree_util.tree_unflatten(treedef, specs)


def param_shardings(cfg: ModelConfig, pol: ShardingPolicy,
                    shapes: PyTree | None = None) -> PyTree:
    return jax.tree.map(lambda s: NamedSharding(pol.mesh, s),
                        param_specs(cfg, pol, shapes))


# ---------------------------------------------------------------------------
# Batch / cache specs
# ---------------------------------------------------------------------------

def batch_spec(global_batch: int, pol: ShardingPolicy, rank: int = 2) -> P:
    """Tokens/labels [B, S]: shard B over batch axes when divisible."""
    bax = pol.batch_axis()
    if bax and _div(global_batch, pol.batch_size_divisor()):
        return P(bax, *([None] * (rank - 1)))
    return P(*([None] * rank))


def cache_specs(cfg: ModelConfig, pol: ShardingPolicy, cache: PyTree,
                global_batch: int) -> PyTree:
    """KV/SSM cache specs: batch -> batch axes, cache-seq -> model axis."""
    bax = pol.batch_axis()
    bdiv = pol.batch_size_divisor()
    msize = pol.model_size()
    m = pol.axes.model

    def spec(kp, leaf):
        pth = jax.tree_util.keystr(kp).lower()
        shp = leaf.shape
        nd = len(shp)
        s = [None] * nd
        if "scale" in pth:
            # int8-KV scales: [*, B, S, Hkv]
            b_dim, s_dim = nd - 3, nd - 2
            if bax and _div(shp[b_dim], bdiv):
                s[b_dim] = bax
            if _div(shp[s_dim], msize):
                s[s_dim] = m
        elif "'k'" in pth or "'v'" in pth:
            # [*, B, S, Hkv, Dh] (lead dims: layer stacking)
            b_dim, s_dim = nd - 4, nd - 3
            if bax and _div(shp[b_dim], bdiv):
                s[b_dim] = bax
            if _div(shp[s_dim], msize):
                s[s_dim] = m
        elif "ssm" in pth:
            # [L, B, H, P, N]
            b_dim = nd - 4
            if bax and _div(shp[b_dim], bdiv):
                s[b_dim] = bax
        elif "conv" in pth:
            b_dim = nd - 3
            if bax and _div(shp[b_dim], bdiv):
                s[b_dim] = bax
        elif "enc_out" in pth or "vision" in pth:
            if bax and _div(shp[0], bdiv):
                s[0] = bax
            if _div(shp[-1], msize):
                s[-1] = m
        return P(*s)

    flat, treedef = jax.tree_util.tree_flatten_with_path(cache)
    return jax.tree_util.tree_unflatten(
        treedef, [spec(kp, leaf) for kp, leaf in flat])


# ---------------------------------------------------------------------------
# Activation constraint helper (used inside model code when a mesh is active)
# ---------------------------------------------------------------------------

def constrain(x, *axes):
    """with_sharding_constraint by logical axis names.

    axes: one entry per dim — "batch" (-> ("pod","data")), "model", or None.
    Dims that don't divide their mesh axes are left unsharded; no-op outside
    a mesh context."""
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or not mesh.shape:
        return x
    names = mesh.axis_names
    batch = tuple(n for n in names if n in ("pod", "data"))
    bsz = int(np.prod([mesh.shape[a] for a in batch])) if batch else 1
    spec = []
    used_model = used_batch = False
    for dim, ax in enumerate(axes):
        if ax == "batch" and batch and not used_batch \
                and x.shape[dim] % bsz == 0:
            spec.append(batch)
            used_batch = True
        elif ax == "model" and "model" in names and not used_model and \
                x.shape[dim] % mesh.shape["model"] == 0:
            spec.append("model")
            used_model = True
        else:
            spec.append(None)
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x


def constrain_batch_model(x, *, d_threshold: int = 2048):
    """Constrain [B, S, D] activations to P(batch, None, model-if-big).

    The residual stream is always batch-sharded; its feature dim is
    additionally model-sharded for d_model >= d_threshold, bounding per-layer
    activation memory for the 9B-90B archs (DESIGN.md §6). No-op outside a
    mesh context (smoke tests, single-device runs)."""
    mesh = jax.sharding.get_abstract_mesh()
    if mesh is None or not mesh.shape:
        return x
    names = mesh.axis_names
    batch = tuple(n for n in names if n in ("pod", "data"))
    bsz = int(np.prod([mesh.shape[a] for a in batch])) if batch else 1
    m = "model" if "model" in names else None
    spec = [None] * x.ndim
    if batch and x.shape[0] % bsz == 0:
        spec[0] = batch
    if m and x.shape[-1] >= d_threshold and \
            x.shape[-1] % mesh.shape["model"] == 0:
        spec[-1] = m
    try:
        return jax.lax.with_sharding_constraint(x, P(*spec))
    except Exception:
        return x
