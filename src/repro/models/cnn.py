"""The paper's evaluation models: LeNet (MNIST) and ResNet-CIFAR (CIFAR-10).

Functional conv nets over param dicts — used by the FEEL reproduction
(examples/feel_mnist.py, benchmarks/fig*). ResNet depth follows the CIFAR
recipe (depth = 6n+2; ResNet-110 => n=18); a shallower default (ResNet-20)
keeps CPU experiment turnaround sane — depth is a parameter.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init


def _conv_init(key, shape, dtype=jnp.float32):
    fan_in = int(np.prod(shape[:-1]))
    return dense_init(key, fan_in, shape, dtype)


def _conv(x, w, stride=1, padding="SAME"):
    return jax.lax.conv_general_dilated(
        x, w, (stride, stride), padding,
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _conv_im2col(x, w):
    """Stride-1 SAME conv as shifted-slice patches + one GEMM.

    Bit-identical to `_conv` in the forward pass, but much faster on
    XLA:CPU for LeNet-sized channel counts (the generic conv lowering is
    scalar-loop-bound there), and its VJP is pad/slice/GEMM — no
    select-and-scatter. The federated round engine spends its FLOPs here."""
    kh, kw, cin, cout = w.shape
    b, h, wd, _ = x.shape
    # XLA SAME padding: (k-1)//2 low, k//2 high (equal for odd kernels)
    xp = jnp.pad(x, ((0, 0), ((kh - 1) // 2, kh // 2),
                     ((kw - 1) // 2, kw // 2), (0, 0)))
    cols = [xp[:, i:i + h, j:j + wd, :]
            for i in range(kh) for j in range(kw)]
    patches = jnp.concatenate(cols, axis=-1)        # [B, H, W, kh*kw*cin]
    return patches @ w.reshape(kh * kw * cin, cout)


def _max_pool_2x2(x):
    """2x2/stride-2 VALID max pool via reshape (even spatial dims only).

    Equivalent to the reduce_window form; the gradient is an argmax mask
    instead of XLA's slow select-and-scatter path."""
    b, h, w, c = x.shape
    return x.reshape(b, h // 2, 2, w // 2, 2, c).max(axis=(2, 4))


# ---------------------------------------------------------------------------
# LeNet-5 (28x28x1 -> 10)
# ---------------------------------------------------------------------------

def lenet_init(key, *, num_classes: int = 10, in_channels: int = 1):
    ks = jax.random.split(key, 5)
    return {
        "conv1": _conv_init(ks[0], (5, 5, in_channels, 6)),
        "conv2": _conv_init(ks[1], (5, 5, 6, 16)),
        "fc1": dense_init(ks[2], 784, (7 * 7 * 16, 120), jnp.float32),
        "b1": jnp.zeros((120,)),
        "fc2": dense_init(ks[3], 120, (120, 84), jnp.float32),
        "b2": jnp.zeros((84,)),
        "fc3": dense_init(ks[4], 84, (84, num_classes), jnp.float32),
        "b3": jnp.zeros((num_classes,)),
    }


def lenet_apply(params, x):
    x = jax.nn.relu(_conv_im2col(x, params["conv1"]))
    x = _max_pool_2x2(x)
    x = jax.nn.relu(_conv_im2col(x, params["conv2"]))
    x = _max_pool_2x2(x)
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"] + params["b1"])
    x = jax.nn.relu(x @ params["fc2"] + params["b2"])
    return x @ params["fc3"] + params["b3"]


# ---------------------------------------------------------------------------
# ResNet-CIFAR (depth = 6n+2), no batchnorm state: GroupNorm-free scale/shift
# (keeps the model purely functional; the paper's optimization machinery is
# agnostic to the normalization choice)
# ---------------------------------------------------------------------------

def resnet_init(key, *, depth: int = 20, num_classes: int = 10,
                in_channels: int = 3, width: int = 16):
    if (depth - 2) % 6:
        raise ValueError("CIFAR ResNet depth must be 6n+2")
    n = (depth - 2) // 6
    ks = iter(jax.random.split(key, 1000))
    params: dict = {"stem": _conv_init(next(ks), (3, 3, in_channels, width))}
    chans = [width, 2 * width, 4 * width]
    blocks = []
    c_in = width
    for stage, c_out in enumerate(chans):
        for b in range(n):
            stride = 2 if (stage > 0 and b == 0) else 1
            # stride is derivable in apply: 2 iff in/out channels differ
            blk = {
                "conv1": _conv_init(next(ks), (3, 3, c_in, c_out)),
                "conv2": _conv_init(next(ks), (3, 3, c_out, c_out)),
                "scale1": jnp.ones((c_out,)), "bias1": jnp.zeros((c_out,)),
                "scale2": jnp.ones((c_out,)), "bias2": jnp.zeros((c_out,)),
            }
            if stride != 1 or c_in != c_out:
                blk["proj"] = _conv_init(next(ks), (1, 1, c_in, c_out))
            blocks.append(blk)
            c_in = c_out
    params["blocks"] = blocks
    params["head"] = dense_init(next(ks), chans[-1], (chans[-1], num_classes),
                                jnp.float32)
    params["head_b"] = jnp.zeros((num_classes,))
    return params


def _norm_act(x, scale, bias):
    mu = x.mean(axis=(1, 2), keepdims=True)
    var = x.var(axis=(1, 2), keepdims=True)
    return jax.nn.relu((x - mu) / jnp.sqrt(var + 1e-5) * scale + bias)


def resnet_apply(params, x):
    x = _conv(x, params["stem"])
    for blk in params["blocks"]:
        stride = 2 if blk["conv1"].shape[2] != blk["conv1"].shape[3] else 1
        h = _norm_act(_conv(x, blk["conv1"], stride=stride),
                      blk["scale1"], blk["bias1"])
        h = _conv(h, blk["conv2"])
        sc = _conv(x, blk["proj"], stride=stride) if "proj" in blk else x
        x = jax.nn.relu(_norm_act(h, blk["scale2"], blk["bias2"]) + sc)
    x = x.mean(axis=(1, 2))
    return x @ params["head"] + params["head_b"]


# ---------------------------------------------------------------------------
# mlp-edge: a two-layer MLP (~100k params) over flattened images. The
# dispatch-bound edge model: one round is cheap enough that the per-round
# host overhead the block engine removes is a measurable fraction of the
# round — the regime real accelerators put any of these models in. Promoted
# from benchmarks/round_engine.py so the experiment API can register it.
# ---------------------------------------------------------------------------

def mlp_edge_init(key, *, hidden: int = 128, num_classes: int = 10,
                  in_dim: int = 784):
    k1, k2 = jax.random.split(key)
    return {"fc1": jax.random.normal(k1, (in_dim, hidden)) * 0.05,
            "b1": jnp.zeros((hidden,)),
            "fc2": jax.random.normal(k2, (hidden, num_classes)) * 0.05,
            "b2": jnp.zeros((num_classes,))}


def mlp_edge_apply(params, x):
    x = x.reshape(x.shape[0], -1)
    x = jax.nn.relu(x @ params["fc1"] + params["b1"])
    return x @ params["fc2"] + params["b2"]


# ---------------------------------------------------------------------------
# Shared loss / eval helpers
# ---------------------------------------------------------------------------

def make_loss_fn(apply_fn):
    def loss(params, x, y):
        logits = apply_fn(params, x)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return (lse - gold).mean()
    # per-sample-weighted companion: FederatedTrainer picks this up so
    # ragged client batches (fewer samples than the batch size) can be
    # padded and stay on the packed round path (core/federated.py)
    loss.weighted = make_weighted_loss_fn(apply_fn)
    return loss


def make_weighted_loss_fn(apply_fn):
    """Mean CE with per-sample weights: sum(sw * ce) / sum(sw).

    With sw = 1 everywhere this is bit-identical to `make_loss_fn`'s plain
    mean (1.0*ce is exact, the reductions share shape and order, and the
    divisor sum(ones) == B exactly), so the packed engine can thread sample
    weights unconditionally. Zero-weight samples (the padding of a ragged
    client batch) are exactly dropped from both the value and the gradient;
    the result is the plain mean over the real samples, evaluated at the
    padded shape — both trainer backends use this same function for ragged
    clients, which is what makes them bit-for-bit comparable (XLA
    reassociates reductions per *shape*, so a mean over [B'] and a masked
    mean over [B] agree in exact arithmetic but not in fp32)."""
    def loss(params, x, y, sw):
        logits = apply_fn(params, x)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, y[:, None], axis=-1)[:, 0]
        return jnp.sum((lse - gold) * sw) / jnp.sum(sw)
    return loss


def make_eval_fn(apply_fn, x_test, y_test, batch: int = 500):
    x_test = jnp.asarray(x_test)
    y_test = jnp.asarray(y_test)

    @jax.jit
    def _batch_eval(params, xb, yb):
        logits = apply_fn(params, xb)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, yb[:, None], axis=-1)[:, 0]
        acc = (logits.argmax(-1) == yb).mean()
        return (lse - gold).mean(), acc

    def eval_fn(params):
        losses, accs = [], []
        for i in range(0, len(y_test), batch):
            l, a = _batch_eval(params, x_test[i:i + batch], y_test[i:i + batch])
            losses.append(float(l))
            accs.append(float(a))
        return float(np.mean(losses)), float(np.mean(accs))

    return eval_fn
