"""Attention: GQA, causal/bidirectional, sliding-window, softcap, KV cache.

Three execution paths (selected by `impl`):
  * "naive":   materializes [Sq, Skv] scores — smoke tests / tiny shapes only.
  * "chunked": flash-style online-softmax over KV chunks under lax.scan —
               O(chunk) live memory; the default for 32k+ contexts. A sliding
               window uses a *banded* dynamic-slice so FLOPs scale with
               S * (window + chunk), not S^2.
  * "pallas":  the Pallas TPU kernel (repro/kernels/flash_attention.py);
               falls back to interpret mode off-TPU.

All functions take q [B,Sq,Hq,D], k/v [B,Skv,Hkv,D] with Hq a multiple of
Hkv (GQA); outputs [B,Sq,Hq,D].
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init, softcap

NEG_INF = -2.0**30  # large-but-finite: avoids NaNs for fully-masked rows


# ---------------------------------------------------------------------------
# Parameters
# ---------------------------------------------------------------------------

def attention_params(key, cfg, *, stacked: int = 0, cross: bool = False) -> dict:
    ks = jax.random.split(key, 4)
    lead = (stacked,) if stacked else ()
    dtype = jnp.dtype(cfg.dtype)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    p = {
        "wq": dense_init(ks[0], d, (*lead, d, qd), dtype),
        "wk": dense_init(ks[1], d, (*lead, d, kvd), dtype),
        "wv": dense_init(ks[2], d, (*lead, d, kvd), dtype),
        "wo": dense_init(ks[3], qd, (*lead, qd, d), dtype),
    }
    if cfg.qkv_bias and not cross:
        p["bq"] = jnp.zeros((*lead, qd), dtype)
        p["bk"] = jnp.zeros((*lead, kvd), dtype)
        p["bv"] = jnp.zeros((*lead, kvd), dtype)
    return p


def project_qkv(x, p, cfg, kv_x=None):
    """x -> q [B,S,Hq,D], k/v [B,Skv,Hkv,D]."""
    kv_src = x if kv_x is None else kv_x
    q = jnp.einsum("bsd,de->bse", x, p["wq"])
    k = jnp.einsum("bsd,de->bse", kv_src, p["wk"])
    v = jnp.einsum("bsd,de->bse", kv_src, p["wv"])
    if "bq" in p:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    b = x.shape[0]
    q = q.reshape(b, -1, cfg.num_heads, cfg.head_dim)
    k = k.reshape(b, -1, cfg.num_kv_heads, cfg.head_dim)
    v = v.reshape(b, -1, cfg.num_kv_heads, cfg.head_dim)
    return q, k, v


def output_proj(o, p):
    b, s = o.shape[:2]
    return jnp.einsum("bse,ed->bsd", o.reshape(b, s, -1), p["wo"])


# ---------------------------------------------------------------------------
# Naive reference
# ---------------------------------------------------------------------------

def naive_attention(
    q, k, v, *, causal: bool = True, window: int = 0, cap: float = 0.0,
    q_offset: int | jnp.ndarray = 0, kv_len: jnp.ndarray | None = None,
):
    """Materialized-scores attention. q_offset: absolute position of q[0]
    (decode: cache position). kv_len: number of valid cache entries."""
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    qr = q.reshape(b, sq, hkv, g, d)
    scores = jnp.einsum("bqhgd,bkhd->bhgqk", qr.astype(jnp.float32),
                        k.astype(jnp.float32)) / np.sqrt(d)
    scores = softcap(scores, cap)
    qpos = jnp.arange(sq)[:, None] + q_offset          # [sq, 1]
    kpos = jnp.arange(skv)[None, :]                    # [1, skv]
    mask = jnp.ones((sq, skv), dtype=bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    if kv_len is not None:
        mask &= kpos < kv_len
    scores = jnp.where(mask[None, None, None], scores, NEG_INF)
    w = jax.nn.softmax(scores, axis=-1)
    o = jnp.einsum("bhgqk,bkhd->bqhgd", w, v.astype(jnp.float32))
    return o.reshape(b, sq, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Chunked (flash-style) attention
# ---------------------------------------------------------------------------

def _online_block(qc, kc, vc, m, l, acc, mask, cap, scale):
    """One online-softmax update. qc [B,C,Hkv,G,D]; kc/vc [B,Ck,Hkv,D]."""
    s = jnp.einsum("bqhgd,bkhd->bhgqk", qc.astype(jnp.float32),
                   kc.astype(jnp.float32)) * scale
    s = softcap(s, cap)
    s = jnp.where(mask[None, None, None], s, NEG_INF)
    m_new = jnp.maximum(m, s.max(axis=-1))
    p = jnp.exp(s - m_new[..., None])
    corr = jnp.exp(m - m_new)
    l_new = l * corr + p.sum(axis=-1)
    acc_new = acc * corr[..., None] + jnp.einsum(
        "bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32))
    return m_new, l_new, acc_new


def chunked_attention(
    q, k, v, *, causal: bool = True, window: int = 0, cap: float = 0.0,
    q_chunk: int = 512, kv_chunk: int = 512,
):
    """Online-softmax attention, O(chunk^2) live scores.

    window > 0 uses a banded gather: each q chunk attends to one contiguous
    KV slice of length `window + q_chunk` -> total FLOPs O(S*(W+C)).
    """
    if window and not causal:
        raise ValueError("sliding windows are causal by definition")
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = 1.0 / np.sqrt(d)
    q_chunk = min(q_chunk, sq)
    kv_chunk = min(kv_chunk, skv)
    if sq % q_chunk or skv % kv_chunk:
        raise ValueError(f"seq lens ({sq},{skv}) must divide chunks "
                         f"({q_chunk},{kv_chunk})")
    nq = sq // q_chunk
    qs = jnp.moveaxis(q.reshape(b, nq, q_chunk, hkv, g, d), 1, 0)

    if window:
        band = window + q_chunk
        band = min(band, skv)

        def q_body(_, xs):
            qc, qi = xs
            q_start = qi * q_chunk
            start = jnp.clip(q_start + q_chunk - band, 0, skv - band)
            kc = jax.lax.dynamic_slice_in_dim(k, start, band, axis=1)
            vc = jax.lax.dynamic_slice_in_dim(v, start, band, axis=1)
            qpos = q_start + jnp.arange(q_chunk)[:, None]
            kpos = start + jnp.arange(band)[None, :]
            mask = (kpos > qpos - window) & ((kpos <= qpos) if causal
                                             else jnp.ones_like(kpos, bool))
            m = jnp.full((b, hkv, g, q_chunk), NEG_INF, jnp.float32)
            l = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
            acc = jnp.zeros((b, hkv, g, q_chunk, d), jnp.float32)
            m, l, acc = _online_block(qc, kc, vc, m, l, acc, mask, cap, scale)
            o = acc / jnp.maximum(l[..., None], 1e-20)
            return None, o

        _, os_ = jax.lax.scan(q_body, None, (qs, jnp.arange(nq)))
    else:
        nk = skv // kv_chunk
        ks = jnp.moveaxis(k.reshape(b, nk, kv_chunk, hkv, d), 1, 0)
        vs = jnp.moveaxis(v.reshape(b, nk, kv_chunk, hkv, d), 1, 0)

        def q_body(_, xs):
            qc, qi = xs
            qpos = qi * q_chunk + jnp.arange(q_chunk)[:, None]

            def kv_body(carry, kv_xs):
                kc, vc, ki = kv_xs
                m, l, acc = carry
                kpos = ki * kv_chunk + jnp.arange(kv_chunk)[None, :]
                mask = (kpos <= qpos) if causal else jnp.ones(
                    (q_chunk, kv_chunk), bool)
                return _online_block(qc, kc, vc, m, l, acc, mask, cap, scale), None

            m0 = jnp.full((b, hkv, g, q_chunk), NEG_INF, jnp.float32)
            l0 = jnp.zeros((b, hkv, g, q_chunk), jnp.float32)
            a0 = jnp.zeros((b, hkv, g, q_chunk, d), jnp.float32)
            (m, l, acc), _ = jax.lax.scan(
                kv_body, (m0, l0, a0), (ks, vs, jnp.arange(nk)))
            o = acc / jnp.maximum(l[..., None], 1e-20)
            return None, o

        _, os_ = jax.lax.scan(q_body, None, (qs, jnp.arange(nq)))

    # os_: [nq, B, Hkv, G, C, D] -> [B, S, Hq, D]
    o = jnp.moveaxis(os_, 0, 1)                       # [B, nq, Hkv, G, C, D]
    o = jnp.moveaxis(o, 4, 2)                         # [B, nq, C, Hkv, G, D]
    return o.reshape(b, sq, hq, d).astype(q.dtype)


def chunked_attention_causal_skip(
    q, k, v, *, cap: float = 0.0, q_chunk: int = 512, kv_chunk: int = 512,
):
    """Causal chunked attention that SKIPS the upper-triangle blocks.

    The plain nested scan (chunked_attention) visits all nq*nk chunk pairs
    and masks — paying 2x the causal FLOPs. Here the scan runs over only the
    nq(nq+1)/2 pairs with ki <= qi, carrying online-softmax state for every
    q chunk ([nq, ...] accumulators). EXPERIMENTS.md §Perf, prefill compute
    iteration; ~1.8x wall-clock on attention-dominated prefill.
    """
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    if sq != skv:
        raise ValueError("triangle skip assumes self-attention (sq == skv)")
    g = hq // hkv
    scale = 1.0 / np.sqrt(d)
    c = min(q_chunk, kv_chunk, sq)
    if sq % c:
        raise ValueError(f"seq {sq} must divide chunk {c}")
    n = sq // c
    qs = jnp.moveaxis(q.reshape(b, n, c, hkv, g, d), 1, 0)
    ks = jnp.moveaxis(k.reshape(b, n, c, hkv, d), 1, 0)
    vs = jnp.moveaxis(v.reshape(b, n, c, hkv, d), 1, 0)

    pair_q, pair_k = np.tril_indices(n)

    def body(carry, pair):
        m, l, acc = carry
        qi, ki = pair
        qc = qs[qi]
        kc, vc = ks[ki], vs[ki]
        qpos = qi * c + jnp.arange(c)[:, None]
        kpos = ki * c + jnp.arange(c)[None, :]
        mask = kpos <= qpos
        mi, li, acci = m[qi], l[qi], acc[qi]
        mi, li, acci = _online_block(qc, kc, vc, mi, li, acci, mask, cap,
                                     scale)
        return (m.at[qi].set(mi), l.at[qi].set(li), acc.at[qi].set(acci)), None

    m0 = jnp.full((n, b, hkv, g, c), NEG_INF, jnp.float32)
    l0 = jnp.zeros((n, b, hkv, g, c), jnp.float32)
    a0 = jnp.zeros((n, b, hkv, g, c, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(
        body, (m0, l0, a0),
        (jnp.asarray(pair_q, jnp.int32), jnp.asarray(pair_k, jnp.int32)))
    o = acc / jnp.maximum(l[..., None], 1e-20)     # [n, B, hkv, g, c, D]
    o = jnp.moveaxis(o, 0, 1)                      # [B, n, hkv, g, c, D]
    o = jnp.moveaxis(o, 4, 2)                      # [B, n, c, hkv, g, D]
    return o.reshape(b, sq, hq, d).astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode (single-token) attention against a KV cache
# ---------------------------------------------------------------------------

def decode_attention(
    q, cache_k, cache_v, pos, *, window: int = 0, cap: float = 0.0,
    k_scale=None, v_scale=None,
):
    """q [B,1,Hq,D]; cache [B,Smax,Hkv,D]; pos: scalar count of valid entries
    (the new token's k/v must already be written at index pos-1).

    With a window, only the last `window` cache entries are read
    (O(window) per token — enables long_500k for SWA archs)."""
    if window:
        smax = cache_k.shape[1]
        w = min(window, smax)
        start = jnp.clip(pos - w, 0, smax - w)
        kc = jax.lax.dynamic_slice_in_dim(cache_k, start, w, axis=1)
        vc = jax.lax.dynamic_slice_in_dim(cache_v, start, w, axis=1)
        kpos = start + jnp.arange(w)
        valid = (kpos < pos) & (kpos >= pos - w)
        return _decode_core(q, kc, vc, valid, cap)
    kpos = jnp.arange(cache_k.shape[1])
    return _decode_core(q, cache_k, cache_v, kpos < pos, cap,
                        k_scale=k_scale, v_scale=v_scale)


def ring_slots(pos, window: int, seq: int | None = None):
    """Absolute positions held by each ring-buffer slot when the write head
    is at `pos` (the token at `pos` has just been written at pos % window).

    slot i holds absolute position: the largest p <= pos with p % window == i.
    Slots that would be negative are invalid (cache not yet full).
    """
    i = jnp.arange(window)
    head = pos % window
    abs_pos = pos - ((head - i) % window)
    return abs_pos  # [window]; invalid where < 0


def decode_attention_ring(q, cache_k, cache_v, pos, *, cap: float = 0.0):
    """Decode against a ring-buffer sliding-window cache of size `window`.

    cache_k/v: [B, W, Hkv, D] with the token at `pos` already written at
    slot pos % W. Attends to every valid slot (abs position in
    [pos-W+1, pos])."""
    w = cache_k.shape[1]
    abs_pos = ring_slots(pos, w)
    valid = abs_pos >= 0
    return _decode_core(q, cache_k, cache_v, valid, cap)


def fill_ring(k: jnp.ndarray, window: int) -> jnp.ndarray:
    """Arrange the last `window` entries of k [B,S,...] into ring order so
    that slot p % window holds position p. Left-pads when S < window."""
    s = k.shape[1]
    if s >= window:
        tail = k[:, s - window:]
    else:
        pad = jnp.zeros((k.shape[0], window - s, *k.shape[2:]), k.dtype)
        tail = jnp.concatenate([pad, k], axis=1)
    return jnp.roll(tail, s % window, axis=1)


def _decode_core(q, k, v, valid, cap, *, k_scale=None, v_scale=None):
    """k/v may be int8 with per-(B,S,H) f32 scales (quantized KV cache);
    the dequant converts fuse into the dots — no bf16 cache materializes."""
    b, _, hq, d = q.shape
    hkv = k.shape[2]
    g = hq // hkv
    qr = q.reshape(b, hkv, g, d)
    s = jnp.einsum("bhgd,bkhd->bhgk", qr.astype(jnp.float32),
                   k.astype(jnp.float32)) / np.sqrt(d)
    if k_scale is not None:  # [B, S, Hkv] -> [B, Hkv, 1, S]
        s = s * jnp.moveaxis(k_scale, 1, 2)[:, :, None, :]
    s = softcap(s, cap)
    s = jnp.where(valid[None, None, None], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    if v_scale is not None:
        w = w * jnp.moveaxis(v_scale, 1, 2)[:, :, None, :]
    o = jnp.einsum("bhgk,bkhd->bhgd", w, v.astype(jnp.float32))
    return o.reshape(b, 1, hq, d).astype(q.dtype)


def quantize_kv(x: jnp.ndarray) -> tuple[jnp.ndarray, jnp.ndarray]:
    """[..., H, D] bf16 -> (int8 values, f32 scale over D)."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.maximum(amax, 1e-6) / 127.0
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale[..., None]),
                 -127, 127).astype(jnp.int8)
    return q, scale


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------

def attend(
    q, k, v, *, impl: str = "chunked", causal: bool = True, window: int = 0,
    cap: float = 0.0, q_chunk: int = 512, kv_chunk: int = 512,
):
    if impl == "naive" or q.shape[1] <= max(q_chunk, 128) // 4:
        return naive_attention(q, k, v, causal=causal, window=window, cap=cap)
    if impl == "pallas":
        from repro.kernels import ops as kops
        return kops.flash_attention(q, k, v, causal=causal, window=window,
                                    cap=cap)
    if impl == "flash_vjp":
        from repro.models.flash_vjp import chunked_attention_vjp
        return chunked_attention_vjp(q, k, v, causal=causal, window=window,
                                     cap=cap, q_chunk=q_chunk,
                                     kv_chunk=kv_chunk)
    if impl == "chunked_skip" and causal and not window \
            and q.shape[1] == k.shape[1]:
        return chunked_attention_causal_skip(q, k, v, cap=cap,
                                             q_chunk=q_chunk,
                                             kv_chunk=kv_chunk)
    return chunked_attention(q, k, v, causal=causal, window=window, cap=cap,
                             q_chunk=q_chunk, kv_chunk=kv_chunk)
