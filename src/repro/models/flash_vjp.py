"""Memory-optimal chunked attention with a hand-written (flash) backward.

jax.lax.scan's autodiff of the online-softmax forward saves every [BQ, BK]
probability block — O(S^2) f32 residuals, ~4 GB/layer for train_4k (measured
in the yi-9b dry-run; EXPERIMENTS.md §Perf). This custom_vjp saves only
(q, k, v, o, lse) and recomputes p blockwise in the backward, exactly like
the FlashAttention-2 backward:

    D  = rowsum(dO ∘ O)
    p  = exp(s - lse)
    dv += pᵀ dO ;  dp = dO vᵀ ;  ds = p ∘ (dp - D)
    dq += ds k scale ;  dk += dsᵀ q scale

Supports GQA, causal, sliding-window (banded), and softcap (tanh chain rule).
Enabled via Runtime(flash_vjp=True); numerically validated against the
autodiff reference in tests/test_attention.py.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

NEG_INF = -2.0**30


def _blk(x, n, c):
    """[B, S, ...] -> [n, B, c, ...]."""
    b = x.shape[0]
    return jnp.moveaxis(x.reshape(b, n, c, *x.shape[2:]), 1, 0)


def _mask(q_start, k_start, bq, bk, causal, window):
    qpos = q_start + jnp.arange(bq)[:, None]
    kpos = k_start + jnp.arange(bk)[None, :]
    m = jnp.ones((bq, bk), bool)
    if causal:
        m &= kpos <= qpos
    if window:
        m &= kpos > qpos - window
    return m


def _fwd_scan(q, k, v, causal, window, cap, bq, bk):
    """Returns o [B,Sq,Hq,D] and lse [B,hkv,g,Sq] (log-sum-exp per row)."""
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = 1.0 / np.sqrt(d)
    nq, nk = sq // bq, skv // bk
    qs = _blk(q.reshape(b, sq, hkv, g, d), nq, bq)
    ks = _blk(k, nk, bk)
    vs = _blk(v, nk, bk)

    def q_body(_, xs):
        qc, qi = xs

        def kv_body(carry, kv_xs):
            kc, vc, ki = kv_xs
            m, l, acc = carry
            s = jnp.einsum("bqhgd,bkhd->bhgqk", qc.astype(jnp.float32),
                           kc.astype(jnp.float32)) * scale
            if cap:
                s = cap * jnp.tanh(s / cap)
            msk = _mask(qi * bq, ki * bk, bq, bk, causal, window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            m_new = jnp.maximum(m, s.max(axis=-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            return (m_new, l * corr + p.sum(-1),
                    acc * corr[..., None] + jnp.einsum(
                        "bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32))), None

        m0 = jnp.full((b, hkv, g, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hkv, g, bq), jnp.float32)
        a0 = jnp.zeros((b, hkv, g, bq, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_body, (m0, l0, a0),
                                      (ks, vs, jnp.arange(nk)))
        o = acc / jnp.maximum(l[..., None], 1e-20)
        lse = m + jnp.log(jnp.maximum(l, 1e-20))
        return None, (o, lse)

    _, (os_, lses) = jax.lax.scan(q_body, None, (qs, jnp.arange(nq)))
    o = jnp.moveaxis(os_, 0, 1)          # [B,nq,hkv,g,bq,d]
    o = jnp.moveaxis(o, 4, 2).reshape(b, sq, hq, d).astype(q.dtype)
    # lses [nq,B,hkv,g,bq] -> [B,hkv,g,nq,bq] -> [B,hkv,g,Sq]
    lse = jnp.moveaxis(lses, 0, 3).reshape(b, hkv, g, sq)
    return o, lse


def _bwd_scan(res, do, causal, window, cap, bq, bk):
    q, k, v, o, lse = res
    b, sq, hq, d = q.shape
    skv, hkv = k.shape[1], k.shape[2]
    g = hq // hkv
    scale = 1.0 / np.sqrt(d)
    nq, nk = sq // bq, skv // bk

    do4 = do.reshape(b, sq, hkv, g, d).astype(jnp.float32)
    o4 = o.reshape(b, sq, hkv, g, d).astype(jnp.float32)
    delta = jnp.moveaxis((do4 * o4).sum(-1), 1, -1)          # [B,hkv,g,Sq]

    qs = _blk(q.reshape(b, sq, hkv, g, d), nq, bq)
    dos = _blk(do.reshape(b, sq, hkv, g, d), nq, bq)
    ks = _blk(k, nk, bk)
    vs = _blk(v, nk, bk)
    lse_b = jnp.moveaxis(lse.reshape(b, hkv, g, nq, bq), 3, 0)   # [nq,B,h,g,bq]
    delta_b = jnp.moveaxis(delta.reshape(b, hkv, g, nq, bq), 3, 0)

    def q_body(carry, xs):
        dk_acc, dv_acc = carry
        qc, doc, lsec, dc, qi = xs

        def kv_body(inner, kv_xs):
            dq_c, dk_a, dv_a = inner
            kc, vc, ki = kv_xs
            s_raw = jnp.einsum("bqhgd,bkhd->bhgqk", qc.astype(jnp.float32),
                               kc.astype(jnp.float32)) * scale
            if cap:
                s = cap * jnp.tanh(s_raw / cap)
            else:
                s = s_raw
            msk = _mask(qi * bq, ki * bk, bq, bk, causal, window)
            s = jnp.where(msk[None, None, None], s, NEG_INF)
            p = jnp.exp(s - lsec[..., None])              # [B,h,g,bq,bk]
            dv_blk = jnp.einsum("bhgqk,bqhgd->bkhd", p,
                                doc.astype(jnp.float32))
            dp = jnp.einsum("bqhgd,bkhd->bhgqk",
                            doc.astype(jnp.float32), vc.astype(jnp.float32))
            ds = p * (dp - dc[..., None])
            if cap:
                ds = ds * (1.0 - jnp.square(s / cap))
            ds = jnp.where(msk[None, None, None], ds, 0.0)
            dq_c = dq_c + jnp.einsum("bhgqk,bkhd->bqhgd", ds,
                                     kc.astype(jnp.float32)) * scale
            dk_blk = jnp.einsum("bhgqk,bqhgd->bkhd", ds,
                                qc.astype(jnp.float32)) * scale
            dk_a = dk_a.at[ki].add(dk_blk)
            dv_a = dv_a.at[ki].add(dv_blk)
            return (dq_c, dk_a, dv_a), None

        dq0 = jnp.zeros((b, bq, hkv, g, d), jnp.float32)
        (dq_c, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_body, (dq0, dk_acc, dv_acc), (ks, vs, jnp.arange(nk)))
        return (dk_acc, dv_acc), dq_c

    dk0 = jnp.zeros((nk, b, bk, hkv, d), jnp.float32)
    dv0 = jnp.zeros((nk, b, bk, hkv, d), jnp.float32)
    (dk_s, dv_s), dqs = jax.lax.scan(
        q_body, (dk0, dv0), (qs, dos, lse_b, delta_b, jnp.arange(nq)))
    dq = jnp.moveaxis(dqs, 0, 1).reshape(b, sq, hq, d).astype(q.dtype)
    dk = jnp.moveaxis(dk_s, 0, 1).reshape(b, skv, hkv, d).astype(k.dtype)
    dv = jnp.moveaxis(dv_s, 0, 1).reshape(b, skv, hkv, d).astype(v.dtype)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7))
def flash_chunked(q, k, v, causal, window, cap, bq, bk):
    o, _ = _fwd_scan(q, k, v, causal, window, cap, bq, bk)
    return o


def _fwd(q, k, v, causal, window, cap, bq, bk):
    o, lse = _fwd_scan(q, k, v, causal, window, cap, bq, bk)
    return o, (q, k, v, o, lse)


def _bwd(causal, window, cap, bq, bk, res, do):
    return _bwd_scan(res, do, causal, window, cap, bq, bk)


flash_chunked.defvjp(_fwd, _bwd)


def chunked_attention_vjp(q, k, v, *, causal=True, window=0, cap=0.0,
                          q_chunk=512, kv_chunk=512):
    """Drop-in for attention.chunked_attention with O(S) backward memory."""
    sq, skv = q.shape[1], k.shape[1]
    bq = min(q_chunk, sq)
    bk = min(kv_chunk, skv)
    if sq % bq or skv % bk:
        raise ValueError(f"seq lens ({sq},{skv}) must divide chunks")
    return flash_chunked(q, k, v, causal, window, cap, bq, bk)
