"""The unified language model: embed -> scanned blocks -> norm -> LM head.

One code path per architecture family, all using lax.scan over stacked layer
weights (compile-time O(1) in depth — essential for 512-device dry-runs).

Public API:
    init_params(key, cfg)                  -> params pytree
    forward(params, tokens, cfg, rt, ...)  -> logits [B,S,V]
    loss_fn(params, tokens, labels, ...)   -> scalar CE (chunked over vocab)
    init_cache(cfg, batch, max_seq)        -> decode cache pytree
    prefill(params, tokens, cache, ...)    -> (last-token logits, cache)
    decode_step(params, token, cache, pos) -> (logits [B,V], cache)
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import blocks as B
from repro.models import ssm as ssm_lib
from repro.models.blocks import Runtime
from repro.models.layers import embed_init, rms_norm, layer_norm, softcap
from repro.sharding.rules import constrain_batch_model

PyTree = Any


# ---------------------------------------------------------------------------
# Parameter construction
# ---------------------------------------------------------------------------

def init_params(key: jax.Array, cfg: ModelConfig) -> PyTree:
    ks = jax.random.split(key, 8)
    dtype = jnp.dtype(cfg.dtype)
    p: dict[str, Any] = {
        "embed": embed_init(ks[0], (cfg.vocab_size, cfg.d_model), dtype),
        "final_norm": jnp.zeros((cfg.d_model,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        p["lm_head"] = embed_init(ks[1], (cfg.d_model, cfg.vocab_size), dtype)

    fam = cfg.family
    if fam == "dense" and cfg.local_global:
        half = cfg.num_layers // 2
        p["blocks"] = {
            "local": B.dense_block_params(ks[2], cfg, stacked=half),
            "global": B.dense_block_params(ks[3], cfg, stacked=half),
        }
    elif fam == "dense":
        p["blocks"] = B.dense_block_params(ks[2], cfg, stacked=cfg.num_layers)
    elif fam == "moe":
        p["blocks"] = B.moe_block_params(ks[2], cfg, stacked=cfg.num_layers)
    elif fam == "ssm":
        p["blocks"] = B.ssm_block_params(ks[2], cfg, stacked=cfg.num_layers)
    elif fam == "hybrid":
        p["blocks"] = B.hybrid_block_params(ks[2], cfg, stacked=cfg.num_layers)
    elif fam == "audio":
        p["pos_embed"] = embed_init(ks[4], (cfg.max_seq, cfg.d_model), dtype)
        p["enc_pos_embed"] = embed_init(ks[5], (cfg.encoder_tokens, cfg.d_model),
                                        dtype)
        p["enc_blocks"] = B.encoder_block_params(ks[2], cfg,
                                                 stacked=cfg.encoder_layers)
        p["enc_final_s"] = jnp.ones((cfg.d_model,), jnp.float32)
        p["enc_final_b"] = jnp.zeros((cfg.d_model,), jnp.float32)
        p["blocks"] = B.cross_block_params(ks[3], cfg, stacked=cfg.num_layers,
                                           self_attn=True, use_layernorm=True)
        p["final_b"] = jnp.zeros((cfg.d_model,), jnp.float32)
    elif fam == "vlm":
        k_every = cfg.cross_attn_every
        n_groups = cfg.num_layers // k_every
        n_self = n_groups * (k_every - 1)
        self_p = B.dense_block_params(ks[2], cfg, stacked=n_self)
        self_p = jax.tree.map(
            lambda a: a.reshape(n_groups, k_every - 1, *a.shape[1:]), self_p)
        p["blocks"] = {
            "self": self_p,
            "cross": B.cross_block_params(ks[3], cfg, stacked=n_groups,
                                          self_attn=False, use_layernorm=False),
        }
        p["vision_proj"] = embed_init(ks[6], (cfg.d_model, cfg.d_model), dtype)
    else:
        raise ValueError(f"unknown family {fam}")
    return p


def param_shapes(cfg: ModelConfig) -> PyTree:
    """Abstract (ShapeDtypeStruct) params — no allocation; for dry-runs."""
    return jax.eval_shape(lambda: init_params(jax.random.key(0), cfg))


def param_count(cfg: ModelConfig) -> int:
    shapes = param_shapes(cfg)
    return sum(int(np.prod(l.shape)) for l in jax.tree.leaves(shapes))


def active_param_count(cfg: ModelConfig) -> int:
    """MoE: params touched per token (top-k experts instead of all)."""
    total = param_count(cfg)
    if not cfg.num_experts:
        return total
    shapes = param_shapes(cfg)
    expert = 0
    for path, leaf in jax.tree_util.tree_flatten_with_path(shapes)[0]:
        ks = jax.tree_util.keystr(path)
        if any(s in ks for s in ("w_gate", "w_up", "w_down")) and "moe" in ks:
            expert += int(np.prod(leaf.shape))
    inactive = expert * (1 - cfg.experts_per_token / cfg.num_experts)
    return int(total - inactive)


# ---------------------------------------------------------------------------
# Caches
# ---------------------------------------------------------------------------

def _kv_cache(cfg, batch, max_seq, dtype, lead=(), quant=False):
    if quant:
        # int8 values + per-(B, S, H) f32 scales (~0.53x bf16 bytes)
        return {
            "k": jnp.zeros((*lead, batch, max_seq, cfg.num_kv_heads,
                            cfg.head_dim), jnp.int8),
            "v": jnp.zeros((*lead, batch, max_seq, cfg.num_kv_heads,
                            cfg.head_dim), jnp.int8),
            "k_scale": jnp.zeros((*lead, batch, max_seq, cfg.num_kv_heads),
                                 jnp.float32),
            "v_scale": jnp.zeros((*lead, batch, max_seq, cfg.num_kv_heads),
                                 jnp.float32),
        }
    return {
        "k": jnp.zeros((*lead, batch, max_seq, cfg.num_kv_heads, cfg.head_dim),
                       dtype),
        "v": jnp.zeros((*lead, batch, max_seq, cfg.num_kv_heads, cfg.head_dim),
                       dtype),
    }


def init_cache(cfg: ModelConfig, batch: int, max_seq: int,
               *, swa_only: bool = False, kv_quant: bool = False) -> PyTree:
    """Decode cache. Sliding-window layers keep ring buffers of `window`
    slots (attention.ring_slots semantics); full layers keep max_seq slots
    (optionally int8-quantized with kv_quant — full-attention layers only;
    ring caches are already window-bounded). `swa_only` must match
    Runtime.swa_only (gemma2 long-context variant)."""
    dtype = jnp.dtype(cfg.dtype)
    fam = cfg.family
    eff = lambda w: min(max_seq, w) if w else max_seq

    if fam == "dense" and cfg.local_global:
        half = cfg.num_layers // 2
        w = cfg.sliding_window or 4096
        glob = eff(w) if swa_only else max_seq
        return {
            "local": _kv_cache(cfg, batch, eff(w), dtype, (half,)),
            "global": _kv_cache(cfg, batch, glob, dtype, (half,),
                                quant=kv_quant and not swa_only),
        }
    if fam == "dense":
        return _kv_cache(cfg, batch, eff(cfg.sliding_window), dtype,
                         (cfg.num_layers,),
                         quant=kv_quant and not cfg.sliding_window)
    if fam == "moe":
        return _kv_cache(cfg, batch, eff(cfg.sliding_window), dtype,
                         (cfg.num_layers,),
                         quant=kv_quant and not cfg.sliding_window)
    if fam == "ssm":
        per = ssm_lib.init_ssm_cache(cfg, batch, dtype)
        return jax.tree.map(
            lambda a: jnp.broadcast_to(a, (cfg.num_layers, *a.shape)).copy(), per)
    if fam == "hybrid":
        per_ssm = ssm_lib.init_ssm_cache(cfg, batch, dtype)
        return {
            "attn": _kv_cache(cfg, batch, eff(cfg.sliding_window), dtype,
                              (cfg.num_layers,)),
            "ssm": jax.tree.map(
                lambda a: jnp.broadcast_to(
                    a, (cfg.num_layers, *a.shape)).copy(), per_ssm),
        }
    if fam == "audio":
        c = _kv_cache(cfg, batch, max_seq, dtype, (cfg.num_layers,))
        c["enc_out"] = jnp.zeros((batch, cfg.encoder_tokens, cfg.d_model), dtype)
        return c
    if fam == "vlm":
        k_every = cfg.cross_attn_every
        n_groups = cfg.num_layers // k_every
        c = _kv_cache(cfg, batch, max_seq, dtype, (n_groups, k_every - 1))
        c["vision"] = jnp.zeros((batch, cfg.vision_tokens, cfg.d_model), dtype)
        return c
    raise ValueError(fam)


# ---------------------------------------------------------------------------
# Forward core: scanned layer stacks (shared by train / prefill / decode)
# ---------------------------------------------------------------------------

def _maybe_remat(fn, rt):
    return jax.checkpoint(fn) if rt.remat else fn


def _run_stack(x, params, cfg, rt, *, cache=None, pos=None, enc=None):
    """Run the whole layer stack. Returns (hidden, new_cache, aux_loss)."""
    fam = cfg.family
    aux_total = jnp.zeros((), jnp.float32)

    if fam == "dense" and cfg.local_global:
        def pair_body(carry, xs):
            h = constrain_batch_model(carry)
            (pl, pg), (cl, cgl) = xs
            h, cl2 = B.dense_block(h, pl, cfg, rt, kind=0, cache=cl, pos=pos)
            h, cg2 = B.dense_block(h, pg, cfg, rt, kind=1, cache=cgl, pos=pos)
            return h, (cl2, cg2)

        caches = (None, None) if cache is None else (cache["local"],
                                                     cache["global"])
        xs = ((params["blocks"]["local"], params["blocks"]["global"]), caches)
        x, newc = jax.lax.scan(_maybe_remat(pair_body, rt), x, xs)
        new_cache = None if cache is None else {"local": newc[0],
                                                "global": newc[1]}
        return x, new_cache, aux_total

    if fam in ("dense", "ssm", "hybrid"):
        block_fn = {"dense": B.dense_block, "ssm": B.ssm_block,
                    "hybrid": B.hybrid_block}[fam]

        if cache is None:
            x, _ = jax.lax.scan(
                _maybe_remat(
                    lambda h, bp: block_fn(constrain_batch_model(h), bp, cfg,
                                           rt), rt),
                x, params["blocks"])
            return x, None, aux_total

        def body(carry, xs):
            h = constrain_batch_model(carry)
            bp, c = xs
            h, c2 = block_fn(h, bp, cfg, rt, cache=c, pos=pos)
            return h, c2

        x, newc = jax.lax.scan(_maybe_remat(body, rt), x,
                               (params["blocks"], cache))
        return x, newc, aux_total

    if fam == "moe":
        def body_nc(h, bp):
            h, (_, aux) = B.moe_block(constrain_batch_model(h), bp, cfg, rt)
            return h, aux

        def body(carry, xs):
            h, auxc = carry
            h = constrain_batch_model(h)
            bp, c = xs
            h, (c2, aux) = B.moe_block(h, bp, cfg, rt, cache=c, pos=pos)
            return (h, auxc + aux), c2

        if cache is None:
            x, auxs = jax.lax.scan(_maybe_remat(body_nc, rt), x,
                                   params["blocks"])
            return x, None, auxs.sum()
        (x, aux_total), newc = jax.lax.scan(
            _maybe_remat(body, rt), (x, aux_total), (params["blocks"], cache))
        return x, newc, aux_total

    if fam == "audio":
        def body(carry, xs):
            h = constrain_batch_model(carry)
            bp, c = xs
            sc = None if c is None else c
            h, c2 = B.cross_block(h, bp, cfg, rt, enc=enc, cache=sc, pos=pos,
                                  use_gelu_mlp=True)
            return h, c2

        if cache is None:
            x, _ = jax.lax.scan(
                _maybe_remat(
                    lambda h, bp: B.cross_block(h, bp, cfg, rt, enc=enc), rt),
                x, params["blocks"])
            return x, None, aux_total
        layer_cache = {"k": cache["k"], "v": cache["v"]}
        x, newc = jax.lax.scan(_maybe_remat(body, rt), x,
                               (params["blocks"], layer_cache))
        new_cache = dict(cache)
        new_cache.update(newc)
        return x, new_cache, aux_total

    if fam == "vlm":
        k_every = cfg.cross_attn_every

        def group_body(carry, xs):
            h = constrain_batch_model(carry)
            (sp, cp), sc = xs

            def self_body(hh, inner):
                bp, c = inner
                hh, c2 = B.dense_block(hh, bp, cfg, rt, cache=c, pos=pos)
                return hh, c2

            if sc is None:
                h, _ = jax.lax.scan(
                    lambda hh, bp: B.dense_block(hh, bp, cfg, rt), h, sp)
                newsc = None
            else:
                h, newsc = jax.lax.scan(self_body, h, (sp, sc))
            h, _ = B.cross_block(h, cp, cfg, rt, enc=enc, gated=True,
                                 use_gelu_mlp=False)
            return h, newsc

        blocks = params["blocks"]
        if cache is None:
            x, _ = jax.lax.scan(
                _maybe_remat(lambda h, xs: group_body(h, (xs, None)), rt),
                x, (blocks["self"], blocks["cross"]))
            return x, None, aux_total
        sc = {"k": cache["k"], "v": cache["v"]}
        x, newsc = jax.lax.scan(
            _maybe_remat(group_body, rt), x,
            ((blocks["self"], blocks["cross"]), sc))
        new_cache = dict(cache)
        new_cache.update(newsc)
        return x, new_cache, aux_total

    raise ValueError(fam)


def _encode(params, enc_input, cfg, rt):
    """Whisper encoder over stub frame embeddings [B, T, D]."""
    x = constrain_batch_model(
        enc_input + params["enc_pos_embed"][None, : enc_input.shape[1]])
    x, _ = jax.lax.scan(
        _maybe_remat(lambda h, bp: (B.encoder_block(h, bp, cfg, rt), None), rt),
        x, params["enc_blocks"])
    return layer_norm(x, params["enc_final_s"], params["enc_final_b"],
                      cfg.norm_eps)


def _embed_tokens(params, tokens, cfg, *, pos0=0):
    x = params["embed"][tokens]
    if cfg.embed_scale:
        # scale in the residual dtype: a f32 scalar would upcast the entire
        # residual stream (gemma2: +10 GB/device of f32 carries)
        x = x * jnp.asarray(np.sqrt(cfg.d_model), x.dtype)
    if cfg.family == "audio":
        s = tokens.shape[1]
        x = x + jax.lax.dynamic_slice_in_dim(params["pos_embed"], pos0, s,
                                             axis=0)[None]
    return x


def _final_hidden(x, params, cfg):
    if cfg.family == "audio":
        return layer_norm(x, 1.0 + params["final_norm"], params["final_b"],
                          cfg.norm_eps)
    return rms_norm(x, params["final_norm"], cfg.norm_eps)


def _head(params, cfg):
    return params["embed"].T if cfg.tie_embeddings else params["lm_head"]


def _extra_enc(params, cfg, rt, extra, cache=None):
    """Resolve the cross-attention memory (encoder out / vision tokens)."""
    if cfg.family == "audio":
        if cache is not None and extra is None:
            return cache["enc_out"]
        return _encode(params, extra["encoder_input"], cfg, rt)
    if cfg.family == "vlm":
        if cache is not None and extra is None:
            return cache["vision"]
        v = extra["vision_embeddings"]
        return jnp.einsum("bnd,de->bne", v, params["vision_proj"])
    return None


# ---------------------------------------------------------------------------
# Public entry points
# ---------------------------------------------------------------------------

def forward(params, tokens, cfg: ModelConfig, rt: Runtime = Runtime(),
            extra: dict | None = None) -> jnp.ndarray:
    """Full-sequence logits [B, S, V] (small vocabs / smoke only)."""
    enc = _extra_enc(params, cfg, rt, extra)
    x = _embed_tokens(params, tokens, cfg)
    x, _, _ = _run_stack(x, params, cfg, rt, enc=enc)
    h = _final_hidden(x, params, cfg)
    logits = jnp.einsum("bsd,dv->bsv", h, _head(params, cfg))
    return softcap(logits.astype(jnp.float32), cfg.final_softcap)


def loss_fn(params, tokens, labels, cfg: ModelConfig, rt: Runtime = Runtime(),
            extra: dict | None = None, *, aux_weight: float = 0.01):
    """Mean next-token CE, computed in sequence chunks so the [B,S,V] logits
    tensor is never materialized (vocab up to 256k; DESIGN.md §6)."""
    enc = _extra_enc(params, cfg, rt, extra)
    x = constrain_batch_model(_embed_tokens(params, tokens, cfg))
    x, _, aux = _run_stack(x, params, cfg, rt, enc=enc)
    h = constrain_batch_model(_final_hidden(x, params, cfg))
    head = _head(params, cfg)

    bsz, s, d = h.shape
    c = min(rt.loss_chunk, s)
    if s % c:
        c = s  # fallback: no chunking on ragged seqs (smoke sizes)
    nch = s // c
    hc = jnp.moveaxis(h.reshape(bsz, nch, c, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(bsz, nch, c), 1, 0)

    @jax.checkpoint  # recompute chunk logits in backward: never hold [B,c,V]
    def chunk_ce(carry, xs):
        hh, ll = xs
        hh = constrain_batch_model(hh)
        logits = jnp.einsum("bcd,dv->bcv", hh, head).astype(jnp.float32)
        logits = constrain_batch_model(logits, d_threshold=1)
        logits = softcap(logits, cfg.final_softcap)
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, ll[..., None], axis=-1)[..., 0]
        return carry + (lse - gold).sum(), None

    total, _ = jax.lax.scan(chunk_ce, jnp.zeros((), jnp.float32), (hc, lc))
    loss = total / (bsz * s)
    return loss + aux_weight * aux


def prefill(params, tokens, cache, cfg: ModelConfig, rt: Runtime = Runtime(),
            extra: dict | None = None):
    """Process the prompt, fill the KV cache, return last-token logits."""
    enc = _extra_enc(params, cfg, rt, extra)
    new_cache = cache
    if cfg.family == "audio" and extra is not None:
        new_cache = dict(cache)
        new_cache["enc_out"] = enc.astype(cache["enc_out"].dtype)
        cache = new_cache
    if cfg.family == "vlm" and extra is not None:
        new_cache = dict(cache)
        new_cache["vision"] = enc.astype(cache["vision"].dtype)
        cache = new_cache
    x = _embed_tokens(params, tokens, cfg)
    x, new_cache, _ = _run_stack(x, params, cfg, rt, cache=cache, enc=enc)
    h = _final_hidden(x[:, -1:], params, cfg)
    logits = jnp.einsum("bsd,dv->bsv", h, _head(params, cfg))[:, 0]
    return softcap(logits.astype(jnp.float32), cfg.final_softcap), new_cache


def decode_step(params, token, cache, pos, cfg: ModelConfig,
                rt: Runtime = Runtime()):
    """One serving step: token [B,1] at position `pos` -> (logits [B,V], cache)."""
    enc = _extra_enc(params, cfg, rt, None, cache=cache)
    x = _embed_tokens(params, token, cfg, pos0=pos)
    x, new_cache, _ = _run_stack(x, params, cfg, rt, cache=cache, pos=pos,
                                 enc=enc)
    h = _final_hidden(x, params, cfg)
    logits = jnp.einsum("bsd,dv->bsv", h, _head(params, cfg))[:, 0]
    return softcap(logits.astype(jnp.float32), cfg.final_softcap), new_cache
