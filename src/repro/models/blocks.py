"""Per-family transformer blocks (param builders + apply fns).

Every block fn has the signature

    y, new_kv = block(x, params, cfg, rt, *, layer_kind, cache=None, pos=None,
                      cross_kv=None)

where `cache` is this block's KV dict for decode ({"k","v"} of shape
[B, Smax, Hkv, Dh]) and `pos` the number of valid cache entries. In prefill
mode (cache provided, S > 1) the block writes its fresh K/V into the cache.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import attention as attn
from repro.models import moe as moe_lib
from repro.models import ssm as ssm_lib
from repro.models.layers import (
    gated_mlp, gated_mlp_params, mlp, mlp_params, rms_norm, layer_norm,
)

PyTree = Any


@dataclasses.dataclass(frozen=True)
class Runtime:
    """Execution knobs (not architecture): attention impl, chunking, remat."""

    attn_impl: str = "chunked"       # naive | chunked | flash_vjp | pallas
    q_chunk: int = 512
    kv_chunk: int = 512
    loss_chunk: int = 512            # vocab CE sequence chunking
    remat: bool = False              # activation checkpointing over layers
    swa_only: bool = False           # long-context variant (gemma2, DESIGN §5)


# ---------------------------------------------------------------------------
# Attention sub-block (shared by all families with attention)
# ---------------------------------------------------------------------------

def attn_apply(x, p, cfg, rt: Runtime, *, window: int, cache=None, pos=None,
               kv_x=None, causal=True, positions=None, impl=None):
    """Returns (attn_out [B,S,D], updated_cache)."""
    b, s, _ = x.shape
    q, k, v = attn.project_qkv(x, p, cfg, kv_x=kv_x)
    decode = cache is not None and s == 1
    if positions is None:
        if decode:
            positions = jnp.full((b, 1), pos, dtype=jnp.int32)
        else:
            positions = jnp.broadcast_to(jnp.arange(q.shape[1]), (b, q.shape[1]))
    if cfg.rope_theta and kv_x is None:  # no RoPE on cross-attention
        from repro.models.layers import apply_rope
        q = apply_rope(q, positions, cfg.rope_theta)
        k = apply_rope(
            k, positions if not decode else jnp.full((b, 1), pos, jnp.int32),
            cfg.rope_theta)
    new_cache = cache
    # Sliding-window layers use ring-buffer caches sized min(window, max_seq)
    # (attention.ring_slots); full-attention layers use positional caches,
    # optionally int8-quantized ("k_scale" present — §Perf decode iteration).
    quant = cache is not None and "k_scale" in cache
    if decode:
        if window:
            w = cache["k"].shape[1]
            slot = pos % w
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, slot, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, slot, 0, 0))
            o = attn.decode_attention_ring(q, ck, cv, pos,
                                           cap=cfg.attn_softcap)
            new_cache = {"k": ck, "v": cv}
        elif quant:
            k8, ks_ = attn.quantize_kv(k)
            v8, vs_ = attn.quantize_kv(v)
            ck = jax.lax.dynamic_update_slice(cache["k"], k8, (0, pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(cache["v"], v8, (0, pos, 0, 0))
            cks = jax.lax.dynamic_update_slice(cache["k_scale"], ks_,
                                               (0, pos, 0))
            cvs = jax.lax.dynamic_update_slice(cache["v_scale"], vs_,
                                               (0, pos, 0))
            o = attn.decode_attention(q, ck, cv, pos + 1, window=0,
                                      cap=cfg.attn_softcap,
                                      k_scale=cks, v_scale=cvs)
            new_cache = {"k": ck, "v": cv, "k_scale": cks, "v_scale": cvs}
        else:
            ck = jax.lax.dynamic_update_slice(
                cache["k"], k.astype(cache["k"].dtype), (0, pos, 0, 0))
            cv = jax.lax.dynamic_update_slice(
                cache["v"], v.astype(cache["v"].dtype), (0, pos, 0, 0))
            o = attn.decode_attention(q, ck, cv, pos + 1, window=0,
                                      cap=cfg.attn_softcap)
            new_cache = {"k": ck, "v": cv}
    else:
        if cache is not None:  # prefill: persist K/V
            if window:
                w = cache["k"].shape[1]
                ck = attn.fill_ring(k.astype(cache["k"].dtype), w)
                cv = attn.fill_ring(v.astype(cache["v"].dtype), w)
                new_cache = {"k": ck, "v": cv}
            elif quant:
                k8, ks_ = attn.quantize_kv(k)
                v8, vs_ = attn.quantize_kv(v)
                upd = lambda c, x: jax.lax.dynamic_update_slice(
                    c, x, (0,) * c.ndim)
                new_cache = {"k": upd(cache["k"], k8),
                             "v": upd(cache["v"], v8),
                             "k_scale": upd(cache["k_scale"], ks_),
                             "v_scale": upd(cache["v_scale"], vs_)}
            else:
                ck = jax.lax.dynamic_update_slice(
                    cache["k"], k.astype(cache["k"].dtype), (0, 0, 0, 0))
                cv = jax.lax.dynamic_update_slice(
                    cache["v"], v.astype(cache["v"].dtype), (0, 0, 0, 0))
                new_cache = {"k": ck, "v": cv}
        o = attn.attend(q, k, v, impl=impl or rt.attn_impl, causal=causal,
                        window=window, cap=cfg.attn_softcap,
                        q_chunk=rt.q_chunk, kv_chunk=rt.kv_chunk)
    return attn.output_proj(o, p), new_cache


def layer_window(cfg, rt: Runtime, kind: int) -> int:
    """Effective sliding window for a layer. kind: 0 = local/SW, 1 = global."""
    if cfg.local_global:
        if kind == 0:
            return cfg.sliding_window or 4096
        return (cfg.sliding_window or 4096) if rt.swa_only else 0
    return cfg.sliding_window


# ---------------------------------------------------------------------------
# Dense block (llama/yi/qwen/granite/gemma2 layer)
# ---------------------------------------------------------------------------

def dense_block_params(key, cfg, *, stacked: int = 0) -> dict:
    ks = jax.random.split(key, 2)
    lead = (stacked,) if stacked else ()
    p = {
        "attn": attn.attention_params(ks[0], cfg, stacked=stacked),
        "mlp": gated_mlp_params(ks[1], cfg.d_model, cfg.d_ff,
                                jnp.dtype(cfg.dtype), stacked=stacked),
        "norm_attn": jnp.zeros((*lead, cfg.d_model), jnp.float32),
        "norm_mlp": jnp.zeros((*lead, cfg.d_model), jnp.float32),
    }
    if cfg.attn_softcap or cfg.local_global:  # gemma2 style post-norms
        p["postnorm_attn"] = jnp.zeros((*lead, cfg.d_model), jnp.float32)
        p["postnorm_mlp"] = jnp.zeros((*lead, cfg.d_model), jnp.float32)
    return p


def dense_block(x, p, cfg, rt, *, kind=0, cache=None, pos=None):
    h, new_cache = attn_apply(rms_norm(x, p["norm_attn"], cfg.norm_eps),
                              p["attn"], cfg, rt,
                              window=layer_window(cfg, rt, kind),
                              cache=cache, pos=pos)
    if "postnorm_attn" in p:
        h = rms_norm(h, p["postnorm_attn"], cfg.norm_eps)
    x = x + h
    h = gated_mlp(rms_norm(x, p["norm_mlp"], cfg.norm_eps), p["mlp"])
    if "postnorm_mlp" in p:
        h = rms_norm(h, p["postnorm_mlp"], cfg.norm_eps)
    return x + h, new_cache


# ---------------------------------------------------------------------------
# MoE block (mixtral / arctic)
# ---------------------------------------------------------------------------

def moe_block_params(key, cfg, *, stacked: int = 0) -> dict:
    ks = jax.random.split(key, 3)
    lead = (stacked,) if stacked else ()
    p = {
        "attn": attn.attention_params(ks[0], cfg, stacked=stacked),
        "moe": moe_lib.moe_params(ks[1], cfg, stacked=stacked),
        "norm_attn": jnp.zeros((*lead, cfg.d_model), jnp.float32),
        "norm_ffn": jnp.zeros((*lead, cfg.d_model), jnp.float32),
    }
    if cfg.dense_residual_ff:  # arctic parallel dense MLP
        import dataclasses as _dc
        dense_cfg_ff = cfg.dense_residual_ff
        p["dense_mlp"] = gated_mlp_params(ks[2], cfg.d_model, dense_cfg_ff,
                                          jnp.dtype(cfg.dtype), stacked=stacked)
    return p


def moe_block(x, p, cfg, rt, *, kind=0, cache=None, pos=None):
    h, new_cache = attn_apply(rms_norm(x, p["norm_attn"], cfg.norm_eps),
                              p["attn"], cfg, rt,
                              window=cfg.sliding_window, cache=cache, pos=pos)
    x = x + h
    hin = rms_norm(x, p["norm_ffn"], cfg.norm_eps)
    y, aux = moe_lib.moe_apply(hin, p["moe"], cfg)
    if "dense_mlp" in p:
        y = y + gated_mlp(hin, p["dense_mlp"])
    return x + y, (new_cache, aux)


# ---------------------------------------------------------------------------
# SSM block (mamba2): mixer only, no MLP
# ---------------------------------------------------------------------------

def ssm_block_params(key, cfg, *, stacked: int = 0) -> dict:
    lead = (stacked,) if stacked else ()
    return {
        "mixer": ssm_lib.ssm_params(key, cfg, stacked=stacked),
        "norm": jnp.zeros((*lead, cfg.d_model), jnp.float32),
    }


def ssm_block(x, p, cfg, rt, *, kind=0, cache=None, pos=None):
    y, new_cache = ssm_lib.ssm_block(rms_norm(x, p["norm"], cfg.norm_eps),
                                     p["mixer"], cfg, cache=cache)
    return x + y, new_cache


# ---------------------------------------------------------------------------
# Hybrid block (hymba): parallel attention + SSM heads, fused by mean
# ---------------------------------------------------------------------------

def hybrid_block_params(key, cfg, *, stacked: int = 0) -> dict:
    ks = jax.random.split(key, 3)
    lead = (stacked,) if stacked else ()
    return {
        "attn": attn.attention_params(ks[0], cfg, stacked=stacked),
        "mixer": ssm_lib.ssm_params(ks[1], cfg, stacked=stacked),
        "mlp": gated_mlp_params(ks[2], cfg.d_model, cfg.d_ff,
                                jnp.dtype(cfg.dtype), stacked=stacked),
        "norm_in": jnp.zeros((*lead, cfg.d_model), jnp.float32),
        "norm_mlp": jnp.zeros((*lead, cfg.d_model), jnp.float32),
    }


def hybrid_block(x, p, cfg, rt, *, kind=0, cache=None, pos=None):
    h = rms_norm(x, p["norm_in"], cfg.norm_eps)
    attn_cache = None if cache is None else cache["attn"]
    ssm_cache = None if cache is None else cache["ssm"]
    ya, attn_cache = attn_apply(h, p["attn"], cfg, rt,
                                window=cfg.sliding_window,
                                cache=attn_cache, pos=pos)
    ys, ssm_cache = ssm_lib.ssm_block(h, p["mixer"], cfg, cache=ssm_cache)
    x = x + 0.5 * (ya + ys)
    x = x + gated_mlp(rms_norm(x, p["norm_mlp"], cfg.norm_eps), p["mlp"])
    new_cache = None if cache is None else {"attn": attn_cache, "ssm": ssm_cache}
    return x, new_cache


# ---------------------------------------------------------------------------
# Encoder block (whisper encoder: bidirectional, LayerNorm, GELU MLP)
# ---------------------------------------------------------------------------

def encoder_block_params(key, cfg, *, stacked: int = 0) -> dict:
    ks = jax.random.split(key, 2)
    lead = (stacked,) if stacked else ()
    d = cfg.d_model
    return {
        "attn": attn.attention_params(ks[0], cfg, stacked=stacked),
        "mlp": mlp_params(ks[1], d, cfg.d_ff, jnp.dtype(cfg.dtype),
                          stacked=stacked),
        "ln1_s": jnp.ones((*lead, d), jnp.float32),
        "ln1_b": jnp.zeros((*lead, d), jnp.float32),
        "ln2_s": jnp.ones((*lead, d), jnp.float32),
        "ln2_b": jnp.zeros((*lead, d), jnp.float32),
    }


def encoder_block(x, p, cfg, rt):
    # encoder frames (1500) are not chunk-aligned; bidirectional + short
    h, _ = attn_apply(layer_norm(x, p["ln1_s"], p["ln1_b"], cfg.norm_eps),
                      p["attn"], cfg, rt, window=0, causal=False,
                      impl="naive")
    x = x + h
    x = x + mlp(layer_norm(x, p["ln2_s"], p["ln2_b"], cfg.norm_eps), p["mlp"])
    return x


# ---------------------------------------------------------------------------
# Cross-attention decoder block (whisper decoder / llama-vision cross layer)
# ---------------------------------------------------------------------------

def cross_block_params(key, cfg, *, stacked: int = 0, self_attn: bool = True,
                       use_layernorm: bool = True) -> dict:
    ks = jax.random.split(key, 4)
    lead = (stacked,) if stacked else ()
    d = cfg.d_model
    p = {
        "cross": attn.attention_params(ks[1], cfg, stacked=stacked, cross=True),
        "mlp": (mlp_params if use_layernorm else gated_mlp_params)(
            ks[2], d, cfg.d_ff, jnp.dtype(cfg.dtype), stacked=stacked),
        "gate": jnp.zeros((*lead,), jnp.float32),  # llama-vision tanh gate
    }
    if self_attn:
        p["self"] = attn.attention_params(ks[0], cfg, stacked=stacked)
    names = ("ln_self", "ln_cross", "ln_mlp")
    for nm in names:
        if use_layernorm:
            p[nm + "_s"] = jnp.ones((*lead, d), jnp.float32)
            p[nm + "_b"] = jnp.zeros((*lead, d), jnp.float32)
        else:
            p[nm] = jnp.zeros((*lead, d), jnp.float32)
    return p


def _norm(x, p, name, cfg):
    if name + "_s" in p:
        return layer_norm(x, p[name + "_s"], p[name + "_b"], cfg.norm_eps)
    return rms_norm(x, p[name], cfg.norm_eps)


def cross_block(x, p, cfg, rt, *, enc, cache=None, pos=None,
                gated=False, use_gelu_mlp=True):
    """Decoder block with (optional) self-attn + cross-attn to `enc`.

    For decode, `cache` = {"k","v", optional "ck","cv"}: self-attn cache plus
    precomputed cross K/V. If "ck" missing, cross K/V are recomputed from enc.
    """
    new_cache = dict(cache) if cache is not None else None
    if "self" in p:
        self_cache = None
        if cache is not None:
            self_cache = {"k": cache["k"], "v": cache["v"]}
        h, self_cache = attn_apply(_norm(x, p, "ln_self", cfg), p["self"], cfg,
                                   rt, window=cfg.sliding_window,
                                   cache=self_cache, pos=pos)
        x = x + h
        if new_cache is not None:
            new_cache.update(self_cache)
    # cross-attention KV (vision patches / encoder frames) is short and not
    # chunk-aligned: the materialized-scores path is the right impl here
    h, _ = attn_apply(_norm(x, p, "ln_cross", cfg), p["cross"], cfg, rt,
                      window=0, kv_x=enc, causal=False, impl="naive")
    if gated:
        h = h * jnp.tanh(p["gate"].astype(h.dtype))
    x = x + h
    hin = _norm(x, p, "ln_mlp", cfg)
    x = x + (mlp(hin, p["mlp"]) if "w_in" in p["mlp"] else gated_mlp(hin, p["mlp"]))
    return x, new_cache
