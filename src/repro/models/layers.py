"""Shared neural-net layers: norms, rotary embeddings, MLPs, init helpers.

Everything is functional: params are plain dicts of jnp arrays, layer
functions are pure. Stacked-over-layers weights carry a leading [L] axis and
are consumed through lax.scan (DESIGN.md §6).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any


# -- init -------------------------------------------------------------------

def dense_init(key, fan_in: int, shape, dtype) -> jnp.ndarray:
    scale = 1.0 / np.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def embed_init(key, shape, dtype) -> jnp.ndarray:
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# -- norms ------------------------------------------------------------------

def rms_norm(x: jnp.ndarray, scale: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def layer_norm(x: jnp.ndarray, scale: jnp.ndarray, bias: jnp.ndarray,
               eps: float = 1e-6) -> jnp.ndarray:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean(jnp.square(x - mu), axis=-1, keepdims=True)
    out = (x - mu) * jax.lax.rsqrt(var + eps) * scale.astype(jnp.float32) \
        + bias.astype(jnp.float32)
    return out.astype(dtype)


# -- rotary embeddings --------------------------------------------------------

def rope_frequencies(head_dim: int, theta: float) -> jnp.ndarray:
    """[head_dim/2] inverse frequencies."""
    exponent = jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim
    return 1.0 / (theta ** exponent)


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, theta: float) -> jnp.ndarray:
    """Rotate pairs. x: [..., S, H, D]; positions: [..., S] (broadcastable)."""
    d = x.shape[-1]
    inv = rope_frequencies(d, theta)                     # [D/2]
    ang = positions[..., :, None, None].astype(jnp.float32) * inv  # [..,S,1,D/2]
    sin, cos = jnp.sin(ang), jnp.cos(ang)
    x1, x2 = x[..., 0::2], x[..., 1::2]
    r1 = x1 * cos - x2 * sin
    r2 = x2 * cos + x1 * sin
    out = jnp.stack([r1, r2], axis=-1).reshape(x.shape)
    return out.astype(x.dtype)


# -- soft capping (gemma2) ----------------------------------------------------

def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    if not cap:
        return x
    return (cap * jnp.tanh(x / cap)).astype(x.dtype)


# -- MLPs ---------------------------------------------------------------------

def gated_mlp_params(key, d_model: int, d_ff: int, dtype, *, stacked: int = 0):
    """SwiGLU weights: w_gate, w_up [D, F], w_down [F, D]."""
    ks = jax.random.split(key, 3)
    lead = (stacked,) if stacked else ()
    return {
        "w_gate": dense_init(ks[0], d_model, (*lead, d_model, d_ff), dtype),
        "w_up": dense_init(ks[1], d_model, (*lead, d_model, d_ff), dtype),
        "w_down": dense_init(ks[2], d_ff, (*lead, d_ff, d_model), dtype),
    }


def gated_mlp(x: jnp.ndarray, p: dict) -> jnp.ndarray:
    g = jnp.einsum("...d,df->...f", x, p["w_gate"])
    u = jnp.einsum("...d,df->...f", x, p["w_up"])
    h = jax.nn.silu(g.astype(jnp.float32)).astype(x.dtype) * u
    return jnp.einsum("...f,fd->...d", h, p["w_down"])


def mlp_params(key, d_model: int, d_ff: int, dtype, *, stacked: int = 0):
    """Plain 2-layer GELU MLP (whisper)."""
    ks = jax.random.split(key, 2)
    lead = (stacked,) if stacked else ()
    return {
        "w_in": dense_init(ks[0], d_model, (*lead, d_model, d_ff), dtype),
        "b_in": jnp.zeros((*lead, d_ff), dtype),
        "w_out": dense_init(ks[1], d_ff, (*lead, d_ff, d_model), dtype),
        "b_out": jnp.zeros((*lead, d_model), dtype),
    }


def mlp(x: jnp.ndarray, p: dict) -> jnp.ndarray:
    h = jnp.einsum("...d,df->...f", x, p["w_in"]) + p["b_in"]
    h = jax.nn.gelu(h.astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("...f,fd->...d", h, p["w_out"]) + p["b_out"]
