"""Mamba-2 SSD (state-space duality) layer — arXiv:2405.21060.

Scalar-identity A per head. The chunked SSD algorithm:
  * intra-chunk (quadratic in chunk): Y_intra = (L ∘ (C Bᵀ)) X with
    L[s,r] = exp(a_s - a_r) 1[r<=s], a = cumsum(A·dt);
  * inter-chunk: a lax.scan carries the [H, P, N] state across chunks.

Decode is the O(1) recurrence h' = exp(A dt) h + dt·B⊗x, y = C·h' + D x.

A depthwise causal conv (width 4) precedes the SSM on (x, B, C) as in the
reference implementation; its rolling state is part of the decode cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init, rms_norm


def ssm_dims(cfg):
    d_inner = cfg.d_inner
    heads = cfg.ssm_heads
    conv_dim = d_inner + 2 * cfg.ssm_state
    return d_inner, heads, conv_dim


def ssm_params(key, cfg, *, stacked: int = 0) -> dict:
    ks = jax.random.split(key, 5)
    dtype = jnp.dtype(cfg.dtype)
    d = cfg.d_model
    d_inner, heads, conv_dim = ssm_dims(cfg)
    n = cfg.ssm_state
    lead = (stacked,) if stacked else ()
    in_dim = 2 * d_inner + 2 * n + heads  # x, z, B, C, dt
    # A in (-inf, 0): A = -exp(a_log); init a_log ~ log U[1, 16]
    a_init = jnp.log(jnp.linspace(1.0, 16.0, heads, dtype=jnp.float32))
    return {
        "in_proj": dense_init(ks[0], d, (*lead, d, in_dim), dtype),
        "conv_w": dense_init(ks[1], cfg.ssm_conv_width,
                             (*lead, cfg.ssm_conv_width, conv_dim), dtype),
        "conv_b": jnp.zeros((*lead, conv_dim), dtype),
        "a_log": jnp.broadcast_to(a_init, (*lead, heads)).copy(),
        "d_skip": jnp.ones((*lead, heads), jnp.float32),
        "dt_bias": jnp.zeros((*lead, heads), jnp.float32),
        "norm_scale": jnp.zeros((*lead, d_inner), jnp.float32),
        "out_proj": dense_init(ks[4], d_inner, (*lead, d_inner, d), dtype),
    }


def _split_proj(zxbcdt, cfg):
    d_inner, heads, _ = ssm_dims(cfg)
    n = cfg.ssm_state
    z, x, b, c, dt = jnp.split(
        zxbcdt, [d_inner, 2 * d_inner, 2 * d_inner + n, 2 * d_inner + 2 * n],
        axis=-1)
    return z, x, b, c, dt


def _causal_conv(u: jnp.ndarray, w: jnp.ndarray, bias: jnp.ndarray,
                 state: jnp.ndarray | None = None):
    """Depthwise causal conv. u [B,S,Cd], w [W,Cd]. Returns (out, new_state)
    where state is the last W-1 inputs (for decode)."""
    width = w.shape[0]
    if state is None:
        pad = jnp.zeros((u.shape[0], width - 1, u.shape[2]), u.dtype)
    else:
        pad = state.astype(u.dtype)
    up = jnp.concatenate([pad, u], axis=1)
    out = sum(up[:, i:i + u.shape[1]] * w[i] for i in range(width)) + bias
    new_state = up[:, -(width - 1):] if width > 1 else pad
    return jax.nn.silu(out.astype(jnp.float32)).astype(u.dtype), new_state


def ssd_chunked(x, b, c, dt, a_log, d_skip, cfg, *, initial_state=None):
    """Chunked SSD scan.

    x  [B,S,H,P]  (P = ssm_head_dim), b/c [B,S,N], dt [B,S,H] (post-softplus),
    a_log [H]. Returns (y [B,S,H,P], final_state [B,H,P,N]).
    """
    bsz, s_orig, h, p = x.shape
    n = b.shape[-1]
    q = min(cfg.ssm_chunk, s_orig)
    if s_orig % q:  # pad with dt=0 steps (identity state transition)
        pad = q - s_orig % q
        zpad = lambda a: jnp.pad(a, [(0, 0), (0, pad)] + [(0, 0)] * (a.ndim - 2))
        x, b, c, dt = zpad(x), zpad(b), zpad(c), zpad(dt)
    s = x.shape[1]
    nc = s // q
    a = -jnp.exp(a_log.astype(jnp.float32))            # [H], negative
    # per-step log decay
    ldec = dt.astype(jnp.float32) * a                  # [B,S,H]
    xr = jnp.moveaxis(x.reshape(bsz, nc, q, h, p), 1, 0)
    br = jnp.moveaxis(b.reshape(bsz, nc, q, n), 1, 0)
    cr = jnp.moveaxis(c.reshape(bsz, nc, q, n), 1, 0)
    dtr = jnp.moveaxis(dt.reshape(bsz, nc, q, h), 1, 0).astype(jnp.float32)
    ldr = jnp.moveaxis(ldec.reshape(bsz, nc, q, h), 1, 0)

    if initial_state is None:
        h0 = jnp.zeros((bsz, h, p, n), jnp.float32)
    else:
        h0 = initial_state.astype(jnp.float32)

    def chunk_body(state, xs):
        xc, bc, cc, dtc, ldc = xs                       # [B,q,...]
        acum = jnp.cumsum(ldc, axis=1)                  # [B,q,H]
        # intra-chunk: L[s,r] = exp(acum_s - acum_r), r <= s.
        # Mask BEFORE the exp: exp of the (positive) upper-triangle entries
        # overflows and poisons the backward pass via inf * 0.
        diff = acum[:, :, None, :] - acum[:, None, :, :]   # [B,q,q,H]
        tril = jnp.tril(jnp.ones((q, q), bool))
        diff = jnp.where(tril[None, :, :, None], diff, -1e30)
        l_mat = jnp.exp(diff)
        cb = jnp.einsum("bsn,brn->bsr", cc.astype(jnp.float32),
                        bc.astype(jnp.float32))         # [B,q,q]
        scores = cb[..., None] * l_mat                  # [B,q,q,H]
        xdt = xc.astype(jnp.float32) * dtc[..., None]   # [B,q,H,P]
        y_intra = jnp.einsum("bsrh,brhp->bshp", scores, xdt)
        # inter-chunk: contribution of the carried state
        y_inter = jnp.einsum("bsn,bhpn,bsh->bshp",
                             cc.astype(jnp.float32), state, jnp.exp(acum))
        # chunk's addition to the state
        atot = acum[:, -1]                              # [B,H]
        decay_r = jnp.exp(atot[:, None] - acum)         # [B,q,H]
        dstate = jnp.einsum("brn,brhp,brh->bhpn",
                            bc.astype(jnp.float32), xdt, decay_r)
        state_new = state * jnp.exp(atot)[:, :, None, None] + dstate
        return state_new, y_intra + y_inter

    final, ys = jax.lax.scan(chunk_body, h0, (xr, br, cr, dtr, ldr))
    y = jnp.moveaxis(ys, 0, 1).reshape(bsz, s, h, p)
    y = y + x.astype(jnp.float32) * d_skip[None, None, :, None]
    return y[:, :s_orig].astype(x.dtype), final


def ssd_step(x, b, c, dt, a_log, d_skip, state):
    """Single decode step. x [B,H,P], b/c [B,N], dt [B,H], state [B,H,P,N]."""
    a = -jnp.exp(a_log.astype(jnp.float32))
    decay = jnp.exp(dt.astype(jnp.float32) * a)                 # [B,H]
    upd = jnp.einsum("bn,bhp->bhpn", b.astype(jnp.float32),
                     x.astype(jnp.float32) * dt[..., None])
    state_new = state * decay[..., None, None] + upd
    y = jnp.einsum("bn,bhpn->bhp", c.astype(jnp.float32), state_new)
    y = y + x.astype(jnp.float32) * d_skip[None, :, None]
    return y.astype(x.dtype), state_new


def ssm_block(x, p, cfg, *, cache=None):
    """Full mamba2 mixer. x [B,S,D]. cache: dict(ssm [B,H,P,N], conv [B,W-1,Cd])
    for decode (S must be 1); returns (y [B,S,D], new_cache)."""
    bsz, s, _ = x.shape
    d_inner, heads, conv_dim = ssm_dims(cfg)
    n = cfg.ssm_state
    ph = cfg.ssm_head_dim
    proj = jnp.einsum("bsd,de->bse", x, p["in_proj"])
    z, xi, b, c, dt = _split_proj(proj, cfg)
    conv_in = jnp.concatenate([xi, b, c], axis=-1)
    decode = cache is not None and s == 1
    conv_state = cache["conv"] if decode else None
    conv_out, conv_state_new = _causal_conv(conv_in, p["conv_w"], p["conv_b"],
                                            conv_state)
    xi, b, c = jnp.split(conv_out, [d_inner, d_inner + n], axis=-1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    xh = xi.reshape(bsz, s, heads, ph)
    if not decode:
        init_state = None if cache is None else cache["ssm"]
        y, final = ssd_chunked(xh, b, c, dt, p["a_log"], p["d_skip"], cfg,
                               initial_state=init_state)
        new_cache = {"ssm": final,
                     "conv": conv_state_new.astype(
                         cache["conv"].dtype) if cache is not None
                     else conv_state_new}
    else:
        y1, state = ssd_step(xh[:, 0], b[:, 0], c[:, 0], dt[:, 0],
                             p["a_log"], p["d_skip"], cache["ssm"])
        y = y1[:, None]
        new_cache = {"ssm": state, "conv": conv_state_new}
    y = y.reshape(bsz, s, d_inner)
    # gated RMS norm (mamba2): norm(y * silu(z))
    y = y * jax.nn.silu(z.astype(jnp.float32)).astype(y.dtype)
    y = rms_norm(y, p["norm_scale"], cfg.norm_eps)
    return jnp.einsum("bse,ed->bsd", y, p["out_proj"]), new_cache


def init_ssm_cache(cfg, batch: int, dtype) -> dict:
    d_inner, heads, conv_dim = ssm_dims(cfg)
    return {
        "ssm": jnp.zeros((batch, heads, cfg.ssm_head_dim, cfg.ssm_state),
                         jnp.float32),
        "conv": jnp.zeros((batch, cfg.ssm_conv_width - 1, conv_dim), dtype),
    }
