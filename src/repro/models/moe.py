"""Mixture-of-Experts: top-k router + capacity-bucketed expert compute.

Dispatch is *grouped*: tokens are split into G groups aligned with the batch
sharding, and each group routes into its own [E, C_g] capacity buckets. All
gathers/scatters are then batched over the (sharded) group axis, so under
SPMD they stay device-local — mirroring per-device expert capacity in
production EP systems. (The ungrouped variant all-gathers an [E*C_global, D]
f32 tensor — 16 GB/device measured on mixtral train_4k; EXPERIMENTS.md
§Perf.) Overflowing tokens are dropped per group (standard capacity-factor
semantics); the router adds the usual load-balance auxiliary loss.

Expert weights [E, D, F] shard E over "model" when E divides it (arctic:
expert parallel), else F over "model" with D over the batch axes (mixtral);
see repro/sharding/rules.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.models.layers import dense_init
from repro.sharding.rules import constrain


def moe_params(key, cfg, *, stacked: int = 0) -> dict:
    ks = jax.random.split(key, 4)
    dtype = jnp.dtype(cfg.dtype)
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    lead = (stacked,) if stacked else ()
    return {
        "router": dense_init(ks[0], d, (*lead, d, e), jnp.float32),
        "w_gate": dense_init(ks[1], d, (*lead, e, d, f), dtype),
        "w_up": dense_init(ks[2], d, (*lead, e, d, f), dtype),
        "w_down": dense_init(ks[3], f, (*lead, e, f, d), dtype),
    }


def route_topk(logits: jnp.ndarray, top_k: int):
    """logits [T, E] -> (weights [T,k], experts [T,k], aux load-balance loss)."""
    probs = jax.nn.softmax(logits.astype(jnp.float32), axis=-1)
    w, idx = jax.lax.top_k(probs, top_k)
    w = w / jnp.maximum(w.sum(axis=-1, keepdims=True), 1e-9)
    e = logits.shape[-1]
    me = probs.mean(axis=0)
    ce = jnp.zeros((e,), jnp.float32).at[idx.reshape(-1)].add(
        jnp.ones_like(idx.reshape(-1), jnp.float32)) / (idx.size)
    aux = e * jnp.sum(me * ce)
    return w, idx, aux


def pick_groups(t: int, *, target: int = 64) -> int:
    """Largest group count <= target dividing t (group axis ~ device grid)."""
    g = min(target, t)
    while g > 1 and t % g:
        g -= 1
    return g


def _group_dispatch(idx_g, w_g, cap, e):
    """Per-group bucket construction (vmapped over groups).

    idx_g/w_g: [Tg, k]. Returns bucket_tok [E, C] (token id, Tg = padding),
    combine_idx [Tg*k] (into flattened [E*C]), combine_w [Tg*k]."""
    tg, k = idx_g.shape
    flat_e = idx_g.reshape(-1)
    flat_t = jnp.repeat(jnp.arange(tg), k)
    flat_w = w_g.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st, sw = flat_e[order], flat_t[order], flat_w[order]
    counts = jnp.zeros((e,), jnp.int32).at[se].add(1)
    starts = jnp.concatenate([jnp.zeros((1,), jnp.int32),
                              jnp.cumsum(counts)[:-1]])
    slot = jnp.arange(tg * k, dtype=jnp.int32) - starts[se]
    keep = slot < cap
    bucket_tok = jnp.full((e, cap), tg, jnp.int32)
    bucket_tok = bucket_tok.at[se, jnp.clip(slot, 0, cap - 1)].set(
        jnp.where(keep, st, tg), mode="drop")
    inv = jnp.argsort(order)
    comb_idx = se[inv] * cap + jnp.clip(slot[inv], 0, cap - 1)
    comb_w = flat_w * (slot[inv] < cap).astype(jnp.float32)
    return bucket_tok, comb_idx, comb_w


def moe_apply(x: jnp.ndarray, p: dict, cfg,
              groups: int | None = None) -> tuple[jnp.ndarray, jnp.ndarray]:
    """x [B, S, D] -> (y [B, S, D], aux loss scalar)."""
    b, s, d = x.shape
    e, k = cfg.num_experts, cfg.experts_per_token
    t = b * s
    xt = x.reshape(t, d)
    logits = jnp.einsum("td,de->te", xt.astype(jnp.float32), p["router"])
    w, idx, aux = route_topk(logits, k)

    g = pick_groups(t) if groups is None else groups
    tg = t // g
    cap = max(int(np.ceil(tg * k / e * cfg.moe_capacity_factor)), k)

    xg = constrain(xt.reshape(g, tg, d), "batch", None, None)
    idx_g = idx.reshape(g, tg, k)
    w_g = w.reshape(g, tg, k)
    bucket_tok, comb_idx, comb_w = jax.vmap(
        lambda i, ww: _group_dispatch(i, ww, cap, e))(idx_g, w_g)
    bucket_tok = constrain(bucket_tok, "batch", "model", None)

    # gather (zeros row at index tg pads) — batched over the group axis
    xpad = jnp.concatenate([xg, jnp.zeros((g, 1, d), xg.dtype)], axis=1)
    xe = jax.vmap(lambda xp, bt: xp[bt])(xpad, bucket_tok)   # [G, E, C, D]
    xe = constrain(xe, "batch", "model", None, None)
    gg = jnp.einsum("gecd,edf->gecf", xe, p["w_gate"])
    uu = jnp.einsum("gecd,edf->gecf", xe, p["w_up"])
    gg = constrain(gg, "batch", "model", None, "model")
    uu = constrain(uu, "batch", "model", None, "model")
    h = jax.nn.silu(gg.astype(jnp.float32)).astype(x.dtype) * uu
    ye = jnp.einsum("gecf,efd->gecd", h, p["w_down"])        # [G, E, C, D]
    ye = constrain(ye, "batch", "model", None, None)

    # combine by gather (inverse permutation) — batched over groups
    contrib = jax.vmap(lambda y_, ci: y_.reshape(e * cap, d)[ci])(
        ye, comb_idx)                                        # [G, Tg*k, D]
    contrib = constrain(contrib, "batch", None, None)
    contrib = contrib * comb_w[..., None].astype(ye.dtype)
    y = contrib.reshape(g, tg, k, d).sum(axis=2)
    y = constrain(y, "batch", None, None)
    return y.reshape(b, s, d), aux
