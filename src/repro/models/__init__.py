"""Model zoo: unified scanned-transformer LM (10 assigned archs) + the
paper's own CNNs (LeNet / ResNet-CIFAR)."""
from repro.models.blocks import Runtime
from repro.models.transformer import (
    init_params, param_shapes, param_count, active_param_count,
    forward, loss_fn, init_cache, prefill, decode_step,
)
from repro.models.cnn import (
    lenet_init, lenet_apply, resnet_init, resnet_apply,
    mlp_edge_init, mlp_edge_apply,
    make_loss_fn, make_weighted_loss_fn, make_eval_fn,
)

__all__ = [
    "Runtime", "init_params", "param_shapes", "param_count",
    "active_param_count", "forward", "loss_fn", "init_cache", "prefill",
    "decode_step", "lenet_init", "lenet_apply", "resnet_init", "resnet_apply",
    "mlp_edge_init", "mlp_edge_apply",
    "make_loss_fn", "make_weighted_loss_fn", "make_eval_fn",
]
