"""Architecture configs: one module per assigned architecture.

Use `repro.configs.get_config(name)` / `list_configs()`; every config cites
its source in `source`.
"""
from repro.configs.base import ModelConfig
from repro.configs.registry import get_config, list_configs, INPUT_SHAPES, InputShape

__all__ = ["ModelConfig", "get_config", "list_configs", "INPUT_SHAPES",
           "InputShape"]
