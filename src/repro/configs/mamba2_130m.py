"""mamba2-130m — attention-free SSD state-space model [arXiv:2405.21060].

24L, d_model 768, ssm_state 128, expand 2 (d_inner 1536, 24 heads of 64),
vocab 50280. Constant-size state => long_500k eligible."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mamba2-130m",
    family="ssm",
    num_layers=24,
    d_model=768,
    num_heads=0,
    num_kv_heads=0,
    d_ff=0,
    vocab_size=50280,
    ssm_state=128,
    ssm_expand=2,
    ssm_head_dim=64,
    ssm_chunk=256,
    rope_theta=0.0,
    tie_embeddings=True,
    source="arXiv:2405.21060",
)
