"""Architecture + input-shape registry."""
from __future__ import annotations

import dataclasses
import importlib

from repro.configs.base import ModelConfig

_ARCHS = {
    "hymba-1.5b": "repro.configs.hymba_1_5b",
    "yi-9b": "repro.configs.yi_9b",
    "arctic-480b": "repro.configs.arctic_480b",
    "whisper-small": "repro.configs.whisper_small",
    "gemma2-9b": "repro.configs.gemma2_9b",
    "llama-3.2-vision-90b": "repro.configs.llama_3_2_vision_90b",
    "qwen2.5-3b": "repro.configs.qwen2_5_3b",
    "mamba2-130m": "repro.configs.mamba2_130m",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "granite-3-2b": "repro.configs.granite_3_2b",
}


def list_configs() -> list[str]:
    return sorted(_ARCHS)


def get_config(name: str) -> ModelConfig:
    if name not in _ARCHS:
        raise KeyError(f"unknown arch {name!r}; options: {list_configs()}")
    return importlib.import_module(_ARCHS[name]).CONFIG


@dataclasses.dataclass(frozen=True)
class InputShape:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # "train" | "prefill" | "decode"


INPUT_SHAPES = {
    "train_4k": InputShape("train_4k", 4096, 256, "train"),
    "prefill_32k": InputShape("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": InputShape("decode_32k", 32768, 128, "decode"),
    "long_500k": InputShape("long_500k", 524288, 1, "decode"),
}


def shape_applicable(cfg: ModelConfig, shape: InputShape) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) — DESIGN.md §5 skip rules."""
    if shape.name == "long_500k" and cfg.family == "audio":
        return False, "enc-dec audio: source caps decoder positions at 448"
    if shape.name == "long_500k" and not cfg.sub_quadratic:
        return False, ("pure full-attention decoder: 524288-token dense KV is "
                       "quadratic-history; no SWA variant claimed by source")
    return True, ""
