"""Model configuration dataclass shared by every assigned architecture."""
from __future__ import annotations

import dataclasses
from typing import Literal

Family = Literal["dense", "moe", "ssm", "hybrid", "audio", "vlm"]


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str
    family: Family
    num_layers: int
    d_model: int
    num_heads: int          # query heads (0 for attention-free)
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0       # 0 => d_model // num_heads
    source: str = ""        # citation (arXiv / hf model card)

    # attention details
    qkv_bias: bool = False
    rope_theta: float = 10000.0
    attn_softcap: float = 0.0        # gemma2: soft-capping on attn logits
    final_softcap: float = 0.0       # gemma2: soft-capping on LM logits
    sliding_window: int = 0          # 0 => full attention
    local_global: bool = False       # gemma2: alternate SW / global layers
    swa_only_long_context: bool = False  # variant flag for long_500k (DESIGN §5)

    # MoE
    num_experts: int = 0
    experts_per_token: int = 0
    moe_capacity_factor: float = 1.25
    dense_residual_ff: int = 0       # arctic: parallel dense MLP

    # SSM (mamba2 SSD)
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_head_dim: int = 64
    ssm_chunk: int = 256
    ssm_conv_width: int = 4

    # hybrid (hymba): parallel attn + SSM heads in every layer
    hybrid_parallel: bool = False

    # encoder-decoder / multimodal
    encoder_layers: int = 0          # whisper encoder depth
    encoder_tokens: int = 1500       # stub frontend sequence length
    cross_attn_every: int = 0        # vlm: one cross-attn block per k layers
    vision_tokens: int = 1601        # stub patch-embedding count

    tie_embeddings: bool = True
    embed_scale: bool = False        # gemma: scale embeddings by sqrt(d_model)
    max_seq: int = 4096              # learned-pos-embedding capacity (audio)
    norm_eps: float = 1e-6
    dtype: str = "bfloat16"

    def __post_init__(self):
        if self.num_heads and self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        if self.num_heads and self.num_kv_heads and self.num_heads % self.num_kv_heads:
            raise ValueError("num_heads must be divisible by num_kv_heads")
        if self.family == "moe" and (not self.num_experts or not self.experts_per_token):
            raise ValueError("moe family requires num_experts/experts_per_token")

    # -- derived sizes ------------------------------------------------------

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def d_inner(self) -> int:
        """SSM inner width."""
        return self.ssm_expand * self.d_model

    @property
    def ssm_heads(self) -> int:
        return self.d_inner // self.ssm_head_dim if self.ssm_state else 0

    @property
    def sub_quadratic(self) -> bool:
        """Eligible for long_500k (DESIGN.md §5)."""
        if self.family == "ssm":
            return True
        if self.hybrid_parallel:
            return True
        if self.sliding_window and not self.local_global:
            return True
        if self.local_global and self.swa_only_long_context:
            return True
        return False

    @property
    def has_decoder(self) -> bool:
        return True  # all assigned archs have a decoding path (whisper: decoder)

    def reduced(self, *, layers: int = 2, d_model: int = 256,
                experts: int = 4, vocab: int = 512) -> "ModelConfig":
        """Smoke-test variant: same family/wiring, tiny dimensions."""
        heads = 0 if self.num_heads == 0 else max(2, min(4, self.num_heads))
        kvh = 0 if heads == 0 else (1 if self.num_kv_heads == 1 else 2)
        changes = dict(
            name=self.name + "-reduced",
            num_layers=layers,
            d_model=d_model,
            num_heads=heads,
            num_kv_heads=kvh,
            head_dim=(d_model // heads if heads else 0),
            d_ff=2 * d_model,
            vocab_size=vocab,
            encoder_layers=min(self.encoder_layers, layers),
            encoder_tokens=min(self.encoder_tokens, 64),
            vision_tokens=min(self.vision_tokens, 64),
            dense_residual_ff=(d_model if self.dense_residual_ff else 0),
            ssm_head_dim=32 if self.ssm_state else self.ssm_head_dim,
            ssm_chunk=32 if self.ssm_state else self.ssm_chunk,
            sliding_window=min(self.sliding_window, 64) if self.sliding_window else 0,
            cross_attn_every=min(self.cross_attn_every, layers) if self.cross_attn_every else 0,
            dtype="float32",
        )
        if self.num_experts:
            changes["num_experts"] = min(experts, self.num_experts)
            changes["experts_per_token"] = min(self.experts_per_token, 2)
        return dataclasses.replace(self, **changes)
