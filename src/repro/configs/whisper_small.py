"""whisper-small — encoder-decoder audio transformer [arXiv:2212.04356].

12L (decoder; encoder 12L), d_model 768, 12H, d_ff 3072, vocab 51865.
The mel-spectrogram + conv frontend is a stub per the assignment:
input_specs() provides precomputed frame embeddings [B, 1500, 768]."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-small",
    family="audio",
    num_layers=12,
    d_model=768,
    num_heads=12,
    num_kv_heads=12,
    head_dim=64,
    d_ff=3072,
    vocab_size=51865,
    encoder_layers=12,
    encoder_tokens=1500,
    rope_theta=0.0,          # learned positional embeddings, no RoPE
    tie_embeddings=True,
    max_seq=4096,            # grown per-shape by input_specs (decode shapes)
    source="arXiv:2212.04356",
)
