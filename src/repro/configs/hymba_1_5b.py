"""hymba-1.5b — hybrid parallel attention + mamba heads [arXiv:2411.13676].

32L, d_model 1600, 25 query heads (GQA kv=5), d_ff 5504, vocab 32001,
ssm_state 16. Attention heads run sliding-window (global context flows
through the SSM path), making the arch sub-quadratic => long_500k eligible
(DESIGN.md §5)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="hymba-1.5b",
    family="hybrid",
    num_layers=32,
    d_model=1600,
    num_heads=25,
    num_kv_heads=5,
    head_dim=64,
    d_ff=5504,
    vocab_size=32001,
    ssm_state=16,
    ssm_expand=2,
    ssm_head_dim=64,
    hybrid_parallel=True,
    sliding_window=1024,
    source="arXiv:2411.13676",
)
