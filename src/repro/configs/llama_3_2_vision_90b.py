"""llama-3.2-vision-90b — VLM with gated cross-attention image layers
[hf:meta-llama/Llama-3.2-11B-Vision].

100L, d_model 8192, 64H (GQA kv=8), d_ff 28672, vocab 128256. Every 5th
layer is a gated cross-attention block over stubbed vision patch embeddings
(ViT encoder + projector stubbed per the assignment)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llama-3.2-vision-90b",
    family="vlm",
    num_layers=100,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=28672,
    vocab_size=128256,
    cross_attn_every=5,
    vision_tokens=1601,
    tie_embeddings=False,
    source="hf:meta-llama/Llama-3.2-11B-Vision",
)
