"""mixtral-8x22b — 8-expert top-2 MoE with sliding-window attention
[arXiv:2401.04088].

56L, d_model 6144, 48H (GQA kv=8), expert d_ff 16384, vocab 32768.
All-layer SWA-4096 => long_500k eligible."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x22b",
    family="moe",
    num_layers=56,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=16384,
    vocab_size=32768,
    num_experts=8,
    experts_per_token=2,
    sliding_window=4096,
    tie_embeddings=False,
    source="arXiv:2401.04088",
)
