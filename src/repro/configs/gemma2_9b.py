"""gemma2-9b — local/global alternating attention + logit softcaps
[arXiv:2408.00118].

42L, d_model 3584, 16H (GQA kv=8, head_dim 256), d_ff 14336, vocab 256000.
Local layers are SWA-4096; `swa_only_long_context` enables the documented
long_500k variant where global layers also window (DESIGN.md §5)."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-9b",
    family="dense",
    num_layers=42,
    d_model=3584,
    num_heads=16,
    num_kv_heads=8,
    head_dim=256,
    d_ff=14336,
    vocab_size=256000,
    local_global=True,
    sliding_window=4096,
    attn_softcap=50.0,
    final_softcap=30.0,
    embed_scale=True,
    swa_only_long_context=True,
    tie_embeddings=True,
    source="arXiv:2408.00118",
)
