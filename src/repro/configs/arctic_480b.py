"""arctic-480b — 128-expert top-2 MoE with dense residual MLP
[hf:Snowflake/snowflake-arctic-base].

35L, d_model 7168, 56H (GQA kv=8), expert d_ff 4864, vocab 32000."""
from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="arctic-480b",
    family="moe",
    num_layers=35,
    d_model=7168,
    num_heads=56,
    num_kv_heads=8,
    head_dim=128,
    d_ff=4864,
    vocab_size=32000,
    num_experts=128,
    experts_per_token=2,
    dense_residual_ff=4864,
    tie_embeddings=False,
    source="hf:Snowflake/snowflake-arctic-base",
)
