"""Packed parameter buffers for the device-resident round engine.

`ParamPack` flattens a model pytree once into a single padded ``[R, 128]``
fp32 buffer (lane-width aligned for the Pallas VPU kernels — DESIGN.md §5),
recording per-leaf offsets/shapes/dtypes so the pytree can be reconstructed
exactly. Importance, thresholding, masking, gradient aggregation, and the
FedSGD update then operate on one contiguous buffer with a handful of fused
kernel launches instead of one Python-level loop iteration per leaf.

Packing is a pure layout transform:

  * ``pack`` casts every leaf to fp32 and concatenates raveled leaves in
    tree-flatten order; the tail is zero padded up to a multiple of
    ``LANES * ROW_BLOCK`` so the buffer tiles cleanly.
  * ``unpack`` slices each leaf back out and casts to its original dtype.
    fp32/bf16/fp16 (and int32 below 2**24) round-trip exactly; the engine
    computes in fp32 regardless of the storage dtype.
  * ``prunable_mask`` is a {0,1} fp32 buffer marking coordinates that belong
    to prunable leaves (per `PruneSpec`); padding coordinates are 0.

Both ``pack`` and ``unpack`` are jittable and differentiable, so gradients
can be taken directly with respect to the packed buffer.

Buffer ownership and donation: the packed (w, v) buffers are long-lived
device state owned by their trainer — `RoundEngine` steps them functionally
by default, but an owner may opt into donation (``RoundEngine(donate=
True)``), in which case the buffers are donated to each ``round_step`` /
``block_step`` dispatch on accelerator backends and updated in place.
Inside a multi-round block the (w, v) pair is additionally the
``lax.scan`` carry, so XLA double-buffers it across the K rounds of the
block without ever round-tripping it to host — callers must treat the
passed-in buffers as consumed either way (`FederatedTrainer` reassigns
them every dispatch).
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.pruning import PruneSpec

PyTree = Any

LANES = 128
# Rows are padded to a multiple of this so packed kernels run with a fixed,
# reasonably large block (grid = rows / ROW_BLOCK) instead of degenerate
# blocks on awkward row counts.
ROW_BLOCK = 256


@dataclasses.dataclass(frozen=True)
class ParamPack:
    """Static layout of a pytree inside a padded ``[rows, LANES]`` buffer."""

    treedef: Any
    paths: tuple[str, ...]
    shapes: tuple[tuple[int, ...], ...]
    dtypes: tuple[Any, ...]
    offsets: tuple[int, ...]
    sizes: tuple[int, ...]
    n_total: int          # real (unpadded) coordinate count
    rows: int             # padded row count; buffer is [rows, LANES]
    prunable_leaf: tuple[bool, ...]
    n_prunable: int       # prunable coordinate count (threshold denominator)

    # -- construction -------------------------------------------------------

    @classmethod
    def build(cls, params: PyTree, spec: PruneSpec = PruneSpec(),
              *, row_block: int = ROW_BLOCK) -> "ParamPack":
        flat, treedef = jax.tree_util.tree_flatten_with_path(params)
        paths = tuple(jax.tree_util.keystr(kp) for kp, _ in flat)
        leaves = [leaf for _, leaf in flat]
        shapes = tuple(tuple(l.shape) for l in leaves)
        dtypes = tuple(jnp.asarray(l).dtype for l in leaves)
        sizes = tuple(int(np.prod(s)) if s else 1 for s in shapes)
        offsets = tuple(int(o) for o in np.cumsum((0,) + sizes)[:-1])
        n_total = int(sum(sizes))
        rows = max(1, -(-n_total // LANES))           # ceil div
        rows = -(-rows // row_block) * row_block      # round up to block
        prunable_leaf = tuple(bool(spec.prunable(p)) for p in paths)
        n_prunable = int(sum(s for s, pr in zip(sizes, prunable_leaf) if pr))
        return cls(treedef=treedef, paths=paths, shapes=shapes, dtypes=dtypes,
                   offsets=offsets, sizes=sizes, n_total=n_total, rows=rows,
                   prunable_leaf=prunable_leaf, n_prunable=n_prunable)

    # -- derived constants --------------------------------------------------

    @property
    def n_padded(self) -> int:
        return self.rows * LANES

    def prunable_mask(self) -> np.ndarray:
        """{0,1} fp32 [rows, LANES]: 1 on real coordinates of prunable leaves."""
        m = np.zeros(self.n_padded, np.float32)
        for off, size, pr in zip(self.offsets, self.sizes, self.prunable_leaf):
            if pr:
                m[off:off + size] = 1.0
        return m.reshape(self.rows, LANES)

    def valid_mask(self) -> np.ndarray:
        """{0,1} fp32 [rows, LANES]: 1 on real (non-padding) coordinates."""
        m = np.zeros(self.n_padded, np.float32)
        m[:self.n_total] = 1.0
        return m.reshape(self.rows, LANES)

    # -- layout transforms (jittable, differentiable) -----------------------

    def pack(self, tree: PyTree) -> jnp.ndarray:
        leaves = jax.tree_util.tree_leaves(tree)
        if len(leaves) != len(self.sizes):
            raise ValueError(
                f"tree has {len(leaves)} leaves, pack expects {len(self.sizes)}")
        flat = jnp.concatenate(
            [jnp.ravel(l).astype(jnp.float32) for l in leaves])
        flat = jnp.pad(flat, (0, self.n_padded - self.n_total))
        return flat.reshape(self.rows, LANES)

    def unpack(self, buf: jnp.ndarray) -> PyTree:
        flat = buf.reshape(-1)
        leaves = [
            jax.lax.dynamic_slice_in_dim(flat, off, size)
            .reshape(shape).astype(dtype)
            for off, size, shape, dtype in zip(
                self.offsets, self.sizes, self.shapes, self.dtypes)
        ]
        return jax.tree_util.tree_unflatten(self.treedef, leaves)
