"""Core library: the paper's contribution (generalization-aware, parameter-
efficient FEEL with joint resource optimization)."""
from repro.core.generalization import (
    GeneralizationStatement,
    generalization_statement,
    client_statements,
    phis,
    generalization_gap_increment_bound,
    entropy,
    cross_entropy,
    kl_divergence,
    mutual_information_term,
    PHI_MAX,
)
from repro.core.convergence import BoundConstants, theta, theta_decomposition, round_term
from repro.core.pruning import (
    PruneSpec,
    taylor_importance,
    exact_importance,
    build_masks,
    apply_masks,
    global_threshold,
    actual_ratio,
    pruning_distortion,
)
from repro.core.optimizer_ao import AOConfig, Schedule, solve_p1, solve_random
from repro.core.packing import ParamPack
from repro.core.client_store import (
    ClientStore, StoreBudgetError, estimated_store_nbytes,
)
from repro.core.cohort_store import CohortStore, fleet_counters_zero
from repro.core.round_engine import RoundEngine, kth_smallest_threshold
from repro.core.federated import ClientData, FederatedTrainer, RoundMetrics
from repro.core.faults import (
    ClientDropout,
    CorruptUpload,
    FaultDraw,
    FaultModel,
    GaussianPoison,
    MixedFaults,
    ScaledMalicious,
    SignFlip,
    StragglerTimeout,
)
from repro.core.aggregators import (
    AGGREGATORS,
    Aggregator,
    aggregator_names,
    make_aggregator,
    register_aggregator,
)

__all__ = [
    "GeneralizationStatement", "generalization_statement", "client_statements",
    "phis", "generalization_gap_increment_bound", "entropy", "cross_entropy",
    "kl_divergence", "mutual_information_term", "PHI_MAX",
    "BoundConstants", "theta", "theta_decomposition", "round_term",
    "PruneSpec", "taylor_importance", "exact_importance", "build_masks",
    "apply_masks", "global_threshold", "actual_ratio", "pruning_distortion",
    "AOConfig", "Schedule", "solve_p1", "solve_random",
    "ParamPack", "ClientStore", "StoreBudgetError", "estimated_store_nbytes",
    "CohortStore", "fleet_counters_zero",
    "RoundEngine", "kth_smallest_threshold",
    "ClientData", "FederatedTrainer", "RoundMetrics",
    "FaultDraw", "FaultModel", "ClientDropout", "StragglerTimeout",
    "CorruptUpload", "MixedFaults", "SignFlip", "ScaledMalicious",
    "GaussianPoison",
    "AGGREGATORS", "Aggregator", "aggregator_names", "make_aggregator",
    "register_aggregator",
]
