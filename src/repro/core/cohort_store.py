"""Streamed cohort store: fleet-scale populations through the packed engine.

`ClientStore` (client_store.py) materializes EVERY client's padded rows on
every device — right for edge-scale federations, impossible for the
100k-1M-client fleets the paper's selection machinery is motivated by. The
cohort store keeps the full population host-side (a lazy `FleetRoster` or a
plain client list) and moves only each block's *cohort* — the union of
clients the schedule actually selects in that block — to device:

  * the trainer registers the whole run's block plans up front (the block
    partition is schedule-pure, so cohort k+1 is known while block k
    trains);
  * a background thread packs cohort k+1's padded ``[C_cohort, N_max, ...]``
    buffers and commits them with `jax.device_put` (+ `block_until_ready`)
    while the main thread's block-k dispatch runs — double-buffered
    prefetch, the PR-3 zero-per-round-sync discipline one level up. At most
    two cohorts are ever device-resident (current + prefetching), so peak
    device bytes track the COHORT size, not the population;
  * `acquire(start)` joins the prefetch (recording stall seconds), drops the
    previous cohort's buffers, kicks off the next prefetch, and returns a
    `Cohort` whose ``remap`` translates global client ids to cohort-local
    rows.

Bitwise contract: cohort rows are byte-copies of the rows a replicated
`ClientStore` would hold, local-id gathers read the identical elements, and
the host-drawn index protocol (core/federated._draw_indices) is untouched —
streaming moves data, never randomness — so a streamed run's trajectory is
bit-for-bit the replicated run's (tests/test_fleet.py asserts it on 1 device
and the forced-4-device leg).

Shard placement: on a mesh the cohort store composes with the engine's
client-axis shard_map instead of replicating. Client-axis position j of a
bucketed block belongs to mesh shard ``j // (c_bucket / shards)``; each
shard's sub-cohort (clients appearing at its positions, trainer padding
included) packs into its slice of one ``[shards * rows_per_shard, ...]``
buffer committed with ``PartitionSpec("data")`` — each device holds ONLY its
clients' rows. Row counts sit on the same pow2 bucket ladder as the client
axis (capped at the population), so trace counts keep the PR-2/PR-3 bounds.
Engine-side, a purely-local shard_map gather (no collective) replaces the
replicated-store gather (round_engine._make_block_impl(sharded_store=True)).
"""
from __future__ import annotations

import dataclasses
import threading
import time
from typing import Any, Callable, Sequence

import jax
import numpy as np

from repro.core.round_engine import bucket_capacity


def fleet_counters_zero() -> dict:
    """The streaming observability counters, in one place so the trainer,
    checkpoints, and RunResult.summary['fleet'] agree on the keys."""
    return {"n_cohort_swaps": 0, "h2d_bytes": 0,
            "prefetch_stall_s": 0.0, "peak_cohort_bytes": 0}


@dataclasses.dataclass
class Cohort:
    """One block's device-resident client rows (ClientStore-shaped).

    ``x``/``y`` are the padded device buffers `RoundEngine.block_step`
    gathers from; ``counts`` the per-row real sample counts (zero on
    padding rows, which are never gathered). ``sharded`` routes the engine
    to the shard-local gather; ``ids_by_shard`` holds each shard's sorted
    global client ids (one entry when unsharded) for ``remap``."""

    x: Any
    y: Any
    counts: np.ndarray
    sharded: bool
    ids_by_shard: list
    per: int                  # client-axis positions per shard (sharded only)
    start: int                # first schedule round of the owning block
    nbytes: int               # device bytes (== H2D bytes of the commit)

    def remap(self, cids: np.ndarray) -> np.ndarray:
        """Global client ids [K, C] -> cohort-local row ids, position-wise.

        Unsharded: one sorted id table. Sharded: position j maps through
        shard ``j // per``'s table into SHARD-LOCAL row space (the engine's
        gather runs inside shard_map, so each shard indexes its own
        ``rows_per_shard`` rows)."""
        if not self.sharded:
            return np.searchsorted(self.ids_by_shard[0],
                                   cids).astype(np.int32)
        k, c_max = cids.shape
        out = np.empty((k, c_max), np.int32)
        for s, ids in enumerate(self.ids_by_shard):
            lo, hi = s * self.per, min((s + 1) * self.per, c_max)
            if lo >= c_max:
                break
            out[:, lo:hi] = np.searchsorted(ids, cids[:, lo:hi])
        return out

    def gather(self, cids, idx):
        """ClientStore.gather over cohort-LOCAL ids (unsharded layout)."""
        return self.x[cids[:, None], idx], self.y[cids[:, None], idx]


class CohortStore:
    """Plans, prefetches, and hands out per-block cohorts (see module doc).

    One instance serves one `FederatedTrainer.run` (plans are a property of
    that run's schedule); the trainer rebuilds it per run and `close`s it
    in the run's finally block.
    """

    def __init__(self, clients: Sequence, *, mesh=None, shards: int = 1,
                 bucket_size: Callable[[int], int] | None = None,
                 max_clients: int | None = None,
                 counters: dict | None = None):
        self.clients = clients
        self.mesh = mesh
        self.shards = int(shards) if mesh is not None else 1
        self._bucket_size = bucket_size or (lambda n: int(n))
        self.max_clients = int(max_clients or len(clients))
        counts = getattr(clients, "counts", None)
        if counts is None:
            counts = [len(c) for c in clients]
        self.counts = np.asarray(counts, np.int64)
        self.n_max = int(self.counts.max())
        x0 = np.asarray(clients[0].x)
        self._xshape, self._xdtype = x0.shape[1:], x0.dtype
        self._ydtype = np.asarray(clients[0].y).dtype
        self.counters = counters if counters is not None \
            else fleet_counters_zero()
        self._lock = threading.Lock()
        self._resident = 0                 # bytes of built, un-dropped cohorts
        self._plans: list[tuple] = []      # (start, cids [K, C], counts [K])
        self._order: dict[int, int] = {}
        self._pending: dict[int, tuple] = {}   # plan idx -> (thread, box)
        self._live: dict[int, Cohort] = {}

    # -- planning / prefetch lifecycle --------------------------------------

    def schedule(self, plans: Sequence[tuple]) -> None:
        """Register the run's blocks in execution order and start
        prefetching the first two cohorts. Each plan is ``(start_round,
        cids [K, c_max] global ids incl. trainer padding, counts [K])`` —
        exactly the arrays `_exec_block` will pass to the engine, which is
        what makes the cohort schedule a pure function of the block plan
        (and therefore bit-for-bit reproducible across resumes)."""
        self._plans = list(plans)
        self._order = {int(p[0]): i for i, p in enumerate(self._plans)}
        self._launch(0)
        self._launch(1)

    def _launch(self, i: int) -> None:
        if i >= len(self._plans) or i in self._pending or i in self._live:
            return
        box: dict = {}
        th = threading.Thread(target=self._worker, args=(i, box), daemon=True)
        self._pending[i] = (th, box)
        th.start()

    def _worker(self, i: int, box: dict) -> None:
        try:
            cohort = self._build(*self._plans[i])
            with self._lock:
                self._resident += cohort.nbytes
                self.counters["peak_cohort_bytes"] = max(
                    self.counters["peak_cohort_bytes"], self._resident)
            box["cohort"] = cohort
        except BaseException as e:          # surfaced at acquire()
            box["error"] = e

    def acquire(self, start: int) -> Cohort:
        """Block on cohort `start` (stall time is the prefetch miss cost),
        retire earlier cohorts, and prefetch the next plan."""
        i = self._order[int(start)]
        for j in [j for j in self._live if j != i]:
            dropped = self._live.pop(j)
            with self._lock:
                self._resident -= dropped.nbytes
        if i not in self._live:
            self._launch(i)                 # miss: first block, or no prefetch
            th, box = self._pending.pop(i)
            t0 = time.perf_counter()
            th.join()
            self.counters["prefetch_stall_s"] += time.perf_counter() - t0
            err = box.get("error")
            if err is not None:
                raise err
            self._live[i] = box["cohort"]
        cohort = self._live[i]
        self.counters["n_cohort_swaps"] += 1
        self.counters["h2d_bytes"] += cohort.nbytes
        self._launch(i + 1)
        return cohort

    def close(self) -> None:
        """Join outstanding prefetches and drop every device buffer."""
        for th, _ in self._pending.values():
            th.join()
        self._pending.clear()
        self._live.clear()
        self._plans = []
        self._order = {}
        with self._lock:
            self._resident = 0

    # -- cohort construction ------------------------------------------------

    def _pack_into(self, x: np.ndarray, y: np.ndarray, rcounts: np.ndarray,
                   ids: np.ndarray, row0: int) -> None:
        """Copy clients `ids` into rows [row0, row0+len(ids)) of the padded
        host buffers — byte-copies of the rows a replicated ClientStore
        holds for the same clients (the bitwise anchor)."""
        for k, cid in enumerate(np.asarray(ids, np.int64)):
            c = self.clients[int(cid)]
            n = int(self.counts[cid])
            x[row0 + k, :n] = c.x
            y[row0 + k, :n] = c.y
            rcounts[row0 + k] = n

    def _alloc(self, rows: int) -> tuple[np.ndarray, np.ndarray, np.ndarray]:
        x = np.zeros((rows, self.n_max) + self._xshape, self._xdtype)
        y = np.zeros((rows, self.n_max), self._ydtype)
        return x, y, np.zeros(rows, np.int64)

    def _build(self, start: int, cids: np.ndarray,
               counts: np.ndarray) -> Cohort:
        cids = np.asarray(cids)
        if self.mesh is None or self.shards <= 1:
            return self._build_flat(int(start), cids)
        return self._build_sharded(int(start), cids, np.asarray(counts))

    def _build_flat(self, start: int, cids: np.ndarray) -> Cohort:
        ids = np.unique(cids).astype(np.int64)
        # pow2 row bucket capped at the population: distinct cohort sizes
        # reuse block traces on the same ladder the client axis does
        rows = max(len(ids), bucket_capacity(
            len(ids), shards=1, max_clients=self.max_clients))
        x, y, rcounts = self._alloc(rows)
        self._pack_into(x, y, rcounts, ids, 0)
        dx, dy = jax.device_put(x), jax.device_put(y)
        dx.block_until_ready()
        dy.block_until_ready()
        return Cohort(x=dx, y=dy, counts=rcounts, sharded=False,
                      ids_by_shard=[ids], per=int(cids.shape[1]),
                      start=start, nbytes=int(dx.nbytes + dy.nbytes))

    def _build_sharded(self, start: int, cids: np.ndarray,
                       counts: np.ndarray) -> Cohort:
        from jax.sharding import NamedSharding, PartitionSpec
        k, c_max = cids.shape
        c_b = self._bucket_size(int(counts.max()))
        per = max(1, c_b // self.shards)
        ids_by_shard = []
        for s in range(self.shards):
            lo, hi = s * per, min((s + 1) * per, c_max)
            cols = (cids[:, lo:hi] if hi > lo
                    else np.empty((k, 0), cids.dtype))
            ids_by_shard.append(np.unique(cols).astype(np.int64))
        cap = -(-self.max_clients // self.shards)
        rps = max(1, max(len(i) for i in ids_by_shard))
        rps = max(rps, bucket_capacity(rps, shards=1, max_clients=cap))
        x, y, rcounts = self._alloc(self.shards * rps)
        for s, ids in enumerate(ids_by_shard):
            self._pack_into(x, y, rcounts, ids, s * rps)
        sharding = NamedSharding(self.mesh, PartitionSpec("data"))
        dx = jax.device_put(x, sharding)
        dy = jax.device_put(y, sharding)
        dx.block_until_ready()
        dy.block_until_ready()
        return Cohort(x=dx, y=dy, counts=rcounts, sharded=True,
                      ids_by_shard=ids_by_shard, per=per, start=start,
                      nbytes=int(dx.nbytes + dy.nbytes))
