"""(P3): pruning-ratio optimization — an LP (paper Sec. IV-B-2).

With {a, p, f} fixed, theta is linear *increasing* in every lambda_n (the
gamma2 term), while the energy/delay constraints are linear *decreasing* in
lambda (every cost carries a (1 - lambda) factor). (P3) is therefore the LP

    min   sum_s  (gamma2 / N_sel_s) * sum_n a_ns lambda_ns
    s.t.  sum_s sum_n a_ns (1-lambda_ns) c^E_ns + bc_s           <= E0
          a_ns ( (1-lambda_ns) c^T_ns + t^dl_n ) <= tau_s,  forall n, s
          sum_s tau_s                                            <= T0
          0 <= lambda_ns <= lambda_max

solved exactly with scipy.optimize.linprog (HiGHS). Variables: the lambdas of
the selected (n, s) pairs plus one epigraph variable tau_s per round.
"""
from __future__ import annotations

import numpy as np
from scipy import optimize as sopt

from repro.core.convergence import BoundConstants
from repro.wireless.comm import (
    SystemParams, uplink_rate, downlink_rate, broadcast_energy,
)

_EPS = 1e-30


def solve_pruning_ratios(
    a: np.ndarray, p: np.ndarray, f: np.ndarray,
    e0: float, t0: float,
    h_up: np.ndarray, h_down: np.ndarray,
    sp: SystemParams, c: BoundConstants,
) -> tuple[np.ndarray, dict]:
    """Solve (P3). a, p, f: [S+1, N]. Returns lambda [S+1, N] and info dict."""
    a = np.atleast_2d(np.asarray(a, dtype=np.float64))
    p = np.atleast_2d(np.asarray(p, dtype=np.float64))
    f = np.atleast_2d(np.asarray(f, dtype=np.float64))
    n_rounds, n_cl = a.shape

    r_up = np.stack([uplink_rate(p[s], h_up, sp) for s in range(n_rounds)])
    r_dn = downlink_rate(h_down, sp)
    t_dl = sp.grad_bits / np.maximum(r_dn, _EPS)

    # Per-(s, n) cost coefficients multiplying (1 - lambda):
    ce = (sp.pue * sp.switched_cap * f**2 * sp.batch_size * sp.flops_per_sample
          / sp.flops_per_cycle) + p * sp.grad_bits / np.maximum(r_up, _EPS)
    ct = (sp.batch_size * sp.flops_per_sample / np.maximum(f * sp.flops_per_cycle, _EPS)
          + sp.grad_bits / np.maximum(r_up, _EPS))

    sel = [(s, n) for s in range(n_rounds) for n in range(n_cl) if a[s, n] > 0]
    n_lam = len(sel)
    if n_lam == 0:
        return np.zeros_like(a), {"status": "no-clients", "objective": 0.0}
    n_var = n_lam + n_rounds  # lambdas then taus

    cost = np.zeros(n_var)
    for j, (s, n) in enumerate(sel):
        n_sel = max(a[s].sum(), 1.0)
        cost[j] = c.gamma2 / n_sel

    a_ub, b_ub = [], []
    # Energy row: sum (1-lam) ce + broadcast <= E0  =>  -sum lam*ce <= E0 - sum ce - bc
    row = np.zeros(n_var)
    rhs = e0
    for j, (s, n) in enumerate(sel):
        row[j] = -ce[s, n]
        rhs -= ce[s, n]
    for s in range(n_rounds):
        if a[s].sum() > 0:
            rhs -= broadcast_energy(h_down, sp)
    a_ub.append(row)
    b_ub.append(rhs)
    # Delay epigraph rows: (1-lam) ct + t_dl <= tau_s
    for j, (s, n) in enumerate(sel):
        row = np.zeros(n_var)
        row[j] = -ct[s, n]
        row[n_lam + s] = -1.0
        a_ub.append(row)
        b_ub.append(-(ct[s, n] + t_dl[n]))
    # sum tau_s <= T0
    row = np.zeros(n_var)
    row[n_lam:] = 1.0
    a_ub.append(row)
    b_ub.append(t0)

    bounds = [(0.0, sp.lambda_max)] * n_lam + [(0.0, None)] * n_rounds
    res = sopt.linprog(cost, A_ub=np.array(a_ub), b_ub=np.array(b_ub),
                       bounds=bounds, method="highs")
    lam = np.zeros_like(a)
    if res.status == 0:
        for j, (s, n) in enumerate(sel):
            lam[s, n] = res.x[j]
        return lam, {"status": "optimal", "objective": float(res.fun)}
    # Infeasible under current (a, p, f): fall back to max pruning (cheapest
    # schedule); the AO outer loop will then adjust selection.
    for (s, n) in sel:
        lam[s, n] = sp.lambda_max
    return lam, {"status": "infeasible-fallback", "objective": float("inf")}
