"""Device-resident client datasets for the multi-round block engine.

`ClientStore` hoists every client's samples onto the device **once** as
padded ``[C, N_max, ...]`` buffers (one for inputs, one for labels) plus a
host-side per-client sample count. `RoundEngine.block_step` then samples
mini-batches *on device* by gathering host-drawn index arrays ``[K, C, B]``
— the per-round host→device upload of stacked batches (the last recurring
transfer inside the round loop) disappears, and only O(K·C·B) int32 indices
cross the boundary per K-round block.

The batch *indices* stay host-drawn from the trainer's existing numpy RNG —
one `rng.choice` call per (round, selected client), exactly the calls the
reference loop makes — so the block engine consumes the identical batch
sequence and the bit-for-bit parity contract with ``backend="reference"``
survives (values gathered on device from the store equal the values the
host would have fancy-indexed out of `ClientData`).

Padding rows (samples beyond a client's count) are zeros and are never
gathered: host-drawn indices are always < the client's count, and padding
*clients* on the bucketed client axis replicate a real client's id/indices.

Memory: the store holds ``C * N_max`` samples on device (vs one batch per
selected client for the per-round path). For edge-scale federations this is
small (the paper's MNIST/CIFAR splits are a few MB); `nbytes` reports the
footprint so callers can decide, and the trainer only builds the store when
block execution is actually enabled (``rounds_per_dispatch > 1``).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


@dataclasses.dataclass(frozen=True)
class ClientStore:
    """Padded on-device datasets: x [C, N_max, ...], y [C, N_max]."""

    x: jnp.ndarray
    y: jnp.ndarray
    counts: np.ndarray          # host [C] int — real samples per client

    @classmethod
    def build(cls, clients: Sequence) -> "ClientStore":
        """Pack `ClientData`-like objects (``.x``, ``.y`` numpy arrays) into
        one padded device buffer per field. Dtypes go through the same
        `jnp.asarray` canonicalization as the per-round upload path
        (float64 -> float32, int64 -> int32 under default jax config), so
        gathered batches are bitwise what the host would have uploaded."""
        counts = np.asarray([len(c) for c in clients], np.int64)
        n_max = int(counts.max())
        x0 = np.asarray(clients[0].x)
        y0 = np.asarray(clients[0].y)
        x = np.zeros((len(clients), n_max) + x0.shape[1:], x0.dtype)
        y = np.zeros((len(clients), n_max), y0.dtype)
        for i, c in enumerate(clients):
            x[i, : counts[i]] = c.x
            y[i, : counts[i]] = c.y
        return cls(x=jnp.asarray(x), y=jnp.asarray(y), counts=counts)

    @property
    def n_clients(self) -> int:
        return int(self.x.shape[0])

    @property
    def nbytes(self) -> int:
        return int(self.x.nbytes + self.y.nbytes)

    def replicated(self, mesh) -> "ClientStore":
        """Copy with (x, y) explicitly replicated over `mesh` (NamedSharding
        with an empty PartitionSpec), so the sharded block step never
        re-transfers the store: every device holds the full dataset and
        gathers only its shard's clients."""
        from repro.launch.mesh import replicate
        x, y = replicate((self.x, self.y), mesh)
        return ClientStore(x=x, y=y, counts=self.counts)

    def gather(self, cids, idx) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Device-side batch assembly: cids [C], idx [C, B] ->
        (x [C, B, ...], y [C, B]). Jittable; used inside the block scan."""
        return self.x[cids[:, None], idx], self.y[cids[:, None], idx]
