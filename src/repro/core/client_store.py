"""Device-resident client datasets for the multi-round block engine.

`ClientStore` hoists every client's samples onto the device **once** as
padded ``[C, N_max, ...]`` buffers (one for inputs, one for labels) plus a
host-side per-client sample count. `RoundEngine.block_step` then samples
mini-batches *on device* by gathering host-drawn index arrays ``[K, C, B]``
— the per-round host→device upload of stacked batches (the last recurring
transfer inside the round loop) disappears, and only O(K·C·B) int32 indices
cross the boundary per K-round block.

The batch *indices* stay host-drawn from the trainer's existing numpy RNG —
one `rng.choice` call per (round, selected client), exactly the calls the
reference loop makes — so the block engine consumes the identical batch
sequence and the bit-for-bit parity contract with ``backend="reference"``
survives (values gathered on device from the store equal the values the
host would have fancy-indexed out of `ClientData`).

Padding rows (samples beyond a client's count) are zeros and are never
gathered: host-drawn indices are always < the client's count, and padding
*clients* on the bucketed client axis replicate a real client's id/indices.

Memory: the store holds ``C * N_max`` samples on device (vs one batch per
selected client for the per-round path). For edge-scale federations this is
small (the paper's MNIST/CIFAR splits are a few MB); `nbytes` reports the
footprint so callers can decide, and the trainer only builds the store when
block execution is actually enabled (``rounds_per_dispatch > 1``).
"""
from __future__ import annotations

import dataclasses
from typing import Sequence

import jax
import jax.numpy as jnp
import numpy as np


class StoreBudgetError(RuntimeError):
    """A replicated ClientStore would blow the device-memory budget.

    Raised by `FederatedTrainer` / `Experiment.build` *before* the H2D
    transfer so the failure is actionable instead of an opaque device OOM."""

    def __init__(self, population: int, nbytes: int, budget: int):
        self.population = int(population)
        self.nbytes = int(nbytes)
        self.budget = int(budget)
        super().__init__(
            f"replicated ClientStore for {population} clients needs "
            f"~{nbytes / 2**20:.1f} MiB on every device, over the "
            f"{budget / 2**20:.1f} MiB device-memory budget. Use "
            f'client_store="streamed" (cohort streaming, RunSpec.client_store'
            f" / FederatedTrainer(client_store=...)) or raise the budget "
            f"(device_mem_budget / REPRO_DEVICE_MEM_BUDGET)."
        )


def _client_counts(clients: Sequence) -> np.ndarray:
    counts = getattr(clients, "counts", None)
    if counts is None:
        counts = [len(c) for c in clients]
    return np.asarray(counts, np.int64)


def _canonical_itemsize(dtype: np.dtype) -> int:
    """Bytes per element after jnp.asarray canonicalization (64-bit dtypes
    narrow to 32-bit unless jax_enable_x64 is set)."""
    dtype = np.dtype(dtype)
    if dtype.itemsize == 8 and not jax.config.jax_enable_x64:
        return 4
    return dtype.itemsize


def estimated_store_nbytes(clients: Sequence) -> int:
    """Device bytes a replicated ClientStore for `clients` would occupy,
    WITHOUT materializing the population: uses ``clients.store_nbytes()``
    when the sequence offers it (FleetRoster), else per-client counts plus
    one materialized client for shapes/dtypes."""
    sizer = getattr(clients, "store_nbytes", None)
    if callable(sizer):
        return int(sizer())
    counts = _client_counts(clients)
    n_max = int(counts.max())
    c0 = clients[0]
    x0 = np.asarray(c0.x)
    per_sample = (int(np.prod(x0.shape[1:])) * _canonical_itemsize(x0.dtype)
                  + _canonical_itemsize(np.asarray(c0.y).dtype))
    return len(counts) * n_max * per_sample


@dataclasses.dataclass(frozen=True)
class ClientStore:
    """Padded on-device datasets: x [C, N_max, ...], y [C, N_max]."""

    x: jnp.ndarray
    y: jnp.ndarray
    counts: np.ndarray          # host [C] int — real samples per client

    @classmethod
    def build(cls, clients: Sequence) -> "ClientStore":
        """Pack `ClientData`-like objects (``.x``, ``.y`` numpy arrays) into
        one padded device buffer per field. Dtypes go through the same
        `jnp.asarray` canonicalization as the per-round upload path
        (float64 -> float32, int64 -> int32 under default jax config), so
        gathered batches are bitwise what the host would have uploaded."""
        counts = _client_counts(clients)
        n_max = int(counts.max())
        x0 = np.asarray(clients[0].x)
        y0 = np.asarray(clients[0].y)
        x = np.zeros((len(counts), n_max) + x0.shape[1:], x0.dtype)
        y = np.zeros((len(counts), n_max), y0.dtype)
        # vectorized pack: one row-major boolean scatter per field fills each
        # client's prefix exactly like the per-client copy loop would
        mask = np.arange(n_max)[None, :] < counts[:, None]
        x[mask] = np.concatenate([np.asarray(c.x, x0.dtype) for c in clients])
        y[mask] = np.concatenate([np.asarray(c.y, y0.dtype) for c in clients])
        return cls(x=jnp.asarray(x), y=jnp.asarray(y), counts=counts)

    @property
    def n_clients(self) -> int:
        return int(self.x.shape[0])

    @property
    def nbytes(self) -> int:
        return int(self.x.nbytes + self.y.nbytes)

    def replicated(self, mesh) -> "ClientStore":
        """Copy with (x, y) explicitly replicated over `mesh` (NamedSharding
        with an empty PartitionSpec), so the sharded block step never
        re-transfers the store: every device holds the full dataset and
        gathers only its shard's clients."""
        from repro.launch.mesh import replicate
        x, y = replicate((self.x, self.y), mesh)
        return ClientStore(x=x, y=y, counts=self.counts)

    def gather(self, cids, idx) -> tuple[jnp.ndarray, jnp.ndarray]:
        """Device-side batch assembly: cids [C], idx [C, B] ->
        (x [C, B, ...], y [C, B]). Jittable; used inside the block scan."""
        return self.x[cids[:, None], idx], self.y[cids[:, None], idx]
