"""Parameter-efficient FedSGD engine (paper Sec. II-A, eqs. 2-7).

Per round s:
  1. server broadcasts the previous global gradient v^(s-1) (downlink, eq. 9);
  2. each selected client computes first-order importance Q = (v * rho)^2
     (eq. 4), prunes the lambda_n fraction of lowest-importance weights
     (eq. 2), yielding the pruned model w~_n;
  3. the client computes a mini-batch gradient on the pruned model (eq. 5)
     and uploads it masked (uplink, eq. 8 / delay eq. 11);
  4. the server averages the selected gradients (eq. 6) and takes the FedSGD
     step w <- w - eta * G (eq. 7).

The engine is model-agnostic: it needs only `loss_fn(params, x, y) -> scalar`.
Time/energy bookkeeping uses the wireless substrate with the schedule's
per-round (a, lambda, p, f).

Two execution backends (DESIGN.md §5):

  * ``backend="packed"`` (default) — the device-resident round engine
    (core/round_engine.py): parameters and the global gradient live in one
    packed [R, 128] buffer across rounds; threshold, masks, per-client
    gradients, aggregation, and the FedSGD step run in a single jitted
    dispatch per round with fused Pallas kernels. No host-side threshold
    computation (`np.partition`/`np.concatenate` over parameters) and no
    device->host parameter transfers inside the round loop.
  * ``backend="reference"`` — the original per-client Python loop (kept as
    the numerical oracle). With the XLA kernel path — what
    ``kernel_impl="auto"`` resolves to everywhere except TPU — the packed
    path reproduces it bit-for-bit on fp32 models (tests/test_packing.py);
    the TPU Pallas path may differ by 1 ulp per update (FMA contraction in
    the fused aggregate kernel, see kernels/ops.packed_fedsgd_update).

Noisy aggregation (beyond the paper, Wu et al.): with ``channel_noise``
set, the server observes ``mean(g) + noise`` instead of the clean
aggregate — the noisy value becomes both the FedSGD update and the next
round's broadcast v. Noise is drawn on host per ROUND INDEX in the packed
buffer layout and consumed identically by both backends and both dispatch
modes (see wireless/channel.GaussianAggregateNoise and DESIGN.md §9), so
the bit-for-bit contract below extends to noisy runs.

Ragged clients (fewer samples than the batch size): when the loss provides
a weighted form (`models.make_loss_fn` attaches one as ``loss.weighted``),
*both* backends evaluate that client via the weighted mean
``sum(sw*ce)/sum(sw)`` on a batch padded with zero-weight repeats — the
plain mean over the real samples in exact arithmetic, but evaluated at the
padded shape. This deliberately redefines the ragged-client oracle (the
pre-PR-2 reference took a plain mean over the short ``[B']`` batch, which
rounds differently because XLA reassociates reductions per shape): it is
the unique form the eager loop and the fused engine can agree on
bit-for-bit, so stragglers stay on the packed path (DESIGN.md §6). Without
a weighted loss, ragged rounds keep the pre-PR-2 short-batch behavior via
the reference fallback (`n_fallback_rounds` counts them).
"""
from __future__ import annotations

import dataclasses
import os
import time
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import pruning
from repro.core.client_store import (ClientStore, StoreBudgetError,
                                     estimated_store_nbytes)
from repro.core.cohort_store import CohortStore, fleet_counters_zero
from repro.core.local import local_spec_key
from repro.core.optimizer_ao import Schedule
from repro.core.packing import LANES, ParamPack
from repro.core.round_engine import RoundEngine, bucket_capacity
from repro.wireless.comm import SystemParams, per_client_delay, round_energy

PyTree = Any

# Block length the packed backend targets per dispatch when
# rounds_per_dispatch="auto" resolves to block execution (accelerators).
DEFAULT_ROUNDS_PER_DISPATCH = 32


def _resolve_rounds_per_dispatch(rpd) -> int:
    """"auto" -> 1 on CPU (rounds there are gradient-FLOP-bound and the
    per-round dispatch is the bit-for-bit-audited default for parity /
    reference work), DEFAULT_ROUNDS_PER_DISPATCH on accelerator backends
    (where the per-round dispatch + H2D upload dominates). Ints pass
    through; both block (>1) and per-round (1) modes are exact."""
    if rpd == "auto":
        return (1 if jax.default_backend() == "cpu"
                else DEFAULT_ROUNDS_PER_DISPATCH)
    r = int(rpd)
    if r < 1:
        raise ValueError(f"rounds_per_dispatch must be >= 1, got {rpd!r}")
    return r


def _default_device_budget() -> int:
    """Device-memory budget the "auto" client-store policy keys on:
    REPRO_DEVICE_MEM_BUDGET (bytes) when set, else a conservative 1 GiB —
    small enough that fleet-scale rosters stream, large enough that every
    edge-scale config in the repo keeps today's replicated store."""
    env = os.environ.get("REPRO_DEVICE_MEM_BUDGET")
    return int(env) if env else 1 << 30


@dataclasses.dataclass
class ClientData:
    x: np.ndarray
    y: np.ndarray

    def __len__(self) -> int:
        return len(self.y)

    def label_histogram(self, num_classes: int) -> np.ndarray:
        return np.bincount(self.y.astype(int), minlength=num_classes).astype(float)


@dataclasses.dataclass
class RoundMetrics:
    round: int
    train_loss: float
    selected: list[int]
    mean_lambda: float
    delay: float
    energy: float
    cumulative_delay: float
    cumulative_energy: float
    test_loss: float | None = None
    test_accuracy: float | None = None
    # graceful-degradation accounting (core/faults.py): uploads that never
    # arrived (dropout/straggler draw) and arrived-but-non-finite uploads
    # the engine's isfinite guard quarantined
    n_faulted: int = 0
    n_quarantined: int = 0
    # robust-aggregation accounting (core/aggregators.py): clients the
    # active robust reducer trimmed / clipped / excluded this round (the
    # aggregator's `stat_field` names which); always 0 on the mean path
    n_agg_adjusted: int = 0


class FederatedTrainer:
    """FedSGD with client selection + importance pruning + masked aggregation."""

    def __init__(
        self,
        loss_fn: Callable[[PyTree, jnp.ndarray, jnp.ndarray], jnp.ndarray],
        params: PyTree,
        clients: Sequence[ClientData],
        *,
        eta: float,
        batch_size: int,
        seed: int = 0,
        prune_spec: pruning.PruneSpec = pruning.PruneSpec(),
        backend: str = "packed",
        client_axis: str = "auto",
        kernel_impl: str = "auto",
        weighted_loss_fn: Callable | None = None,
        shards: int | None = None,
        rounds_per_dispatch: int | str = "auto",
        channel_noise=None,
        fault_model=None,
        aggregator=None,
        client_store: str = "auto",
        device_mem_budget: int | None = None,
        local_scheme=None,
    ):
        if backend not in ("packed", "reference"):
            raise ValueError(f"unknown backend {backend!r}")
        if client_store not in ("auto", "replicated", "streamed"):
            raise ValueError(f"unknown client_store {client_store!r}")
        self.loss_fn = loss_fn
        # sequences that publish per-client `counts` (FleetRoster) stay
        # lazy — list()-ing a 1e5-client roster would materialize the fleet
        self.clients = (clients if getattr(clients, "counts", None)
                        is not None else list(clients))
        self.eta = float(eta)
        self.batch_size = int(batch_size)
        self.rng = np.random.default_rng(seed)
        self.prune_spec = prune_spec
        self.backend = backend
        self._grad_fn = jax.jit(jax.value_and_grad(loss_fn))
        # Per-sample-weighted loss: lets ragged client batches (fewer
        # samples than the batch size) be padded with zero-weight samples
        # so they stay on the packed path. models.make_loss_fn attaches one
        # as loss_fn.weighted; custom losses can pass weighted_loss_fn
        # explicitly, otherwise ragged rounds fall back to the per-client
        # reference loop exactly as before (n_fallback_rounds counts them).
        self._weighted_loss = (weighted_loss_fn
                               or getattr(loss_fn, "weighted", None))
        self._wgrad_fn = (jax.jit(jax.value_and_grad(self._weighted_loss))
                          if self._weighted_loss is not None else None)
        self.n_fallback_rounds = 0
        # Block execution (rounds_per_dispatch > 1, packed backend only):
        # K consecutive schedule rounds run as ONE jitted lax.scan dispatch
        # with batches gathered on device from a ClientStore — no per-round
        # host sync, no per-round batch upload, K-1 of every K dispatches
        # gone. n_batch_uploads counts per-round host->device stacked-batch
        # transfers (the block path performs none — bench-asserted).
        self.rounds_per_dispatch = (
            _resolve_rounds_per_dispatch(rounds_per_dispatch)
            if backend == "packed" else 1)
        self._store: ClientStore | None = None
        self.n_batch_uploads = 0
        self.n_block_dispatches = 0
        # Fleet-scale client-store policy (core/cohort_store.py):
        # "replicated" keeps the PR-3 full ClientStore, "streamed" moves
        # per-block cohorts with double-buffered prefetch, "auto" picks by
        # the estimated replicated footprint vs the device-memory budget.
        # Streaming only moves data — the RNG/index protocol is untouched —
        # so streamed trajectories are bitwise the replicated ones.
        self.client_store = client_store
        self.device_mem_budget = (int(device_mem_budget)
                                  if device_mem_budget
                                  else _default_device_budget())
        self._store_nbytes: int | None = None
        self._cohorts: CohortStore | None = None
        self.streaming = False
        self.fleet_counters = fleet_counters_zero()
        # Noisy aggregation channel (wireless/channel.GaussianAggregateNoise
        # protocol: sample_packed(round, shape, valid)). Noise is drawn on
        # host keyed by the ROUND INDEX only, in the packed [R, 128] layout
        # (the reference backend unpacks the same buffer through a layout-
        # only ParamPack, built lazily), so both backends, both dispatch
        # modes, and resumed runs all consume identical draws.
        self.channel_noise = channel_noise
        self._noise_ref_pack: ParamPack | None = None
        self._noise_valid: np.ndarray | None = None
        # Client fault injection (core/faults.FaultModel protocol): draws
        # are host-side, keyed (seed, round, kind), attached to the round's
        # schedule info, and consumed identically by both backends — fault
        # runs stay bitwise packed-vs-reference. Counters accumulate at
        # materialization points (and checkpoint/restore with the batch
        # RNG, so resumed totals match an uninterrupted run).
        self.fault_model = fault_model
        self.fault_counters = {"n_dropped": 0, "n_quarantined": 0,
                               "n_skipped_rounds": 0, "n_corrupt_finite": 0}
        # Byzantine-robust aggregation (core/aggregators.py): an engine
        # construction constant, like eta — it changes every round graph,
        # so swapping reducers means a new trainer (Experiment.build /
        # the sweep pool key both fold `aggregator_key` in). None keeps
        # the builtin weighted-mean path byte-identical.
        self.aggregator = aggregator
        self.aggregator_key = (aggregator.spec_key
                               if aggregator is not None else "mean")
        self.agg_counters = ({aggregator.stat_field: 0}
                             if aggregator is not None else {})
        # Local-update scheme (core/local.py, DESIGN.md §14): None is the
        # single-step FedSGD body (today's paths, byte-identical). Like the
        # aggregator it is an engine construction constant — swapping
        # schemes means a new trainer, and `local_key` is the fragment the
        # sweep pool / Experiment.build reuse keys fold in so pooled
        # trainers can never mix per-client state across schemes.
        self.local_scheme = local_scheme
        self.local_key = local_spec_key(local_scheme)
        # FedDyn per-client correction state: one packed [R, 128] row per
        # client in the population, lazily allocated at first use (zeros)
        # on BOTH backends — the reference updates it with the same eager
        # jnp ops the engine fuses, so the state trajectories are bitwise
        # comparable. Rides checkpoints (repro.api.callbacks) for
        # bit-for-bit resume.
        self._h = None
        # lifecycle hooks for the current run() (repro.api.Callback
        # protocol); held on the instance so _exec_block can fire
        # on_block_end without threading them through every call
        self._callbacks: tuple = ()
        if backend == "packed":
            self.pack = ParamPack.build(params, prune_spec)
            # the trainer owns the packed buffers and reassigns them every
            # round, so donation is safe here
            self.engine = RoundEngine(loss_fn, self.pack, eta=self.eta,
                                      client_axis=client_axis,
                                      kernel_impl=kernel_impl, donate=True,
                                      weighted_loss_fn=self._weighted_loss,
                                      shards=shards,
                                      max_clients=len(self.clients),
                                      aggregator=aggregator,
                                      local_scheme=local_scheme)
            self._w, self._v = self.engine.init_buffers(params)
            # pytree views of the packed buffers, memoized on buffer
            # identity so repeated property reads (eval_fn, the ragged
            # fallback's client_update loop) don't rebuild the unpack graph
            self._w_view = self._v_view = None
        else:
            self.pack = self.engine = None
            self._params = params
            self._global_grad: PyTree = jax.tree.map(jnp.zeros_like, params)

    # Params / global gradient are stored packed on the packed backend; the
    # properties give both backends (and external callers) the same pytree
    # view. Writes pack straight back into the device-resident buffers.

    @property
    def params(self) -> PyTree:
        if self.backend == "packed":
            if self._w_view is None or self._w_view[0] is not self._w:
                self._w_view = (self._w, self.pack.unpack(self._w))
            return self._w_view[1]
        return self._params

    @params.setter
    def params(self, tree: PyTree) -> None:
        if self.backend == "packed":
            self._w = self.pack.pack(tree)
            self._w_view = None
        else:
            self._params = tree

    @property
    def global_grad(self) -> PyTree:
        if self.backend == "packed":
            if self._v_view is None or self._v_view[0] is not self._v:
                self._v_view = (self._v, self.pack.unpack(self._v))
            return self._v_view[1]
        return self._global_grad

    @global_grad.setter
    def global_grad(self, tree: PyTree) -> None:
        if self.backend == "packed":
            self._v = self.pack.pack(tree)
            self._v_view = None
        else:
            self._global_grad = tree

    # -- run-state lifecycle ------------------------------------------------

    def reset(self, params: PyTree, seed: int, *, channel_noise=None,
              fault_model=None) -> None:
        """Reinitialize all run state for a FRESH run over the same
        (clients, loss, eta, batch, backend, shards) wiring — the sweep
        engine's trainer-reuse hook (repro.api.sweep). Compiled engine
        traces and the device-resident ClientStore survive, which is what
        makes an S-seed sweep cost far less than S cold trainers; params,
        the global gradient, the batch RNG, and every counter are reset
        exactly as the constructor would, so a reused trainer's trajectory
        is bit-for-bit a cold one's."""
        self.rng = np.random.default_rng(seed)
        self.channel_noise = channel_noise
        self.fault_model = fault_model
        self.fault_counters = {"n_dropped": 0, "n_quarantined": 0,
                               "n_skipped_rounds": 0, "n_corrupt_finite": 0}
        self.agg_counters = ({self.aggregator.stat_field: 0}
                             if self.aggregator is not None else {})
        self.n_fallback_rounds = 0
        self.n_batch_uploads = 0
        self.n_block_dispatches = 0
        self._callbacks = ()
        # zero the fleet counters IN PLACE: a run's CohortStore accumulates
        # into this dict by reference
        self.fleet_counters.update(fleet_counters_zero())
        self.streaming = False
        self._cohorts = None
        # per-client optimizer state MUST NOT survive pooling: a reused
        # trainer carrying the previous cell's FedDyn correction buffer
        # would silently bias the next run (the regression test in
        # tests/test_local_schemes.py pins pooled == cold byte-identical).
        # Dropping the buffer (rather than zeroing in place) also frees
        # the device memory until the next stateful run touches it.
        self._h = None
        if self.engine is not None:
            self.engine.last_h = None
        if self.backend == "packed":
            self._w, self._v = self.engine.init_buffers(params)
            self._w_view = self._v_view = None
        else:
            self._params = params
            self._global_grad = jax.tree.map(jnp.zeros_like, params)

    # -- noisy aggregation channel ------------------------------------------

    def _noise_layout(self) -> ParamPack:
        """The packed layout noise is drawn in: the engine's pack on the
        packed backend; a lazily built layout-only pack on the reference
        backend (ParamPack.build is pure metadata — no buffers)."""
        if self.pack is not None:
            return self.pack
        if self._noise_ref_pack is None:
            self._noise_ref_pack = ParamPack.build(self._params,
                                                   self.prune_spec)
        return self._noise_ref_pack

    def _noise_packed(self, s: int) -> np.ndarray:
        """Round-s aggregation noise as a packed [R, 128] host array with
        padding lanes zeroed (they hold no real coordinates and must stay
        zero in the buffers)."""
        pack = self._noise_layout()
        if self._noise_valid is None:
            self._noise_valid = pack.valid_mask()
        return self.channel_noise.sample_packed(
            s, (pack.rows, LANES), self._noise_valid)

    def _noise_tree(self, s: int) -> PyTree:
        """The same round-s draw as a pytree (reference backend): unpack is
        a pure gather of the packed draw, so per-coordinate values are
        identical to what the packed engine adds."""
        return self._noise_layout().unpack(jnp.asarray(self._noise_packed(s)))

    def _poison_stack(self, fault) -> np.ndarray | None:
        """Materialize a fault draw's lazy additive poison in the packed
        [C_sel, R, 128] layout (padding lanes masked to 0.0), shared by
        both backends — the reference unpacks the identical rows, so
        per-coordinate poison values match the packed engine's exactly
        (the GaussianPoison analog of `_noise_packed`)."""
        if fault is None or getattr(fault, "poison", None) is None:
            return None
        pack = self._noise_layout()
        if self._noise_valid is None:
            self._noise_valid = pack.valid_mask()
        return fault.poison((pack.rows, LANES), self._noise_valid)

    # -- per-client optimizer state (FedDyn) --------------------------------

    def _ensure_h(self) -> jnp.ndarray:
        """The FedDyn correction state [C_all, R, 128], zeros at first use.
        Device-resident for both backends (the reference updates it with
        eager jnp scatters). NOTE: the buffer covers the full population —
        fleet-scale rosters should not run stateful schemes yet (the
        streamed path moves data cohorts, not optimizer state slabs beyond
        the per-block gather below)."""
        if self._h is None:
            pack = self._noise_layout()
            self._h = jnp.zeros((len(self.clients), pack.rows, LANES),
                                jnp.float32)
        return self._h

    # -- round primitives ---------------------------------------------------

    def _draw_indices(self, count: int) -> np.ndarray:
        """THE batch-index draw — one `choice` call per (round, selected
        client), shared by the per-round path (which gathers on host) and
        the block path (which ships the indices to the on-device gather).
        Keeping the call in one place is what pins both paths to the same
        RNG stream, which the bit-for-bit contract depends on. Takes the
        client's sample COUNT, not the client: the block path over a fleet
        roster draws indices without ever materializing the client's data
        (the cohort prefetcher does that, off-thread)."""
        count = int(count)
        return self.rng.choice(
            count, size=min(self.batch_size, count),
            replace=count < self.batch_size)

    def _client_len(self, n: int) -> int:
        """Sample count of client n without materializing it: rosters
        publish a host-resident `counts` array; plain client lists fall
        back to len()."""
        counts = getattr(self.clients, "counts", None)
        return int(counts[n]) if counts is not None else len(self.clients[n])

    def _sample_batch(
        self, client: ClientData,
    ) -> tuple[jnp.ndarray, jnp.ndarray, np.ndarray]:
        """Draw one mini-batch: (x, y, sample_weights).

        A client smaller than the batch size yields a short batch; when a
        weighted loss is available the batch is padded back to batch_size
        with repeated samples carrying weight 0, so every client's batch is
        stackable and the round stays on the packed path. The RNG stream is
        identical to the unpadded draw (one `choice` call either way)."""
        idx = self._draw_indices(len(client))
        x, y = client.x[idx], client.y[idx]
        n = len(idx)
        if n < self.batch_size and self._weighted_loss is not None:
            pad = self.batch_size - n
            x = np.concatenate([x, np.repeat(x[-1:], pad, axis=0)])
            y = np.concatenate([y, np.repeat(y[-1:], pad, axis=0)])
            sw = np.zeros(self.batch_size, np.float32)
            sw[:n] = 1.0
        else:
            sw = np.ones(n, np.float32)
        return jnp.asarray(x), jnp.asarray(y), sw

    def client_update(
        self, n: int, lam: float,
        batch: tuple | None = None,
    ) -> tuple[PyTree, PyTree, float]:
        """Steps 2-3 for client n: returns (masked gradient, mask, loss)."""
        if lam > 0.0:
            imp = pruning.taylor_importance(self.params, self.global_grad)
            masks = pruning.build_masks(imp, lam, self.prune_spec)
        else:
            masks = jax.tree.map(
                lambda w: jnp.ones_like(w, dtype=jnp.float32), self.params)
        pruned = pruning.apply_masks(self.params, masks)
        if batch is None:
            batch = self._sample_batch(self.clients[n])
        x, y, sw = batch if len(batch) == 3 else (*batch, None)
        if sw is None or sw.all():
            # full batch: the plain mean loss, byte-identical to the seed
            loss, grads = self._grad_fn(pruned, x, y)
        else:
            # ragged client: the same weighted mean the packed engine
            # computes, so the two backends stay bit-for-bit comparable
            loss, grads = self._wgrad_fn(pruned, x, y, jnp.asarray(sw))
        grads = pruning.apply_masks(grads, masks)  # pruned coords not uploaded
        return grads, masks, float(loss)

    def _client_update_local(self, n: int, lam: float, batches: list,
                             h_row=None):
        """Eager reference body for the local-update scheme zoo (DESIGN.md
        §14), mirroring the packed step scan op for op: E local steps from
        the pruned start u0 = w*mask, each taking a masked gradient at the
        CURRENT iterate, folding in the scheme's regularizer, accumulating
        the direction into the upload (from a zeros accumulator, so the
        first add normalizes -0.0 exactly like the engine's), and stepping
        `u <- u - eta*d`. Every jnp op here is its own eager dispatch, so
        each product rounds to fp32 exactly where the engine fences it.

        `batches`: the client's E drawn batches in step order. `h_row`:
        the client's packed FedDyn state row (or None); its pytree view is
        an exact gather through the layout pack. Returns (upload tree,
        loss at step 0, packed FedDyn state delta or None)."""
        ls = self.local_scheme
        if lam > 0.0:
            imp = pruning.taylor_importance(self.params, self.global_grad)
            masks = pruning.build_masks(imp, lam, self.prune_spec)
        else:
            masks = jax.tree.map(
                lambda w: jnp.ones_like(w, dtype=jnp.float32), self.params)
        u0 = pruning.apply_masks(self.params, masks)
        u = u0
        acc = jax.tree.map(jnp.zeros_like, u0)
        hm = None
        if h_row is not None:
            hm = pruning.apply_masks(
                self._noise_layout().unpack(jnp.asarray(h_row)), masks)
        coeff = jnp.float32(ls.coeff)
        loss0 = None
        for t, batch in enumerate(batches):
            x, y, sw = batch if len(batch) == 3 else (*batch, None)
            if sw is None or sw.all():
                loss, g = self._grad_fn(u, x, y)
            else:
                loss, g = self._wgrad_fn(u, x, y, jnp.asarray(sw))
            if t == 0:
                loss0 = float(loss)
            g = pruning.apply_masks(g, masks)
            if ls.name == "fedavg":
                d = g
            else:
                # coeff*(u - u0): two eager dispatches (sub, then the
                # product) — the rounding sequence the engine's FMA fence
                # reproduces inside its fused graph
                prox = jax.tree.map(lambda a, b: coeff * (a - b), u, u0)
                d = jax.tree.map(lambda gt, p: gt + p, g, prox)
                if ls.stateful:
                    d = jax.tree.map(lambda dt, m: dt - m, d, hm)
            acc = jax.tree.map(lambda a, dt: a + dt, acc, d)
            u = jax.tree.map(lambda ut, dt: ut - self.eta * dt, u, d)
        hd = None
        if ls.stateful:
            alpha = jnp.float32(ls.alpha)
            hd = self._noise_layout().pack(
                jax.tree.map(lambda a, b: alpha * (a - b), u, u0))
        return acc, loss0, hd

    def server_step(self, grads: list[PyTree], noise: PyTree | None = None) -> None:
        """Eqs. (6)-(7): average selected gradients, FedSGD update.
        `noise` (a pytree, `_noise_tree`) models the noisy aggregation
        channel: the server observes mean(g) + noise and both broadcasts
        and updates with it.

        Deliberately eager: each op runs as its own dispatch, so eta*g is
        rounded to fp32 before the subtraction. The packed engine blocks
        FMA contraction of the same pair inside its fused graph, which is
        what makes the two backends bit-identical (see round_engine)."""
        if not grads:
            return
        inv = 1.0 / len(grads)
        g = grads[0]
        for extra in grads[1:]:
            g = jax.tree.map(lambda acc, e: acc + e, g, extra)
        g = jax.tree.map(lambda t: t * inv, g)
        if noise is not None:
            g = jax.tree.map(lambda t, nz: t + nz, g, noise)
        self.global_grad = g
        self.params = jax.tree.map(
            lambda w, gg: w - self.eta * gg.astype(w.dtype), self.params, g)

    def _reference_round(self, selected: list[int], lam_s: np.ndarray,
                         batches: list, s: int = 0, fault=None):
        """Original per-client loop: steps 2-4 with host-side thresholds.

        The fault draw is applied EAGERLY, mirroring the packed engine op
        for op: every selected client still computes its update (identical
        RNG stream), corruption factors scale the upload, uploads that
        never arrived are dropped before aggregation, and — the eager form
        of the engine's always-on isfinite guard — a non-finite upload is
        quarantined host-side. `server_step` over the survivors then
        renormalizes by their count (and early-returns when none survive),
        which is the semantics the packed guard reproduces on device.
        With a robust ``aggregator`` the round instead routes through
        `_reference_robust_round` — the eager mirror of the engine's
        robust reduce over the same bucket-padded packed stack.
        Returns (per-client losses, surviving upload count, agg stat —
        None on the mean path)."""
        if self.aggregator is not None:
            return self._reference_robust_round(selected, lam_s, batches,
                                                s=s, fault=fault)
        grads, losses = [], []
        ok = (np.asarray(fault.upload_ok, bool) if fault is not None
              else np.ones(len(selected), bool))
        cf = fault.corrupt if fault is not None else None
        po = self._poison_stack(fault)
        ls = self.local_scheme
        dyn = ls is not None and ls.stateful
        hbuf = self._ensure_h() if dyn else None
        surv_ids, surv_hds = [], []
        for j, (n, batch) in enumerate(zip(selected, batches)):
            if ls is None:
                g, _, loss = self.client_update(n, float(lam_s[n]),
                                                batch=batch)
                hd = None
            else:
                g, loss, hd = self._client_update_local(
                    n, float(lam_s[n]), batch,
                    h_row=hbuf[n] if dyn else None)
            losses.append(loss)
            if not ok[j]:
                continue                     # the upload never arrived
            if cf is not None:
                g = jax.tree.map(
                    lambda t, c=np.float32(cf[j]): t * c, g)
            if po is not None:
                # applied to EVERY arriving upload (zeros for clean
                # clients), mirroring the engine's stack-wide add — the
                # `g + 0.0` normalization of -0.0 then matches bitwise
                pz = self._noise_layout().unpack(jnp.asarray(po[j]))
                g = jax.tree.map(lambda t, z: t + z, g, pz)
            if all(bool(jnp.all(jnp.isfinite(leaf)))
                   for leaf in jax.tree_util.tree_leaves(g)):
                grads.append(g)
                if dyn:
                    # the state only moves for arrived-AND-finite uploads
                    # (post-fault — exactly the engine's cw_eff gate)
                    surv_ids.append(n)
                    surv_hds.append(hd)
        self.server_step(
            grads,
            noise=self._noise_tree(s) if self.channel_noise else None)
        if surv_ids:
            # one scatter-add contribution per surviving row — bitwise the
            # engine's h.at[cid].add (padding rows there contribute exact
            # +0.0, a no-op)
            self._h = hbuf.at[jnp.asarray(np.asarray(surv_ids, np.int32))
                              ].add(-jnp.stack(surv_hds))
        return losses, len(grads), None

    def _reference_robust_round(self, selected: list[int], lam_s: np.ndarray,
                                batches: list, s: int = 0, fault=None):
        """Eager robust round — the reference oracle for a non-mean
        aggregator, mirroring the packed engine op for op over the SAME
        bucket-padded [C_b, R, 128] stack (DESIGN.md §11):

        every selected client's masked gradient is packed at its
        selected-order position (packing is a pure scatter, so the rows are
        bitwise the engine's), faults apply as ``cf * g + poison``, the
        effective weight is ``arrived & finite`` (the eager isfinite
        quarantine), padding rows are zero with weight 0 — the reducers
        are weight-aware and bucket-capacity invariant, so zero padding
        and the engine's replicated-batch padding give identical bits.
        The SAME `Aggregator.reduce` then runs eagerly, and the update is
        the eager form of the engine's fenced inv=1.0 tail: ``ghat (+
        noise)`` becomes the broadcast v and ``w - eta*v`` the step (the
        separate eager multiply rounds exactly like the fence). A round
        with no survivors skips the update (server_step's empty-grads
        early return)."""
        pack = self._noise_layout()
        ok = (np.asarray(fault.upload_ok, bool) if fault is not None
              else np.ones(len(selected), bool))
        cf = fault.corrupt if fault is not None else None
        po = self._poison_stack(fault)
        ls = self.local_scheme
        dyn = ls is not None and ls.stateful
        hbuf = self._ensure_h() if dyn else None
        losses, gps, cws, hds = [], [], [], []
        for j, (n, batch) in enumerate(zip(selected, batches)):
            if ls is None:
                g, _, loss = self.client_update(n, float(lam_s[n]),
                                                batch=batch)
                hd = None
            else:
                g, loss, hd = self._client_update_local(
                    n, float(lam_s[n]), batch,
                    h_row=hbuf[n] if dyn else None)
            losses.append(loss)
            hds.append(hd)
            gp = pack.pack(g)
            if cf is not None:
                gp = gp * jnp.float32(cf[j])
            if po is not None:
                gp = gp + jnp.asarray(po[j])
            fin = bool(jnp.all(jnp.isfinite(gp)))
            gps.append(gp)
            cws.append(1.0 if (ok[j] and fin) else 0.0)
        c_b = bucket_capacity(len(selected),
                              max_clients=len(self.clients))
        zero = jnp.zeros((pack.rows, LANES), jnp.float32)
        gps += [zero] * (c_b - len(selected))
        cws += [0.0] * (c_b - len(selected))
        stack = jnp.stack(gps)
        cw = jnp.asarray(np.asarray(cws, np.float32))
        ghat, ast = self.aggregator.reduce(stack, cw)
        n_ok = int(np.asarray(cws).sum())
        if dyn:
            ids = [n for n, c in zip(selected, cws) if c > 0]
            if ids:
                self._h = hbuf.at[jnp.asarray(np.asarray(ids, np.int32))
                                  ].add(-jnp.stack(
                                      [h for h, c in zip(hds, cws)
                                       if c > 0]))
        if n_ok > 0:
            g = pack.unpack(ghat)
            if self.channel_noise:
                g = jax.tree.map(lambda t, nz: t + nz, g,
                                 self._noise_tree(s))
            self.global_grad = g
            self.params = jax.tree.map(
                lambda w, gg: w - self.eta * gg.astype(w.dtype),
                self.params, g)
        return losses, n_ok, ast

    def _round(self, selected: list[int], lam_s: np.ndarray, s: int = 0,
               fault=None):
        """Steps 2-4 for one round; batches are drawn once, in selected
        order, so both backends consume the identical RNG sequence.

        Returns the per-client losses *without* synchronizing: a device
        array on the packed path (materialized lazily by `run`, so rounds
        pipeline on accelerators), a list of floats on the reference path.
        With a weighted loss every batch is padded to batch_size, so ragged
        clients and round-to-round varying selection sizes all stay on the
        packed path (the engine buckets the client axis); the reference
        fallback only fires for custom losses without a weighted form.

        Returns (losses, n_ok, ast): n_ok is the surviving weighted-upload
        count — a lazy device scalar on the packed path (the engine's
        `last_n_ok`), an int on the reference path — materialized with the
        losses to drive the fault counters; ast is the robust aggregator's
        per-round diagnostic count (None on the mean path)."""
        ls = self.local_scheme
        if ls is None:
            batches = [self._sample_batch(self.clients[n]) for n in selected]
            stackable = len({b[0].shape for b in batches}) <= 1
        else:
            # E draws per (round, client), client-major — THE step-batch
            # RNG order, identical on the packed, block, and reference
            # paths (the bit-for-bit contract's multi-step extension)
            batches = [[self._sample_batch(self.clients[n])
                        for _ in range(ls.steps)] for n in selected]
            stackable = len({b[0].shape
                             for bs in batches for b in bs}) <= 1
        if self.backend != "packed" or not stackable:
            if self.backend == "packed":
                self.n_fallback_rounds += 1
            return self._reference_round(selected, lam_s, batches, s=s,
                                         fault=fault)
        lam_sel = np.asarray([lam_s[n] for n in selected], np.float64)
        if ls is None:
            xs = jnp.stack([b[0] for b in batches])
            ys = jnp.stack([b[1] for b in batches])
            sws = np.stack([b[2] for b in batches])
        else:
            xs = jnp.stack([jnp.stack([b[0] for b in bs])
                            for bs in batches])
            ys = jnp.stack([jnp.stack([b[1] for b in bs])
                            for bs in batches])
            sws = np.stack([np.stack([b[2] for b in bs])
                            for bs in batches])
        extra = {}
        if ls is not None and ls.stateful:
            extra = dict(h=self._ensure_h(),
                         client_ids=np.asarray(selected, np.int32))
        self.n_batch_uploads += 1
        self._w, self._v, losses, _, _ = self.engine.round_step(
            self._w, self._v, xs, ys, lam_sel,
            # all-ones weights carry no information: skip the transfer and
            # let the engine materialize them on device
            sample_weights=None if sws.all() else sws,
            noise=self._noise_packed(s) if self.channel_noise else None,
            upload_weights=(fault.upload_ok.astype(np.float32)
                            if fault is not None else None),
            corrupt=fault.corrupt if fault is not None else None,
            poison=self._poison_stack(fault), **extra)
        if extra:
            self._h = self.engine.last_h
        ast = (self.engine.last_agg_stat if self.aggregator is not None
               else None)
        return losses, self.engine.last_n_ok, ast

    # -- block execution ----------------------------------------------------

    def store_nbytes(self) -> int:
        """Estimated device footprint of a REPLICATED ClientStore for this
        trainer's clients (cached; never materializes a roster)."""
        if self._store_nbytes is None:
            self._store_nbytes = estimated_store_nbytes(self.clients)
        return self._store_nbytes

    def store_mode(self) -> str:
        """The resolved client-store policy: "replicated" or "streamed"
        ("auto" keys on the estimated footprint vs device_mem_budget)."""
        if self.client_store != "auto":
            return self.client_store
        return ("replicated" if self.store_nbytes() <= self.device_mem_budget
                else "streamed")

    def check_store_budget(self) -> None:
        """OOM guard: raise the actionable StoreBudgetError when block
        execution would build a replicated store over the device-memory
        budget (an explicit client_store="replicated" on a fleet-scale
        roster — "auto" streams instead). Called by Experiment.build at
        spec time and by _ensure_store right before the H2D transfer."""
        if (self.backend == "packed" and self.rounds_per_dispatch > 1
                and self.store_mode() == "replicated"
                and self.store_nbytes() > self.device_mem_budget):
            raise StoreBudgetError(len(self.clients), self.store_nbytes(),
                                   self.device_mem_budget)

    def _ensure_store(self) -> ClientStore:
        """Build (once) the device-resident dataset store the block path
        gathers batches from; replicated over the engine's mesh when the
        client axis is sharded, so shards never re-transfer the data."""
        if self._store is None:
            self.check_store_budget()
            store = ClientStore.build(self.clients)
            if self.engine is not None and self.engine.mesh is not None:
                store = store.replicated(self.engine.mesh)
            self._store = store
        return self._store

    def _block_key(self, selected: list[int], lam_s: np.ndarray):
        """Homogeneity key for grouping consecutive rounds into one block
        (client-axis bucket, lambda family, drawn batch length) — or None
        when the round cannot take the block path (empty selection, or
        mixed batch lengths without a weighted loss: those rounds fall to
        the per-round path, which handles them exactly as before)."""
        if not selected:
            return None
        lens = [min(self.batch_size, self._client_len(n)) for n in selected]
        if self._weighted_loss is not None:
            blen = self.batch_size       # ragged clients pad to batch_size
        elif len(set(lens)) == 1:
            blen = lens[0]               # uniformly short: packed, no pad
        else:
            return None                  # per-round path -> reference fallback
        ks = np.floor(np.asarray([lam_s[n] for n in selected], np.float64)
                      * self.pack.n_prunable).astype(np.int32)
        shared = bool((ks == ks[0]).all())
        return (self.engine.bucket_size(len(selected)), shared, blen)

    def _plan_blocks(self, infos, boundaries: set, rpd: int,
                     first_round: int = 0) -> dict:
        """Partition the (truncated) schedule into blocks: {start: K}.

        Rounds group while their _block_key matches; a run always ends at
        a boundary round — an eval round or a checkpoint round (both read
        coherent state AFTER that round, so a block may not span one).
        Each homogeneous run is then decomposed into power-of-two chunks
        of at most `rpd` rounds — decomposition rather than padding,
        because a padded round would cost a full round of gradient FLOPs —
        which keeps compiled block lengths on a pow2 ladder
        (<= log2(rpd)+1 distinct K per (bucket, family) pair).
        `first_round` skips already-executed rounds when resuming from a
        checkpoint."""
        blocks: dict[int, int] = {}
        n = len(infos)
        i = first_round
        while i < n:
            key = self._block_key(infos[i][0], infos[i][1])
            if key is None:
                i += 1
                continue
            j = i
            while j < n and self._block_key(infos[j][0], infos[j][1]) == key:
                j += 1
                if (j - 1) in boundaries:
                    break
            start, left = i, j - i
            while left:
                k = 1 << (min(left, rpd).bit_length() - 1)
                blocks[start] = k
                start += k
                left -= k
            i = j
        return blocks

    def _block_cids(self, start: int, n_rounds: int,
                    infos) -> tuple[np.ndarray, np.ndarray]:
        """The block's stacked GLOBAL client ids [K, c_max] (trainer
        padding included — rows pad by replicating the round's last real
        client, exactly what _exec_block executes) plus per-round real
        counts [K]. Selection-pure — consumes NO RNG — so the cohort store
        can plan every block's cohort before execution starts, which is
        what makes prefetch schedules (and resume) deterministic."""
        sels = [infos[start + k][0] for k in range(n_rounds)]
        counts = np.asarray([len(s) for s in sels], np.int64)
        c_max = int(counts.max())
        cids = np.empty((n_rounds, c_max), np.int32)
        for k, sel in enumerate(sels):
            cids[k, :len(sel)] = sel
            cids[k, len(sel):] = sel[-1]
        return cids, counts

    def _exec_block(self, start: int, n_rounds: int, infos,
                    out: dict) -> None:
        """Run rounds [start, start+n_rounds) as one engine.block_step
        dispatch; per-round loss slices (still device arrays) land in
        `out`. Indices are drawn from self.rng with the identical
        `choice` calls — same order, same arguments — that the per-round
        path's _sample_batch would make, so the batch sequence is
        bit-for-bit the reference one."""
        sels = [infos[start + k][0] for k in range(n_rounds)]
        cids, counts = self._block_cids(start, n_rounds, infos)
        c_max = int(counts.max())
        blen = self._block_key(sels[0], infos[start][1])[2]
        # multi-step schemes draw an E-deep index stack per (round, client)
        # — same RNG calls, same round -> client -> step order as the
        # per-round path, so the batch stream stays bit-for-bit shared
        ls = self.local_scheme
        if ls is None:
            idxs = np.empty((n_rounds, c_max, blen), np.int32)
            sw = np.ones((n_rounds, c_max, blen), np.float32)
        else:
            idxs = np.empty((n_rounds, c_max, ls.steps, blen), np.int32)
            sw = np.ones((n_rounds, c_max, ls.steps, blen), np.float32)
        lams = np.empty((n_rounds, c_max), np.float64)
        # host-drawn fault masks join the stacked [K, C] schedule operands
        # (ones = clean defaults, exact no-ops on device) whenever a fault
        # model is active — one upload per block, zero per-round H2D
        fault_on = self.fault_model is not None
        pos = None
        if fault_on:
            fw = np.ones((n_rounds, c_max), np.float32)
            cfa = np.ones((n_rounds, c_max), np.float32)
            # the additive-poison stack is built lazily: zero until some
            # round in the block actually flagged a byzantine client, so
            # clean blocks never allocate the [K, C, R, L] operand
            if any(infos[start + k][6] is not None
                   and infos[start + k][6].poison is not None
                   for k in range(n_rounds)):
                pack = self._noise_layout()
                pos = np.zeros((n_rounds, c_max, pack.rows, LANES),
                               np.float32)
        any_ragged = False
        for k, sel in enumerate(sels):
            lam_s = infos[start + k][1]
            if fault_on:
                fault = infos[start + k][6]
                if fault is not None:
                    fw[k, :len(sel)] = np.asarray(fault.upload_ok,
                                                  np.float32)
                    if fault.corrupt is not None:
                        cfa[k, :len(sel)] = fault.corrupt
                    if pos is not None and fault.poison is not None:
                        pos[k, :len(sel)] = self._poison_stack(fault)
            for j, n in enumerate(sel):
                lams[k, j] = lam_s[n]
                for t in range(1 if ls is None else ls.steps):
                    row = idxs[k, j] if ls is None else idxs[k, j, t]
                    swr = sw[k, j] if ls is None else sw[k, j, t]
                    draw = self._draw_indices(self._client_len(n))
                    m = len(draw)
                    if m < blen:         # ragged: repeat last drawn sample
                        row[:m] = draw              # with weight 0, exactly
                        row[m:] = draw[-1]          # like _sample_batch
                        swr[m:] = 0.0
                        any_ragged = True
                    else:
                        row[:] = draw
            c_k = len(sel)               # pad rows to c_max by replicating
            idxs[k, c_k:] = idxs[k, c_k - 1]     # the round's last client
            sw[k, c_k:] = sw[k, c_k - 1]         # (cids padded identically
            lams[k, c_k:] = lam_s[sel[-1]]       # by _block_cids)
        dyn = ls is not None and ls.stateful
        slab_ids = None
        h_arg = None
        if self._cohorts is not None:
            # streamed path: this block's prefetched cohort stands in for
            # the full store; global ids remap to cohort-local rows (the
            # index DRAWS above are layout-independent, so the RNG stream
            # — and the bitwise contract — is untouched)
            store = self._cohorts.acquire(start)
            cids = store.remap(cids)
            if dyn:
                # FedDyn state slab, cohort-swap protocol: slice the rows
                # of this cohort's clients in cohort-row order (remapped
                # cids index the slab exactly like the data buffers);
                # padded slab rows replicate the last client — remapped
                # ids never reference them, and only the unique prefix is
                # scattered back, so the slab round-trip is an exact copy
                ids = np.asarray(store.ids_by_shard[0], np.int64)
                rows = len(store.counts)
                gidx = np.concatenate(
                    [ids, np.full(rows - len(ids), ids[-1], np.int64)])
                slab_ids = ids
                h_arg = self._ensure_h()[jnp.asarray(gidx)]
        else:
            store = self._ensure_store()
            if dyn:
                h_arg = self._ensure_h()
        noises = (np.stack([self._noise_packed(start + k)
                            for k in range(n_rounds)])
                  if self.channel_noise else None)
        self._w, self._v, losses, _ = self.engine.block_step(
            self._w, self._v, store, cids, idxs, lams, counts,
            sample_weights=sw if any_ragged else None, noises=noises,
            upload_weights=fw if fault_on else None,
            corrupt=cfa if fault_on else None, poisons=pos, h=h_arg)
        if dyn:
            if slab_ids is None:
                self._h = self.engine.last_h
            else:
                self._h = self._h.at[jnp.asarray(slab_ids)].set(
                    self.engine.last_h[:len(slab_ids)])
        n_oks = self.engine.last_n_ok        # [K] lazy survivor counts
        asts = (self.engine.last_agg_stat    # [K] lazy reducer diagnostics
                if self.aggregator is not None else None)
        self.n_block_dispatches += 1
        for k in range(n_rounds):
            out[start + k] = (losses[k, : int(counts[k])], n_oks[k],
                              asts[k] if asts is not None else None)
        # fires right after the dispatch returns: the block's losses are
        # still lazy device arrays, so hooks here never force a sync
        for cb in self._callbacks:
            cb.on_block_end(start, n_rounds, self)

    # -- full run -----------------------------------------------------------

    def run(
        self,
        schedule: Schedule,
        sp: SystemParams,
        h_up: np.ndarray,
        h_down: np.ndarray,
        *,
        eval_fn: Callable[[PyTree], tuple[float, float]] | None = None,
        eval_every: int = 10,
        stop_delay: float | None = None,
        stop_energy: float | None = None,
        callbacks: Sequence = (),
        start_round: int = 0,
    ) -> list[RoundMetrics]:
        """Execute the schedule. eval_fn(params) -> (test_loss, test_acc).

        ``eval_fn``/``eval_every`` are the LEGACY direct-use evaluation
        path, kept for hand-wired callers; new code should drive runs
        through the experiment API (repro.api), whose RunSpec configures
        them and layers the callback protocol on top.

        ``callbacks`` take objects following the repro.api.Callback
        protocol. Hooks fire at MATERIALIZATION points only — they never
        force a per-round device sync (see repro.api.callbacks):

          * ``on_round_end(m, self)`` — once per round, in order, batched
            at the next materialization point (m.train_loss is set);
          * ``on_eval(m, self)`` — at eval rounds, after eval_fn;
          * ``on_block_end(start, k, self)`` — after each block dispatch;
          * ``on_checkpoint(m, self)`` — at rounds where ``m.round %
            cb.checkpoint_every == 0``. Those rounds become block
            boundaries and materialization points, so trainer state there
            is exactly the state after round m.round (what bit-for-bit
            checkpoint/resume requires).

        ``start_round`` skips execution of rounds before it (their
        wireless bookkeeping is still computed, keeping cumulative
        counters, stop truncation, and eval cadence bitwise identical to
        an uninterrupted run): with params/global-grad/batch-RNG restored
        from a checkpoint taken after round ``start_round - 1``, the
        remaining trajectory replays bit-for-bit on fp32 — the resume
        contract the experiment API builds on. The returned history covers
        only the executed rounds.

        Per-round train losses are kept as device arrays and materialized
        lazily (at eval/checkpoint points and at the end of the run): the
        packed round then never blocks on a device->host sync, so
        consecutive rounds pipeline on accelerators instead of
        serializing on `float(loss)`.

        With ``rounds_per_dispatch > 1`` (packed backend) the schedule is
        consumed in multi-round BLOCKS: the wireless bookkeeping and stop
        conditions are schedule-pure, so they are precomputed, the
        surviving rounds are partitioned into homogeneous blocks ending at
        eval/checkpoint points (`_plan_blocks`), and each block runs as a
        single `RoundEngine.block_step` dispatch with batches sampled on
        device — no per-round dispatch, host sync, or batch upload.
        Per-round metrics, eval cadence, stop behavior, and the training
        trajectory (bit-for-bit on fp32 single-device) are unchanged.
        """
        callbacks = tuple(callbacks)
        self._callbacks = callbacks
        history: list[RoundMetrics] = []
        # rounds whose train_loss / survivor count are still unmaterialized
        # device values: (metrics, losses, n_ok, fault draw, agg stat)
        pending: list[tuple[RoundMetrics, Any, Any, Any, Any]] = []

        def materialize():
            for m, losses, n_ok, fault, ast in pending:
                mask = (np.asarray(fault.upload_ok, bool)
                        if fault is not None else None)
                if losses is not None:
                    # float64 mean over the synced fp32 values — identical
                    # to the old eager np.mean over a list of floats;
                    # restricted to the uploads that arrived (the server
                    # never observes a dropped client's loss)
                    arr = np.asarray(losses, np.float64)
                    if mask is not None:
                        arr = arr[mask]
                    m.train_loss = float(arr.mean()) if arr.size else float("nan")
                n_sel = len(m.selected)
                n_up = int(mask.sum()) if mask is not None else n_sel
                m.n_faulted = n_sel - n_up
                if n_ok is not None:
                    ok = int(n_ok)
                    # on the robust path the quarantine count folds the
                    # reducer's survivor arithmetic the same way: n_ok is
                    # still "weighted clients whose upload stayed finite"
                    m.n_quarantined = max(0, n_up - ok)
                    if n_sel and ok == 0:
                        self.fault_counters["n_skipped_rounds"] += 1
                self.fault_counters["n_dropped"] += m.n_faulted
                self.fault_counters["n_quarantined"] += m.n_quarantined
                # corrupt-but-FINITE arrivals: damage the isfinite guard
                # cannot see (satellite of the quarantine's documented
                # blind spot) — counted host-side from the draw so reports
                # stop under-counting corruption. `.get` keeps restores of
                # pre-PR-7 checkpoints (no such key) working.
                if fault is not None:
                    ncf = 0
                    arrived = (mask if mask is not None
                               else np.ones(n_sel, bool))
                    if fault.corrupt is not None:
                        cfv = np.asarray(fault.corrupt, np.float64)
                        ncf += int((arrived & np.isfinite(cfv)
                                    & (cfv != 1.0)).sum())
                    flags = getattr(fault.poison, "flags", None)
                    if flags is not None:
                        ncf += int((arrived & np.asarray(flags, bool)).sum())
                    self.fault_counters["n_corrupt_finite"] = (
                        self.fault_counters.get("n_corrupt_finite", 0) + ncf)
                if ast is not None and self.aggregator is not None:
                    m.n_agg_adjusted = int(ast)
                    sf = self.aggregator.stat_field
                    self.agg_counters[sf] = (self.agg_counters.get(sf, 0)
                                             + m.n_agg_adjusted)
                for cb in callbacks:
                    cb.on_round_end(m, self)
            pending.clear()

        n_rounds = schedule.a.shape[0]
        # Per-round host bookkeeping is schedule-pure (independent of
        # training state), so compute it — and the stop-condition
        # truncation — up front; the block partition then only has to
        # respect eval boundaries.
        infos = []
        cum_t = cum_e = 0.0
        for s in range(n_rounds):
            a_s, lam_s = schedule.a[s], schedule.lam[s]
            p_s, f_s = schedule.power[s], schedule.freq[s]
            selected = [int(i) for i in np.flatnonzero(a_s > 0)]
            # per-client tau_n + tau^_n feed both the round deadline (the
            # gated max is round_delay's expression verbatim — bitwise
            # identical bookkeeping) and the straggler fault model's
            # judgment against that deadline
            per = per_client_delay(lam_s, p_s, f_s, h_up, h_down, sp)
            gated = np.asarray(a_s, np.float64) * per
            d = float(gated.max()) if gated.size else 0.0
            e = round_energy(a_s, lam_s, p_s, f_s, h_up, h_down, sp)
            cum_t += d
            cum_e += e
            fault = None
            if self.fault_model is not None and selected:
                sel_arr = np.asarray(selected, int)
                fault = self.fault_model.draw(
                    s, len(self.clients), sel_arr,
                    delays=per[sel_arr], deadline=d)
            infos.append((selected, lam_s, d, e, cum_t, cum_e, fault))
            if stop_delay is not None and cum_t >= stop_delay:
                break
            if stop_energy is not None and cum_e >= stop_energy:
                break

        # Checkpoint rounds (repro.api.Callback.checkpoint_every): these
        # become materialization points and block boundaries so the hook
        # observes state coherent at exactly that round.
        def _ckpt_cbs(s: int) -> list:
            return [cb for cb in callbacks
                    if getattr(cb, "checkpoint_every", None)
                    and s % cb.checkpoint_every == 0]

        ckpt_rounds = {s for s in range(start_round, len(infos))
                       if _ckpt_cbs(s)}

        blocks: dict[int, int] = {}
        if self.rounds_per_dispatch > 1 and self.backend == "packed":
            boundaries = set(ckpt_rounds)
            if eval_fn is not None:
                boundaries |= {s for s in range(len(infos))
                               if s % eval_every == 0}
                boundaries.add(n_rounds - 1)
            blocks = self._plan_blocks(infos, boundaries,
                                       self.rounds_per_dispatch,
                                       first_round=start_round)

        self.streaming = False
        self._cohorts = None
        if blocks and self.store_mode() == "streamed":
            # cohort plans are a pure function of the block partition
            # (selection-only, no RNG), so a resumed run — same infos, same
            # first_round — replays the identical cohort schedule bit for
            # bit; prefetch of the first two cohorts starts here, before
            # any round executes
            self._cohorts = CohortStore(
                self.clients, mesh=self.engine.mesh,
                shards=self.engine.shards,
                bucket_size=self.engine.bucket_size,
                max_clients=len(self.clients),
                counters=self.fleet_counters)
            self._cohorts.schedule(
                [(st, *self._block_cids(st, blocks[st], infos))
                 for st in sorted(blocks)])
            self.streaming = True

        block_losses: dict[int, Any] = {}
        try:
            for s, (selected, lam_s, d, e, cum_t, cum_e,
                    fault) in enumerate(infos):
                if s < start_round:
                    continue   # already executed before the checkpoint
                if s in blocks:
                    self._exec_block(s, blocks[s], infos, block_losses)
                if s in block_losses:
                    losses, n_ok, ast = block_losses.pop(s)
                elif selected:
                    losses, n_ok, ast = self._round(selected, lam_s, s=s,
                                                    fault=fault)
                else:
                    losses = n_ok = ast = None
                m = RoundMetrics(
                    round=s,
                    train_loss=float("nan"),
                    selected=selected,
                    mean_lambda=(float(lam_s[selected].mean())
                                 if selected else 0.0),
                    delay=d, energy=e,
                    cumulative_delay=cum_t, cumulative_energy=cum_e,
                )
                pending.append((m, losses, n_ok, fault, ast))
                is_eval = (eval_fn is not None
                           and (s % eval_every == 0 or s == n_rounds - 1))
                if is_eval or s in ckpt_rounds:
                    materialize()  # eval/ckpt sync anyway; drain the backlog
                    if is_eval:
                        m.test_loss, m.test_accuracy = eval_fn(self.params)
                        for cb in callbacks:
                            cb.on_eval(m, self)
                    for cb in _ckpt_cbs(s):
                        cb.on_checkpoint(m, self)
                history.append(m)
            materialize()
        finally:
            # a raising hook (e.g. a simulated kill after a checkpoint)
            # must not leave stale callback refs on the long-lived trainer;
            # the cohort store's prefetch threads and device buffers go
            # with it (self.streaming stays set for result surfacing)
            self._callbacks = ()
            if self._cohorts is not None:
                self._cohorts.close()
                self._cohorts = None
        return history
