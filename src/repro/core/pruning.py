"""Model pruning: importance scores (eqs. 3-4) and mask construction.

The paper prunes, per selected client and round, the fraction lambda_n of model
weights with the *lowest* importance, where importance is the first-order
Taylor surrogate (eq. 4):

    Q_{n,m} = (v_m^{(s-1)} * rho_{n,m}^{(s-1)})^2

(v = global gradient of weight m from the previous round, rho = the weight).
The exact squared-loss-difference score (eq. 3) is also provided as the oracle
the surrogate approximates — tests verify their Spearman agreement on small
models.

Masks are pytrees of {0,1} arrays congruent with the parameter pytree. Only
tensors whose path matches `prunable` predicates are maskable (embeddings,
norm scales and router weights are protected — see DESIGN.md §4).
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

PyTree = Any

# Parameters whose leaf-path contains one of these substrings are never pruned.
PROTECTED_SUBSTRINGS = (
    "embed", "norm", "scale", "bias", "router", "gate_logit", "pos_emb",
    "a_log", "dt",  # SSM time-constant / decay params: tiny & dynamics-critical
)


def default_prunable(path: str) -> bool:
    p = path.lower()
    return not any(s in p for s in PROTECTED_SUBSTRINGS)


def _flatten_with_paths(tree: PyTree) -> list[tuple[str, jnp.ndarray]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    return [(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]


def taylor_importance(params: PyTree, grads: PyTree) -> PyTree:
    """Eq. (4): Q = (v * rho)^2, elementwise over the whole pytree."""
    return jax.tree.map(lambda w, g: (w * g) ** 2, params, grads)


def exact_importance(
    loss_fn: Callable[[PyTree], jnp.ndarray], params: PyTree
) -> PyTree:
    """Eq. (3): Q_m = (L(w) - L(w|rho_m=0))^2 — the O(M) oracle.

    Only usable on tiny models (tests); evaluates the loss once per scalar.
    """
    base = float(loss_fn(params))
    leaves, treedef = jax.tree_util.tree_flatten(params)
    out = []
    for i, leaf in enumerate(leaves):
        flat = np.asarray(leaf).ravel().copy()
        scores = np.zeros_like(flat, dtype=np.float64)
        for j in range(flat.size):
            saved = flat[j]
            flat[j] = 0.0
            pert = leaves.copy()
            pert[i] = jnp.asarray(flat.reshape(leaf.shape), leaf.dtype)
            scores[j] = (base - float(loss_fn(
                jax.tree_util.tree_unflatten(treedef, pert)))) ** 2
            flat[j] = saved
        out.append(jnp.asarray(scores.reshape(leaf.shape), jnp.float32))
    return jax.tree_util.tree_unflatten(treedef, out)


@dataclasses.dataclass(frozen=True)
class PruneSpec:
    """Which tensors may be pruned."""

    prunable: Callable[[str], bool] = default_prunable


def global_threshold(
    importance: PyTree, lam: float, spec: PruneSpec = PruneSpec()
) -> float:
    """k-th smallest importance over all prunable leaves, k = lam * M_prunable.

    Weights with importance strictly below the threshold are pruned; this
    realizes 'remove the lambda fraction of lowest-importance weights'.
    """
    if not (0.0 <= lam < 1.0):
        raise ValueError(f"lambda must be in [0,1), got {lam}")
    vals = [np.asarray(v).ravel()
            for pth, v in _flatten_with_paths(importance) if spec.prunable(pth)]
    if not vals or lam == 0.0:
        return -np.inf
    allv = np.concatenate(vals)
    k = int(np.floor(lam * allv.size))
    if k <= 0:
        return -np.inf
    # threshold such that exactly k entries are strictly below it
    part = np.partition(allv, k - 1)
    return float(np.nextafter(part[k - 1], np.inf))


def build_masks(
    importance: PyTree, lam: float, spec: PruneSpec = PruneSpec()
) -> PyTree:
    """Binary {0,1} masks: 0 = pruned. Non-prunable leaves get all-ones."""
    thr = global_threshold(importance, lam, spec)

    def leaf_mask(pth: str, q: jnp.ndarray) -> jnp.ndarray:
        if not spec.prunable(pth) or thr == -np.inf:
            return jnp.ones_like(q, dtype=jnp.float32)
        return (q >= thr).astype(jnp.float32)

    flat, treedef = jax.tree_util.tree_flatten_with_path(importance)
    masks = [leaf_mask(jax.tree_util.keystr(kp), leaf) for kp, leaf in flat]
    return jax.tree_util.tree_unflatten(treedef, masks)


def apply_masks(params: PyTree, masks: PyTree) -> PyTree:
    """w~ = w * mask (pruned model of eq. (2))."""
    return jax.tree.map(lambda w, m: w * m.astype(w.dtype), params, masks)


def actual_ratio(masks: PyTree, spec: PruneSpec = PruneSpec()) -> float:
    """Realized pruning ratio lambda = pruned / prunable."""
    pruned = total = 0
    for pth, m in _flatten_with_paths(masks):
        if spec.prunable(pth):
            m = np.asarray(m)
            total += m.size
            pruned += int((m == 0).sum())
    return pruned / total if total else 0.0


def pruning_distortion(params: PyTree, masks: PyTree) -> tuple[float, float]:
    """(||w - w~||^2, ||w||^2) — checks Assumption 4:
    E||w - w~||^2 <= lambda * E||w||^2 when masks drop the smallest-magnitude
    coordinates; with Taylor importance it holds in expectation and is asserted
    statistically in tests."""
    d2 = n2 = 0.0
    for w, m in zip(jax.tree.leaves(params), jax.tree.leaves(masks)):
        w = np.asarray(w, dtype=np.float64)
        m = np.asarray(m, dtype=np.float64)
        d2 += float(((w * (1 - m)) ** 2).sum())
        n2 += float((w**2).sum())
    return d2, n2
