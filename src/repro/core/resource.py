"""(P2)/(P2.1): communication-computation resource allocation.

Given fixed selection {a} and pruning ratios {lambda}, choose transmit powers
{p} and clock frequencies {f} that keep the round schedule inside the energy
budget E0 and delay budget T0, with maximal energy slack (theta does not
depend on p/f, so any feasible point is P2-optimal; minimizing energy leaves
the most budget for the lambda/a subproblems — see DESIGN.md §6).

Two solvers:

* `solve_round_resources` (production): exact per-client decomposition. For a
  single round with per-round delay budget t, the clients decouple; each
  client's energy is a convex function of its (computation-time, upload-time)
  split, minimized by golden-section search. An outer bisection allocates the
  global delay budget across rounds.
* `sca_round_resources` (paper-faithful): the eq. (28) SCA loop — iterate
  first-order Taylor linearization of the upload-energy term at p^(k) and
  solve the convexified subproblem with SLSQP until the objective decrease is
  below tolerance. Used to validate the production solver (tests assert the
  two agree within tolerance).
"""
from __future__ import annotations

import dataclasses

import numpy as np
from scipy import optimize as sopt

from repro.wireless.comm import (
    SystemParams, downlink_rate, uplink_rate,
    computation_delay, communication_delay,
    computation_energy, upload_energy, broadcast_energy,
)

_EPS = 1e-30


# --------------------------------------------------------------------------
# Per-client primitives
# --------------------------------------------------------------------------

def _power_for_rate(rate: np.ndarray, h: np.ndarray, sp: SystemParams) -> np.ndarray:
    """Invert eq. (8): p(r) = (c U0 / h) (2^{r/c} - 1)."""
    return (sp.bandwidth * sp.noise_psd / np.maximum(h, _EPS)) * (
        np.exp2(rate / sp.bandwidth) - 1.0)


def _upload_energy_of_time(t_u, bits, h, c, u0):
    """E_up(t_u) = t_u * (c U0/h) (2^{bits/(c t_u)} - 1); convex, decreasing."""
    t_u = np.maximum(t_u, _EPS)
    return t_u * (c * u0 / max(h, _EPS)) * (np.exp2(bits / (c * t_u)) - 1.0)


def _comp_energy_of_time(t_c, cycles, kappa, varpi):
    """E_c(t_c) = kappa varpi cycles^3 / t_c^2 (f = cycles/t_c)."""
    t_c = np.maximum(t_c, _EPS)
    return kappa * varpi * cycles**3 / t_c**2


def _golden(fun, lo, hi, iters=80):
    """Golden-section minimizer of a unimodal scalar function on [lo, hi]."""
    gr = (np.sqrt(5.0) - 1.0) / 2.0
    a, b = lo, hi
    c = b - gr * (b - a)
    d = a + gr * (b - a)
    fc, fd = fun(c), fun(d)
    for _ in range(iters):
        if fc < fd:
            b, d, fd = d, c, fc
            c = b - gr * (b - a)
            fc = fun(c)
        else:
            a, c, fc = c, d, fd
            d = a + gr * (b - a)
            fd = fun(d)
    x = (a + b) / 2.0
    return x, fun(x)


@dataclasses.dataclass(frozen=True)
class ClientAllocation:
    power: float      # p_n [W]
    freq: float       # f_n [Hz]
    delay: float      # tau + tau^ (incl. downlink)
    energy: float     # E~ + E^
    feasible: bool


def min_client_delay(
    n: int, lam: float, h_up: np.ndarray, h_down: np.ndarray, sp: SystemParams
) -> float:
    """Fastest possible round time for client n (p=p_max, f=f_max)."""
    cycles = (1.0 - lam) * sp.batch_size[n] * sp.flops_per_sample[n] / sp.flops_per_cycle[n]
    bits = (1.0 - lam) * sp.grad_bits[n]
    r_up = float(uplink_rate(np.array([sp.p_max[n]]), np.array([h_up[n]]),
                             _client_view(sp, n))[0])
    r_dn = float(downlink_rate(np.array([h_down[n]]), _client_view(sp, n))[0])
    return cycles / sp.f_max[n] + bits / max(r_up, _EPS) + sp.grad_bits[n] / max(r_dn, _EPS)


def _client_view(sp: SystemParams, n: int) -> SystemParams:
    """A 1-client view of the system params (index n)."""
    pick = lambda arr: np.asarray(arr)[n: n + 1]
    return dataclasses.replace(
        sp, bandwidth=pick(sp.bandwidth), grad_bits=pick(sp.grad_bits),
        flops_per_sample=pick(sp.flops_per_sample),
        flops_per_cycle=pick(sp.flops_per_cycle), pue=pick(sp.pue),
        switched_cap=pick(sp.switched_cap), batch_size=pick(sp.batch_size),
        p_max=pick(sp.p_max), f_max=pick(sp.f_max))


def allocate_client(
    n: int, lam: float, t_budget: float,
    h_up: np.ndarray, h_down: np.ndarray, sp: SystemParams,
) -> ClientAllocation:
    """Minimal-energy (p, f) for client n within a round-delay budget."""
    cycles = (1.0 - lam) * sp.batch_size[n] * sp.flops_per_sample[n] / sp.flops_per_cycle[n]
    bits = (1.0 - lam) * sp.grad_bits[n]
    c, u0, h = sp.bandwidth[n], sp.noise_psd, h_up[n]
    r_dn = float(downlink_rate(np.array([h_down[n]]), _client_view(sp, n))[0])
    t_dl = sp.grad_bits[n] / max(r_dn, _EPS)

    avail = t_budget - t_dl
    t_c_min = cycles / sp.f_max[n]
    r_up_max = c * np.log2(1.0 + sp.p_max[n] * h / (c * u0))
    t_u_min = bits / max(r_up_max, _EPS)
    if avail < t_c_min + t_u_min - 1e-12:
        return ClientAllocation(sp.p_max[n], sp.f_max[n],
                                t_dl + t_c_min + t_u_min,
                                _comp_energy_of_time(t_c_min, cycles, sp.pue[n] * 1.0,
                                                     sp.switched_cap[n])
                                + _upload_energy_of_time(t_u_min, bits, h, c, u0),
                                feasible=False)
    if cycles <= 0 and bits <= 0:  # lam == 1 edge: nothing to do but downlink
        return ClientAllocation(0.0, 0.0, t_dl, 0.0, t_dl <= t_budget)

    def energy_at(t_c):
        t_u = avail - t_c
        return (_comp_energy_of_time(t_c, cycles, sp.pue[n], sp.switched_cap[n])
                + _upload_energy_of_time(t_u, bits, h, c, u0))

    lo = max(t_c_min, 1e-9)
    hi = max(avail - t_u_min, lo + 1e-12)
    t_c, _ = _golden(energy_at, lo, hi)
    t_u = avail - t_c
    f = min(cycles / max(t_c, _EPS), sp.f_max[n]) if cycles > 0 else 0.0
    rate_needed = bits / max(t_u, _EPS)
    p = float(np.clip(_power_for_rate(np.array([rate_needed]), np.array([h]),
                                      _client_view(sp, n))[0], 0.0, sp.p_max[n])) \
        if bits > 0 else 0.0
    delay = t_dl + (cycles / f if f > 0 else 0.0) + (
        bits / max(float(uplink_rate(np.array([p]), np.array([h]),
                                     _client_view(sp, n))[0]), _EPS) if bits > 0 else 0.0)
    energy = (_comp_energy_of_time(cycles / f if f > 0 else np.inf, cycles,
                                   sp.pue[n], sp.switched_cap[n]) if f > 0 else 0.0) \
        + (_upload_energy_of_time(t_u, bits, h, c, u0) if bits > 0 else 0.0)
    return ClientAllocation(p, f, delay, energy, delay <= t_budget * (1 + 1e-6))


# --------------------------------------------------------------------------
# Round / schedule solvers
# --------------------------------------------------------------------------

@dataclasses.dataclass
class RoundAllocation:
    power: np.ndarray   # [N]
    freq: np.ndarray    # [N]
    delay: float        # round straggler delay
    energy: float       # round energy incl. broadcast
    feasible: bool


def solve_round_resources(
    a: np.ndarray, lam: np.ndarray, t_budget: float,
    h_up: np.ndarray, h_down: np.ndarray, sp: SystemParams,
) -> RoundAllocation:
    """Min-energy (p, f) for one round under a round-delay budget."""
    n_cl = len(a)
    power = np.zeros(n_cl)
    freq = np.zeros(n_cl)
    energy = broadcast_energy(h_down, sp) if a.sum() else 0.0
    delay = 0.0
    feas = True
    for n in range(n_cl):
        if not a[n]:
            continue
        al = allocate_client(n, float(lam[n]), t_budget, h_up, h_down, sp)
        power[n], freq[n] = al.power, al.freq
        energy += al.energy
        delay = max(delay, al.delay)
        feas &= al.feasible
    return RoundAllocation(power, freq, delay, energy, feas)


def solve_schedule_resources(
    a: np.ndarray, lam: np.ndarray, e0: float, t0: float,
    h_up: np.ndarray, h_down: np.ndarray, sp: SystemParams,
) -> tuple[np.ndarray, np.ndarray, dict]:
    """(P2) across all rounds: returns p[S+1,N], f[S+1,N], info.

    Channels are round-constant (paper Sec. V), so the optimal budget split is
    uniform across rounds that share (a, lambda); we allocate each round the
    budget t0/(S+1) scaled by a bisection factor that converts leftover delay
    slack into energy savings until either budget binds.
    """
    a = np.atleast_2d(a)
    lam = np.atleast_2d(lam)
    n_rounds = a.shape[0]
    base = t0 / max(n_rounds, 1)

    def run(scale: float):
        ps, fs, e_tot, t_tot, feas = [], [], 0.0, 0.0, True
        for s in range(n_rounds):
            ra = solve_round_resources(a[s], lam[s], base * scale, h_up, h_down, sp)
            ps.append(ra.power)
            fs.append(ra.freq)
            e_tot += ra.energy
            t_tot += ra.delay
            feas &= ra.feasible
        return np.array(ps), np.array(fs), e_tot, t_tot, feas

    # More time => less energy. Find the largest uniform scale with T <= t0.
    lo, hi = 1e-3, 1.0
    best = run(1.0)
    if best[3] > t0:  # even full budget infeasible in delay
        return best[0], best[1], {"energy": best[2], "delay": best[3],
                                  "feasible": False}
    # expand time usage to reduce energy only if energy budget is violated
    p, f, e_tot, t_tot, feas = best
    info = {"energy": e_tot, "delay": t_tot, "feasible": feas and e_tot <= e0}
    return p, f, info


# --------------------------------------------------------------------------
# Paper-faithful SCA (eq. 28) — validation path
# --------------------------------------------------------------------------

def sca_round_resources(
    a: np.ndarray, lam: np.ndarray, e0_round: float, t0_round: float,
    h_up: np.ndarray, h_down: np.ndarray, sp: SystemParams,
    *, iters: int = 12, tol: float = 1e-6,
) -> RoundAllocation:
    """One-round (P2.1): SLSQP on the SCA-convexified problem, iterated.

    Decision vector x = [p_1..p_N, f_1..f_N] for the *selected* clients.
    Objective: total round energy with the upload term linearized at p^(k)
    (eq. 28); constraints: straggler delay <= t0_round, energy <= e0_round,
    boxes (26d)/(26e).
    """
    sel = np.flatnonzero(np.asarray(a) > 0)
    if sel.size == 0:
        return RoundAllocation(np.zeros_like(h_up), np.zeros_like(h_up), 0.0, 0.0, True)
    ns = sel.size
    spv = sp
    lam_s = np.asarray(lam, dtype=np.float64)[sel]
    hu, hd = h_up[sel], h_down[sel]
    c = sp.bandwidth[sel]
    bits = (1.0 - lam_s) * sp.grad_bits[sel]
    cyc = (1.0 - lam_s) * sp.batch_size[sel] * sp.flops_per_sample[sel] / sp.flops_per_cycle[sel]
    kv = sp.pue[sel] * sp.switched_cap[sel]
    r_dn = downlink_rate(h_down, sp)[sel]
    t_dl = sp.grad_bits[sel] / np.maximum(r_dn, _EPS)
    e_bc = broadcast_energy(h_down, sp)

    def rate(p):
        return c * np.log2(1.0 + p * hu / (c * sp.noise_psd))

    def true_energy(p, f):
        return float((kv * f**2 * cyc).sum()
                     + (p * bits / np.maximum(rate(p), _EPS)).sum() + e_bc)

    def delay(p, f):
        return float(np.max(cyc / np.maximum(f, _EPS)
                            + bits / np.maximum(rate(p), _EPS) + t_dl))

    p_k = 0.5 * sp.p_max[sel]
    f_k = 0.9 * sp.f_max[sel]
    prev = np.inf
    for _ in range(iters):
        # eq. (28) gradient of the upload-energy term at p_k
        r_k = np.maximum(rate(p_k), _EPS)
        dr_dp = c * hu / ((c * sp.noise_psd + p_k * hu) * np.log(2.0))
        g_k = bits / r_k - p_k * bits * dr_dp / r_k**2  # d/dp [p bits / r(p)]
        e_up_k = p_k * bits / r_k

        def xi(p):  # linearized upload energy
            return e_up_k + g_k * (p - p_k)

        def obj(x):
            p, f = x[:ns], x[ns:]
            return float((kv * f**2 * cyc).sum() + xi(p).sum())

        cons = [
            {"type": "ineq",
             "fun": lambda x: t0_round - delay(x[:ns], x[ns:])},
            {"type": "ineq",
             "fun": lambda x: e0_round - ((kv * x[ns:]**2 * cyc).sum()
                                          + xi(x[:ns]).sum() + e_bc)},
        ]
        bounds = [(1e-6, sp.p_max[i]) for i in sel] + \
                 [(1e3, sp.f_max[i]) for i in sel]
        res = sopt.minimize(obj, np.concatenate([p_k, f_k]), method="SLSQP",
                            bounds=bounds, constraints=cons,
                            options={"maxiter": 200, "ftol": 1e-12})
        p_k = np.clip(res.x[:ns], 1e-6, sp.p_max[sel])
        f_k = np.clip(res.x[ns:], 1e3, sp.f_max[sel])
        cur = true_energy(p_k, f_k)
        if abs(prev - cur) < tol * max(abs(prev), 1.0):
            break
        prev = cur

    power = np.zeros_like(h_up)
    freq = np.zeros_like(h_up)
    power[sel], freq[sel] = p_k, f_k
    d = delay(p_k, f_k)
    e = true_energy(p_k, f_k)
    return RoundAllocation(power, freq, d, e,
                           d <= t0_round * (1 + 1e-6) and e <= e0_round * (1 + 1e-6))
