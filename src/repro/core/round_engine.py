"""Device-resident federated round engine (paper Sec. II-A, eqs. 2-7).

One jitted ``round_step`` executes an entire FedSGD round on device over the
packed ``[R, 128]`` parameter buffer (core/packing.py):

  1. importance Q = (w * v)^2 (eq. 4) over the packed buffer;
  2. the global pruning threshold — the k-th smallest prunable importance,
     k = floor(lambda * M_prunable) — via an on-device binary search over
     fp32 bit patterns (`kth_smallest_threshold`; no sort, no host
     `np.partition`, no device->host parameter transfer);
  3. fused importance+keep-mask Pallas launch (kernels/pruning_mask.py) —
     one kernel for the whole model instead of one per leaf; when every
     selected client shares lambda the threshold and mask are computed once
     (no per-client recompute), otherwise the batched kernel emits all
     per-client masks from a single read of (w, v);
  4. per-client mini-batch gradients on the pruned model (eq. 5) over the
     stacked client batches — gradients are taken directly with respect to
     the packed buffer (unpacking is differentiable) and masked on device
     (pruned coordinates are never "uploaded");
  5. fused aggregate+update Pallas launch: average the stacked gradients
     (eq. 6) and take the FedSGD step (eq. 7) in one pass; the mean gradient
     doubles as the next round's broadcast v.

The client axis (step 4) supports three strategies:

  * ``"scan"`` (the ``"auto"`` default) — `lax.scan` over the stacked
    batches: O(1) program size in the client count and the fastest path in
    practice; the loop boundary materializes each client's masked gradient,
    which keeps the per-client backward identical to the reference loop's.
  * ``"unroll"`` — a statically unrolled loop inside the jit; same results,
    compile time grows with the client count.
  * ``"vmap"`` — batched clients; best on accelerators with spare
    parallelism, but the batched backward may differ from the reference at
    the ulp level (reassociated reductions).

With scan/unroll (and ``kernel_impl="xla"``) the packed engine reproduces
the reference trainer **bit-for-bit** on fp32 models (tests/
test_packing.py); the one genuine hazard — XLA contracting the update's
`w - eta*g` into an FMA and skipping the product's rounding — is fenced in
`kernels/ops._rounded_product`. Only the integer k = floor(lambda *
M_prunable) is computed on host (O(1) scalar arithmetic on the schedule's
lambda); parameters never leave the device.

With ``donate=True`` (used by `FederatedTrainer`, which owns the buffers)
the parameter / global-gradient buffers are donated to the step on
accelerator backends and updated in place round over round; the default
keeps ``round_step`` purely functional.
"""
from __future__ import annotations

from typing import Any, Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.packing import ParamPack
from repro.kernels import ops

PyTree = Any


def kth_smallest_threshold(q: jnp.ndarray, prunable: jnp.ndarray,
                           k: jnp.ndarray) -> jnp.ndarray:
    """Threshold such that exactly k prunable entries are strictly below it.

    Matches `pruning.global_threshold` bit-for-bit: the k-th smallest
    prunable importance, nudged one ulp up (`nextafter`), computed entirely
    on device. `k` may be a scalar or a [C] vector of per-client counts
    (one pass amortized across clients).

    Exact selection without a sort: importance scores are non-negative, and
    for non-negative IEEE-754 floats the value order equals the integer
    order of the bit patterns, so the k-th smallest element is found by a
    31-step binary search over bit patterns with one masked count per step
    (~10x faster than `jnp.sort` on CPU, O(n) instead of O(n log n)).
    """
    bits = jax.lax.bitcast_convert_type(q.reshape(-1), jnp.int32)
    valid = prunable.reshape(-1) > 0
    k = jnp.asarray(k, jnp.int32)

    def body(_, lohi):
        lo, hi = lohi
        mid = lo + (hi - lo) // 2   # (lo+hi)//2 overflows int32 for q >= 2.0
        below = jnp.where(valid, bits[..., :] <= mid[..., None], False)
        ge = below.sum(axis=-1) >= k
        return jnp.where(ge, lo, mid + 1), jnp.where(ge, mid, hi)

    lo0 = jnp.zeros(k.shape, jnp.int32)
    hi0 = jnp.full(k.shape, jnp.int32(2**31 - 1))
    lo, _ = jax.lax.fori_loop(0, 31, body, (lo0, hi0))
    kth = jax.lax.bitcast_convert_type(lo, jnp.float32)
    return jnp.where(k > 0, jnp.nextafter(kth, jnp.inf),
                     -jnp.asarray(jnp.inf, jnp.float32))


class RoundEngine:
    """Jitted packed-buffer FedSGD round (selection -> pruning -> aggregate).

    Parameters
    ----------
    loss_fn : loss(params_pytree, x, y) -> scalar; the engine differentiates
        it through `pack.unpack`, so gradients live on the packed buffer.
    pack : ParamPack describing the model layout.
    eta : FedSGD learning rate (compile-time constant).
    """

    def __init__(self, loss_fn: Callable, pack: ParamPack, *, eta: float,
                 client_axis: str = "auto", kernel_impl: str = "auto",
                 donate: bool = False):
        if client_axis not in ("auto", "unroll", "scan", "vmap"):
            raise ValueError(f"unknown client_axis {client_axis!r}")
        self.pack = pack
        self.eta = float(eta)
        self.client_axis = client_axis
        self.kernel_impl = kernel_impl
        self.prunable = jnp.asarray(pack.prunable_mask())

        def packed_loss(wp, x, y):
            return loss_fn(pack.unpack(wp), x, y)

        self._value_and_grad = jax.value_and_grad(packed_loss)
        # donate=True lets XLA update the parameter / global-gradient
        # buffers in place on accelerators, but the caller must then treat
        # the passed-in (w, v) as consumed — reading them after round_step
        # raises a deleted-buffer error. Only enable it for owners of the
        # buffers (FederatedTrainer does); the default keeps round_step
        # purely functional. CPU does not implement donation, so skip it
        # there to avoid per-compile warnings.
        donate_args = ((0, 1) if donate
                       and jax.default_backend() in ("tpu", "gpu") else ())
        self._step_shared = jax.jit(self._shared_impl,
                                    donate_argnums=donate_args)
        self._step_multi = jax.jit(self._multi_impl,
                                   donate_argnums=donate_args)

    # -- jitted bodies ------------------------------------------------------

    @property
    def _axis(self) -> str:
        # "auto" = scan: O(1) program size in the client count, and it
        # empirically beats the unrolled loop once the whole round is fused
        # into one program, with the same bit-for-bit results.
        return "scan" if self.client_axis == "auto" else self.client_axis

    def _grads_shared(self, pruned, mask, xs, ys):
        """Shared-lambda client axis: every client sees the same pruned
        buffer / mask [R, L] (never materialized per client). Returns
        (losses [C], masked grads [C, R, L])."""
        n_clients = xs.shape[0]
        ax = self._axis
        if ax == "unroll":
            out = [self._value_and_grad(pruned, xs[c], ys[c])
                   for c in range(n_clients)]
            return (jnp.stack([l for l, _ in out]),
                    jnp.stack([g * mask for _, g in out]))
        if ax == "vmap":
            losses, grads = jax.vmap(
                lambda x, y: self._value_and_grad(pruned, x, y))(xs, ys)
            return losses, grads * mask

        def body(carry, inp):
            x, y = inp
            loss, g = self._value_and_grad(pruned, x, y)
            return carry, (loss, g * mask)

        _, (losses, grads) = jax.lax.scan(body, 0.0, (xs, ys))
        return losses, grads

    def _grads_multi(self, w, masks, xs, ys):
        """Per-client-lambda client axis: masks are [C, R, L]. Each client's
        pruned buffer w * masks[c] is formed inside its own step so the
        [C, R, L] stack of pruned models is never materialized."""
        n_clients = xs.shape[0]
        ax = self._axis
        if ax == "unroll":
            out = [self._value_and_grad(w * masks[c], xs[c], ys[c])
                   for c in range(n_clients)]
            return (jnp.stack([l for l, _ in out]),
                    jnp.stack([g * masks[c] for c, (_, g) in enumerate(out)]))
        if ax == "vmap":
            losses, grads = jax.vmap(
                lambda m, x, y: self._value_and_grad(w * m, x, y))(
                    masks, xs, ys)
            return losses, grads * masks

        def body(carry, inp):
            m, x, y = inp
            loss, g = self._value_and_grad(w * m, x, y)
            return carry, (loss, g * m)

        _, (losses, grads) = jax.lax.scan(body, 0.0, (masks, xs, ys))
        return losses, grads

    def _shared_impl(self, w, v, xs, ys, k):
        q = (w * v) ** 2
        thr = kth_smallest_threshold(q, self.prunable, k)
        _, mask = ops.packed_importance_mask(w, v, self.prunable, thr,
                                             impl=self.kernel_impl)
        pruned = w * mask
        losses, grads = self._grads_shared(pruned, mask, xs, ys)
        # step stays an output of the jitted graph: see packed_fedsgd_update
        w2, g, step = ops.packed_fedsgd_update(w, grads, self.eta,
                                               impl=self.kernel_impl)
        return w2, g, losses, thr, step

    def _multi_impl(self, w, v, xs, ys, ks):
        q = (w * v) ** 2
        thr = kth_smallest_threshold(q, self.prunable, ks)      # [C]
        _, masks = ops.packed_importance_masks(w, v, self.prunable, thr,
                                               impl=self.kernel_impl)
        losses, grads = self._grads_multi(w, masks, xs, ys)
        w2, g, step = ops.packed_fedsgd_update(w, grads, self.eta,
                                               impl=self.kernel_impl)
        return w2, g, losses, thr, step

    # -- public API ---------------------------------------------------------

    def init_buffers(self, params: PyTree) -> tuple[jnp.ndarray, jnp.ndarray]:
        w = self.pack.pack(params)
        return w, jnp.zeros_like(w)

    def round_step(self, w, v, xs, ys, lams):
        """One full round. xs: [C, B, ...], ys: [C, B], lams: [C] host-side
        pruning ratios for the selected clients. Returns (w', v', losses [C],
        threshold, step) — all device arrays; nothing is synced to host.
        `step` is the applied update eta*v' (kept as an output so the
        update's multiply can never be FMA-contracted — the bit-for-bit
        contract with the reference trainer depends on it)."""
        lams = np.atleast_1d(np.asarray(lams, np.float64))
        if np.any((lams < 0.0) | (lams >= 1.0)):
            raise ValueError(f"lambda must be in [0,1), got {lams}")
        ks = np.floor(lams * self.pack.n_prunable).astype(np.int32)
        if np.all(ks == ks[0]):
            return self._step_shared(w, v, xs, ys,
                                     jnp.asarray(ks[0], jnp.int32))
        return self._step_multi(w, v, xs, ys, jnp.asarray(ks))
